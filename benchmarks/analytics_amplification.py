"""Paper Fig. 2: I/O amplification of the CPU-centric model on the six
data-dependent taxi queries (and BaM's, for contrast)."""
from benchmarks.common import scaled
from repro.analytics import (QUERIES, make_taxi_table, run_query,
                             run_query_baseline)


def run():
    tbl = make_taxi_table(scaled(1 << 16, 1 << 12), seed=0)
    rows = []
    for q in QUERIES:
        _, io = run_query(tbl, q)
        _, iob = run_query_baseline(tbl, q)
        rows.append((
            f"amplification/{q}", 0.0,
            f"cpu_centric={iob['amplification']:.2f}x "
            f"bam={io['amplification']:.2f}x "
            f"(paper Q1: 6.34x, Q2: 10.36x cpu-centric)"))
    return rows
