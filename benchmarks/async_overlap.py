"""First-class async I/O: submission-window depth sweep (§II-C, Table I).

The paper's central quantitative argument is Little's law: to sustain
target IOPS ``T`` against device latency ``L`` the queues must hold
``Q_d = T x L`` requests *in flight* — far more than one wavefront's worth.
A synchronous per-op API (``read`` = submit **and** drain) can never get
there: every wavefront drains alone at its own shallow concurrency.

This benchmark drives the same request stream through the token API at
submission-window depths 1→8: ``window`` wavefronts are submitted
back-to-back (their SQ commands coexist in the rings) before the oldest is
waited, so each drain retires ``window×`` the commands at ``window×`` the
concurrency.  Reported per window:

* ``sim_time_s``  — total simulated device time (the Little's-law win);
* ``max_tokens_in_flight`` — the measured window (must reach the config);
* ``max_queue_depth`` — in-flight SQ commands at the high watermark.

Standalone (``python benchmarks/async_overlap.py``) prints a JSON report
and exits nonzero unless, at window ≥ 4, (a) the measured in-flight token
window reaches the configured depth, (b) the measured SQ depth reaches
``window ×`` the per-wavefront command count, and (c) async total time is
no worse than the synchronous per-op time on the identical stream — the
PR's acceptance gate, CI-runnable.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import SMOKE, scaled
except ImportError:        # standalone: python benchmarks/<module>.py
    from common import SMOKE, scaled
from repro.core import BamArray, IORequest
from repro.core.ssd import ArrayOfSSDs, INTEL_OPTANE_P5800X

BLOCK_ELEMS = 128                  # 512B lines of float32
BLOCKS_PER_WAVE = scaled(64, 16)   # distinct lines touched per wavefront
WAVES = scaled(32, 4)              # wavefronts in the stream
WINDOWS = (1, 2, 4, 8)
N_BLOCKS = BLOCKS_PER_WAVE * WAVES


def _build():
    data = np.random.default_rng(1).standard_normal(
        (N_BLOCKS, BLOCK_ELEMS)).astype(np.float32)
    # cache sized to hold the deepest window's pinned lines comfortably
    return BamArray.build(
        data, block_elems=BLOCK_ELEMS,
        num_sets=max(2 * max(WINDOWS) * BLOCKS_PER_WAVE // 8, 4), ways=8,
        num_queues=8, queue_depth=1024,
        ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, 1))


def _wave_idx(w: int) -> jnp.ndarray:
    """One element per block of wave ``w``'s disjoint block range: the
    wavefront coalesces to exactly BLOCKS_PER_WAVE storage commands."""
    blocks = w * BLOCKS_PER_WAVE + np.arange(BLOCKS_PER_WAVE)
    return jnp.asarray(blocks * BLOCK_ELEMS, jnp.int32)


def _run_sync() -> dict:
    arr, st = _build()
    read = arr.read_jit()
    checksum = 0.0
    for w in range(WAVES):
        v, st = read(st, _wave_idx(w))
        checksum += float(v.sum())
    m = st.metrics.summary()
    m["checksum"] = checksum
    return m


def _run_async(window: int) -> dict:
    arr, st = _build()
    submit = arr.submit_jit()
    wait = arr.wait_jit()
    checksum = 0.0
    for base in range(0, WAVES, window):
        chunk = range(base, min(base + window, WAVES))
        toks = []
        for w in chunk:                       # fill the submission window
            st, tok = submit(st, IORequest.read(_wave_idx(w)))
            toks.append(tok)
        for tok in toks:                      # drain it FIFO
            st, v = wait(st, tok)
            checksum += float(v.sum())
    m = st.metrics.summary()
    m["checksum"] = checksum
    assert int(st.cache.refcount.sum()) == 0, "leaked pins"
    return m


def sweep() -> dict:
    sync = _run_sync()
    report = {
        "workload": {"n_blocks": N_BLOCKS, "block_bytes": BLOCK_ELEMS * 4,
                     "blocks_per_wave": BLOCKS_PER_WAVE, "waves": WAVES},
        "sync": {"sim_time_s": sync["sim_time_s"],
                 "max_queue_depth": sync["max_queue_depth"],
                 "checksum": sync["checksum"]},
        "windows": [],
    }
    for w in WINDOWS:
        m = _run_async(w)
        report["windows"].append({
            "window": w,
            "sim_time_s": m["sim_time_s"],
            "speedup_vs_sync": sync["sim_time_s"] / max(m["sim_time_s"],
                                                        1e-30),
            "max_tokens_in_flight": m["max_tokens_in_flight"],
            "max_queue_depth": m["max_queue_depth"],
            "tokens_submitted": m["tokens_submitted"],
            "values_match_sync": abs(m["checksum"] - sync["checksum"])
                <= 1e-6 * max(abs(sync["checksum"]), 1.0),
        })
    deep = [p for p in report["windows"] if p["window"] >= 4]
    report["window_reached"] = all(
        p["max_tokens_in_flight"] == p["window"] for p in deep)
    report["queue_depth_reached"] = all(
        p["max_queue_depth"] >= p["window"] * BLOCKS_PER_WAVE for p in deep)
    report["async_no_slower"] = all(
        p["sim_time_s"] <= report["sync"]["sim_time_s"] * (1 + 1e-6)
        for p in report["windows"])
    report["values_ok"] = all(p["values_match_sync"]
                              for p in report["windows"])
    report["gate_ok"] = (report["window_reached"]
                         and report["queue_depth_reached"]
                         and report["async_no_slower"]
                         and report["values_ok"])
    return report


def run():
    rep = sweep()
    rows = [(
        "async_overlap/sync_per_op", rep["sync"]["sim_time_s"] * 1e6,
        f"depth={rep['sync']['max_queue_depth']}")]
    for p in rep["windows"]:
        rows.append((
            f"async_overlap/window_{p['window']}",
            p["sim_time_s"] * 1e6,
            f"speedup={p['speedup_vs_sync']:.2f}x "
            f"tokens={p['max_tokens_in_flight']} "
            f"depth={p['max_queue_depth']}"))
    return rows


if __name__ == "__main__":
    rep = sweep()
    print(json.dumps(rep, indent=2))
    # Thresholds are calibrated for full sizes; at smoke sizes assert only
    # that the sweep runs and values stay correct.
    ok = rep["values_ok"] and (SMOKE or rep["gate_ok"])
    raise SystemExit(0 if ok else 1)
