"""Paper Fig. 8: impact of BaM cache-line (access granularity) 512B..8KB.

Expected reproduction of the paper's findings on graph workloads:
(a) fine grain minimises I/O amplification; (b) the workload's spatial
locality (neighbor lists) makes larger lines cheaper in device time until
the link saturates (4KB sweet spot, 8KB flat).
"""
from benchmarks.common import scaled
from repro.core.ssd import ArrayOfSSDs, INTEL_OPTANE_P5800X
from repro.graph import BamGraph, bfs, random_graph


def run():
    rows = []
    indptr, dst = random_graph(scaled(2000, 300), 12.0, seed=7)
    for line in scaled((512, 1024, 2048, 4096, 8192), (512, 4096)):
        g = BamGraph.build(indptr, dst, cacheline_bytes=line,
                           cache_bytes=1 << 16,
                           ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, 1))
        _, st = bfs(g, 0)
        m = st.metrics.summary()
        rows.append((
            f"cacheline/bfs_{line}B", m["sim_time_s"] * 1e6,
            f"amp={m['amplification']:.2f} misses={m['misses']:.0f} "
            f"iops={m['read_iops']/1e6:.2f}M"))
    return rows
