"""Shared benchmark helpers: timing, CSV row emission, CI smoke mode."""
from __future__ import annotations

import os
import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]     # (name, us_per_call, derived)

# Same guard as tests/conftest.py: on single-core machines XLA's async CPU
# dispatch can deadlock (the client thread pool is sized by core count, so
# a dependent dispatch — e.g. a state-threading timing loop — waits on a
# worker that never frees up).  Synchronous dispatch sidesteps it and, with
# no parallelism to lose, does not change what the timings measure.
if os.cpu_count() == 1:
    try:
        import jax as _jax
        _jax.config.update("jax_cpu_enable_async_dispatch", False)
    except ImportError:
        pass

# CI smoke mode: BAM_BENCH_SMOKE=1 shrinks every module's problem sizes so
# the whole suite exercises its code paths in seconds.  The numbers are
# meaningless in smoke mode — the run only asserts that nothing crashes.
SMOKE = os.environ.get("BAM_BENCH_SMOKE", "") not in ("", "0")


def scaled(full, tiny):
    """Pick the real size, or the tiny one under BAM_BENCH_SMOKE=1."""
    return tiny if SMOKE else full


def _block(out) -> None:
    """Wait for async JAX dispatch on ``out`` (no-op without jax).

    Only the *import* is guarded: an error raised by the computation
    itself at block time must propagate, or timed loops would report
    dispatch-only wall clock for ops that never actually completed.
    """
    try:
        import jax
    except ImportError:
        return
    jax.block_until_ready(out)


def time_us(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Host wall-clock per call, in µs.

    Every warmup *and* timed iteration blocks on its output: leftover
    async dispatch from warmup never leaks into the timed window, and the
    clock stops only when the last iteration's values actually exist.
    """
    out = None
    for _ in range(warmup):
        out = fn(*args)
    _block(out)                       # drain warmup dispatch before t0
    t0 = time.perf_counter()
    for _ in range(iters):
        _block(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def time_us_state(step: Callable, state, *, warmup: int = 2,
                  iters: int = 5) -> float:
    """Host wall-clock per call for *state-threading* steps, in µs.

    ``step(state) -> state'`` is iterated, feeding each result into the
    next call.  This is the only timing shape compatible with buffer
    donation (``*_jit(donate=True)``): re-calling with a previously
    donated argument — what :func:`time_us` does — would read dead
    buffers.  Each iteration blocks on its own output, so the clock
    measures completed rounds, not enqueued dispatch.
    """
    for _ in range(warmup):
        state = step(state)
    _block(state)
    t0 = time.perf_counter()
    for _ in range(iters):
        state = step(state)
        _block(state)
    return (time.perf_counter() - t0) / iters * 1e6
