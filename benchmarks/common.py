"""Shared benchmark helpers: timing + CSV row emission."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]     # (name, us_per_call, derived)


def time_us(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    # block on async dispatch if jax arrays
    try:
        import jax
        jax.block_until_ready(out)
    except Exception:
        pass
    return (time.perf_counter() - t0) / iters * 1e6
