"""Shared benchmark helpers: timing, CSV row emission, CI smoke mode."""
from __future__ import annotations

import os
import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]     # (name, us_per_call, derived)

# CI smoke mode: BAM_BENCH_SMOKE=1 shrinks every module's problem sizes so
# the whole suite exercises its code paths in seconds.  The numbers are
# meaningless in smoke mode — the run only asserts that nothing crashes.
SMOKE = os.environ.get("BAM_BENCH_SMOKE", "") not in ("", "0")


def scaled(full, tiny):
    """Pick the real size, or the tiny one under BAM_BENCH_SMOKE=1."""
    return tiny if SMOKE else full


def time_us(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    # block on async dispatch if jax arrays
    try:
        import jax
        jax.block_until_ready(out)
    except Exception:
        pass
    return (time.perf_counter() - t0) / iters * 1e6
