"""Paper Fig. 7 / §IV-A: per-device I/O channels — IOPS scaling and skew.

Sweeps ``n_devices`` ∈ {1, 2, 4, 8} behind one accelerator with the full
BaM stack (coalesce → per-device SQ groups → per-device Little's-law
drain), under three (stream, placement) combinations:

* **uniform** — random blocks over cache-line striping: channels balance
  and aggregate read IOPS scales near-linearly until the PCIe Gen4 x16
  link (26.3 GBps measured) gates further gains;
* **zipf** — a Zipfian block stream over the same fine striping: the
  coalescer absorbs per-line popularity (duplicates collapse to one
  fetch) and line-grain interleaving scatters the hot set, so the
  channels stay near-balanced — the paper's argument *for* fine-grain
  striping, now measurable;
* **zipf_sharded** — the same Zipfian stream over shard-aligned placement
  (one contiguous shard per device, ``stripe_blocks = n_blocks /
  n_devices``): the hot head of the distribution lives on one device,
  the wavefront completes when that straggler drains (max over devices,
  not the old averaged single budget), and the *straggler gap*
  (max/mean per-device busy time) exposes it.

Standalone (``python benchmarks/device_channels.py``) prints a JSON report
and exits nonzero if uniform 1→4-device scaling falls under 3x or the
sharded Zipfian straggler gap is not measurably above uniform's — the
acceptance self-check, CI-runnable as a regression gate.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import SMOKE, scaled
except ImportError:        # standalone: python benchmarks/<module>.py
    from common import SMOKE, scaled
from repro.core import BamArray
from repro.core.ssd import ArrayOfSSDs, INTEL_OPTANE_P5800X

N_BLOCKS = scaled(1 << 16, 1 << 10)
BLOCK_ELEMS = 128                  # 512B lines of float32
WAVEFRONT = scaled(4096, 256)
WAVES = scaled(8, 2)
DEVICES = (1, 2, 4, 8)


def _streams(n_blocks: int, rng):
    """[(name, stripe_fn, per-wave block-id arrays)] — stripe_fn(nd) gives
    the striping unit for that device count."""
    zipf_blocks = np.minimum(rng.zipf(1.2, size=(WAVES, WAVEFRONT)),
                             n_blocks) - 1
    uniform = [rng.integers(0, n_blocks, WAVEFRONT) for _ in range(WAVES)]
    zipf = [zipf_blocks[w] for w in range(WAVES)]
    line_grain = lambda nd: 1
    shard_grain = lambda nd: max(n_blocks // nd, 1)
    return [("uniform", line_grain, uniform),
            ("zipf", line_grain, zipf),
            ("zipf_sharded", shard_grain, zipf)]


def _run_stream(data, n_devices: int, stripe_blocks: int, waves) -> dict:
    """Cold cache per point; tiny cache so every wavefront exercises the
    channels rather than the cache."""
    arr, st = BamArray.build(
        data, block_elems=BLOCK_ELEMS,
        num_sets=4, ways=4,                   # tiny cache: force misses
        num_queues=8 * max(n_devices, 1), queue_depth=1024,
        ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, n_devices,
                        stripe_blocks=stripe_blocks))
    read = arr.read_jit()
    for wave in waves:
        _, st = read(st, jnp.asarray(wave * BLOCK_ELEMS, jnp.int32))
    return st.metrics.summary()


def sweep() -> dict:
    rng = np.random.default_rng(0)
    data = np.random.default_rng(1).standard_normal(
        (N_BLOCKS, BLOCK_ELEMS)).astype(np.float32)
    report = {"workload": {"n_blocks": N_BLOCKS, "block_bytes":
                           BLOCK_ELEMS * 4, "wavefront": WAVEFRONT,
                           "waves": WAVES}, "streams": {}}
    for name, stripe_fn, waves in _streams(N_BLOCKS, rng):
        points = []
        for nd in DEVICES:
            m = _run_stream(data, nd, stripe_fn(nd), waves)
            points.append({
                "n_devices": nd,
                "stripe_blocks": stripe_fn(nd),
                "read_iops": m["read_iops"],
                "read_time_s": m["read_time_s"],
                "straggler_gap": m["straggler_gap"],
                "dev_reads": m["dev_reads"],
                "misses": m["misses"],
            })
        base = points[0]["read_iops"]
        for p in points:
            p["speedup_vs_1dev"] = p["read_iops"] / max(base, 1e-30)
        report["streams"][name] = points
    uni = {p["n_devices"]: p for p in report["streams"]["uniform"]}
    shard = {p["n_devices"]: p for p in report["streams"]["zipf_sharded"]}
    report["uniform_scaling_1_to_4"] = uni[4]["speedup_vs_1dev"]
    report["uniform_straggler_gap_4dev"] = uni[4]["straggler_gap"]
    report["zipf_sharded_straggler_gap_4dev"] = shard[4]["straggler_gap"]
    report["scaling_ok"] = report["uniform_scaling_1_to_4"] >= 3.0
    report["skew_visible"] = (
        report["zipf_sharded_straggler_gap_4dev"]
        > report["uniform_straggler_gap_4dev"] + 0.25)
    return report


def run():
    rep = sweep()
    rows = []
    for name, points in rep["streams"].items():
        for p in points:
            rows.append((
                f"device_channels/{name}_{p['n_devices']}dev",
                p["read_time_s"] * 1e6,
                f"iops={p['read_iops']/1e6:.2f}M "
                f"speedup={p['speedup_vs_1dev']:.2f}x "
                f"straggler={p['straggler_gap']:.2f}"))
    return rows


if __name__ == "__main__":
    rep = sweep()
    print(json.dumps(rep, indent=2))
    # The thresholds are calibrated for full sizes; at smoke sizes the
    # wavefronts are too small to saturate 4 channels, so only assert the
    # sweep runs.
    ok = SMOKE or (rep["scaling_ok"] and rep["skew_visible"])
    raise SystemExit(0 if ok else 1)
