"""Fault sweep: throughput + correctness under injected storage errors.

Sweeps the :class:`~repro.core.ssd.FaultModel` transient error rate
(0 .. 1%) x retry budget over a streaming read workload, plus a
hard-failed-device dropout point, and self-checks the robustness PR's
acceptance gates:

* **zero-fault identity** — a *disabled* model (rate 0, no failed
  devices, whatever the other knobs) is bit-identical in values and
  metrics to the default build;
* **smooth degradation** — with a modest retry budget, effective
  throughput at a 1% transient rate stays within a few percent of clean
  (retries recover transients; the backoff surcharge is bounded by
  ``rate x tail_latency_mult``), and no cliff appears at any swept rate;
* **exact conservation** — per device, accepted commands equal drained
  commands and ``failed_commands == sum(dev_errors)``; per tenant, the
  shared-runtime error counters sum exactly to the global ones;
* **no garbage fills** — every lane the wait reports OK is
  element-exact against the host array, every errored lane reads 0, and
  at ``rate=1.0, budget=0`` not a single cache line is ever filled.

Standalone (``python benchmarks/fault_sweep.py``) prints a JSON report
and exits nonzero if any gate fails.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import SMOKE, scaled
except ImportError:        # standalone: python benchmarks/<module>.py
    from common import SMOKE, scaled
from repro.core import BamArray, BamRuntime, IORequest, TenantSpec
from repro.core.ssd import ArrayOfSSDs, FaultModel, INTEL_OPTANE_P5800X

N_BLOCKS = scaled(1 << 14, 1 << 9)
BLOCK_ELEMS = 128                  # 512B lines of float32
WAVEFRONT = scaled(2048, 128)
WAVES = scaled(6, 2)
N_DEVICES = 4
RATES = (0.0, 1e-4, 1e-3, 1e-2)
BUDGETS = (0, 2)


def _tree_bits(tree):
    return [np.asarray(jax.device_get(x)).tobytes()
            for x in jax.tree_util.tree_leaves(tree)]


def _run_stream(data, fault, waves) -> dict:
    """Replay the wavefronts; verify the value contract on every wave."""
    arr, st = BamArray.build(
        data, block_elems=BLOCK_ELEMS,
        num_sets=16, ways=4,
        num_queues=2 * N_DEVICES, queue_depth=1024,
        ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, N_DEVICES, fault=fault))
    flat = data.reshape(-1)
    vals_bits, degraded, lanes = [], 0, 0
    for wave in waves:
        idx = jnp.asarray(wave, jnp.int32)
        st, tok = arr.submit(st, IORequest.read(idx))
        st, vals, err = arr.wait_ex(st, tok)
        v, e = np.asarray(vals), np.asarray(err)
        ok = ~e
        if not np.array_equal(v[ok], flat[wave][ok]):
            raise AssertionError("OK lane returned a wrong value")
        if e.any() and v[e].any():
            raise AssertionError("errored lane returned non-zero data")
        vals_bits.append(v.tobytes())
        degraded += int(e.sum())
        lanes += len(wave)
    m = st.metrics
    qs = st.queues
    conserved = (
        np.array_equal(np.asarray(qs.dev_enqueued),
                       np.asarray(qs.dev_completed))
        and int(m.failed_commands) == int(np.asarray(m.dev_errors).sum())
        and int(m.degraded_reads) == degraded)
    return {
        "sim_time_s": float(m.sim_time_s),
        "read_iops": m.summary()["read_iops"],
        "retries": int(m.retries),
        "transient_errors": int(m.transient_errors),
        "failed_commands": int(m.failed_commands),
        "degraded_frac": degraded / max(lanes, 1),
        "dev_reads": [int(x) for x in np.asarray(m.dev_reads)],
        "conserved": bool(conserved),
        "_vals_bits": vals_bits,
        "_metrics_bits": _tree_bits(m),
    }


def _tenant_conservation(data, fault) -> bool:
    """Two shared-runtime tenants under faults: counters must sum exactly."""
    rt, rst = BamRuntime.build(
        [TenantSpec("a", data[:N_BLOCKS // 2], block_elems=BLOCK_ELEMS),
         TenantSpec("b", data[N_BLOCKS // 2:], block_elems=BLOCK_ELEMS,
                    weight=2.0)],
        num_sets=8, ways=4, num_queues=2 * N_DEVICES, queue_depth=256,
        ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, N_DEVICES, fault=fault))
    rng = np.random.default_rng(3)
    n = data[:N_BLOCKS // 2].size
    for _ in range(scaled(3, 1)):
        for name in ("a", "b"):
            idx = jnp.asarray(rng.integers(0, n, WAVEFRONT // 2), jnp.int32)
            rst, tok = rt.submit(rst, name, IORequest.read(idx))
            rst, _, _ = rt.wait_ex(rst, name, tok)
    try:
        rt.assert_metrics_consistent(rst)
    except AssertionError:
        return False
    return True


def sweep() -> dict:
    rng = np.random.default_rng(0)
    data = np.random.default_rng(1).standard_normal(
        (N_BLOCKS, BLOCK_ELEMS)).astype(np.float32)
    waves = [rng.integers(0, N_BLOCKS, WAVEFRONT) * BLOCK_ELEMS
             + rng.integers(0, BLOCK_ELEMS, WAVEFRONT)
             for _ in range(WAVES)]

    report = {"workload": {"n_blocks": N_BLOCKS,
                           "block_bytes": BLOCK_ELEMS * 4,
                           "wavefront": WAVEFRONT, "waves": WAVES,
                           "n_devices": N_DEVICES},
              "points": []}

    clean = _run_stream(data, FaultModel(), waves)
    # gate 1: a disabled model with non-default knobs is bit-identical
    shadow = _run_stream(data, FaultModel(transient_error_rate=0.0,
                                          tail_latency_mult=8.0,
                                          retry_budget=7, seed=99), waves)
    report["zero_fault_identical"] = (
        clean["_vals_bits"] == shadow["_vals_bits"]
        and clean["_metrics_bits"] == shadow["_metrics_bits"])

    conserved_all, cliff_free = True, True
    for budget in BUDGETS:
        for rate in RATES:
            fault = FaultModel(transient_error_rate=rate,
                               retry_budget=budget,
                               tail_latency_mult=2.0, seed=7)
            p = _run_stream(data, fault, waves)
            conserved_all &= p["conserved"]
            point = {k: v for k, v in p.items()
                     if not k.startswith("_")}
            point.update(rate=rate, retry_budget=budget,
                         throughput_vs_clean=(
                             clean["sim_time_s"] / max(p["sim_time_s"],
                                                       1e-30)))
            report["points"].append(point)

    # gate 2: smooth degradation with retries — at 1% rate and budget=2
    # the failure probability per command is rate^3 (~1e-6): effectively
    # zero degraded lanes and a bounded backoff surcharge.
    recovered = [p for p in report["points"]
                 if p["retry_budget"] == 2 and p["rate"] == 1e-2][0]
    report["degraded_frac_at_1pct"] = recovered["degraded_frac"]
    report["throughput_vs_clean_at_1pct"] = \
        recovered["throughput_vs_clean"]
    cliff_free = (recovered["degraded_frac"] <= 1e-3
                  and recovered["throughput_vs_clean"] >= 0.9)
    # and no swept point may lose more than its backoff bound explains:
    for p in report["points"]:
        if p["retry_budget"] == 2 and p["throughput_vs_clean"] < 0.8:
            cliff_free = False

    # gate 3: total failure never fills a line (tiny probe workload)
    probe = data[:4]
    arr, st = BamArray.build(
        probe, block_elems=BLOCK_ELEMS, num_sets=4, ways=2,
        num_queues=2 * N_DEVICES, queue_depth=64,
        ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, N_DEVICES,
                        fault=FaultModel(transient_error_rate=1.0,
                                         retry_budget=0)))
    idx = jnp.arange(4, dtype=jnp.int32) * BLOCK_ELEMS
    st, tok = arr.submit(st, IORequest.read(idx))
    st, vals, err = arr.wait_ex(st, tok)
    report["no_fill_on_total_failure"] = (
        bool(np.asarray(err).all())
        and not np.asarray(vals).any()
        and bool((np.asarray(st.cache.tags) == -1).all())
        and not np.asarray(st.cache.refcount).any())

    # gate 4: device dropout — survivors absorb the dead device's blocks
    drop = _run_stream(
        data, FaultModel(failed_devices=(2,)), waves)
    report["dropout"] = {k: v for k, v in drop.items()
                        if not k.startswith("_")}
    report["dropout"]["values_exact"] = \
        drop["_vals_bits"] == clean["_vals_bits"]
    report["dropout_ok"] = (
        drop["dev_reads"][2] == 0
        and drop["failed_commands"] == 0
        and report["dropout"]["values_exact"]
        and drop["conserved"])

    report["tenant_counters_exact"] = _tenant_conservation(
        data, FaultModel(transient_error_rate=5e-3, retry_budget=1,
                         seed=11))

    report["conserved_all"] = conserved_all
    report["cliff_free"] = cliff_free
    report["clean_sim_time_s"] = clean["sim_time_s"]
    return report


def run():
    rep = sweep()
    rows = []
    for p in rep["points"]:
        rows.append((
            f"fault_sweep/rate{p['rate']:g}_budget{p['retry_budget']}",
            p["sim_time_s"] * 1e6 / WAVES,
            f"tput={p['throughput_vs_clean']:.3f}x "
            f"degraded={p['degraded_frac']:.2e} "
            f"retries={p['retries']}"))
    d = rep["dropout"]
    rows.append((
        "fault_sweep/device_dropout_1of4",
        d["sim_time_s"] * 1e6 / WAVES,
        f"tput={rep['clean_sim_time_s'] / max(d['sim_time_s'], 1e-30):.3f}x "
        f"exact={d['values_exact']}"))
    return rows


if __name__ == "__main__":
    rep = sweep()
    print(json.dumps(rep, indent=2))
    # Exactness gates hold at any size; the smooth-degradation thresholds
    # are calibrated for full sizes, so smoke mode only asserts them
    # loosely via the exact gates.
    exact_ok = (rep["zero_fault_identical"] and rep["conserved_all"]
                and rep["no_fill_on_total_failure"] and rep["dropout_ok"]
                and rep["tenant_counters_exact"])
    ok = exact_ok and (SMOKE or rep["cliff_free"])
    raise SystemExit(0 if ok else 1)
