"""Paper Fig. 7: BFS/CC end-to-end — BaM vs the DRAM-only target system T.

Scaled-down reproduction: synthetic graphs stand in for GAP/LAW datasets.
The target system T keeps the edge list in (host) memory after an initial
file load; BaM reads edges on demand from the storage tier.  End-to-end
time uses the Little's-law device model for BaM I/O and a
bandwidth-limited load-time model for T (the paper's key point: T pays the
full file load before any compute; BaM overlaps).
"""
import numpy as np

from benchmarks.common import scaled

from repro.core.ssd import (ArrayOfSSDs, INTEL_OPTANE_P5800X,
                            PCIE_GEN4_X16_BW)
from repro.graph import BamGraph, bfs, cc, random_graph

# sized so the edge list reaches the bandwidth regime of the paper's
# Fig. 7 while staying tractable on one CPU core (larger graphs only make
# BaM look better: the per-iteration latency floor amortises away)
GRAPHS = scaled(
    {"K-like": (6_000, 24.0), "F-like": (4_000, 16.0),
     "U-like": (5_000, 8.0)},
    {"K-like": (600, 8.0)})


def run():
    rows = []
    for name, (n, deg) in GRAPHS.items():
        indptr, dst = random_graph(n, deg, seed=hash(name) % 97)
        edge_bytes = dst.nbytes
        for algo, fn in (("bfs", lambda g: bfs(g, 0)),
                         ("cc", lambda g: cc(g))):
            # paper config: the software cache holds a sizeable fraction
            # of the working set (8GB cache vs ~30GB graphs)
            g = BamGraph.build(indptr, dst, cacheline_bytes=4096,
                               cache_bytes=max(dst.nbytes // 2, 1 << 16),
                               ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, 4))
            _, st = fn(g)
            m = st.metrics.summary()
            bam_t = m["sim_time_s"]
            # target T: full edge-list load at PCIe x16, then compute reads
            # from host memory at the same link
            t_load = edge_bytes / PCIE_GEN4_X16_BW
            t_compute_io = m["bytes_requested"] / PCIE_GEN4_X16_BW
            target_t = t_load + t_compute_io
            rows.append((
                f"graph/{algo}_{name}", bam_t * 1e6,
                f"bam={bam_t*1e3:.3f}ms targetT={target_t*1e3:.3f}ms "
                f"speedup={target_t/max(bam_t,1e-12):.2f}x "
                f"hit={m['hit_rate']:.2f} amp={m['amplification']:.2f}"))
    return rows
