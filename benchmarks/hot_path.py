"""Hot-path wall-clock microbenchmark: fused kernels + the jit-cached op
family (§III-D/E — "the software request path must be cheap enough to
keep the queues full").

BaM's throughput claim is a *request-rate* claim: the Little's-law math
of §II-C only holds if submitting an I/O costs less than servicing it.
This module measures the host-side software cost of every stage of this
repo's request path, at several wavefront sizes:

* ``probe``          — kernel-dispatched tag probe (`cache.probe`);
* ``alloc_fused``    — the fused probe+allocate pass
                       (`cache.probe_allocate`, argsort-free);
* ``alloc_argsort``  — the legacy two-step probe + argsort clock sweep
                       (`cache.allocate`), kept as the baseline the fused
                       pass replaces;
* ``submit`` / ``wait`` — the token API halves, jit-cached
                       (`BamArray.submit_jit` / `wait_jit`);
* ``read_jit``       — end-to-end read through the jit-cached op family;
* ``read_eager``     — the identical read with NO jit: every jnp op
                       dispatches one by one, the state of the hot path
                       before this PR's jit-cached op family.

All numbers are host wall-clock µs per call (``time_us`` blocks on every
iteration's output), with derived ops/sec.  The driver (`run.py`) writes
them to ``BENCH_hot_path.json`` — the repo's measured perf trajectory.

Standalone (``python benchmarks/hot_path.py``) prints a JSON report and
exits nonzero unless (the PR acceptance gate, CI-runnable):

* the jit-cached end-to-end read is ≥ 2× faster than the eager path at
  the largest swept batch (CPU ref backend);
* the fused ``probe_allocate`` kernel (``impl='pallas', interpret=True``)
  is bit-identical to the jnp oracle across a differential mini-sweep;
* steady-state ``read``/``submit``/``wait`` at fixed shapes trigger zero
  retraces after the first call (the trace-count probe).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import SMOKE, scaled, time_us
except ImportError:        # standalone: python benchmarks/<module>.py
    from common import SMOKE, scaled, time_us
from repro.core import BamArray, IORequest
from repro.core import cache as C
from repro.kernels import ops

BLOCK_ELEMS = 128                       # 512B lines of float32
BATCHES = scaled((256, 1024, 4096), (32, 64))
WAYS = 8
NUM_SETS = scaled(512, 16)
N_BLOCKS = 4 * NUM_SETS * WAYS          # 4x oversubscribed storage tier
READ_ITERS = scaled(5, 2)


def _build():
    data = np.random.default_rng(7).standard_normal(
        (N_BLOCKS, BLOCK_ELEMS)).astype(np.float32)
    return BamArray.build(data, block_elems=BLOCK_ELEMS,
                          num_sets=NUM_SETS, ways=WAYS,
                          num_queues=8, queue_depth=4096)


def _warm_cache(m: int):
    """A cache directory with a resident working set + the request mix."""
    rng = np.random.default_rng(m)
    cache = C.make_cache(NUM_SETS, WAYS, BLOCK_ELEMS)
    resident = jnp.asarray(
        rng.choice(N_BLOCKS, NUM_SETS * WAYS // 2, replace=False), jnp.int32)
    cache, _, _ = C.probe_allocate(cache, resident, impl="ref")
    # request mix: half the resident set, half fresh keys
    keys = jnp.asarray(np.concatenate([
        rng.choice(np.asarray(resident), m // 2),
        rng.integers(0, N_BLOCKS, m - m // 2),
    ]).astype(np.int32))
    return jax.block_until_ready(cache), keys


def _stage_times(m: int) -> dict:
    cache, keys = _warm_cache(m)
    # the benchmark deliberately times freshly-built wrappers
    probe = jax.jit(lambda c, k: C.probe(c, k))  # bamlint: ignore[BAM105]
    fused = jax.jit(lambda c, k: C.probe_allocate(c, k))  # bamlint: ignore[BAM105]

    def _two_step(c, k):
        pr = C.probe(c, k)
        return C.allocate(c, k, (k >= 0) & ~pr.hit, protect_slots=pr.slot)

    argsort = jax.jit(_two_step)  # bamlint: ignore[BAM105]
    return {
        "probe_us": time_us(probe, cache, keys),
        "alloc_fused_us": time_us(fused, cache, keys),
        "alloc_argsort_us": time_us(argsort, cache, keys),
    }


def _op_times(arr, st, m: int) -> dict:
    rng = np.random.default_rng(100 + m)
    idx = jnp.asarray(rng.integers(0, arr.size, m), jnp.int32)
    submit = arr.submit_jit()
    wait = arr.wait_jit()
    read = arr.read_jit()
    st1, tok = submit(st, IORequest.read(idx))
    jax.block_until_ready(st1)
    out = {
        "submit_us": time_us(submit, st, IORequest.read(idx)),
        "wait_us": time_us(wait, st1, tok),
        "read_jit_us": time_us(read, st, idx, warmup=1, iters=READ_ITERS),
        "read_eager_us": time_us(arr.read, st, idx, warmup=1,
                                 iters=READ_ITERS),
    }
    out["jit_speedup"] = out["read_eager_us"] / max(out["read_jit_us"], 1e-9)
    out["elems_per_s"] = m / (out["read_jit_us"] * 1e-6)
    return out


def _differential_sweep() -> bool:
    """Fused kernel (pallas, interpret) vs jnp oracle: bit-identical."""
    rng = np.random.default_rng(0)
    cases = [(4, 1, 7), (8, 4, 33), (16, 8, 64)]
    variants = [dict(), dict(tenant=1), dict(way_lo=1, way_hi=3),
                dict(spec_insert=True), dict(protect_hits=False)]
    for S, W, m in cases:
        tags = jnp.asarray(rng.integers(-1, 200, (S, W)), jnp.int32)
        owner = jnp.asarray(rng.integers(0, 2, (S, W)), jnp.int32)
        refc = jnp.asarray(rng.integers(0, 2, (S, W)), jnp.int32)
        dirty = jnp.asarray(rng.integers(0, 2, (S, W)).astype(bool))
        spec = jnp.asarray(rng.integers(0, 2, (S, W)).astype(bool))
        hand = jnp.asarray(rng.integers(0, W, (S,)), jnp.int32)
        keys = jnp.asarray(rng.integers(-1, 250, m), jnp.int32)
        prot = jnp.asarray(rng.integers(-1, S * W, 5), jnp.int32)
        for kw in variants:
            if W == 1 and "way_hi" in kw:
                continue
            args = (tags, owner, refc, dirty, spec, hand, keys)
            r = ops.probe_allocate(*args, protect_slots=prot, impl="ref",
                                   **kw)
            p = ops.probe_allocate(*args, protect_slots=prot, impl="pallas",
                                   interpret=True, **kw)
            for a, b in zip(r, p):
                if not np.array_equal(np.asarray(a), np.asarray(b)):
                    return False
    return True


def _retrace_check() -> bool:
    """Fixed-shape steady state must trace each op exactly once (a fresh
    array, so the sweep's other batch shapes don't pollute the counts)."""
    arr, st = _build()
    idx = jnp.asarray(np.arange(64) * 3 % arr.size, jnp.int32)
    read, submit, wait = arr.read_jit(), arr.submit_jit(), arr.wait_jit()
    for _ in range(3):
        _, st = read(st, idx)
        st, tok = submit(st, IORequest.read(idx))
        st, _ = wait(st, tok)
    tc = arr.trace_counts
    return tc.get("read") == 1 and tc.get("submit") == 1 \
        and tc.get("wait") == 1


def sweep() -> dict:
    arr, st = _build()
    report = {
        "workload": {"block_bytes": BLOCK_ELEMS * 4, "num_sets": NUM_SETS,
                     "ways": WAYS, "n_blocks": N_BLOCKS,
                     "batches": list(BATCHES)},
        "batches": [],
    }
    for m in BATCHES:
        point = {"batch": m}
        point.update(_stage_times(m))
        point.update(_op_times(arr, st, m))
        report["batches"].append(point)
    last = report["batches"][-1]
    report["jit_speedup_at_max"] = last["jit_speedup"]
    report["jit_beats_eager_2x"] = last["jit_speedup"] >= 2.0
    report["differential_ok"] = _differential_sweep()
    report["no_retrace"] = _retrace_check()
    report["gate_ok"] = (report["jit_beats_eager_2x"]
                         and report["differential_ok"]
                         and report["no_retrace"])
    return report


def run():
    rep = sweep()
    rows = []
    for p in rep["batches"]:
        m = p["batch"]
        for stage in ("probe", "alloc_fused", "alloc_argsort", "submit",
                      "wait", "read_eager"):
            us = p[f"{stage}_us"]
            rows.append((
                f"hot_path/{stage}_b{m}", us,
                f"ops_per_s={1e6 / max(us, 1e-9):.0f}"))
        rows.append((
            f"hot_path/read_jit_b{m}", p["read_jit_us"],
            f"ops_per_s={1e6 / max(p['read_jit_us'], 1e-9):.0f} "
            f"speedup_vs_eager={p['jit_speedup']:.2f}x "
            f"elems_per_s={p['elems_per_s']:.0f}"))
    return rows


if __name__ == "__main__":
    rep = sweep()
    print(json.dumps(rep, indent=2))
    # Speedup threshold is calibrated for full sizes; at smoke sizes only
    # correctness (differential + retrace) must hold.
    ok = rep["differential_ok"] and rep["no_retrace"] \
        and (SMOKE or rep["jit_beats_eager_2x"])
    raise SystemExit(0 if ok else 1)
