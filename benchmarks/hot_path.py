"""Hot-path wall-clock microbenchmark: fused kernels + the jit-cached op
family (§III-D/E — "the software request path must be cheap enough to
keep the queues full").

BaM's throughput claim is a *request-rate* claim: the Little's-law math
of §II-C only holds if submitting an I/O costs less than servicing it.
This module measures the host-side software cost of every stage of this
repo's request path, at several wavefront sizes:

* ``probe``          — kernel-dispatched tag probe (`cache.probe`);
* ``alloc_fused``    — the fused probe+allocate pass
                       (`cache.probe_allocate`, argsort-free);
* ``alloc_argsort``  — the legacy two-step probe + argsort clock sweep
                       (`cache.allocate`), kept as the baseline the fused
                       pass replaces;
* ``submit`` / ``wait`` — the token API halves, jit-cached
                       (`BamArray.submit_jit` / `wait_jit`);
* ``submit_wait_fused`` — one full fused round as ONE executable
                       (`BamArray.submit_wait_jit(donate=True)`), state
                       threaded through donated buffers — the
                       steady-state hot path after the fused-round
                       refactor;
* ``submit_wait_pair`` — the same round as the two-executable donated
                       ``submit_jit`` + ``wait_jit`` pair: the delta to
                       ``submit_wait_fused`` is the second dispatch plus
                       the host-side state/token round-trip the fusion
                       removes;
* ``submit_wait_legacy`` — the same round on the step-by-step
                       (``fused_rounds=False``) path, no donation — the
                       pre-fusion baseline, measured at the max batch;
* ``read_jit``       — end-to-end read through the jit-cached op family;
* ``read_eager``     — the identical read with NO jit: every jnp op
                       dispatches one by one, the state of the hot path
                       before this PR's jit-cached op family.

All numbers are host wall-clock µs per call (``time_us`` blocks on every
iteration's output), with derived ops/sec.  The driver (`run.py`) writes
them to ``BENCH_hot_path.json`` — the repo's measured perf trajectory.

Standalone (``python benchmarks/hot_path.py``) prints a JSON report and
exits nonzero unless (the PR acceptance gate, CI-runnable):

* the jit-cached end-to-end read is ≥ 2× faster than the eager path at
  the largest swept batch (CPU ref backend);
* the fused donated submit+wait round at the max batch is ≥ 3× faster
  than the PR 5 recorded baseline (``PR5_SUBMIT_WAIT_B4096_US``) and
  clears the elems/s floor (``ROUND_ELEMS_PER_S_FLOOR``);
* the fused ``probe_allocate`` kernel (``impl='pallas', interpret=True``)
  is bit-identical to the jnp oracle across a differential mini-sweep;
* steady-state ``read``/``submit``/``wait`` at fixed shapes trigger zero
  retraces after the first call (the trace-count probe), and a ragged
  bucketed sweep compiles at most one executable per shape bucket with
  zero steady-state retraces.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import SMOKE, scaled, time_us, time_us_state
except ImportError:        # standalone: python benchmarks/<module>.py
    from common import SMOKE, scaled, time_us, time_us_state
from repro.core import BamArray, IORequest
from repro.core import cache as C
from repro.kernels import ops

BLOCK_ELEMS = 128                       # 512B lines of float32
BATCHES = scaled((256, 1024, 4096), (32, 64))
WAYS = 8
NUM_SETS = scaled(512, 16)
N_BLOCKS = 4 * NUM_SETS * WAYS          # 4x oversubscribed storage tier
READ_ITERS = scaled(5, 2)

# PR 5 trajectory point: submit_b4096 (20206.0µs) + wait_b4096 (15213.5µs)
# from BENCH_hot_path.json as committed at PR 5 — the step-by-step token
# round the fused passes replace.  The acceptance gate requires the fused
# donated round at batch 4096 to beat this by >= 3x.
PR5_SUBMIT_WAIT_B4096_US = 35419.5
ROUND_SPEEDUP_GATE = 3.0
# CI floor on steady-state async throughput (elements retired per second
# through one submit+wait round at the max batch).
ROUND_ELEMS_PER_S_FLOOR = 150_000.0


def _build():
    data = np.random.default_rng(7).standard_normal(
        (N_BLOCKS, BLOCK_ELEMS)).astype(np.float32)
    return BamArray.build(data, block_elems=BLOCK_ELEMS,
                          num_sets=NUM_SETS, ways=WAYS,
                          num_queues=8, queue_depth=4096)


def _warm_cache(m: int):
    """A cache directory with a resident working set + the request mix."""
    rng = np.random.default_rng(m)
    cache = C.make_cache(NUM_SETS, WAYS, BLOCK_ELEMS)
    resident = jnp.asarray(
        rng.choice(N_BLOCKS, NUM_SETS * WAYS // 2, replace=False), jnp.int32)
    cache, _, _ = C.probe_allocate(cache, resident, impl="ref")
    # request mix: half the resident set, half fresh keys
    keys = jnp.asarray(np.concatenate([
        rng.choice(np.asarray(resident), m // 2),
        rng.integers(0, N_BLOCKS, m - m // 2),
    ]).astype(np.int32))
    return jax.block_until_ready(cache), keys


def _stage_times(m: int) -> dict:
    cache, keys = _warm_cache(m)
    # the benchmark deliberately times freshly-built wrappers
    probe = jax.jit(lambda c, k: C.probe(c, k))  # bamlint: ignore[BAM105]
    fused = jax.jit(lambda c, k: C.probe_allocate(c, k))  # bamlint: ignore[BAM105]

    def _two_step(c, k):
        pr = C.probe(c, k)
        return C.allocate(c, k, (k >= 0) & ~pr.hit, protect_slots=pr.slot)

    argsort = jax.jit(_two_step)  # bamlint: ignore[BAM105]
    return {
        "probe_us": time_us(probe, cache, keys),
        "alloc_fused_us": time_us(fused, cache, keys),
        "alloc_argsort_us": time_us(argsort, cache, keys),
    }


def _op_times(arr, st, m: int) -> dict:
    rng = np.random.default_rng(100 + m)
    idx = jnp.asarray(rng.integers(0, arr.size, m), jnp.int32)
    submit = arr.submit_jit()
    # guard=False: the timing loop deliberately redeems the same concrete
    # token on every iteration — exactly what the single-redemption guard
    # exists to reject in real code.
    wait = arr.wait_jit(guard=False)
    read = arr.read_jit()
    st1, tok = submit(st, IORequest.read(idx))
    jax.block_until_ready(st1)
    out = {
        "submit_us": time_us(submit, st, IORequest.read(idx)),
        "wait_us": time_us(wait, st1, tok),
        "read_jit_us": time_us(read, st, idx, warmup=1, iters=READ_ITERS),
        "read_eager_us": time_us(arr.read, st, idx, warmup=1,
                                 iters=READ_ITERS),
    }
    out["jit_speedup"] = out["read_eager_us"] / max(out["read_jit_us"], 1e-9)
    out["elems_per_s"] = m / (out["read_jit_us"] * 1e-6)
    return out


def _fresh_state(st):
    """Deep-copy a BamState so a donated run can't kill shared buffers."""
    return jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True), st)


def _round_times(arr, st, m: int) -> dict:
    """One full fused async round — submit + wait, donated state threading.

    This is the steady-state shape of the token hot path: every round
    rebinds the state from the previous round's output, so
    ``donate=True`` lets XLA reuse the cache/queue buffers in place
    instead of copying the full state per op.  The round itself is the
    op family's ``submit_wait_jit`` — submit and wait back to back in ONE
    executable, so the round pays one dispatch and the token never
    materialises on the host (``round_pair_us`` keeps the two-executable
    pair as a reference point for what the fusion saves).
    """
    rng = np.random.default_rng(200 + m)
    idx = jnp.asarray(rng.integers(0, arr.size, m), jnp.int32)
    req = IORequest.read(idx)
    rnd = arr.submit_wait_jit(donate=True)
    submit = arr.submit_jit(donate=True)
    wait = arr.wait_jit(donate=True)

    def round_step(s):
        s, _ = rnd(s, req)
        return s

    def pair_step(s):
        s, tok = submit(s, req)
        s, _ = wait(s, tok)
        return s

    us = time_us_state(round_step, _fresh_state(st), iters=READ_ITERS)
    pair_us = time_us_state(pair_step, _fresh_state(st), iters=READ_ITERS)
    return {"round_fused_us": us,
            "round_pair_us": pair_us,
            "round_elems_per_s": m / (us * 1e-6)}


def _legacy_round_us(arr, st, m: int) -> float:
    """The same submit+wait round on the step-by-step path, no donation."""
    legacy = dataclasses.replace(arr, fused_rounds=False,
                                 _jit_ops={}, _trace_counts={})
    rng = np.random.default_rng(200 + m)
    idx = jnp.asarray(rng.integers(0, arr.size, m), jnp.int32)
    req = IORequest.read(idx)
    submit = legacy.submit_jit()
    wait = legacy.wait_jit()

    def round_step(s):
        s, tok = submit(s, req)
        s, _ = wait(s, tok)
        return s

    return time_us_state(round_step, _fresh_state(st), iters=READ_ITERS)


def _differential_sweep() -> bool:
    """Fused kernel (pallas, interpret) vs jnp oracle: bit-identical."""
    rng = np.random.default_rng(0)
    cases = [(4, 1, 7), (8, 4, 33), (16, 8, 64)]
    variants = [dict(), dict(tenant=1), dict(way_lo=1, way_hi=3),
                dict(spec_insert=True), dict(protect_hits=False)]
    for S, W, m in cases:
        tags = jnp.asarray(rng.integers(-1, 200, (S, W)), jnp.int32)
        owner = jnp.asarray(rng.integers(0, 2, (S, W)), jnp.int32)
        refc = jnp.asarray(rng.integers(0, 2, (S, W)), jnp.int32)
        dirty = jnp.asarray(rng.integers(0, 2, (S, W)).astype(bool))
        spec = jnp.asarray(rng.integers(0, 2, (S, W)).astype(bool))
        hand = jnp.asarray(rng.integers(0, W, (S,)), jnp.int32)
        keys = jnp.asarray(rng.integers(-1, 250, m), jnp.int32)
        prot = jnp.asarray(rng.integers(-1, S * W, 5), jnp.int32)
        for kw in variants:
            if W == 1 and "way_hi" in kw:
                continue
            args = (tags, owner, refc, dirty, spec, hand, keys)
            r = ops.probe_allocate(*args, protect_slots=prot, impl="ref",
                                   **kw)
            p = ops.probe_allocate(*args, protect_slots=prot, impl="pallas",
                                   interpret=True, **kw)
            for a, b in zip(r, p):
                if not np.array_equal(np.asarray(a), np.asarray(b)):
                    return False
    return True


def _retrace_check() -> bool:
    """Fixed-shape steady state must trace each op exactly once (a fresh
    array, so the sweep's other batch shapes don't pollute the counts)."""
    arr, st = _build()
    idx = jnp.asarray(np.arange(64) * 3 % arr.size, jnp.int32)
    read, submit, wait = arr.read_jit(), arr.submit_jit(), arr.wait_jit()
    for _ in range(3):
        _, st = read(st, idx)
        st, tok = submit(st, IORequest.read(idx))
        st, _ = wait(st, tok)
    tc = arr.trace_counts
    return tc.get("read") == 1 and tc.get("submit") == 1 \
        and tc.get("wait") == 1


def _bucketed_retrace_check() -> bool:
    """Ragged wavefronts through the bucketed round: at most one
    executable per shape bucket actually used, and a replay of the same
    ragged sweep at steady state must trigger zero further retraces."""
    arr, st = _build()
    sizes = [3, 17, 64, 65, 130, 200, 9, 77]   # all land in buckets {64, 256}

    def ragged_sweep(st):
        for n in sizes:
            idx = jnp.asarray(np.arange(n) * 5 % arr.size, jnp.int32)
            st, tok = arr.submit_bucketed(st, IORequest.read(idx))
            st, _ = arr.wait_bucketed(st, tok)
        return st

    st = ragged_sweep(st)
    used = {arr.bucket_size(n) for n in sizes}
    tc = dict(arr.trace_counts)
    if tc.get("submit") != len(used) or tc.get("wait") != len(used):
        return False
    ragged_sweep(st)
    return dict(arr.trace_counts) == tc


def sweep() -> dict:
    arr, st = _build()
    report = {
        "workload": {"block_bytes": BLOCK_ELEMS * 4, "num_sets": NUM_SETS,
                     "ways": WAYS, "n_blocks": N_BLOCKS,
                     "batches": list(BATCHES)},
        "batches": [],
    }
    for m in BATCHES:
        point = {"batch": m}
        point.update(_stage_times(m))
        point.update(_op_times(arr, st, m))
        point.update(_round_times(arr, st, m))
        report["batches"].append(point)
    last = report["batches"][-1]
    last["round_legacy_us"] = _legacy_round_us(arr, st, last["batch"])
    last["round_speedup_vs_legacy"] = (
        last["round_legacy_us"] / max(last["round_fused_us"], 1e-9))
    report["jit_speedup_at_max"] = last["jit_speedup"]
    report["jit_beats_eager_2x"] = last["jit_speedup"] >= 2.0
    report["round_fused_us_at_max"] = last["round_fused_us"]
    report["round_speedup_vs_pr5"] = (
        PR5_SUBMIT_WAIT_B4096_US / max(last["round_fused_us"], 1e-9))
    # PR5_SUBMIT_WAIT_B4096_US is a batch-4096 number: the comparison only
    # means anything at full sizes (smoke shrinks the sweep to b<=64).
    report["submit_wait_3x_vs_pr5"] = (
        report["round_speedup_vs_pr5"] >= ROUND_SPEEDUP_GATE)
    report["round_elems_floor_ok"] = (
        last["round_elems_per_s"] >= ROUND_ELEMS_PER_S_FLOOR)
    report["differential_ok"] = _differential_sweep()
    report["no_retrace"] = _retrace_check()
    report["bucketed_no_retrace"] = _bucketed_retrace_check()
    report["gate_ok"] = (report["jit_beats_eager_2x"]
                         and report["submit_wait_3x_vs_pr5"]
                         and report["round_elems_floor_ok"]
                         and report["differential_ok"]
                         and report["no_retrace"]
                         and report["bucketed_no_retrace"])
    return report


def run():
    rep = sweep()
    rows = []
    for p in rep["batches"]:
        m = p["batch"]
        for stage in ("probe", "alloc_fused", "alloc_argsort", "submit",
                      "wait", "read_eager"):
            us = p[f"{stage}_us"]
            rows.append((
                f"hot_path/{stage}_b{m}", us,
                f"ops_per_s={1e6 / max(us, 1e-9):.0f}"))
        rows.append((
            f"hot_path/read_jit_b{m}", p["read_jit_us"],
            f"ops_per_s={1e6 / max(p['read_jit_us'], 1e-9):.0f} "
            f"speedup_vs_eager={p['jit_speedup']:.2f}x "
            f"elems_per_s={p['elems_per_s']:.0f}"))
        derived = (f"ops_per_s={1e6 / max(p['round_fused_us'], 1e-9):.0f} "
                   f"elems_per_s={p['round_elems_per_s']:.0f}")
        if "round_speedup_vs_legacy" in p:
            derived += (f" speedup_vs_legacy="
                        f"{p['round_speedup_vs_legacy']:.2f}x")
        rows.append((f"hot_path/submit_wait_fused_b{m}",
                     p["round_fused_us"], derived))
        rows.append((
            f"hot_path/submit_wait_pair_b{m}", p["round_pair_us"],
            f"ops_per_s={1e6 / max(p['round_pair_us'], 1e-9):.0f}"))
        if "round_legacy_us" in p:
            rows.append((
                f"hot_path/submit_wait_legacy_b{m}", p["round_legacy_us"],
                f"ops_per_s={1e6 / max(p['round_legacy_us'], 1e-9):.0f}"))
    return rows


if __name__ == "__main__":
    rep = sweep()
    print(json.dumps(rep, indent=2))
    # Speedup thresholds are calibrated for full sizes; at smoke sizes only
    # correctness (differential + retrace probes) must hold.
    ok = rep["differential_ok"] and rep["no_retrace"] \
        and rep["bucketed_no_retrace"] \
        and (SMOKE or (rep["jit_beats_eager_2x"]
                       and rep["submit_wait_3x_vs_pr5"]
                       and rep["round_elems_floor_ok"]))
    raise SystemExit(0 if ok else 1)
