"""Paper Fig. 6: 512B random read/write IOPS scaling with #SSDs.

Two measurements:
  (a) software issue rate — wall-clock of the jitted BaM queue stack
      (coalesce -> enqueue -> doorbell -> drain) per request on this CPU;
      the claim reproduced is structural: the stack issues requests far
      faster than devices serve them, so devices stay the bottleneck;
  (b) device-limited IOPS from the Little's-law device model — linear
      scaling to 7 Optane SSDs (35M read IOPs peak, as in the paper).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_us
from repro.core import enqueue, make_queues, service_all
from repro.core.ssd import ArrayOfSSDs, INTEL_OPTANE_P5800X
from repro.utils import round_up


def run():
    rows = []
    rng = np.random.default_rng(0)
    wave = 4096
    keys = jnp.asarray(rng.integers(0, 1 << 20, wave), jnp.int32)

    @jax.jit  # bamlint: ignore[BAM105] -- built once per benchmark run
    def submit_drain(qs, keys):
        qs, rec = enqueue(qs, keys)
        qs, comps = service_all(qs)
        return qs, rec.n_accepted

    qs = make_queues(16, 1024)
    us = time_us(lambda: submit_drain(qs, keys)[1])
    sw_iops = wave / (us / 1e6)
    rows.append(("iops/software_issue_rate", us,
                 f"{sw_iops/1e6:.2f}M req/s through the queue stack (CPU)"))

    for n in (1, 2, 4, 7):
        dev = ArrayOfSSDs(INTEL_OPTANE_P5800X, n)
        # per-device channels: a balanced histogram, each channel's
        # concurrency capped by its own queue group — 16 rings rounded up
        # to a multiple of n (as BamArray.build does) x depth 1024 — and
        # drain time is the max over channels.
        hist = [1_000_000 // n] * n
        group_depth = round_up(16, n) // n * 1024
        t, _ = dev.service_time_per_device(hist, 512,
                                           queue_depth_limit=group_depth)
        riops = sum(hist) / t
        t_w, _ = dev.service_time_per_device(hist, 512, write=True,
                                             queue_depth_limit=group_depth)
        wiops = sum(hist) / t_w
        rows.append((f"iops/read_512B_{n}ssd", t * 1e6 / 1e6,
                     f"{riops/1e6:.1f}M IOPs (paper: {5.1*n:.1f}M)"))
        rows.append((f"iops/write_512B_{n}ssd", t_w * 1e6 / 1e6,
                     f"{wiops/1e6:.1f}M IOPs (paper: {1.0*n:.1f}M)"))
    return rows
