"""Paper §II-C: Little's law (T x L = Q_d) — the design math, verified.

Reproduces the paper's worked numbers: 51M IOPs @ 512B over x16 Gen4 needs
Q_d = 561 in flight on Optane (11us) and 16,524 on 980pro (324us); with X
concurrently-serviceable requests the sustained rate is X/(L + X/T).
"""
from repro.core.ssd import (INTEL_OPTANE_P5800X, SAMSUNG_980PRO,
                            required_queue_depth, sustained_rate,
                            target_iops_for_link, PCIE_GEN4_X16_BW)


def run():
    rows = []
    T512 = target_iops_for_link(PCIE_GEN4_X16_BW, 512)
    rows.append(("littles_law/target_iops_512B", 0.0,
                 f"T={T512/1e6:.1f}M/s (paper: 51M)"))
    qd_opt = required_queue_depth(T512, INTEL_OPTANE_P5800X.latency_s)
    qd_sam = required_queue_depth(T512, SAMSUNG_980PRO.latency_s)
    rows.append(("littles_law/qd_optane", 0.0,
                 f"Q_d={qd_opt} (paper: 561)"))
    rows.append(("littles_law/qd_980pro", 0.0,
                 f"Q_d={qd_sam} (paper: 16524)"))
    # sustained-rate curve: X needed to reach 95% of peak
    for spec, name, paper_x in ((INTEL_OPTANE_P5800X, "optane", "~8K"),
                                (SAMSUNG_980PRO, "980pro", "~256K")):
        X = 1
        while sustained_rate(X, spec.latency_s, T512) < 0.95 * T512:
            X *= 2
        rows.append((f"littles_law/concurrency_95pct_{name}", 0.0,
                     f"X={X} (paper: {paper_x})"))
    return rows
