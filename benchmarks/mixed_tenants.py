"""Multi-tenant shared BaM runtime: cache partitioning under contention.

The paper's central claim is that *one* fine-grained software cache and
*one* pool of high-throughput queues serve many concurrent GPU
applications at storage speed.  This benchmark runs three real scenarios
*at once* against a single shared :class:`~repro.core.BamRuntime`:

* **kv** — ``BamKVStore`` lookups with a hot working set (the
  cache-friendly tenant: its hot lines fit in its way quota);
* **bfs** — frontier expansion over a BamArray-backed CSR edge list
  (bursty, moderate reuse);
* **scan** — a taxi-style streaming column scan (the adversarial tenant:
  zero reuse, pure eviction pressure).

Wavefronts interleave round-robin (one scan wave, one KV batch, one BFS
iteration per round), so the tenants genuinely contend for the same
sets/ways and SQ rings.  Three configurations are measured:

* **solo**      — the KV tenant alone, on a cache exactly the size of its
  way quota (the isolation baseline);
* **partitioned** — all three tenants, each clock sweep confined to its
  own ways (``isolation="partitioned"``);
* **shared**    — all three tenants, free-for-all eviction
  (``isolation="shared"``): the scan thrashes the KV tenant's hot lines.

Acceptance gate (standalone run / CI): with way-partitioning the KV
tenant retains **>= 80 %** of its solo hit rate while the scan runs
concurrently, per-tenant IOMetrics sum exactly to the global counters
(``BamRuntime.assert_metrics_consistent``), and the shared free-for-all
shows a visibly lower KV hit rate than the partitioned run — the thrash
the partitioning exists to prevent.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import SMOKE, scaled
except ImportError:        # standalone: python benchmarks/<module>.py
    from common import SMOKE, scaled
from repro.analytics.taxi import scan_column_runtime
from repro.core import BamKVStore, BamRuntime, TenantSpec
from repro.graph.analytics import BamGraph, random_graph

BLOCK_ELEMS = 32                     # 128B float32 cache lines, all tenants
NUM_SETS = scaled(64, 16)
KV_WAYS, BFS_WAYS, SCAN_WAYS = 4, 2, 2
WAYS = KV_WAYS + BFS_WAYS + SCAN_WAYS

N_KEYS = scaled(2048, 256)           # KV population
VALUE_ELEMS = 8
HOT_KEYS = scaled(128, 16)           # hot set (fits the KV way quota)
HOT_FRAC = 0.9
KV_BATCH = scaled(256, 64)

N_NODES = scaled(2048, 256)
AVG_DEG = 8.0

SCAN_ELEMS = scaled(1 << 15, 1 << 11)   # column length (floats)
SCAN_WAVE = scaled(4096, 512)           # streaming wavefront per round

ROUNDS = scaled(48, 6)
WARM_ROUNDS = scaled(8, 2)


def _kv_tenant_data(rng):
    keys = np.arange(N_KEYS, dtype=np.int32)
    values = rng.standard_normal((N_KEYS, VALUE_ELEMS)).astype(np.float32)
    table, store_vals, capacity = BamKVStore.build_table(
        keys, values, capacity=2 * N_KEYS, probes=8)
    return keys, values, jnp.asarray(table), store_vals, capacity


def _kv_batches(rng, rounds):
    """Zipf-ish KV traffic: HOT_FRAC of lookups hit the first HOT_KEYS.

    Callers pass a *dedicated* generator so the solo baseline and the
    concurrent runs replay the identical request stream — the isolation
    gate compares the same workload, not two random draws."""
    out = []
    for _ in range(rounds):
        hot = rng.integers(0, HOT_KEYS, KV_BATCH)
        cold = rng.integers(0, N_KEYS, KV_BATCH)
        pick = rng.random(KV_BATCH) < HOT_FRAC
        out.append(jnp.asarray(np.where(pick, hot, cold), jnp.int32))
    return out


class _BfsDriver:
    """Restartable frontier BFS over a runtime tenant (one step per round).

    Built on :meth:`BamGraph.from_runtime`: the CSR metadata comes from the
    graph layer, the edge-target reads go through the shared runtime."""

    def __init__(self, rt, rst, indptr):
        g = BamGraph.from_runtime(rt, rst, "bfs", indptr)
        self.n_nodes = g.n_nodes
        self.edge_src = g.edge_src
        self.edge_ids = jnp.arange(g.n_edges, dtype=jnp.int32)
        self.INF = jnp.int32(2 ** 30)
        self.source = 0
        self.it = 0
        self.depth = jnp.full((self.n_nodes,), self.INF,
                              jnp.int32).at[self.source].set(0)

        def step(rst, depth, it):
            frontier = depth == it
            active = frontier[self.edge_src]
            req = jnp.where(active, self.edge_ids, -1)
            nbrs, rst = rt.read(rst, "bfs", req, active)
            nbrs = jnp.where(active, nbrs.astype(jnp.int32), 0)
            first = active & (depth[nbrs] >= self.INF)
            depth = depth.at[jnp.where(first, nbrs, 0)].min(
                jnp.where(first, it + 1, self.INF))
            return rst, depth, jnp.any(first)

        self._step = jax.jit(step)

    def round(self, rst):
        rst, self.depth, more = self._step(rst, self.depth,
                                           jnp.int32(self.it))
        self.it += 1
        if not bool(more):          # restart from the next source
            self.source = (self.source + 17) % self.n_nodes
            self.it = 0
            self.depth = jnp.full((self.n_nodes,), self.INF,
                                  jnp.int32).at[self.source].set(0)
        return rst


def _hit_rate_window(summ_end, summ_start):
    """Demand hit rate over the measured window (cold warmup excluded)."""
    h = summ_end["hits"] - summ_start["hits"]
    m = summ_end["misses"] - summ_start["misses"]
    return h / (h + m) if h + m > 0 else 0.0


def _run_config(isolation, *, with_neighbours, seed=0):
    """One full interleaved run; returns per-tenant window hit rates."""
    rng = np.random.default_rng(seed)
    _, _, table, store_vals, capacity = _kv_tenant_data(rng)
    kv_spec = TenantSpec("kv", store_vals, block_elems=BLOCK_ELEMS,
                         ways=KV_WAYS)
    if with_neighbours:
        indptr, dst = random_graph(N_NODES, AVG_DEG, seed=seed + 1)
        scan_col = rng.standard_normal(SCAN_ELEMS).astype(np.float32)
        specs = [
            kv_spec,
            TenantSpec("bfs", dst.astype(np.int32),
                       block_elems=BLOCK_ELEMS, ways=BFS_WAYS),
            TenantSpec("scan", scan_col, block_elems=BLOCK_ELEMS,
                       ways=SCAN_WAYS, weight=0.5),
        ]
        ways = WAYS
    else:
        specs, ways = [kv_spec], KV_WAYS
    # Deferred drain: all three tenants' commands accumulate in the shared
    # rings each round and one drain retires them weighted-fair — the
    # arbitration is exercised on a genuinely mixed stream.
    rt, rst = BamRuntime.build(specs, num_sets=NUM_SETS, ways=ways,
                               num_queues=8, queue_depth=1024,
                               isolation=isolation, drain="deferred")
    kv = BamKVStore(array=rt.array("kv"), capacity=capacity,
                    value_elems=VALUE_ELEMS, probes=8)

    def kv_round(rst, keys):
        st = rt.tenant_view(rst, "kv")
        vals, found, st = kv.lookup(st, table, keys)
        return vals, found, rt.absorb(rst, "kv", st)

    kv_round = jax.jit(kv_round)  # bamlint: ignore[BAM105] -- once per sweep
    batches = _kv_batches(np.random.default_rng(seed + 1000), ROUNDS)

    if with_neighbours:
        bfs = _BfsDriver(rt, rst, indptr)
        scan_pos = 0

    import time
    window_start = {}
    arb_stream = []
    t0 = time.perf_counter()
    for r in range(ROUNDS):
        if r == WARM_ROUNDS:
            window_start = {n: rt.tenant_summary(rst, n) for n in rt.tenants}
            t0 = time.perf_counter()
        if with_neighbours:
            _, rst, scan_pos = scan_column_runtime(
                rt, rst, "scan", n_rows=SCAN_ELEMS, wavefront=SCAN_WAVE,
                start=scan_pos, waves=1)
        vals, found, rst = kv_round(rst, batches[r])
        assert bool(found.all()), "kv lookup lost keys under sharing"
        if with_neighbours:
            rst = bfs.round(rst)
        # round barrier: one weighted-fair drain of the mixed stream
        rst, comps = rt.drain(rst)
        if r == ROUNDS - 1:
            arb_stream = np.asarray(comps.tenant)[
                np.asarray(comps.valid)].tolist()
    elapsed_us = (time.perf_counter() - t0) / max(ROUNDS - WARM_ROUNDS,
                                                  1) * 1e6

    rt.assert_metrics_consistent(rst)
    out = {"isolation": isolation, "round_us": elapsed_us, "tenants": {}}
    for n in rt.tenants:
        end = rt.tenant_summary(rst, n)
        start = window_start.get(n)
        out["tenants"][n] = {
            "hit_rate": _hit_rate_window(end, start) if start
            else end["hit_rate"],
            "requests": end["requests"],
            "dropped": end["dropped"],
        }
    # cross-check: queue-level per-tenant conservation after full drain
    qs = rst.queues
    enq = np.asarray(qs.tenant_enqueued)
    comp = np.asarray(qs.tenant_completed)
    assert np.array_equal(enq, comp), (enq, comp)
    if arb_stream:
        # observable weighted-fair interleave of the final round's drain:
        # fraction of adjacent completion pairs that switch tenant (a
        # FIFO burst drain would be ~n_tenants/len, WFQ interleaves)
        switches = sum(a != b for a, b in zip(arb_stream, arb_stream[1:]))
        out["arbitration"] = {
            "last_drain_counts": {int(t): arb_stream.count(t)
                                  for t in set(arb_stream)},
            "interleave": switches / max(len(arb_stream) - 1, 1),
        }
    return out


def sweep() -> dict:
    solo = _run_config("partitioned", with_neighbours=False)
    part = _run_config("partitioned", with_neighbours=True)
    shared = _run_config("shared", with_neighbours=True)
    solo_hr = solo["tenants"]["kv"]["hit_rate"]
    part_hr = part["tenants"]["kv"]["hit_rate"]
    shared_hr = shared["tenants"]["kv"]["hit_rate"]
    retained = part_hr / solo_hr if solo_hr > 0 else 0.0
    return {
        "workload": {
            "num_sets": NUM_SETS, "ways": WAYS,
            "quotas": {"kv": KV_WAYS, "bfs": BFS_WAYS, "scan": SCAN_WAYS},
            "rounds": ROUNDS, "kv_batch": KV_BATCH,
            "scan_wave": SCAN_WAVE, "hot_keys": HOT_KEYS,
        },
        "solo": solo, "partitioned": part, "shared": shared,
        "kv_hit_solo": solo_hr,
        "kv_hit_partitioned": part_hr,
        "kv_hit_shared": shared_hr,
        "kv_retained_partitioned": retained,
        "isolation_ok": retained >= 0.8,
        "thrash_visible": shared_hr < part_hr - 0.05,
    }


def run():
    rep = sweep()
    rows = []
    for cfg in ("solo", "partitioned", "shared"):
        for name, t in rep[cfg]["tenants"].items():
            rows.append((
                f"mixed_tenants/{cfg}_{name}",
                rep[cfg]["round_us"],
                f"hit_rate={t['hit_rate']:.3f} dropped={t['dropped']:.0f}"))
    rows.append((
        "mixed_tenants/isolation",
        rep["partitioned"]["round_us"],
        f"retained={rep['kv_retained_partitioned']:.2f} "
        f"(shared={rep['kv_hit_shared']:.3f})"))
    return rows


if __name__ == "__main__":
    rep = sweep()
    print(json.dumps(rep, indent=2))
    # Thresholds are calibrated for full sizes; at smoke sizes only assert
    # the interleaved runs complete and the metrics stay consistent (the
    # asserts inside _run_config).
    ok = SMOKE or (rep["isolation_ok"] and rep["thrash_visible"])
    raise SystemExit(0 if ok else 1)
