"""Beyond-paper: MoE expert paging through the BaM cache.

Routing decides which experts' weight blocks are touched (the paper's
'compute decides what to read').  At small decode batches only a few of the
64 experts are hit per step and the BaM cache turns expert reuse across
steps into hits; at large batches every expert is touched and the fetch
degenerates to streaming.  Reports amplification + hit rate vs batch.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import scaled
from repro.core import BamArray


def run():
    rows = []
    rng = np.random.default_rng(0)
    E, D, F = 32, 64, 128                  # scaled-down expert pool
    chunk = 512                            # elements per BaM block
    weights = rng.standard_normal((E, D * F)).astype(np.float32)
    blocks_per_expert = D * F // chunk
    arr, st = BamArray.build(weights.reshape(-1, chunk), block_elems=chunk,
                             num_sets=64, ways=4)

    @jax.jit  # bamlint: ignore[BAM105] -- built once per benchmark run
    def fetch_experts(st, expert_ids, valid):
        # all blocks of each selected expert
        base = expert_ids[:, None] * blocks_per_expert \
            + jnp.arange(blocks_per_expert)[None, :]
        idx = (base * chunk)[..., None] + jnp.arange(chunk)[None, None, :]
        flat = idx.reshape(-1)
        v = jnp.repeat(valid, blocks_per_expert * chunk)
        vals, st = arr.read(st, flat, v)
        return vals.reshape(expert_ids.shape[0], D * F), st

    top_k = 4
    for B in scaled((1, 4, 16), (1, 4)):
        st_b = st
        hits0 = misses0 = 0.0
        for step in range(8):              # decode steps with reuse
            route = rng.choice(E, size=(B, top_k), replace=True)
            uniq = np.unique(route)
            ids = np.full((E,), -1, np.int32)
            ids[:len(uniq)] = uniq
            _, st_b = fetch_experts(st_b, jnp.asarray(ids),
                                    jnp.asarray(ids >= 0))
        m = st_b.metrics.summary()
        full_bytes = 8 * E * D * F * 4     # fetch-everything baseline
        rows.append((
            f"moe_paging/batch_{B}", m["sim_time_s"] * 1e6,
            f"hit_rate={m['hit_rate']:.2f} "
            f"bytes={m['bytes_from_storage']:.2e} "
            f"vs_full_fetch={full_bytes/max(m['bytes_from_storage'],1):.1f}x"
            " less"))
    return rows
