"""Beyond-paper: BaM-paged KV cache — spill/fetch traffic vs hot window.

The LM-serving integration of the paper's technique: cold KV pages spill to
the storage tier and return on demand.  Sweeps the resident hot window and
reports pages moved + simulated device time per decoded token.
"""
import jax
import numpy as np

from benchmarks.common import scaled
from repro.configs.base import smoke_config
from repro.models.model import build_model
from repro.serving import PagedKVManager
from repro.serving.engine import Request, ServeEngine


def run():
    rows = []
    cfg = smoke_config("gemma3_12b").replace(window=None, local_ratio=(0, 1),
                                             dtype="float32")
    api = build_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0), 96)
    for keep in scaled((16, 32, 64), (16,)):
        kv = PagedKVManager(keep_last=keep)
        eng = ServeEngine(cfg, params, batch_slots=2, max_seq=96,
                          kv_manager=kv)
        for i in range(2):
            eng.submit(Request(rid=i, prompt=list(range(2, 40)),
                               max_new_tokens=scaled(24, 6)))
        eng.run()
        m = kv.metrics.summary()
        rows.append((
            f"paged_kv/hot_window_{keep}", m["sim_time_s"] * 1e6,
            f"spilled={m['write_ops']:.0f} fetched={m['misses']:.0f} "
            f"bytes_moved={m['bytes_to_storage']+m['bytes_from_storage']:.0f}"))

    # Deferred (submit/wait-style) charging: the same serve run, but page
    # moves accumulate and one drain charges the batch at its batched
    # Little's-law concurrency — the async analogue for the KV manager.
    keep = scaled(32, 16)
    kv = PagedKVManager(keep_last=keep, deferred=True)
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=96, kv_manager=kv)
    for i in range(2):
        eng.submit(Request(rid=i, prompt=list(range(2, 40)),
                           max_new_tokens=scaled(24, 6)))
    eng.run()
    n_r, n_w = kv.drain()
    m = kv.metrics.summary()
    rows.append((
        f"paged_kv/deferred_hot_window_{keep}", m["sim_time_s"] * 1e6,
        f"drained_reads={n_r} drained_writes={n_w} "
        f"bytes_moved={m['bytes_to_storage']+m['bytes_from_storage']:.0f}"))
    return rows
