"""Readahead window sweep (beyond paper; motivated by §III-C/D).

The sequential taxi-analytics scan (``repro.analytics.scan_column``) is the
readahead showcase: every wavefront's block keys advance by stride 1, so
the prefetch detector in ``repro.core.prefetch`` can bring the next
``window`` lines in through the low-priority lane before demand asks for
them.  This sweep measures, per window size, the cache hit rate and the
I/O amplification of a full column scan against the demand-only baseline
(``window=0``).

Expected shape of the result: hit rate rises steeply with the first few
lines of window (every wavefront after warmup runs fully resident) while
amplification stays flat — sequential readahead fetches exactly the lines
demand was about to fetch anyway, so no wasted bytes; a mispredicting
workload would instead show up as ``prefetch_accuracy < 1``.

Run standalone for the JSON report::

    PYTHONPATH=src python benchmarks/prefetch_sweep.py

or through the CSV driver::

    PYTHONPATH=src python -m benchmarks.run prefetch_sweep
"""
from __future__ import annotations

import json

try:
    from benchmarks.common import scaled
except ImportError:        # standalone: python benchmarks/<module>.py
    from common import scaled
from repro.analytics import make_taxi_table, scan_column
from repro.core import PrefetchConfig

N_ROWS = scaled(1 << 16, 1 << 13)
COLUMN = "trip_dist"
WINDOWS = scaled((0, 2, 4, 8, 16, 32), (0, 4))


def sweep(windows=WINDOWS, n_rows: int = N_ROWS, column: str = COLUMN,
          wavefront: int = 1024) -> list[dict]:
    """One full sequential scan per window size; ``window=0`` is the
    demand-only baseline.  Tables are rebuilt per point (same seed) so
    every scan starts from a cold cache."""
    points = []
    for w in windows:
        cfg = PrefetchConfig(enabled=w > 0, window=w)
        tbl = make_taxi_table(n_rows, seed=2, prefetch=cfg)
        value, m = scan_column(tbl, column, wavefront=wavefront)
        points.append({
            "window": w,
            "value": value,                       # scan checksum (must match)
            "hit_rate": m["hit_rate"],
            "amplification": m["amplification"],
            "misses": m["misses"],
            "prefetch_issued": m["prefetch_issued"],
            "prefetch_hits": m["prefetch_hits"],
            "prefetch_accuracy": m["prefetch_accuracy"],
            "sim_time_s": m["sim_time_s"],
        })
    return points


def run():
    """CSV rows for benchmarks/run.py."""
    points = sweep()
    base = points[0]
    rows = []
    for p in points:
        rows.append((
            f"prefetch/scan_w{p['window']}", p["sim_time_s"] * 1e6,
            f"hit_rate={p['hit_rate']:.3f} (base {base['hit_rate']:.3f}) "
            f"amp={p['amplification']:.3f} (base {base['amplification']:.3f}) "
            f"pf_acc={p['prefetch_accuracy']:.3f}"))
    return rows


def main() -> None:
    points = sweep()
    base = points[0]
    enabled = [p for p in points if p["window"] > 0]
    report = {
        "benchmark": "prefetch_sweep",
        "workload": f"sequential scan of taxi column {COLUMN!r}, "
                    f"{N_ROWS} rows, 512B lines, cold cache per point",
        "baseline": base,
        "points": points,
        # The acceptance check: readahead strictly raises the hit rate and
        # never raises amplification on the sequential scan.
        "readahead_improves_hit_rate": all(
            p["hit_rate"] > base["hit_rate"] for p in enabled),
        "readahead_amplification_ok": all(
            p["amplification"] <= base["amplification"] + 1e-9
            for p in enabled),
    }
    print(json.dumps(report, indent=2))
    if not report["readahead_improves_hit_rate"]:
        raise SystemExit("readahead did not improve the hit rate")
    if not report["readahead_amplification_ok"]:
        raise SystemExit("readahead raised I/O amplification")


if __name__ == "__main__":
    main()
