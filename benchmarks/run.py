"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Each module's ``run()`` returns
``[(name, us_per_call, derived), ...]``.

  littles_law              §II-C   T x L = Q_d worked numbers
  ssd_cost                 Tab III $/GB advantage over DRAM
  uvm_bound                Fig 1   UVM fault ceiling vs BaM issue rate
  analytics_amplification  Fig 2   I/O amplification Q1..Q6
  iops_scaling             Fig 6   512B random IOPS vs #SSDs
  graph_analytics          Fig 7   BFS/CC vs DRAM-only target T
  cacheline_sweep          Fig 8   512B..8KB granularity
  ssd_scaling              Fig 9   1..8 SSDs
  device_channels          Fig 7/§IV-A per-device channels: scaling + skew
  taxi_queries             Fig 10  Q1..Q6 end-to-end
  paged_kv                 (beyond paper) KV spill/fetch
  moe_paging               (beyond paper) expert paging
  prefetch_sweep           (beyond paper) readahead window sweep
  mixed_tenants            (§I sharing claim) multi-tenant isolation
  async_overlap            (§II-C) submit/wait token window depth sweep

Set ``BAM_BENCH_SMOKE=1`` to shrink every module to smoke-test sizes (CI).
"""
import importlib
import sys
import traceback

MODULES = [
    "littles_law", "ssd_cost", "uvm_bound", "analytics_amplification",
    "iops_scaling", "graph_analytics", "cacheline_sweep", "ssd_scaling",
    "device_channels", "taxi_queries", "paged_kv", "moe_paging",
    "prefetch_sweep", "mixed_tenants", "async_overlap",
]


def main() -> None:
    only = sys.argv[1:] or MODULES
    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        if mod_name not in only:
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            for name, us, derived in mod.run():
                print(f"{name},{us:.2f},{derived}")
        except Exception as e:
            failed.append(mod_name)
            print(f"{mod_name},nan,FAILED: {type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
