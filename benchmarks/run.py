"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Each module's ``run()`` returns
``[(name, us_per_call, derived), ...]``.

  littles_law              §II-C   T x L = Q_d worked numbers
  ssd_cost                 Tab III $/GB advantage over DRAM
  uvm_bound                Fig 1   UVM fault ceiling vs BaM issue rate
  analytics_amplification  Fig 2   I/O amplification Q1..Q6
  iops_scaling             Fig 6   512B random IOPS vs #SSDs
  graph_analytics          Fig 7   BFS/CC vs DRAM-only target T
  cacheline_sweep          Fig 8   512B..8KB granularity
  ssd_scaling              Fig 9   1..8 SSDs
  device_channels          Fig 7/§IV-A per-device channels: scaling + skew
  taxi_queries             Fig 10  Q1..Q6 end-to-end
  paged_kv                 (beyond paper) KV spill/fetch
  moe_paging               (beyond paper) expert paging
  prefetch_sweep           (beyond paper) readahead window sweep
  mixed_tenants            (§I sharing claim) multi-tenant isolation
  async_overlap            (§II-C) submit/wait token window depth sweep
  hot_path                 (§III-D/E) wall-clock µs/op: fused kernels + jit
  fault_sweep              (robustness) error-rate x retry-budget sweep

Alongside the CSV, every module that runs writes a machine-readable
``BENCH_<module>.json`` artifact (one object per row: name / value /
units / derived, plus backend + versions metadata) — the repo's measured
perf trajectory.  Artifacts land in the repo root by default; set
``BAM_BENCH_OUT=<dir>`` to redirect them, or ``BAM_BENCH_OUT=`` (empty)
to disable writing.

Set ``BAM_BENCH_SMOKE=1`` to shrink every module to smoke-test sizes
(CI); smoke artifacts are stamped ``"smoke": true`` so a tiny-size run is
never mistaken for a trajectory point.
"""
import importlib
import json
import os
import pathlib
import sys
import traceback

MODULES = [
    "littles_law", "ssd_cost", "uvm_bound", "analytics_amplification",
    "iops_scaling", "graph_analytics", "cacheline_sweep", "ssd_scaling",
    "device_channels", "taxi_queries", "paged_kv", "moe_paging",
    "prefetch_sweep", "mixed_tenants", "async_overlap", "hot_path",
    "fault_sweep",
]

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _artifact_dir() -> pathlib.Path | None:
    out = os.environ.get("BAM_BENCH_OUT")
    if out is None:
        return _REPO_ROOT
    if not out:
        return None
    path = pathlib.Path(out)
    path.mkdir(parents=True, exist_ok=True)
    return path


def write_artifact(mod_name: str, rows, out_dir: pathlib.Path) -> pathlib.Path:
    """Write ``BENCH_<module>.json``: the module's rows plus run metadata."""
    import platform
    import time

    import jax

    from benchmarks.common import SMOKE

    payload = {
        "schema": "bam-bench-v1",
        "module": mod_name,
        "smoke": SMOKE,
        "meta": {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "python": platform.python_version(),
            "platform": platform.platform(),
            "unix_time": time.time(),
        },
        "rows": [
            {"name": name, "value": float(us), "units": "us_per_call",
             "derived": derived}
            for name, us, derived in rows
        ],
    }
    path = out_dir / f"BENCH_{mod_name}.json"
    path.write_text(json.dumps(payload, indent=2) + "\n")
    return path


def main() -> None:
    only = sys.argv[1:] or MODULES
    out_dir = _artifact_dir()
    print("name,us_per_call,derived")
    failed = []
    artifact_failed = []
    for mod_name in MODULES:
        if mod_name not in only:
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            rows = list(mod.run())
            for name, us, derived in rows:
                print(f"{name},{us:.2f},{derived}")
        except Exception as e:
            failed.append(mod_name)
            print(f"{mod_name},nan,FAILED: {type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
            continue
        if out_dir is not None:
            # An unwritable artifact must not masquerade as a benchmark
            # failure: the rows above are real — report it separately.
            try:
                write_artifact(mod_name, rows, out_dir)
            except OSError as e:
                artifact_failed.append(mod_name)
                print(f"bench: could not write BENCH_{mod_name}.json: {e}",
                      file=sys.stderr)
    if failed or artifact_failed:
        raise SystemExit(
            f"benchmarks failed: {failed}; artifacts failed: "
            f"{artifact_failed}")


if __name__ == "__main__":
    main()
