"""Paper Table III: $/GB advantage of SSD-backed BaM over DRAM-only."""
from repro.core.ssd import SSD_PRESETS, DRAM_DIMM


def run():
    rows = []
    for name, spec in SSD_PRESETS.items():
        if name == "dram-dimm":
            continue
        adv = DRAM_DIMM.dollars_per_gb / spec.dollars_per_gb
        rows.append((f"ssd_cost/{name}", 0.0,
                     f"{adv:.1f}x cheaper per GB than DRAM "
                     f"(paper range: 4.4-21.8x)"))
    return rows
