"""Paper Fig. 9: scaling the number of SSDs behind one accelerator.

Linear until the workload can't generate requests fast enough (paper: ~2
Optanes for graph analytics) or the accelerator link saturates.
"""
from benchmarks.common import scaled
from repro.core.ssd import ArrayOfSSDs, INTEL_OPTANE_P5800X
from repro.graph import BamGraph, bfs, random_graph


def run():
    rows = []
    indptr, dst = random_graph(scaled(2000, 300), 12.0, seed=3)
    base_t = None
    for n in (1, 2, 4, 8):
        g = BamGraph.build(indptr, dst, cacheline_bytes=4096,
                           cache_bytes=1 << 16,
                           ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, n))
        _, st = bfs(g, 0)
        t = st.metrics.summary()["sim_time_s"]
        base_t = base_t or t
        rows.append((f"ssd_scaling/bfs_{n}ssd", t * 1e6,
                     f"speedup_vs_1ssd={base_t/max(t,1e-12):.2f}x"))
    return rows
