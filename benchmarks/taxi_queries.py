"""Paper Fig. 10: taxi queries Q1-Q6 — BaM vs the CPU-centric baseline.

End-to-end modelled time: device time for the bytes each scheme moves (one
Optane SSD, Little's law) + the baseline's CPU staging overhead.  The
reproduced claim: the baseline degrades as data-dependent columns are
added; BaM stays nearly flat (paper: up to 4.9x).
"""
from benchmarks.common import scaled
from repro.analytics import (QUERIES, make_taxi_table, run_query,
                             run_query_baseline)
from repro.core.ssd import ArrayOfSSDs, INTEL_OPTANE_P5800X


def run():
    tbl = make_taxi_table(scaled(1 << 16, 1 << 12), seed=2)
    dev = ArrayOfSSDs(INTEL_OPTANE_P5800X, 1)
    rows = []
    for q in QUERIES:
        _, io = run_query(tbl, q)
        _, iob = run_query_baseline(tbl, q)
        blk = 4096
        t_bam = dev.service_time(int(io["bytes_moved_total"] // blk) + 1,
                                 blk, queue_depth_limit=16384)
        t_base = dev.service_time(int(iob["bytes_moved_total"] // blk) + 1,
                                  blk, queue_depth_limit=64)
        rows.append((
            f"taxi/{q}", t_bam * 1e6,
            f"bam={t_bam*1e3:.3f}ms baseline_cold={t_base*1e3:.3f}ms "
            f"speedup={t_base/max(t_bam,1e-12):.2f}x"))
    return rows
