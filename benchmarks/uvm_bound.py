"""Paper Fig. 1 / §II-A: the UVM page-fault ceiling vs the BaM queue rate.

The paper measures the CPU-centric UVM fault handler topping out at ~500K
IOPs (~14.5 GBps of 4K pages, 55% of PCIe), far below one Optane SSD.  We
reproduce the comparison structurally: the measured BaM software issue rate
(from the queue stack on this host) against the 500K UVM ceiling and the
per-SSD device rates.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_us
from repro.core import enqueue, make_queues, service_all
from repro.core.ssd import INTEL_OPTANE_P5800X, SAMSUNG_980PRO

UVM_FAULT_IOPS = 500e3            # paper's measured ceiling


def run():
    rng = np.random.default_rng(0)
    wave = 4096
    keys = jnp.asarray(rng.integers(0, 1 << 20, wave), jnp.int32)

    @jax.jit  # bamlint: ignore[BAM105] -- built once per benchmark run
    def submit_drain(qs, keys):
        # issue-rate probe: the drain below counts every command anyway
        # bamlint: ignore[BAM108] -- receipt deliberately unread
        qs, _ = enqueue(qs, keys)
        qs, comps = service_all(qs)
        return comps.count

    qs = make_queues(16, 1024)
    us = time_us(lambda: submit_drain(qs, keys))
    bam_rate = wave / (us / 1e6)
    rows = [
        ("uvm_bound/uvm_fault_ceiling", 0.0,
         f"{UVM_FAULT_IOPS/1e3:.0f}K IOPs (paper measurement)"),
        ("uvm_bound/bam_issue_rate", us,
         f"{bam_rate/1e6:.2f}M IOPs -> {bam_rate/UVM_FAULT_IOPS:.1f}x the "
         "UVM ceiling"),
        ("uvm_bound/optane_demand", 0.0,
         f"1 Optane needs {INTEL_OPTANE_P5800X.read_iops_512/1e6:.1f}M IOPs"
         f" = {INTEL_OPTANE_P5800X.read_iops_512/UVM_FAULT_IOPS:.0f}x the"
         " UVM ceiling"),
        ("uvm_bound/980pro_demand", 0.0,
         f"1 980pro needs {SAMSUNG_980PRO.read_iops_512/1e6:.2f}M IOPs"),
    ]
    return rows
