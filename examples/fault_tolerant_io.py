"""Fault-tolerant training over a fault-injectable storage tier.

Wires the two fault layers of this repo together:

* the **storage** fault layer — a :class:`~repro.core.ssd.FaultModel` on
  the ``ArrayOfSSDs`` behind a :class:`~repro.core.BamArray` injects
  deterministic per-command transient errors; ``wait_ex`` surfaces the
  lanes that degraded as an ``error_mask`` instead of silently zeroing
  data into the model;
* the **training** fault layer — ``run_training``'s checkpoint / restore
  / replay loop (``training/fault_tolerance.py``), whose
  :class:`FailureInjector` decides when a step "crashes".

The bridge is :class:`StorageFailureInjector`: instead of a hard-coded
``fail_at`` schedule, ``maybe_fail(step)`` fetches the step's wavefront
through the faulty array and raises exactly when the I/O round reports
degraded lanes — an uncorrected storage error *is* the failure schedule.
The driver restores the model from the latest checkpoint and replays;
the replayed fetch issues fresh commands (new tickets → new hash draws),
so a transient storage error heals on retry, exactly like the in-round
retry budget but at checkpoint granularity.  Raising the in-round
``--retry-budget`` makes the same error rate complete with zero
restarts — the two recovery layers trade off visibly.

    PYTHONPATH=src python examples/fault_tolerant_io.py
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ArrayOfSSDs, BamArray, INTEL_OPTANE_P5800X, IORequest
from repro.core.ssd import FaultModel
from repro.training.fault_tolerance import FailureInjector, run_training


class StorageFailureInjector(FailureInjector):
    """A FailureInjector whose schedule IS the storage fault layer.

    ``maybe_fail(step)`` performs the step's read through the faulty
    ``BamArray`` and raises when any lane degrades; clean fetches are
    cached for ``batch_for_step`` to hand to the train step.
    """

    def __init__(self, arr, st, wavefront_for_step):
        super().__init__()
        self.arr = arr
        self.st = st
        self.wavefront_for_step = wavefront_for_step
        self.batches = {}
        self.degraded_steps = []

    def maybe_fail(self, step: int):
        if step in self.batches:          # replay after restore: re-fetch
            del self.batches[step]
        idx = self.wavefront_for_step(step)
        self.st, tok = self.arr.submit(self.st, IORequest.read(idx))
        self.st, vals, err = self.arr.wait_ex(self.st, tok)
        n_err = int(np.asarray(err).sum())
        if n_err:
            self.degraded_steps.append(step)
            raise self.exc(
                f"storage degraded {n_err} lane(s) at step {step}")
        self.batches[step] = vals


# A toy model: learn the mean of the storage tier from sampled batches.
@jax.jit
def train_step(state, batch):
    mean = jnp.mean(batch)
    w = state["w"] + 0.1 * (mean - state["w"])
    return {"w": w}, {"loss": (mean - w) ** 2}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--elems", type=int, default=1 << 20)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--wavefront", type=int, default=512)
    ap.add_argument("--error-rate", type=float, default=2e-3,
                    help="per-command transient error probability")
    ap.add_argument("--retry-budget", type=int, default=0,
                    help="in-round retries before a command errors")
    ap.add_argument("--workdir", default=None)
    args = ap.parse_args()
    workdir = args.workdir or tempfile.mkdtemp(prefix="bam_ft_")

    rng = np.random.default_rng(0)
    data = rng.standard_normal(args.elems).astype(np.float32)

    fault = FaultModel(transient_error_rate=args.error_rate,
                       retry_budget=args.retry_budget,
                       tail_latency_mult=2.0, seed=1)
    arr, st = BamArray.build(
        data, block_elems=256, num_sets=32, ways=4,
        num_queues=8, queue_depth=1024,
        ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, 2, fault=fault))

    def wavefront_for_step(step):     # pure in step => replay-deterministic
        r = np.random.default_rng(step)
        return jnp.asarray(r.integers(0, args.elems, args.wavefront),
                           jnp.int32)

    injector = StorageFailureInjector(arr, st, wavefront_for_step)

    result = run_training(
        train_step,
        init_state=lambda: {"w": jnp.zeros((), jnp.float32)},
        batch_for_step=lambda step: injector.batches[step],
        n_steps=args.steps,
        ckpt_dir=workdir, ckpt_every=5,
        max_restarts=args.steps,
        failure_injector=injector,
    )

    m = injector.st.metrics
    print("== fault-tolerant I/O demo ==")
    print(f"steps completed        : {result.step}/{args.steps}")
    print(f"storage-triggered      : {len(injector.degraded_steps)} "
          f"restart(s) at steps {injector.degraded_steps}")
    print(f"transient errors       : {int(m.transient_errors)} "
          f"(retries {int(m.retries)}, failed commands "
          f"{int(m.failed_commands)})")
    print(f"degraded read lanes    : {int(m.degraded_reads)}")
    print(f"learned mean           : {float(result.state['w']):+.5f} "
          f"(true {float(data.mean()):+.5f})")
    assert result.step == args.steps
    # every failure the storage layer injected was healed by replay
    assert result.restarts == len(injector.degraded_steps)


if __name__ == "__main__":
    main()
