"""Paper §IV-B end to end: BFS + CC over a BamArray-backed graph.

    PYTHONPATH=src python examples/graph_analytics.py [--nodes 4000]
"""
import argparse

import numpy as np

from repro.core.ssd import ArrayOfSSDs, INTEL_OPTANE_P5800X, PCIE_GEN4_X16_BW
from repro.graph import BamGraph, bfs, bfs_oracle, cc, random_graph


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=3000)
    ap.add_argument("--avg-deg", type=float, default=12.0)
    ap.add_argument("--ssds", type=int, default=4)
    args = ap.parse_args()

    indptr, dst = random_graph(args.nodes, args.avg_deg, seed=0)
    print(f"graph: {args.nodes} nodes, {len(dst)} directed edges "
          f"({dst.nbytes/1e6:.1f} MB edge list on 'SSD')")

    g = BamGraph.build(indptr, dst, cacheline_bytes=4096,
                       cache_bytes=1 << 18,
                       ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, args.ssds))
    # Frontier-ahead via async tokens: iteration t submits iteration t+1's
    # edge fetch as an IOToken, so the storage commands are in flight
    # across the iteration boundary (no hint duplication, no extra reads).
    depth, st = bfs(g, 0, async_tokens=True)
    assert (depth == bfs_oracle(indptr, dst, 0)).all()
    m = st.metrics.summary()
    t_load = dst.nbytes / PCIE_GEN4_X16_BW
    print(f"BFS   : reached {(depth >= 0).sum()} nodes, max depth "
          f"{depth.max()} (frontier-ahead async tokens)")
    print(f"        bam sim time {m['sim_time_s']*1e3:.3f} ms | target-T "
          f"file load alone {t_load*1e3:.3f} ms")
    print(f"        hit rate {m['hit_rate']:.2f}, amplification "
          f"{m['amplification']:.2f}x, peak queue depth "
          f"{m['max_queue_depth']}")

    g2 = BamGraph.build(indptr, dst, cacheline_bytes=4096,
                        cache_bytes=1 << 18,
                        ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, args.ssds))
    labels, st2 = cc(g2)
    m2 = st2.metrics.summary()
    print(f"CC    : {len(set(labels.tolist()))} components")
    print(f"        bam sim time {m2['sim_time_s']*1e3:.3f} ms, hit rate "
          f"{m2['hit_rate']:.2f}")


if __name__ == "__main__":
    main()
