"""Quickstart: the BaM core in one page.

Builds a storage-backed BamArray, reads a sparse wavefront on demand
(coalesce -> cache -> NVMe queues -> gather), and prints the I/O metrics
that are the paper's whole argument: fine-grain on-demand access moves a
tiny fraction of the bytes a coarse-grain staging approach would.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ArrayOfSSDs, BamArray, INTEL_OPTANE_P5800X


def main():
    rng = np.random.default_rng(0)
    # A "massive" structure: 8M floats (32 MB) on the storage tier.
    big = rng.standard_normal((8 << 20,)).astype(np.float32)

    # BamArray: 4KB cache lines, 1MB on-accelerator software cache,
    # 16 NVMe queue pairs, one simulated Optane SSD behind it.
    arr, st = BamArray.build(
        big.reshape(1, -1), block_elems=1024,
        num_sets=64, ways=4, num_queues=16, queue_depth=1024,
        ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, 1))

    # The compute decides what to read: a sparse, data-dependent wavefront.
    idx = rng.integers(0, big.size, 4096).astype(np.int32)

    read = jax.jit(arr.read)
    vals, st = read(st, jnp.asarray(idx))
    np.testing.assert_allclose(np.asarray(vals), big[idx], rtol=1e-6)

    m = st.metrics.summary()
    print("== BaM quickstart ==")
    print(f"requests               : {m['requests']:.0f}")
    print(f"cache-line misses      : {m['misses']:.0f}  (dedup'd by the "
          "warp coalescer)")
    print(f"bytes from storage     : {m['bytes_from_storage']:.3e}")
    print(f"I/O amplification      : {m['amplification']:.1f}x "
          f"(whole-array staging would be "
          f"{big.nbytes / m['bytes_requested']:.0f}x)")
    print(f"simulated device time  : {m['sim_time_s']*1e3:.3f} ms "
          f"({m['read_iops']/1e6:.2f}M IOPs)")
    print(f"doorbells rung         : {m['doorbells']:.0f} "
          "(batched: one per queue per wavefront)")

    # Second touch: the software cache absorbs it.
    vals, st = read(st, jnp.asarray(idx))
    m2 = st.metrics.summary()
    print(f"re-read hit rate       : "
          f"{(m2['hits']-m['hits'])/max(m2['hits']+m2['misses']-m['hits']-m['misses'],1):.2f}")


if __name__ == "__main__":
    main()
