"""Quickstart: the BaM core in one page.

Builds a storage-backed BamArray and drives it through the *first-class
async* I/O surface: ``submit`` enqueues a wavefront's storage commands
(coalesce -> probe -> pin -> SQ rings) and returns an ``IOToken``;
``wait`` drains, DMAs, fills the cache and gathers the elements.  Holding
several tokens in flight is what fills the queues to the depth Little's
law demands — then prints the I/O metrics that are the paper's whole
argument: fine-grain on-demand access moves a tiny fraction of the bytes
a coarse-grain staging approach would.

    PYTHONPATH=src python examples/quickstart.py
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ArrayOfSSDs, BamArray, INTEL_OPTANE_P5800X, IORequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--elems", type=int, default=8 << 20,
                    help="elements on the storage tier")
    ap.add_argument("--wavefront", type=int, default=1024)
    ap.add_argument("--window", type=int, default=4,
                    help="submission-window depth (outstanding tokens)")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    # A "massive" structure (32 MB at the default size) on the storage tier.
    big = rng.standard_normal((args.elems,)).astype(np.float32)

    # BamArray: 4KB cache lines, 1MB on-accelerator software cache,
    # 16 NVMe queue pairs, one simulated Optane SSD behind it.
    arr, st = BamArray.build(
        big.reshape(1, -1), block_elems=1024,
        num_sets=64, ways=4, num_queues=16, queue_depth=1024,
        ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, 1))

    # The compute decides what to read: sparse, data-dependent wavefronts.
    waves = [rng.integers(0, big.size, args.wavefront).astype(np.int32)
             for _ in range(args.window)]

    # Async window: submit every wavefront before waiting the first — their
    # commands coexist in the SQ rings and drain at batched concurrency.
    submit = arr.submit_jit()          # jit-cached: compiles once per shape
    wait = arr.wait_jit()
    tokens = []
    for idx in waves:
        st, tok = submit(st, IORequest.read(jnp.asarray(idx)))
        tokens.append(tok)
    for idx, tok in zip(waves, tokens):
        st, vals = wait(st, tok)
        np.testing.assert_allclose(np.asarray(vals), big[idx], rtol=1e-6)

    m = st.metrics.summary()
    print("== BaM quickstart ==")
    print(f"requests               : {m['requests']:.0f}")
    print(f"cache-line misses      : {m['misses']:.0f}  (dedup'd by the "
          "warp coalescer)")
    print(f"in-flight window       : {m['max_tokens_in_flight']} tokens, "
          f"{m['max_queue_depth']} queued commands at peak")
    print(f"bytes from storage     : {m['bytes_from_storage']:.3e}")
    print(f"I/O amplification      : {m['amplification']:.1f}x "
          f"(whole-array staging would be "
          f"{big.nbytes / m['bytes_requested']:.0f}x)")
    print(f"simulated device time  : {m['sim_time_s']*1e3:.3f} ms "
          f"({m['read_iops']/1e6:.2f}M IOPs)")
    print(f"doorbells rung         : {m['doorbells']:.0f} "
          "(batched: one per queue per wavefront)")

    # Second touch: the software cache absorbs it (sync shim = submit+wait).
    read = arr.read_jit()
    _, st = read(st, jnp.asarray(waves[0]))
    m2 = st.metrics.summary()
    print(f"re-read hit rate       : "
          f"{(m2['hits']-m['hits'])/max(m2['hits']+m2['misses']-m['hits']-m['misses'],1):.2f}")


if __name__ == "__main__":
    main()
