"""End-to-end serving driver: continuous batching over the BaM-paged KV
cache, with cold pages spilling to the storage tier and returning on
demand (the paper's mechanism applied to LM decode).

    PYTHONPATH=src python examples/serve_lm.py --requests 8
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import smoke_config
from repro.models.model import build_model, count_params
from repro.serving import PagedKVManager, ServeEngine
from repro.serving.engine import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--hot-window", type=int, default=32)
    args = ap.parse_args()

    cfg = smoke_config("gemma3_12b").replace(
        window=None, local_ratio=(0, 1), dtype="float32",
        n_layers=4, d_model=128, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=256, kv_page_size=16)
    api = build_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0), args.max_seq)
    print(f"serving a {count_params(params)/1e6:.1f}M-param model, "
          f"{args.slots} slots, page size {cfg.kv_page_size}, hot window "
          f"{args.hot_window} tokens")

    kv = PagedKVManager(keep_last=args.hot_window)
    eng = ServeEngine(cfg, params, batch_slots=args.slots,
                      max_seq=args.max_seq, kv_manager=kv)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab, 16).tolist(),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    m = kv.metrics.summary()
    print(f"completed {done}/{len(reqs)} requests, {toks} tokens in "
          f"{dt:.1f}s ({toks/dt:.1f} tok/s on CPU)")
    print(f"BaM paged-KV: spilled {m['write_ops']:.0f} pages, fetched back "
          f"{m['misses']:.0f}, simulated device time "
          f"{m['sim_time_s']*1e3:.2f} ms")
    print("sample:", reqs[0].out)


if __name__ == "__main__":
    main()
