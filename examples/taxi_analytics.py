"""Paper §IV-C end to end: the six data-dependent taxi queries.

    PYTHONPATH=src python examples/taxi_analytics.py [--rows 262144]
"""
import argparse

from repro.analytics import (QUERIES, make_taxi_table, run_query,
                             run_query_baseline)
from repro.analytics.taxi import scan_column


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1 << 17)
    ap.add_argument("--scan-window", type=int, default=4,
                    help="async submission window for the column scan demo")
    args = ap.parse_args()

    tbl = make_taxi_table(args.rows)
    print(f"taxi table: {args.rows} rows x 7 columns "
          f"({args.rows*7*4/1e6:.1f} MB) | filter selectivity 0.05%")
    print(f"{'query':>6} {'value':>9} {'bam amp':>8} {'cpu amp':>8} "
          f"{'bam MB':>8} {'cpu MB':>8}")
    for q in QUERIES:
        r, io = run_query(tbl, q)
        rb, iob = run_query_baseline(tbl, q)
        assert abs(r["value"] - rb["value"]) < 1e-3
        print(f"{q:>6} {r['value']:9.4f} {io['amplification']:8.2f} "
              f"{iob['amplification']:8.2f} "
              f"{io['bytes_moved_total']/1e6:8.3f} "
              f"{iob['bytes_moved_total']/1e6:8.3f}")
    print("(paper Fig. 2: CPU-centric amplification grows 6.3x -> 10.4x; "
          "BaM stays near 1)")

    # Async column scan: hold --scan-window wavefronts in flight through
    # the submit/wait token API so the queues fill toward Little's-law
    # depth instead of draining one wavefront at a time.
    tbl_sync = make_taxi_table(args.rows)
    _, m_sync = scan_column(tbl_sync, "tolls")
    tbl_async = make_taxi_table(args.rows)
    _, m_async = scan_column(tbl_async, "tolls", window=args.scan_window)
    print(f"column scan: sync {m_sync['sim_time_s']*1e3:.3f} ms vs "
          f"window={args.scan_window} async {m_async['sim_time_s']*1e3:.3f} "
          f"ms (in-flight depth {m_async['max_queue_depth']} vs "
          f"{m_sync['max_queue_depth']})")


if __name__ == "__main__":
    main()
