"""Paper §IV-C end to end: the six data-dependent taxi queries.

    PYTHONPATH=src python examples/taxi_analytics.py [--rows 262144]
"""
import argparse

from repro.analytics import (QUERIES, make_taxi_table, run_query,
                             run_query_baseline)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1 << 17)
    args = ap.parse_args()

    tbl = make_taxi_table(args.rows)
    print(f"taxi table: {args.rows} rows x 7 columns "
          f"({args.rows*7*4/1e6:.1f} MB) | filter selectivity 0.05%")
    print(f"{'query':>6} {'value':>9} {'bam amp':>8} {'cpu amp':>8} "
          f"{'bam MB':>8} {'cpu MB':>8}")
    for q in QUERIES:
        r, io = run_query(tbl, q)
        rb, iob = run_query_baseline(tbl, q)
        assert abs(r["value"] - rb["value"]) < 1e-3
        print(f"{q:>6} {r['value']:9.4f} {io['amplification']:8.2f} "
              f"{iob['amplification']:8.2f} "
              f"{io['bytes_moved_total']/1e6:8.3f} "
              f"{iob['bytes_moved_total']/1e6:8.3f}")
    print("(paper Fig. 2: CPU-centric amplification grows 6.3x -> 10.4x; "
          "BaM stays near 1)")


if __name__ == "__main__":
    main()
