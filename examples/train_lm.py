"""End-to-end training driver: data pipeline -> sharded train step ->
checkpoint/restart, on a synthetic corpus.

Default is a laptop-scale config (few minutes on this CPU).  ``--big``
selects a ~100M-parameter minitron-family model — the same driver, just
bigger dims (use on real hardware).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python -m repro.launch.train ...   # cluster launcher
"""
import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data import DataConfig, Loader, TokenStore, synth_corpus
from repro.models.model import build_model, count_params
from repro.training import optimizer as opt
from repro.training.fault_tolerance import run_training
from repro.training.train_loop import make_train_step


def small_cfg(big: bool) -> ArchConfig:
    if big:
        return ArchConfig(name="demo-100m", family="dense", n_layers=8,
                          d_model=768, n_heads=12, n_kv_heads=4,
                          d_ff=2048, vocab=32768, dtype="float32",
                          remat="none")
    return ArchConfig(name="demo-8m", family="dense", n_layers=4,
                      d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
                      vocab=8192, dtype="float32", remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--workdir", default="/tmp/bam_train_demo")
    args = ap.parse_args()

    cfg = small_cfg(args.big)
    api = build_model(cfg)
    wd = Path(args.workdir)
    corpus = wd / "corpus.bin"
    if not corpus.exists():
        synth_corpus(corpus, n_tokens=2_000_000, vocab=cfg.vocab)
    loader = Loader(TokenStore.open(corpus),
                    DataConfig(seq_len=args.seq, global_batch=args.batch))

    acfg = opt.AdamWConfig(lr=3e-3, warmup=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, api, adamw=acfg),  # bamlint: ignore[BAM105]
                      donate_argnums=(0,))

    def init_state():
        params, _ = api.init(jax.random.PRNGKey(0), args.seq)
        print(f"model: {count_params(params)/1e6:.1f}M params")
        return {"params": params, "opt": opt.adamw_init(params, acfg)}

    def batch_for_step(s):
        b = loader.batch_for_step(s)
        return {"tokens": jnp.asarray(b["tokens"])}

    t0 = time.time()

    def on_metrics(step, m):
        if step % 20 == 0:
            tok_s = step * args.batch * args.seq / (time.time() - t0)
            print(f"step {step:5d}  loss {m['loss']:.4f}  "
                  f"gnorm {m['grad_norm']:.2f}  {tok_s:,.0f} tok/s")

    res = run_training(step_fn, init_state, batch_for_step, args.steps,
                       ckpt_dir=wd / "ckpt", ckpt_every=50,
                       on_metrics=on_metrics)
    first = sum(m["loss"] for m in res.metrics_history[:10]) / 10
    last = sum(m["loss"] for m in res.metrics_history[-10:]) / 10
    print(f"done: loss {first:.3f} -> {last:.3f} over {res.step} steps "
          f"({res.restarts} restarts)")


if __name__ == "__main__":
    main()
