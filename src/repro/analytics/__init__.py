from repro.analytics.taxi import (TaxiTable, make_taxi_table, run_query,
                                  run_query_baseline, scan_column, QUERIES)

__all__ = ["TaxiTable", "make_taxi_table", "run_query",
           "run_query_baseline", "scan_column", "QUERIES"]
