"""Data analytics on BaM (paper §II-B, §IV-C): the NYC-taxi query suite.

A synthetic stand-in for the 1.7B-row taxi table: a columnar store whose
columns live in the BaM storage tier.  The six queries reproduce the
paper's structure — scan the ``pickup_gid`` filter column, then aggregate
1..6 *data-dependent* columns over the ~0.05% matching rows:

  Q1  avg(trip_dist)                       | +surcharge (Q3) +hail (Q4)
  Q2  avg(total_amt / trip_dist)           | +tolls (Q5)     +tax (Q6)

The CPU-centric baseline (RAPIDS in the paper) must ship every dependent
column in full; BaM fetches only the cache lines holding matching rows.
``IOMetrics.amplification`` gives Fig. 2/Fig. 10's ratio directly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from typing import Optional

from repro.core import BamArray, BamState, IORequest, PrefetchConfig
from repro.core.ssd import ArrayOfSSDs, INTEL_OPTANE_P5800X

COLUMNS = ["pickup_gid", "trip_dist", "total_amt", "surcharge",
           "hail_fee", "tolls", "tax"]

# query -> dependent columns aggregated over the filtered rows
QUERIES: Dict[str, List[str]] = {
    "Q1": ["trip_dist"],
    "Q2": ["trip_dist", "total_amt"],
    "Q3": ["trip_dist", "total_amt", "surcharge"],
    "Q4": ["trip_dist", "total_amt", "surcharge", "hail_fee"],
    "Q5": ["trip_dist", "total_amt", "surcharge", "hail_fee", "tolls"],
    "Q6": ["trip_dist", "total_amt", "surcharge", "hail_fee", "tolls",
           "tax"],
}

WILLIAMSBURG = 17                     # the filter value


@dataclasses.dataclass
class TaxiTable:
    n_rows: int
    pickup: jax.Array                  # filter column, device-resident scan
    cols: Dict[str, BamArray]
    states: Dict[str, BamState]
    host: Dict[str, np.ndarray]        # oracle copies


def make_taxi_table(n_rows: int = 1 << 18, *, selectivity: float = 5e-4,
                    block_bytes: int = 512, cache_bytes: int = 1 << 18,
                    seed: int = 0, backend: str = "sim",
                    n_devices: int = 1,
                    prefetch: Optional[PrefetchConfig] = None) -> TaxiTable:
    """``n_devices`` stripes every column over that many SSD channels —
    the Fig. 9 scaling knob for the analytics workload."""
    rng = np.random.default_rng(seed)
    pickup = rng.integers(0, 256, n_rows).astype(np.int32)
    # plant the target selectivity for gid == WILLIAMSBURG
    pickup[pickup == WILLIAMSBURG] = 255
    hits = rng.choice(n_rows, max(int(n_rows * selectivity), 1),
                      replace=False)
    pickup[hits] = WILLIAMSBURG
    host = {"pickup_gid": pickup}
    cols, states = {}, {}
    block_elems = block_bytes // 4
    for name in COLUMNS[1:]:
        data = rng.gamma(2.0, 3.0, n_rows).astype(np.float32)
        host[name] = data
        arr, st = BamArray.build(
            data.reshape(1, -1), block_elems=block_elems,
            num_sets=max(cache_bytes // block_bytes // 4, 1), ways=4,
            num_queues=16, queue_depth=1024,
            ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, n_devices), backend=backend,
            prefetch=prefetch)
        cols[name] = arr
        states[name] = st
    return TaxiTable(n_rows=n_rows, pickup=jnp.asarray(pickup), cols=cols,
                     states=states, host=host)


def run_query(tbl: TaxiTable, query: str) -> Tuple[dict, dict]:
    """BaM execution: full scan of the filter column + on-demand gathers of
    dependent columns.  Returns (result, io_summary)."""
    dep = QUERIES[query]
    match = tbl.pickup == WILLIAMSBURG                   # (N,)
    rows = jnp.where(match, jnp.arange(tbl.n_rows, dtype=jnp.int32), -1)
    vals = {}
    io = {"bytes_from_storage": 0.0, "bytes_requested": 0.0,
          "amplification": 0.0, "hit_rate": 0.0}
    for name in dep:
        arr, st = tbl.cols[name], tbl.states[name]
        v, st2 = arr.read_jit()(st, rows, match)
        tbl.states[name] = st2
        vals[name] = v
        s = st2.metrics.summary()
        io["bytes_from_storage"] += s["bytes_from_storage"]
        io["bytes_requested"] += s["bytes_requested"]
    n = jnp.maximum(match.sum(), 1).astype(jnp.float32)
    if query == "Q1":
        res = float((vals["trip_dist"] * match).sum() / n)
    else:
        dist = jnp.where(match & (vals["trip_dist"] > 0),
                         vals["trip_dist"], 1.0)
        total = vals["total_amt"]
        for extra in dep[2:]:
            total = total + vals[extra]
        res = float(((total / dist) * match).sum() / n)
    # the filter column itself is one full sequential scan
    scan_bytes = tbl.n_rows * 4
    io["scan_bytes"] = scan_bytes
    moved = io["bytes_from_storage"] + scan_bytes
    useful = io["bytes_requested"] + scan_bytes
    io["amplification"] = moved / max(useful, 1.0)
    io["bytes_moved_total"] = moved
    return {"query": query, "value": res}, io


def scan_column(tbl: TaxiTable, name: str, *, wavefront: int = 1024,
                window: int = 0) -> Tuple[float, dict]:
    """Full sequential scan of one BaM-resident column, one wavefront at a
    time — the readahead / async-window showcase.

    With ``window >= 1`` the scan holds that many wavefronts *in flight*
    through the submit/wait token API: up to ``window`` reads' SQ commands
    coexist in the rings before the oldest is drained, so the queues fill
    toward the Little's-law depth instead of draining one wavefront at a
    time (``window=0`` keeps the synchronous per-op path).

    With the table built under ``PrefetchConfig(enabled=True)``, each
    wavefront's stride-1 pattern triggers the readahead detector, so every
    wavefront after warmup finds its lines already resident (speculative
    fills promoted on the demand hit).  Returns ``(column_sum, io_summary)``
    where the summary is the column's cumulative :class:`IOMetrics`.
    """
    arr, st = tbl.cols[name], tbl.states[name]
    total = 0.0
    if window > 0:
        submit = arr.submit_jit()
        wait = arr.wait_jit()
        pending: List = []
        for start in range(0, tbl.n_rows, wavefront):
            idx = jnp.arange(start, start + wavefront, dtype=jnp.int32)
            st, tok = submit(st, IORequest.read(idx))
            pending.append(tok)
            if len(pending) >= window:
                st, v = wait(st, pending.pop(0))
                total += float(v.sum())
        while pending:
            st, v = wait(st, pending.pop(0))
            total += float(v.sum())
        tbl.states[name] = st
        return total, st.metrics.summary()
    read = arr.read_jit()
    for start in range(0, tbl.n_rows, wavefront):
        idx = jnp.arange(start, start + wavefront, dtype=jnp.int32)
        v, st = read(st, idx)
        total += float(v.sum())
    tbl.states[name] = st
    return total, st.metrics.summary()


def scan_column_runtime(rt, rst, name: str, *, n_rows: int,
                        wavefront: int = 1024, start: int = 0,
                        waves: Optional[int] = None):
    """Streaming scan of a column registered as a *shared-runtime tenant*.

    The multi-tenant analogue of :func:`scan_column`: the column lives
    behind a :class:`~repro.core.BamRuntime` tenant, so the scan contends
    with (or, under way-partitioning, is isolated from) the other tenants'
    traffic.  Scans ``waves`` wavefronts starting at row ``start``
    (``waves=None`` = one full pass), wrapping around the column.  Returns
    ``(partial_sum, rst, next_start)`` so interleaved drivers (e.g.
    ``benchmarks/mixed_tenants.py``) can advance one wavefront per round.
    """
    if waves is None:
        waves = -(-n_rows // wavefront)
    read = rt.read_jit(name)
    total = 0.0
    for _ in range(waves):
        # Past-the-end lanes are masked invalid inside read() (they
        # contribute 0), exactly like scan_column — never wrapped within a
        # wave, or a "full pass" would double-count the head rows.  The
        # stream wraps *between* waves.
        idx = start + jnp.arange(wavefront, dtype=jnp.int32)
        v, rst = read(rst, idx)
        total += float(v.sum())
        start = start + wavefront
        if start >= n_rows:
            start = 0
    return total, rst, start


def run_query_baseline(tbl: TaxiTable, query: str) -> Tuple[dict, dict]:
    """CPU-centric baseline: ships every dependent column in full (the
    RAPIDS behaviour the paper measures in Fig. 2)."""
    dep = QUERIES[query]
    pickup = tbl.host["pickup_gid"]
    match = pickup == WILLIAMSBURG
    n = max(match.sum(), 1)
    if query == "Q1":
        res = float(tbl.host["trip_dist"][match].mean())
    else:
        dist = np.where(tbl.host["trip_dist"][match] > 0,
                        tbl.host["trip_dist"][match], 1.0)
        total = tbl.host["total_amt"][match].copy()
        for extra in dep[2:]:
            total += tbl.host[extra][match]
        res = float((total / dist).mean())
    scan_bytes = tbl.n_rows * 4
    moved = scan_bytes + len(dep) * tbl.n_rows * 4       # full columns
    useful = scan_bytes + int(match.sum()) * 4 * len(dep)
    io = {"bytes_moved_total": float(moved),
          "amplification": moved / max(useful, 1),
          "scan_bytes": scan_bytes}
    return {"query": query, "value": res}, io


def oracle_value(tbl: TaxiTable, query: str) -> float:
    return run_query_baseline(tbl, query)[0]["value"]
