"""JAX version-compatibility shims.

The repo is written against the modern JAX surface (top-level
``jax.shard_map`` with ``axis_names``/``check_vma``; Pallas
``pltpu.CompilerParams``), but must also run on the 0.4.x line where those
names live elsewhere (``jax.experimental.shard_map`` with ``auto`` /
``check_rep``; ``pltpu.TPUCompilerParams``).  Route every use through this
module instead of feature-detecting at the call sites.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "tpu_compiler_params"]


def shard_map(f, mesh=None, in_specs=None, out_specs=None,
              axis_names=None, check_vma=None, **kwargs):
    """``jax.shard_map`` with graceful fallback to the experimental API.

    ``axis_names`` is the set of mesh axes the body is manual over (the
    modern keyword); ``check_vma`` maps to the old ``check_rep``.  On the
    0.4.x fallback the body runs manual over *all* mesh axes (``axis_names``
    is dropped rather than translated to the complementary ``auto`` set):
    partial-manual mode there lowers ``axis_index`` to a bare PartitionId,
    which the 0.4.x SPMD partitioner rejects.  Every body in this repo is
    replicated over its non-manual axes, so the two modes agree.
    """
    if hasattr(jax, "shard_map"):
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    if check_vma is not None:
        kwargs["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (renamed from ``TPUCompilerParams``)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)
