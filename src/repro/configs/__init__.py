from repro.configs.base import (ArchConfig, ShapeCell, SHAPES, get_config,
                                list_archs, smoke_config)

__all__ = ["ArchConfig", "ShapeCell", "SHAPES", "get_config", "list_archs",
           "smoke_config"]
