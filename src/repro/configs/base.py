"""Architecture + shape-cell config system.

Every assigned architecture is a :class:`ArchConfig` in its own module
(``src/repro/configs/<id>.py``), selectable as ``--arch <id>``.  Each arch is
paired with the four LM shape cells; ``input_specs`` produce
``ShapeDtypeStruct`` stand-ins (weak-type-correct, shardable, no device
allocation) for the dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------- shapes ---
@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


# ------------------------------------------------------------------ arch ---
@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    norm: str = "rms"           # rms | layer
    act: str = "silu"
    gated_mlp: bool = True
    rope_theta: float = 10_000.0
    pos_emb: str = "rope"       # rope | learned | none
    qk_norm: bool = False
    tie_embeddings: bool = False
    # local:global attention interleave — (n_local, n_global) repeating
    window: Optional[int] = None
    local_ratio: Tuple[int, int] = (0, 1)
    logit_softcap: Optional[float] = None
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # enc-dec (audio)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500
    # ssm / hybrid
    ssm_state: int = 0
    n_meta_tokens: int = 0
    slstm_every: int = 0        # xLSTM: one sLSTM per N blocks (0 = none)
    proj_factor: float = 2.0    # xLSTM up-projection
    # vlm
    n_patches: int = 0
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # BaM integration
    bam_kv: bool = True
    kv_page_size: int = 256
    bam_expert_paging: bool = False
    bam_embedding: bool = False
    remat: str = "full"         # none | full | dots_saveable
    # lowering
    use_pallas: str = "auto"    # auto | pallas | ref
    # serving perf: shard-local flash-decoding over the striped KV pool
    # (each model shard attends over its own pages; (m,l,acc) psum-combined)
    flash_decode_shards: bool = False
    # perf: bf16 attention tiles (q/k/v/p in bf16, f32 accumulate)
    attn_f32: bool = True
    # perf: MoE combine strategy — "gather" (XLA-chosen, replicates),
    # "allgather" (explicit AG of expert outputs, batch-local gather),
    # "scatter" (scatter-add with batch-local indices -> partial + psum)
    moe_combine: str = "gather"

    # ------------------------------------------------------------- derived
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def group(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context without O(S^2) attention or
        an unbounded dense KV read per token?  (SSM/hybrid/sliding-window.)"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.window is not None and self.local_ratio[0] > 0

    def supports_cell(self, cell: ShapeCell) -> bool:
        if cell.name == "long_500k":
            return self.is_sub_quadratic
        return True

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # number of layers that are "global attention" under the interleave
    def layer_windows(self, seq_len: int):
        """Per-layer window sizes; >= seq_len means global attention."""
        big = max(seq_len, 1 << 30 - 1)
        nl, ng = self.local_ratio
        period = max(nl + ng, 1)
        out = []
        for i in range(self.n_layers):
            if self.window is not None and nl > 0 and (i % period) < nl:
                out.append(self.window)
            else:
                out.append(big)
        return out


# -------------------------------------------------------------- registry ---
ASSIGNED = [
    "llava_next_mistral_7b", "gemma3_12b", "gemma3_1b", "qwen2_5_14b",
    "minitron_4b", "olmoe_1b_7b", "moonshot_v1_16b_a3b", "whisper_large_v3",
    "xlstm_1_3b", "hymba_1_5b",
]

_ALIASES = {a.replace("_", "-"): a for a in ASSIGNED}
_ALIASES.update({
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "gemma3-12b": "gemma3_12b", "gemma3-1b": "gemma3_1b",
    "qwen2.5-14b": "qwen2_5_14b", "minitron-4b": "minitron_4b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "whisper-large-v3": "whisper_large_v3", "xlstm-1.3b": "xlstm_1_3b",
    "hymba-1.5b": "hymba_1_5b",
})


def list_archs():
    return list(ASSIGNED)


def get_config(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod_name = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE


# ----------------------------------------------------------- input specs ---
def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Weak-type-correct, shardable, no device allocation — what the dry-run
    lowers against.  ``train``/``prefill`` feed the full-sequence step;
    ``decode`` feeds one new token per sequence (the cache comes from
    ``init_decode_cache`` via ``jax.eval_shape``).
    """
    B, S = cell.global_batch, cell.seq_len
    sds = jax.ShapeDtypeStruct
    emb_dtype = jnp.dtype(cfg.dtype)
    if cell.kind in ("train", "prefill"):
        batch = {}
        if cfg.family == "vlm":
            n_patch = min(cfg.n_patches, S // 2)
            batch["patch_embeds"] = sds((B, n_patch, cfg.d_model), emb_dtype)
            batch["tokens"] = sds((B, S - n_patch), jnp.int32)
        elif cfg.family == "audio":
            batch["enc_frames"] = sds((B, cfg.enc_seq, cfg.d_model),
                                      emb_dtype)
            batch["tokens"] = sds((B, S), jnp.int32)
        else:
            batch["tokens"] = sds((B, S), jnp.int32)
        return batch
    # decode: one token per sequence
    return {"tokens": sds((B,), jnp.int32)}
