"""gemma3-12b — 5:1 local:global sliding-window interleave, 262k vocab.

Local layers use a 1024-token window; every 6th layer is global.  The
global layers' decode KV is BaM-paged (page table + striped pool), which is
what makes long_500k runnable (locals are O(W), globals O(S) per token).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab=262144, qk_norm=True, tie_embeddings=True,
    window=1024, local_ratio=(5, 1), rope_theta=1_000_000.0, act="gelu",
)

SMOKE = ArchConfig(
    name="gemma3-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, qk_norm=True, tie_embeddings=True,
    window=8, local_ratio=(2, 1), act="gelu", dtype="float32",
    kv_page_size=8,
)
