"""gemma3-1b — small gemma3: 5:1 local:global, kv=1 (MQA), 262k vocab."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262144, qk_norm=True, tie_embeddings=True,
    window=512, local_ratio=(5, 1), rope_theta=1_000_000.0, act="gelu",
)

SMOKE = ArchConfig(
    name="gemma3-1b-smoke", family="dense",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=1, head_dim=12,
    d_ff=96, vocab=128, qk_norm=True, tie_embeddings=True,
    window=8, local_ratio=(2, 1), act="gelu", dtype="float32",
    kv_page_size=8,
)
