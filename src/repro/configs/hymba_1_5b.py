"""hymba-1.5b — parallel attention + mamba heads (arXiv:2411.13676).

Every layer fuses a sliding-window GQA path with a selective-SSM path;
layers {0, L/2, L-1} are global attention; 128 meta tokens prepended.
long_500k: RUNS (SWA + O(1) SSM state; 3 global layers O(S)/token, paged).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001, ssm_state=16, n_meta_tokens=128,
    window=1024,
)

SMOKE = ArchConfig(
    name="hymba-smoke", family="hybrid",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, ssm_state=4, n_meta_tokens=4, window=8,
    dtype="float32", kv_page_size=8,
)
