"""llava-next-mistral-7b — Mistral-7B backbone + anyres vision tiling (stub).

The modality frontend is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings (B, n_patches, d_model) which the model
prepends to the text embeddings.  long_500k: SKIPPED (pure full attention).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, rope_theta=1_000_000.0, n_patches=2880,
)

SMOKE = ArchConfig(
    name="llava-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, n_patches=8, dtype="float32", kv_page_size=8,
)
