"""minitron-4b — width-pruned nemotron (arXiv:2407.14679).
long_500k: SKIPPED (pure full attention)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=9216,
    vocab=256000, gated_mlp=False, act="gelu",
)

SMOKE = ArchConfig(
    name="minitron-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, gated_mlp=False, act="gelu", dtype="float32",
    kv_page_size=8,
)
