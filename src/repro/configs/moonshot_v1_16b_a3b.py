"""moonshot-v1-16b-a3b (kimi/moonlight) — 64-expert top-6 MoE.
long_500k: SKIPPED (full attention)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=163840, moe=True, n_experts=64, top_k=6,
    bam_expert_paging=True,
)

SMOKE = ArchConfig(
    name="moonshot-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=48,
    vocab=256, moe=True, n_experts=8, top_k=2, dtype="float32",
    kv_page_size=8, bam_expert_paging=True,
)
