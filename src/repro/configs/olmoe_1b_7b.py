"""olmoe-1b-7b — 64-expert top-8 MoE (arXiv:2409.02060).

BaM integration: expert weights are paged through the BaM cache at decode
(`bam_expert_paging`) — routing decides which experts' blocks are fetched,
the paper's 'compute decides what to read'. long_500k: SKIPPED (full attn).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024,
    vocab=50304, moe=True, n_experts=64, top_k=8, qk_norm=True,
    bam_expert_paging=True,
)

SMOKE = ArchConfig(
    name="olmoe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
    vocab=256, moe=True, n_experts=8, top_k=2, qk_norm=True,
    dtype="float32", kv_page_size=8, bam_expert_paging=True,
)
