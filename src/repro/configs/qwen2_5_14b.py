"""qwen2.5-14b — dense GQA with QKV bias. long_500k: SKIPPED (full attn)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824,
    vocab=152064, qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE = ArchConfig(
    name="qwen-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, qkv_bias=True, dtype="float32", kv_page_size=8,
)
