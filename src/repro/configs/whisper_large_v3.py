"""whisper-large-v3 — enc-dec audio transformer (arXiv:2212.04356).

Conv/mel frontend is a STUB: ``input_specs`` provides precomputed frame
embeddings (B, 1500, d_model) for the encoder.  Decoder = causal self-attn
+ cross-attn.  long_500k: SKIPPED (full attention, enc-dec).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab=51866, enc_dec=True, n_enc_layers=32, enc_seq=1500,
    norm="layer", act="gelu", gated_mlp=False, pos_emb="learned",
)

SMOKE = ArchConfig(
    name="whisper-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=256, enc_dec=True, n_enc_layers=2, enc_seq=16,
    norm="layer", act="gelu", gated_mlp=False, pos_emb="learned",
    dtype="float32", kv_page_size=8,
)
