"""xlstm-1.3b — sLSTM + mLSTM blocks (arXiv:2405.04517), 7:1 interleave.

d_ff=0: xLSTM blocks carry their own 2x up-projection (proj_factor).
long_500k: RUNS (O(1) recurrent state per token).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, slstm_every=8, proj_factor=2.0,
)

SMOKE = ArchConfig(
    name="xlstm-smoke", family="ssm",
    n_layers=3, d_model=32, n_heads=2, n_kv_heads=2, d_ff=0,
    vocab=128, slstm_every=3, proj_factor=2.0, dtype="float32",
)
