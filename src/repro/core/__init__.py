"""BaM core — the paper's contribution as a composable JAX library.

Layers (bottom-up):
  ssd.py        device cost models + Little's-law math   (paper §II-C, Tab. III)
  storage.py    block-store backends (host sim / in-graph)
  queues.py     high-throughput SQ/CQ rings              (paper §III-C)
  coalescer.py  wavefront dedup ("warp coalescing")      (paper §III-D)
  cache.py      set-assoc clock software cache           (paper §III-D)
  bam_array.py  BamArray / BamKVStore abstractions       (paper §III-E)
  prefetch.py   stride readahead + prefetch hints        (beyond paper)
  pipeline.py   fetch/compute overlap (latency hiding)   (paper §III-B)
  metrics.py    I/O amplification & throughput counters  (paper §II-B)
"""
from repro.core.bam_array import (
    BamArray, BamKVStore, BamRuntime, BamState, IORequest, IOToken,
    RuntimeState, TenantCtx, TenantSpec,
)
from repro.core.cache import CacheState, make_cache
from repro.core.coalescer import CoalesceResult, coalesce
from repro.core.metrics import (
    IOMetrics, metrics_accumulate, metrics_delta, metrics_sum,
)
from repro.core.pipeline import pipelined_bam_map, software_pipeline
from repro.core.prefetch import PrefetchConfig, modal_stride, readahead_keys
from repro.core.queues import (
    QueueState, enqueue, in_flight, in_flight_per_device,
    in_flight_per_tenant, make_queues, service_all,
)
from repro.core.ssd import (
    ArrayOfSSDs, SSDSpec, SSD_PRESETS, DRAM_DIMM, INTEL_OPTANE_P5800X,
    SAMSUNG_980PRO, SAMSUNG_ZNAND_P1735, device_histogram, device_of_block,
    required_queue_depth, sustained_rate, target_iops_for_link,
)
from repro.core.storage import HBMStorage, SimStorage

__all__ = [
    "BamArray", "BamKVStore", "BamRuntime", "BamState", "IORequest",
    "IOToken", "RuntimeState",
    "TenantCtx", "TenantSpec", "CacheState", "make_cache",
    "CoalesceResult", "coalesce", "IOMetrics", "metrics_accumulate",
    "metrics_delta", "metrics_sum", "pipelined_bam_map",
    "software_pipeline", "PrefetchConfig", "modal_stride", "readahead_keys",
    "QueueState", "enqueue", "in_flight", "in_flight_per_device",
    "in_flight_per_tenant", "make_queues", "service_all",
    "ArrayOfSSDs", "SSDSpec", "SSD_PRESETS", "DRAM_DIMM",
    "INTEL_OPTANE_P5800X", "SAMSUNG_980PRO", "SAMSUNG_ZNAND_P1735",
    "device_histogram", "device_of_block",
    "required_queue_depth", "sustained_rate", "target_iops_for_link",
    "HBMStorage", "SimStorage",
]
