"""``BamArray<T>`` / ``BamKVStore`` — BaM's high-level abstractions (§III-E).

``BamArray`` is the paper's array whose subscript operator transparently:
coalesces the wavefront's accesses, probes the software cache, issues NVMe
reads for the misses through the high-throughput queues, fills the cache,
and returns elements.  Here the subscript is a *functional* ``read``:

    values, state' = bam.read(state, flat_indices)

with every piece of BaM state (cache, queues, I/O metrics, and — for the
in-graph backend — the storage tier itself) threaded through explicitly.

The life of a wavefront (paper Fig. 3, adapted):

    element idx ──► block key + offset
        │ coalesce (warp coalescer, §III-D)          -> unique lines, leaders
        │ probe cache                                 -> hits / misses
        │   (hit on a prefetched line: promote it, count a prefetch hit)
        │ allocate victims (clock)                    -> slots (or bypass)
        │ gather evicted dirty lines                  -> write-back commands
        │ readahead detect + speculative allocate     -> low-priority fills
        │ enqueue reads+write-backs+readahead,        -> SQ rings (§III-C)
        │   ring doorbells (demand lane drains first)
        │ service (simulated NVMe drain + DMA)        -> fetched lines
        │ fill cache, update tags/dirty/speculative
        ▼ gather elements (hit: cache line, miss: fetched line)

Requests dropped by full rings are still served read-through (and counted),
so a mis-sized queue config degrades accounting, never correctness.

Prefetching is off by default (``PrefetchConfig.enabled``); when on, the
stride detector in :mod:`repro.core.prefetch` extrapolates the wavefront's
block pattern ``window`` lines ahead and brings those lines in through the
readahead lane as evict-first *speculative* residents.  The explicit
:meth:`BamArray.prefetch` API lets applications (BFS frontiers, column
scans) push known-future wavefronts directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cache as C
from repro.core import queues as Q
from repro.core.coalescer import coalesce
from repro.core.metrics import IOMetrics
from repro.core.prefetch import PrefetchConfig, readahead_keys
from repro.core.ssd import ArrayOfSSDs, INTEL_OPTANE_P5800X, device_histogram
from repro.core.storage import HBMStorage, SimStorage
from repro.utils import pytree_dataclass, round_up

__all__ = ["BamArray", "BamState", "BamKVStore", "PrefetchConfig"]


@pytree_dataclass
class BamState:
    """All mutable BaM state, threaded functionally through reads/writes."""

    cache: C.CacheState
    queues: Q.QueueState
    metrics: IOMetrics
    storage: Any  # HBMStorage pytree for the in-graph backend, else None


@dataclasses.dataclass
class BamArray:
    """Static description of one BaM-backed array (not a pytree)."""

    storage: Any                    # SimStorage (host) or None (in-graph)
    shape: tuple
    dtype: Any
    block_elems: int
    ssd: ArrayOfSSDs = dataclasses.field(
        default_factory=lambda: ArrayOfSSDs(INTEL_OPTANE_P5800X, 1))
    prefetch_cfg: PrefetchConfig = dataclasses.field(
        default_factory=PrefetchConfig)

    # ---------------------------------------------------------------- init
    @staticmethod
    def build(data, block_elems: int, *,
              num_sets: int, ways: int = 4,
              num_queues: int = 8, queue_depth: int = 1024,
              ssd: Optional[ArrayOfSSDs] = None,
              prefetch: Optional[PrefetchConfig] = None,
              backend: str = "sim") -> Tuple["BamArray", BamState]:
        """Create the array + its initial state from a host/jnp array.

        ``backend='sim'``: data lives on the host, fetched via pure_callback
        (the NVMe DMA stand-in).  ``backend='hbm'``: data is an in-graph cold
        buffer — used by dry-runs so the compiler sees the traffic.

        The SQ pool is partitioned per storage device (``ssd.n_devices``
        equal ring groups); ``num_queues`` is rounded up to the next
        multiple of the device count so every channel gets the same depth.
        """
        import numpy as np
        shape = tuple(data.shape)
        if backend == "sim":
            store = SimStorage.from_array(np.asarray(data), block_elems)
            state_store = None
            dtype = store.dtype
        elif backend == "hbm":
            hs = HBMStorage.from_array(jnp.asarray(data), block_elems)
            store, state_store, dtype = None, hs, hs.dtype
        else:
            raise ValueError(f"unknown backend {backend!r}")
        ssd = ssd or ArrayOfSSDs(INTEL_OPTANE_P5800X, 1)
        num_queues = round_up(num_queues, ssd.n_devices)
        arr = BamArray(
            storage=store, shape=shape, dtype=dtype, block_elems=block_elems,
            ssd=ssd,
            prefetch_cfg=prefetch or PrefetchConfig())
        st = BamState(
            cache=C.make_cache(num_sets, ways, block_elems, dtype),
            queues=Q.make_queues(num_queues, queue_depth,
                                 n_devices=ssd.n_devices,
                                 stripe_blocks=ssd.stripe_blocks),
            metrics=IOMetrics.zeros(ssd.n_devices),
            storage=state_store,
        )
        return arr, st

    # ------------------------------------------------------------- helpers
    @property
    def block_bytes(self) -> int:
        return self.block_elems * jnp.dtype(self.dtype).itemsize

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n

    @property
    def num_blocks(self) -> int:
        return -(-self.size // self.block_elems)

    def with_prefetch(self, cfg: PrefetchConfig) -> "BamArray":
        """Same array, different (static) readahead policy."""
        return dataclasses.replace(self, prefetch_cfg=cfg)

    def _store(self, st: BamState):
        return self.storage if self.storage is not None else st.storage

    def _check_channels(self, st: BamState) -> None:
        """SQ routing and device accounting must share one striping.

        Both are static metadata, so a hand-assembled state that pairs
        mismatched ``make_queues``/``ArrayOfSSDs`` channel configs fails at
        trace time instead of silently charging metrics to the wrong
        device.
        """
        qs = st.queues
        if (qs.n_devices, qs.stripe_blocks) != (self.ssd.n_devices,
                                                self.ssd.stripe_blocks):
            raise ValueError(
                f"queue channels (n_devices={qs.n_devices}, "
                f"stripe_blocks={qs.stripe_blocks}) do not match the SSD "
                f"array (n_devices={self.ssd.n_devices}, "
                f"stripe_blocks={self.ssd.stripe_blocks}); build the state "
                "with BamArray.build or make_queues with the same config")

    def _split(self, idx: jax.Array):
        return (idx // self.block_elems).astype(jnp.int32), \
               (idx % self.block_elems).astype(jnp.int32)

    def _charge_channels(self, mt: IOMetrics, qs: Q.QueueState,
                         dev_reads: jax.Array, dev_writes: jax.Array,
                         depth_now: jax.Array, depth_dev: jax.Array) -> dict:
        """Device-time and per-device counter updates shared by every I/O
        path (read/write/prefetch/flush).

        Each channel drains its own share at its own Little's-law rate
        (concurrency capped by its queue group's depth); the wavefront is
        gated by the slowest channel.  Returns the IOMetrics field updates
        as kwargs so callers splice them into their own counter math.
        """
        group_limit = qs.group_size * qs.depth
        t_read, t_read_dev = self.ssd.service_time_per_device_traced(
            dev_reads, self.block_bytes, queue_depth_limit=group_limit)
        t_write, t_write_dev = self.ssd.service_time_per_device_traced(
            dev_writes, self.block_bytes, write=True,
            queue_depth_limit=group_limit)
        return dict(
            sim_time_s=mt.sim_time_s + t_read + t_write,
            read_time_s=mt.read_time_s + t_read,
            write_time_s=mt.write_time_s + t_write,
            max_queue_depth=jnp.maximum(mt.max_queue_depth,
                                        depth_now.astype(jnp.int32)),
            dev_reads=mt.dev_reads + dev_reads,
            dev_writes=mt.dev_writes + dev_writes,
            dev_bytes=mt.dev_bytes
                + (dev_reads + dev_writes) * self.block_bytes,
            dev_time_s=mt.dev_time_s + t_read_dev + t_write_dev,
            dev_max_depth=jnp.maximum(mt.dev_max_depth,
                                      depth_dev.astype(jnp.int32)),
        )

    # ---------------------------------------------------------------- read
    def read(self, st: BamState, idx: jax.Array,
             valid: jax.Array | None = None) -> Tuple[jax.Array, BamState]:
        """Gather ``self.flat[idx]`` for a wavefront of element indices."""
        self._check_channels(st)
        n = idx.shape[0]
        if valid is None:
            valid = (idx >= 0) & (idx < self.size)
        blk, off = self._split(jnp.where(valid, idx, 0))
        blk = jnp.where(valid, blk, -1)

        # 1) warp-coalesce the wavefront to unique cache lines.
        co = coalesce(blk, valid)
        ukeys = co.unique_keys                      # (n,) padded with -1
        uvalid = ukeys >= 0

        # 2) probe the software cache.  A demand hit on a prefetched line is
        #    a prefetch hit: promote the line to an ordinary resident.
        pr = C.probe(st.cache, ukeys, uvalid)
        n_hit = jnp.sum(pr.hit.astype(jnp.int32))
        n_pref_hit = jnp.sum(pr.speculative.astype(jnp.int32))
        cache1 = C.count_hits(st.cache, n_hit)
        cache1 = C.promote(cache1, jnp.where(pr.speculative, pr.slot, -1))
        miss = uvalid & ~pr.hit

        # 3) allocate victims for the misses (hits protected this round).
        cache2, alloc = C.allocate(cache1, ukeys, miss,
                                   protect_slots=pr.slot)

        # 4) evicted dirty lines -> write-back commands (gather before fill).
        ev_rows = jnp.where(alloc.ok, alloc.slot, 0)
        ev_lines = cache2.data[ev_rows]
        wb = alloc.ok & alloc.evicted_dirty & (alloc.evicted_key >= 0)
        wb_keys = jnp.where(wb, alloc.evicted_key, -1)

        # 4b) readahead: extrapolate the wavefront's stride pattern and
        #     speculatively allocate the predicted lines.  Demand slots (this
        #     round's hits and grants) are protected, so readahead can only
        #     claim invalid or stale lines — it never displaces the wavefront.
        cfg = self.prefetch_cfg
        ra_on = cfg.enabled and cfg.window > 0
        if ra_on:
            ra_cand = readahead_keys(
                ukeys, uvalid, window=cfg.window, num_blocks=self.num_blocks,
                min_support=cfg.min_support, max_stride=cfg.max_stride,
                raw_keys=blk, raw_valid=valid)
            ra_pr = C.probe(cache2, ra_cand, ra_cand >= 0)
            ra_want = (ra_cand >= 0) & ~ra_pr.hit
            # Never speculatively re-fetch a line this wavefront just
            # evicted: on the sim backend the fetch (pure_callback) is not
            # ordered against the dirty write-back (io_callback), so it
            # could observe the pre-write-back bytes — and re-fetching a
            # just-evicted line is pure thrash regardless of backend.
            evk = jnp.where(alloc.ok & (alloc.evicted_key >= 0),
                            alloc.evicted_key, -2)
            ra_want = ra_want & ~jnp.any(
                ra_cand[:, None] == evk[None, :], axis=1)
            cache2, ra_alloc = C.allocate(
                cache2, ra_cand, ra_want,
                protect_slots=jnp.concatenate([pr.slot, alloc.slot]),
                speculative=True)
            ra_keys = jnp.where(ra_alloc.ok, ra_cand, -1)
            ra_rows = jnp.where(ra_alloc.ok, ra_alloc.slot, 0)
            ra_ev_lines = cache2.data[ra_rows]
            ra_wb = ra_alloc.ok & ra_alloc.evicted_dirty \
                & (ra_alloc.evicted_key >= 0)
            ra_wb_keys = jnp.where(ra_wb, ra_alloc.evicted_key, -1)

        # 5) submit reads + write-backs to the SQ rings; ring doorbells.
        #    Readahead goes last and in the low-priority lane: it is the
        #    first thing dropped under back-pressure and the last retired.
        qs1, rec_r = Q.enqueue(st.queues, jnp.where(miss, ukeys, -1),
                               dst=alloc.slot)
        qs2, rec_w = Q.enqueue(qs1, wb_keys,
                               is_write=jnp.ones_like(wb))
        n_doorbells = rec_r.n_doorbells + rec_w.n_doorbells
        if ra_on:
            qs2, rec_rw = Q.enqueue(qs2, ra_wb_keys,
                                    is_write=jnp.ones_like(ra_wb))
            qs2, rec_ra = Q.enqueue(qs2, ra_keys, dst=ra_alloc.slot,
                                    prio=Q.PRIO_READAHEAD)
            n_doorbells = n_doorbells + rec_rw.n_doorbells + rec_ra.n_doorbells
        depth_now = Q.in_flight(qs2)
        depth_dev = Q.in_flight_per_device(qs2)
        qs3, comps = Q.service_all(qs2)

        # 6) the DMA: fetch missed lines / write back dirty lines.  Fetch
        #    keys are disjoint from this round's evictions (demand misses
        #    by the probe, readahead by the explicit exclusion above), so
        #    the unordered fetch callback can never race a write-back of
        #    the same line.
        store = self._store(st)
        lines_u = store.fetch_blocks(jnp.where(miss, ukeys, -1))
        new_storage = st.storage
        if self.storage is None:                    # in-graph backend
            new_storage = store.write_blocks(wb_keys, ev_lines)
            if ra_on:
                new_storage = new_storage.write_blocks(ra_wb_keys, ra_ev_lines)
                lines_ra = new_storage.fetch_blocks(ra_keys)
        else:
            self.storage.write_blocks(wb_keys, ev_lines)
            if ra_on:
                self.storage.write_blocks(ra_wb_keys, ra_ev_lines)
                lines_ra = self.storage.fetch_blocks(ra_keys)

        # 7) completion: fill granted slots with fetched lines.
        cache3 = C.fill(cache2, alloc.slot, alloc.ok, lines_u)
        if ra_on:
            cache3 = C.fill(cache3, ra_alloc.slot, ra_alloc.ok, lines_ra)

        # 8) gather elements back to every requester (leader broadcast).
        u = co.inverse_idx                          # (n,) request -> unique row
        hit_u = pr.hit[u]
        slot_u = jnp.where(pr.slot[u] >= 0, pr.slot[u], 0)
        from_cache = cache3.data[slot_u, off]
        from_fetch = lines_u[u, off]
        vals = jnp.where(hit_u, from_cache, from_fetch)
        vals = jnp.where(valid, vals, 0).astype(self.dtype)

        # 9) metrics.  Readahead reads share the device drain with demand
        #    (one busy-time accumulation) but are accounted separately:
        #    ``misses`` stays demand-only, ``prefetch_issued`` carries the
        #    speculative lines, and both contribute to bytes moved.  Device
        #    time is per channel: each device drains its own share, the
        #    slowest one gates the wavefront (max, not average).
        n_valid = jnp.sum(valid.astype(jnp.int32))
        n_miss = jnp.sum(miss.astype(jnp.int32))
        n_wb = jnp.sum(wb.astype(jnp.int32))
        n_ra = jnp.zeros((), jnp.int32)
        nd = self.ssd.n_devices
        dev_reads = device_histogram(ukeys, nd, miss, self.ssd.stripe_blocks)
        dev_writes = device_histogram(wb_keys, nd,
                                      stripe_blocks=self.ssd.stripe_blocks)
        if ra_on:
            n_ra = jnp.sum(ra_alloc.ok.astype(jnp.int32))
            n_wb = n_wb + jnp.sum(ra_wb.astype(jnp.int32))
            dev_reads = dev_reads + device_histogram(
                ra_keys, nd, stripe_blocks=self.ssd.stripe_blocks)
            dev_writes = dev_writes + device_histogram(
                ra_wb_keys, nd, stripe_blocks=self.ssd.stripe_blocks)
        itemsize = jnp.dtype(self.dtype).itemsize
        mt = st.metrics
        metrics = IOMetrics(
            requests=mt.requests + n_valid,
            bytes_requested=mt.bytes_requested + n_valid * itemsize,
            hits=mt.hits + n_hit,
            misses=mt.misses + n_miss,
            bytes_from_storage=mt.bytes_from_storage
                + (n_miss + n_ra) * self.block_bytes,
            write_ops=mt.write_ops + n_wb,
            bytes_to_storage=mt.bytes_to_storage + n_wb * self.block_bytes,
            doorbells=mt.doorbells + n_doorbells,
            prefetch_issued=mt.prefetch_issued + n_ra,
            prefetch_hits=mt.prefetch_hits + n_pref_hit,
            **self._charge_channels(mt, st.queues, dev_reads, dev_writes,
                                    depth_now, depth_dev),
        )
        return vals, BamState(cache=cache3, queues=qs3, metrics=metrics,
                              storage=new_storage)

    # ------------------------------------------------------------- prefetch
    def prefetch(self, st: BamState, idx: jax.Array,
                 valid: jax.Array | None = None) -> BamState:
        """Hint the array at a future wavefront: warm the cache, no values.

        The lines covering ``idx`` are brought in through the low-priority
        readahead lane as *speculative* residents — inserted without pin, so
        a hint that never materialises is the first thing the clock hand
        reclaims.  Already-resident lines and invalid/out-of-range lanes
        cost nothing.  Works regardless of :class:`PrefetchConfig.enabled`
        (that flag only gates the automatic stride readahead in
        :meth:`read`).  Demand counters (requests/hits/misses) are untouched:
        a prefetch is not compute traffic.
        """
        self._check_channels(st)
        if valid is None:
            valid = (idx >= 0) & (idx < self.size)
        blk, _ = self._split(jnp.where(valid, idx, 0))
        blk = jnp.where(valid, blk, -1)

        co = coalesce(blk, valid)
        ukeys = co.unique_keys
        uvalid = ukeys >= 0
        pr = C.probe(st.cache, ukeys, uvalid)
        want = uvalid & ~pr.hit
        cache1, alloc = C.allocate(st.cache, ukeys, want,
                                   protect_slots=pr.slot, speculative=True)
        ev_rows = jnp.where(alloc.ok, alloc.slot, 0)
        ev_lines = cache1.data[ev_rows]
        wb = alloc.ok & alloc.evicted_dirty & (alloc.evicted_key >= 0)
        wb_keys = jnp.where(wb, alloc.evicted_key, -1)
        keys = jnp.where(alloc.ok, ukeys, -1)

        qs1, rec_w = Q.enqueue(st.queues, wb_keys, is_write=jnp.ones_like(wb))
        qs2, rec_r = Q.enqueue(qs1, keys, dst=alloc.slot,
                               prio=Q.PRIO_READAHEAD)
        depth_now = Q.in_flight(qs2)
        depth_dev = Q.in_flight_per_device(qs2)
        qs3, _ = Q.service_all(qs2)

        store = self._store(st)
        new_storage = st.storage
        if self.storage is None:                    # in-graph backend
            new_storage = store.write_blocks(wb_keys, ev_lines)
            lines = new_storage.fetch_blocks(keys)
        else:
            self.storage.write_blocks(wb_keys, ev_lines)
            lines = self.storage.fetch_blocks(keys)
        cache2 = C.fill(cache1, alloc.slot, alloc.ok, lines)

        n_ra = jnp.sum(alloc.ok.astype(jnp.int32))
        n_wb = jnp.sum(wb.astype(jnp.int32))
        nd = self.ssd.n_devices
        dev_reads = device_histogram(keys, nd,
                                     stripe_blocks=self.ssd.stripe_blocks)
        dev_writes = device_histogram(wb_keys, nd,
                                      stripe_blocks=self.ssd.stripe_blocks)
        mt = st.metrics
        metrics = dataclasses.replace(
            mt,
            bytes_from_storage=mt.bytes_from_storage + n_ra * self.block_bytes,
            write_ops=mt.write_ops + n_wb,
            bytes_to_storage=mt.bytes_to_storage + n_wb * self.block_bytes,
            doorbells=mt.doorbells + rec_r.n_doorbells + rec_w.n_doorbells,
            prefetch_issued=mt.prefetch_issued + n_ra,
            **self._charge_channels(mt, st.queues, dev_reads, dev_writes,
                                    depth_now, depth_dev),
        )
        return BamState(cache=cache2, queues=qs3, metrics=metrics,
                        storage=new_storage)

    # --------------------------------------------------------------- write
    def write(self, st: BamState, idx: jax.Array, values: jax.Array,
              valid: jax.Array | None = None) -> BamState:
        """Element-level writes: read-modify-write with write-allocate.

        Duplicate element indices within one wavefront are last-writer-wins
        with unspecified order (as on the GPU).
        """
        self._check_channels(st)
        n = idx.shape[0]
        if valid is None:
            valid = (idx >= 0) & (idx < self.size)
        blk, off = self._split(jnp.where(valid, idx, 0))
        blk = jnp.where(valid, blk, -1)

        co = coalesce(blk, valid)
        ukeys = co.unique_keys
        uvalid = ukeys >= 0
        pr = C.probe(st.cache, ukeys, uvalid)
        n_hit = jnp.sum(pr.hit.astype(jnp.int32))
        n_pref_hit = jnp.sum(pr.speculative.astype(jnp.int32))
        cache1 = C.count_hits(st.cache, n_hit)
        cache1 = C.promote(cache1, jnp.where(pr.speculative, pr.slot, -1))
        miss = uvalid & ~pr.hit

        cache2, alloc = C.allocate(cache1, ukeys, miss, protect_slots=pr.slot)
        ev_rows = jnp.where(alloc.ok, alloc.slot, 0)
        ev_lines = cache2.data[ev_rows]
        wb = alloc.ok & alloc.evicted_dirty & (alloc.evicted_key >= 0)
        wb_keys = jnp.where(wb, alloc.evicted_key, -1)
        # Bypassed lines (no slot granted) will be written through below;
        # their commands ride the rings like every other write.
        byp = miss & ~alloc.ok
        bt_keys = jnp.where(byp, ukeys, -1)

        qs1, rec_r = Q.enqueue(st.queues, jnp.where(miss, ukeys, -1),
                               dst=alloc.slot)
        qs2, rec_w = Q.enqueue(qs1, wb_keys, is_write=jnp.ones_like(wb))
        qs2, rec_bt = Q.enqueue(qs2, bt_keys, is_write=jnp.ones_like(byp))
        depth_now = Q.in_flight(qs2)
        depth_dev = Q.in_flight_per_device(qs2)
        qs3, _ = Q.service_all(qs2)

        store = self._store(st)
        lines_u = store.fetch_blocks(jnp.where(miss, ukeys, -1))  # write-allocate
        new_storage = st.storage
        if self.storage is None:
            new_storage = store.write_blocks(wb_keys, ev_lines)
        else:
            self.storage.write_blocks(wb_keys, ev_lines)
        cache3 = C.fill(cache2, alloc.slot, alloc.ok, lines_u)

        # Scatter the new element values into their lines *in the cache*.
        u = co.inverse_idx
        slot_r = jnp.where(pr.hit[u], pr.slot[u], alloc.slot[u])  # (n,)
        in_cache = slot_r >= 0
        rows = jnp.where(valid & in_cache, slot_r, cache3.num_lines)
        cols = jnp.where(valid & in_cache, off, 0)
        data = cache3.data.at[rows, cols].set(
            values.astype(self.dtype), mode="drop")
        cache4 = C._replace_data(cache3, data=data)
        touched_slots = jnp.where(valid & in_cache, slot_r, -1)
        cache5 = C.mark_dirty(cache4, touched_slots)

        # Bypassed lines: write-through directly (enqueued above).
        byp_any = byp[u] & valid
        byp_rows = jnp.where(byp_any, u, lines_u.shape[0])
        byp_lines = lines_u.at[byp_rows, jnp.where(byp_any, off, 0)].set(
            values.astype(self.dtype), mode="drop")
        if self.storage is None:
            new_storage = new_storage.write_blocks(bt_keys, byp_lines)
        else:
            self.storage.write_blocks(bt_keys, byp_lines)

        n_valid = jnp.sum(valid.astype(jnp.int32))
        n_miss = jnp.sum(miss.astype(jnp.int32))
        n_wb = jnp.sum(wb.astype(jnp.int32)) + jnp.sum(byp.astype(jnp.int32))
        nd = self.ssd.n_devices
        dev_reads = device_histogram(ukeys, nd, miss, self.ssd.stripe_blocks)
        dev_writes = device_histogram(
            wb_keys, nd, stripe_blocks=self.ssd.stripe_blocks) \
            + device_histogram(bt_keys, nd,
                               stripe_blocks=self.ssd.stripe_blocks)
        itemsize = jnp.dtype(self.dtype).itemsize
        mt = st.metrics
        metrics = IOMetrics(
            requests=mt.requests + n_valid,
            bytes_requested=mt.bytes_requested + n_valid * itemsize,
            hits=mt.hits + n_hit,
            misses=mt.misses + n_miss,
            bytes_from_storage=mt.bytes_from_storage + n_miss * self.block_bytes,
            write_ops=mt.write_ops + n_wb,
            bytes_to_storage=mt.bytes_to_storage + n_wb * self.block_bytes,
            doorbells=mt.doorbells + rec_r.n_doorbells + rec_w.n_doorbells
                + rec_bt.n_doorbells,
            prefetch_issued=mt.prefetch_issued,
            prefetch_hits=mt.prefetch_hits + n_pref_hit,
            **self._charge_channels(mt, st.queues, dev_reads, dev_writes,
                                    depth_now, depth_dev),
        )
        return BamState(cache=cache5, queues=qs3, metrics=metrics,
                        storage=new_storage)

    def flush(self, st: BamState) -> BamState:
        """Write back every dirty resident line (shutdown / barrier path).

        Write-backs go through the SQ rings like every other I/O: enqueue,
        doorbell, drain — so ``doorbells``/``max_queue_depth`` and the
        per-device counters see shutdown traffic exactly as they see
        ``read``/``write`` write-backs.  Lines the rings cannot hold this
        round are still persisted (the drop degrades accounting, never
        correctness — same contract as the read path's read-through).
        """
        self._check_channels(st)
        tags = st.cache.tags.reshape(-1)
        dirty = st.cache.dirty.reshape(-1)
        keys = jnp.where(dirty & (tags >= 0), tags, -1)
        qs1, rec_w = Q.enqueue(st.queues, keys,
                               is_write=jnp.ones(keys.shape, bool))
        depth_now = Q.in_flight(qs1)
        depth_dev = Q.in_flight_per_device(qs1)
        qs2, _ = Q.service_all(qs1)
        store = self._store(st)
        new_storage = st.storage
        if self.storage is None:
            new_storage = store.write_blocks(keys, st.cache.data)
        else:
            self.storage.write_blocks(keys, st.cache.data)
        n_wb = jnp.sum((keys >= 0).astype(jnp.int32))
        nd = self.ssd.n_devices
        dev_writes = device_histogram(keys, nd,
                                      stripe_blocks=self.ssd.stripe_blocks)
        cache = C._replace_data(st.cache, dirty=jnp.zeros_like(st.cache.dirty))
        mt = st.metrics
        metrics = dataclasses.replace(
            mt,
            write_ops=mt.write_ops + n_wb,
            bytes_to_storage=mt.bytes_to_storage + n_wb * self.block_bytes,
            doorbells=mt.doorbells + rec_w.n_doorbells,
            **self._charge_channels(mt, st.queues,
                                    jnp.zeros_like(dev_writes), dev_writes,
                                    depth_now, depth_dev),
        )
        return BamState(cache=cache, queues=qs2, metrics=metrics,
                        storage=new_storage)


@dataclasses.dataclass
class BamKVStore:
    """Key-value abstraction: device-resident open-addressed index over
    storage-resident fixed-width values (the paper's 'key-value store').

    The index (one int32 per capacity slot) is small and lives in device
    memory; the values — the massive structure — live behind a
    :class:`BamArray`.  This is exactly the split used by the framework's
    on-demand embedding feature.
    """

    array: BamArray                 # values: (capacity, value_elems) flattened
    capacity: int
    value_elems: int
    probes: int = 8

    @staticmethod
    def _hash_host(key: int, capacity: int) -> int:
        """The shared hash: Knuth multiply with a uint32 wrap, then mod.

        ``lookup`` computes the identical quantity in uint32 arithmetic
        (:meth:`_hash_traced`); the wrap must happen *before* the modulo on
        both sides or roughly half of all keys (those whose wrapped product
        lands >= 2^31) probe different slots at build vs lookup time.  No
        ``abs`` anywhere: ``abs(INT32_MIN)`` is itself negative in int32.
        """
        return ((int(key) & 0xFFFFFFFF) * 2654435761 & 0xFFFFFFFF) % capacity

    def _hash_traced(self, keys: jax.Array) -> jax.Array:
        """uint32-wrap hash of a key wavefront -> int32 slots in [0, cap)."""
        h = keys.astype(jnp.uint32) * jnp.uint32(2654435761)
        return (h % jnp.uint32(self.capacity)).astype(jnp.int32)

    @staticmethod
    def build(keys, values, *, capacity: int | None = None,
              probes: int = 8, **bam_kw):
        """Host-side bulk build; returns (kv, index_table, BamState)."""
        import numpy as np
        keys = np.asarray(keys, np.int32)
        values = np.asarray(values)
        n, value_elems = values.shape
        capacity = capacity or max(2 * n, 16)
        table = np.full((capacity,), -1, np.int32)     # key per slot
        rows = np.full((capacity,), -1, np.int32)      # value row per slot
        store_vals = np.zeros((capacity, value_elems), values.dtype)
        for i, k in enumerate(keys):
            if k == -1:
                raise ValueError(
                    "key -1 is reserved as the empty-slot sentinel")
            h = BamKVStore._hash_host(k, capacity)
            # Place within lookup's probe window only: a key parked further
            # out would be silently unfindable (lookup unrolls `probes`
            # slots) — fail loudly instead.
            for j in range(min(probes, capacity)):
                s = (h + j) % capacity
                if table[s] == -1 or table[s] == k:
                    table[s] = k
                    rows[s] = i
                    store_vals[s] = values[i]
                    break
            else:
                raise ValueError(
                    f"kv store: key {int(k)} cannot be placed within "
                    f"probes={probes} slots of its home slot; raise "
                    "capacity or probes")
        bam_kw.setdefault("block_elems", value_elems)
        arr, st = BamArray.build(store_vals, **bam_kw)
        kv = BamKVStore(array=arr, capacity=capacity,
                        value_elems=value_elems, probes=probes)
        return kv, jnp.asarray(table), st

    def lookup(self, st: BamState, table: jax.Array, keys: jax.Array
               ) -> Tuple[jax.Array, jax.Array, BamState]:
        """Return (values, found_mask, state') for a wavefront of keys."""
        cap = self.capacity
        h = self._hash_traced(keys)
        slot = jnp.full_like(keys, -1)
        for j in range(self.probes):                   # static unroll, small
            s = (h + j) % cap
            match = (table[s] == keys) & (slot < 0)
            slot = jnp.where(match, s, slot)
        # key -1 would "match" every empty slot (the sentinel); never found.
        found = (slot >= 0) & (keys != -1)
        base = jnp.where(found, slot, 0) * self.value_elems
        # one wavefront read per value element column (value_elems small) —
        # flatten to a single wavefront of element indices instead:
        idx = (base[:, None] + jnp.arange(self.value_elems)[None, :]).reshape(-1)
        vmask = jnp.repeat(found, self.value_elems)
        flat, st = self.array.read(st, idx, vmask)
        vals = flat.reshape(keys.shape[0], self.value_elems)
        return vals, found, st
