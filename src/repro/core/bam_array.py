"""``BamArray<T>`` / ``BamKVStore`` — BaM's high-level abstractions (§III-E).

``BamArray`` is the paper's array whose subscript operator transparently:
coalesces the wavefront's accesses, probes the software cache, issues NVMe
reads for the misses through the high-throughput queues, fills the cache,
and returns elements.  Here the subscript is a *functional* ``read``:

    values, state' = bam.read(state, flat_indices)

with every piece of BaM state (cache, queues, I/O metrics, and — for the
in-graph backend — the storage tier itself) threaded through explicitly.

The life of a wavefront (paper Fig. 3, adapted):

    element idx ──► block key + offset
        │ coalesce (warp coalescer, §III-D)          -> unique lines, leaders
        │ probe cache                                 -> hits / misses
        │   (hit on a prefetched line: promote it, count a prefetch hit)
        │ allocate victims (clock)                    -> slots (or bypass)
        │ gather evicted dirty lines                  -> write-back commands
        │ readahead detect + speculative allocate     -> low-priority fills
        │ enqueue reads+write-backs+readahead,        -> SQ rings (§III-C)
        │   ring doorbells (demand lane drains first)
        │ service (simulated NVMe drain + DMA)        -> fetched lines
        │ fill cache, update tags/dirty/speculative
        ▼ gather elements (hit: cache line, miss: fetched line)

Requests dropped by full rings are still served read-through (and counted),
so a mis-sized queue config degrades accounting, never correctness.

Prefetching is off by default (``PrefetchConfig.enabled``); when on, the
stride detector in :mod:`repro.core.prefetch` extrapolates the wavefront's
block pattern ``window`` lines ahead and brings those lines in through the
readahead lane as evict-first *speculative* residents.  The explicit
:meth:`BamArray.prefetch` API lets applications (BFS frontiers, column
scans) push known-future wavefronts directly.

Multi-tenant sharing (:class:`BamRuntime`): the paper's central claim is
that *one* software cache and *one* queue pool serve many concurrent GPU
applications.  ``BamRuntime`` registers several tenants (each a
``BamArray`` over its own storage) against a single shared
``CacheState``/``QueueState``: each tenant's :class:`TenantCtx` carries
its tenant id (namespacing cache tags and queue commands) and its cache
*way quota* (``isolation="partitioned"`` confines each tenant's clock
sweep to its own ways; ``isolation="shared"`` keeps the free-for-all so
contention is measurable).  Per-tenant :class:`IOMetrics` accumulate next
to a global view, with the invariant that additive tenant counters sum
exactly to the global counters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import cache as C
from repro.core import queues as Q
from repro.core.coalescer import coalesce
from repro.core.metrics import (
    IOMetrics, metrics_accumulate, metrics_delta, metrics_sum,
)
from repro.core.prefetch import PrefetchConfig, readahead_keys
from repro.core.ssd import ArrayOfSSDs, INTEL_OPTANE_P5800X, device_histogram
from repro.core.storage import HBMStorage, SimStorage
from repro.utils import pytree_dataclass, round_up

__all__ = ["BamArray", "BamState", "BamKVStore", "PrefetchConfig",
           "TenantCtx", "TenantSpec", "BamRuntime", "RuntimeState"]


@dataclasses.dataclass(frozen=True)
class TenantCtx:
    """Static multi-tenant context of one :class:`BamArray`.

    ``tenant`` namespaces this array's cache tags and queue commands;
    ``[way_lo, way_hi)`` is its cache way quota (``way_hi=None`` = all
    ways).  The default is the single-tenant identity: tenant 0 with the
    whole cache.
    """

    tenant: int = 0
    way_lo: int = 0
    way_hi: int | None = None


@pytree_dataclass
class BamState:
    """All mutable BaM state, threaded functionally through reads/writes."""

    cache: C.CacheState
    queues: Q.QueueState
    metrics: IOMetrics
    storage: Any  # HBMStorage pytree for the in-graph backend, else None


@dataclasses.dataclass
class BamArray:
    """Static description of one BaM-backed array (not a pytree)."""

    storage: Any                    # SimStorage (host) or None (in-graph)
    shape: tuple
    dtype: Any
    block_elems: int
    ssd: ArrayOfSSDs = dataclasses.field(
        default_factory=lambda: ArrayOfSSDs(INTEL_OPTANE_P5800X, 1))
    prefetch_cfg: PrefetchConfig = dataclasses.field(
        default_factory=PrefetchConfig)
    tenant_ctx: TenantCtx = dataclasses.field(default_factory=TenantCtx)
    # Deferred drain (multi-tenant): when True, ops enqueue commands but do
    # NOT drain the rings; the runtime drains once per round
    # (BamRuntime.drain), so several tenants' commands genuinely coexist
    # and the weighted-fair arbitration orders a real mixed stream.
    defer_drain: bool = False

    def _drain(self, qs: Q.QueueState) -> Q.QueueState:
        """Per-op ring drain, skipped under the runtime's deferred mode."""
        return qs if self.defer_drain else Q.service_all(qs)[0]

    # ---------------------------------------------------------------- init
    @staticmethod
    def build(data, block_elems: int, *,
              num_sets: int, ways: int = 4,
              num_queues: int = 8, queue_depth: int = 1024,
              ssd: Optional[ArrayOfSSDs] = None,
              prefetch: Optional[PrefetchConfig] = None,
              backend: str = "sim") -> Tuple["BamArray", BamState]:
        """Create the array + its initial state from a host/jnp array.

        ``backend='sim'``: data lives on the host, fetched via pure_callback
        (the NVMe DMA stand-in).  ``backend='hbm'``: data is an in-graph cold
        buffer — used by dry-runs so the compiler sees the traffic.

        The SQ pool is partitioned per storage device (``ssd.n_devices``
        equal ring groups); ``num_queues`` is rounded up to the next
        multiple of the device count so every channel gets the same depth.
        """
        import numpy as np
        shape = tuple(data.shape)
        if backend == "sim":
            store = SimStorage.from_array(np.asarray(data), block_elems)
            state_store = None
            dtype = store.dtype
        elif backend == "hbm":
            hs = HBMStorage.from_array(jnp.asarray(data), block_elems)
            store, state_store, dtype = None, hs, hs.dtype
        else:
            raise ValueError(f"unknown backend {backend!r}")
        ssd = ssd or ArrayOfSSDs(INTEL_OPTANE_P5800X, 1)
        num_queues = round_up(num_queues, ssd.n_devices)
        arr = BamArray(
            storage=store, shape=shape, dtype=dtype, block_elems=block_elems,
            ssd=ssd,
            prefetch_cfg=prefetch or PrefetchConfig())
        st = BamState(
            cache=C.make_cache(num_sets, ways, block_elems, dtype),
            queues=Q.make_queues(num_queues, queue_depth,
                                 n_devices=ssd.n_devices,
                                 stripe_blocks=ssd.stripe_blocks),
            metrics=IOMetrics.zeros(ssd.n_devices),
            storage=state_store,
        )
        return arr, st

    # ------------------------------------------------------------- helpers
    @property
    def block_bytes(self) -> int:
        return self.block_elems * jnp.dtype(self.dtype).itemsize

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n

    @property
    def num_blocks(self) -> int:
        return -(-self.size // self.block_elems)

    def with_prefetch(self, cfg: PrefetchConfig) -> "BamArray":
        """Same array, different (static) readahead policy."""
        return dataclasses.replace(self, prefetch_cfg=cfg)

    def _store(self, st: BamState):
        return self.storage if self.storage is not None else st.storage

    def _check_channels(self, st: BamState) -> None:
        """SQ routing and device accounting must share one striping.

        Both are static metadata, so a hand-assembled state that pairs
        mismatched ``make_queues``/``ArrayOfSSDs`` channel configs fails at
        trace time instead of silently charging metrics to the wrong
        device.
        """
        qs = st.queues
        if (qs.n_devices, qs.stripe_blocks) != (self.ssd.n_devices,
                                                self.ssd.stripe_blocks):
            raise ValueError(
                f"queue channels (n_devices={qs.n_devices}, "
                f"stripe_blocks={qs.stripe_blocks}) do not match the SSD "
                f"array (n_devices={self.ssd.n_devices}, "
                f"stripe_blocks={self.ssd.stripe_blocks}); build the state "
                "with BamArray.build or make_queues with the same config")

    def _split(self, idx: jax.Array):
        return (idx // self.block_elems).astype(jnp.int32), \
               (idx % self.block_elems).astype(jnp.int32)

    def _charge_channels(self, mt: IOMetrics, qs: Q.QueueState,
                         dev_reads: jax.Array, dev_writes: jax.Array,
                         depth_now: jax.Array, depth_dev: jax.Array) -> dict:
        """Device-time and per-device counter updates shared by every I/O
        path (read/write/prefetch/flush).

        Each channel drains its own share at its own Little's-law rate
        (concurrency capped by its queue group's depth); the wavefront is
        gated by the slowest channel.  Returns the IOMetrics field updates
        as kwargs so callers splice them into their own counter math.
        """
        group_limit = qs.group_size * qs.depth
        t_read, t_read_dev = self.ssd.service_time_per_device_traced(
            dev_reads, self.block_bytes, queue_depth_limit=group_limit)
        t_write, t_write_dev = self.ssd.service_time_per_device_traced(
            dev_writes, self.block_bytes, write=True,
            queue_depth_limit=group_limit)
        return dict(
            sim_time_s=mt.sim_time_s + t_read + t_write,
            read_time_s=mt.read_time_s + t_read,
            write_time_s=mt.write_time_s + t_write,
            max_queue_depth=jnp.maximum(mt.max_queue_depth,
                                        depth_now.astype(jnp.int32)),
            dev_reads=mt.dev_reads + dev_reads,
            dev_writes=mt.dev_writes + dev_writes,
            dev_bytes=mt.dev_bytes
                + (dev_reads + dev_writes) * self.block_bytes,
            dev_time_s=mt.dev_time_s + t_read_dev + t_write_dev,
            dev_max_depth=jnp.maximum(mt.dev_max_depth,
                                      depth_dev.astype(jnp.int32)),
        )

    # ---------------------------------------------------------------- read
    def read(self, st: BamState, idx: jax.Array,
             valid: jax.Array | None = None) -> Tuple[jax.Array, BamState]:
        """Gather ``self.flat[idx]`` for a wavefront of element indices."""
        self._check_channels(st)
        n = idx.shape[0]
        if valid is None:
            valid = (idx >= 0) & (idx < self.size)
        blk, off = self._split(jnp.where(valid, idx, 0))
        blk = jnp.where(valid, blk, -1)

        # 1) warp-coalesce the wavefront to unique cache lines.
        co = coalesce(blk, valid)
        ukeys = co.unique_keys                      # (n,) padded with -1
        uvalid = ukeys >= 0

        # 2) probe the software cache.  A demand hit on a prefetched line is
        #    a prefetch hit: promote the line to an ordinary resident.
        ctx = self.tenant_ctx
        pr = C.probe(st.cache, ukeys, uvalid, tenant=ctx.tenant)
        n_hit = jnp.sum(pr.hit.astype(jnp.int32))
        n_pref_hit = jnp.sum(pr.speculative.astype(jnp.int32))
        cache1 = C.count_hits(st.cache, n_hit)
        cache1 = C.promote(cache1, jnp.where(pr.speculative, pr.slot, -1))
        miss = uvalid & ~pr.hit

        # 3) allocate victims for the misses (hits protected this round).
        cache2, alloc = C.allocate(cache1, ukeys, miss,
                                   protect_slots=pr.slot,
                                   tenant=ctx.tenant, way_lo=ctx.way_lo,
                                   way_hi=ctx.way_hi)

        # 4) evicted dirty lines -> write-back commands (gather before fill).
        ev_rows = jnp.where(alloc.ok, alloc.slot, 0)
        ev_lines = cache2.data[ev_rows]
        wb = alloc.ok & alloc.evicted_dirty & (alloc.evicted_key >= 0)
        wb_keys = jnp.where(wb, alloc.evicted_key, -1)

        # 4b) readahead: extrapolate the wavefront's stride pattern and
        #     speculatively allocate the predicted lines.  Demand slots (this
        #     round's hits and grants) are protected, so readahead can only
        #     claim invalid or stale lines — it never displaces the wavefront.
        cfg = self.prefetch_cfg
        ra_on = cfg.enabled and cfg.window > 0
        if ra_on:
            ra_cand = readahead_keys(
                ukeys, uvalid, window=cfg.window, num_blocks=self.num_blocks,
                min_support=cfg.min_support, max_stride=cfg.max_stride,
                raw_keys=blk, raw_valid=valid)
            ra_pr = C.probe(cache2, ra_cand, ra_cand >= 0,
                            tenant=ctx.tenant)
            ra_want = (ra_cand >= 0) & ~ra_pr.hit
            # Never speculatively re-fetch a line this wavefront just
            # evicted: on the sim backend the fetch (pure_callback) is not
            # ordered against the dirty write-back (io_callback), so it
            # could observe the pre-write-back bytes — and re-fetching a
            # just-evicted line is pure thrash regardless of backend.
            evk = jnp.where(alloc.ok & (alloc.evicted_key >= 0),
                            alloc.evicted_key, -2)
            ra_want = ra_want & ~jnp.any(
                ra_cand[:, None] == evk[None, :], axis=1)
            cache2, ra_alloc = C.allocate(
                cache2, ra_cand, ra_want,
                protect_slots=jnp.concatenate([pr.slot, alloc.slot]),
                speculative=True,
                tenant=ctx.tenant, way_lo=ctx.way_lo, way_hi=ctx.way_hi)
            ra_keys = jnp.where(ra_alloc.ok, ra_cand, -1)
            ra_rows = jnp.where(ra_alloc.ok, ra_alloc.slot, 0)
            ra_ev_lines = cache2.data[ra_rows]
            ra_wb = ra_alloc.ok & ra_alloc.evicted_dirty \
                & (ra_alloc.evicted_key >= 0)
            ra_wb_keys = jnp.where(ra_wb, ra_alloc.evicted_key, -1)

        # 5) submit reads + write-backs to the SQ rings; ring doorbells.
        #    Readahead goes last and in the low-priority lane: it is the
        #    first thing dropped under back-pressure and the last retired.
        qs1, rec_r = Q.enqueue(st.queues, jnp.where(miss, ukeys, -1),
                               dst=alloc.slot, tenant=ctx.tenant)
        qs2, rec_w = Q.enqueue(qs1, wb_keys,
                               is_write=jnp.ones_like(wb), tenant=ctx.tenant)
        n_doorbells = rec_r.n_doorbells + rec_w.n_doorbells
        n_dropped = rec_r.n_dropped + rec_w.n_dropped
        if ra_on:
            qs2, rec_rw = Q.enqueue(qs2, ra_wb_keys,
                                    is_write=jnp.ones_like(ra_wb),
                                    tenant=ctx.tenant)
            qs2, rec_ra = Q.enqueue(qs2, ra_keys, dst=ra_alloc.slot,
                                    prio=Q.PRIO_READAHEAD, tenant=ctx.tenant)
            n_doorbells = n_doorbells + rec_rw.n_doorbells + rec_ra.n_doorbells
            n_dropped = n_dropped + rec_rw.n_dropped + rec_ra.n_dropped
        depth_now = Q.in_flight(qs2)
        depth_dev = Q.in_flight_per_device(qs2)
        qs3 = self._drain(qs2)

        # 6) the DMA: fetch missed lines / write back dirty lines.  Fetch
        #    keys are disjoint from this round's evictions (demand misses
        #    by the probe, readahead by the explicit exclusion above), so
        #    the unordered fetch callback can never race a write-back of
        #    the same line.
        store = self._store(st)
        lines_u = store.fetch_blocks(jnp.where(miss, ukeys, -1))
        new_storage = st.storage
        if self.storage is None:                    # in-graph backend
            new_storage = store.write_blocks(wb_keys, ev_lines)
            if ra_on:
                new_storage = new_storage.write_blocks(ra_wb_keys, ra_ev_lines)
                lines_ra = new_storage.fetch_blocks(ra_keys)
        else:
            self.storage.write_blocks(wb_keys, ev_lines)
            if ra_on:
                self.storage.write_blocks(ra_wb_keys, ra_ev_lines)
                lines_ra = self.storage.fetch_blocks(ra_keys)

        # 7) completion: fill granted slots with fetched lines.
        cache3 = C.fill(cache2, alloc.slot, alloc.ok, lines_u)
        if ra_on:
            cache3 = C.fill(cache3, ra_alloc.slot, ra_alloc.ok, lines_ra)

        # 8) gather elements back to every requester (leader broadcast).
        u = co.inverse_idx                          # (n,) request -> unique row
        hit_u = pr.hit[u]
        slot_u = jnp.where(pr.slot[u] >= 0, pr.slot[u], 0)
        from_cache = cache3.data[slot_u, off]
        from_fetch = lines_u[u, off]
        vals = jnp.where(hit_u, from_cache, from_fetch)
        vals = jnp.where(valid, vals, 0).astype(self.dtype)

        # 9) metrics.  Readahead reads share the device drain with demand
        #    (one busy-time accumulation) but are accounted separately:
        #    ``misses`` stays demand-only, ``prefetch_issued`` carries the
        #    speculative lines, and both contribute to bytes moved.  Device
        #    time is per channel: each device drains its own share, the
        #    slowest one gates the wavefront (max, not average).
        n_valid = jnp.sum(valid.astype(jnp.int32))
        n_miss = jnp.sum(miss.astype(jnp.int32))
        n_wb = jnp.sum(wb.astype(jnp.int32))
        n_ra = jnp.zeros((), jnp.int32)
        nd = self.ssd.n_devices
        dev_reads = device_histogram(ukeys, nd, miss, self.ssd.stripe_blocks)
        dev_writes = device_histogram(wb_keys, nd,
                                      stripe_blocks=self.ssd.stripe_blocks)
        if ra_on:
            n_ra = jnp.sum(ra_alloc.ok.astype(jnp.int32))
            n_wb = n_wb + jnp.sum(ra_wb.astype(jnp.int32))
            dev_reads = dev_reads + device_histogram(
                ra_keys, nd, stripe_blocks=self.ssd.stripe_blocks)
            dev_writes = dev_writes + device_histogram(
                ra_wb_keys, nd, stripe_blocks=self.ssd.stripe_blocks)
        itemsize = jnp.dtype(self.dtype).itemsize
        mt = st.metrics
        metrics = IOMetrics(
            requests=mt.requests + n_valid,
            bytes_requested=mt.bytes_requested + n_valid * itemsize,
            hits=mt.hits + n_hit,
            misses=mt.misses + n_miss,
            bytes_from_storage=mt.bytes_from_storage
                + (n_miss + n_ra) * self.block_bytes,
            write_ops=mt.write_ops + n_wb,
            bytes_to_storage=mt.bytes_to_storage + n_wb * self.block_bytes,
            doorbells=mt.doorbells + n_doorbells,
            dropped=mt.dropped + n_dropped,
            prefetch_issued=mt.prefetch_issued + n_ra,
            prefetch_hits=mt.prefetch_hits + n_pref_hit,
            **self._charge_channels(mt, st.queues, dev_reads, dev_writes,
                                    depth_now, depth_dev),
        )
        return vals, BamState(cache=cache3, queues=qs3, metrics=metrics,
                              storage=new_storage)

    # ------------------------------------------------------------- prefetch
    def prefetch(self, st: BamState, idx: jax.Array,
                 valid: jax.Array | None = None) -> BamState:
        """Hint the array at a future wavefront: warm the cache, no values.

        The lines covering ``idx`` are brought in through the low-priority
        readahead lane as *speculative* residents — inserted without pin, so
        a hint that never materialises is the first thing the clock hand
        reclaims.  Already-resident lines and invalid/out-of-range lanes
        cost nothing.  Works regardless of :class:`PrefetchConfig.enabled`
        (that flag only gates the automatic stride readahead in
        :meth:`read`).  Demand counters (requests/hits/misses) are untouched:
        a prefetch is not compute traffic.
        """
        self._check_channels(st)
        if valid is None:
            valid = (idx >= 0) & (idx < self.size)
        blk, _ = self._split(jnp.where(valid, idx, 0))
        blk = jnp.where(valid, blk, -1)

        co = coalesce(blk, valid)
        ukeys = co.unique_keys
        uvalid = ukeys >= 0
        ctx = self.tenant_ctx
        pr = C.probe(st.cache, ukeys, uvalid, tenant=ctx.tenant)
        want = uvalid & ~pr.hit
        cache1, alloc = C.allocate(st.cache, ukeys, want,
                                   protect_slots=pr.slot, speculative=True,
                                   tenant=ctx.tenant, way_lo=ctx.way_lo,
                                   way_hi=ctx.way_hi)
        ev_rows = jnp.where(alloc.ok, alloc.slot, 0)
        ev_lines = cache1.data[ev_rows]
        wb = alloc.ok & alloc.evicted_dirty & (alloc.evicted_key >= 0)
        wb_keys = jnp.where(wb, alloc.evicted_key, -1)
        keys = jnp.where(alloc.ok, ukeys, -1)

        qs1, rec_w = Q.enqueue(st.queues, wb_keys, is_write=jnp.ones_like(wb),
                               tenant=ctx.tenant)
        qs2, rec_r = Q.enqueue(qs1, keys, dst=alloc.slot,
                               prio=Q.PRIO_READAHEAD, tenant=ctx.tenant)
        depth_now = Q.in_flight(qs2)
        depth_dev = Q.in_flight_per_device(qs2)
        qs3 = self._drain(qs2)

        store = self._store(st)
        new_storage = st.storage
        if self.storage is None:                    # in-graph backend
            new_storage = store.write_blocks(wb_keys, ev_lines)
            lines = new_storage.fetch_blocks(keys)
        else:
            self.storage.write_blocks(wb_keys, ev_lines)
            lines = self.storage.fetch_blocks(keys)
        cache2 = C.fill(cache1, alloc.slot, alloc.ok, lines)

        n_ra = jnp.sum(alloc.ok.astype(jnp.int32))
        n_wb = jnp.sum(wb.astype(jnp.int32))
        nd = self.ssd.n_devices
        dev_reads = device_histogram(keys, nd,
                                     stripe_blocks=self.ssd.stripe_blocks)
        dev_writes = device_histogram(wb_keys, nd,
                                      stripe_blocks=self.ssd.stripe_blocks)
        mt = st.metrics
        metrics = dataclasses.replace(
            mt,
            bytes_from_storage=mt.bytes_from_storage + n_ra * self.block_bytes,
            write_ops=mt.write_ops + n_wb,
            bytes_to_storage=mt.bytes_to_storage + n_wb * self.block_bytes,
            doorbells=mt.doorbells + rec_r.n_doorbells + rec_w.n_doorbells,
            dropped=mt.dropped + rec_r.n_dropped + rec_w.n_dropped,
            prefetch_issued=mt.prefetch_issued + n_ra,
            **self._charge_channels(mt, st.queues, dev_reads, dev_writes,
                                    depth_now, depth_dev),
        )
        return BamState(cache=cache2, queues=qs3, metrics=metrics,
                        storage=new_storage)

    # --------------------------------------------------------------- write
    def write(self, st: BamState, idx: jax.Array, values: jax.Array,
              valid: jax.Array | None = None) -> BamState:
        """Element-level writes: read-modify-write with write-allocate.

        Duplicate element indices within one wavefront are last-writer-wins
        with unspecified order (as on the GPU).
        """
        self._check_channels(st)
        n = idx.shape[0]
        if valid is None:
            valid = (idx >= 0) & (idx < self.size)
        blk, off = self._split(jnp.where(valid, idx, 0))
        blk = jnp.where(valid, blk, -1)

        co = coalesce(blk, valid)
        ukeys = co.unique_keys
        uvalid = ukeys >= 0
        ctx = self.tenant_ctx
        pr = C.probe(st.cache, ukeys, uvalid, tenant=ctx.tenant)
        n_hit = jnp.sum(pr.hit.astype(jnp.int32))
        n_pref_hit = jnp.sum(pr.speculative.astype(jnp.int32))
        cache1 = C.count_hits(st.cache, n_hit)
        cache1 = C.promote(cache1, jnp.where(pr.speculative, pr.slot, -1))
        miss = uvalid & ~pr.hit

        cache2, alloc = C.allocate(cache1, ukeys, miss, protect_slots=pr.slot,
                                   tenant=ctx.tenant, way_lo=ctx.way_lo,
                                   way_hi=ctx.way_hi)
        ev_rows = jnp.where(alloc.ok, alloc.slot, 0)
        ev_lines = cache2.data[ev_rows]
        wb = alloc.ok & alloc.evicted_dirty & (alloc.evicted_key >= 0)
        wb_keys = jnp.where(wb, alloc.evicted_key, -1)
        # Bypassed lines (no slot granted) will be written through below;
        # their commands ride the rings like every other write.
        byp = miss & ~alloc.ok
        bt_keys = jnp.where(byp, ukeys, -1)

        qs1, rec_r = Q.enqueue(st.queues, jnp.where(miss, ukeys, -1),
                               dst=alloc.slot, tenant=ctx.tenant)
        qs2, rec_w = Q.enqueue(qs1, wb_keys, is_write=jnp.ones_like(wb),
                               tenant=ctx.tenant)
        qs2, rec_bt = Q.enqueue(qs2, bt_keys, is_write=jnp.ones_like(byp),
                                tenant=ctx.tenant)
        depth_now = Q.in_flight(qs2)
        depth_dev = Q.in_flight_per_device(qs2)
        qs3 = self._drain(qs2)

        store = self._store(st)
        lines_u = store.fetch_blocks(jnp.where(miss, ukeys, -1))  # write-allocate
        new_storage = st.storage
        if self.storage is None:
            new_storage = store.write_blocks(wb_keys, ev_lines)
        else:
            self.storage.write_blocks(wb_keys, ev_lines)
        cache3 = C.fill(cache2, alloc.slot, alloc.ok, lines_u)

        # Scatter the new element values into their lines *in the cache*.
        u = co.inverse_idx
        slot_r = jnp.where(pr.hit[u], pr.slot[u], alloc.slot[u])  # (n,)
        in_cache = slot_r >= 0
        rows = jnp.where(valid & in_cache, slot_r, cache3.num_lines)
        cols = jnp.where(valid & in_cache, off, 0)
        data = cache3.data.at[rows, cols].set(
            values.astype(self.dtype), mode="drop")
        cache4 = C._replace_data(cache3, data=data)
        touched_slots = jnp.where(valid & in_cache, slot_r, -1)
        cache5 = C.mark_dirty(cache4, touched_slots)

        # Bypassed lines: write-through directly (enqueued above).
        byp_any = byp[u] & valid
        byp_rows = jnp.where(byp_any, u, lines_u.shape[0])
        byp_lines = lines_u.at[byp_rows, jnp.where(byp_any, off, 0)].set(
            values.astype(self.dtype), mode="drop")
        if self.storage is None:
            new_storage = new_storage.write_blocks(bt_keys, byp_lines)
        else:
            self.storage.write_blocks(bt_keys, byp_lines)

        n_valid = jnp.sum(valid.astype(jnp.int32))
        n_miss = jnp.sum(miss.astype(jnp.int32))
        n_wb = jnp.sum(wb.astype(jnp.int32)) + jnp.sum(byp.astype(jnp.int32))
        nd = self.ssd.n_devices
        dev_reads = device_histogram(ukeys, nd, miss, self.ssd.stripe_blocks)
        dev_writes = device_histogram(
            wb_keys, nd, stripe_blocks=self.ssd.stripe_blocks) \
            + device_histogram(bt_keys, nd,
                               stripe_blocks=self.ssd.stripe_blocks)
        itemsize = jnp.dtype(self.dtype).itemsize
        mt = st.metrics
        metrics = IOMetrics(
            requests=mt.requests + n_valid,
            bytes_requested=mt.bytes_requested + n_valid * itemsize,
            hits=mt.hits + n_hit,
            misses=mt.misses + n_miss,
            bytes_from_storage=mt.bytes_from_storage + n_miss * self.block_bytes,
            write_ops=mt.write_ops + n_wb,
            bytes_to_storage=mt.bytes_to_storage + n_wb * self.block_bytes,
            doorbells=mt.doorbells + rec_r.n_doorbells + rec_w.n_doorbells
                + rec_bt.n_doorbells,
            dropped=mt.dropped + rec_r.n_dropped + rec_w.n_dropped
                + rec_bt.n_dropped,
            prefetch_issued=mt.prefetch_issued,
            prefetch_hits=mt.prefetch_hits + n_pref_hit,
            **self._charge_channels(mt, st.queues, dev_reads, dev_writes,
                                    depth_now, depth_dev),
        )
        return BamState(cache=cache5, queues=qs3, metrics=metrics,
                        storage=new_storage)

    def flush(self, st: BamState) -> BamState:
        """Write back every dirty resident line (shutdown / barrier path).

        Write-backs go through the SQ rings like every other I/O: enqueue,
        doorbell, drain — so ``doorbells``/``max_queue_depth`` and the
        per-device counters see shutdown traffic exactly as they see
        ``read``/``write`` write-backs.  Lines the rings cannot hold this
        round are still persisted (the drop degrades accounting, never
        correctness — same contract as the read path's read-through).

        In a shared cache only *this tenant's* dirty lines are flushed (a
        foreign line's write-back belongs to its owner's storage tier);
        other tenants' dirty bits are left untouched.
        """
        self._check_channels(st)
        ctx = self.tenant_ctx
        tags = st.cache.tags.reshape(-1)
        dirty = st.cache.dirty.reshape(-1)
        mine = st.cache.owner.reshape(-1) == jnp.int32(ctx.tenant)
        keys = jnp.where(dirty & mine & (tags >= 0), tags, -1)
        qs1, rec_w = Q.enqueue(st.queues, keys,
                               is_write=jnp.ones(keys.shape, bool),
                               tenant=ctx.tenant)
        depth_now = Q.in_flight(qs1)
        depth_dev = Q.in_flight_per_device(qs1)
        qs2 = self._drain(qs1)
        store = self._store(st)
        new_storage = st.storage
        if self.storage is None:
            new_storage = store.write_blocks(keys, st.cache.data)
        else:
            self.storage.write_blocks(keys, st.cache.data)
        n_wb = jnp.sum((keys >= 0).astype(jnp.int32))
        nd = self.ssd.n_devices
        dev_writes = device_histogram(keys, nd,
                                      stripe_blocks=self.ssd.stripe_blocks)
        flushed = (keys >= 0).reshape(st.cache.dirty.shape)
        cache = C._replace_data(st.cache, dirty=st.cache.dirty & ~flushed)
        mt = st.metrics
        metrics = dataclasses.replace(
            mt,
            write_ops=mt.write_ops + n_wb,
            bytes_to_storage=mt.bytes_to_storage + n_wb * self.block_bytes,
            doorbells=mt.doorbells + rec_w.n_doorbells,
            dropped=mt.dropped + rec_w.n_dropped,
            **self._charge_channels(mt, st.queues,
                                    jnp.zeros_like(dev_writes), dev_writes,
                                    depth_now, depth_dev),
        )
        return BamState(cache=cache, queues=qs2, metrics=metrics,
                        storage=new_storage)


@dataclasses.dataclass
class BamKVStore:
    """Key-value abstraction: device-resident open-addressed index over
    storage-resident fixed-width values (the paper's 'key-value store').

    The index (one int32 per capacity slot) is small and lives in device
    memory; the values — the massive structure — live behind a
    :class:`BamArray`.  This is exactly the split used by the framework's
    on-demand embedding feature.
    """

    array: BamArray                 # values: (capacity, value_elems) flattened
    capacity: int
    value_elems: int
    probes: int = 8

    @staticmethod
    def _hash_host(key: int, capacity: int) -> int:
        """The shared hash: Knuth multiply with a uint32 wrap, then mod.

        ``lookup`` computes the identical quantity in uint32 arithmetic
        (:meth:`_hash_traced`); the wrap must happen *before* the modulo on
        both sides or roughly half of all keys (those whose wrapped product
        lands >= 2^31) probe different slots at build vs lookup time.  No
        ``abs`` anywhere: ``abs(INT32_MIN)`` is itself negative in int32.
        """
        return ((int(key) & 0xFFFFFFFF) * 2654435761 & 0xFFFFFFFF) % capacity

    def _hash_traced(self, keys: jax.Array) -> jax.Array:
        """uint32-wrap hash of a key wavefront -> int32 slots in [0, cap)."""
        h = keys.astype(jnp.uint32) * jnp.uint32(2654435761)
        return (h % jnp.uint32(self.capacity)).astype(jnp.int32)

    @staticmethod
    def build_table(keys, values, *, capacity: int | None = None,
                    probes: int = 8):
        """Host-side open-addressing placement shared by :meth:`build` and
        the multi-tenant runtime: returns ``(table, store_vals, capacity)``
        where ``store_vals[(hash(k)+j) % capacity]`` holds key ``k``'s
        value row."""
        import numpy as np
        keys = np.asarray(keys, np.int32)
        values = np.asarray(values)
        n, value_elems = values.shape
        capacity = capacity or max(2 * n, 16)
        table = np.full((capacity,), -1, np.int32)     # key per slot
        store_vals = np.zeros((capacity, value_elems), values.dtype)
        for i, k in enumerate(keys):
            if k == -1:
                raise ValueError(
                    "key -1 is reserved as the empty-slot sentinel")
            h = BamKVStore._hash_host(k, capacity)
            # Place within lookup's probe window only: a key parked further
            # out would be silently unfindable (lookup unrolls `probes`
            # slots) — fail loudly instead.
            for j in range(min(probes, capacity)):
                s = (h + j) % capacity
                if table[s] == -1 or table[s] == k:
                    table[s] = k
                    store_vals[s] = values[i]
                    break
            else:
                raise ValueError(
                    f"kv store: key {int(k)} cannot be placed within "
                    f"probes={probes} slots of its home slot; raise "
                    "capacity or probes")
        return table, store_vals, capacity

    @staticmethod
    def build(keys, values, *, capacity: int | None = None,
              probes: int = 8, **bam_kw):
        """Host-side bulk build; returns (kv, index_table, BamState)."""
        import numpy as np
        values = np.asarray(values)
        _, value_elems = values.shape
        table, store_vals, capacity = BamKVStore.build_table(
            keys, values, capacity=capacity, probes=probes)
        bam_kw.setdefault("block_elems", value_elems)
        arr, st = BamArray.build(store_vals, **bam_kw)
        kv = BamKVStore(array=arr, capacity=capacity,
                        value_elems=value_elems, probes=probes)
        return kv, jnp.asarray(table), st

    def lookup(self, st: BamState, table: jax.Array, keys: jax.Array
               ) -> Tuple[jax.Array, jax.Array, BamState]:
        """Return (values, found_mask, state') for a wavefront of keys."""
        cap = self.capacity
        h = self._hash_traced(keys)
        slot = jnp.full_like(keys, -1)
        for j in range(self.probes):                   # static unroll, small
            s = (h + j) % cap
            match = (table[s] == keys) & (slot < 0)
            slot = jnp.where(match, s, slot)
        # key -1 would "match" every empty slot (the sentinel); never found.
        found = (slot >= 0) & (keys != -1)
        base = jnp.where(found, slot, 0) * self.value_elems
        # one wavefront read per value element column (value_elems small) —
        # flatten to a single wavefront of element indices instead:
        idx = (base[:, None] + jnp.arange(self.value_elems)[None, :]).reshape(-1)
        vmask = jnp.repeat(found, self.value_elems)
        flat, st = self.array.read(st, idx, vmask)
        vals = flat.reshape(keys.shape[0], self.value_elems)
        return vals, found, st


# ===================================================================== runtime
@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's registration against a shared :class:`BamRuntime`.

    ``ways`` is the cache way quota under ``isolation="partitioned"``
    (``None`` = equal split of the leftover ways); ``weight`` is the
    queue-arbitration service weight (see :func:`repro.core.queues
    .service_all`).
    """

    name: str
    data: Any                      # host / jnp array backing this tenant
    block_elems: int
    ways: int | None = None
    weight: float = 1.0
    prefetch: Optional[PrefetchConfig] = None


@pytree_dataclass
class RuntimeState:
    """All mutable state of a shared multi-tenant runtime.

    One cache + one queue pool serve every tenant; metrics are kept per
    tenant *and* globally.  Invariant (checked by
    :meth:`BamRuntime.assert_metrics_consistent` and the multi-tenant
    tests): every additive counter of the global ``metrics`` equals the
    sum of the tenants' counters.
    """

    cache: C.CacheState
    queues: Q.QueueState
    metrics: IOMetrics             # global accumulator
    tenant_metrics: tuple          # per-tenant IOMetrics, indexed by tid
    storages: tuple                # per-tenant in-graph storage (None for sim)


@dataclasses.dataclass
class BamRuntime:
    """The shared multi-tenant BaM runtime (paper §I: "multiple processes
    can share" the cache and queues).

    Several tenants — each a :class:`BamArray` over its own storage tier —
    run against *one* ``CacheState`` and *one* ``QueueState``:

    * cache isolation is **way-partitioning**: under
      ``isolation="partitioned"`` each tenant's clock sweep is confined to
      its contiguous way quota, so a streaming scan tenant cannot evict a
      cache-friendly neighbour's lines; ``isolation="shared"`` keeps
      today's free-for-all (tenants evict each other's clean lines) so
      the thrash is measurable;
    * queue sharing is **weighted-fair arbitration**: commands carry their
      tenant id and the simulated controller drains tenants in proportion
      to their ``TenantSpec.weight`` within each priority class, with
      back-pressure drops accounted per tenant;
    * metrics are **per tenant + global**, additive counters summing
      exactly.

    All tenants share the cache line geometry (``block_elems``) and the
    cache data dtype: lines are stored in ``cache_dtype`` and cast back to
    each tenant's dtype on read (exact for float32 tenants and for integer
    tenants whose values fit float32's 2**24 integer range).
    """

    tenants: Dict[str, BamArray]
    tenant_ids: Dict[str, int]
    isolation: str
    ways: int
    drain_mode: str = "per_op"
    _jit_reads: Dict[str, Any] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    # ---------------------------------------------------------------- build
    @staticmethod
    def build(specs: Sequence[TenantSpec], *,
              num_sets: int, ways: int = 8,
              num_queues: int = 8, queue_depth: int = 1024,
              ssd: Optional[ArrayOfSSDs] = None,
              isolation: str = "partitioned",
              drain: str = "per_op",
              backend: str = "sim",
              cache_dtype=jnp.float32,
              ) -> Tuple["BamRuntime", RuntimeState]:
        """``drain="per_op"`` (default) drains the rings inside every
        tenant op, exactly like a standalone ``BamArray``.
        ``drain="deferred"`` leaves commands pending so several tenants'
        wavefronts coexist in the shared rings; the caller then calls
        :meth:`drain` once per round and the weighted-fair arbitration
        orders the genuinely mixed completion stream.  Values are
        identical either way (fetches bypass the simulated controller);
        only when a round's commands overflow the rings does deferred
        mode drop more — accounting degrades, never correctness."""
        import numpy as np
        if isolation not in ("partitioned", "shared"):
            raise ValueError(
                f"isolation must be 'partitioned' or 'shared', "
                f"got {isolation!r}")
        if drain not in ("per_op", "deferred"):
            raise ValueError(
                f"drain must be 'per_op' or 'deferred', got {drain!r}")
        if not specs:
            raise ValueError("need at least one TenantSpec")
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {names}")
        block_elems = specs[0].block_elems
        for s in specs:
            if s.block_elems != block_elems:
                raise ValueError(
                    "all tenants must share the cache line geometry: "
                    f"{s.name} wants block_elems={s.block_elems}, "
                    f"{specs[0].name} has {block_elems}")
        nt = len(specs)

        # Way quotas: explicit quotas are honoured, the rest equal-split.
        if isolation == "partitioned":
            fixed = sum(s.ways for s in specs if s.ways is not None)
            free = [s for s in specs if s.ways is None]
            rest = ways - fixed
            if rest < len(free) or (not free and fixed != ways):
                raise ValueError(
                    f"way quotas don't fit: ways={ways}, explicit={fixed}, "
                    f"{len(free)} tenants left to split the remainder")
            share = {id(s): rest // len(free) for s in free} if free else {}
            for s in free[:rest % len(free) if free else 0]:
                share[id(s)] += 1
            lo = 0
            windows = []
            for s in specs:
                q = s.ways if s.ways is not None else share[id(s)]
                if q < 1:
                    raise ValueError(f"tenant {s.name} got a zero way quota")
                windows.append((lo, lo + q))
                lo += q
        else:
            windows = [(0, ways)] * nt

        ssd = ssd or ArrayOfSSDs(INTEL_OPTANE_P5800X, 1)
        num_queues = round_up(num_queues, ssd.n_devices)
        weights = tuple(float(s.weight) for s in specs)

        tenants: Dict[str, BamArray] = {}
        tenant_ids: Dict[str, int] = {}
        storages = []
        for tid, s in enumerate(specs):
            lo, hi = windows[tid]
            if backend == "sim":
                store = SimStorage.from_array(np.asarray(s.data), block_elems)
                state_store, dtype = None, store.dtype
            elif backend == "hbm":
                hs = HBMStorage.from_array(jnp.asarray(s.data), block_elems)
                store, state_store, dtype = None, hs, hs.dtype
            else:
                raise ValueError(f"unknown backend {backend!r}")
            # Integer tenants round-trip through the shared cache's float
            # dtype: refuse values outside its exact-integer range (e.g.
            # 2^24 for float32) instead of silently corrupting them.
            if (np.issubdtype(np.dtype(dtype), np.integer)
                    and jnp.issubdtype(cache_dtype, jnp.floating)):
                exact = 1 << (jnp.finfo(cache_dtype).nmant + 1)
                host = np.asarray(s.data)
                peak = int(np.abs(host).max()) if host.size else 0
                if peak > exact:
                    raise ValueError(
                        f"tenant {s.name!r} holds integer values up to "
                        f"{peak}, beyond the exact-integer range "
                        f"(+/-{exact}) of the shared cache dtype "
                        f"{jnp.dtype(cache_dtype).name}; pass a wider "
                        "cache_dtype to BamRuntime.build")
            tenants[s.name] = BamArray(
                storage=store, shape=tuple(np.shape(s.data)), dtype=dtype,
                block_elems=block_elems, ssd=ssd,
                prefetch_cfg=s.prefetch or PrefetchConfig(),
                tenant_ctx=TenantCtx(tenant=tid, way_lo=lo, way_hi=hi),
                defer_drain=(drain == "deferred"))
            tenant_ids[s.name] = tid
            storages.append(state_store)

        rst = RuntimeState(
            cache=C.make_cache(num_sets, ways, block_elems, cache_dtype),
            queues=Q.make_queues(num_queues, queue_depth,
                                 n_devices=ssd.n_devices,
                                 stripe_blocks=ssd.stripe_blocks,
                                 n_tenants=nt, tenant_weights=weights),
            metrics=IOMetrics.zeros(ssd.n_devices),
            tenant_metrics=tuple(IOMetrics.zeros(ssd.n_devices)
                                 for _ in specs),
            storages=tuple(storages),
        )
        return BamRuntime(tenants=tenants, tenant_ids=tenant_ids,
                          isolation=isolation, ways=ways,
                          drain_mode=drain), rst

    # ------------------------------------------------------------- plumbing
    def array(self, name: str) -> BamArray:
        """The tenant's :class:`BamArray` (its ``TenantCtx`` rides along) —
        hand it to scenario code (``BamGraph``, ``BamKVStore.lookup``)
        together with a :meth:`tenant_view`."""
        return self.tenants[name]

    def tenant_view(self, rst: RuntimeState, name: str) -> BamState:
        """Project the shared state into the per-tenant :class:`BamState`
        that ``BamArray.read``/``write``/... consume."""
        tid = self.tenant_ids[name]
        return BamState(cache=rst.cache, queues=rst.queues,
                        metrics=rst.tenant_metrics[tid],
                        storage=rst.storages[tid])

    def absorb(self, rst: RuntimeState, name: str,
               st: BamState) -> RuntimeState:
        """Fold a tenant op's updated :class:`BamState` back into the
        shared runtime state: cache/queues replace (they are shared), the
        tenant's metrics delta also accumulates into the global view."""
        tid = self.tenant_ids[name]
        delta = metrics_delta(st.metrics, rst.tenant_metrics[tid])
        tm = list(rst.tenant_metrics)
        tm[tid] = st.metrics
        stores = list(rst.storages)
        stores[tid] = st.storage
        return RuntimeState(
            cache=st.cache, queues=st.queues,
            metrics=metrics_accumulate(rst.metrics, delta),
            tenant_metrics=tuple(tm), storages=tuple(stores))

    # ------------------------------------------------------------------ ops
    def read(self, rst: RuntimeState, name: str, idx: jax.Array,
             valid: jax.Array | None = None
             ) -> Tuple[jax.Array, RuntimeState]:
        vals, st = self.tenants[name].read(self.tenant_view(rst, name),
                                           idx, valid)
        return vals, self.absorb(rst, name, st)

    def read_jit(self, name: str):
        """A cached ``jax.jit`` of ``lambda rst, idx: self.read(rst, name,
        idx)`` — one compilation per tenant however often callers grab it
        (streaming drivers call this every wavefront)."""
        fn = self._jit_reads.get(name)
        if fn is None:
            fn = jax.jit(lambda rst, idx: self.read(rst, name, idx))
            self._jit_reads[name] = fn
        return fn

    def write(self, rst: RuntimeState, name: str, idx: jax.Array,
              values: jax.Array, valid: jax.Array | None = None
              ) -> RuntimeState:
        st = self.tenants[name].write(self.tenant_view(rst, name),
                                      idx, values, valid)
        return self.absorb(rst, name, st)

    def prefetch(self, rst: RuntimeState, name: str, idx: jax.Array,
                 valid: jax.Array | None = None) -> RuntimeState:
        st = self.tenants[name].prefetch(self.tenant_view(rst, name),
                                         idx, valid)
        return self.absorb(rst, name, st)

    def flush(self, rst: RuntimeState,
              name: str | None = None) -> RuntimeState:
        """Flush one tenant's dirty lines, or every tenant's (name=None)."""
        names = [name] if name is not None else list(self.tenants)
        for n in names:
            st = self.tenants[n].flush(self.tenant_view(rst, n))
            rst = self.absorb(rst, n, st)
        return rst

    def drain(self, rst: RuntimeState
              ) -> Tuple[RuntimeState, Q.Completions]:
        """Drain the shared rings once (the ``drain="deferred"`` round
        barrier).  The returned :class:`~repro.core.queues.Completions`
        stream is priority-major and weighted-fair across tenants — the
        observable arbitration order.  A no-op on already-empty rings
        (per-op mode), so callers may drain unconditionally."""
        qs, comps = Q.service_all(rst.queues)
        return RuntimeState(cache=rst.cache, queues=qs,
                            metrics=rst.metrics,
                            tenant_metrics=rst.tenant_metrics,
                            storages=rst.storages), comps

    # -------------------------------------------------------------- metrics
    def tenant_summary(self, rst: RuntimeState, name: str) -> dict:
        return rst.tenant_metrics[self.tenant_ids[name]].summary()

    def assert_metrics_consistent(self, rst: RuntimeState,
                                  time_rtol: float = 1e-4) -> None:
        """The tentpole invariant: per-tenant metrics sum to the global
        counters — exactly for the integer-valued counters, to ``time_rtol``
        for the float time accumulators (summation order differs)."""
        import numpy as np
        from repro.core.metrics import ADDITIVE_FIELDS
        total = metrics_sum(rst.tenant_metrics)
        for f in ADDITIVE_FIELDS:
            a = np.asarray(jax.device_get(getattr(total, f)), np.float64)
            b = np.asarray(jax.device_get(getattr(rst.metrics, f)),
                           np.float64)
            if f.endswith("_time_s"):
                ok = np.allclose(a, b, rtol=time_rtol, atol=1e-12)
            else:
                ok = np.array_equal(a, b)
            if not ok:
                raise AssertionError(
                    f"tenant metrics do not sum to global for {f}: "
                    f"sum(tenants)={a}, global={b}")
