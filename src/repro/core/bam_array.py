"""``BamArray<T>`` / ``BamKVStore`` — BaM's high-level abstractions (§III-E).

``BamArray`` is the paper's array whose subscript operator transparently:
coalesces the wavefront's accesses, probes the software cache, issues NVMe
reads for the misses through the high-throughput queues, fills the cache,
and returns elements.  Here the subscript is a *functional* ``read``:

    values, state' = bam.read(state, flat_indices)

with every piece of BaM state (cache, queues, I/O metrics, and — for the
in-graph backend — the storage tier itself) threaded through explicitly.

Asynchrony is first-class: the primitive surface is ``submit(st, req) ->
(st, token)`` / ``wait(st, token) -> (st, values)``, where
:class:`IORequest` is the unified op descriptor (read / write / prefetch
share one submission path) and :class:`IOToken` the redeemable future.
``submit`` probes, pins, allocates and enqueues SQ commands *without*
draining; ``wait`` drains, performs the deferred fetch DMA, fills,
gathers and unpins.  Many tokens may be outstanding at once — that is
the paper's whole point (§II-C): the queues must hold ``Q_d = T x L``
requests in flight, which a synchronous per-op API can never reach.
Duplicate blocks are coalesced *across* pending ops (a submission that
probes a line another token is already fetching rides that command — the
cache's in-flight bit is the per-line lock), and the legacy ``read`` /
``write`` / ``prefetch`` calls below are thin submit+wait shims.

The life of a wavefront (paper Fig. 3, adapted):

    element idx ──► block key + offset
        │ coalesce (warp coalescer, §III-D)          -> unique lines, leaders
        │ probe cache                                 -> hits / misses
        │   (hit on a prefetched line: promote it, count a prefetch hit)
        │ allocate victims (clock)                    -> slots (or bypass)
        │ gather evicted dirty lines                  -> write-back commands
        │ readahead detect + speculative allocate     -> low-priority fills
        │ enqueue reads+write-backs+readahead,        -> SQ rings (§III-C)
        │   ring doorbells (demand lane drains first)
        │ service (simulated NVMe drain + DMA)        -> fetched lines
        │ fill cache, update tags/dirty/speculative
        ▼ gather elements (hit: cache line, miss: fetched line)

Requests dropped by full rings are still served read-through (and counted),
so a mis-sized queue config degrades accounting, never correctness.

Prefetching is off by default (``PrefetchConfig.enabled``); when on, the
stride detector in :mod:`repro.core.prefetch` extrapolates the wavefront's
block pattern ``window`` lines ahead and brings those lines in through the
readahead lane as evict-first *speculative* residents.  The explicit
:meth:`BamArray.prefetch` API lets applications (BFS frontiers, column
scans) push known-future wavefronts directly.

Multi-tenant sharing (:class:`BamRuntime`): the paper's central claim is
that *one* software cache and *one* queue pool serve many concurrent GPU
applications.  ``BamRuntime`` registers several tenants (each a
``BamArray`` over its own storage) against a single shared
``CacheState``/``QueueState``: each tenant's :class:`TenantCtx` carries
its tenant id (namespacing cache tags and queue commands) and its cache
*way quota* (``isolation="partitioned"`` confines each tenant's clock
sweep to its own ways; ``isolation="shared"`` keeps the free-for-all so
contention is measurable).  Per-tenant :class:`IOMetrics` accumulate next
to a global view, with the invariant that additive tenant counters sum
exactly to the global counters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import cache as C
from repro.core import queues as Q
from repro.core.coalescer import coalesce
from repro.kernels import ops as K
from repro.core.metrics import (
    IOMetrics, metrics_accumulate, metrics_delta, metrics_sum,
    recheck_token_watermark,
)
from repro.core.prefetch import PrefetchConfig, readahead_keys
from repro.core.ssd import (ArrayOfSSDs, INTEL_OPTANE_P5800X,
                            device_histogram, device_of_block)
from repro.core.storage import HBMStorage, SimStorage
from repro.utils import pad_to, pytree_dataclass, round_up

__all__ = ["BamArray", "BamState", "BamKVStore", "PrefetchConfig",
           "TenantCtx", "TenantSpec", "BamRuntime", "RuntimeState",
           "IORequest", "IOToken", "DEFAULT_BUCKETS", "OpFamilyEntry"]

# Wavefront shape buckets for the bucketed submit/wait wrappers: ragged
# production batch sizes are padded up to the smallest bucket (masked
# lanes are provably inert), so a sweep of sizes compiles at most
# ``len(DEFAULT_BUCKETS)`` executables per op instead of one per size.
DEFAULT_BUCKETS = (64, 256, 1024, 4096)


def _cached_jit(cache: Dict[str, Any], counts: Dict[str, int], key: str,
                make, donate_argnums=()):
    """One ``jax.jit`` per op key, cached in ``cache``; jit itself keys
    compiled executables by argument shape/dtype/pytree structure, so
    steady-state ops at fixed shapes never retrace.

    The Python body of the traced callable bumps ``counts[key]`` — Python
    runs only while JAX *traces* (a jit cache miss), so the counter is an
    exact retrace probe (the retrace-regression tests and
    ``benchmarks/hot_path.py`` read it).  Shared by :class:`BamArray` and
    :class:`BamRuntime`.

    ``donate_argnums`` is forwarded to ``jax.jit``: a donating op key
    (``"submit[donated]"`` …) hands its state argument's buffers to the
    output, so steady-state rounds update the multi-MB ``CacheState`` /
    ``QueueState`` in place instead of copying them.  The caller must not
    touch a donated value afterwards (JAX raises on reuse; bamlint rule
    BAM106 flags it statically).
    """
    fn = cache.get(key)
    if fn is None:
        raw = make()

        def counted(*args, _raw=raw, _key=key, **kw):
            counts[_key] = counts.get(_key, 0) + 1
            return _raw(*args, **kw)

        # this IS the per-instance jit cache the rule points at
        fn = jax.jit(counted,  # bamlint: ignore[BAM105]
                     donate_argnums=tuple(donate_argnums))
        cache[key] = fn
    return fn


def _mark_redeemed(token: "IOToken") -> None:
    """Host-side single-redemption guard for :class:`IOToken`.

    A token's pins are released exactly once, by its wait; redeeming the
    same token twice would re-run the drain/gather path and over-release
    the pin refcounts.  The guard lives on the *host* token object (jit
    bodies only run at trace time), so it is enforced at every eager
    ``wait`` call and by the ``wait_jit`` wrapper; tokens that are tracers
    (inside a scan/jit trace) are exempt — their lifecycle is the traced
    program's own.
    """
    if isinstance(token.valid, jax.core.Tracer):
        return
    # host-only from here on (tracers returned above); the attribute read
    # is trace-time Python, not a traced branch.
    if getattr(token, "_redeemed", False):  # bamlint: ignore[BAM104]
        raise ValueError(
            "IOToken has already been redeemed by wait(); a token must be "
            "waited exactly once (a second wait would over-release its "
            "cache pins)")
    object.__setattr__(token, "_redeemed", True)


@dataclasses.dataclass(frozen=True)
class OpFamilyEntry:
    """One member of the jit-cached op family, as enumerated by
    :meth:`BamArray.iter_op_family` / :meth:`BamRuntime.iter_op_family`.

    This is the registry hook the lowered-artifact verifier
    (``tools/bamverify``) walks so it never hand-maintains the op list:
    adding a new ``*_jit`` op here automatically puts it under the BAM5xx
    rules and into the compiled-graph manifest.

    ``kind`` is ``"jit"`` for directly lowerable jit-cached callables
    (``get(donate=...)`` returns the cached ``jax.jit`` object,
    ``example_args(state, n)`` builds a canonical batch-``n`` argument
    tuple for ``.lower()``) or ``"bucketed"`` for the host-side
    shape-bucketing wrappers (``get()`` returns a ``(state, n) -> state``
    round driver; ``trace_keys`` names the jit-cache keys it compiles
    into, whose trace counts the BAM505 executable-count rule audits).

    ``pure_all_hit`` marks executables whose all-hit fast path must stay
    free of *unconditional* host callbacks (the ``lax.cond``-gated fetch
    contract) — the BAM503 rule only audits entries that claim it.
    """

    name: str
    kind: str = "jit"              # "jit" | "bucketed"
    donatable: bool = False        # accepts donate=True (separate cache key)
    pure_all_hit: bool = False     # callbacks must be cond-gated (BAM503)
    get: Any = None                # (donate=False) -> callable
    example_args: Any = None       # (state, n) -> positional arg tuple
    trace_keys: Tuple[str, ...] = ()   # jit-cache keys audited by BAM505


@dataclasses.dataclass(frozen=True)
class TenantCtx:
    """Static multi-tenant context of one :class:`BamArray`.

    ``tenant`` namespaces this array's cache tags and queue commands;
    ``[way_lo, way_hi)`` is its cache way quota (``way_hi=None`` = all
    ways).  The default is the single-tenant identity: tenant 0 with the
    whole cache.
    """

    tenant: int = 0
    way_lo: int = 0
    way_hi: int | None = None


@pytree_dataclass
class BamState:
    """All mutable BaM state, threaded functionally through reads/writes."""

    cache: C.CacheState
    queues: Q.QueueState
    metrics: IOMetrics
    storage: Any  # HBMStorage pytree for the in-graph backend, else None


@pytree_dataclass(meta_fields=("kind",))
class IORequest:
    """Unified op descriptor: read / write / prefetch share one submission
    path (:meth:`BamArray.submit`).

    ``idx`` is a wavefront of element indices; ``valid`` masks lanes
    (``None`` = in-bounds check); ``values`` carries the write payload for
    ``kind="write"``.  Build with the :meth:`read`/:meth:`write`/
    :meth:`prefetch` constructors rather than the raw dataclass.
    """

    kind: str                       # "read" | "write" | "prefetch"
    idx: jax.Array                  # (n,) element indices
    values: jax.Array | None = None  # (n,) write payload (kind="write")
    valid: jax.Array | None = None  # (n,) lane mask; None = bounds check

    @staticmethod
    def read(idx: jax.Array, valid: jax.Array | None = None) -> "IORequest":
        return IORequest(kind="read", idx=idx, valid=valid)

    @staticmethod
    def write(idx: jax.Array, values: jax.Array,
              valid: jax.Array | None = None) -> "IORequest":
        return IORequest(kind="write", idx=idx, values=values, valid=valid)

    @staticmethod
    def prefetch(idx: jax.Array,
                 valid: jax.Array | None = None) -> "IORequest":
        return IORequest(kind="prefetch", idx=idx, valid=valid)


@pytree_dataclass(meta_fields=("kind",))
class IOToken:
    """Future returned by :meth:`BamArray.submit`; redeem exactly once with
    :meth:`BamArray.wait`.

    A token is a fixed-shape pytree (it rides ``lax.scan`` carries), holding
    what completion needs: the request's lane geometry, the unique block
    keys, the cache slots pinned at submit (released at wait), the write
    payload, and the per-device command histograms for the accounting that
    is deferred to the drain.  Dropping a token without waiting it leaks
    its cache pins; waiting it twice over-releases them.
    """

    kind: str                       # mirrors the IORequest kind
    valid: jax.Array                # (n,) request lanes
    off: jax.Array                  # (n,) element offset within its line
    inverse: jax.Array              # (n,) request lane -> unique-key row
    ukeys: jax.Array                # (n,) coalesced block keys, -1 padded
    pin_slots: jax.Array            # (n,) flat slots pinned at submit (-1 none)
    values: jax.Array | None        # (n,) write payload (kind="write")
    ra_keys: jax.Array | None       # (window,) stride-readahead keys issued
    dev_reads: jax.Array            # (nd,) read commands issued (incl. dropped)
    dev_writes: jax.Array           # (nd,) write commands issued (incl. dropped)
    drop_dev_reads: jax.Array       # (nd,) read commands the rings rejected
    drop_dev_writes: jax.Array      # (nd,) write commands the rings rejected
    # Per-unique-row fault-hash ordinal of the row's own fetch command
    # (SubmitReceipt.ticket): -1 for rows that enqueued none — hits,
    # cross-op riders, ring-dropped and invalid rows.  wait() recomputes
    # the command's retry/error fate from (device, ticket) with the same
    # pure FaultModel.command_status the drain uses, so the two agree by
    # construction.
    ticket: jax.Array               # (n,) int32
    ra_ticket: jax.Array | None     # (window,) readahead command tickets
    # Back-pressure visibility (satellite: drops must not be silent): True
    # on every request lane whose unique line's command the rings rejected
    # this submit.  Dropped commands are still served read/write-through,
    # so the lane's *value* is unaffected — the mask is how callers see
    # that their configured queue depth is saturating.
    dropped_mask: jax.Array         # (n,) bool, per request lane


@dataclasses.dataclass
class BamArray:
    """Static description of one BaM-backed array (not a pytree)."""

    storage: Any                    # SimStorage (host) or None (in-graph)
    shape: tuple
    dtype: Any
    block_elems: int
    ssd: ArrayOfSSDs = dataclasses.field(
        default_factory=lambda: ArrayOfSSDs(INTEL_OPTANE_P5800X, 1))
    prefetch_cfg: PrefetchConfig = dataclasses.field(
        default_factory=PrefetchConfig)
    tenant_ctx: TenantCtx = dataclasses.field(default_factory=TenantCtx)
    # Deferred drain (multi-tenant): when True, ops enqueue commands but do
    # NOT drain the rings; the runtime drains once per round
    # (BamRuntime.drain), so several tenants' commands genuinely coexist
    # and the weighted-fair arbitration orders a real mixed stream.
    defer_drain: bool = False
    # Kernel dispatch policy for the probe / fused probe+allocate / gather
    # hot path: "auto" (Pallas on TPU, jnp-oracle XLA elsewhere),
    # "pallas", or "ref" — threaded to repro.kernels.ops on every op.
    kernel_impl: str = "auto"
    # Fully traced I/O rounds (default): submit's multi-segment SQ enqueue
    # runs as one fused pass (queues.enqueue_segments), wait's ring drain
    # as closed-form accounting (queues.drain_accounting), the cache
    # bookkeeping as single-pass rebuilds, and a warm-cache wait elides
    # the host fetch DMA behind lax.cond.  False = the legacy step-by-step
    # path, kept as the differential oracle (tests pin both bit-identical).
    fused_rounds: bool = True
    # Shape buckets for submit_bucketed/wait_bucketed (ascending).
    buckets: Tuple[int, ...] = DEFAULT_BUCKETS
    # Per-instance jit cache for the op family (read/write/submit/wait/…)
    # plus the trace-count probe the retrace-regression tests read.  Both
    # are identity-bound to this instance's static config — `with_prefetch`
    # resets them on the copy.
    _jit_ops: Dict[str, Any] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    _trace_counts: Dict[str, int] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    # ---------------------------------------------------------------- init
    @staticmethod
    def build(data, block_elems: int, *,
              num_sets: int, ways: int = 4,
              num_queues: int = 8, queue_depth: int = 1024,
              ssd: Optional[ArrayOfSSDs] = None,
              prefetch: Optional[PrefetchConfig] = None,
              backend: str = "sim",
              kernel_impl: str = "auto") -> Tuple["BamArray", BamState]:
        """Create the array + its initial state from a host/jnp array.

        ``backend='sim'``: data lives on the host, fetched via pure_callback
        (the NVMe DMA stand-in).  ``backend='hbm'``: data is an in-graph cold
        buffer — used by dry-runs so the compiler sees the traffic.

        The SQ pool is partitioned per storage device (``ssd.n_devices``
        equal ring groups); ``num_queues`` is rounded up to the next
        multiple of the device count so every channel gets the same depth.

        ``kernel_impl`` picks the hot-path kernels (probe, fused
        probe+allocate, line gather): ``"auto"`` compiles the Pallas
        kernels natively on TPU and runs the bit-identical jnp oracles as
        XLA graphs elsewhere; ``"pallas"``/``"ref"`` pin one side (tests
        pin ``"pallas"`` with interpret mode for the differential sweeps).
        """
        import numpy as np
        shape = tuple(data.shape)
        if backend == "sim":
            store = SimStorage.from_array(np.asarray(data), block_elems)
            state_store = None
            dtype = store.dtype
        elif backend == "hbm":
            hs = HBMStorage.from_array(jnp.asarray(data), block_elems)
            store, state_store, dtype = None, hs, hs.dtype
        else:
            raise ValueError(f"unknown backend {backend!r}")
        if kernel_impl not in ("auto", "pallas", "ref"):
            raise ValueError(
                f"kernel_impl must be 'auto', 'pallas' or 'ref', "
                f"got {kernel_impl!r}")
        ssd = ssd or ArrayOfSSDs(INTEL_OPTANE_P5800X, 1)
        num_queues = round_up(num_queues, ssd.n_devices)
        arr = BamArray(
            storage=store, shape=shape, dtype=dtype, block_elems=block_elems,
            ssd=ssd,
            prefetch_cfg=prefetch or PrefetchConfig(),
            kernel_impl=kernel_impl)
        st = BamState(
            cache=C.make_cache(num_sets, ways, block_elems, dtype),
            queues=Q.make_queues(num_queues, queue_depth,
                                 n_devices=ssd.n_devices,
                                 stripe_blocks=ssd.stripe_blocks,
                                 failed_devices=ssd.fault.failed_devices),
            metrics=IOMetrics.zeros(ssd.n_devices),
            storage=state_store,
        )
        return arr, st

    # ------------------------------------------------------------- helpers
    @property
    def block_bytes(self) -> int:
        return self.block_elems * jnp.dtype(self.dtype).itemsize

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n

    @property
    def num_blocks(self) -> int:
        return -(-self.size // self.block_elems)

    def with_prefetch(self, cfg: PrefetchConfig) -> "BamArray":
        """Same array, different (static) readahead policy.

        The jit-op cache is reset on the copy: its cached callables close
        over the *original* instance's static config.
        """
        return dataclasses.replace(self, prefetch_cfg=cfg,
                                   _jit_ops={}, _trace_counts={})

    # ------------------------------------------------- jit-cached op family
    def _jit_op(self, name: str, make, donate_argnums=()):
        """See :func:`_cached_jit` (the shared cache + retrace probe)."""
        return _cached_jit(self._jit_ops, self._trace_counts, name, make,
                           donate_argnums=donate_argnums)

    @property
    def trace_counts(self) -> Dict[str, int]:
        """How many times each jit-cached op has been traced (not called)."""
        return dict(self._trace_counts)

    def read_jit(self):
        """Cached ``jax.jit`` of :meth:`read` — grab it every wavefront,
        it compiles once per distinct index shape."""
        return self._jit_op(
            "read", lambda: lambda st, idx, valid=None:
                self.read(st, idx, valid))

    def write_jit(self):
        return self._jit_op(
            "write", lambda: lambda st, idx, values, valid=None:
                self.write(st, idx, values, valid))

    def prefetch_jit(self):
        return self._jit_op(
            "prefetch", lambda: lambda st, idx, valid=None:
                self.prefetch(st, idx, valid))

    def submit_jit(self, *, donate: bool = False):
        """Cached ``jax.jit`` of :meth:`submit` ``(st, req) -> (st, tok)``
        — the token API's steady-state entry point.  ``IORequest.kind`` is
        pytree metadata, so read/write/prefetch submissions share the one
        cached callable and key their compilations by request structure.

        ``donate=True`` donates the state argument's buffers to the output
        (a separate cache key — the non-donating executables are
        unaffected): steady-state rounds then update ``CacheState`` /
        ``QueueState`` in place instead of copying.  The caller must not
        use the passed-in state again (JAX raises ``Array has been
        deleted``; bamlint BAM106 flags the pattern statically)."""
        key = "submit[donated]" if donate else "submit"
        return self._jit_op(
            key, lambda: lambda st, req: self.submit(st, req),
            donate_argnums=(0,) if donate else ())

    def wait_jit(self, *, donate: bool = False, guard: bool = True):
        """Cached ``jax.jit`` of :meth:`wait` ``(st, tok) -> (st, vals)``.

        ``donate`` as in :meth:`submit_jit`.  ``guard=True`` (default)
        returns a cached wrapper enforcing the single-redemption contract
        on the host token before dispatch (see :func:`_mark_redeemed`);
        ``guard=False`` is for callers that deliberately replay a wait
        (benchmark timing loops re-time one wait against copies of the
        same pre-wait state)."""
        key = "wait[donated]" if donate else "wait"
        fn = self._jit_op(
            key, lambda: lambda st, tok: self.wait(st, tok),
            donate_argnums=(0,) if donate else ())
        if not guard:
            return fn
        wkey = key + "#guard"
        w = self._jit_ops.get(wkey)
        if w is None:
            def guarded(st, tok, _fn=fn):
                _mark_redeemed(tok)
                return _fn(st, tok)

            self._jit_ops[wkey] = w = guarded
        return w

    def submit_wait_jit(self, *, donate: bool = False):
        """Cached ``jax.jit`` of a whole submit → wait round
        ``(st, req) -> (st, vals)`` as ONE executable.

        The async pair exists to *overlap* outstanding tokens; a caller
        that redeems immediately (the synchronous round-trip) would pay a
        second dispatch plus a full state flatten/unflatten between the
        two ops for a token that never outlives the call.  Fusing the
        pair runs the same two passes back to back inside one executable
        — same values, same metrics, bit-identical to
        :meth:`submit_jit` + :meth:`wait_jit` (the differential oracle
        pins it) — and the intermediate token never materialises on the
        host.  ``donate`` as in :meth:`submit_jit`."""
        key = "submit_wait[donated]" if donate else "submit_wait"

        def make():
            def op(st, req):
                st, tok = self.submit(st, req)
                return self.wait(st, tok)
            return op

        return self._jit_op(key, make,
                            donate_argnums=(0,) if donate else ())

    # --------------------------------------------- bucketed wavefronts
    def bucket_size(self, n: int) -> int:
        """Smallest configured bucket >= ``n`` (overflow: next multiple of
        the largest bucket, so giant wavefronts still reuse executables)."""
        for b in self.buckets:
            if n <= b:
                return int(b)
        return round_up(n, int(self.buckets[-1]))

    def submit_bucketed(self, st: BamState, req: IORequest, *,
                        donate: bool = False) -> Tuple[BamState, IOToken]:
        """Submit through the jit cache with the wavefront padded up to a
        bucket size, so a ragged sweep of batch sizes compiles at most
        ``len(self.buckets)`` submit executables instead of one per size.

        Padded lanes are inert by construction (``idx=-1, valid=False``):
        the coalescer drops them, they probe no tags, pin no slots, take
        no ring slots and move no metric — the retrace-regression tests
        pin bucketed execution bit-identical to unbucketed.  Zero-length
        batches short-circuit *before* padding (no size-0 executable).
        ``donate`` forwards to :meth:`submit_jit`.
        """
        n = int(req.idx.shape[0])
        if n == 0:
            return self.submit(st, req)     # eager no-op, nothing traced
        m = self.bucket_size(n)
        # Always materialise the lane mask: a request with valid=None has a
        # different pytree structure than a padded one, and structure keys
        # the jit cache — one treedef per bucket, not two.
        valid = req.valid
        if valid is None:
            valid = (req.idx >= 0) & (req.idx < self.size)
        values = None
        if req.values is not None:
            values = pad_to(req.values, m, 0)
        req = IORequest(kind=req.kind, idx=pad_to(req.idx, m, -1),
                        values=values, valid=pad_to(valid, m, False))
        st2, tok = self.submit_jit(donate=donate)(st, req)
        # host-side bookkeeping (not a pytree leaf): wait_bucketed slices
        # the values back to the caller's length
        object.__setattr__(tok, "_orig_len", n)
        return st2, tok

    def wait_bucketed(self, st: BamState, token: IOToken, *,
                      donate: bool = False) -> Tuple[BamState, jax.Array]:
        """Redeem a :meth:`submit_bucketed` token, slicing the values back
        to the original (pre-padding) wavefront length."""
        if token.ukeys.shape[0] == 0:
            return self.wait(st, token)     # eager no-op + redeem guard
        st2, vals = self.wait_jit(donate=donate)(st, token)
        n = getattr(token, "_orig_len", None)
        if n is not None:
            vals = vals[:n]
        return st2, vals

    # ------------------------------------------------- op-family registry
    def _example_wavefront(self, n: int):
        """Canonical batch-``n`` index wavefront for artifact lowering:
        strided so it spans multiple cache sets, with the lane mask always
        materialised (the same pytree structure the bucketed wrappers
        produce, so lowered artifacts and bucketed traffic share
        executables)."""
        idx = (jnp.arange(n, dtype=jnp.int32) * 7) % self.size
        return idx, idx < self.size

    def _bucketed_round(self, st: BamState, n: int,
                        donate: bool = False) -> BamState:
        """One bucketed submit+wait round at raw batch ``n`` (the BAM505
        sweep driver: ragged ``n`` must reuse at most one executable per
        configured bucket)."""
        idx, valid = self._example_wavefront(n)
        st, tok = self.submit_bucketed(st, IORequest.read(idx, valid),
                                       donate=donate)
        st, _ = self.wait_bucketed(st, tok, donate=donate)
        return st

    def iter_op_family(self):
        """Enumerate the jit-cached op family (see :class:`OpFamilyEntry`).

        This is the registry ``tools/bamverify`` lowers and lints: every
        steady-state executable this array can produce is listed here —
        the synchronous shims, the token ops with their donated variants,
        the fused whole-round op, and the shape-bucketed drivers.  Keep it
        in sync with the ``*_jit`` surface; the verifier's tests assert
        the family covers the jit cache keys actually used.
        """
        def args_read(st, n):
            idx, valid = self._example_wavefront(n)
            return (st, idx, valid)

        def args_write(st, n):
            idx, valid = self._example_wavefront(n)
            return (st, idx, jnp.ones((n,), self.dtype), valid)

        def args_req(st, n):
            idx, valid = self._example_wavefront(n)
            return (st, IORequest.read(idx, valid))

        def args_token(st, n):
            idx, valid = self._example_wavefront(n)
            st1, tok = self.submit(st, IORequest.read(idx, valid))
            return (st1, tok)

        yield OpFamilyEntry(
            name="read", get=lambda donate=False: self.read_jit(),
            example_args=args_read, trace_keys=("read",))
        yield OpFamilyEntry(
            name="write", get=lambda donate=False: self.write_jit(),
            example_args=args_write, trace_keys=("write",))
        yield OpFamilyEntry(
            name="prefetch", get=lambda donate=False: self.prefetch_jit(),
            example_args=args_read, trace_keys=("prefetch",))
        yield OpFamilyEntry(
            name="submit", donatable=True,
            get=lambda donate=False: self.submit_jit(donate=donate),
            example_args=args_req, trace_keys=("submit",))
        # wait's fetch DMA is lax.cond-gated (PR 8): an all-hit round must
        # never pay the host callback, so its executables claim
        # pure_all_hit and BAM503 audits callback placement in them.
        yield OpFamilyEntry(
            name="wait", donatable=True, pure_all_hit=True,
            get=lambda donate=False: self.wait_jit(donate=donate,
                                                   guard=False),
            example_args=args_token, trace_keys=("wait",))
        yield OpFamilyEntry(
            name="submit_wait", donatable=True,
            get=lambda donate=False: self.submit_wait_jit(donate=donate),
            example_args=args_req, trace_keys=("submit_wait",))
        yield OpFamilyEntry(
            name="bucketed_round", kind="bucketed",
            get=lambda donate=False:
                lambda st, n: self._bucketed_round(st, n, donate),
            trace_keys=("submit", "wait"))

    def _store(self, st: BamState):
        return self.storage if self.storage is not None else st.storage

    def _check_channels(self, st: BamState) -> None:
        """SQ routing and device accounting must share one striping.

        Both are static metadata, so a hand-assembled state that pairs
        mismatched ``make_queues``/``ArrayOfSSDs`` channel configs fails at
        trace time instead of silently charging metrics to the wrong
        device.
        """
        qs = st.queues
        if (qs.n_devices, qs.stripe_blocks) != (self.ssd.n_devices,
                                                self.ssd.stripe_blocks):
            raise ValueError(
                f"queue channels (n_devices={qs.n_devices}, "
                f"stripe_blocks={qs.stripe_blocks}) do not match the SSD "
                f"array (n_devices={self.ssd.n_devices}, "
                f"stripe_blocks={self.ssd.stripe_blocks}); build the state "
                "with BamArray.build or make_queues with the same config")
        # bamlint: ignore[BAM104] -- both sides are static host tuples
        if qs.failed_devices != self.ssd.fault.failed_devices:
            raise ValueError(
                f"queue failed_devices {qs.failed_devices} do not match "
                f"the SSD fault model's {self.ssd.fault.failed_devices}: "
                "SQ routing and the device-time charge would remap dead "
                "stripes differently; build the state with BamArray.build "
                "or pass the same failed_devices to make_queues")

    def _split(self, idx: jax.Array):
        return (idx // self.block_elems).astype(jnp.int32), \
               (idx % self.block_elems).astype(jnp.int32)

    # ----------------------------------------------------------- async core
    def submit(self, st: BamState, req: IORequest
               ) -> Tuple[BamState, IOToken]:
        """Issue a wavefront of storage commands without draining them.

        The submission half of every op (read/write/prefetch — one path):

            coalesce -> probe -> pin -> allocate (+mark in-flight)
                     -> write back evicted dirty lines -> enqueue SQ commands

        No DMA fetch, no ring drain, no device-time charge happens here;
        those belong to :meth:`wait`.  Multiple tokens may be outstanding at
        once: every line this op touched (hit or newly granted) is pinned
        until its wait, granted-but-unfilled lines carry the cache's
        ``inflight`` bit, and a later submission that probes a key another
        pending token is already fetching *coalesces* against it (counted in
        ``cross_op_coalesced``) instead of enqueuing a duplicate command —
        the submission-window coalescer working across ops, not just within
        one wavefront.
        """
        self._check_channels(st)
        kind = req.kind
        if kind not in ("read", "write", "prefetch"):
            raise ValueError(f"unknown IORequest kind {kind!r}")
        if kind == "write" and req.values is None:
            raise ValueError("IORequest(kind='write') needs values")
        if req.idx.shape[0] == 0:
            return self._submit_empty(st, req)
        idx = req.idx
        valid = req.valid
        if valid is None:
            valid = (idx >= 0) & (idx < self.size)
        blk, off = self._split(jnp.where(valid, idx, 0))
        blk = jnp.where(valid, blk, -1)

        # 1) warp-coalesce the wavefront to unique cache lines.
        co = coalesce(blk, valid)
        ukeys = co.unique_keys                      # (n,) padded with -1
        uvalid = ukeys >= 0
        ctx = self.tenant_ctx
        nd = self.ssd.n_devices
        sb = self.ssd.stripe_blocks
        fd = self.ssd.fault.failed_devices
        mt = st.metrics
        if kind == "prefetch":
            return self._submit_prefetch(st, co, off, valid)

        # 2+3) fused probe + victim allocate: ONE kernel pass
        #    (repro.kernels.ops.probe_allocate, Pallas on TPU / jnp oracle
        #    elsewhere) probes the tags and grants a victim slot per miss.
        #    This round's hits are protected in-pass; lines pinned by
        #    other outstanding tokens are refcount-protected.
        cache2, pr, alloc = C.probe_allocate(
            st.cache, ukeys, uvalid, tenant=ctx.tenant,
            way_lo=ctx.way_lo, way_hi=ctx.way_hi, impl=self.kernel_impl)

        #    Demand probe accounting.  A hit on a prefetched line promotes
        #    it; a hit on an *in-flight* line is a cross-op coalesce — some
        #    pending token already has the fetch in the rings, so this op
        #    rides that command instead of issuing its own.  (Hit slots and
        #    granted slots are disjoint, so promoting after the allocation
        #    scatters is bit-identical to promoting before them.)
        n_hit = jnp.sum(pr.hit.astype(jnp.int32))
        n_pref_hit = jnp.sum(pr.speculative.astype(jnp.int32))
        n_cross = jnp.sum(pr.inflight.astype(jnp.int32))
        miss = uvalid & ~pr.hit

        # 3b) pin everything this token touched until its wait, and mark
        #     granted (not-yet-filled) lines in flight.  The four
        #     bookkeeping steps (hit count, promote, pin, in-flight) touch
        #     disjoint cache fields, so the fused path folds them into one
        #     CacheState rebuild — bit-identical to the sequential helpers.
        pin_slots = jnp.where(pr.hit, pr.slot,
                              jnp.where(alloc.ok, alloc.slot, -1))
        grant_slots = jnp.where(alloc.ok, alloc.slot, -1)
        promote_slots = jnp.where(pr.speculative, pr.slot, -1)
        if self.fused_rounds:
            cache2 = C.grant_bookkeeping(cache2, n_hit, promote_slots,
                                         pin_slots, grant_slots)
        else:
            cache2 = C.count_hits(cache2, n_hit)
            cache2 = C.promote(cache2, promote_slots)
            cache2 = C.acquire(cache2, pin_slots)
            cache2 = C.mark_inflight(cache2, grant_slots)

        # 4) evicted dirty lines -> write-back commands + immediate DMA
        #    (the line leaves the cache now, so its bytes must be persisted
        #    now; only the *fetch* side of the op is deferred to wait()).
        wb = alloc.ok & alloc.evicted_dirty & (alloc.evicted_key >= 0)
        wb_keys = jnp.where(wb, alloc.evicted_key, -1)
        # Only the dirty-evicted lanes' bytes ever reach storage (the DMA
        # drops key -1 lanes), so the line gather is masked by ``wb`` and
        # skipped outright when the wavefront evicted nothing dirty — the
        # warm-cache steady state never touches the line store here.
        ev_lines = jax.lax.cond(
            jnp.any(wb),
            lambda: cache2.data[jnp.where(wb, alloc.slot, 0)],
            lambda: jnp.zeros((ukeys.shape[0], cache2.line_elems),
                              cache2.data.dtype))

        # 4b) readahead (read ops): extrapolate the wavefront's stride and
        #     speculatively claim the predicted lines — enqueued in the
        #     low-priority lane, fetched at wait.
        cfg = self.prefetch_cfg
        ra_on = kind == "read" and cfg.enabled and cfg.window > 0
        ra_keys_tok = None
        if ra_on:
            ra_cand = readahead_keys(
                ukeys, uvalid, window=cfg.window, num_blocks=self.num_blocks,
                min_support=cfg.min_support, max_stride=cfg.max_stride,
                raw_keys=blk, raw_valid=valid)
            # Never speculatively re-fetch a line this wavefront just
            # evicted: on the sim backend the fetch (pure_callback) is not
            # ordered against the dirty write-back (io_callback), so it
            # could observe the pre-write-back bytes — and re-fetching a
            # just-evicted line is pure thrash regardless of backend.
            evk = jnp.where(alloc.ok & (alloc.evicted_key >= 0),
                            alloc.evicted_key, -2)
            not_evicted = ~jnp.any(
                ra_cand[:, None] == evk[None, :], axis=1)
            # Fused probe + speculative allocate for the predicted lines
            # (probe hits are NOT protected here — only the demand
            # wavefront's hit and granted slots are, as before).
            cache2, _, ra_alloc = C.probe_allocate(
                cache2, ra_cand, ra_cand >= 0, alloc_mask=not_evicted,
                protect_slots=jnp.concatenate([pr.slot, alloc.slot]),
                protect_hits=False, speculative=True,
                tenant=ctx.tenant, way_lo=ctx.way_lo, way_hi=ctx.way_hi,
                impl=self.kernel_impl)
            ra_keys = jnp.where(ra_alloc.ok, ra_cand, -1)
            ra_rows = jnp.where(ra_alloc.ok, ra_alloc.slot, 0)
            ra_ev_lines = cache2.data[ra_rows]
            ra_wb = ra_alloc.ok & ra_alloc.evicted_dirty \
                & (ra_alloc.evicted_key >= 0)
            ra_wb_keys = jnp.where(ra_wb, ra_alloc.evicted_key, -1)
            cache2 = C.mark_inflight(
                cache2, jnp.where(ra_alloc.ok, ra_alloc.slot, -1))
            ra_keys_tok = ra_keys

        # 5) enqueue reads + write-backs into the SQ rings; ring doorbells.
        #    Readahead goes last and in the low-priority lane: it is the
        #    first thing dropped under back-pressure and the last retired.
        #    The rings are NOT drained here — that is wait()'s job, so
        #    commands from several outstanding tokens genuinely coexist and
        #    the queues fill toward the Little's-law depth.
        read_keys = jnp.where(miss, ukeys, -1)
        segs = [(read_keys, alloc.slot, None, None, Q.PRIO_DEMAND),
                (wb_keys, None, jnp.ones_like(wb), None, Q.PRIO_DEMAND)]
        if kind == "write":
            # Bypassed lines (no slot granted) are written through at wait;
            # their commands ride the rings like every other write.
            byp = miss & ~alloc.ok
            bt_keys = jnp.where(byp, ukeys, -1)
            segs.append((bt_keys, None, jnp.ones_like(byp), None,
                         Q.PRIO_DEMAND))
        if ra_on:
            segs.append((ra_wb_keys, None, jnp.ones_like(ra_wb), None,
                         Q.PRIO_DEMAND))
            segs.append((ra_keys, ra_alloc.slot, None, None,
                         Q.PRIO_READAHEAD))
        if self.fused_rounds:
            # one fused pass: one combined scatter per SQ ring field, one
            # QueueState rebuild (bit-identical to the sequential enqueues
            # — the differential oracle pins it)
            qs2, recs = Q.enqueue_segments(st.queues, segs,
                                           tenant=ctx.tenant,
                                           impl=self.kernel_impl)
        else:
            qs2, recs = st.queues, []
            # static unroll: segs has trace-time-constant length (2-4)
            for keys_s, dst_s, w_s, v_s, p_s in segs:  # bamlint: ignore[BAM104]
                qs2, rec = Q.enqueue(qs2, keys_s, dst=dst_s, is_write=w_s,
                                     valid=v_s, prio=p_s, tenant=ctx.tenant)
                recs.append(rec)
        it = iter(recs)
        rec_r, rec_w = next(it), next(it)
        n_doorbells = rec_r.n_doorbells + rec_w.n_doorbells
        n_dropped = rec_r.n_dropped + rec_w.n_dropped
        dev_reads_tok = device_histogram(ukeys, nd, miss, sb, fd)
        dev_writes_tok = device_histogram(wb_keys, nd, stripe_blocks=sb,
                                          failed_devices=fd)
        drop_reads = device_histogram(read_keys, nd, ~rec_r.accepted, sb, fd)
        drop_writes = device_histogram(wb_keys, nd, ~rec_w.accepted, sb, fd)
        # Per-unique-row command tickets (the fault-hash counter) and the
        # rows whose demand command the rings rejected: both feed the token
        # so wait() can resolve completion status and callers can see
        # back-pressure drops per lane instead of only as a global count.
        ticket_tok = rec_r.ticket
        drop_u = miss & ~rec_r.accepted
        if kind == "write":
            rec_bt = next(it)
            n_doorbells = n_doorbells + rec_bt.n_doorbells
            n_dropped = n_dropped + rec_bt.n_dropped
            dev_writes_tok = dev_writes_tok + device_histogram(
                bt_keys, nd, stripe_blocks=sb, failed_devices=fd)
            drop_writes = drop_writes + device_histogram(
                bt_keys, nd, ~rec_bt.accepted, sb, fd)
            drop_u = drop_u | (byp & ~rec_bt.accepted)
        ra_ticket_tok = None
        if ra_on:
            rec_rw, rec_ra = next(it), next(it)
            n_doorbells = n_doorbells + rec_rw.n_doorbells + rec_ra.n_doorbells
            n_dropped = n_dropped + rec_rw.n_dropped + rec_ra.n_dropped
            dev_reads_tok = dev_reads_tok + device_histogram(
                ra_keys, nd, stripe_blocks=sb, failed_devices=fd)
            dev_writes_tok = dev_writes_tok + device_histogram(
                ra_wb_keys, nd, stripe_blocks=sb, failed_devices=fd)
            drop_reads = drop_reads + device_histogram(
                ra_keys, nd, ~rec_ra.accepted, sb, fd)
            drop_writes = drop_writes + device_histogram(
                ra_wb_keys, nd, ~rec_rw.accepted, sb, fd)
            ra_ticket_tok = rec_ra.ticket
        depth_now = Q.in_flight(qs2)
        depth_dev = Q.in_flight_per_device(qs2)

        # 6) persist evicted dirty lines (write DMA happens at submit; the
        #    fetch DMA is deferred to wait).
        store = self._store(st)
        new_storage = st.storage
        if self.storage is None:                    # in-graph backend
            new_storage = store.write_blocks(wb_keys, ev_lines)
            if ra_on:
                new_storage = new_storage.write_blocks(ra_wb_keys,
                                                       ra_ev_lines)
        else:
            self.storage.write_blocks(wb_keys, ev_lines)
            if ra_on:
                self.storage.write_blocks(ra_wb_keys, ra_ev_lines)

        # 7) submission-side metrics.  Device busy time, bytes fetched and
        #    the per-device charge histograms are wait-side (they belong to
        #    the drain); everything the submission itself decides is here.
        n_valid = jnp.sum(valid.astype(jnp.int32))
        n_miss = jnp.sum(miss.astype(jnp.int32))
        n_wb = jnp.sum(wb.astype(jnp.int32))
        n_ra = jnp.zeros((), jnp.int32)
        if ra_on:
            n_ra = jnp.sum(ra_alloc.ok.astype(jnp.int32))
            n_wb = n_wb + jnp.sum(ra_wb.astype(jnp.int32))
        if kind == "write":
            n_wb = n_wb + jnp.sum(byp.astype(jnp.int32))
        itemsize = jnp.dtype(self.dtype).itemsize
        tok_new = jnp.any(valid).astype(mt.requests.dtype)
        window_now = (mt.tokens_in_flight + tok_new).astype(jnp.int32)
        metrics = dataclasses.replace(
            mt,
            requests=mt.requests + n_valid,
            bytes_requested=mt.bytes_requested + n_valid * itemsize,
            hits=mt.hits + n_hit,
            misses=mt.misses + n_miss,
            write_ops=mt.write_ops + n_wb,
            bytes_to_storage=mt.bytes_to_storage + n_wb * self.block_bytes,
            doorbells=mt.doorbells + n_doorbells,
            dropped=mt.dropped + n_dropped,
            prefetch_issued=mt.prefetch_issued + n_ra,
            prefetch_hits=mt.prefetch_hits + n_pref_hit,
            max_queue_depth=jnp.maximum(mt.max_queue_depth,
                                        depth_now.astype(jnp.int32)),
            dev_max_depth=jnp.maximum(mt.dev_max_depth,
                                      depth_dev.astype(jnp.int32)),
            tokens_submitted=mt.tokens_submitted + tok_new,
            tokens_in_flight=mt.tokens_in_flight + tok_new,
            cross_op_coalesced=mt.cross_op_coalesced + n_cross,
            max_tokens_in_flight=jnp.maximum(mt.max_tokens_in_flight,
                                             window_now),
        )
        token = IOToken(
            kind=kind, valid=valid, off=off, inverse=co.inverse_idx,
            ukeys=ukeys, pin_slots=pin_slots,
            values=req.values if kind == "write" else None,
            ra_keys=ra_keys_tok,
            dev_reads=dev_reads_tok, dev_writes=dev_writes_tok,
            drop_dev_reads=drop_reads, drop_dev_writes=drop_writes,
            ticket=ticket_tok, ra_ticket=ra_ticket_tok,
            dropped_mask=valid & drop_u[co.inverse_idx])
        return BamState(cache=cache2, queues=qs2, metrics=metrics,
                        storage=new_storage), token

    def _submit_empty(self, st: BamState, req: IORequest
                      ) -> Tuple[BamState, IOToken]:
        """Zero-length wavefront (an exhausted BFS frontier, a drained
        producer): no commands, no cache traffic, no metrics — the state
        passes through untouched and a zero-shaped token keeps the
        submit/wait pairing uniform.  Guarded *before* any tracing or
        bucket padding so no degenerate size-0 executable is ever built.
        """
        nd = self.ssd.n_devices
        z = jnp.zeros((0,), jnp.int32)
        zh = jnp.zeros((nd,), jnp.int32)
        token = IOToken(
            kind=req.kind, valid=jnp.zeros((0,), bool), off=z, inverse=z,
            ukeys=jnp.full((0,), -1, jnp.int32),
            pin_slots=jnp.full((0,), -1, jnp.int32),
            values=req.values if req.kind == "write" else None,
            ra_keys=None, dev_reads=zh, dev_writes=zh,
            drop_dev_reads=zh, drop_dev_writes=zh,
            ticket=jnp.full((0,), -1, jnp.int32), ra_ticket=None,
            dropped_mask=jnp.zeros((0,), bool))
        return st, token

    def _submit_prefetch(self, st: BamState, co, off, valid
                         ) -> Tuple[BamState, IOToken]:
        """Prefetch submission: speculative insert-without-pin through the
        readahead lane.  Unlike demand ops the granted lines are *not*
        pinned (a hint that never materialises stays the clock hand's first
        victim), but they do carry the in-flight bit so demand submissions
        coalesce against them instead of double-fetching."""
        ctx = self.tenant_ctx
        nd = self.ssd.n_devices
        sb = self.ssd.stripe_blocks
        fd = self.ssd.fault.failed_devices
        mt = st.metrics
        ukeys = co.unique_keys
        uvalid = ukeys >= 0
        # Fused probe + speculative allocate (probe hits protected, as
        # before).  A hint landing on a line some pending token is already
        # fetching is a cross-op coalesce too: nothing to claim, nothing
        # to enqueue.
        cache1, pr, alloc = C.probe_allocate(
            st.cache, ukeys, uvalid, speculative=True, tenant=ctx.tenant,
            way_lo=ctx.way_lo, way_hi=ctx.way_hi, impl=self.kernel_impl)
        n_cross = jnp.sum(pr.inflight.astype(jnp.int32))
        ev_rows = jnp.where(alloc.ok, alloc.slot, 0)
        ev_lines = cache1.data[ev_rows]
        wb = alloc.ok & alloc.evicted_dirty & (alloc.evicted_key >= 0)
        wb_keys = jnp.where(wb, alloc.evicted_key, -1)
        keys = jnp.where(alloc.ok, ukeys, -1)
        cache1 = C.mark_inflight(cache1,
                                 jnp.where(alloc.ok, alloc.slot, -1))

        segs = [(wb_keys, None, jnp.ones_like(wb), None, Q.PRIO_DEMAND),
                (keys, alloc.slot, None, None, Q.PRIO_READAHEAD)]
        if self.fused_rounds:
            qs2, (rec_w, rec_r) = Q.enqueue_segments(
                st.queues, segs, tenant=ctx.tenant, impl=self.kernel_impl)
        else:
            qs2, rec_w = Q.enqueue(st.queues, wb_keys,
                                   is_write=jnp.ones_like(wb),
                                   tenant=ctx.tenant)
            qs2, rec_r = Q.enqueue(qs2, keys, dst=alloc.slot,
                                   prio=Q.PRIO_READAHEAD, tenant=ctx.tenant)
        depth_now = Q.in_flight(qs2)
        depth_dev = Q.in_flight_per_device(qs2)

        store = self._store(st)
        new_storage = st.storage
        if self.storage is None:                    # in-graph backend
            new_storage = store.write_blocks(wb_keys, ev_lines)
        else:
            self.storage.write_blocks(wb_keys, ev_lines)

        n_ra = jnp.sum(alloc.ok.astype(jnp.int32))
        n_wb = jnp.sum(wb.astype(jnp.int32))
        dev_reads_tok = device_histogram(keys, nd, stripe_blocks=sb,
                                         failed_devices=fd)
        dev_writes_tok = device_histogram(wb_keys, nd, stripe_blocks=sb,
                                          failed_devices=fd)
        drop_reads = device_histogram(keys, nd, ~rec_r.accepted, sb, fd)
        drop_writes = device_histogram(wb_keys, nd, ~rec_w.accepted, sb, fd)
        tok_new = jnp.any(valid).astype(mt.requests.dtype)
        window_now = (mt.tokens_in_flight + tok_new).astype(jnp.int32)
        metrics = dataclasses.replace(
            mt,
            write_ops=mt.write_ops + n_wb,
            bytes_to_storage=mt.bytes_to_storage + n_wb * self.block_bytes,
            doorbells=mt.doorbells + rec_r.n_doorbells + rec_w.n_doorbells,
            dropped=mt.dropped + rec_r.n_dropped + rec_w.n_dropped,
            prefetch_issued=mt.prefetch_issued + n_ra,
            max_queue_depth=jnp.maximum(mt.max_queue_depth,
                                        depth_now.astype(jnp.int32)),
            dev_max_depth=jnp.maximum(mt.dev_max_depth,
                                      depth_dev.astype(jnp.int32)),
            tokens_submitted=mt.tokens_submitted + tok_new,
            tokens_in_flight=mt.tokens_in_flight + tok_new,
            cross_op_coalesced=mt.cross_op_coalesced + n_cross,
            max_tokens_in_flight=jnp.maximum(mt.max_tokens_in_flight,
                                             window_now),
        )
        token = IOToken(
            kind="prefetch", valid=valid, off=off, inverse=co.inverse_idx,
            ukeys=ukeys, pin_slots=jnp.full_like(ukeys, -1),
            values=None, ra_keys=None,
            dev_reads=dev_reads_tok, dev_writes=dev_writes_tok,
            drop_dev_reads=drop_reads, drop_dev_writes=drop_writes,
            ticket=rec_r.ticket, ra_ticket=None,
            dropped_mask=valid & (alloc.ok
                                  & ~rec_r.accepted)[co.inverse_idx])
        return BamState(cache=cache1, queues=qs2, metrics=metrics,
                        storage=new_storage), token

    def _fetch_gated(self, store, keys: jax.Array,
                     need: jax.Array) -> jax.Array:
        """Fetch ``keys`` (``-1`` lanes skipped), eliding the host DMA
        round-trip entirely when *no* lane needs one.

        ``SimStorage.fetch_blocks`` of an all-``-1`` wavefront returns
        zeros, so the skip branch is lane-wise value-identical to the
        fetch — and ``lax.cond`` executes exactly one branch at runtime,
        so a warm-cache wait never pays the ``pure_callback`` host
        round-trip.  Only the sim backend's *fetch* may be gated: its
        dirty write-back uses an **ordered** ``io_callback`` (not legal
        under ``cond``), and the HBM backend's fetch is an in-graph gather
        with nothing to elide.
        """
        if not (self.fused_rounds and isinstance(store, SimStorage)):
            return store.fetch_blocks(keys)
        zeros = lambda k: jnp.zeros((k.shape[0], self.block_elems),
                                    store.dtype)
        return jax.lax.cond(jnp.any(need), store.fetch_blocks, zeros, keys)

    def wait(self, st: BamState, token: IOToken
             ) -> Tuple[BamState, jax.Array]:
        """Complete a pending token: drain, fetch, fill, gather, unpin.

        Drains the SQ rings (the simulated controller retires *everything*
        pending — commands from every outstanding token, so deep submission
        windows are serviced at batched Little's-law concurrency and the
        drain's device time is charged here, to the waiter), performs the
        deferred fetch DMA for this token's lines that are still in flight,
        fills and un-flags them, gathers/applies the op's element values,
        and releases the pins taken at submit.

        Under ``defer_drain`` (the runtime's ``drain="deferred"`` mode) the
        rings are left pending for :meth:`BamRuntime.drain` and the token's
        own command histograms are charged instead, exactly like the
        pre-async deferred accounting.

        Returns ``(state', values)``: the gathered elements for a read
        token, the (masked) written values for a write token, zeros for a
        prefetch token.  A token must be redeemed exactly once: a second
        ``wait`` of the same (host) token raises ``ValueError`` instead of
        silently over-releasing its cache pins.  A zero-shaped token (from
        an empty submit) completes as a no-op.

        Thin shim over :meth:`wait_ex`, discarding the per-lane error
        mask — with the :class:`~repro.core.ssd.FaultModel` disabled (the
        default) the mask is identically False and nothing is lost.
        """
        st, vals, _ = self.wait_ex(st, token)
        return st, vals

    def wait_ex(self, st: BamState, token: IOToken
                ) -> Tuple[BamState, jax.Array, jax.Array]:
        """:meth:`wait` returning ``(state', values, error_mask)``.

        ``error_mask`` is per request lane: True where the lane's unique
        line's own fetch command retired with an error after exhausting
        the fault model's retry budget (or was routed to a hard-failed
        device).  Errored lanes read as 0 and their write payloads are
        **not** applied; the degradation contract is that a cache line is
        never filled from a failed fetch — the line is invalidated
        (un-inflighted, tag freed, never garbage-filled) and the next
        demand for that key re-fetches it.  Lanes that rode another
        token's command (cross-op coalescing) or whose command the rings
        dropped are served by this wait's own DMA and complete OK.  With
        the fault model disabled the mask is constant False and the op is
        bit-identical to the fault-free wait.
        """
        _mark_redeemed(token)
        self._check_channels(st)
        if token.ukeys.shape[0] == 0:
            # empty token: nothing was enqueued, pinned or fetched
            return st, jnp.zeros((0,), self.dtype), jnp.zeros((0,), bool)
        ctx = self.tenant_ctx
        nd = self.ssd.n_devices
        sb = self.ssd.stripe_blocks
        fault = self.ssd.fault
        fd = fault.failed_devices
        ukeys = token.ukeys
        uvalid = ukeys >= 0
        valid = token.valid
        off = token.off

        # 1) drain the rings and pick the device-time charge basis: the
        #    drained batch (plus this token's ring-rejected commands, which
        #    are still served read/write-through) — or, under deferred
        #    drain, this token's own commands.  The fused path drains with
        #    closed-form accounting (queues.drain_accounting): wait only
        #    consumes order-free reductions of the completion stream, so
        #    the WFQ arbitration sort and the per-command materialisation
        #    are skipped (BamRuntime.drain keeps service_all — it *is* the
        #    observable arbitration order).
        fstats = None                       # fault accounting for this drain
        if self.defer_drain:
            qs2 = st.queues
            reads_charge = token.dev_reads
            writes_charge = token.dev_writes
            if fault.enabled:
                # Deferred mode never drains here; account this token's
                # OWN commands from its ticket stamps (write-backs carry
                # no token ticket — their errors surface at the round
                # drain, not in per-token metrics).
                fstats = self._token_fault_stats(token)
        elif self.fused_rounds:
            qs2, dr = Q.drain_accounting(
                st.queues, impl=self.kernel_impl,
                fault=fault if fault.enabled else None)
            reads_charge = dr.reads_dev + token.drop_dev_reads
            writes_charge = dr.writes_dev + token.drop_dev_writes
            if fault.enabled:
                fstats = dict(err_reads=dr.err_reads_dev,
                              err_writes=dr.err_writes_dev,
                              retry_reads=dr.retry_reads_dev,
                              retry_writes=dr.retry_writes_dev,
                              transient=dr.transient_errors)
        else:
            qs2, comps = Q.service_all(
                st.queues, fault=fault if fault.enabled else None)
            cvalid = comps.valid
            reads_charge = device_histogram(
                comps.keys, nd, cvalid & ~comps.is_write, sb, fd) \
                + token.drop_dev_reads
            writes_charge = device_histogram(
                comps.keys, nd, cvalid & comps.is_write, sb, fd) \
                + token.drop_dev_writes
            if fault.enabled:
                fstats = dict(err_reads=comps.err_reads_dev,
                              err_writes=comps.err_writes_dev,
                              retry_reads=comps.retry_reads_dev,
                              retry_writes=comps.retry_writes_dev,
                              transient=comps.transient)

        # 2) fresh probe: lines this token submitted may since have been
        #    filled by another token's wait (cross-op coalescing), written
        #    to, or — for unpinned speculative lines — evicted.
        pr2 = C.probe(st.cache, ukeys, uvalid, tenant=ctx.tenant,
                      impl=self.kernel_impl)
        pend = pr2.hit & pr2.inflight              # resident, fill pending
        # Resolve this token's command fates from the (device, ticket)
        # stamps — the same pure function the drain accounting uses, so
        # wait and drain can never disagree about which commands failed.
        failed_u = jnp.zeros(ukeys.shape, bool)
        ok_u = jnp.ones(ukeys.shape, bool)
        if fault.enabled:
            dev_u = device_of_block(ukeys, nd, sb, fd)
            ok_u, _, _ = fault.command_status(dev_u, token.ticket)
            failed_u = uvalid & ~ok_u
        if token.kind == "prefetch":
            # only materialise lines still awaiting their speculative fill
            need = pend & ~failed_u
        else:
            # fetch everything not gatherable from the cache: still-pending
            # grants plus bypassed keys (read/write-through) — minus rows
            # whose own command errored: a failed fetch moves no data.
            need = uvalid & (~pr2.hit | pend) & ~failed_u

        # 3) the deferred fetch DMA + completion fill.  Filling only lines
        #    that are *still* in flight makes completion idempotent across
        #    tokens: whoever waits first fills; later waiters see a filled
        #    resident line and never clobber newer data with a re-fetch.
        #    Failed commands invalidate their pending line instead of
        #    filling it (never garbage-filled, never left in-flight).
        store = self._store(st)
        lines = self._fetch_gated(store, jnp.where(need, ukeys, -1), need)
        if fault.enabled:
            cache1 = C.fill_complete_status(st.cache, pr2.slot, pend, ok_u,
                                            lines)
        elif self.fused_rounds:
            cache1 = C.fill_complete(st.cache, pr2.slot, pend, lines)
        else:
            cache1 = C.fill(st.cache, pr2.slot, pend, lines)
            cache1 = C.clear_inflight(cache1,
                                      jnp.where(pend, pr2.slot, -1))
        n_fetch = jnp.sum(need.astype(jnp.int32))
        new_storage = st.storage

        # 3b) stride-readahead lines issued by this token's submit.
        if token.ra_keys is not None:
            ra = token.ra_keys
            ra_pr = C.probe(cache1, ra, ra >= 0, tenant=ctx.tenant,
                            impl=self.kernel_impl)
            ra_pend = ra_pr.hit & ra_pr.inflight
            ra_need = ra_pend
            ra_ok = jnp.ones(ra.shape, bool)
            if fault.enabled and token.ra_ticket is not None:
                dev_ra = device_of_block(ra, nd, sb, fd)
                ra_ok, _, _ = fault.command_status(dev_ra, token.ra_ticket)
                # a failed speculative fetch degrades silently: the line
                # is invalidated, no lane errors (nothing demanded it yet)
                ra_need = ra_pend & ((ra < 0) | ra_ok)
            lines_ra = self._fetch_gated(store, jnp.where(ra_need, ra, -1),
                                         ra_need)
            if fault.enabled:
                cache1 = C.fill_complete_status(cache1, ra_pr.slot, ra_pend,
                                                ra_ok, lines_ra)
            elif self.fused_rounds:
                cache1 = C.fill_complete(cache1, ra_pr.slot, ra_pend,
                                         lines_ra)
            else:
                cache1 = C.fill(cache1, ra_pr.slot, ra_pend, lines_ra)
                cache1 = C.clear_inflight(
                    cache1, jnp.where(ra_pend, ra_pr.slot, -1))
            n_fetch = n_fetch + jnp.sum(ra_need.astype(jnp.int32))

        # 4) op-specific completion.
        u = token.inverse
        # Lanes whose unique line's own command errored: they read 0, their
        # write payloads are withheld, and the caller sees them in the
        # returned error_mask.  Constant False with the fault disabled.
        err_lane = valid & failed_u[u]
        if token.kind == "read":
            # Gather the hit lanes through the kernel dispatch layer
            # (Pallas scalar-prefetch line gather on TPU — the BlockSpec
            # index map *is* the page-table walk; on the ref/XLA path the
            # `off` column keeps it an element gather, not line-wide).
            hit_u = pr2.hit[u]
            hit_vals = K.gather_blocks(
                cache1.data, jnp.where(hit_u, pr2.slot[u], -1), off=off,
                impl=self.kernel_impl)
            vals = jnp.where(hit_u, hit_vals, lines[u, off])
            vals = jnp.where(valid, vals, 0).astype(self.dtype)
            if fault.enabled:
                # errored pend rows were invalidated, not filled — the
                # stale probe still says hit, so mask their lanes to 0
                vals = jnp.where(err_lane, jnp.zeros((), self.dtype), vals)
            cache_f = cache1
        elif token.kind == "write":
            values = token.values
            assert values is not None   # write tokens carry their payload
            # scatter the new element values into resident lines...
            # (errored lanes excluded: their line was invalidated, their
            # write did not happen — no torn lines, no phantom dirty bits)
            wr_lane = valid & ~err_lane if fault.enabled else valid
            slot_r = jnp.where(pr2.hit[u], pr2.slot[u], -1)
            in_cache = slot_r >= 0
            rows = jnp.where(wr_lane & in_cache, slot_r, cache1.num_lines)
            cols = jnp.where(wr_lane & in_cache, off, 0)
            data = cache1.data.at[rows, cols].set(
                values.astype(self.dtype), mode="drop")
            cache_f = C._replace_data(cache1, data=data)
            cache_f = C.mark_dirty(cache_f,
                                   jnp.where(wr_lane & in_cache, slot_r, -1))
            # ...and write through the lines that have no slot (bypass);
            # a bypass row whose fetch errored wrote nothing (its RMW
            # background line never arrived — skipping beats corrupting
            # storage with a zero-filled line).
            byp_u = (~pr2.hit[u]) & wr_lane
            byp_rows = jnp.where(byp_u, u, lines.shape[0])
            byp_lines = lines.at[byp_rows, jnp.where(byp_u, off, 0)].set(
                values.astype(self.dtype), mode="drop")
            bt_keys = jnp.where(uvalid & ~pr2.hit & ~failed_u, ukeys, -1)
            if self.storage is None:
                new_storage = new_storage.write_blocks(bt_keys, byp_lines)
            else:
                self.storage.write_blocks(bt_keys, byp_lines)
            vals = jnp.where(valid, values, 0).astype(self.dtype)
            if fault.enabled:
                vals = jnp.where(err_lane, jnp.zeros((), self.dtype), vals)
        else:                                       # prefetch: no values
            vals = jnp.zeros(off.shape, self.dtype)
            cache_f = cache1

        # 5) release the pins taken at submit.
        cache_f = C.release(cache_f, token.pin_slots)

        # 6) completion-side metrics: bytes actually fetched + the drain's
        #    device busy time (max over channels gates the batch).
        mt = st.metrics
        tok_done = jnp.any(valid).astype(mt.requests.dtype)
        fault_kw = {}
        if fstats is not None:
            n_err = fstats["err_reads"] + fstats["err_writes"]
            n_retry = fstats["retry_reads"] + fstats["retry_writes"]
            fault_kw = dict(
                transient_errors=mt.transient_errors + fstats["transient"],
                retries=mt.retries + jnp.sum(n_retry),
                failed_commands=mt.failed_commands + jnp.sum(n_err),
                degraded_reads=mt.degraded_reads
                    + jnp.sum(err_lane.astype(jnp.int32)),
                dev_errors=mt.dev_errors + n_err,
            )
        metrics = dataclasses.replace(
            mt,
            bytes_from_storage=mt.bytes_from_storage
                + n_fetch * self.block_bytes,
            tokens_waited=mt.tokens_waited + tok_done,
            tokens_in_flight=mt.tokens_in_flight - tok_done,
            **self._charge_wait(mt, st.queues, reads_charge, writes_charge,
                                fstats=fstats),
            **fault_kw,
        )
        return BamState(cache=cache_f, queues=qs2, metrics=metrics,
                        storage=new_storage), vals, err_lane

    def _token_fault_stats(self, token: IOToken) -> dict:
        """Fault accounting from a token's OWN command tickets (deferred
        drain: the shared rings are not drained here, so the whole-batch
        receipt does not exist yet).  Read commands only — write-backs are
        not ticket-stamped on the token."""
        fault = self.ssd.fault
        nd = self.ssd.n_devices
        sb = self.ssd.stripe_blocks
        fd = fault.failed_devices
        devs = jnp.arange(nd, dtype=jnp.int32)

        def _per_dev(dev, w):
            oh = (dev[:, None] == devs[None, :]).astype(jnp.int32)
            return jnp.sum(oh * w[:, None].astype(jnp.int32),
                           axis=0).astype(jnp.int32)

        dev_u = device_of_block(token.ukeys, nd, sb, fd)
        ok_u, retry_u, trans_u = fault.command_status(dev_u, token.ticket)
        err_reads = _per_dev(dev_u, ~ok_u)
        retry_reads = _per_dev(dev_u, retry_u)
        transient = jnp.sum(trans_u).astype(jnp.int32)
        if token.ra_ticket is not None:
            dev_ra = device_of_block(token.ra_keys, nd, sb, fd)
            ra_ok, ra_retry, ra_trans = fault.command_status(
                dev_ra, token.ra_ticket)
            err_reads = err_reads + _per_dev(dev_ra, ~ra_ok)
            retry_reads = retry_reads + _per_dev(dev_ra, ra_retry)
            transient = transient + jnp.sum(ra_trans).astype(jnp.int32)
        zero = jnp.zeros((nd,), jnp.int32)
        return dict(err_reads=err_reads, err_writes=zero,
                    retry_reads=retry_reads, retry_writes=zero,
                    transient=transient)

    def _charge_wait(self, mt: IOMetrics, qs: Q.QueueState,
                     reads_hist: jax.Array, writes_hist: jax.Array,
                     fstats: dict | None = None) -> dict:
        """Device-time charge for a drain: each channel retires its share at
        its own Little's-law rate, the straggler gates the batch.

        With fault accounting (``fstats``) the retry/backoff cost lands on
        the device clocks — every re-issue is charged as
        ``tail_latency_mult`` extra commands' worth of service time on its
        device — while the data counters (``dev_reads``/``dev_writes``/
        ``dev_bytes``) count only commands that *completed*: an errored
        command burned time but moved no data.  ``fstats=None`` (fault
        disabled) is the exact pre-fault charge."""
        group_limit = qs.group_size * qs.depth
        t_reads, t_writes = reads_hist, writes_hist
        ok_reads, ok_writes = reads_hist, writes_hist
        if fstats is not None:
            mult = self.ssd.fault.tail_latency_mult
            t_reads = reads_hist + mult * fstats["retry_reads"]
            t_writes = writes_hist + mult * fstats["retry_writes"]
            ok_reads = reads_hist - fstats["err_reads"]
            ok_writes = writes_hist - fstats["err_writes"]
        t_read, t_read_dev = self.ssd.service_time_per_device_traced(
            t_reads, self.block_bytes, queue_depth_limit=group_limit)
        t_write, t_write_dev = self.ssd.service_time_per_device_traced(
            t_writes, self.block_bytes, write=True,
            queue_depth_limit=group_limit)
        return dict(
            sim_time_s=mt.sim_time_s + t_read + t_write,
            read_time_s=mt.read_time_s + t_read,
            write_time_s=mt.write_time_s + t_write,
            dev_reads=mt.dev_reads + ok_reads,
            dev_writes=mt.dev_writes + ok_writes,
            dev_bytes=mt.dev_bytes
                + (ok_reads + ok_writes) * self.block_bytes,
            dev_time_s=mt.dev_time_s + t_read_dev + t_write_dev,
        )

    # ----------------------------------------------- synchronous shims
    def read(self, st: BamState, idx: jax.Array,
             valid: jax.Array | None = None) -> Tuple[jax.Array, BamState]:
        """Gather ``self.flat[idx]`` for a wavefront of element indices.

        Compatibility shim: exactly ``submit`` + ``wait`` back to back (the
        op drains alone, paying full miss latency — use the token API to
        keep a multi-wavefront window in flight).
        """
        st, tok = self.submit(st, IORequest.read(idx, valid))
        st, vals = self.wait(st, tok)
        return vals, st

    # ------------------------------------------------------------- prefetch
    def prefetch(self, st: BamState, idx: jax.Array,
                 valid: jax.Array | None = None) -> BamState:
        """Hint the array at a future wavefront: warm the cache, no values.

        The lines covering ``idx`` are brought in through the low-priority
        readahead lane as *speculative* residents — inserted without pin, so
        a hint that never materialises is the first thing the clock hand
        reclaims.  Already-resident lines and invalid/out-of-range lanes
        cost nothing.  Works regardless of :class:`PrefetchConfig.enabled`
        (that flag only gates the automatic stride readahead in
        :meth:`read`).  Demand counters (requests/hits/misses) are untouched:
        a prefetch is not compute traffic.

        Compatibility shim over ``submit`` + ``wait``; submitting an
        ``IORequest.prefetch`` and waiting it later turns the hint into a
        genuinely asynchronous warm-up.
        """
        st, tok = self.submit(st, IORequest.prefetch(idx, valid))
        st, _ = self.wait(st, tok)
        return st

    # --------------------------------------------------------------- write
    def write(self, st: BamState, idx: jax.Array, values: jax.Array,
              valid: jax.Array | None = None) -> BamState:
        """Element-level writes: read-modify-write with write-allocate.

        Duplicate element indices within one wavefront are last-writer-wins
        with unspecified order (as on the GPU).  Compatibility shim over
        ``submit`` + ``wait``.
        """
        st, tok = self.submit(st, IORequest.write(idx, values, valid))
        st, _ = self.wait(st, tok)
        return st

    def flush(self, st: BamState) -> BamState:
        """Write back every dirty resident line (shutdown / barrier path).

        Write-backs go through the SQ rings like every other I/O: enqueue,
        doorbell, drain — so ``doorbells``/``max_queue_depth`` and the
        per-device counters see shutdown traffic exactly as they see
        ``read``/``write`` write-backs.  Lines the rings cannot hold this
        round are still persisted (the drop degrades accounting, never
        correctness — same contract as the read path's read-through).

        In a shared cache only *this tenant's* dirty lines are flushed (a
        foreign line's write-back belongs to its owner's storage tier);
        other tenants' dirty bits are left untouched.
        """
        self._check_channels(st)
        ctx = self.tenant_ctx
        nd = self.ssd.n_devices
        sb = self.ssd.stripe_blocks
        fault = self.ssd.fault
        fd = fault.failed_devices
        tags = st.cache.tags.reshape(-1)
        dirty = st.cache.dirty.reshape(-1)
        mine = st.cache.owner.reshape(-1) == jnp.int32(ctx.tenant)
        keys = jnp.where(dirty & mine & (tags >= 0), tags, -1)
        qs1, rec_w = Q.enqueue(st.queues, keys,
                               is_write=jnp.ones(keys.shape, bool),
                               tenant=ctx.tenant)
        depth_now = Q.in_flight(qs1)
        depth_dev = Q.in_flight_per_device(qs1)
        # Drain charges the clock, exactly as in wait(): the retired batch
        # may also carry outstanding tokens' commands (a flush inside a
        # submission window), whose device time lands here, on the
        # barrier; their own waits then drain an empty ring.  Ring-dropped
        # flush write-backs are still persisted, so they are charged too.
        fstats = None
        if self.defer_drain:
            qs2 = qs1
            reads_charge = jnp.zeros((nd,), jnp.int32)
            writes_charge = device_histogram(keys, nd, stripe_blocks=sb,
                                             failed_devices=fd)
        elif self.fused_rounds:
            qs2, dr = Q.drain_accounting(
                qs1, impl=self.kernel_impl,
                fault=fault if fault.enabled else None)
            reads_charge = dr.reads_dev
            writes_charge = dr.writes_dev \
                + device_histogram(keys, nd, ~rec_w.accepted, sb, fd)
            if fault.enabled:
                fstats = dict(err_reads=dr.err_reads_dev,
                              err_writes=dr.err_writes_dev,
                              retry_reads=dr.retry_reads_dev,
                              retry_writes=dr.retry_writes_dev,
                              transient=dr.transient_errors)
        else:
            qs2, comps = Q.service_all(
                qs1, fault=fault if fault.enabled else None)
            cvalid = comps.valid
            reads_charge = device_histogram(comps.keys, nd,
                                            cvalid & ~comps.is_write, sb, fd)
            writes_charge = device_histogram(comps.keys, nd,
                                             cvalid & comps.is_write,
                                             sb, fd) \
                + device_histogram(keys, nd, ~rec_w.accepted, sb, fd)
            if fault.enabled:
                fstats = dict(err_reads=comps.err_reads_dev,
                              err_writes=comps.err_writes_dev,
                              retry_reads=comps.retry_reads_dev,
                              retry_writes=comps.retry_writes_dev,
                              transient=comps.transient)
        store = self._store(st)
        new_storage = st.storage
        if self.storage is None:
            new_storage = store.write_blocks(keys, st.cache.data)
        else:
            self.storage.write_blocks(keys, st.cache.data)
        n_wb = jnp.sum((keys >= 0).astype(jnp.int32))
        flushed = (keys >= 0).reshape(st.cache.dirty.shape)
        cache = C._replace_data(st.cache, dirty=st.cache.dirty & ~flushed)
        mt = st.metrics
        fault_kw = {}
        if fstats is not None:
            # Errored flush commands are accounting-only degradation: the
            # barrier's host write persists every dirty line regardless,
            # so no data is lost — the counters record the burned attempts.
            n_err = fstats["err_reads"] + fstats["err_writes"]
            n_retry = fstats["retry_reads"] + fstats["retry_writes"]
            fault_kw = dict(
                transient_errors=mt.transient_errors + fstats["transient"],
                retries=mt.retries + jnp.sum(n_retry),
                failed_commands=mt.failed_commands + jnp.sum(n_err),
                dev_errors=mt.dev_errors + n_err,
            )
        metrics = dataclasses.replace(
            mt,
            write_ops=mt.write_ops + n_wb,
            bytes_to_storage=mt.bytes_to_storage + n_wb * self.block_bytes,
            doorbells=mt.doorbells + rec_w.n_doorbells,
            dropped=mt.dropped + rec_w.n_dropped,
            max_queue_depth=jnp.maximum(mt.max_queue_depth,
                                        depth_now.astype(jnp.int32)),
            dev_max_depth=jnp.maximum(mt.dev_max_depth,
                                      depth_dev.astype(jnp.int32)),
            **self._charge_wait(mt, st.queues, reads_charge, writes_charge,
                                fstats=fstats),
            **fault_kw,
        )
        # A flush can retire pending tokens' commands mid-window; re-check
        # the in-flight-token watermark so interleaved flush+wait sequences
        # never under-report it.
        metrics = recheck_token_watermark(metrics)
        return BamState(cache=cache, queues=qs2, metrics=metrics,
                        storage=new_storage)


@dataclasses.dataclass
class BamKVStore:
    """Key-value abstraction: device-resident open-addressed index over
    storage-resident fixed-width values (the paper's 'key-value store').

    The index (one int32 per capacity slot) is small and lives in device
    memory; the values — the massive structure — live behind a
    :class:`BamArray`.  This is exactly the split used by the framework's
    on-demand embedding feature.
    """

    array: BamArray                 # values: (capacity, value_elems) flattened
    capacity: int
    value_elems: int
    probes: int = 8

    @staticmethod
    def _hash_host(key: int, capacity: int) -> int:
        """The shared hash: Knuth multiply with a uint32 wrap, then mod.

        ``lookup`` computes the identical quantity in uint32 arithmetic
        (:meth:`_hash_traced`); the wrap must happen *before* the modulo on
        both sides or roughly half of all keys (those whose wrapped product
        lands >= 2^31) probe different slots at build vs lookup time.  No
        ``abs`` anywhere: ``abs(INT32_MIN)`` is itself negative in int32.
        """
        return ((int(key) & 0xFFFFFFFF) * 2654435761 & 0xFFFFFFFF) % capacity

    def _hash_traced(self, keys: jax.Array) -> jax.Array:
        """uint32-wrap hash of a key wavefront -> int32 slots in [0, cap)."""
        h = keys.astype(jnp.uint32) * jnp.uint32(2654435761)
        return (h % jnp.uint32(self.capacity)).astype(jnp.int32)

    @staticmethod
    def build_table(keys, values, *, capacity: int | None = None,
                    probes: int = 8):
        """Host-side open-addressing placement shared by :meth:`build` and
        the multi-tenant runtime: returns ``(table, store_vals, capacity)``
        where ``store_vals[(hash(k)+j) % capacity]`` holds key ``k``'s
        value row."""
        import numpy as np
        keys = np.asarray(keys, np.int32)
        values = np.asarray(values)
        n, value_elems = values.shape
        capacity = capacity or max(2 * n, 16)
        table = np.full((capacity,), -1, np.int32)     # key per slot
        store_vals = np.zeros((capacity, value_elems), values.dtype)
        for i, k in enumerate(keys):
            if k == -1:
                raise ValueError(
                    "key -1 is reserved as the empty-slot sentinel")
            h = BamKVStore._hash_host(k, capacity)
            # Place within lookup's probe window only: a key parked further
            # out would be silently unfindable (lookup unrolls `probes`
            # slots) — fail loudly instead.
            for j in range(min(probes, capacity)):
                s = (h + j) % capacity
                if table[s] == -1 or table[s] == k:
                    table[s] = k
                    store_vals[s] = values[i]
                    break
            else:
                raise ValueError(
                    f"kv store: key {int(k)} cannot be placed within "
                    f"probes={probes} slots of its home slot; raise "
                    "capacity or probes")
        return table, store_vals, capacity

    @staticmethod
    def build(keys, values, *, capacity: int | None = None,
              probes: int = 8, **bam_kw):
        """Host-side bulk build; returns (kv, index_table, BamState)."""
        import numpy as np
        values = np.asarray(values)
        _, value_elems = values.shape
        table, store_vals, capacity = BamKVStore.build_table(
            keys, values, capacity=capacity, probes=probes)
        bam_kw.setdefault("block_elems", value_elems)
        arr, st = BamArray.build(store_vals, **bam_kw)
        kv = BamKVStore(array=arr, capacity=capacity,
                        value_elems=value_elems, probes=probes)
        return kv, jnp.asarray(table), st

    def lookup_submit(self, st: BamState, table: jax.Array, keys: jax.Array
                      ) -> Tuple[BamState, IOToken, jax.Array]:
        """Asynchronous lookup, submission half: probe the device-resident
        index and *submit* the value gather, returning ``(state', token,
        found_mask)``.  The found mask is available immediately (the index
        is device memory); the values arrive at :meth:`lookup_wait`.
        Several lookups' tokens may be outstanding at once — duplicate hot
        keys across pending lookups coalesce onto one storage fetch.
        """
        cap = self.capacity
        h = self._hash_traced(keys)
        slot = jnp.full_like(keys, -1)
        for j in range(self.probes):                   # static unroll, small
            s = (h + j) % cap
            match = (table[s] == keys) & (slot < 0)
            slot = jnp.where(match, s, slot)
        # key -1 would "match" every empty slot (the sentinel); never found.
        found = (slot >= 0) & (keys != -1)
        base = jnp.where(found, slot, 0) * self.value_elems
        # one wavefront read per value element column (value_elems small) —
        # flatten to a single wavefront of element indices instead:
        idx = (base[:, None] + jnp.arange(self.value_elems)[None, :]).reshape(-1)
        vmask = jnp.repeat(found, self.value_elems)
        st, tok = self.array.submit(st, IORequest.read(idx, vmask))
        return st, tok, found

    def lookup_wait(self, st: BamState, token: IOToken
                    ) -> Tuple[BamState, jax.Array]:
        """Redeem a :meth:`lookup_submit` token: ``(state', values)`` with
        values shaped ``(n_keys, value_elems)``."""
        st, flat = self.array.wait(st, token)
        return st, flat.reshape(-1, self.value_elems)

    def lookup(self, st: BamState, table: jax.Array, keys: jax.Array
               ) -> Tuple[jax.Array, jax.Array, BamState]:
        """Return (values, found_mask, state') for a wavefront of keys
        (synchronous shim: ``lookup_submit`` + ``lookup_wait``)."""
        st, tok, found = self.lookup_submit(st, table, keys)
        st, vals = self.lookup_wait(st, tok)
        return vals, found, st


# ===================================================================== runtime
@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's registration against a shared :class:`BamRuntime`.

    ``ways`` is the cache way quota under ``isolation="partitioned"``
    (``None`` = equal split of the leftover ways); ``weight`` is the
    queue-arbitration service weight (see :func:`repro.core.queues
    .service_all`).
    """

    name: str
    data: Any                      # host / jnp array backing this tenant
    block_elems: int
    ways: int | None = None
    weight: float = 1.0
    prefetch: Optional[PrefetchConfig] = None


@pytree_dataclass
class RuntimeState:
    """All mutable state of a shared multi-tenant runtime.

    One cache + one queue pool serve every tenant; metrics are kept per
    tenant *and* globally.  Invariant (checked by
    :meth:`BamRuntime.assert_metrics_consistent` and the multi-tenant
    tests): every additive counter of the global ``metrics`` equals the
    sum of the tenants' counters.
    """

    cache: C.CacheState
    queues: Q.QueueState
    metrics: IOMetrics             # global accumulator
    tenant_metrics: tuple          # per-tenant IOMetrics, indexed by tid
    storages: tuple                # per-tenant in-graph storage (None for sim)


@dataclasses.dataclass
class BamRuntime:
    """The shared multi-tenant BaM runtime (paper §I: "multiple processes
    can share" the cache and queues).

    Several tenants — each a :class:`BamArray` over its own storage tier —
    run against *one* ``CacheState`` and *one* ``QueueState``:

    * cache isolation is **way-partitioning**: under
      ``isolation="partitioned"`` each tenant's clock sweep is confined to
      its contiguous way quota, so a streaming scan tenant cannot evict a
      cache-friendly neighbour's lines; ``isolation="shared"`` keeps
      today's free-for-all (tenants evict each other's clean lines) so
      the thrash is measurable;
    * queue sharing is **weighted-fair arbitration**: commands carry their
      tenant id and the simulated controller drains tenants in proportion
      to their ``TenantSpec.weight`` within each priority class, with
      back-pressure drops accounted per tenant;
    * metrics are **per tenant + global**, additive counters summing
      exactly.

    All tenants share the cache line geometry (``block_elems``) and the
    cache data dtype: lines are stored in ``cache_dtype`` and cast back to
    each tenant's dtype on read (exact for float32 tenants and for integer
    tenants whose values fit float32's 2**24 integer range).
    """

    tenants: Dict[str, BamArray]
    tenant_ids: Dict[str, int]
    isolation: str
    ways: int
    drain_mode: str = "per_op"
    _jit_ops: Dict[str, Any] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    _trace_counts: Dict[str, int] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    # ---------------------------------------------------------------- build
    @staticmethod
    def build(specs: Sequence[TenantSpec], *,
              num_sets: int, ways: int = 8,
              num_queues: int = 8, queue_depth: int = 1024,
              ssd: Optional[ArrayOfSSDs] = None,
              isolation: str = "partitioned",
              drain: str = "per_op",
              backend: str = "sim",
              cache_dtype=jnp.float32,
              kernel_impl: str = "auto",
              ) -> Tuple["BamRuntime", RuntimeState]:
        """``drain="per_op"`` (default) drains the rings inside every
        tenant op, exactly like a standalone ``BamArray``.
        ``drain="deferred"`` leaves commands pending so several tenants'
        wavefronts coexist in the shared rings; the caller then calls
        :meth:`drain` once per round and the weighted-fair arbitration
        orders the genuinely mixed completion stream.  Values are
        identical either way (fetches bypass the simulated controller);
        only when a round's commands overflow the rings does deferred
        mode drop more — accounting degrades, never correctness."""
        import numpy as np
        if isolation not in ("partitioned", "shared"):
            raise ValueError(
                f"isolation must be 'partitioned' or 'shared', "
                f"got {isolation!r}")
        if drain not in ("per_op", "deferred"):
            raise ValueError(
                f"drain must be 'per_op' or 'deferred', got {drain!r}")
        if kernel_impl not in ("auto", "pallas", "ref"):
            raise ValueError(
                f"kernel_impl must be 'auto', 'pallas' or 'ref', "
                f"got {kernel_impl!r}")
        if not specs:
            raise ValueError("need at least one TenantSpec")
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {names}")
        block_elems = specs[0].block_elems
        for s in specs:
            if s.block_elems != block_elems:
                raise ValueError(
                    "all tenants must share the cache line geometry: "
                    f"{s.name} wants block_elems={s.block_elems}, "
                    f"{specs[0].name} has {block_elems}")
        nt = len(specs)

        # Way quotas: explicit quotas are honoured, the rest equal-split.
        if isolation == "partitioned":
            fixed = sum(s.ways for s in specs if s.ways is not None)
            free = [s for s in specs if s.ways is None]
            rest = ways - fixed
            if rest < len(free) or (not free and fixed != ways):
                raise ValueError(
                    f"way quotas don't fit: ways={ways}, explicit={fixed}, "
                    f"{len(free)} tenants left to split the remainder")
            share = {id(s): rest // len(free) for s in free} if free else {}
            for s in free[:rest % len(free) if free else 0]:
                share[id(s)] += 1
            lo = 0
            windows = []
            for s in specs:
                q = s.ways if s.ways is not None else share[id(s)]
                if q < 1:
                    raise ValueError(f"tenant {s.name} got a zero way quota")
                windows.append((lo, lo + q))
                lo += q
        else:
            windows = [(0, ways)] * nt

        ssd = ssd or ArrayOfSSDs(INTEL_OPTANE_P5800X, 1)
        num_queues = round_up(num_queues, ssd.n_devices)
        weights = tuple(float(s.weight) for s in specs)

        tenants: Dict[str, BamArray] = {}
        tenant_ids: Dict[str, int] = {}
        storages = []
        for tid, s in enumerate(specs):
            lo, hi = windows[tid]
            if backend == "sim":
                store = SimStorage.from_array(np.asarray(s.data), block_elems)
                state_store, dtype = None, store.dtype
            elif backend == "hbm":
                hs = HBMStorage.from_array(jnp.asarray(s.data), block_elems)
                store, state_store, dtype = None, hs, hs.dtype
            else:
                raise ValueError(f"unknown backend {backend!r}")
            # Integer tenants round-trip through the shared cache's float
            # dtype: refuse values outside its exact-integer range (e.g.
            # 2^24 for float32) instead of silently corrupting them.
            if (np.issubdtype(np.dtype(dtype), np.integer)
                    and jnp.issubdtype(cache_dtype, jnp.floating)):
                exact = 1 << (jnp.finfo(cache_dtype).nmant + 1)
                host = np.asarray(s.data)
                peak = int(np.abs(host).max()) if host.size else 0
                if peak > exact:
                    raise ValueError(
                        f"tenant {s.name!r} holds integer values up to "
                        f"{peak}, beyond the exact-integer range "
                        f"(+/-{exact}) of the shared cache dtype "
                        f"{jnp.dtype(cache_dtype).name}; pass a wider "
                        "cache_dtype to BamRuntime.build")
            tenants[s.name] = BamArray(
                storage=store, shape=tuple(np.shape(s.data)), dtype=dtype,
                block_elems=block_elems, ssd=ssd,
                prefetch_cfg=s.prefetch or PrefetchConfig(),
                tenant_ctx=TenantCtx(tenant=tid, way_lo=lo, way_hi=hi),
                defer_drain=(drain == "deferred"),
                kernel_impl=kernel_impl)
            tenant_ids[s.name] = tid
            storages.append(state_store)

        rst = RuntimeState(
            cache=C.make_cache(num_sets, ways, block_elems, cache_dtype),
            queues=Q.make_queues(num_queues, queue_depth,
                                 n_devices=ssd.n_devices,
                                 stripe_blocks=ssd.stripe_blocks,
                                 n_tenants=nt, tenant_weights=weights,
                                 failed_devices=ssd.fault.failed_devices),
            metrics=IOMetrics.zeros(ssd.n_devices),
            tenant_metrics=tuple(IOMetrics.zeros(ssd.n_devices)
                                 for _ in specs),
            storages=tuple(storages),
        )
        return BamRuntime(tenants=tenants, tenant_ids=tenant_ids,
                          isolation=isolation, ways=ways,
                          drain_mode=drain), rst

    # ------------------------------------------------------------- plumbing
    def array(self, name: str) -> BamArray:
        """The tenant's :class:`BamArray` (its ``TenantCtx`` rides along) —
        hand it to scenario code (``BamGraph``, ``BamKVStore.lookup``)
        together with a :meth:`tenant_view`."""
        return self.tenants[name]

    def tenant_view(self, rst: RuntimeState, name: str) -> BamState:
        """Project the shared state into the per-tenant :class:`BamState`
        that ``BamArray.read``/``write``/... consume."""
        tid = self.tenant_ids[name]
        return BamState(cache=rst.cache, queues=rst.queues,
                        metrics=rst.tenant_metrics[tid],
                        storage=rst.storages[tid])

    def absorb(self, rst: RuntimeState, name: str,
               st: BamState) -> RuntimeState:
        """Fold a tenant op's updated :class:`BamState` back into the
        shared runtime state: cache/queues replace (they are shared), the
        tenant's metrics delta also accumulates into the global view."""
        tid = self.tenant_ids[name]
        delta = metrics_delta(st.metrics, rst.tenant_metrics[tid])
        tm = list(rst.tenant_metrics)
        tm[tid] = st.metrics
        stores = list(rst.storages)
        stores[tid] = st.storage
        # The global in-flight window is the SUM of the tenants' windows,
        # but accumulate only maxes the per-tenant watermarks — two tenants
        # each holding one token would report a global watermark of 1.
        # Re-check against the summed window after every fold.
        metrics = recheck_token_watermark(
            metrics_accumulate(rst.metrics, delta))
        return RuntimeState(
            cache=st.cache, queues=st.queues, metrics=metrics,
            tenant_metrics=tuple(tm), storages=tuple(stores))

    # ------------------------------------------------------------------ ops
    def read(self, rst: RuntimeState, name: str, idx: jax.Array,
             valid: jax.Array | None = None
             ) -> Tuple[jax.Array, RuntimeState]:
        vals, st = self.tenants[name].read(self.tenant_view(rst, name),
                                           idx, valid)
        return vals, self.absorb(rst, name, st)

    def _jit_op(self, key: str, make, donate_argnums=()):
        """Per-(op, tenant) jit cache — see :func:`_cached_jit`."""
        return _cached_jit(self._jit_ops, self._trace_counts, key, make,
                           donate_argnums=donate_argnums)

    @property
    def trace_counts(self) -> Dict[str, int]:
        return dict(self._trace_counts)

    def read_jit(self, name: str):
        """A cached ``jax.jit`` of ``lambda rst, idx: self.read(rst, name,
        idx)`` — one compilation per (tenant, shape) however often callers
        grab it (streaming drivers call this every wavefront)."""
        return self._jit_op(
            f"read:{name}",
            lambda: lambda rst, idx: self.read(rst, name, idx))

    def write_jit(self, name: str):
        return self._jit_op(
            f"write:{name}",
            lambda: lambda rst, idx, values: self.write(rst, name, idx,
                                                        values))

    def submit_jit(self, name: str, *, donate: bool = False):
        """Cached jit of :meth:`submit` for one tenant ``(rst, req) ->
        (rst, token)``.  ``donate=True`` donates the shared state's
        buffers to the output (separate cache key; see
        :meth:`BamArray.submit_jit` for the reuse contract)."""
        key = f"submit:{name}" + ("[donated]" if donate else "")
        return self._jit_op(
            key, lambda: lambda rst, req: self.submit(rst, name, req),
            donate_argnums=(0,) if donate else ())

    def wait_jit(self, name: str, *, donate: bool = False,
                 guard: bool = True):
        """Cached jit of :meth:`wait` for one tenant ``(rst, token) ->
        (rst, values)``.  ``donate``/``guard`` as in
        :meth:`BamArray.wait_jit`."""
        key = f"wait:{name}" + ("[donated]" if donate else "")
        fn = self._jit_op(
            key, lambda: lambda rst, tok: self.wait(rst, name, tok),
            donate_argnums=(0,) if donate else ())
        if not guard:
            return fn
        wkey = key + "#guard"
        w = self._jit_ops.get(wkey)
        if w is None:
            def guarded(rst, tok, _fn=fn):
                _mark_redeemed(tok)
                return _fn(rst, tok)

            self._jit_ops[wkey] = w = guarded
        return w

    def iter_op_family(self):
        """Enumerate the runtime's per-tenant jit-cached op family (see
        :class:`OpFamilyEntry` and :meth:`BamArray.iter_op_family`) —
        the registry hook ``tools/bamverify`` lowers for multi-tenant
        artifacts.  One entry per (op, tenant); ``example_args`` take the
        shared :class:`RuntimeState`."""
        for name in self.tenants:
            arr = self.tenants[name]

            def args_read(rst, n, _arr=arr):
                idx, valid = _arr._example_wavefront(n)
                return (rst, idx)

            def args_req(rst, n, _arr=arr):
                idx, valid = _arr._example_wavefront(n)
                return (rst, IORequest.read(idx, valid))

            def args_token(rst, n, _name=name, _arr=arr):
                idx, valid = _arr._example_wavefront(n)
                rst1, tok = self.submit(rst, _name,
                                        IORequest.read(idx, valid))
                return (rst1, tok)

            yield OpFamilyEntry(
                name=f"read:{name}",
                get=lambda donate=False, _n=name: self.read_jit(_n),
                example_args=args_read, trace_keys=(f"read:{name}",))
            yield OpFamilyEntry(
                name=f"submit:{name}", donatable=True,
                get=lambda donate=False, _n=name:
                    self.submit_jit(_n, donate=donate),
                example_args=args_req, trace_keys=(f"submit:{name}",))
            yield OpFamilyEntry(
                name=f"wait:{name}", donatable=True, pure_all_hit=True,
                get=lambda donate=False, _n=name:
                    self.wait_jit(_n, donate=donate, guard=False),
                example_args=args_token, trace_keys=(f"wait:{name}",))

    def write(self, rst: RuntimeState, name: str, idx: jax.Array,
              values: jax.Array, valid: jax.Array | None = None
              ) -> RuntimeState:
        st = self.tenants[name].write(self.tenant_view(rst, name),
                                      idx, values, valid)
        return self.absorb(rst, name, st)

    def submit(self, rst: RuntimeState, name: str, req: IORequest
               ) -> Tuple[RuntimeState, IOToken]:
        """Asynchronously submit one tenant's op against the shared state.

        Tokens from different tenants freely interleave: their commands
        coexist in the shared rings and their pins/in-flight lines in the
        shared cache.  Under ``drain="deferred"`` the per-token wait leaves
        the rings pending and :meth:`drain` retires the WFQ-ordered mixed
        stream, exactly as with the synchronous ops.
        """
        st, tok = self.tenants[name].submit(self.tenant_view(rst, name), req)
        return self.absorb(rst, name, st), tok

    def wait(self, rst: RuntimeState, name: str, token: IOToken
             ) -> Tuple[RuntimeState, jax.Array]:
        """Complete one tenant's pending token (see :meth:`BamArray.wait`).
        ``name`` must be the tenant that submitted the token."""
        st, vals = self.tenants[name].wait(self.tenant_view(rst, name),
                                           token)
        return self.absorb(rst, name, st), vals

    def wait_ex(self, rst: RuntimeState, name: str, token: IOToken
                ) -> Tuple[RuntimeState, jax.Array, jax.Array]:
        """:meth:`wait` returning the per-lane ``error_mask`` as well (see
        :meth:`BamArray.wait_ex`).  The errored token's fault counters
        land in the tenant's own :class:`IOMetrics` first and flow into
        the global view through :meth:`absorb`'s delta accumulation, so
        per-tenant error counters keep summing exactly to the global
        ones."""
        st, vals, err = self.tenants[name].wait_ex(
            self.tenant_view(rst, name), token)
        return self.absorb(rst, name, st), vals, err

    def prefetch(self, rst: RuntimeState, name: str, idx: jax.Array,
                 valid: jax.Array | None = None) -> RuntimeState:
        st = self.tenants[name].prefetch(self.tenant_view(rst, name),
                                         idx, valid)
        return self.absorb(rst, name, st)

    def flush(self, rst: RuntimeState,
              name: str | None = None) -> RuntimeState:
        """Flush one tenant's dirty lines, or every tenant's (name=None)."""
        names = [name] if name is not None else list(self.tenants)
        for n in names:
            st = self.tenants[n].flush(self.tenant_view(rst, n))
            rst = self.absorb(rst, n, st)
        return rst

    def drain(self, rst: RuntimeState
              ) -> Tuple[RuntimeState, Q.Completions]:
        """Drain the shared rings once (the ``drain="deferred"`` round
        barrier).  The returned :class:`~repro.core.queues.Completions`
        stream is priority-major and weighted-fair across tenants — the
        observable arbitration order.  A no-op on already-empty rings
        (per-op mode), so callers may drain unconditionally.

        The completion stream carries per-command ``status`` codes when
        the tenants' shared :class:`~repro.core.ssd.FaultModel` is
        enabled (all tenants share one ``ArrayOfSSDs``, so any tenant's
        model is *the* model)."""
        fault = next(iter(self.tenants.values())).ssd.fault
        qs, comps = Q.service_all(
            rst.queues, fault=fault if fault.enabled else None)
        return RuntimeState(cache=rst.cache, queues=qs,
                            metrics=rst.metrics,
                            tenant_metrics=rst.tenant_metrics,
                            storages=rst.storages), comps

    # -------------------------------------------------------------- metrics
    def tenant_summary(self, rst: RuntimeState, name: str) -> dict:
        return rst.tenant_metrics[self.tenant_ids[name]].summary()

    def assert_metrics_consistent(self, rst: RuntimeState,
                                  time_rtol: float = 1e-4) -> None:
        """The tentpole invariant: per-tenant metrics sum to the global
        counters — exactly for the integer-valued counters, to ``time_rtol``
        for the float time accumulators (summation order differs)."""
        import numpy as np
        from repro.core.metrics import ADDITIVE_FIELDS
        total = metrics_sum(rst.tenant_metrics)
        for f in ADDITIVE_FIELDS:
            a = np.asarray(jax.device_get(getattr(total, f)), np.float64)
            b = np.asarray(jax.device_get(getattr(rst.metrics, f)),
                           np.float64)
            if f.endswith("_time_s"):
                ok = np.allclose(a, b, rtol=time_rtol, atol=1e-12)
            else:
                ok = np.array_equal(a, b)
            if not ok:
                raise AssertionError(
                    f"tenant metrics do not sum to global for {f}: "
                    f"sum(tenants)={a}, global={b}")
