"""BaM software cache (§III-D) — set-associative, clock replacement, functional.

The paper's cache is a GPU-resident, lock-minimal cache keyed by block
offset: per-line atomic state + reference counts; a global clock hand picks
victims; line locks prevent duplicate fetches of the same line.

TPU adaptation.  The unit of concurrency is the wavefront, and the BaM
coalescer (``core/coalescer.py``) runs *before* the cache, so by construction
at most one requester per line reaches the cache — the paper's per-line lock
becomes a static guarantee.  All state transitions are vectorized scatters
over a functional :class:`CacheState`:

* probe      — hash(key) -> set, compare the set's ``ways`` tags at once;
* allocate   — per-set clock sweep; concurrent misses that collide on a set
  are rank-ordered with a segmented prefix-sum so each takes a distinct way
  (or bypasses the cache when the set has no evictable way — the paper's
  "thread moves on and retries" becomes read-through-without-insert);
* fill       — scatter fetched lines into the data array;
* refcounts  — ``acquire``/``release`` pin lines against eviction, and a
  transient ``protect`` overlay guards this wavefront's hits.

The clock hand is per-set (a sharded fine-grain analogue of the paper's
single global counter — same policy, no cross-set serialization).

Prefetch support (``core/prefetch.py``): lines filled by readahead carry a
``speculative`` bit.  The victim sweep orders each set's ways *invalid
first, speculative second, demand-resident last* (within each class, clock
order), so a wrong prefetch is reclaimed before any demand line is touched
— speculative fills are "insert without pin".  A demand hit on a
speculative line *promotes* it (clears the bit): from then on it is an
ordinary resident line.

Async submission support (``BamArray.submit``/``wait``): a line whose tag
has been claimed but whose DMA has not completed carries an ``inflight``
bit — the vectorized analogue of the paper's per-line lock held between
command submission and completion.  A later submission that probes the
same key *hits* the in-flight line (cross-op coalescing: the duplicate
fetch is suppressed before it ever touches the SQ rings) and whichever
token completes first performs the fill and clears the bit.  Reference
counts now hold across the whole submit→wait span: every line a pending
token touched (hit or newly granted) stays pinned until that token is
waited, so interleaved tokens can never evict each other's in-flight data.

Multi-tenant support (``BamRuntime``): several BaM arrays can share one
``CacheState``.  Every resident line records its ``owner`` tenant, and
``probe``/``allocate`` take a ``tenant`` id so block key *k* of tenant A
never aliases block *k* of tenant B (the tag match requires the owner to
match too).  Isolation is *way-partitioning*: ``allocate(way_lo, way_hi)``
confines a tenant's clock sweep to its contiguous way quota, so a
streaming tenant can never evict a partitioned neighbour's lines.  With
the full way range (the default, and the runtime's ``isolation="shared"``
mode) tenants compete for every way exactly as a single tenant does today
— except that *foreign dirty* lines are never victimised: a write-back
must go to the evictor's own storage tier, so evicting another tenant's
dirty line would corrupt it.  Clean foreign lines are fair game (they are
re-fetchable from their owner's storage).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as _ops
from repro.utils import mix_hash, pytree_dataclass, segment_rank

__all__ = [
    "CacheState", "make_cache", "probe", "allocate", "probe_allocate",
    "fill", "acquire", "release", "pin_keys", "mark_dirty", "promote",
    "mark_inflight", "clear_inflight", "grant_bookkeeping",
    "fill_complete",
]


@pytree_dataclass(meta_fields=("num_sets", "ways", "line_elems"))
class CacheState:
    num_sets: int
    ways: int
    line_elems: int
    tags: jax.Array        # (num_sets, ways) int32 block key, -1 invalid
    owner: jax.Array       # (num_sets, ways) int32 tenant id of the line
    refcount: jax.Array    # (num_sets, ways) int32 — pinned lines have >0
    dirty: jax.Array       # (num_sets, ways) bool — needs write-back on evict
    speculative: jax.Array  # (num_sets, ways) bool — prefetched, evict-first
    inflight: jax.Array    # (num_sets, ways) bool — tag claimed, fill pending
    clock_hand: jax.Array  # (num_sets,) int32 in [0, ways)
    data: jax.Array        # (num_sets*ways, line_elems)
    hits: jax.Array        # () int32 cumulative line hits (post-coalesce)
    misses: jax.Array      # () int32 cumulative line misses
    bypasses: jax.Array    # () int32 misses that could not be inserted

    @property
    def num_lines(self) -> int:
        return self.num_sets * self.ways


def make_cache(num_sets: int, ways: int, line_elems: int,
               dtype=jnp.float32) -> CacheState:
    z = lambda: jnp.zeros((), jnp.int32)
    return CacheState(
        num_sets=num_sets, ways=ways, line_elems=line_elems,
        tags=jnp.full((num_sets, ways), -1, jnp.int32),
        owner=jnp.zeros((num_sets, ways), jnp.int32),
        refcount=jnp.zeros((num_sets, ways), jnp.int32),
        dirty=jnp.zeros((num_sets, ways), bool),
        speculative=jnp.zeros((num_sets, ways), bool),
        inflight=jnp.zeros((num_sets, ways), bool),
        clock_hand=jnp.zeros((num_sets,), jnp.int32),
        data=jnp.zeros((num_sets * ways, line_elems), dtype),
        hits=z(), misses=z(), bypasses=z(),
    )


def _set_of(cache: CacheState, keys: jax.Array) -> jax.Array:
    return mix_hash(keys) % cache.num_sets


@pytree_dataclass
class ProbeResult:
    hit: jax.Array    # (m,) bool
    slot: jax.Array   # (m,) int32 flat line slot (set*ways+way); -1 on miss
    set_idx: jax.Array  # (m,) int32 (reused by allocate)
    speculative: jax.Array  # (m,) bool — hit landed on a prefetched line
    inflight: jax.Array  # (m,) bool — hit landed on a not-yet-filled line


def probe(cache: CacheState, keys: jax.Array,
          valid: jax.Array | None = None, tenant: int = 0,
          impl: str = "auto") -> ProbeResult:
    """Vectorized set-associative lookup for a wavefront of (unique) keys.

    ``tenant`` namespaces the tag match: a line counts as a hit only when
    its owner matches, so shared-cache tenants with overlapping key spaces
    never read each other's lines.  Single-tenant callers keep the default
    (every line is owned by tenant 0).

    The tag compare is dispatched through :mod:`repro.kernels.ops`
    (``impl="auto"``: the Pallas one-hot-matmul probe on TPU, the
    bit-identical jnp oracle as an XLA graph elsewhere).
    """
    if valid is None:
        valid = keys >= 0
    sets = _set_of(cache, keys)                         # (m,)
    hit, slot = _ops.cache_probe(cache.tags, jnp.where(valid, keys, -1),
                                 owner=cache.owner, tenant=tenant, impl=impl)
    safe = jnp.where(hit, slot, 0)
    spec = hit & cache.speculative.reshape(-1)[safe]
    infl = hit & cache.inflight.reshape(-1)[safe]
    return ProbeResult(hit=hit, slot=slot, set_idx=sets.astype(jnp.int32),
                       speculative=spec, inflight=infl)


_segment_rank = segment_rank


def _apply_grants(cache: CacheState, keys: jax.Array, sets: jax.Array,
                  way: jax.Array, ok: jax.Array, n_valid: jax.Array,
                  speculative: bool, tenant: int) -> CacheState:
    """Commit a wavefront of victim grants: scatter the claimed tags and
    flags, advance each touched set's clock hand past the granted way,
    bump the miss/bypass counters.

    The single copy of the grant-commit block shared by :func:`allocate`
    and :func:`probe_allocate` — the two paths stay bit-identical by
    construction.  Rows with ``ok=False`` scatter out of bounds and drop;
    granted ``(set, way)`` pairs are distinct per wavefront by the rank
    disambiguation, so scatter order cannot matter.
    """
    ways = cache.ways

    def _commit():
        s_i = jnp.where(ok, sets, cache.num_sets)
        w_i = jnp.where(ok, way, 0)
        tags = cache.tags.at[s_i, w_i].set(keys, mode="drop")
        owner = cache.owner.at[s_i, w_i].set(jnp.int32(tenant), mode="drop")
        dirty = cache.dirty.at[s_i, w_i].set(False, mode="drop")
        spec = cache.speculative.at[s_i, w_i].set(speculative, mode="drop")
        # A granted line starts life *filled from the grantor's
        # perspective*: the async submit path re-marks it in flight right
        # after allocation.
        infl = cache.inflight.at[s_i, w_i].set(False, mode="drop")

        # Advance each touched set's hand past the granted way's clock
        # position (the victim select may run in class-sorted order, so the
        # position is recovered from the way index, not the sweep
        # position).
        hand = cache.clock_hand[sets]
        clock_pos = (way - hand) % ways
        adv = jnp.zeros((cache.num_sets,), jnp.int32).at[s_i].max(
            clock_pos + 1, mode="drop")
        return (tags, owner, dirty, spec, infl,
                (cache.clock_hand + adv) % ways)

    def _no_grants():
        return (cache.tags, cache.owner, cache.dirty, cache.speculative,
                cache.inflight, cache.clock_hand)

    # Hit fast path: a wavefront with no grants drops every update, so the
    # directory passes through bit-identical — skip the commit scatters.
    tags, owner, dirty, spec, infl, clock_hand = jax.lax.cond(
        jnp.any(ok), _commit, _no_grants)

    n_ok = jnp.sum(ok.astype(jnp.int32))
    # Speculative fills are not demand traffic: keep the miss/bypass
    # counters (the hit-rate denominators) demand-only.
    miss_inc = jnp.int32(0) if speculative else n_valid
    byp_inc = jnp.int32(0) if speculative else n_valid - n_ok
    return _replace_data(
        cache, tags=tags, owner=owner, dirty=dirty, speculative=spec,
        inflight=infl, clock_hand=clock_hand,
        misses=cache.misses + miss_inc,
        bypasses=cache.bypasses + byp_inc)


@pytree_dataclass
class AllocResult:
    slot: jax.Array          # (m,) int32 flat slot granted; -1 if bypassed/invalid
    ok: jax.Array            # (m,) bool — inserted into the cache
    evicted_key: jax.Array   # (m,) int32 key previously in the slot (-1 none)
    evicted_dirty: jax.Array  # (m,) bool — evicted line needs write-back


def allocate(cache: CacheState, keys: jax.Array,
             valid: jax.Array,
             protect_slots: jax.Array | None = None,
             speculative: bool = False,
             tenant: int = 0,
             way_lo: int = 0,
             way_hi: int | None = None,
             ) -> Tuple[CacheState, AllocResult]:
    """Grant a victim slot per missed key (clock sweep, rank-disambiguated).

    ``protect_slots`` is a wavefront-transient list of flat slots that must
    not be evicted (this round's hits); pass the probe hits' slots.

    ``speculative=True`` marks the granted lines as prefetched: they are
    inserted without pin and become the sweep's preferred victims until a
    demand hit :func:`promote`\\ s them.  Speculative allocations also never
    cannibalize a *pending* (unpromoted) prefetched line — they take free
    ways or retire old demand lines, and when a set offers neither the hint
    is simply dropped (``ok=False``, nothing fetched).  Without this rule a
    deep readahead window evicts its own not-yet-consumed predictions under
    set conflicts and turns into pure I/O waste.

    Multi-tenant knobs (all static): granted lines are stamped with
    ``tenant``; ``[way_lo, way_hi)`` confines the victim sweep to that way
    window (the runtime's way-partitioning — a partitioned tenant can only
    ever evict lines inside its own quota).  Whatever the window, a line
    that is *dirty and owned by another tenant* is never victimised: its
    write-back would be routed to the wrong storage tier.  The defaults
    (tenant 0, full way range) are byte-for-byte today's single-tenant
    behaviour.
    """
    m = keys.shape[0]
    ways = cache.ways
    way_hi = ways if way_hi is None else way_hi
    if not (0 <= way_lo < way_hi <= ways):
        raise ValueError(
            f"way window [{way_lo}, {way_hi}) invalid for ways={ways}")
    sets = _set_of(cache, keys)

    # Eviction eligibility per line: not referenced, not protected this
    # round, not another tenant's dirty data, inside the caller's way quota.
    elig_line = (cache.refcount == 0).reshape(-1)
    foreign_dirty = (cache.owner != jnp.int32(tenant)) \
        & (cache.tags >= 0) & cache.dirty
    elig_line = elig_line & ~foreign_dirty.reshape(-1)
    if way_lo != 0 or way_hi != ways:
        in_window = (jnp.arange(ways, dtype=jnp.int32) >= way_lo) \
            & (jnp.arange(ways, dtype=jnp.int32) < way_hi)
        elig_line = elig_line & jnp.broadcast_to(
            in_window[None, :], (cache.num_sets, ways)).reshape(-1)
    if speculative:
        pending = (cache.speculative & (cache.tags >= 0)).reshape(-1)
        elig_line = elig_line & ~pending
    if protect_slots is not None:
        psafe = jnp.where(protect_slots >= 0, protect_slots,
                          cache.num_lines)           # OOB -> dropped
        overlay = jnp.zeros((cache.num_lines,), bool).at[psafe].set(
            True, mode="drop")
        elig_line = elig_line & ~overlay
    elig = elig_line.reshape(cache.num_sets, ways)

    rank = _segment_rank(sets, valid)                   # (m,)
    hand = cache.clock_hand[sets]                       # (m,)
    way_order = (hand[:, None] + jnp.arange(ways, dtype=jnp.int32)[None, :]) % ways
    # Victim class per way: 0 = invalid (free), 1 = speculative (prefetched,
    # unpromoted), 2 = demand-resident.  A stable sort of the clock-rotated
    # sweep by class keeps clock order within each class while guaranteeing
    # prefetched lines are reclaimed before any demand line is touched.
    # With no speculative lines this coincides with the plain clock sweep:
    # tags are only ever invalid before first use and sets fill in clock
    # order, so the invalid ways are exactly the suffix the hand points at
    # — the demand-only path is unchanged from the paper's policy.
    vclass = jnp.where(cache.tags < 0, 0,
                       jnp.where(cache.speculative, 1, 2)).astype(jnp.int32)
    class_rot = vclass[sets[:, None], way_order]        # (m, ways)
    pref = jnp.argsort(class_rot, axis=1, stable=True)  # (m, ways)
    way_order = jnp.take_along_axis(way_order, pref, axis=1)
    elig_rot = elig[sets[:, None], way_order]           # (m, ways) in sweep order
    csum = jnp.cumsum(elig_rot.astype(jnp.int32), axis=1)
    want = (rank + 1)[:, None]
    sel = elig_rot & (csum == want)                     # first way with cum count == rank+1
    ok = valid & (csum[:, -1] >= rank + 1)
    way_pos = jnp.argmax(sel, axis=1).astype(jnp.int32)
    way = way_order[jnp.arange(m), way_pos]
    slot = (sets * ways + way).astype(jnp.int32)

    evicted_key = jnp.where(ok, cache.tags[sets, way], -1).astype(jnp.int32)
    evicted_dirty = jnp.where(ok, cache.dirty[sets, way], False)

    cache2 = _apply_grants(cache, keys, sets, way, ok,
                           jnp.sum(valid.astype(jnp.int32)),
                           speculative, tenant)
    return cache2, AllocResult(
        slot=jnp.where(ok, slot, -1), ok=ok,
        evicted_key=evicted_key, evicted_dirty=evicted_dirty)


def probe_allocate(cache: CacheState, keys: jax.Array,
                   valid: jax.Array | None = None, *,
                   alloc_mask: jax.Array | None = None,
                   protect_slots: jax.Array | None = None,
                   protect_hits: bool = True,
                   speculative: bool = False,
                   tenant: int = 0,
                   way_lo: int = 0,
                   way_hi: int | None = None,
                   impl: str = "auto",
                   ) -> Tuple[CacheState, ProbeResult, AllocResult]:
    """Fused :func:`probe` + :func:`allocate` — the submission hot path.

    One kernel pass (:func:`repro.kernels.ops.probe_allocate`) performs
    the tag probe and, for the misses, the class-then-clock victim select
    — argsort-free, honouring exactly what the two-step path honours:
    pinned lines, foreign dirty lines, the ``[way_lo, way_hi)`` tenant
    way window, pending speculative lines under ``speculative=True``,
    this wavefront's own hits (``protect_hits=True``, the fused
    equivalent of passing the probe's slots as ``protect_slots``) and any
    extra ``protect_slots``.  ``alloc_mask`` further restricts which
    misses may allocate (the readahead path's "never re-fetch a line this
    wavefront just evicted" rule).

    The scatters (tag claim, owner stamp, clock-hand advance, miss/bypass
    counters) are identical to :func:`allocate`'s; results are
    bit-identical to ``probe`` + ``allocate`` with
    ``protect_slots=probe.slot`` — the oracle tests assert it.
    """
    m = keys.shape[0]
    ways = cache.ways
    way_hi = ways if way_hi is None else way_hi
    if not (0 <= way_lo < way_hi <= ways):
        raise ValueError(
            f"way window [{way_lo}, {way_hi}) invalid for ways={ways}")
    if valid is None:
        valid = keys >= 0
    sets = _set_of(cache, keys)

    hit, hslot, way, ok, evicted_key, evicted_dirty = _ops.probe_allocate(
        cache.tags, cache.owner, cache.refcount, cache.dirty,
        cache.speculative, cache.clock_hand, keys, valid=valid,
        alloc_mask=alloc_mask, protect_slots=protect_slots, tenant=tenant,
        way_lo=way_lo, way_hi=way_hi, spec_insert=speculative,
        protect_hits=protect_hits, impl=impl)

    safe = jnp.where(hit, hslot, 0)
    pr = ProbeResult(
        hit=hit, slot=hslot, set_idx=sets.astype(jnp.int32),
        speculative=hit & cache.speculative.reshape(-1)[safe],
        inflight=hit & cache.inflight.reshape(-1)[safe])

    slot = (sets * ways + jnp.where(ok, way, 0)).astype(jnp.int32)

    miss = valid & ~hit
    if alloc_mask is not None:
        miss = miss & alloc_mask
    cache2 = _apply_grants(cache, keys, sets, way, ok,
                           jnp.sum(miss.astype(jnp.int32)),
                           speculative, tenant)
    return cache2, pr, AllocResult(
        slot=jnp.where(ok, slot, -1), ok=ok,
        evicted_key=evicted_key, evicted_dirty=evicted_dirty)


def fill(cache: CacheState, slots: jax.Array, ok: jax.Array,
         lines: jax.Array) -> CacheState:
    """DMA-completion analogue: scatter fetched lines into granted slots.

    A completion wave with nothing pending (every lane already resident —
    the warm-cache steady state) drops every update, so the line store
    passes through bit-identical and the full-width data scatter is
    skipped."""
    def _commit():
        idx = jnp.where(ok, slots, cache.num_lines)      # OOB -> dropped
        return cache.data.at[idx].set(lines.astype(cache.data.dtype),
                                      mode="drop")
    data = jax.lax.cond(jnp.any(ok), _commit, lambda: cache.data)
    return _replace_data(cache, data=data)


def count_hits(cache: CacheState, n_hits: jax.Array) -> CacheState:
    return _replace_data(cache, hits=cache.hits + n_hits)


def acquire(cache: CacheState, slots: jax.Array) -> CacheState:
    """refcount++ on the given flat slots (slot<0 ignored)."""
    ok = slots >= 0
    idx = jnp.where(ok, slots, 0)
    rc = cache.refcount.reshape(-1).at[idx].add(ok.astype(jnp.int32))
    return _replace_data(cache, refcount=rc.reshape(cache.num_sets, cache.ways))


def release(cache: CacheState, slots: jax.Array) -> CacheState:
    ok = slots >= 0
    idx = jnp.where(ok, slots, 0)
    rc = cache.refcount.reshape(-1).at[idx].add(-ok.astype(jnp.int32))
    rc = jnp.maximum(rc, 0)
    return _replace_data(cache, refcount=rc.reshape(cache.num_sets, cache.ways))


def pin_keys(cache: CacheState, keys: jax.Array,
             tenant: int = 0) -> CacheState:
    """User-directed residency control (paper: 'fine-grain control of cache
    residency'): pin resident lines for the given keys."""
    pr = probe(cache, keys, tenant=tenant)
    return acquire(cache, pr.slot)


def promote(cache: CacheState, slots: jax.Array) -> CacheState:
    """Clear the speculative bit on the given flat slots (slot<0 ignored).

    Called when a demand access hits a prefetched line: from then on the
    line competes for residency like any other demand line.
    """
    ok = slots >= 0
    idx = jnp.where(ok, slots, cache.num_lines)          # OOB -> dropped
    s = cache.speculative.reshape(-1)
    s = s.at[idx].set(False, mode="drop")
    return _replace_data(cache,
                         speculative=s.reshape(cache.num_sets, cache.ways))


def mark_inflight(cache: CacheState, slots: jax.Array) -> CacheState:
    """Mark the given flat slots as *in flight* (slot<0 ignored).

    An in-flight line has its tag claimed (so concurrent submissions
    coalesce against it — the paper's per-line lock / BaM's duplicate-fetch
    suppression) but its data not yet DMA'd.  The token that fills the line
    (or any waiter that finds it still pending) clears the bit; readers
    must never gather from a line whose in-flight bit is set.
    """
    ok = slots >= 0
    idx = jnp.where(ok, slots, cache.num_lines)          # OOB -> dropped
    s = cache.inflight.reshape(-1)
    s = s.at[idx].set(True, mode="drop")
    return _replace_data(cache,
                         inflight=s.reshape(cache.num_sets, cache.ways))


def clear_inflight(cache: CacheState, slots: jax.Array) -> CacheState:
    """Clear the in-flight bit on the given flat slots (slot<0 ignored)."""
    ok = slots >= 0
    idx = jnp.where(ok, slots, cache.num_lines)          # OOB -> dropped
    s = cache.inflight.reshape(-1)
    s = s.at[idx].set(False, mode="drop")
    return _replace_data(cache,
                         inflight=s.reshape(cache.num_sets, cache.ways))


def grant_bookkeeping(cache: CacheState, n_hits: jax.Array,
                      promote_slots: jax.Array, pin_slots: jax.Array,
                      inflight_slots: jax.Array) -> CacheState:
    """Fused submission-side bookkeeping: :func:`count_hits` +
    :func:`promote` + :func:`acquire` + :func:`mark_inflight` in ONE
    :class:`CacheState` construction.

    The four steps touch disjoint fields (``hits``, ``speculative``,
    ``refcount``, ``inflight``), so the fusion is bit-identical to the
    sequential helpers in any order — this is the traced-submit hot path
    trimming three full pytree rebuilds per wavefront.
    """
    ok_p = promote_slots >= 0
    spec = cache.speculative.reshape(-1).at[
        jnp.where(ok_p, promote_slots, cache.num_lines)].set(
        False, mode="drop")
    ok_a = pin_slots >= 0
    rc = cache.refcount.reshape(-1).at[
        jnp.where(ok_a, pin_slots, 0)].add(ok_a.astype(jnp.int32))
    ok_i = inflight_slots >= 0
    infl = cache.inflight.reshape(-1).at[
        jnp.where(ok_i, inflight_slots, cache.num_lines)].set(
        True, mode="drop")
    shape2 = (cache.num_sets, cache.ways)
    return _replace_data(
        cache, hits=cache.hits + n_hits,
        speculative=spec.reshape(shape2), refcount=rc.reshape(shape2),
        inflight=infl.reshape(shape2))


def fill_complete(cache: CacheState, slots: jax.Array, ok: jax.Array,
                  lines: jax.Array) -> CacheState:
    """Fused completion: :func:`fill` + :func:`clear_inflight` on the same
    slots in ONE :class:`CacheState` construction (``data`` and
    ``inflight`` are disjoint fields — bit-identical to the pair).

    Gated like :func:`fill`: a wait with nothing pending (warm-cache
    steady state) skips the full-width data scatter entirely."""
    def _commit():
        idx = jnp.where(ok, slots, cache.num_lines)      # OOB -> dropped
        data = cache.data.at[idx].set(lines.astype(cache.data.dtype),
                                      mode="drop")
        infl = cache.inflight.reshape(-1).at[idx].set(False, mode="drop")
        return data, infl.reshape(cache.num_sets, cache.ways)

    data, infl = jax.lax.cond(
        jnp.any(ok), _commit, lambda: (cache.data, cache.inflight))
    return _replace_data(cache, data=data, inflight=infl)


def invalidate_failed(cache: CacheState, slots: jax.Array,
                      mask: jax.Array) -> CacheState:
    """Graceful degradation: evict granted-but-never-filled lines whose
    fetch command errored out past its retry budget.

    The line's tag is freed (so a later access re-allocates and re-fetches
    it), its in-flight and speculative bits clear, and its dirty bit
    clears — the data store is **not** touched: a line is never filled
    from a failed fetch, and a freed tag can never be gathered.  Pins are
    left to the normal :func:`release` pairing (a still-pinned invalid
    slot cannot be re-allocated — victim eligibility requires
    ``refcount == 0`` — so riders holding the pin stay safe and fall back
    to read-through).
    """
    live = mask & (slots >= 0)
    idx = jnp.where(live, slots, cache.num_lines)        # OOB -> dropped
    shape2 = (cache.num_sets, cache.ways)
    tags = cache.tags.reshape(-1).at[idx].set(-1, mode="drop")
    infl = cache.inflight.reshape(-1).at[idx].set(False, mode="drop")
    spec = cache.speculative.reshape(-1).at[idx].set(False, mode="drop")
    dirty = cache.dirty.reshape(-1).at[idx].set(False, mode="drop")
    return _replace_data(
        cache, tags=tags.reshape(shape2), inflight=infl.reshape(shape2),
        speculative=spec.reshape(shape2), dirty=dirty.reshape(shape2))


def fill_complete_status(cache: CacheState, slots: jax.Array,
                         pend: jax.Array, ok: jax.Array,
                         lines: jax.Array) -> CacheState:
    """Status-aware fused completion: :func:`fill` the ``pend & ok`` slots,
    :func:`clear_inflight` every ``pend`` slot, and
    :func:`invalidate_failed` the ``pend & ~ok`` slots, in ONE
    :class:`CacheState` construction.

    The fault-enabled counterpart of :func:`fill_complete` (which is the
    ``ok == True`` special case): failed fetches never reach the data
    store — their lines leave the wait un-inflighted, tag-free and
    clean, exactly as :func:`invalidate_failed` documents.  Gated on any
    pending slot, like the helpers it fuses.
    """
    def _commit():
        good = pend & ok & (slots >= 0)
        bad = pend & ~ok & (slots >= 0)
        live = pend & (slots >= 0)
        idx_g = jnp.where(good, slots, cache.num_lines)  # OOB -> dropped
        idx_p = jnp.where(live, slots, cache.num_lines)
        idx_b = jnp.where(bad, slots, cache.num_lines)
        data = cache.data.at[idx_g].set(lines.astype(cache.data.dtype),
                                        mode="drop")
        infl = cache.inflight.reshape(-1).at[idx_p].set(False, mode="drop")
        tags = cache.tags.reshape(-1).at[idx_b].set(-1, mode="drop")
        spec = cache.speculative.reshape(-1).at[idx_b].set(False,
                                                          mode="drop")
        dirty = cache.dirty.reshape(-1).at[idx_b].set(False, mode="drop")
        shape2 = (cache.num_sets, cache.ways)
        return (data, infl.reshape(shape2), tags.reshape(shape2),
                spec.reshape(shape2), dirty.reshape(shape2))

    data, infl, tags, spec, dirty = jax.lax.cond(
        jnp.any(pend), _commit,
        lambda: (cache.data, cache.inflight, cache.tags, cache.speculative,
                 cache.dirty))
    return _replace_data(cache, data=data, inflight=infl, tags=tags,
                         speculative=spec, dirty=dirty)


def mark_dirty(cache: CacheState, slots: jax.Array) -> CacheState:
    ok = slots >= 0
    idx = jnp.where(ok, slots, cache.num_lines)          # OOB -> dropped
    d = cache.dirty.reshape(-1)
    d = d.at[idx].set(True, mode="drop")
    return _replace_data(cache, dirty=d.reshape(cache.num_sets, cache.ways))


def write_line(cache: CacheState, slots: jax.Array, ok: jax.Array,
               lines: jax.Array) -> CacheState:
    """Update resident lines in place and mark them dirty (write hit path)."""
    cache = fill(cache, slots, ok, lines)
    return mark_dirty(cache, jnp.where(ok, slots, -1))


def _replace_data(cache: CacheState, **kw) -> CacheState:
    fields = dict(
        num_sets=cache.num_sets, ways=cache.ways, line_elems=cache.line_elems,
        tags=cache.tags, owner=cache.owner, refcount=cache.refcount,
        dirty=cache.dirty, speculative=cache.speculative,
        inflight=cache.inflight,
        clock_hand=cache.clock_hand, data=cache.data,
        hits=cache.hits, misses=cache.misses, bypasses=cache.bypasses,
    )
    fields.update(kw)
    return CacheState(**fields)
