"""Wavefront coalescer — the TPU analogue of BaM's warp coalescing (§III-D).

On the GPU, BaM uses ``__match_any_sync`` so that threads in a warp that
request the same cache line elect a leader; only the leader touches cache
state, and the line address is broadcast back with ``__shfl_sync``.

On a TPU there are no divergent threads: the whole *wavefront* of requests
(every index a compute step touches) arrives as one dense vector.  The
coalescer is therefore a sort-based vectorized ``unique``:

  1. sort the block keys (invalid keys, < 0, sort to the end),
  2. the first occurrence of each run is the "leader",
  3. an exclusive prefix-sum over leader flags assigns each unique key a
     compact slot — this same prefix sum is BaM's atomic *ticket counter*,
     now computed in O(log n) depth with no atomics,
  4. an inverse permutation maps every requester to its leader's slot
     (the ``__shfl_sync`` broadcast).

Everything is fixed-shape: ``unique_keys`` is padded with the sentinel to
the wavefront length and ``num_unique`` is a traced scalar.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass


@pytree_dataclass
class CoalesceResult:
    unique_keys: jax.Array   # (n,) int32, first num_unique valid, rest -1
    num_unique: jax.Array    # () int32
    inverse_idx: jax.Array   # (n,) int32: original position -> slot in unique_keys
                             #   (invalid requests map to slot of a sentinel, vals masked upstream)
    leader_mask: jax.Array   # (n,) bool over *original* positions: True for one requester per line


def coalesce(keys: jax.Array, valid: jax.Array | None = None) -> CoalesceResult:
    """Deduplicate a wavefront of block keys.

    Args:
      keys: (n,) int32 block keys; entries may repeat.
      valid: optional (n,) bool; invalid entries are ignored (treated as no
        request).  Defaults to ``keys >= 0``.
    """
    n = keys.shape[0]
    if n == 0:
        # Empty wavefront (e.g. an exhausted BFS frontier): every concat /
        # trailing-index trick below assumes n >= 1, so short-circuit with
        # the fixed-shape empty result.
        return CoalesceResult(
            unique_keys=jnp.full((0,), -1, jnp.int32),
            num_unique=jnp.zeros((), jnp.int32),
            inverse_idx=jnp.zeros((0,), jnp.int32),
            leader_mask=jnp.zeros((0,), bool),
        )
    if valid is None:
        valid = keys >= 0
    else:
        valid = valid & (keys >= 0)

    # Invalid keys get +inf-like key so they sort last; stable sort keeps
    # deterministic leader election (lowest original index wins).
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    masked = jnp.where(valid, keys, big)
    order = jnp.argsort(masked, stable=True)          # (n,) original index per sorted pos
    sorted_keys = masked[order]

    prev = jnp.concatenate([jnp.full((1,), -2, sorted_keys.dtype), sorted_keys[:-1]])
    is_first = (sorted_keys != prev) & (sorted_keys != big)   # leader per run, invalid excluded

    # Ticket counter: exclusive cumsum of leader flags == compact slot id.
    slot_sorted = jnp.cumsum(is_first.astype(jnp.int32)) - 1  # (n,) slot per sorted pos
    num_unique = jnp.maximum(slot_sorted[-1] + 1, 0).astype(jnp.int32)
    num_unique = jnp.where(is_first.any(), num_unique, jnp.int32(0))

    # Compact unique keys into the first num_unique positions.
    unique_keys = jnp.full((n,), -1, jnp.int32)
    scatter_pos = jnp.where(is_first, slot_sorted, n - 1)  # n-1 may be clobbered; fix below
    # Use a safe scatter: drop non-leaders by scattering to a dump row then slicing.
    dump = jnp.full((n + 1,), -1, jnp.int32)
    scatter_pos = jnp.where(is_first, slot_sorted, n)
    unique_keys = dump.at[scatter_pos].set(jnp.where(is_first, sorted_keys, -1),
                                           mode="drop")[:n]

    # Inverse map: original position -> slot. Invalid requests map to slot 0
    # arbitrarily (callers mask with `valid`).
    inverse = jnp.zeros((n,), jnp.int32)
    inverse = inverse.at[order].set(jnp.where(slot_sorted >= 0, slot_sorted, 0))

    leader_mask = jnp.zeros((n,), bool).at[order].set(is_first)
    return CoalesceResult(
        unique_keys=unique_keys,
        num_unique=num_unique,
        inverse_idx=inverse,
        leader_mask=leader_mask,
    )
