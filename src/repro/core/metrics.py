"""I/O accounting — hit rates, queue depths and I/O amplification (paper §II-B).

I/O amplification = bytes moved from the storage tier / bytes the compute
actually consumed.  The paper's headline data-analytics result is that the
CPU-centric model ships whole columns (6.34x-10.36x amplification on the
taxi queries) while BaM ships cache lines on demand.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass


@pytree_dataclass
class IOMetrics:
    requests: jax.Array          # element-level requests issued by compute
    bytes_requested: jax.Array   # bytes the compute consumed (useful bytes)
    hits: jax.Array              # cache-line hits (post-coalescing)
    misses: jax.Array            # cache-line misses -> storage reads
    bytes_from_storage: jax.Array
    write_ops: jax.Array
    bytes_to_storage: jax.Array
    doorbells: jax.Array         # batched ring-tail updates (1 per queue per round)
    sim_time_s: jax.Array        # simulated device service time accumulated
    max_queue_depth: jax.Array   # high-watermark of in-flight requests
    prefetch_issued: jax.Array   # cache lines fetched speculatively (readahead)
    prefetch_hits: jax.Array     # demand line-hits served by a prefetched line

    @staticmethod
    def zeros() -> "IOMetrics":
        f = lambda: jnp.zeros((), jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32)
        i = lambda: jnp.zeros((), jnp.int32)
        return IOMetrics(
            requests=f(), bytes_requested=f(), hits=f(), misses=f(),
            bytes_from_storage=f(), write_ops=f(), bytes_to_storage=f(),
            doorbells=f(), sim_time_s=f(), max_queue_depth=i(),
            prefetch_issued=f(), prefetch_hits=f(),
        )

    # Derived quantities (host-side, after device_get) -------------------
    def amplification(self) -> float:
        br = float(self.bytes_requested)
        return float(self.bytes_from_storage) / br if br > 0 else 0.0

    def hit_rate(self) -> float:
        tot = float(self.hits) + float(self.misses)
        return float(self.hits) / tot if tot > 0 else 0.0

    def read_iops(self) -> float:
        t = float(self.sim_time_s)
        return float(self.misses) / t if t > 0 else 0.0

    def prefetch_accuracy(self) -> float:
        """Fraction of speculatively fetched lines later used by demand."""
        issued = float(self.prefetch_issued)
        return float(self.prefetch_hits) / issued if issued > 0 else 0.0

    def summary(self) -> dict:
        return {
            "requests": float(self.requests),
            "bytes_requested": float(self.bytes_requested),
            "hits": float(self.hits),
            "misses": float(self.misses),
            "hit_rate": self.hit_rate(),
            "bytes_from_storage": float(self.bytes_from_storage),
            "write_ops": float(self.write_ops),
            "bytes_to_storage": float(self.bytes_to_storage),
            "amplification": self.amplification(),
            "doorbells": float(self.doorbells),
            "sim_time_s": float(self.sim_time_s),
            "read_iops": self.read_iops(),
            "max_queue_depth": int(self.max_queue_depth),
            "prefetch_issued": float(self.prefetch_issued),
            "prefetch_hits": float(self.prefetch_hits),
            "prefetch_accuracy": self.prefetch_accuracy(),
        }
