"""I/O accounting — hit rates, queue depths and I/O amplification (paper §II-B).

I/O amplification = bytes moved from the storage tier / bytes the compute
actually consumed.  The paper's headline data-analytics result is that the
CPU-centric model ships whole columns (6.34x-10.36x amplification on the
taxi queries) while BaM ships cache lines on demand.

Per-device channels (paper §IV-A): every counter that touches the storage
tier also has a ``(n_devices,)`` per-device breakdown, so device skew —
one straggler SSD gating the wavefront — is observable instead of being
averaged away.  Device time is split by direction (``read_time_s`` vs
``write_time_s``): demand reads and readahead charge the read clock,
write-backs charge the write clock, and ``sim_time_s`` stays their sum, so
``read_iops`` no longer dilutes as soon as write-backs or prefetch are
active.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass


@pytree_dataclass
class IOMetrics:
    requests: jax.Array          # element-level requests issued by compute
    bytes_requested: jax.Array   # bytes the compute consumed (useful bytes)
    hits: jax.Array              # cache-line hits (post-coalescing)
    misses: jax.Array            # cache-line misses -> storage reads
    bytes_from_storage: jax.Array
    write_ops: jax.Array
    bytes_to_storage: jax.Array
    doorbells: jax.Array         # batched ring-tail updates (1 per queue per round)
    dropped: jax.Array           # commands rejected by ring back-pressure
    sim_time_s: jax.Array        # simulated device service time accumulated
    read_time_s: jax.Array       # read-direction share (demand + readahead)
    write_time_s: jax.Array      # write-direction share (write-backs, flush)
    max_queue_depth: jax.Array   # high-watermark of in-flight requests
    prefetch_issued: jax.Array   # cache lines fetched speculatively (readahead)
    prefetch_hits: jax.Array     # demand line-hits served by a prefetched line
    # Async submission-window accounting (submit/wait tokens).
    tokens_submitted: jax.Array  # IOTokens issued (submits with >=1 valid lane)
    tokens_waited: jax.Array     # IOTokens completed by wait()
    tokens_in_flight: jax.Array  # running outstanding-token count (+1/-1)
    cross_op_coalesced: jax.Array  # line requests merged with a *pending*
    #                                token's in-flight fetch (saved commands)
    max_tokens_in_flight: jax.Array  # high-watermark of the in-flight window
    # Fault-injection accounting (all zero with the FaultModel disabled).
    transient_errors: jax.Array  # attempt-level failures (incl. recovered)
    retries: jax.Array           # command re-issues (bounded by retry_budget)
    failed_commands: jax.Array   # commands retired with an error status
    degraded_reads: jax.Array    # element lanes redeemed with error_mask set
    # Per-device channel breakdown, all shape (n_devices,).
    dev_reads: jax.Array         # lines fetched per device (demand + readahead)
    dev_writes: jax.Array        # lines written back per device
    dev_bytes: jax.Array         # bytes moved per device (both directions)
    dev_time_s: jax.Array        # per-device busy time (the straggler signal)
    dev_errors: jax.Array        # failed commands per device
    dev_max_depth: jax.Array     # per-device in-flight high-watermark, int32

    @staticmethod
    def zeros(n_devices: int = 1) -> "IOMetrics":
        # float64 under x64, float32 otherwise (the canonical float dtype)
        ftype = jax.dtypes.canonicalize_dtype(jnp.float64)
        f = lambda: jnp.zeros((), ftype)
        i = lambda: jnp.zeros((), jnp.int32)
        return IOMetrics(
            requests=f(), bytes_requested=f(), hits=f(), misses=f(),
            bytes_from_storage=f(), write_ops=f(), bytes_to_storage=f(),
            doorbells=f(), dropped=f(),
            sim_time_s=f(), read_time_s=f(), write_time_s=f(),
            max_queue_depth=i(),
            prefetch_issued=f(), prefetch_hits=f(),
            tokens_submitted=f(), tokens_waited=f(), tokens_in_flight=f(),
            cross_op_coalesced=f(), max_tokens_in_flight=i(),
            transient_errors=f(), retries=f(), failed_commands=f(),
            degraded_reads=f(),
            dev_reads=jnp.zeros((n_devices,), ftype),
            dev_writes=jnp.zeros((n_devices,), ftype),
            dev_bytes=jnp.zeros((n_devices,), ftype),
            dev_time_s=jnp.zeros((n_devices,), ftype),
            dev_errors=jnp.zeros((n_devices,), ftype),
            dev_max_depth=jnp.zeros((n_devices,), jnp.int32),
        )

    @property
    def n_devices(self) -> int:
        return int(self.dev_reads.shape[0])

    # Derived quantities (host-side, after device_get) -------------------
    def amplification(self) -> float:
        br = float(self.bytes_requested)
        return float(self.bytes_from_storage) / br if br > 0 else 0.0

    def hit_rate(self) -> float:
        tot = float(self.hits) + float(self.misses)
        return float(self.hits) / tot if tot > 0 else 0.0

    def read_iops(self) -> float:
        """Lines fetched (demand + readahead) per second of *read* time.

        Write-backs and their service time are excluded from both numerator
        and denominator, so background write traffic no longer deflates the
        reported read throughput.  Falls back to ``sim_time_s`` for states
        (e.g. external accumulators) that never split the clocks.
        """
        fetched = float(self.misses) + float(self.prefetch_issued)
        t = float(self.read_time_s)
        if t <= 0.0:
            t = float(self.sim_time_s)
        return fetched / t if t > 0 else 0.0

    def prefetch_accuracy(self) -> float:
        """Fraction of speculatively fetched lines later used by demand."""
        issued = float(self.prefetch_issued)
        return float(self.prefetch_hits) / issued if issued > 0 else 0.0

    def straggler_gap(self) -> float:
        """Max over mean of per-device busy time (1.0 = perfectly balanced).

        The Fig. 7 skew observable: a uniform stream keeps this near 1;
        a Zipfian stream concentrates load on few channels and the gap
        grows with it.  0.0 when no device time has been charged.
        """
        t = jax.device_get(self.dev_time_s)
        mean = float(t.mean())
        return float(t.max()) / mean if mean > 0 else 0.0

    def summary(self) -> dict:
        return {
            "requests": float(self.requests),
            "bytes_requested": float(self.bytes_requested),
            "hits": float(self.hits),
            "misses": float(self.misses),
            "hit_rate": self.hit_rate(),
            "bytes_from_storage": float(self.bytes_from_storage),
            "write_ops": float(self.write_ops),
            "bytes_to_storage": float(self.bytes_to_storage),
            "amplification": self.amplification(),
            "doorbells": float(self.doorbells),
            "dropped": float(self.dropped),
            "sim_time_s": float(self.sim_time_s),
            "read_time_s": float(self.read_time_s),
            "write_time_s": float(self.write_time_s),
            "read_iops": self.read_iops(),
            "max_queue_depth": int(self.max_queue_depth),
            "prefetch_issued": float(self.prefetch_issued),
            "prefetch_hits": float(self.prefetch_hits),
            "prefetch_accuracy": self.prefetch_accuracy(),
            "tokens_submitted": float(self.tokens_submitted),
            "tokens_waited": float(self.tokens_waited),
            "tokens_in_flight": float(self.tokens_in_flight),
            "cross_op_coalesced": float(self.cross_op_coalesced),
            "max_tokens_in_flight": int(self.max_tokens_in_flight),
            "transient_errors": float(self.transient_errors),
            "retries": float(self.retries),
            "failed_commands": float(self.failed_commands),
            "degraded_reads": float(self.degraded_reads),
            "n_devices": self.n_devices,
            "dev_reads": [float(x) for x in jax.device_get(self.dev_reads)],
            "dev_writes": [float(x) for x in jax.device_get(self.dev_writes)],
            "dev_bytes": [float(x) for x in jax.device_get(self.dev_bytes)],
            "dev_time_s": [float(x) for x in jax.device_get(self.dev_time_s)],
            "dev_errors": [float(x) for x in jax.device_get(self.dev_errors)],
            "dev_max_depth": [int(x)
                              for x in jax.device_get(self.dev_max_depth)],
            "straggler_gap": self.straggler_gap(),
        }


# Watermark (high-water) fields combine by max; everything else is an
# additive counter.  The shared-runtime facade relies on this split: it
# accumulates each tenant op's *delta* into the global IOMetrics, and the
# invariant "additive tenant counters sum exactly to the global counters"
# is what the multi-tenant tests (and the mixed_tenants gate) assert.
WATERMARK_FIELDS = ("max_queue_depth", "dev_max_depth",
                    "max_tokens_in_flight")
ADDITIVE_FIELDS = tuple(
    f for f in IOMetrics.__dataclass_fields__ if f not in WATERMARK_FIELDS)


def metrics_delta(new: IOMetrics, old: IOMetrics) -> IOMetrics:
    """Per-op increment: additive fields subtract, watermarks carry ``new``."""
    kw = {f: getattr(new, f) - getattr(old, f) for f in ADDITIVE_FIELDS}
    kw.update({f: getattr(new, f) for f in WATERMARK_FIELDS})
    return IOMetrics(**kw)


def metrics_accumulate(acc: IOMetrics, delta: IOMetrics) -> IOMetrics:
    """Fold a :func:`metrics_delta` into an accumulator (sum / max)."""
    kw = {f: getattr(acc, f) + getattr(delta, f) for f in ADDITIVE_FIELDS}
    kw.update({f: jnp.maximum(getattr(acc, f), getattr(delta, f))
               for f in WATERMARK_FIELDS})
    return IOMetrics(**kw)


def metrics_sum(parts) -> IOMetrics:
    """Combine per-tenant metrics into the global view (sum / max)."""
    parts = list(parts)
    acc = parts[0]
    for p in parts[1:]:
        acc = metrics_accumulate(acc, p)
    return acc


def recheck_token_watermark(mt: IOMetrics) -> IOMetrics:
    """Re-arm ``max_tokens_in_flight`` against the *current* window.

    Every path that changes ``tokens_in_flight`` must re-check the
    watermark, not just ``submit``: a ``flush()`` that retires tokens
    mid-window, or a shared-runtime ``absorb`` that sums several tenants'
    windows, can otherwise leave the high-water mark below a level the
    window actually reached.
    """
    return dataclasses.replace(
        mt, max_tokens_in_flight=jnp.maximum(
            mt.max_tokens_in_flight,
            mt.tokens_in_flight.astype(jnp.int32)))
