"""Latency hiding: double-buffered fetch/compute software pipeline.

BaM hides 10–300 µs device latency with 10⁴–10⁵ oversubscribed GPU threads:
while some threads wait on the SSD, others compute.  A TPU has no thread
oversubscription — the idiomatic equivalent (used by Pallas's
``emit_pipeline`` for HBM→VMEM and by every production input pipeline) is a
*software pipeline*: inside a ``lax.scan``, step ``t`` issues the fetch for
step ``t+1``'s data while computing on the data fetched at ``t``.  The two
halves of each iteration are data-independent, so the compiler/runtime can
overlap the storage DMA with the compute — structurally the same
latency-hiding budget Little's law demands (the in-flight window is one
wavefront of ``Q_d`` requests).

``software_pipeline`` is generic over any (read_fn, compute_fn) pair; BaM
reads plug in as ``read_fn = lambda st, idx: bam.read(st, idx)``.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

__all__ = ["software_pipeline", "pipelined_bam_map"]


def software_pipeline(
    read_fn: Callable[[Any, jax.Array], Tuple[jax.Array, Any]],
    compute_fn: Callable[[Any, jax.Array, jax.Array], Tuple[Any, Any]],
    idx_seq: jax.Array,          # (T, n) element indices per step
    read_state: Any,
    compute_carry: Any,
):
    """Run ``T`` steps with fetch(t+1) overlapped against compute(t).

    Args:
      read_fn: ``(read_state, idx) -> (values, read_state')``.
      compute_fn: ``(carry, values, idx) -> (carry', y)`` — consumes the
        values fetched for its own step.
      idx_seq: stacked per-step index wavefronts.
      read_state: e.g. a ``BamState``.
      compute_carry: initial compute carry.

    Returns ``(read_state', compute_carry', ys)``.
    """
    T = idx_seq.shape[0]
    # Prologue: fetch step 0 before the loop (pipeline fill).
    vals0, read_state = read_fn(read_state, idx_seq[0])

    # Steady state: at iteration t we carry values for step t, fetch t+1.
    nxt = jnp.concatenate(
        [idx_seq[1:], jnp.full_like(idx_seq[:1], -1)], axis=0)  # (T, n)

    def body(carry, x):
        rs, cc, vals_t = carry
        idx_t, idx_next = x
        # (a) issue the prefetch for t+1 — independent of (b), overlappable.
        vals_next, rs = read_fn(rs, idx_next)
        # (b) compute on this step's already-fetched values.
        cc, y = compute_fn(cc, vals_t, idx_t)
        return (rs, cc, vals_next), y

    (read_state, compute_carry, _), ys = jax.lax.scan(
        body, (read_state, compute_carry, vals0), (idx_seq, nxt))
    return read_state, compute_carry, ys


def pipelined_bam_map(bam, st, idx_seq: jax.Array,
                      fn: Callable[[jax.Array], jax.Array]):
    """Map ``fn`` over BaM-fetched value wavefronts with overlap.

    ``ys[t] = fn(bam.flat[idx_seq[t]])`` — the pipelined analogue of the
    paper's Listing 1 kernel loop.
    """
    def read_fn(s, idx):
        return bam.read(s, idx)

    def compute_fn(carry, vals, _idx):
        return carry, fn(vals)

    st, _, ys = software_pipeline(read_fn, compute_fn, idx_seq, st, None)
    return ys, st
