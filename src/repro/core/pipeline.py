"""Latency hiding: token-pipelined fetch/compute software pipeline.

BaM hides 10–300 µs device latency with 10⁴–10⁵ oversubscribed GPU threads:
while some threads wait on the SSD, others compute.  A TPU has no thread
oversubscription — the idiomatic equivalent (used by Pallas's
``emit_pipeline`` for HBM→VMEM and by every production input pipeline) is a
*software pipeline*: inside a ``lax.scan``, step ``t`` issues the fetch for
step ``t+1``'s data while computing on the data fetched at ``t``.

With the first-class async I/O surface this is now a *real* in-flight
window, not just instruction-level overlap: the scan carry holds an
:class:`~repro.core.bam_array.IOToken` for the next step's wavefront, so
step ``t+1``'s SQ commands are pending in the rings while step ``t``
computes — the submit/complete split of BaM's §III-C queues expressed in
the scan's dataflow.  The epilogue is exact: the scan runs ``T-1`` steps
and the last wavefront is waited/computed after the loop, so no dummy
``-1`` wavefront is ever submitted (the old epilogue issued a junk fetch
that polluted metrics and cache state).

``software_pipeline`` is generic over any (submit_fn, wait_fn) pair; BaM
arrays plug in as ``submit_fn = lambda st, idx: bam.submit(st,
IORequest.read(idx))`` and ``wait_fn = bam.wait``.  A legacy synchronous
``read_fn`` is adapted automatically (the "token" is the eagerly fetched
wavefront itself, preserving the old fetch-ahead schedule).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["software_pipeline", "pipelined_bam_map"]


def software_pipeline(
    read_fn: Optional[Callable[[Any, jax.Array], Tuple[jax.Array, Any]]],
    compute_fn: Callable[[Any, jax.Array, jax.Array], Tuple[Any, Any]],
    idx_seq: jax.Array,          # (T, n) element indices per step
    read_state: Any,
    compute_carry: Any,
    *,
    submit_fn: Optional[Callable[[Any, jax.Array], Tuple[Any, Any]]] = None,
    wait_fn: Optional[Callable[[Any, Any], Tuple[Any, jax.Array]]] = None,
):
    """Run ``T`` steps with fetch(t+1) in flight while compute(t) runs.

    Args:
      read_fn: legacy synchronous ``(read_state, idx) -> (values,
        read_state')`` — adapted to a degenerate submit/wait pair when no
        explicit one is given.  Pass ``None`` when using submit/wait.
      compute_fn: ``(carry, values, idx) -> (carry', y)`` — consumes the
        values fetched for its own step.
      idx_seq: stacked per-step index wavefronts.
      read_state: e.g. a ``BamState``.
      compute_carry: initial compute carry.
      submit_fn: async ``(read_state, idx) -> (read_state', token)``.
      wait_fn: async ``(read_state, token) -> (read_state', values)``.

    Returns ``(read_state', compute_carry', ys)``.

    Schedule: the prologue submits step 0; iteration ``t`` submits step
    ``t+1`` *then* waits step ``t`` (so two wavefronts overlap in flight)
    and computes on the waited values; the epilogue waits and computes the
    final step outside the scan — no junk wavefront is ever issued.
    """
    if (submit_fn is None) != (wait_fn is None):
        raise ValueError("pass submit_fn and wait_fn together")
    if submit_fn is None:
        if read_fn is None:
            raise ValueError("need read_fn or a submit_fn/wait_fn pair")
        sync_read = read_fn

        # Legacy adapter: "submit" performs the read eagerly (keeping the
        # old fetch-ahead-of-compute schedule); the token is the wavefront.
        def _eager_submit(rs, idx):
            vals, rs = sync_read(rs, idx)
            return rs, vals

        def _eager_wait(rs, tok):
            return rs, tok

        submit_fn, wait_fn = _eager_submit, _eager_wait
    assert wait_fn is not None

    T = idx_seq.shape[0]
    read_state, tok0 = submit_fn(read_state, idx_seq[0])

    def body(carry, x):
        rs, cc, tok_t = carry
        idx_t, idx_next = x
        # (a) issue t+1 — its commands sit in the rings while (b) runs.
        rs, tok_next = submit_fn(rs, idx_next)
        # (b) complete step t and compute on its values.
        rs, vals_t = wait_fn(rs, tok_t)
        cc, y = compute_fn(cc, vals_t, idx_t)
        return (rs, cc, tok_next), y

    (read_state, compute_carry, tok_last), ys = jax.lax.scan(
        body, (read_state, compute_carry, tok0),
        (idx_seq[:-1], idx_seq[1:]))

    # Exact epilogue: the last step completes outside the loop.
    read_state, vals_last = wait_fn(read_state, tok_last)
    compute_carry, y_last = compute_fn(compute_carry, vals_last,
                                       idx_seq[-1])
    ys = jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a, b[None]], axis=0), ys, y_last)
    return read_state, compute_carry, ys


def pipelined_bam_map(bam, st, idx_seq: jax.Array,
                      fn: Callable[[jax.Array], jax.Array]):
    """Map ``fn`` over BaM-fetched value wavefronts with a real async window.

    ``ys[t] = fn(bam.flat[idx_seq[t]])`` — the pipelined analogue of the
    paper's Listing 1 kernel loop, now carrying an :class:`IOToken` in the
    scan carry so step ``t+1``'s storage commands are genuinely in flight
    while ``fn`` runs on step ``t``.
    """
    from repro.core.bam_array import IORequest

    def submit_fn(s, idx):
        return bam.submit(s, IORequest.read(idx))

    def compute_fn(carry, vals, _idx):
        return carry, fn(vals)

    st, _, ys = software_pipeline(None, compute_fn, idx_seq, st, None,
                                  submit_fn=submit_fn, wait_fn=bam.wait)
    return ys, st
