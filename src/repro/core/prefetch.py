"""Asynchronous readahead & prefetch for the BaM cache (beyond-paper).

BaM (§III-C/D) keeps the SSDs saturated only while the *demand* stream
carries enough parallelism.  A purely demand-driven ``BamArray.read`` pays
full miss latency on every wavefront of a sequential or strided sweep —
exactly the gap GPU readahead prefetchers (Dimitsas & Silberstein,
arXiv:2109.05366) and GIDS (Park et al., arXiv:2306.16384) close on top of
a BaM-style cache.  This module supplies the two pieces:

* a **readahead detector** over the wavefront's *coalesced* block keys: a
  jit-safe modal-stride estimate (sort → run-length → argmax, the same
  sort-based idiom as the coalescer) that, when enough of the wavefront
  agrees on one positive stride, extrapolates the pattern ``window`` lines
  past the wavefront's last key — clamped to the array bounds so readahead
  never fabricates traffic past the end of the data;

* a :class:`PrefetchConfig` of static knobs threaded through
  :class:`~repro.core.bam_array.BamArray`.

The fills themselves go through the normal SQ rings but in a *low-priority
lane* (``prio=1`` in :mod:`repro.core.queues`) so the simulated controller
drains demand reads first, and they are inserted **without protection** as
*speculative* lines (``speculative=True`` in :mod:`repro.core.cache`) so an
unlucky prediction is the first thing the clock hand reclaims — prefetch
can delay demand, but never starve it.

Under the first-class async surface the two pieces ride the token
lifecycle: ``BamArray.submit`` runs the detector and *claims* the
predicted lines (speculative + in-flight, commands enqueued, nothing
fetched), and the issuing token's ``wait`` performs the deferred fill.  A
demand submission that lands on a claimed-but-unfilled prediction
coalesces against it (promote + ``cross_op_coalesced``) instead of
re-fetching, and an explicit ``IORequest.prefetch`` token turns the old
synchronous hint into a genuinely asynchronous warm-up.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["PrefetchConfig", "modal_stride", "readahead_keys"]

_BIG = jnp.iinfo(jnp.int32).max


@dataclasses.dataclass(frozen=True)
class PrefetchConfig:
    """Static readahead knobs for one :class:`BamArray` (hashable, jit-static).

    Attributes:
      enabled: master switch; ``False`` keeps the demand path byte-identical.
      window: cache lines of readahead generated per wavefront.
      min_support: fraction of the wavefront's key deltas that must agree on
        the modal stride before the detector commits (sequential scans give
        1.0; random access gives ~1/n and never triggers).
      max_stride: ignore pattern strides larger than this many blocks — a
        guard against pathological "patterns" that would fetch far-away
        lines and inflate I/O amplification.
    """

    enabled: bool = False
    window: int = 8
    min_support: float = 0.75
    max_stride: int = 64

    def __post_init__(self):
        if self.window < 0:
            raise ValueError("window must be >= 0")
        if not (0.0 < self.min_support <= 1.0):
            raise ValueError("min_support must be in (0, 1]")


def modal_stride(keys: jax.Array, valid: jax.Array
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Dominant positive stride of a wavefront of unique block keys.

    Args:
      keys: (n,) int32 coalesced (duplicate-free) block keys.
      valid: (n,) bool.

    Returns ``(stride, support, n_deltas)`` scalars: the most common gap
    between consecutive sorted keys, how many gaps equal it, and how many
    gaps there were.  All zeros when fewer than two valid keys.
    """
    n = keys.shape[0]
    zero = jnp.zeros((), jnp.int32)
    if n < 2:
        return zero, zero, zero
    big = jnp.int32(_BIG)
    masked = jnp.where(valid, keys, big)
    skeys = jnp.sort(masked)
    # Consecutive gaps; keys are unique so every valid gap is > 0.
    d = skeys[1:] - skeys[:-1]
    dvalid = (skeys[1:] != big) & (skeys[:-1] != big)
    dm = jnp.where(dvalid, d, big)

    # Run-length encode the sorted gaps; the longest run is the mode.
    ds = jnp.sort(dm)
    prev = jnp.concatenate([jnp.full((1,), -1, ds.dtype), ds[:-1]])
    is_first = ds != prev
    run_id = jnp.cumsum(is_first.astype(jnp.int32)) - 1
    m = ds.shape[0]
    counts = jnp.zeros((m,), jnp.int32).at[run_id].add(
        (ds != big).astype(jnp.int32))
    run_val = jnp.full((m,), big, jnp.int32).at[run_id].min(ds)
    counts = jnp.where(run_val == big, 0, counts)
    best = jnp.argmax(counts)
    n_deltas = jnp.sum(dvalid.astype(jnp.int32))
    stride = jnp.where(n_deltas > 0, run_val[best], 0).astype(jnp.int32)
    support = counts[best].astype(jnp.int32)
    return stride, support, n_deltas


def readahead_keys(keys: jax.Array, valid: jax.Array, *,
                   window: int, num_blocks: int,
                   min_support: float = 0.75,
                   max_stride: int = 64,
                   raw_keys: jax.Array | None = None,
                   raw_valid: jax.Array | None = None) -> jax.Array:
    """Predict the next ``window`` block keys of the wavefront's pattern.

    Extrapolates ``stride * (1..window)`` past the wavefront's extreme key
    when the modal stride clears the ``min_support`` confidence bar,
    clamping every candidate to ``[0, num_blocks)`` — predictions past the
    array bounds come back as the ``-1`` sentinel (the "window clamp"), so
    the caller fetches nothing for them.  Returns a fixed-shape
    ``(window,)`` int32 vector.

    The coalesced ``keys`` are sorted, so they carry the stride *magnitude*
    but not the scan *direction*.  Pass the wavefront's pre-coalesce block
    keys as ``raw_keys``/``raw_valid`` to recover it: when the last valid
    raw key is below the first, the scan is descending and the pattern is
    extrapolated downward from the smallest key.  Without ``raw_keys`` an
    ascending scan is assumed.
    """
    if window == 0:
        return jnp.full((0,), -1, jnp.int32)
    stride, support, n_deltas = modal_stride(keys, valid)
    need = jnp.maximum(
        jnp.int32(1),
        jnp.ceil(min_support * n_deltas.astype(jnp.float32)).astype(jnp.int32))
    confident = (n_deltas > 0) & (support >= need) \
        & (stride > 0) & (stride <= max_stride)
    descending = jnp.zeros((), bool)
    if raw_keys is not None:
        rv = raw_valid if raw_valid is not None else raw_keys >= 0
        first = jnp.argmax(rv)                       # first valid lane
        last = raw_keys.shape[0] - 1 - jnp.argmax(rv[::-1])
        descending = jnp.any(rv) & (raw_keys[last] < raw_keys[first])
    big = jnp.int32(_BIG)
    base = jnp.where(descending,
                     jnp.min(jnp.where(valid, keys, big)),
                     jnp.max(jnp.where(valid, keys, -1)))
    step = jnp.where(descending, -stride, stride)
    steps = jnp.arange(1, window + 1, dtype=jnp.int32)
    cand = base + step * steps
    ok = confident & (base >= 0) & (base < num_blocks) \
        & (cand >= 0) & (cand < num_blocks)
    return jnp.where(ok, cand, -1).astype(jnp.int32)
