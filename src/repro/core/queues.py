"""High-throughput I/O queues — the TPU adaptation of BaM's §III-C I/O stack.

The paper's GPU design lets thousands of divergent threads enqueue NVMe
commands concurrently with an atomic ticket counter, a ``turn_counter``
array, and a mark bit-vector, so that only the doorbell write is a critical
section (and one thread's doorbell covers every contiguously-marked command).

On a TPU the unit of concurrency is the *wavefront* (a dense vector of
requests produced by one compute step), so the same protocol becomes a
deterministic prefix-sum:

* atomic ticket counter  -> ``tail + exclusive_cumsum(valid)``;
* turn_counter ordering  -> positions are assigned in compact order, so
  there is never a thread waiting for its turn;
* mark bit-vector race   -> the whole wavefront's commands are contiguous by
  construction, so the "advance tail past all marked entries and ring once"
  optimisation degenerates to a *single* doorbell per queue per wavefront —
  exactly the batched-doorbell behaviour the paper identifies as optimal.

Ring semantics (wrap-around, full-queue back-pressure, head advancement on
completion, per-queue doorbells) are kept faithfully; the *device* side is a
synchronous drain whose wall-clock cost is taken from the
:mod:`repro.core.ssd` Little's-law model.

Per-device channels (paper §IV-A, Fig. 7): the queue pool is partitioned
into ``n_devices`` equal groups — the paper's one-SQ/CQ-pair-per-SSD layout
generalised to a group of rings per SSD.  Each command is routed to its
block key's device (:func:`repro.core.ssd.device_of_block` striping), then
round-robin *within* that device's group, matching the paper's
micro-benchmark setup (§IV-A).  Back-pressure is therefore per device: one
slow or overloaded SSD fills only its own rings and drops only its own
commands, leaving the other channels flowing.  With ``n_devices=1`` (the
default) the whole pool is one group and behaviour is exactly the classic
single-device round-robin.

Everything is fixed-shape and jit-safe: monotonic 32-bit virtual heads/tails
(slot = counter % depth), masked scatters, no data-dependent shapes.

Priority lane (prefetch support): every command carries a priority —
``PRIO_DEMAND`` (0) for demand reads/write-backs, ``PRIO_READAHEAD`` (1)
for speculative fills.  The simulated controller drains demand first:
:func:`service_all` returns completions sorted priority-major (stable, so
queue-major order is preserved within a class), the analogue of an NVMe
weighted-round-robin arbitration burst favouring the urgent queue class.
Readahead also loses the back-pressure race naturally: it is enqueued after
the demand wavefront, so when rings fill it is the first thing dropped.

Multi-tenant arbitration (``BamRuntime``): when the pool is built with
``n_tenants > 1`` every command also carries its *tenant id* next to the
priority bit, and the controller drains each priority class in
weighted-fair order across tenants: the *i*-th pending command of tenant
*t* within its priority class gets virtual finish time
``(i+1) / weight[t]`` and completions come back in ascending virtual time
— classic weighted round-robin, so a tenant with weight 2 retires two
commands for every one of a weight-1 tenant instead of a bursty
first-come-first-served drain (and a tenant's demand backlog never
penalises its own readahead against other tenants' readahead).  Accounting is per tenant *and* per
device: ``tenant_enqueued/_dropped/_completed`` and
``dev_enqueued/_completed`` let callers check the conservation law
``enqueued == completed + in-flight`` for every lane of every tenant.
With ``n_tenants=1`` (the default) the drain path is bit-for-bit the
single-tenant behaviour above.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.ssd import _alive_devices, device_of_block
from repro.kernels import ops as _ops
from repro.utils import pytree_dataclass

__all__ = ["QueueState", "make_queues", "enqueue", "enqueue_segments",
           "service_all", "drain_accounting", "SubmitReceipt",
           "DrainReceipt", "PRIO_DEMAND", "PRIO_READAHEAD",
           "in_flight", "in_flight_per_device", "in_flight_per_tenant"]

PRIO_DEMAND = 0      # demand reads and write-backs
PRIO_READAHEAD = 1   # speculative readahead fills (drain last, drop first)


@pytree_dataclass(meta_fields=("num_queues", "depth", "n_devices",
                                "stripe_blocks", "n_tenants",
                                "tenant_weights", "failed_devices"))
class QueueState:
    """A pool of NVMe submission/completion queue pairs living "in HBM".

    The pool is split into ``n_devices`` contiguous groups of
    ``num_queues // n_devices`` rings each; queues
    ``[d*group, (d+1)*group)`` belong to device ``d``.

    ``n_tenants``/``tenant_weights`` configure the shared-runtime
    arbitration: commands carry their tenant id and the drain interleaves
    tenants weighted-fair within each priority class.

    ``failed_devices`` (static, normally mirroring the SSD array's
    :class:`~repro.core.ssd.FaultModel`) lists hard-failed channels:
    routing remaps their blocks across the surviving groups (see
    :func:`~repro.core.ssd.device_of_block`), so a dead device's ring
    group simply stops receiving commands and its load amplifies the
    survivors' back-pressure.
    """

    num_queues: int
    depth: int
    n_devices: int
    stripe_blocks: int
    n_tenants: int
    tenant_weights: tuple   # per-tenant service weights (floats), len n_tenants
    failed_devices: tuple   # hard-failed device ids (static; usually empty)
    # Submission-queue entries. key < 0 means the slot is free.
    sq_key: jax.Array        # (num_queues, depth) int32 — block key of the command
    sq_dst: jax.Array        # (num_queues, depth) int32 — destination cache slot (or -1)
    sq_is_write: jax.Array   # (num_queues, depth) bool  — write command?
    sq_prio: jax.Array       # (num_queues, depth) int32 — PRIO_DEMAND / PRIO_READAHEAD
    sq_tenant: jax.Array     # (num_queues, depth) int32 — issuing tenant id
    sq_ticket: jax.Array     # (num_queues, depth) int32 — per-device command
    #                          ordinal (the fault-hash counter), -1 when free
    # Monotonic virtual pointers (never wrapped; slot = ptr % depth).
    sq_tail: jax.Array       # (num_queues,) int32
    sq_head: jax.Array       # (num_queues,) int32
    # Per-device round-robin dispatch pointer within each device's group.
    rr_ptr: jax.Array        # (n_devices,) int32
    # Counters (the observability the IOPS benchmarks read).
    ticket_total: jax.Array  # () int32 — cumulative tickets issued (paper's atomic ctr)
    doorbells: jax.Array     # () int32 — batched doorbell register writes
    completions: jax.Array   # () int32 — CQ entries consumed
    dropped: jax.Array       # () int32 — requests rejected because every ring was full
    dev_dropped: jax.Array   # (n_devices,) int32 — drops per device channel
    dev_enqueued: jax.Array  # (n_devices,) int32 — commands accepted per device
    dev_completed: jax.Array  # (n_devices,) int32 — commands drained per device
    tenant_enqueued: jax.Array   # (n_tenants,) int32 — accepted per tenant
    tenant_dropped: jax.Array    # (n_tenants,) int32 — back-pressure drops per tenant
    tenant_completed: jax.Array  # (n_tenants,) int32 — drained per tenant

    @property
    def group_size(self) -> int:
        """SQ rings per device."""
        return self.num_queues // self.n_devices


def make_queues(num_queues: int, depth: int, n_devices: int = 1,
                stripe_blocks: int = 1, n_tenants: int = 1,
                tenant_weights: tuple | None = None,
                failed_devices=()) -> QueueState:
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if stripe_blocks < 1:
        raise ValueError(f"stripe_blocks must be >= 1, got {stripe_blocks}")
    if num_queues % n_devices != 0:
        raise ValueError(
            f"num_queues ({num_queues}) must be a multiple of n_devices "
            f"({n_devices}) so every device gets an equal ring group")
    if n_tenants < 1:
        raise ValueError(f"n_tenants must be >= 1, got {n_tenants}")
    if tenant_weights is None:
        tenant_weights = (1.0,) * n_tenants
    tenant_weights = tuple(float(w) for w in tenant_weights)
    if len(tenant_weights) != n_tenants:
        raise ValueError(
            f"tenant_weights has {len(tenant_weights)} entries for "
            f"n_tenants={n_tenants}")
    if any(w <= 0 for w in tenant_weights):
        raise ValueError(f"tenant_weights must be positive: {tenant_weights}")
    if failed_devices:
        _alive_devices(n_devices, failed_devices)   # range / all-dead check
    failed_devices = tuple(sorted({int(d) for d in failed_devices}))
    z = lambda: jnp.zeros((), jnp.int32)
    return QueueState(
        num_queues=num_queues,
        depth=depth,
        n_devices=n_devices,
        stripe_blocks=stripe_blocks,
        n_tenants=n_tenants,
        tenant_weights=tenant_weights,
        failed_devices=failed_devices,
        sq_key=jnp.full((num_queues, depth), -1, jnp.int32),
        sq_dst=jnp.full((num_queues, depth), -1, jnp.int32),
        sq_is_write=jnp.zeros((num_queues, depth), bool),
        sq_prio=jnp.zeros((num_queues, depth), jnp.int32),
        sq_tenant=jnp.zeros((num_queues, depth), jnp.int32),
        sq_ticket=jnp.full((num_queues, depth), -1, jnp.int32),
        sq_tail=jnp.zeros((num_queues,), jnp.int32),
        sq_head=jnp.zeros((num_queues,), jnp.int32),
        rr_ptr=jnp.zeros((n_devices,), jnp.int32),
        ticket_total=z(), doorbells=z(), completions=z(), dropped=z(),
        dev_dropped=jnp.zeros((n_devices,), jnp.int32),
        dev_enqueued=jnp.zeros((n_devices,), jnp.int32),
        dev_completed=jnp.zeros((n_devices,), jnp.int32),
        tenant_enqueued=jnp.zeros((n_tenants,), jnp.int32),
        tenant_dropped=jnp.zeros((n_tenants,), jnp.int32),
        tenant_completed=jnp.zeros((n_tenants,), jnp.int32),
    )


@pytree_dataclass
class SubmitReceipt:
    """What the wavefront learns from its enqueue (shapes match the request)."""

    queue: jax.Array      # (n,) int32 — queue each request landed in (-1 dropped/invalid)
    vslot: jax.Array      # (n,) int32 — virtual slot (monotonic) in that queue
    accepted: jax.Array   # (n,) bool
    ticket: jax.Array     # (n,) int32 — per-device command ordinal (the
    #                       fault-hash counter), -1 when not accepted
    n_accepted: jax.Array  # () int32
    n_dropped: jax.Array   # () int32 — valid requests rejected by back-pressure
    n_doorbells: jax.Array  # () int32 — distinct queues rung by this wavefront


def enqueue(
    qs: QueueState,
    keys: jax.Array,
    dst: jax.Array | None = None,
    is_write: jax.Array | None = None,
    valid: jax.Array | None = None,
    prio: jax.Array | int = PRIO_DEMAND,
    tenant: int = 0,
) -> Tuple[QueueState, SubmitReceipt]:
    """Submit a wavefront of commands into the SQ rings.

    Each valid request is routed to its block key's *device* (striping by
    :func:`repro.core.ssd.device_of_block`), then the i-th valid request
    *of that device* (in compact prefix-sum order — the per-device ticket)
    goes to queue ``group_base + (rr_ptr[dev] + i) % group_size`` at that
    queue's next virtual slot.  Requests that would overflow a full ring
    are dropped and counted — per device, so one saturated channel never
    back-pressures the others; callers treat a drop as "retry next
    wavefront" (the paper's thread would spin).

    ``prio`` tags the lane: demand commands (``PRIO_DEMAND``) drain before
    readahead (``PRIO_READAHEAD``) in :func:`service_all`.  ``tenant``
    (static, < ``n_tenants``) stamps the commands for the weighted-fair
    drain and the per-tenant conservation counters; one enqueue call is
    always a single tenant's wavefront.
    """
    n = keys.shape[0]
    nq, depth, nd = qs.num_queues, qs.depth, qs.n_devices
    gsize = qs.group_size
    if valid is None:
        valid = keys >= 0
    else:
        valid = valid & (keys >= 0)
    if dst is None:
        dst = jnp.full((n,), -1, jnp.int32)
    if is_write is None:
        is_write = jnp.zeros((n,), bool)
    prio = jnp.broadcast_to(jnp.asarray(prio, jnp.int32), (n,))
    if not 0 <= tenant < qs.n_tenants:
        raise ValueError(
            f"tenant {tenant} out of range for n_tenants={qs.n_tenants}")

    # --- device routing + ticket assignment (per-device exclusive cumsum) --
    dev = device_of_block(keys, nd, qs.stripe_blocks,
                          qs.failed_devices)                # (n,)
    onehot = (dev[:, None] == jnp.arange(nd, dtype=jnp.int32)[None, :]) \
        & valid[:, None]                                    # (n, nd)
    onehot = onehot.astype(jnp.int32)
    # i-th valid command *of its device* — the paper's atomic ticket, one
    # counter per device channel.
    ticket = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=0) - onehot, dev[:, None], axis=1)[:, 0]
    k_dev = jnp.sum(onehot, axis=0)                         # (nd,) per-device count
    k = jnp.sum(k_dev)                                      # () accepted upper bound

    queue = dev * gsize + (qs.rr_ptr[dev] + ticket) % gsize  # (n,)
    # position within this wavefront's allocation for that queue
    pos_in_q = ticket // gsize                              # (n,)
    vslot = qs.sq_tail[queue] + pos_in_q                    # (n,) monotonic slot

    # Ring-full back-pressure: a command fits iff vslot - head < depth.
    fits = (vslot - qs.sq_head[queue]) < depth
    accepted = valid & fits
    # NOTE: with round-robin tickets, drops are a suffix per queue, so the
    # accepted commands remain contiguous from each tail — ring stays dense.

    slot = (vslot % depth).astype(jnp.int32)
    # Per-device *accepted* ordinal: the command's lifetime-unique fault
    # ticket.  Base is the cumulative accepted counter (``dev_enqueued``),
    # so the ordinal is stable across wavefronts and across back-pressure
    # drops — wait() recomputes a command's fate from (device, ticket)
    # alone and must see exactly what the drain stamped.
    acc_oh = onehot * accepted.astype(jnp.int32)[:, None]   # (n, nd)
    arank = jnp.take_along_axis(
        jnp.cumsum(acc_oh, axis=0) - acc_oh, dev[:, None], axis=1)[:, 0]
    ticket_id = (qs.dev_enqueued[dev] + arank).astype(jnp.int32)
    # rejected rows scatter out of bounds and are dropped (never clobber a
    # live slot — the GPU analogue is "thread spins without writing").
    qidx = jnp.where(accepted, queue, nq)
    sidx = jnp.where(accepted, slot, 0)
    sq_key = qs.sq_key.at[qidx, sidx].set(keys, mode="drop")
    sq_dst = qs.sq_dst.at[qidx, sidx].set(dst, mode="drop")
    sq_is_write = qs.sq_is_write.at[qidx, sidx].set(is_write, mode="drop")
    sq_prio = qs.sq_prio.at[qidx, sidx].set(prio, mode="drop")
    sq_tenant = qs.sq_tenant.at[qidx, sidx].set(jnp.int32(tenant),
                                                mode="drop")
    sq_ticket = qs.sq_ticket.at[qidx, sidx].set(ticket_id, mode="drop")

    # New tails: per queue, number of accepted commands assigned to it.
    per_q = jnp.zeros((nq,), jnp.int32).at[queue].add(accepted.astype(jnp.int32))
    sq_tail = qs.sq_tail + per_q
    # One doorbell per queue that received at least one command (batched ring).
    n_doorbells = jnp.sum((per_q > 0).astype(jnp.int32))

    drops = valid & ~fits
    n_accepted = jnp.sum(accepted.astype(jnp.int32))
    n_dropped = jnp.sum(drops.astype(jnp.int32))
    receipt = SubmitReceipt(
        queue=jnp.where(accepted, queue, -1).astype(jnp.int32),
        vslot=jnp.where(accepted, vslot, -1).astype(jnp.int32),
        accepted=accepted,
        ticket=jnp.where(accepted, ticket_id, -1).astype(jnp.int32),
        n_accepted=n_accepted,
        n_dropped=n_dropped,
        n_doorbells=n_doorbells,
    )
    dev_drops = jnp.zeros((nd,), jnp.int32).at[dev].add(
        drops.astype(jnp.int32))
    dev_acc = jnp.zeros((nd,), jnp.int32).at[dev].add(
        accepted.astype(jnp.int32))
    t_i = jnp.int32(tenant)
    qs2 = QueueState(
        num_queues=nq, depth=depth, n_devices=nd,
        stripe_blocks=qs.stripe_blocks,
        n_tenants=qs.n_tenants, tenant_weights=qs.tenant_weights,
        failed_devices=qs.failed_devices,
        sq_key=sq_key, sq_dst=sq_dst, sq_is_write=sq_is_write,
        sq_prio=sq_prio, sq_tenant=sq_tenant, sq_ticket=sq_ticket,
        sq_tail=sq_tail, sq_head=qs.sq_head,
        rr_ptr=(qs.rr_ptr + k_dev) % gsize,
        ticket_total=qs.ticket_total + k,
        doorbells=qs.doorbells + n_doorbells,
        completions=qs.completions,
        dropped=qs.dropped + n_dropped,
        dev_dropped=qs.dev_dropped + dev_drops,
        dev_enqueued=qs.dev_enqueued + dev_acc,
        dev_completed=qs.dev_completed,
        tenant_enqueued=qs.tenant_enqueued.at[t_i].add(n_accepted),
        tenant_dropped=qs.tenant_dropped.at[t_i].add(n_dropped),
        tenant_completed=qs.tenant_completed,
    )
    return qs2, receipt


def enqueue_segments(
    qs: QueueState,
    segments,
    tenant: int = 0,
    impl: str = "auto",
) -> Tuple[QueueState, list]:
    """Submit several command segments of one tenant in a single fused pass.

    ``segments`` is a sequence of ``(keys, dst, is_write, valid, prio)``
    tuples in issue order (``None`` entries default exactly as in
    :func:`enqueue`); the result is bit-identical to calling
    :func:`enqueue` once per segment — same routing, same per-segment
    doorbells, same round-robin pointer advancement, same receipts — but
    the five SQ ring fields are each written by ONE combined scatter and
    the counter updates are folded into one :class:`QueueState`
    construction (the kernel-dispatch-layer submission hot path; see
    :func:`repro.kernels.ref.sq_enqueue_ref`).

    Returns ``(qs', receipts)`` with one :class:`SubmitReceipt` per
    segment.
    """
    nq, depth, nd = qs.num_queues, qs.depth, qs.n_devices
    gsize = qs.group_size
    if not 0 <= tenant < qs.n_tenants:
        raise ValueError(
            f"tenant {tenant} out of range for n_tenants={qs.n_tenants}")
    keys_l, dst_l, w_l, valid_l, prio_l = [], [], [], [], []
    bounds = []
    off = 0
    # static unroll: the segment list has trace-time-constant length
    for seg in segments:  # bamlint: ignore[BAM104]
        keys, dst, is_write, valid, prio = seg
        n = keys.shape[0]
        if valid is None:
            valid = keys >= 0
        else:
            valid = valid & (keys >= 0)
        if dst is None:
            dst = jnp.full((n,), -1, jnp.int32)
        if is_write is None:
            is_write = jnp.zeros((n,), bool)
        prio = jnp.broadcast_to(jnp.asarray(prio, jnp.int32), (n,))
        keys_l.append(keys)
        dst_l.append(dst)
        w_l.append(is_write)
        valid_l.append(valid)
        prio_l.append(prio)
        bounds.append((off, off + n))
        off += n
    (sq_key, sq_dst, sq_is_write, sq_prio, sq_tenant, sq_ticket, sq_tail,
     rr_ptr, queue, vslot, accepted, ticket_id, per_seg) = _ops.sq_enqueue(
        qs.sq_key, qs.sq_dst, qs.sq_is_write, qs.sq_prio, qs.sq_tenant,
        qs.sq_ticket, qs.sq_tail, qs.sq_head, qs.rr_ptr, qs.dev_enqueued,
        jnp.concatenate(keys_l), jnp.concatenate(dst_l),
        jnp.concatenate(w_l), jnp.concatenate(prio_l),
        jnp.concatenate(valid_l),
        seg_bounds=tuple(bounds), n_devices=nd,
        stripe_blocks=qs.stripe_blocks, tenant=tenant,
        failed_devices=qs.failed_devices, impl=impl)

    receipts = []
    for i, (s, e) in enumerate(bounds):
        acc = accepted[s:e]
        receipts.append(SubmitReceipt(
            queue=jnp.where(acc, queue[s:e], -1).astype(jnp.int32),
            vslot=jnp.where(acc, vslot[s:e], -1).astype(jnp.int32),
            accepted=acc,
            ticket=jnp.where(acc, ticket_id[s:e], -1).astype(jnp.int32),
            n_accepted=per_seg["n_accepted"][i],
            n_dropped=per_seg["n_dropped"][i],
            n_doorbells=per_seg["n_doorbells"][i],
        ))
    t_i = jnp.int32(tenant)
    qs2 = QueueState(
        num_queues=nq, depth=depth, n_devices=nd,
        stripe_blocks=qs.stripe_blocks,
        n_tenants=qs.n_tenants, tenant_weights=qs.tenant_weights,
        failed_devices=qs.failed_devices,
        sq_key=sq_key, sq_dst=sq_dst, sq_is_write=sq_is_write,
        sq_prio=sq_prio, sq_tenant=sq_tenant, sq_ticket=sq_ticket,
        sq_tail=sq_tail, sq_head=qs.sq_head,
        rr_ptr=rr_ptr,
        ticket_total=qs.ticket_total + jnp.sum(per_seg["n_tickets"]),
        doorbells=qs.doorbells + jnp.sum(per_seg["n_doorbells"]),
        completions=qs.completions,
        dropped=qs.dropped + jnp.sum(per_seg["n_dropped"]),
        dev_dropped=qs.dev_dropped + jnp.sum(per_seg["dev_dropped"], axis=0),
        dev_enqueued=qs.dev_enqueued + jnp.sum(per_seg["dev_accepted"],
                                               axis=0),
        dev_completed=qs.dev_completed,
        tenant_enqueued=qs.tenant_enqueued.at[t_i].add(
            jnp.sum(per_seg["n_accepted"])),
        tenant_dropped=qs.tenant_dropped.at[t_i].add(
            jnp.sum(per_seg["n_dropped"])),
        tenant_completed=qs.tenant_completed,
    )
    return qs2, receipts


@pytree_dataclass
class Completions:
    """Drained commands; filter with ``valid``, order by position.

    When readahead is in flight the entries are priority-major (demand
    commands lead, readahead trails, empty slots last) — the device retires
    the urgent class first.  Pure demand traffic keeps the plain
    (queue-major, slot) ring order, which is already class-sorted.
    """

    keys: jax.Array      # (num_queues*depth,) int32, -1 for empty slots
    dst: jax.Array       # (num_queues*depth,) int32
    is_write: jax.Array  # (num_queues*depth,) bool
    prio: jax.Array      # (num_queues*depth,) int32
    tenant: jax.Array    # (num_queues*depth,) int32 — issuing tenant id
    valid: jax.Array     # (num_queues*depth,) bool
    status: jax.Array    # (num_queues*depth,) int32 — 0 OK, 1 error (all
    #                      zero when the drain ran without a fault model)
    count: jax.Array     # () int32
    count_dev: jax.Array  # (n_devices,) int32 — drained per device channel
    count_tenant: jax.Array  # (n_tenants,) int32 — drained per tenant
    # Fault accounting over this drain (zeros when fault is disabled).
    error_dev: jax.Array     # (n_devices,) int32 — errored commands
    error_tenant: jax.Array  # (n_tenants,) int32
    retries_dev: jax.Array   # (n_devices,) int32 — re-issued attempts
    transient: jax.Array     # () int32 — attempt-level transient failures
    # Direction split of the error/retry counts: reads and writes charge
    # different device clocks, so the caller needs both sides separately
    # (error_dev == err_reads_dev + err_writes_dev, same for retries).
    err_reads_dev: jax.Array    # (n_devices,) int32
    err_writes_dev: jax.Array   # (n_devices,) int32
    retry_reads_dev: jax.Array  # (n_devices,) int32
    retry_writes_dev: jax.Array  # (n_devices,) int32


def service_all(qs: QueueState, fault=None
                ) -> Tuple[QueueState, Completions]:
    """The simulated NVMe controller: consume every pending SQ entry.

    Returns the drained command list; the caller performs the actual block
    fetch/write against a :class:`~repro.core.storage.BlockStore` (that is
    the DMA) and charges simulated device time via the
    :class:`~repro.core.ssd.ArrayOfSSDs` cost model.  The drain is *per
    device*: ``count_dev`` reports how many commands each device channel
    retired, so the caller can charge each device its own Little's-law
    service time and take the max — the straggler, not the average, gates
    the wavefront.  Completion-side ring maintenance (head advancement, CQ
    doorbell) is folded into this drain: heads jump to tails, matching a CQ
    sweep that retires every entry — the paper's "one thread resets markers
    as far as possible" fast path.

    The drain is priority-arbitrated: demand-lane commands come back ahead
    of readahead-lane commands (stable within each class).  With
    ``n_tenants > 1`` each priority class is additionally drained
    weighted-fair across tenants (ascending virtual finish time
    ``(i+1)/weight[t]`` for the i-th pending command of tenant ``t``
    *within that class*), the per-tenant analogue of NVMe
    weighted-round-robin arbitration.

    ``fault`` (a :class:`~repro.core.ssd.FaultModel`, static) resolves
    each pending command's bounded retry loop from its ``(device,
    ticket)`` stamp: the per-command ``status`` codes ride the completion
    stream through the arbitration sort, and the per-device/per-tenant
    error and retry counts come back order-free.  Errored commands still
    *drain* (they consumed their ring slot and their attempts' device
    time); what they never do is deliver data — the caller must not fill
    a cache line from a status != 0 completion.  ``fault=None`` (or a
    disabled model) keeps every pre-existing output bit-identical.
    """
    pending = qs.sq_key >= 0
    nd, gsize = qs.n_devices, qs.group_size
    count = jnp.sum(pending.astype(jnp.int32))
    # Queues [d*group, (d+1)*group) belong to device d.
    count_dev = jnp.sum(
        pending.reshape(nd, gsize * qs.depth).astype(jnp.int32), axis=1)
    flat_pend = pending.reshape(-1)
    flat_prio = qs.sq_prio.reshape(-1)
    flat_tenant = qs.sq_tenant.reshape(-1)
    nt = qs.n_tenants
    tclasses = jnp.arange(nt, dtype=jnp.int32)
    count_tenant = jnp.sum(
        (flat_tenant[:, None] == tclasses[None, :]) & flat_pend[:, None],
        axis=0).astype(jnp.int32)
    # bamlint: ignore[BAM104] -- fault is static config, not a traced value
    if fault is not None and fault.enabled:
        # Entry device is positional: queue // group_size.  The retry loop
        # is closed-form over (device, ticket) — see command_status.
        dev_of_entry = (jnp.arange(qs.num_queues, dtype=jnp.int32)
                        // gsize)[:, None]
        ok_e, retries_e, transient_e = fault.command_status(
            dev_of_entry, qs.sq_ticket)
        err_e = pending & ~ok_e
        flat_status = jnp.where(err_e, jnp.int32(1),
                                jnp.int32(0)).reshape(-1)
        error_dev = jnp.sum(err_e.reshape(nd, gsize * qs.depth)
                            .astype(jnp.int32), axis=1)
        error_tenant = jnp.sum(
            (flat_tenant[:, None] == tclasses[None, :])
            & (err_e.reshape(-1))[:, None], axis=0).astype(jnp.int32)
        retries_dev = jnp.sum(
            jnp.where(pending, retries_e, 0).reshape(nd, gsize * qs.depth),
            axis=1).astype(jnp.int32)
        transient = jnp.sum(jnp.where(pending, transient_e,
                                      0)).astype(jnp.int32)

        def _dir_dev(mask, w):
            m = jnp.where(mask, w, 0).reshape(nd, gsize * qs.depth)
            return jnp.sum(m, axis=1).astype(jnp.int32)

        err_writes_dev = _dir_dev(err_e & qs.sq_is_write, 1)
        err_reads_dev = error_dev - err_writes_dev
        retry_writes_dev = _dir_dev(pending & qs.sq_is_write, retries_e)
        retry_reads_dev = retries_dev - retry_writes_dev
    else:
        flat_status = jnp.zeros_like(flat_prio)
        error_dev = jnp.zeros((nd,), jnp.int32)
        error_tenant = jnp.zeros((nt,), jnp.int32)
        retries_dev = jnp.zeros((nd,), jnp.int32)
        transient = jnp.zeros((), jnp.int32)
        err_reads_dev = err_writes_dev = jnp.zeros((nd,), jnp.int32)
        retry_reads_dev = retry_writes_dev = jnp.zeros((nd,), jnp.int32)
    flat = (qs.sq_key.reshape(-1), qs.sq_dst.reshape(-1),
            qs.sq_is_write.reshape(-1), flat_prio, flat_tenant, flat_pend,
            flat_status)

    # Demand first, readahead second, empty slots last; stable keeps
    # queue-major order within each class.  When every pending command is
    # demand-lane (and, multi-tenant, only one tenant has commands in
    # flight) the unsorted rings are already correctly ordered, so the
    # arbitration sort (an argsort over all num_queues*depth slots) only
    # runs when readahead or genuine tenant contention is in flight.
    def _arbitrate(f):
        pend, prio = f[5], f[3]
        sort_key = jnp.where(pend, prio, jnp.int32(jnp.iinfo(jnp.int32).max))
        order = jnp.argsort(sort_key, stable=True)
        return tuple(x[order] for x in f)

    if nt == 1:
        has_ra = jnp.any(flat_pend & (flat_prio != PRIO_DEMAND))
        (keys_o, dst_o, is_write_o, prio_o, ten_o, pend_o,
         status_o) = jax.lax.cond(has_ra, _arbitrate, lambda f: f, flat)
    else:
        # Weighted-fair queuing across tenants.  Within each priority
        # class, the i-th pending command of tenant t (in ring order)
        # finishes at virtual time (i+1)/w_t; draining in ascending
        # virtual time interleaves tenants in proportion to their weights.
        # Ranks are per (tenant, priority) class so a tenant's demand
        # backlog never delays its own readahead relative to other
        # tenants' readahead — WFQ orders strictly *within* a class.
        def _wfq(f):
            prio, ten, pend = f[3], f[4], f[5]
            w = jnp.asarray(qs.tenant_weights, jnp.float32)
            cls = ten * 2 + jnp.clip(prio, 0, 1)         # (tenant, prio)
            cids = jnp.arange(2 * nt, dtype=jnp.int32)
            oh = ((cls[:, None] == cids[None, :])
                  & pend[:, None]).astype(jnp.int32)
            rank = jnp.take_along_axis(
                jnp.cumsum(oh, axis=0) - oh, cls[:, None], axis=1)[:, 0]
            vfinish = (rank + 1).astype(jnp.float32) / w[ten]
            sort_key = jnp.where(pend, prio,
                                 jnp.int32(jnp.iinfo(jnp.int32).max))
            pos = jnp.arange(pend.shape[0], dtype=jnp.int32)
            order = jnp.lexsort((pos, vfinish, sort_key))
            return tuple(x[order] for x in f)

        has_ra = jnp.any(flat_pend & (flat_prio != PRIO_DEMAND))
        multi = jnp.sum((count_tenant > 0).astype(jnp.int32)) > 1
        (keys_o, dst_o, is_write_o, prio_o, ten_o, pend_o,
         status_o) = jax.lax.cond(has_ra | multi, _wfq, lambda f: f, flat)
    comps = Completions(
        keys=keys_o, dst=dst_o, is_write=is_write_o, prio=prio_o,
        tenant=ten_o, valid=pend_o, status=status_o,
        count=count, count_dev=count_dev,
        count_tenant=count_tenant,
        error_dev=error_dev, error_tenant=error_tenant,
        retries_dev=retries_dev, transient=transient,
        err_reads_dev=err_reads_dev, err_writes_dev=err_writes_dev,
        retry_reads_dev=retry_reads_dev,
        retry_writes_dev=retry_writes_dev,
    )
    qs2 = QueueState(
        num_queues=qs.num_queues, depth=qs.depth, n_devices=qs.n_devices,
        stripe_blocks=qs.stripe_blocks,
        n_tenants=nt, tenant_weights=qs.tenant_weights,
        failed_devices=qs.failed_devices,
        sq_key=jnp.full_like(qs.sq_key, -1),
        sq_dst=jnp.full_like(qs.sq_dst, -1),
        sq_is_write=jnp.zeros_like(qs.sq_is_write),
        sq_prio=jnp.zeros_like(qs.sq_prio),
        sq_tenant=jnp.zeros_like(qs.sq_tenant),
        sq_ticket=jnp.full_like(qs.sq_ticket, -1),
        sq_tail=qs.sq_tail,
        sq_head=qs.sq_tail,           # all consumed
        rr_ptr=qs.rr_ptr,
        ticket_total=qs.ticket_total,
        doorbells=qs.doorbells + jnp.where(count > 0, jnp.int32(1), jnp.int32(0)),  # CQ doorbell
        completions=qs.completions + count,
        dropped=qs.dropped,
        dev_dropped=qs.dev_dropped,
        dev_enqueued=qs.dev_enqueued,
        dev_completed=qs.dev_completed + count_dev,
        tenant_enqueued=qs.tenant_enqueued,
        tenant_dropped=qs.tenant_dropped,
        tenant_completed=qs.tenant_completed + count_tenant,
    )
    return qs2, comps


@pytree_dataclass
class DrainReceipt:
    """Order-free accounting of one full ring drain.

    What :meth:`BamArray.wait` actually consumes from a drain: per-device
    read/write completion counts (the device-time charge basis) and the
    global/per-device/per-tenant completion totals.  Unlike
    :class:`Completions` no per-command stream is materialised and no
    arbitration sort runs — every field is a reduction whose value is
    independent of the WFQ/priority completion *order*.
    """

    count: jax.Array         # () int32 — commands drained
    count_dev: jax.Array     # (n_devices,) int32
    count_tenant: jax.Array  # (n_tenants,) int32
    reads_dev: jax.Array     # (n_devices,) int32 — read commands per device
    writes_dev: jax.Array    # (n_devices,) int32 — write commands per device
    # Fault accounting (zeros when the drain ran without a fault model).
    errors_dev: jax.Array       # (n_devices,) int32 — errored commands
    errors_tenant: jax.Array    # (n_tenants,) int32
    err_reads_dev: jax.Array    # (n_devices,) int32 — errored read commands
    err_writes_dev: jax.Array   # (n_devices,) int32 — errored write commands
    retry_reads_dev: jax.Array  # (n_devices,) int32 — read re-issues
    retry_writes_dev: jax.Array  # (n_devices,) int32 — write re-issues
    transient_errors: jax.Array  # () int32 — attempt-level failures


def drain_accounting(qs: QueueState, impl: str = "auto", fault=None
                     ) -> Tuple[QueueState, DrainReceipt]:
    """Drain every pending SQ entry, returning accounting only.

    The fused-wait counterpart of :func:`service_all`: the post-drain
    :class:`QueueState` is bit-identical (cleared rings, heads advanced to
    tails, CQ doorbell, completion counters), but the completion *stream*
    is never materialised — callers that only charge device time and
    counters (``BamArray.wait``/``flush``) don't pay the WFQ lexsort or
    the 2×(num_queues·depth)-lane histograms.  Callers that need the
    arbitration order itself (:meth:`BamRuntime.drain`) keep
    :func:`service_all`.

    Per-device read/write counts rely on the enqueue routing invariant:
    every command in device *d*'s ring group has
    ``device_of_block(key) == d``, so group-reshaped sums equal the
    key-striped histograms over the drained stream.

    ``fault`` (static) adds order-free error/retry accounting from the
    ``sq_ticket`` stamps, exactly matching what :func:`service_all` would
    report over the same rings; disabled, the receipt's fault fields are
    zeros and every pre-existing output stays bit-identical.
    """
    (count, count_dev, count_tenant, reads_dev, writes_dev,
     fstats) = _ops.wfq_drain(
        qs.sq_key, qs.sq_is_write, qs.sq_tenant, qs.sq_ticket,
        n_devices=qs.n_devices, n_tenants=qs.n_tenants, fault=fault,
        impl=impl)
    qs2 = QueueState(
        num_queues=qs.num_queues, depth=qs.depth, n_devices=qs.n_devices,
        stripe_blocks=qs.stripe_blocks,
        n_tenants=qs.n_tenants, tenant_weights=qs.tenant_weights,
        failed_devices=qs.failed_devices,
        sq_key=jnp.full_like(qs.sq_key, -1),
        sq_dst=jnp.full_like(qs.sq_dst, -1),
        sq_is_write=jnp.zeros_like(qs.sq_is_write),
        sq_prio=jnp.zeros_like(qs.sq_prio),
        sq_tenant=jnp.zeros_like(qs.sq_tenant),
        sq_ticket=jnp.full_like(qs.sq_ticket, -1),
        sq_tail=qs.sq_tail,
        sq_head=qs.sq_tail,           # all consumed
        rr_ptr=qs.rr_ptr,
        ticket_total=qs.ticket_total,
        doorbells=qs.doorbells + jnp.where(count > 0, jnp.int32(1),
                                           jnp.int32(0)),  # CQ doorbell
        completions=qs.completions + count,
        dropped=qs.dropped,
        dev_dropped=qs.dev_dropped,
        dev_enqueued=qs.dev_enqueued,
        dev_completed=qs.dev_completed + count_dev,
        tenant_enqueued=qs.tenant_enqueued,
        tenant_dropped=qs.tenant_dropped,
        tenant_completed=qs.tenant_completed + count_tenant,
    )
    return qs2, DrainReceipt(count=count, count_dev=count_dev,
                             count_tenant=count_tenant,
                             reads_dev=reads_dev, writes_dev=writes_dev,
                             **fstats)


def in_flight(qs: QueueState) -> jax.Array:
    """Current total queue depth in use (the Little's-law Q_d observable)."""
    return jnp.sum(qs.sq_tail - qs.sq_head)


def in_flight_per_device(qs: QueueState) -> jax.Array:
    """Per-device in-flight depth: (n_devices,) — each channel's own Q_d."""
    return jnp.sum((qs.sq_tail - qs.sq_head)
                   .reshape(qs.n_devices, qs.group_size), axis=1)


def in_flight_per_tenant(qs: QueueState) -> jax.Array:
    """Pending commands currently in the rings per tenant: (n_tenants,).

    Counts live SQ entries by their tenant stamp (equivalently,
    ``tenant_enqueued - tenant_completed`` — the conservation law the
    property tests check).
    """
    pend = (qs.sq_key >= 0).reshape(-1)
    ten = qs.sq_tenant.reshape(-1)
    return jnp.sum(
        (ten[:, None] == jnp.arange(qs.n_tenants, dtype=jnp.int32)[None, :])
        & pend[:, None], axis=0).astype(jnp.int32)
