"""Storage-device models and the Little's-law throughput math (paper §II-C, Table III).

The paper's design analysis is governed by Little's law::

    T x L = Q_d

where ``T`` is target IOPs, ``L`` the mean device latency, and ``Q_d`` the
queue depth that must be kept in flight.  On this container there is no real
NVMe device, so the *device* is an explicit, parameterised cost model; every
number in the presets below is lifted from the paper (Table III and §II-C
measurements).  The BaM software stack (queues/cache/coalescer) is agnostic
to which preset backs it — exactly the paper's claim (d): "BaM design is
agnostic to the SSD storage medium used".
"""
from __future__ import annotations

import dataclasses
import math

# Measured peak PCIe Gen4 x16 bandwidth from the paper (26.3 GBps measured,
# 32 GBps nominal); per-SSD links are Gen4 x4.
PCIE_GEN4_X16_BW = 26.3e9  # bytes/s, measured (paper §II-A)
PCIE_GEN4_X4_BW = 6.575e9  # bytes/s, x16/4


@dataclasses.dataclass(frozen=True)
class SSDSpec:
    """One storage technology row of Table III."""

    name: str
    read_iops_512: float
    read_iops_4k: float
    write_iops_512: float
    write_iops_4k: float
    latency_s: float
    dwpd: float
    dollars_per_gb: float
    link_bw: float = PCIE_GEN4_X4_BW  # per-device link

    def read_iops(self, block_bytes: int) -> float:
        """Interpolate peak random-read IOPs for a block size (512B..4KB anchor points)."""
        return _interp_iops(block_bytes, self.read_iops_512, self.read_iops_4k)

    def write_iops(self, block_bytes: int) -> float:
        return _interp_iops(block_bytes, self.write_iops_512, self.write_iops_4k)


def _interp_iops(block_bytes: int, iops_512: float, iops_4k: float) -> float:
    if block_bytes <= 512:
        return iops_512
    if block_bytes >= 4096:
        # Beyond 4KB the device is bandwidth-bound: scale down by size.
        return iops_4k * 4096.0 / block_bytes
    # Log-linear interpolation between the two anchor points.
    t = (math.log2(block_bytes) - 9.0) / 3.0  # 2^9=512, 2^12=4096
    return iops_512 * (iops_4k / iops_512) ** t


# --- Table III presets -------------------------------------------------------
DRAM_DIMM = SSDSpec(
    name="dram-dimm",
    read_iops_512=10e6, read_iops_4k=10e6,
    write_iops_512=10e6, write_iops_4k=10e6,
    latency_s=0.1e-6, dwpd=1000.0, dollars_per_gb=11.13,
    link_bw=PCIE_GEN4_X16_BW,
)
INTEL_OPTANE_P5800X = SSDSpec(
    name="intel-optane-p5800x",
    read_iops_512=5.1e6, read_iops_4k=1.5e6,
    write_iops_512=1.0e6, write_iops_4k=1.5e6,
    latency_s=11e-6, dwpd=100.0, dollars_per_gb=2.54,
)
SAMSUNG_ZNAND_P1735 = SSDSpec(
    name="samsung-znand-p1735",
    read_iops_512=1.1e6, read_iops_4k=1.6e6,
    write_iops_512=351e3, write_iops_4k=351e3,
    latency_s=25e-6, dwpd=3.0, dollars_per_gb=2.56,
)
SAMSUNG_980PRO = SSDSpec(
    name="samsung-980pro",
    read_iops_512=750e3, read_iops_4k=750e3,
    write_iops_512=172e3, write_iops_4k=172e3,
    latency_s=324e-6, dwpd=0.3, dollars_per_gb=0.51,
)

SSD_PRESETS: dict[str, SSDSpec] = {
    s.name: s
    for s in (DRAM_DIMM, INTEL_OPTANE_P5800X, SAMSUNG_ZNAND_P1735, SAMSUNG_980PRO)
}


# --- block -> device striping ------------------------------------------------
def _alive_devices(n_devices: int, failed_devices) -> tuple:
    """Surviving device ids, in order, after masking out hard failures."""
    failed = frozenset(int(d) for d in failed_devices)
    bad = [d for d in failed if d < 0 or d >= n_devices]
    if bad:
        raise ValueError(f"failed_devices {sorted(bad)} out of range for "
                         f"n_devices={n_devices}")
    alive = tuple(d for d in range(n_devices) if d not in failed)
    if not alive:
        raise ValueError("all devices marked failed: nothing left to route to")
    return alive


def device_of_block(keys, n_devices: int, stripe_blocks: int = 1,
                    failed_devices=()):
    """Stripe block keys across the array's devices (round-robin by stripe).

    ``stripe_blocks`` is the striping unit: device = ``(key // stripe) %
    n_devices``.  Unit 1 (the default) interleaves at cache-line grain and
    balances even popularity-skewed streams (hot keys scatter over all
    channels); coarse stripes model shard/column-aligned placement, where a
    hot region lives on one device and shows up as a straggler.

    ``failed_devices`` lists hard-failed device ids: blocks whose home
    stripe lands on a dead channel remap deterministically across the
    survivors (``alive[(key // stripe) % n_alive]``), so a device dropout
    degrades into amplified load on the remaining stripes instead of lost
    commands.  Empty (the default) leaves the routing bit-identical to the
    fault-free path.

    Works on Python ints and on traced int arrays; invalid keys (< 0) map to
    device 0 so they can be masked downstream without out-of-range scatters.
    The same function routes SQ commands (:mod:`repro.core.queues`) and
    charges per-device service time, so the two can never disagree.
    """
    if isinstance(keys, int):
        if keys < 0:
            return 0
        dev = (keys // stripe_blocks) % n_devices
        if failed_devices:
            alive = _alive_devices(n_devices, failed_devices)
            if dev not in alive:
                dev = alive[(keys // stripe_blocks) % len(alive)]
        return dev
    import jax.numpy as jnp

    k = jnp.asarray(keys)
    dev = ((k // stripe_blocks) % n_devices).astype(jnp.int32)
    if failed_devices:
        alive = _alive_devices(n_devices, failed_devices)
        alive_t = jnp.asarray(alive, dtype=jnp.int32)
        remap = alive_t[((k // stripe_blocks) % len(alive)).astype(jnp.int32)]
        dead = jnp.zeros(dev.shape, dtype=bool)
        for d in failed_devices:
            dead = dead | (dev == int(d))
        dev = jnp.where(dead, remap, dev)
    return jnp.where(k >= 0, dev, 0).astype(jnp.int32)


def device_histogram(keys, n_devices: int, mask=None,
                     stripe_blocks: int = 1, failed_devices=()):
    """Count valid block keys per device: (n_devices,) int32 (jit-safe)."""
    import jax.numpy as jnp

    k = jnp.asarray(keys)
    valid = k >= 0
    if mask is not None:
        valid = valid & mask
    dev = device_of_block(k, n_devices, stripe_blocks, failed_devices)
    # one-hot reduction, not a scatter-add: integer sums are order-free
    # (bit-identical) and XLA:CPU vectorizes the (m, n_devices) sum where
    # it would serialize m scattered updates
    onehot = (dev[..., None] == jnp.arange(n_devices, dtype=jnp.int32)) \
        & valid[..., None]
    return jnp.sum(onehot, axis=tuple(range(onehot.ndim - 1)),
                   dtype=jnp.int32)


# --- fault injection ---------------------------------------------------------
_FAULT_PROB_BITS = 24   # error rates quantize to multiples of 2^-24 (exact)


def _fmix32(h):
    """murmur3 finalizer over uint32 — avalanches the counter hash so the
    per-(device, ticket, attempt) failure decisions are statistically
    uniform while staying a pure function of the inputs (bit-reproducible,
    no host RNG, safe under jit/donation/bucketing)."""
    import jax.numpy as jnp

    h = jnp.asarray(h).astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Deterministic fault injection for an :class:`ArrayOfSSDs`.

    * ``transient_error_rate`` — per-command-attempt failure probability,
      decided by a counter-based hash of ``(device, ticket, attempt)``;
      quantized to multiples of ``2^-24`` so the threshold compare is an
      exact integer test.
    * ``tail_latency_mult`` — service-time multiplier charged for each
      retried attempt (backoff: a retry costs more than a first issue).
    * ``failed_devices`` — hard-failed device ids; their blocks remap
      across survivors (see :func:`device_of_block`) and any command that
      still reaches them errors immediately.
    * ``retry_budget`` — bounded re-issues per command before the command
      retires with an error status and the read degrades.
    * ``seed`` — hash salt; different seeds give independent fault
      schedules, the same seed is bit-reproducible.

    The model is **static configuration** (hashable, compared by value):
    the traced fault computation is only built when :attr:`enabled`, so a
    disabled model leaves every compiled graph bit-identical to the
    fault-free path.
    """

    transient_error_rate: float = 0.0
    tail_latency_mult: float = 1.0
    failed_devices: tuple = ()
    retry_budget: int = 3
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.transient_error_rate <= 1.0:
            raise ValueError("transient_error_rate must be in [0, 1]")
        if self.tail_latency_mult < 1.0:
            raise ValueError("tail_latency_mult must be >= 1 (a retry can "
                             "not be cheaper than a first issue)")
        if self.retry_budget < 0:
            raise ValueError("retry_budget must be >= 0")
        object.__setattr__(
            self, "failed_devices",
            tuple(sorted({int(d) for d in self.failed_devices})))

    @property
    def threshold(self) -> int:
        """Integer failure threshold: P(attempt fails) = threshold / 2^24."""
        return int(round(self.transient_error_rate * (1 << _FAULT_PROB_BITS)))

    @property
    def enabled(self) -> bool:
        return self.threshold > 0 or bool(self.failed_devices)

    def attempt_failed(self, dev, ticket, attempt: int):
        """Did attempt #``attempt`` of command ``ticket`` on ``dev`` fail?

        Pure counter-based decision (jit-safe, order-free): hash the three
        counters with the static seed, compare the low 24 bits against the
        quantized rate.  ``dev``/``ticket`` may be traced int arrays;
        ``attempt`` is a static Python int (the retry loop is unrolled).
        """
        import jax.numpy as jnp

        d = jnp.asarray(dev).astype(jnp.uint32)
        t = jnp.asarray(ticket).astype(jnp.uint32)
        h = (d * jnp.uint32(0x9E3779B1)) \
            ^ (t * jnp.uint32(0x85EBCA77)) \
            ^ jnp.uint32((attempt * 0xC2B2AE3D) & 0xFFFFFFFF) \
            ^ jnp.uint32((self.seed * 0x27D4EB2F) & 0xFFFFFFFF)
        h = _fmix32(h)
        mask = jnp.uint32((1 << _FAULT_PROB_BITS) - 1)
        return (h & mask) < jnp.uint32(self.threshold)

    def command_status(self, dev, ticket):
        """Resolve a command's bounded retry loop in closed form.

        Returns ``(ok, retries, transient)`` elementwise over ``dev`` /
        ``ticket`` (broadcast together):

        * ``ok`` — bool, the command eventually completed (some attempt
          ``<= retry_budget`` succeeded on a live device);
        * ``retries`` — int32, re-issues actually made (leading failed
          attempts, capped at the budget — the final failure of an
          exhausted command is not re-issued);
        * ``transient`` — int32, attempt-level transient failures
          (including the ones a retry later recovered).

        Rows with ``ticket < 0`` carry no command and report
        ``(True, 0, 0)``; a hard-failed device errors immediately without
        burning retries (the controller knows the channel is dead).  The
        retry loop is **statically unrolled** over ``retry_budget + 1``
        attempts, so the result is a pure jit-safe function of the inputs
        — ``wait`` and the drain recompute it independently and agree by
        construction.
        """
        import jax.numpy as jnp

        d = jnp.asarray(dev)
        t = jnp.asarray(ticket)
        d, t = jnp.broadcast_arrays(d, t)
        has_cmd = t >= 0
        if self.threshold == 0:
            all_failed = jnp.zeros(t.shape, dtype=bool)
            transient = jnp.zeros(t.shape, dtype=jnp.int32)
        else:
            prefix = jnp.ones(t.shape, dtype=bool)
            transient = jnp.zeros(t.shape, dtype=jnp.int32)
            for attempt in range(self.retry_budget + 1):
                prefix = prefix & self.attempt_failed(d, t, attempt)
                transient = transient + prefix.astype(jnp.int32)
            all_failed = prefix
        hard = jnp.zeros(t.shape, dtype=bool)
        for fd in self.failed_devices:
            hard = hard | (d == int(fd))
        live = has_cmd & ~hard
        ok = ~has_cmd | (live & ~all_failed)
        retries = jnp.where(
            live, jnp.minimum(transient, jnp.int32(self.retry_budget)),
            0).astype(jnp.int32)
        transient = jnp.where(live, transient, 0).astype(jnp.int32)
        return ok, retries, transient


# --- Little's law ------------------------------------------------------------
def required_queue_depth(target_iops: float, latency_s: float) -> int:
    """Q_d = T x L (paper §II-C)."""
    return int(math.ceil(target_iops * latency_s))


def sustained_rate(concurrent: float, latency_s: float, peak_iops: float) -> float:
    """Delivery rate for X concurrently-serviceable requests: X / (L + X/T).

    Approaches ``peak_iops`` when X >> T*L (paper §II-C).
    """
    if concurrent <= 0:
        return 0.0
    return concurrent / (latency_s + concurrent / peak_iops)


def target_iops_for_link(link_bw: float, block_bytes: int) -> float:
    """T such that T x block = link bandwidth (e.g. 26GBps/512B = 51M/s)."""
    return link_bw / block_bytes


def min_ssds_for_target(spec: SSDSpec, block_bytes: int, target_iops: float,
                        write: bool = False) -> int:
    per_dev = spec.write_iops(block_bytes) if write else spec.read_iops(block_bytes)
    per_dev = min(per_dev, spec.link_bw / block_bytes)
    return int(math.ceil(target_iops / per_dev))


@dataclasses.dataclass(frozen=True)
class ArrayOfSSDs:
    """N identical devices behind one accelerator link (the BaM prototype shape).

    ``stripe_blocks`` sets the block→device striping unit (see
    :func:`device_of_block`): 1 = cache-line interleave (BaM's layout),
    larger = shard-aligned placement.  ``fault`` injects deterministic
    command errors / device dropout (see :class:`FaultModel`); the default
    model is disabled and leaves every path bit-identical.
    """

    spec: SSDSpec
    n_devices: int = 1
    accel_link_bw: float = PCIE_GEN4_X16_BW  # GPU/TPU-side ingest bound
    stripe_blocks: int = 1
    fault: FaultModel = FaultModel()

    def peak_read_iops(self, block_bytes: int) -> float:
        dev = self.n_devices * min(
            self.spec.read_iops(block_bytes), self.spec.link_bw / block_bytes
        )
        return min(dev, self.accel_link_bw / block_bytes)

    def peak_write_iops(self, block_bytes: int) -> float:
        dev = self.n_devices * min(
            self.spec.write_iops(block_bytes), self.spec.link_bw / block_bytes
        )
        return min(dev, self.accel_link_bw / block_bytes)

    def service_time(self, n_requests: int, block_bytes: int, *,
                     queue_depth_limit: int | None = None,
                     write: bool = False) -> float:
        """Simulated wall-clock to drain ``n_requests`` random accesses.

        X/(L + X/T) delivery rate, optionally capped by the total in-flight
        budget (num queues x depth) — the knob the IOPS benchmark sweeps.
        """
        if n_requests <= 0:
            return 0.0
        peak = (self.peak_write_iops if write else self.peak_read_iops)(block_bytes)
        concurrent = float(n_requests)
        if queue_depth_limit is not None:
            concurrent = min(concurrent, float(queue_depth_limit))
        rate = sustained_rate(concurrent, self.spec.latency_s, peak)
        return n_requests / rate

    def service_time_traced(self, n_requests, block_bytes: int, *,
                            queue_depth_limit: int | None = None,
                            write: bool = False):
        """Jit-safe version of :meth:`service_time` for traced request counts.

        All device constants are Python floats (static); only ``n_requests``
        is traced.  Returns a float32 scalar array of simulated seconds.
        """
        import jax.numpy as jnp

        peak = (self.peak_write_iops if write else self.peak_read_iops)(block_bytes)
        n = n_requests.astype(jnp.float32)
        concurrent = n
        if queue_depth_limit is not None:
            concurrent = jnp.minimum(concurrent, float(queue_depth_limit))
        rate = concurrent / (self.spec.latency_s + concurrent / peak)
        return jnp.where(n > 0, n / jnp.maximum(rate, 1e-30), 0.0)

    # --- per-device channel model (paper §IV-A, Fig. 7) ------------------
    def per_device_peak_iops(self, block_bytes: int, *, write: bool = False
                             ) -> float:
        """One device's peak, capped by its own x4 link (no array ceiling)."""
        iops = (self.spec.write_iops if write else self.spec.read_iops)(
            block_bytes)
        return min(iops, self.spec.link_bw / block_bytes)

    def service_time_per_device(self, n_per_device, block_bytes: int, *,
                                queue_depth_limit: int | None = None,
                                write: bool = False):
        """Wavefront drain time with per-device channels (host-side).

        ``n_per_device`` is the per-device request histogram.  Each device
        drains its own share at its own Little's-law rate (concurrency
        capped by *its* queue group's depth); the wavefront completes when
        the slowest device does — ``max`` over devices, not an average — so
        skew and stragglers are visible.  The accelerator-side x16 link is
        an aggregate floor: no matter how many devices, bytes must cross it.

        Returns ``(t_total, t_per_device)``.
        """
        assert len(n_per_device) == self.n_devices
        peak = self.per_device_peak_iops(block_bytes, write=write)
        t_dev = []
        total = 0.0
        for n in n_per_device:
            n = float(n)
            total += n
            if n <= 0:
                t_dev.append(0.0)
                continue
            conc = n if queue_depth_limit is None else min(
                n, float(queue_depth_limit))
            rate = conc / (self.spec.latency_s + conc / peak)
            t_dev.append(n / rate)
        t_link = total * block_bytes / self.accel_link_bw
        return max(max(t_dev), t_link), t_dev

    def service_time_per_device_traced(self, n_per_device, block_bytes: int, *,
                                       queue_depth_limit: int | None = None,
                                       write: bool = False):
        """Jit-safe :meth:`service_time_per_device` for traced histograms.

        ``n_per_device`` is a traced ``(n_devices,)`` int array; device
        constants stay static.  Returns ``(t_total, t_per_device)`` float32.
        """
        import jax.numpy as jnp

        peak = self.per_device_peak_iops(block_bytes, write=write)
        n = n_per_device.astype(jnp.float32)
        conc = n
        if queue_depth_limit is not None:
            conc = jnp.minimum(conc, float(queue_depth_limit))
        rate = conc / (self.spec.latency_s + conc / peak)
        t_dev = jnp.where(n > 0, n / jnp.maximum(rate, 1e-30), 0.0)
        t_link = jnp.sum(n) * float(block_bytes) / self.accel_link_bw
        return jnp.maximum(jnp.max(t_dev), t_link), t_dev

    def cost_usd(self, capacity_gb: float) -> float:
        return capacity_gb * self.spec.dollars_per_gb
