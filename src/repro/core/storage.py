"""Block-store backends for the BaM storage tier.

Two interchangeable backends sit below the BaM queues/cache:

* ``SimStorage`` — the data lives on the host (a numpy array or ``np.memmap``)
  and is fetched with ``jax.pure_callback`` from inside jitted code.  This is
  the *functional* backend used by the application examples and tests: real
  data, real gathers, host round-trip standing in for the NVMe DMA.

* ``HBMStorage`` — the data is an in-graph ``jnp`` array (shardable across the
  mesh).  This is the *dry-run/roofline* backend: the compiler sees the gather
  traffic of on-demand fetches, so ``cost_analysis()`` and the HLO collective
  schedule account for the BaM data path.  On a real deployment this tier is
  host/remote memory reached by DMA; the software above is identical.

Both expose ``fetch_blocks(keys) -> (n, block_elems)`` with sentinel keys
(< 0) returning zeros, and a write path for the BaM write support.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Protocol

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback as _io_callback


class BlockStore(Protocol):
    num_blocks: int
    block_elems: int
    dtype: jnp.dtype

    def fetch_blocks(self, keys: jax.Array) -> jax.Array: ...
    def write_blocks(self, keys: jax.Array, lines: jax.Array) -> None: ...


@dataclasses.dataclass
class SimStorage:
    """Host-resident block store fetched via pure_callback (the 'SSD')."""

    data: np.ndarray  # (num_blocks, block_elems)

    def __post_init__(self):
        assert self.data.ndim == 2, "block store must be (num_blocks, block_elems)"

    @property
    def num_blocks(self) -> int:
        return self.data.shape[0]

    @property
    def block_elems(self) -> int:
        return self.data.shape[1]

    @property
    def dtype(self):
        return jnp.dtype(self.data.dtype)

    def _host_fetch(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys)
        safe = np.clip(keys, 0, self.num_blocks - 1)
        out = self.data[safe]
        out[keys < 0] = 0
        return out

    def fetch_blocks(self, keys: jax.Array) -> jax.Array:
        out_shape = jax.ShapeDtypeStruct((keys.shape[0], self.block_elems), self.dtype)
        return jax.pure_callback(self._host_fetch, out_shape, keys, vmap_method="sequential")

    def _host_write(self, keys: np.ndarray, lines: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys)
        mask = keys >= 0
        self.data[keys[mask]] = np.asarray(lines)[mask]
        return np.zeros((), np.int32)

    def write_blocks(self, keys: jax.Array, lines: jax.Array) -> jax.Array:
        # io_callback: ordered side effect (a write IOP).
        return _io_callback(
            self._host_write, jax.ShapeDtypeStruct((), jnp.int32), keys, lines,
            ordered=True,
        )

    @staticmethod
    def from_array(arr: np.ndarray, block_elems: int) -> "SimStorage":
        flat = np.ascontiguousarray(arr).reshape(-1)
        pad = (-flat.shape[0]) % block_elems
        if pad:
            flat = np.concatenate([flat, np.zeros(pad, flat.dtype)])
        return SimStorage(flat.reshape(-1, block_elems))


@jax.tree_util.register_pytree_node_class
class HBMStorage:
    """In-graph block store (a shardable cold tier the compiler can see)."""

    def __init__(self, data: jax.Array):
        self.data = data  # (num_blocks, block_elems)

    @property
    def num_blocks(self) -> int:
        return self.data.shape[0]

    @property
    def block_elems(self) -> int:
        return self.data.shape[1]

    @property
    def dtype(self):
        return self.data.dtype

    def fetch_blocks(self, keys: jax.Array) -> jax.Array:
        safe = jnp.clip(keys, 0, self.num_blocks - 1)
        out = jnp.take(self.data, safe, axis=0)
        return jnp.where((keys >= 0)[:, None], out, 0)

    def write_blocks(self, keys: jax.Array, lines: jax.Array) -> "HBMStorage":
        safe = jnp.clip(keys, 0, self.num_blocks - 1)
        cur = jnp.take(self.data, safe, axis=0)
        lines = jnp.where((keys >= 0)[:, None], lines, cur)
        return HBMStorage(self.data.at[safe].set(lines))

    # pytree plumbing -----------------------------------------------------
    def tree_flatten(self):
        return (self.data,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    @staticmethod
    def from_array(arr: jax.Array, block_elems: int) -> "HBMStorage":
        flat = arr.reshape(-1)
        pad = (-flat.shape[0]) % block_elems
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
        return HBMStorage(flat.reshape(-1, block_elems))
