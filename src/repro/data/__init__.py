from repro.data.pipeline import DataConfig, Loader, make_loader
from repro.data.tokens import TokenStore, synth_corpus

__all__ = ["DataConfig", "Loader", "make_loader", "TokenStore",
           "synth_corpus"]
