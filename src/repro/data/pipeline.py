"""Sharded deterministic input pipeline with prefetch and skip-resume.

Design for 1000+ nodes:

* ``batch_for_step(step)`` is a **pure function** of (step, rank): restart
  and elastic re-mesh replay identically with zero coordination — this is
  the skip-resume mechanism (no iterator state to checkpoint).
* Each data-parallel rank reads a disjoint, strided slice of the token
  stream (memmap: bounded-latency reads — no network tail / stragglers).
* A background thread prefetches ``depth`` steps ahead (host-side double
  buffering; the device-side analogue is the BaM software pipeline in
  ``core/pipeline.py``).
* ``skip_slow_shard``: if a rank's read exceeds ``slow_ms``, the loader
  serves that rank's *previous* batch instead of stalling the step's
  collectives (bounded-staleness straggler mitigation).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Iterator, Optional

import numpy as np

from repro.data.tokens import TokenStore

__all__ = ["DataConfig", "Loader", "make_loader"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    n_ranks: int = 1
    rank: int = 0
    prefetch_depth: int = 2
    skip_slow_shard: bool = False
    slow_ms: float = 100.0


class Loader:
    def __init__(self, store: TokenStore, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_ranks == 0
        self.store = store
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_ranks
        self._prev = None
        self._q: Optional[queue.Queue] = None
        self._thread = None
        self._stop = threading.Event()

    # -- deterministic addressing ------------------------------------
    def batch_for_step(self, step: int) -> dict:
        """Pure (step, rank) -> batch; the skip-resume primitive."""
        cfg = self.cfg
        span = cfg.seq_len + 1
        t0 = time.monotonic()
        rows = []
        for b in range(self.local_batch):
            gidx = step * cfg.global_batch + self.cfg.rank \
                * self.local_batch + b
            offset = (gidx * span * 7919) % max(self.store.n_tokens - span,
                                                1)
            rows.append(self.store.read(offset, span))
        took_ms = (time.monotonic() - t0) * 1e3
        arr = np.stack(rows)
        if self.cfg.skip_slow_shard and took_ms > self.cfg.slow_ms \
                and self._prev is not None:
            return self._prev                   # bounded staleness
        batch = {"tokens": arr[:, :-1].astype(np.int32),
                 "labels": arr[:, 1:].astype(np.int32)}
        self._prev = batch
        return batch

    # -- prefetching iterator ----------------------------------------
    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        cfg = self.cfg
        q: queue.Queue = queue.Queue(maxsize=cfg.prefetch_depth)
        self._q = q
        self._stop.clear()

        def worker():
            s = start_step
            while not self._stop.is_set():
                try:
                    q.put((s, self.batch_for_step(s)), timeout=0.1)
                    s += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        try:
            while True:
                s, b = q.get()
                yield b
        finally:
            self.stop()

    def stop(self):
        self._stop.set()
        if self._q is not None:
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass


def make_loader(store_path, seq_len: int, global_batch: int, *,
                n_ranks: int = 1, rank: int = 0, **kw) -> Loader:
    store = TokenStore.open(store_path)
    return Loader(store, DataConfig(seq_len=seq_len,
                                    global_batch=global_batch,
                                    n_ranks=n_ranks, rank=rank, **kw))
