"""Token store: memmap-backed corpus + deterministic synthetic generator.

The synthetic corpus is a Zipf-ish Markov token stream — enough structure
that a ~100M-param model visibly learns (loss drops) in a few hundred
steps, which is what the end-to-end example asserts.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np

__all__ = ["synth_corpus", "TokenStore"]


def synth_corpus(path, *, n_tokens: int, vocab: int, seed: int = 0,
                 order: int = 1) -> Path:
    """Write a deterministic synthetic token stream to ``path`` (memmap)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    rng = np.random.default_rng(seed)
    # sparse bigram transition structure: each context prefers ~8 tokens
    n_ctx = min(vocab * 4, 65536)
    prefer = rng.integers(0, vocab, size=(n_ctx, 8), dtype=np.int32)
    out = np.empty((n_tokens,), np.int32)
    state = 0
    # vectorised blocks: choose preferred token w.p. 0.9, uniform otherwise
    block = 1 << 16
    pos = 0
    while pos < n_tokens:
        n = min(block, n_tokens - pos)
        u = rng.random(n)
        pick = rng.integers(0, 8, size=n)
        rand_tok = rng.integers(0, vocab, size=n, dtype=np.int32)
        for i in range(n):                      # cheap chain at build time
            t = prefer[state, pick[i]] if u[i] < 0.9 else rand_tok[i]
            out[pos + i] = t
            if order <= 1:
                state = int(t) % n_ctx          # pure bigram: learnable
            else:
                state = (state * 31 + int(t)) % n_ctx
        pos += n
    mm = np.memmap(path, dtype=np.int32, mode="w+", shape=(n_tokens,))
    mm[:] = out
    mm.flush()
    return path


@dataclasses.dataclass
class TokenStore:
    """Memmap token stream with (step, rank)-addressable slicing."""

    path: Path
    n_tokens: int

    @staticmethod
    def open(path) -> "TokenStore":
        path = Path(path)
        mm = np.memmap(path, dtype=np.int32, mode="r")
        return TokenStore(path=path, n_tokens=mm.shape[0])

    def read(self, offset: int, n: int) -> np.ndarray:
        mm = np.memmap(self.path, dtype=np.int32, mode="r")
        idx = (offset + np.arange(n)) % self.n_tokens
        return np.asarray(mm[idx])
