from repro.distributed.sharding import (
    AxisRules, DEFAULT_RULES, activate, axes_to_spec, constrain,
    current_mesh, current_rules, param_shardings, spec_for,
)

__all__ = [
    "AxisRules", "DEFAULT_RULES", "activate", "axes_to_spec", "constrain",
    "current_mesh", "current_rules", "param_shardings", "spec_for",
]
