"""GPipe-style pipeline parallelism over the ``pod`` axis (optional).

The production mesh's leading axis can act as a pipeline instead of a data
axis: stages live on successive pods and microbatches flow through a
``shard_map`` + ``ppermute`` schedule. The classic GPipe utilisation
(M microbatches over P stages ⇒ (M)/(M+P-1) bubble efficiency) applies.

Kept deliberately small: a composable ``gpipe`` transform for a stacked
per-stage step function, exercised by tests on fake devices and available
to the launcher via ``--pp``. The DP/TP/EP paths inside each stage remain
auto-sharded (partial-manual shard_map over the pipeline axis only).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

__all__ = ["gpipe"]


def gpipe(stage_fn: Callable, n_stages: int, n_microbatches: int, *,
          axis: str = "pod", mesh=None):
    """Build a pipelined forward: ``y = pipe(stage_params, x)``.

    ``stage_fn(params_s, x) -> x`` is one stage's computation;
    ``stage_params`` is a pytree whose leaves are stacked on a leading
    stage dimension (sharded over ``axis``); ``x`` is the global batch,
    split into ``n_microbatches`` along dim 0.

    Schedule: at tick t, stage s processes microbatch (t - s); activations
    hop stage s -> s+1 via ``ppermute`` between ticks. Total ticks =
    M + P - 1 (the GPipe bubble).
    """
    assert n_microbatches >= 1

    def pipe(stage_params, x):
        B = x.shape[0]
        assert B % n_microbatches == 0
        mb = B // n_microbatches

        def per_stage(params_stacked, x_all):
            # params_stacked leaves: (1, ...) slice for this stage
            params = jax.tree_util.tree_map(lambda a: a[0], params_stacked)
            stage = jax.lax.axis_index(axis)
            xs = x_all.reshape(n_microbatches, mb, *x_all.shape[1:])
            n_ticks = n_microbatches + n_stages - 1
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

            def tick(carry, t):
                buf, outs = carry
                # stage 0 injects microbatch t (others got theirs via the
                # previous tick's ppermute)
                inject = jnp.where(t < n_microbatches,
                                   jnp.clip(t, 0, n_microbatches - 1), 0)
                x_in = jnp.where(stage == 0, xs[inject], buf)
                y = stage_fn(params, x_in)
                # the microbatch index this stage just produced
                mb_idx = t - stage
                is_last = stage == n_stages - 1
                live = (mb_idx >= 0) & (mb_idx < n_microbatches) & is_last
                outs = jax.lax.cond(
                    live,
                    lambda o: o.at[jnp.clip(mb_idx, 0,
                                            n_microbatches - 1)].set(y),
                    lambda o: o, outs)
                buf2 = jax.lax.ppermute(y, axis, perm)
                return (buf2, outs), None

            buf0 = jnp.zeros((mb, *x_all.shape[1:]), x_all.dtype)
            outs0 = jnp.zeros((n_microbatches, mb, *x_all.shape[1:]),
                              x_all.dtype)
            (_, outs), _ = jax.lax.scan(
                tick, (buf0, outs0), jnp.arange(n_ticks))
            # only the last stage holds real outputs; broadcast them so the
            # result is replicated over the pipeline axis
            outs = jax.lax.psum(
                jnp.where(stage == n_stages - 1, outs, 0.0), axis)
            return outs.reshape(B, *x_all.shape[1:])

        return compat.shard_map(
            per_stage, mesh=mesh,
            in_specs=(P(axis), P()), out_specs=P(),
            axis_names={axis}, check_vma=False,
        )(stage_params, x)

    return pipe
