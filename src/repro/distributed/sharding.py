"""Logical-axis sharding: one rule table maps model-space axis names onto
mesh axes, giving DP / FSDP(ZeRO-3) / TP / EP / SP from a single config.

Model code annotates every parameter and key activation with *logical*
axis names (``("batch", "seq", None)``); the active :class:`AxisRules`
resolves them to ``PartitionSpec``s for the active mesh.  Swapping the rule
table (not the model code) is how §Perf hillclimbs re-shard.

Default placement on the production mesh (pod, data, model):

=============  =====================  =============================
logical axis   mesh axes              gives
=============  =====================  =============================
batch          ("pod", "data")        DP over pods × data groups
w_embed        "data"                 ZeRO-3/FSDP weight sharding
heads/kv/ffn   "model"                Megatron TP
vocab          "model"                TP'd embedding + logits
experts        "model"                expert parallelism (EP)
kv_pages       "model"                BaM-paged KV pool striping —
                                      the paper's blocks-over-SSDs
                                      round-robin, mapped onto chips
long_seq       "model"                SP for 500k decode state
=============  =====================  =============================
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rule = Union[None, str, Tuple[str, ...]]

DEFAULT_RULES: dict[str, Rule] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    "head_dim": None,
    "act_ffn": "model",
    "enc_seq": None,
    # weights
    "w_embed": "data",          # ZeRO-3: shard the d_model dim of weights
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "experts": "model",
    "expert_cap": None,
    "w_inner": "model",         # xlstm/mamba inner dim
    "conv": None,
    # serving state
    "kv_pages": "model",        # paged KV pool striped over chips
    "kv_seq": "model",          # dense long-context KV sharded on seq (SP)
    "state_head": "model",      # recurrent state heads
    # data pipeline
    "host_batch": ("pod", "data"),
}


@dataclasses.dataclass(frozen=True)
class AxisRules:
    rules: Mapping[str, Rule]

    def resolve(self, name: Optional[str], mesh: Optional[Mesh]) -> Rule:
        if name is None:
            return None
        rule = self.rules.get(name, None)
        if rule is None or mesh is None:
            return None
        axes = mesh.axis_names
        if isinstance(rule, str):
            return rule if rule in axes else None
        picked = tuple(a for a in rule if a in axes)
        return picked if picked else None


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: AxisRules = AxisRules(DEFAULT_RULES)


_CTX = _Ctx()


@contextlib.contextmanager
def activate(mesh: Optional[Mesh], rules: Mapping[str, Rule] | None = None):
    """Enter a mesh + rule context; model annotations become constraints."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    if rules is not None:
        _CTX.rules = AxisRules(dict(rules))
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def current_rules() -> AxisRules:
    return _CTX.rules


def axes_to_spec(axes: Sequence[Optional[str]],
                 mesh: Optional[Mesh] = None,
                 rules: Optional[AxisRules] = None) -> P:
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    return P(*[rules.resolve(a, mesh) for a in axes])


def spec_for(axes, mesh=None, rules=None) -> P:
    return axes_to_spec(axes, mesh, rules)


def _axis_size(mesh: Mesh, rule: Rule) -> int:
    if rule is None:
        return 1
    if isinstance(rule, str):
        return mesh.shape[rule]
    n = 1
    for a in rule:
        n *= mesh.shape[a]
    return n


def _spec_for_shape(axes, shape, mesh, rules) -> P:
    """Resolve axes -> spec, dropping mesh axes that don't divide the dim.

    (e.g. hymba's 25 query heads over a 16-way model axis: the weight's
    flattened 1600 dim shards; the (B, 25, S, hd) activation skips it.)
    """
    parts = []
    for a, d in zip(axes, shape):
        rule = rules.resolve(a, mesh)
        if rule is not None and d % _axis_size(mesh, rule) != 0:
            rule = None
        parts.append(rule)
    return P(*parts)


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Annotate an activation with logical axes (no-op outside a mesh).

    Axes that don't divide the corresponding dim are silently dropped.
    """
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = _spec_for_shape(axes, x.shape, mesh, _CTX.rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def param_shardings(axes_tree: Any, mesh: Optional[Mesh] = None,
                    rules: Optional[AxisRules] = None,
                    shapes_tree: Any = None) -> Any:
    """Map a tree of logical-axes tuples to a tree of NamedShardings.

    If ``shapes_tree`` (a matching tree of arrays/ShapeDtypeStructs) is
    given, mesh axes that don't divide a dim are dropped per-leaf.
    """
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if mesh is None:
        raise ValueError("param_shardings requires a mesh")
    is_axes_leaf = lambda x: x is None or (
        isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                     for a in x))

    if shapes_tree is None:
        def one(axes):
            if axes is None:
                return NamedSharding(mesh, P())
            return NamedSharding(mesh, axes_to_spec(axes, mesh, rules))
        return jax.tree_util.tree_map(one, axes_tree, is_leaf=is_axes_leaf)

    def one2(axes, leaf):
        if axes is None:
            return NamedSharding(mesh, P())
        return NamedSharding(
            mesh, _spec_for_shape(axes, leaf.shape, mesh, rules))

    return jax.tree_util.tree_map(one2, axes_tree, shapes_tree,
                                  is_leaf=is_axes_leaf)
