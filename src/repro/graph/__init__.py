from repro.graph.analytics import (BamGraph, bfs, bfs_oracle, cc, cc_oracle,
                                   random_graph)

__all__ = ["BamGraph", "bfs", "bfs_oracle", "cc", "cc_oracle",
           "random_graph"]
