"""Graph analytics on BaM (paper §IV-B): BFS and Connected Components over
a BamArray-backed CSR edge list.

The graph topology metadata (``indptr``, per-edge source id) is small and
device-resident; the **edge target array** — the paper's multi-GB
structure — lives in the BaM storage tier and is read *on demand*: each
BFS iteration requests exactly the edges of the current frontier (invalid
lanes are -1 and never fetched), so the I/O metrics show the paper's
fine-grain-access advantage over staging the whole edge list.

BFS assigns a warp per frontier node in the paper; here the whole
frontier's edges form one wavefront (the TPU warp).  CC is iterative
min-label propagation — the paper's bursty all-edges access pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BamArray, BamState, IORequest
from repro.core.bam_array import _cached_jit
from repro.core.ssd import ArrayOfSSDs, INTEL_OPTANE_P5800X


# ------------------------------------------------------------- graph build --
def random_graph(n_nodes: int, avg_deg: float, seed: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Random CSR graph (undirected, symmetrised). Returns (indptr, dst)."""
    rng = np.random.default_rng(seed)
    m = int(n_nodes * avg_deg / 2)
    src = rng.integers(0, n_nodes, m)
    dst = rng.integers(0, n_nodes, m)
    su = np.concatenate([src, dst])
    du = np.concatenate([dst, src])
    order = np.argsort(su, kind="stable")
    su, du = su[order], du[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, su + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr.astype(np.int64), du.astype(np.int32)


@dataclasses.dataclass
class BamGraph:
    """CSR graph with the edge-target array behind BaM."""

    n_nodes: int
    n_edges: int
    indptr: jax.Array          # (N+1,) device-resident metadata
    edge_src: jax.Array        # (E,) source node per edge (derived metadata)
    edges: BamArray            # edge targets, storage-resident
    state: BamState
    # Per-graph jit cache for the traversal steps (BamArray's
    # ``_cached_jit`` pattern): one wrapper per (graph, op) for the
    # process lifetime, so repeated bfs()/cc() calls never rebuild or
    # retrace their jitted step functions.
    _jit_cache: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    _trace_counts: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    def _jit_op(self, key: str, make):
        return _cached_jit(self._jit_cache, self._trace_counts, key, make)

    @property
    def trace_counts(self) -> dict:
        """Times each traversal op has been traced (not called) — the
        retrace probe, mirroring :attr:`BamArray.trace_counts`."""
        return dict(self._trace_counts)

    @staticmethod
    def build(indptr: np.ndarray, dst: np.ndarray, *,
              cacheline_bytes: int = 4096, cache_bytes: int = 1 << 20,
              ways: int = 4, ssd: Optional[ArrayOfSSDs] = None,
              n_devices: int = 1, backend: str = "sim") -> "BamGraph":
        """``n_devices`` stripes the edge array over that many SSD channels
        (ignored when an explicit ``ssd`` array is passed)."""
        n_nodes = len(indptr) - 1
        n_edges = len(dst)
        block_elems = max(cacheline_bytes // 4, 1)
        num_lines = max(cache_bytes // cacheline_bytes, ways)
        arr, st = BamArray.build(
            dst.astype(np.int32).reshape(1, -1), block_elems=block_elems,
            num_sets=max(num_lines // ways, 1), ways=ways,
            num_queues=16, queue_depth=1024,
            ssd=ssd or ArrayOfSSDs(INTEL_OPTANE_P5800X, n_devices),
            backend=backend)
        edge_src = np.repeat(np.arange(n_nodes, dtype=np.int32),
                             np.diff(indptr))
        return BamGraph(
            n_nodes=n_nodes, n_edges=n_edges,
            indptr=jnp.asarray(indptr, jnp.int32),
            edge_src=jnp.asarray(edge_src),
            edges=arr, state=st)

    @staticmethod
    def from_runtime(rt, rst, name: str, indptr: np.ndarray) -> "BamGraph":
        """Attach a CSR graph whose edge-target array is a *shared-runtime
        tenant* (multi-tenant BaM: one cache + one queue pool for many
        apps).

        The tenant registered as ``name`` on :class:`~repro.core.BamRuntime`
        ``rt`` must hold the CSR ``dst`` array.  :func:`bfs`/:func:`cc`
        then run against the shared cache/queues under the tenant's way
        quota and arbitration weight.  The returned graph's ``state`` is
        the tenant's *view* of ``rst`` — fold the post-traversal state back
        with ``rt.absorb(rst, name, g_state)`` so neighbours and the global
        metrics see the traversal's effects.
        """
        arr = rt.array(name)
        n_nodes = len(indptr) - 1
        n_edges = arr.size
        if int(indptr[-1]) != n_edges:
            raise ValueError(
                f"indptr covers {int(indptr[-1])} edges but tenant "
                f"{name!r} holds {n_edges}")
        edge_src = np.repeat(np.arange(n_nodes, dtype=np.int32),
                             np.diff(indptr))
        return BamGraph(
            n_nodes=n_nodes, n_edges=n_edges,
            indptr=jnp.asarray(indptr, jnp.int32),
            edge_src=jnp.asarray(edge_src),
            edges=arr, state=rt.tenant_view(rst, name))


# ------------------------------------------------- jit-cached step bodies --
_INF = jnp.int32(2 ** 30)


def _edge_ids(g: BamGraph) -> jax.Array:
    return jnp.arange(g.n_edges, dtype=jnp.int32)


def _frontier_req(g: BamGraph, edge_ids, depth, it) -> IORequest:
    """Read request for exactly the frontier-at-``it``'s edges."""
    active = (depth == it)[g.edge_src]
    return IORequest.read(jnp.where(active, edge_ids, -1), active)


def _make_bfs_submit0(g: BamGraph):
    edge_ids = _edge_ids(g)

    def submit0(depth, st):
        return g.edges.submit(st, _frontier_req(g, edge_ids, depth, 0))

    return submit0


def _make_bfs_step_tok(g: BamGraph):
    edge_ids = _edge_ids(g)

    def step(depth, st, tok, it):
        st, nbrs = g.edges.wait(st, tok)       # values for frontier @ it
        active = (depth == it)[g.edge_src]
        nbrs = jnp.where(active, nbrs.astype(jnp.int32), 0)
        first_visit = active & (depth[nbrs] >= _INF)
        depth = depth.at[jnp.where(first_visit, nbrs, 0)].min(
            jnp.where(first_visit, it + 1, _INF))
        # frontier-ahead: issue t+1's read before t's caller even looks
        st, tok = g.edges.submit(
            st, _frontier_req(g, edge_ids, depth, it + 1))
        return depth, st, tok, jnp.any(first_visit)

    return step


def _make_bfs_step(g: BamGraph, prefetch: bool):
    edge_ids = _edge_ids(g)

    def step(depth, st, it):
        frontier = depth == it                 # (N,)
        active = frontier[g.edge_src]          # (E,) edges to expand
        req = jnp.where(active, edge_ids, -1)
        nbrs, st = g.edges.read(st, req, active)   # on-demand fine-grain
        nbrs = jnp.where(active, nbrs.astype(jnp.int32), 0)
        first_visit = active & (depth[nbrs] >= _INF)
        depth = depth.at[jnp.where(first_visit, nbrs, 0)].min(
            jnp.where(first_visit, it + 1, _INF))
        if prefetch:                           # frontier-ahead hint
            nxt = depth == it + 1
            active_n = nxt[g.edge_src]
            st = g.edges.prefetch(st, jnp.where(active_n, edge_ids, -1),
                                  active_n)
        return depth, st, jnp.any(first_visit)

    return step


def _make_cc_submit0(g: BamGraph):
    edge_ids = _edge_ids(g)

    def submit0(st):
        return g.edges.submit(st, IORequest.read(edge_ids))

    return submit0


def _make_cc_step_tok(g: BamGraph):
    edge_ids = _edge_ids(g)

    def step(labels, st, tok):
        st, nbrs = g.edges.wait(st, tok)
        nbrs = nbrs.astype(jnp.int32)
        lsrc = labels[g.edge_src]
        new = labels.at[nbrs].min(lsrc)
        new = new.at[g.edge_src].min(new[nbrs])
        st, tok = g.edges.submit(st, IORequest.read(edge_ids))
        return new, st, tok, jnp.any(new != labels)

    return step


def _make_cc_step(g: BamGraph):
    edge_ids = _edge_ids(g)

    def step(labels, st):
        # only edges whose source label changed since convergence matters;
        # paper's CC touches all edges every round (bursty) — match that.
        nbrs, st = g.edges.read(st, edge_ids)
        nbrs = nbrs.astype(jnp.int32)
        lsrc = labels[g.edge_src]
        # push min label across each edge
        new = labels.at[nbrs].min(lsrc)
        new = new.at[g.edge_src].min(new[nbrs])
        return new, st, jnp.any(new != labels)

    return step


# --------------------------------------------------------------------- BFS --
def bfs(g: BamGraph, source: int, max_iters: Optional[int] = None,
        prefetch: bool = False, async_tokens: bool = False
        ) -> Tuple[np.ndarray, BamState]:
    """Frontier BFS; returns (depth per node (-1 unreachable), BamState).

    With ``async_tokens=True`` the traversal is *frontier-ahead via
    tokens*: as soon as iteration ``t`` has updated the depth array it
    submits the read for iteration ``t+1``'s frontier edges as an
    :class:`~repro.core.IORequest` and carries the :class:`IOToken` into
    the next iteration, which redeems it as its demand values — the fetch
    is genuinely in flight across the iteration boundary and no edge is
    read twice.  This supersedes the hint path below for overlap.

    With ``prefetch=True`` each iteration instead *hints* the next
    frontier's edges through :meth:`BamArray.prefetch` (the legacy
    GIDS-style workload hint): the next iteration's demand wavefront then
    finds its lines resident.  The hints ride the low-priority readahead
    lane as evict-first speculative fills, so they never displace the
    current iteration's demand lines.
    """
    if prefetch and async_tokens:
        raise ValueError("pick one of prefetch= (hints) or async_tokens=")
    max_iters = max_iters or g.n_nodes
    INF = jnp.int32(2 ** 30)
    depth = jnp.full((g.n_nodes,), INF, jnp.int32).at[source].set(0)
    st = g.state

    if async_tokens:
        submit0 = g._jit_op("bfs_submit0", lambda: _make_bfs_submit0(g))
        step = g._jit_op("bfs_step_tok", lambda: _make_bfs_step_tok(g))
        st, tok = submit0(depth, st)
        for it in range(max_iters):
            depth, st, tok, more = step(depth, st, tok, it)
            if not bool(more):
                break
        st, _ = g.edges.wait(st, tok)              # retire the last token
        depth = jnp.where(depth >= INF, -1, depth)
        return np.asarray(depth), st

    step = g._jit_op(f"bfs_step:pf{int(prefetch)}",
                     lambda: _make_bfs_step(g, prefetch))
    for it in range(max_iters):
        depth, st, more = step(depth, st, it)
        if not bool(more):
            break
    depth = jnp.where(depth >= INF, -1, depth)
    return np.asarray(depth), st


def bfs_oracle(indptr: np.ndarray, dst: np.ndarray, source: int
               ) -> np.ndarray:
    n = len(indptr) - 1
    depth = np.full(n, -1, np.int32)
    depth[source] = 0
    frontier = [source]
    d = 0
    while frontier:
        nxt = []
        for u in frontier:
            for v in dst[indptr[u]:indptr[u + 1]]:
                if depth[v] < 0:
                    depth[v] = d + 1
                    nxt.append(int(v))
        frontier = nxt
        d += 1
    return depth


# ---------------------------------------------------------------------- CC --
def cc(g: BamGraph, max_iters: Optional[int] = None,
       prefetch: bool = False, async_tokens: bool = False
       ) -> Tuple[np.ndarray, BamState]:
    """Connected components by min-label propagation (bursty all-edge
    reads — the paper's CC access pattern). Returns (labels, BamState).

    With ``async_tokens=True`` each round submits the next round's
    all-edge read as an :class:`IOToken` before the label update is even
    consumed, so round ``t+1``'s storage commands are pending while round
    ``t``'s caller checks convergence (after warmup the reads are all
    pinned cache hits and the tokens are pure overlap).

    CC's frontier is *every* edge, every round, so with ``prefetch=True``
    the whole edge array is instead hinted once up front (a warmup through
    the readahead lane); iterations after the first then run at full cache
    speed for the portion that fits.
    """
    if prefetch and async_tokens:
        raise ValueError("pick one of prefetch= (hints) or async_tokens=")
    max_iters = max_iters or g.n_nodes
    labels = jnp.arange(g.n_nodes, dtype=jnp.int32)
    edge_ids = jnp.arange(g.n_edges, dtype=jnp.int32)
    st = g.state
    if prefetch:
        st = g.edges.prefetch(st, edge_ids)

    if async_tokens:
        submit0 = g._jit_op("cc_submit0", lambda: _make_cc_submit0(g))
        step_tok = g._jit_op("cc_step_tok", lambda: _make_cc_step_tok(g))
        st, tok = submit0(st)
        for _ in range(max_iters):
            labels, st, tok, more = step_tok(labels, st, tok)
            if not bool(more):
                break
        st, _ = g.edges.wait(st, tok)              # retire the last token
        return np.asarray(labels), st

    step = g._jit_op("cc_step", lambda: _make_cc_step(g))
    for _ in range(max_iters):
        labels, st, more = step(labels, st)
        if not bool(more):
            break
    return np.asarray(labels), st


def cc_oracle(indptr: np.ndarray, dst: np.ndarray) -> np.ndarray:
    n = len(indptr) - 1
    labels = np.arange(n)
    # union-find
    def find(x):
        while labels[x] != x:
            labels[x] = labels[labels[x]]
            x = labels[x]
        return x
    src = np.repeat(np.arange(n), np.diff(indptr))
    for u, v in zip(src, dst):
        ru, rv = find(u), find(int(v))
        if ru != rv:
            labels[max(ru, rv)] = min(ru, rv)
    return np.array([find(i) for i in range(n)])
