"""Pallas TPU kernels for the BaM data path + attention hot spots.

Each kernel ships as ``<name>.py`` (pl.pallas_call + BlockSpec tiling),
with ``ops.py`` as the jit'd public wrapper (auto interpret off-TPU) and
``ref.py`` the pure-jnp oracles tests compare against.
"""
from repro.kernels import ops, ref
from repro.kernels.ops import (cache_probe, flash_attention, gather_blocks,
                               paged_attention, probe_allocate)

__all__ = ["ops", "ref", "cache_probe", "flash_attention", "gather_blocks",
           "paged_attention", "probe_allocate"]
