"""Set-associative cache probe Pallas-TPU kernel.

BaM's GPU probe is a per-thread hash + tag compare with warp coalescing.
The TPU-native adaptation replaces the random tag-row *gather* (a poor fit
for the TPU memory system) with a **one-hot MXU matmul**: the wavefront's
set indices become a one-hot matrix that multiplies the tag directory, so
the probe rides the systolic array instead of scalar loads.

int32 tags are exact-gathered by splitting into two 16-bit halves (each
exactly representable in f32), gathering both halves with the same one-hot
matmul, and recombining — a standard exact-gather-by-matmul trick.

Grid: one step per block of requests; the tag directory block is the whole
``(num_sets, ways)`` array resident in VMEM (directories used by the BaM
cache are ≤ a few MB; larger directories shard over a second grid axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _hash(k):
    k = k.astype(jnp.uint32)
    k = (k * jnp.uint32(2654435761)) & jnp.uint32(0xFFFFFFFF)
    k = k ^ (k >> 16)
    return (k.astype(jnp.int32) & jnp.int32(0x7FFFFFFF))


def _exact_rows(onehot, table):
    """Exact int32 row gather through the one-hot matmul (16-bit halves)."""
    t_u = table.astype(jnp.uint32)
    lo = (t_u & jnp.uint32(0xFFFF)).astype(jnp.float32)       # (S, W)
    hi = (t_u >> 16).astype(jnp.float32)                      # (S, W)
    row_lo = jax.lax.dot_general(onehot, lo, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    row_hi = jax.lax.dot_general(onehot, hi, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    rows = (row_hi.astype(jnp.uint32) << 16) | row_lo.astype(jnp.uint32)
    return rows.astype(jnp.int32)


def _probe_kernel(keys_ref, tags_ref, *rest, num_sets: int,
                  ways: int, bm: int, tenant: int, has_owner: bool):
    if has_owner:
        owner_ref, hit_ref, slot_ref = rest
    else:
        owner_ref, (hit_ref, slot_ref) = None, rest
    keys = keys_ref[0]                               # (bm,)
    valid = keys >= 0
    sets = _hash(jnp.where(valid, keys, 0)) % num_sets  # (bm,)

    onehot = (sets[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (bm, num_sets), 1)
              ).astype(jnp.float32)                  # (bm, S)
    rows = _exact_rows(onehot, tags_ref[...])        # (bm, W) gathered tags

    eq = (rows == keys[:, None]) & valid[:, None]
    if has_owner:
        own_rows = _exact_rows(onehot, owner_ref[...])
        eq = eq & (own_rows == jnp.int32(tenant))
    hit = eq.any(axis=1)
    way = jnp.argmax(eq, axis=1).astype(jnp.int32)
    slot = jnp.where(hit, sets * ways + way, -1).astype(jnp.int32)
    hit_ref[0] = hit.astype(jnp.int32)
    slot_ref[0] = slot


def cache_probe_pallas(tags: jax.Array, keys: jax.Array, *,
                       owner: jax.Array | None = None, tenant: int = 0,
                       block_m: int = 512, interpret: bool = False):
    """tags: (num_sets, ways) int32; keys: (m,) int32.

    Returns (hit (m,) bool, slot (m,) int32 flat line slot, -1 on miss) —
    bit-identical to :func:`repro.core.cache.probe`.  With ``owner`` (the
    per-line tenant stamp), a hit additionally requires ``owner ==
    tenant`` — the multi-tenant tag namespacing.
    """
    num_sets, ways = tags.shape
    m = keys.shape[0]
    bm = min(block_m, m)
    pad = (-m) % bm
    kp = jnp.pad(keys, (0, pad), constant_values=-1) if pad else keys
    nb = kp.shape[0] // bm
    kp2 = kp.reshape(nb, bm)

    kernel = functools.partial(_probe_kernel, num_sets=num_sets, ways=ways,
                               bm=bm, tenant=tenant,
                               has_owner=owner is not None)
    dir_spec = pl.BlockSpec((num_sets, ways), lambda i: (0, 0))
    in_specs = [pl.BlockSpec((1, bm), lambda i: (i, 0)), dir_spec]
    operands = [kp2, tags]
    if owner is not None:
        in_specs.append(dir_spec)
        operands.append(owner)
    hit, slot = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, bm), lambda i: (i, 0)),
            pl.BlockSpec((1, bm), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bm), jnp.int32),
            jax.ShapeDtypeStruct((nb, bm), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*operands)
    return hit.reshape(-1)[:m].astype(bool), slot.reshape(-1)[:m]
