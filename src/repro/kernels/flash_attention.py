"""Blockwise (flash) attention Pallas-TPU kernel — prefill hot path.

VMEM tiling: the grid is ``(batch, q_heads, q_blocks, kv_blocks)`` with the
last axis sequential ("arbitrary") so the online-softmax running state
(m, l, acc) lives in VMEM scratch across kv blocks.  GQA is folded into the
``BlockSpec`` index maps: the kv index map divides the query-head index by
the group size, so no repeated/materialised KV.

Supports causal masking and a sliding window (`window` kv positions behind
the query) — the gemma3/hymba local-attention pattern.  Masked kv blocks are
still visited but contribute -inf scores; the block-skip optimisation is
recorded as a §Perf candidate.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  bq: int, bkv: int, nkv: int, kv_len: int):
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)          # (bkv, d)
    v = v_ref[0, 0].astype(jnp.float32)          # (bkv, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    iq = pl.program_id(2)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kv_pos = ikv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = kv_pos < kv_len
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                          # (bq, 128) lanes replicated
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)   # (bq, 1)
    m_next = jnp.maximum(m_prev, m_cur)          # (bq, 128)
    alpha = jnp.exp(m_prev - m_next)
    p = jnp.exp(s - m_next[:, :1])               # (bq, bkv)
    # fully-masked rows: keep p at exactly 0 (exp(NEG_INF - NEG_INF) = 1 trap)
    p = jnp.where(mask, p, 0.0)
    l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha[:, :1] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_next

    @pl.when(ikv == nkv - 1)
    def _finish():
        l = l_scr[...][:, :1]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def _flash_kernel_dyn(meta_ref, q_ref, k_ref, v_ref, o_ref,
                      m_scr, l_scr, acc_scr, *,
                      scale: float, causal: bool,
                      bq: int, bkv: int, nkv: int, kv_len: int):
    """Variant with a *traced* sliding window (meta_ref[0]).

    Used when the window size is a scanned per-layer value (gemma3's 5:1
    local:global interleave inside one scan-over-layers); a window >= kv_len
    means global attention.  Scalar-prefetched so it is resident before the
    first tile arrives.
    """
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    iq = pl.program_id(2)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kv_pos = ikv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = kv_pos < kv_len
    if causal:
        mask &= kv_pos <= q_pos
    mask &= kv_pos > q_pos - meta_ref[0]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_next = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_next)
    p = jnp.exp(s - m_next[:, :1])
    p = jnp.where(mask, p, 0.0)
    l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha[:, :1] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_next

    @pl.when(ikv == nkv - 1)
    def _finish():
        l = l_scr[...][:, :1]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int | jax.Array | None = None,
    scale: float | None = None,
    block_q: int = 256, block_kv: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0.

    ``window`` may be a traced int32 scalar (per-layer scanned value)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)

    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    pq = (-Sq) % bq
    pkv = (-Skv) % bkv
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pkv), (0, 0))) if pkv else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pkv), (0, 0))) if pkv else v
    nq = qp.shape[2] // bq
    nkv = kp.shape[2] // bkv

    dynamic_window = window is not None and not isinstance(window, int)
    scratch = [
        pltpu.VMEM((bq, 128), jnp.float32),
        pltpu.VMEM((bq, 128), jnp.float32),
        pltpu.VMEM((bq, D), jnp.float32),
    ]
    if dynamic_window:
        kernel = functools.partial(
            _flash_kernel_dyn, scale=scale, causal=causal,
            bq=bq, bkv=bkv, nkv=nkv, kv_len=Skv)
        meta = jnp.asarray(window, jnp.int32).reshape(1)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, Hq, nq, nkv),
            in_specs=[
                pl.BlockSpec((1, 1, bq, D),
                             lambda b, h, i, j, m: (b, h, i, 0)),
                pl.BlockSpec((1, 1, bkv, D),
                             lambda b, h, i, j, m, g=group: (b, h // g, j, 0)),
                pl.BlockSpec((1, 1, bkv, D),
                             lambda b, h, i, j, m, g=group: (b, h // g, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, bq, D),
                                   lambda b, h, i, j, m: (b, h, i, 0)),
            scratch_shapes=scratch,
        )
        out = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
            compiler_params=tpu_compiler_params(
                dimension_semantics=("parallel", "parallel", "parallel",
                                     "arbitrary")),
            interpret=interpret,
        )(meta, qp, kp, vp)
        return out[:, :, :Sq, :]

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bkv=bkv, nkv=nkv, kv_len=Skv)

    grid = (B, Hq, nq, nkv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bkv, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=scratch,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq, :]
