"""Paged block gather Pallas-TPU kernel — the BamArray data path.

``out[i] = data[slots[i]]`` with the slot vector scalar-prefetched so the
``BlockSpec`` index map *is* the page table walk: each grid step's input DMA
is redirected at a dynamic physical line while the previous line streams
out.  This is the literal TPU translation of BaM's "SSD DMA engine delivers
the requested block into the assigned buffer": HBM→VMEM DMA indexed by the
request wavefront.

Negative slots (invalid / bypassed requests) are clamped in the index map
and zero-filled in the kernel body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _gather_kernel(slots_ref, data_ref, out_ref, *, rows_per_block: int):
    i = pl.program_id(0)
    base = i * rows_per_block
    # one requested line per row of this block
    for r in range(rows_per_block):            # static unroll, small
        ok = slots_ref[base + r] >= 0
        line = data_ref[r]                     # (line_elems,) — already DMA'd
        out_ref[r] = jnp.where(ok, line, jnp.zeros_like(line))


def _index_one(i, slots_ref, *, rows_per_block, r):
    return (jnp.maximum(slots_ref[i * rows_per_block + r], 0), 0)


def gather_blocks_pallas(data: jax.Array, slots: jax.Array, *,
                         interpret: bool = False) -> jax.Array:
    """data: (num_lines, line_elems); slots: (n,) int32 -> (n, line_elems).

    Each grid step gathers one line (rows_per_block=1): the scalar-prefetched
    slot feeds the input index map, so consecutive steps' DMAs pipeline.
    """
    n = slots.shape[0]
    _, line_elems = data.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((None, line_elems),
                         lambda i, slots_ref: (jnp.maximum(slots_ref[i], 0), 0)),
        ],
        out_specs=pl.BlockSpec((None, line_elems), lambda i, slots_ref: (i, 0)),
    )

    def kernel(slots_ref, data_ref, out_ref):
        i = pl.program_id(0)
        ok = slots_ref[i] >= 0
        out_ref[...] = jnp.where(ok, data_ref[...],
                                 jnp.zeros_like(data_ref[...]))

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, line_elems), data.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(slots, data)
