"""Public jit'd wrappers for the Pallas kernels.

Backend policy: on TPU the Pallas kernels compile natively; everywhere else
(this CPU container) they run under ``interpret=True``, which executes the
kernel body in Python per grid step — bit-faithful, slow.  Because interpret
mode is too slow for the big model graphs, the model code calls these
wrappers with ``impl='auto'`` which picks:

  * 'pallas'    on TPU backends,
  * 'ref'       (the pure-jnp oracle, an XLA graph) elsewhere — so smoke
                tests and the CPU dry-run use honest XLA HLO that
                ``cost_analysis()`` can account.

Tests pin ``impl='pallas', interpret=True`` and sweep shapes/dtypes against
``impl='ref'``.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.cache_probe import cache_probe_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gather_blocks import gather_blocks_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.probe_allocate import probe_allocate_pallas

Impl = Literal["auto", "pallas", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: Impl) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    block_q=256, block_kv=256, tile_f32: bool = True,
                    impl: Impl = "auto", interpret: bool | None = None):
    if _resolve(impl) == "ref":
        # blockwise XLA path once the score matrix would exceed ~16M elems
        # per (batch, head) — bounded memory for the 32k/500k cells.
        if q.shape[2] * k.shape[2] > (1 << 22):
            return _ref.flash_attention_xla(q, k, v, causal=causal,
                                            window=window, scale=scale,
                                            tile_f32=tile_f32)
        return _ref.flash_attention_ref(q, k, v, causal=causal,
                                        window=window, scale=scale)
    itp = (not _on_tpu()) if interpret is None else interpret
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_kv=block_kv, interpret=itp)


def paged_attention(q, k_pages, v_pages, page_table, seq_lens, *, scale=None,
                    impl: Impl = "auto", interpret: bool | None = None):
    if _resolve(impl) == "ref":
        return _ref.paged_attention_ref(q, k_pages, v_pages, page_table,
                                        seq_lens, scale=scale)
    itp = (not _on_tpu()) if interpret is None else interpret
    return paged_attention_pallas(q, k_pages, v_pages, page_table, seq_lens,
                                  scale=scale, interpret=itp)


def gather_blocks(data, slots, *, off=None, impl: Impl = "auto",
                  interpret: bool | None = None):
    """Paged line gather; with ``off``, an element gather ``data[slots,
    off]``.  The Pallas path always DMAs whole lines (the TPU moves
    line-granular anyway) and selects the element after; the ref/XLA path
    gathers just the elements.
    """
    if _resolve(impl) == "ref":
        return _ref.gather_blocks_ref(data, slots, off=off)
    itp = (not _on_tpu()) if interpret is None else interpret
    lines = gather_blocks_pallas(data, slots, interpret=itp)
    if off is None:
        return lines
    return lines[jnp.arange(off.shape[0]), off]


def cache_probe(tags, keys, *, owner=None, tenant=0, block_m=512,
                impl: Impl = "auto", interpret: bool | None = None):
    if _resolve(impl) == "ref":
        return _ref.cache_probe_ref(tags, keys, owner=owner, tenant=tenant)
    itp = (not _on_tpu()) if interpret is None else interpret
    return cache_probe_pallas(tags, keys, owner=owner, tenant=tenant,
                              block_m=block_m, interpret=itp)


def sq_enqueue(sq_key, sq_dst, sq_is_write, sq_prio, sq_tenant, sq_ticket,
               sq_tail, sq_head, rr_ptr, dev_enqueued,
               keys, dst, is_write, prio, valid, *,
               seg_bounds, n_devices, stripe_blocks, tenant,
               failed_devices=(),
               impl: Impl = "auto", interpret: bool | None = None):
    """Fused multi-segment SQ enqueue (one scatter round per ring field)
    — see :func:`repro.kernels.ref.sq_enqueue_ref` for exact semantics.

    The op is scatter-bound with no matmul/reduction structure for a TPU
    kernel to exploit, so every backend (including ``impl="pallas"``) runs
    the jnp oracle as an XLA graph; the ``impl`` knob is accepted for
    dispatch-layer symmetry with the probe/gather ops.
    """
    del impl, interpret
    return _ref.sq_enqueue_ref(
        sq_key, sq_dst, sq_is_write, sq_prio, sq_tenant, sq_ticket,
        sq_tail, sq_head, rr_ptr, dev_enqueued,
        keys, dst, is_write, prio, valid,
        seg_bounds=seg_bounds, n_devices=n_devices,
        stripe_blocks=stripe_blocks, tenant=tenant,
        failed_devices=failed_devices)


def wfq_drain(sq_key, sq_is_write, sq_tenant, sq_ticket=None, *,
              n_devices, n_tenants, fault=None,
              impl: Impl = "auto", interpret: bool | None = None):
    """Closed-form drain accounting (no completion-stream sort) — see
    :func:`repro.kernels.ref.wfq_drain_ref`.  Reduction-only; all backends
    share the jnp oracle (same rationale as :func:`sq_enqueue`).
    """
    del impl, interpret
    return _ref.wfq_drain_ref(sq_key, sq_is_write, sq_tenant, sq_ticket,
                              n_devices=n_devices, n_tenants=n_tenants,
                              fault=fault)


def probe_allocate(tags, owner, refcount, dirty, speculative, clock_hand,
                   keys, *, valid=None, alloc_mask=None, protect_slots=None,
                   tenant=0, way_lo=0, way_hi=None, spec_insert=False,
                   protect_hits=True, impl: Impl = "auto",
                   interpret: bool | None = None):
    """Fused cache probe + clock-sweep victim select (the BaM submission
    hot path, one set-local pass).  Returns ``(hit, hit_slot, way, ok,
    evicted_key, evicted_dirty)`` — see
    :func:`repro.kernels.ref.probe_allocate_ref` for the exact semantics.
    """
    if valid is None:
        valid = keys >= 0
    if _resolve(impl) == "ref":
        return _ref.probe_allocate_ref(
            tags, owner, refcount, dirty, speculative, clock_hand, keys,
            valid, alloc_mask, protect_slots, tenant=tenant, way_lo=way_lo,
            way_hi=way_hi, spec_insert=spec_insert,
            protect_hits=protect_hits)
    itp = (not _on_tpu()) if interpret is None else interpret
    return probe_allocate_pallas(
        tags, owner, refcount, dirty, speculative, clock_hand, keys, valid,
        alloc_mask, protect_slots, tenant=tenant, way_lo=way_lo,
        way_hi=way_hi, spec_insert=spec_insert, protect_hits=protect_hits,
        interpret=itp)
