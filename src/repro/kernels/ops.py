"""Public jit'd wrappers for the Pallas kernels.

Backend policy: on TPU the Pallas kernels compile natively; everywhere else
(this CPU container) they run under ``interpret=True``, which executes the
kernel body in Python per grid step — bit-faithful, slow.  Because interpret
mode is too slow for the big model graphs, the model code calls these
wrappers with ``impl='auto'`` which picks:

  * 'pallas'    on TPU backends,
  * 'ref'       (the pure-jnp oracle, an XLA graph) elsewhere — so smoke
                tests and the CPU dry-run use honest XLA HLO that
                ``cost_analysis()`` can account.

Tests pin ``impl='pallas', interpret=True`` and sweep shapes/dtypes against
``impl='ref'``.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.cache_probe import cache_probe_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gather_blocks import gather_blocks_pallas
from repro.kernels.paged_attention import paged_attention_pallas

Impl = Literal["auto", "pallas", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: Impl) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    block_q=256, block_kv=256, tile_f32: bool = True,
                    impl: Impl = "auto", interpret: bool | None = None):
    if _resolve(impl) == "ref":
        # blockwise XLA path once the score matrix would exceed ~16M elems
        # per (batch, head) — bounded memory for the 32k/500k cells.
        if q.shape[2] * k.shape[2] > (1 << 22):
            return _ref.flash_attention_xla(q, k, v, causal=causal,
                                            window=window, scale=scale,
                                            tile_f32=tile_f32)
        return _ref.flash_attention_ref(q, k, v, causal=causal,
                                        window=window, scale=scale)
    itp = (not _on_tpu()) if interpret is None else interpret
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_kv=block_kv, interpret=itp)


def paged_attention(q, k_pages, v_pages, page_table, seq_lens, *, scale=None,
                    impl: Impl = "auto", interpret: bool | None = None):
    if _resolve(impl) == "ref":
        return _ref.paged_attention_ref(q, k_pages, v_pages, page_table,
                                        seq_lens, scale=scale)
    itp = (not _on_tpu()) if interpret is None else interpret
    return paged_attention_pallas(q, k_pages, v_pages, page_table, seq_lens,
                                  scale=scale, interpret=itp)


def gather_blocks(data, slots, *, impl: Impl = "auto",
                  interpret: bool | None = None):
    if _resolve(impl) == "ref":
        return _ref.gather_blocks_ref(data, slots)
    itp = (not _on_tpu()) if interpret is None else interpret
    return gather_blocks_pallas(data, slots, interpret=itp)


def cache_probe(tags, keys, *, block_m=512, impl: Impl = "auto",
                interpret: bool | None = None):
    if _resolve(impl) == "ref":
        return _ref.cache_probe_ref(tags, keys)
    itp = (not _on_tpu()) if interpret is None else interpret
    return cache_probe_pallas(tags, keys, block_m=block_m, interpret=itp)
