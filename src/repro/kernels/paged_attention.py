"""Paged decode attention Pallas-TPU kernel — the BaM-paged-KV hot path.

Decode attention where the KV cache is a *paged pool* (the BaM software
cache's data array): physical pages are gathered on demand through a page
table, exactly like BamArray lines.  The page table is a **scalar-prefetch**
operand so each grid step's ``BlockSpec`` index map points the next DMA at
the right physical page while the current page is being processed — the
Pallas analogue of BaM overlapping in-flight NVMe requests with compute.

Pool layout is per-sequence: ``(B, P_phys, page, Hkv, D)``; on the
production mesh the batch dim shards over ``data`` and the physical-page
dim stripes over ``model`` — the TPU mapping of BaM's blocks-round-robin-
over-SSDs.  ``page_table[b, i]`` gives the physical page (within sequence
b's pool row) backing logical page i; -1 marks a hole (spilled page).

Grid: ``(batch, kv_heads, num_logical_pages)`` with the page axis
sequential; online-softmax state (m, l, acc) for the ``group`` query heads
of this kv head sits in VMEM scratch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _paged_kernel(page_table_ref, seq_lens_ref,    # scalar prefetch
                  q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  scale: float, page_size: int, n_pages: int):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, d)
    k = k_ref[0, 0, :, 0].astype(jnp.float32)      # (page, d)
    v = v_ref[0, 0, :, 0].astype(jnp.float32)      # (page, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # logical positions covered by this logical page index
    pos = i * page_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)                     # (G, page)
    live = (pos < seq_lens_ref[b]) & (page_table_ref[b, i] >= 0)
    s = jnp.where(live, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_next = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_next)
    p = jnp.exp(s - m_next[:, :1])
    p = jnp.where(live, p, 0.0)
    l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha[:, :1] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_next

    @pl.when(i == n_pages - 1)
    def _finish():
        l = l_scr[...][:, :1]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def paged_attention_pallas(
    q: jax.Array,             # (B, Hq, D) — one new token per sequence
    k_pages: jax.Array,       # (B, P_phys, page_size, Hkv, D) pool
    v_pages: jax.Array,       # (B, P_phys, page_size, Hkv, D)
    page_table: jax.Array,    # (B, n_pages) int32 physical page id, -1 hole
    seq_lens: jax.Array,      # (B,) int32 — tokens live in the cache
    *, scale: float | None = None, interpret: bool = False,
) -> jax.Array:
    """Flash-decoding over a BaM-paged KV pool. Returns (B, Hq, D)."""
    B, Hq, D = q.shape
    _, P, page_size, Hkv, _ = k_pages.shape
    n_pages = page_table.shape[1]
    assert Hq % Hkv == 0
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, Hkv, G, D)
    kernel = functools.partial(_paged_kernel, scale=scale,
                               page_size=page_size, n_pages=n_pages)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, i, pt, sl: (b, h, 0, 0)),
            pl.BlockSpec(
                (1, 1, page_size, 1, D),
                lambda b, h, i, pt, sl:
                    (b, jnp.maximum(pt[b, i], 0), 0, h, 0)),
            pl.BlockSpec(
                (1, 1, page_size, 1, D),
                lambda b, h, i, pt, sl:
                    (b, jnp.maximum(pt[b, i], 0), 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, i, pt, sl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, seq_lens, qg, k_pages, v_pages)
    return out.reshape(B, Hq, D)
