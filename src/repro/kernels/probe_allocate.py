"""Fused cache probe + clock-sweep victim select — Pallas-TPU kernel.

This is the BaM submission hot path (probe → allocate) as *one* set-local
pass: hash each request to its set, compare the set's tags (hit / miss),
and — for the misses — pick the victim way in **class-then-clock** order
(invalid first, speculative second, demand-resident last; clock order
within each class), honouring pinned lines, the tenant way window, foreign
dirty lines, pending speculative lines and protected slots.

TPU adaptation, same playbook as ``cache_probe.py``:

* every row gather (tags, owner, refcount, dirty, speculative, clock hand)
  rides a **one-hot MXU matmul** instead of a random gather; int32 values
  are exact-gathered by 16-bit halves;
* the paper's "threads racing on the clock hand" becomes the segmented
  rank of each miss among same-set misses — computed here as an exclusive
  **cumsum of the one-hot set matrix** (no sort, no atomic);
* the victim is selected *without materializing the ``(m, ways)`` stable
  argsort* the jnp core used: each way's sort key is
  ``class * ways + clock_pos`` (distinct per row), and the chosen way is
  the eligible one whose *eligible-order index* — the count of eligible
  ways with a strictly smaller key, a ``ways``-step unrolled comparison —
  equals the request's rank.  This selects exactly the way the stable
  argsort would;
* protected slots (this wavefront's hits + the caller's explicit list)
  are scattered into a per-(set, way) count matrix by a second one-hot
  matmul — a scatter-by-matmul, no ``.at[]``.

Grid: a single step; the wavefront, the directory and the one-hot matrix
are resident in VMEM (same envelope as ``cache_probe.py`` — BaM
directories are ≤ a few MB; larger ones shard over a grid axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params
from repro.utils import round_up


def _hash(k):
    k = k.astype(jnp.uint32)
    k = (k * jnp.uint32(2654435761)) & jnp.uint32(0xFFFFFFFF)
    k = k ^ (k >> 16)
    return (k.astype(jnp.int32) & jnp.int32(0x7FFFFFFF))


def _pa_kernel(keys_ref, amask_ref, prot_ref, tags_ref, owner_ref,
               refcount_ref, dirty_ref, spec_ref, hand_ref,
               hit_ref, hslot_ref, way_ref, ok_ref, evk_ref, evd_ref, *,
               num_sets: int, ways: int, m: int, tenant: int, way_lo: int,
               way_hi: int, spec_insert: bool, protect_hits: bool):
    f32 = jnp.float32
    keys = keys_ref[0]                                   # (m,)
    valid = keys >= 0
    amask = amask_ref[0] != 0
    sets = _hash(jnp.where(valid, keys, 0)) % num_sets   # (m,)

    onehot = (sets[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (m, num_sets), 1)
              ).astype(f32)                              # (m, S)

    def gather_i32(table):
        """Exact (m, ways) row gather of an int32 (S, W) table by 16-bit
        halves through the one-hot matmul."""
        t_u = table.astype(jnp.uint32)
        lo = (t_u & jnp.uint32(0xFFFF)).astype(f32)
        hi = (t_u >> 16).astype(f32)
        row_lo = jax.lax.dot_general(onehot, lo, (((1,), (0,)), ((), ())),
                                     preferred_element_type=f32)
        row_hi = jax.lax.dot_general(onehot, hi, (((1,), (0,)), ((), ())),
                                     preferred_element_type=f32)
        rows = (row_hi.astype(jnp.uint32) << 16) | row_lo.astype(jnp.uint32)
        return rows.astype(jnp.int32)

    def gather_small(table_f32):
        """Row gather of small non-negative counts/flags (exact in f32)."""
        return jax.lax.dot_general(onehot, table_f32,
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=f32)

    tags_rows = gather_i32(tags_ref[...])                # (m, W)
    owner_rows = gather_i32(owner_ref[...])
    ref_rows = gather_small(refcount_ref[...].astype(f32))
    dirty_rows = gather_small(dirty_ref[...].astype(f32)) > 0.5
    spec_rows = gather_small(spec_ref[...].astype(f32)) > 0.5
    hand = gather_small(hand_ref[...].astype(f32))[:, 0].astype(jnp.int32)

    # ---- probe ----------------------------------------------------------
    eq = (tags_rows == keys[:, None]) & valid[:, None] \
        & (owner_rows == jnp.int32(tenant))
    hit = eq.any(axis=1)
    hway = jnp.argmax(eq, axis=1).astype(jnp.int32)
    hslot = jnp.where(hit, sets * ways + hway, -1).astype(jnp.int32)

    miss = valid & ~hit & amask

    # ---- per-(row, way) eligibility -------------------------------------
    warange = jax.lax.broadcasted_iota(jnp.int32, (m, ways), 1)
    elig = ref_rows < 0.5
    foreign_dirty = (owner_rows != jnp.int32(tenant)) \
        & (tags_rows >= 0) & dirty_rows
    elig = elig & ~foreign_dirty
    if way_lo != 0 or way_hi != ways:
        elig = elig & (warange >= way_lo) & (warange < way_hi)
    if spec_insert:
        elig = elig & ~(spec_rows & (tags_rows >= 0))

    # protected (set, way) pairs: scatter-by-matmul into a count matrix.
    prot_mat = jnp.zeros((num_sets, ways), f32)
    if protect_hits:
        w1 = ((hway[:, None] == warange) & hit[:, None]).astype(f32)
        prot_mat = prot_mat + jax.lax.dot_general(
            onehot * hit[:, None].astype(f32), w1,
            (((0,), (0,)), ((), ())), preferred_element_type=f32)
    prot = prot_ref[0]                                   # (p,) flat slots
    pvalid = prot >= 0
    psets = jnp.where(pvalid, prot // ways, 0)
    pways = jnp.where(pvalid, prot % ways, 0)
    p = prot.shape[0]
    ponehot = ((psets[:, None] ==
                jax.lax.broadcasted_iota(jnp.int32, (p, num_sets), 1))
               & pvalid[:, None]).astype(f32)
    pw1 = (pways[:, None] ==
           jax.lax.broadcasted_iota(jnp.int32, (p, ways), 1)).astype(f32)
    prot_mat = prot_mat + jax.lax.dot_general(
        ponehot, pw1, (((0,), (0,)), ((), ())), preferred_element_type=f32)
    elig = elig & ~(gather_small(prot_mat) > 0.5)

    # ---- rank among same-set misses: exclusive cumsum, no sort ----------
    miss_col = onehot * miss[:, None].astype(f32)        # (m, S)
    csum = jnp.cumsum(miss_col, axis=0) - miss_col       # exclusive prefix
    rank = jnp.sum(csum * onehot, axis=1).astype(jnp.int32)

    # ---- class-then-clock victim select, argsort-free -------------------
    clock_pos = (warange - hand[:, None]) % ways
    vclass = jnp.where(tags_rows < 0, 0,
                       jnp.where(spec_rows, 1, 2)).astype(jnp.int32)
    key_w = vclass * ways + clock_pos                    # distinct per row
    eidx = jnp.zeros((m, ways), jnp.int32)
    for wp in range(ways):                               # static unroll
        eidx = eidx + ((key_w[:, wp:wp + 1] < key_w)
                       & elig[:, wp:wp + 1]).astype(jnp.int32)
    n_elig = jnp.sum(elig.astype(jnp.int32), axis=1)
    sel = elig & (eidx == rank[:, None]) & miss[:, None]
    ok = miss & (n_elig >= rank + 1)
    way = jnp.argmax(sel, axis=1).astype(jnp.int32)
    evk = jnp.zeros((m,), jnp.int32)
    evd = jnp.zeros((m,), bool)
    for w in range(ways):                                # static unroll
        pick = way == w
        evk = jnp.where(pick, tags_rows[:, w], evk)
        evd = jnp.where(pick, dirty_rows[:, w], evd)

    hit_ref[0] = hit.astype(jnp.int32)
    hslot_ref[0] = hslot
    way_ref[0] = jnp.where(ok, way, -1)
    ok_ref[0] = ok.astype(jnp.int32)
    evk_ref[0] = jnp.where(ok, evk, -1)
    evd_ref[0] = (ok & evd).astype(jnp.int32)


def probe_allocate_pallas(tags, owner, refcount, dirty, speculative,
                          clock_hand, keys, valid, alloc_mask=None,
                          protect_slots=None, *, tenant: int = 0,
                          way_lo: int = 0, way_hi: int | None = None,
                          spec_insert: bool = False,
                          protect_hits: bool = True,
                          interpret: bool = False):
    """Fused probe + victim select over a raw cache directory.

    Inputs mirror :class:`repro.core.cache.CacheState` fields; ``keys`` /
    ``valid`` / ``alloc_mask`` are the wavefront.  Returns ``(hit,
    hit_slot, way, ok, evicted_key, evicted_dirty)``, bit-identical to
    :func:`repro.kernels.ref.probe_allocate_ref`.
    """
    num_sets, ways = tags.shape
    way_hi = ways if way_hi is None else way_hi
    m = keys.shape[0]
    mp = round_up(m, 128)
    keys_p = jnp.full((mp,), -1, jnp.int32).at[:m].set(
        jnp.where(valid, keys, -1).astype(jnp.int32))
    am = jnp.ones((m,), jnp.int32) if alloc_mask is None \
        else alloc_mask.astype(jnp.int32)
    am_p = jnp.zeros((mp,), jnp.int32).at[:m].set(am)
    prot = jnp.full((1,), -1, jnp.int32) if protect_slots is None \
        else protect_slots.astype(jnp.int32)
    pp = round_up(prot.shape[0], 128)
    prot_p = jnp.full((pp,), -1, jnp.int32).at[:prot.shape[0]].set(prot)

    kernel = functools.partial(
        _pa_kernel, num_sets=num_sets, ways=ways, m=mp, tenant=tenant,
        way_lo=way_lo, way_hi=way_hi, spec_insert=spec_insert,
        protect_hits=protect_hits)
    out = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, mp), lambda i: (0, 0)),
            pl.BlockSpec((1, mp), lambda i: (0, 0)),
            pl.BlockSpec((1, pp), lambda i: (0, 0)),
            pl.BlockSpec((num_sets, ways), lambda i: (0, 0)),
            pl.BlockSpec((num_sets, ways), lambda i: (0, 0)),
            pl.BlockSpec((num_sets, ways), lambda i: (0, 0)),
            pl.BlockSpec((num_sets, ways), lambda i: (0, 0)),
            pl.BlockSpec((num_sets, ways), lambda i: (0, 0)),
            pl.BlockSpec((num_sets, 1), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((1, mp), lambda i: (0, 0))] * 6,
        out_shape=[jax.ShapeDtypeStruct((1, mp), jnp.int32)] * 6,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(keys_p.reshape(1, mp), am_p.reshape(1, mp), prot_p.reshape(1, pp),
      tags, owner, refcount.astype(jnp.int32),
      dirty.astype(jnp.int32), speculative.astype(jnp.int32),
      clock_hand.reshape(num_sets, 1))
    hit, hslot, way, ok, evk, evd = [o.reshape(-1)[:m] for o in out]
    return (hit.astype(bool), hslot, way, ok.astype(bool), evk,
            evd.astype(bool))
