"""Pure-jnp oracles for every Pallas kernel (the ``assert_allclose`` refs)."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D). Plain masked softmax."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows -> zeros, matching the kernel's l=0 guard
    any_live = mask.any(axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    out = jnp.where(any_live[None, None, :, None], out, 0.0)
    return out.astype(q.dtype)


def _fa_mask(iq, jk, bq, bkv, skv, causal, window):
    q_pos = iq * bq + jnp.arange(bq)[:, None]
    kv_pos = jk * bkv + jnp.arange(bkv)[None, :]
    mask = kv_pos < skv
    if causal:
        mask &= kv_pos <= q_pos
    mask &= kv_pos > q_pos - window
    return mask


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _fa_core(q, k, v, window, causal, scale, bq, bkv, kv_len):
    out, _ = _fa_fwd_inner(q, k, v, window, causal, scale, bq, bkv, kv_len)
    return out


def _fa_fwd_inner(q, k, v, window, causal, scale, bq, bkv, kv_len=None):
    """Blockwise online-softmax forward. q: (B,Hkv,G,Sq,D) (padded);
    k, v: (B,Hkv,Skv,D). Tiles keep the input dtype (bf16 tiles when the
    config sets attn_f32=False); accumulation is f32 via
    preferred_element_type. Returns (out, logsumexp L)."""
    B, Hkv, G, Sq, D = q.shape
    Skv_pad = k.shape[2]
    Skv = Skv_pad if kv_len is None else kv_len     # mask bound (true len)
    nq, nkv = Sq // bq, Skv_pad // bkv
    qs = q.reshape(B, Hkv, G, nq, bq, D).transpose(3, 0, 1, 2, 4, 5)
    ks = k.reshape(B, Hkv, nkv, bkv, D).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, Hkv, nkv, bkv, D).transpose(2, 0, 1, 3, 4)

    def one_q(_, qi):
        qb, iq = qi                                     # (B,Hkv,G,bq,D)

        def kv_step(carry, kvj):
            m, l, acc = carry
            kb, vb, jk = kvj
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = _fa_mask(iq, jk, bq, bkv, Skv, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m2 = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m2)
            p = jnp.where(mask[None, None, None], jnp.exp(s - m2[..., None]),
                          0.0)
            l2 = l * alpha + p.sum(-1)
            acc2 = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(qb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m2, l2, acc2), None

        m0 = jnp.full((B, Hkv, G, bq), NEG_INF, jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, jnp.zeros_like(m0),
                      jnp.zeros((B, Hkv, G, bq, D), jnp.float32)),
            (ks, vs, jnp.arange(nkv)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(one_q, None, (qs, jnp.arange(nq)))
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hkv, G, Sq, D)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, Hkv, G, Sq)
    return out, lse


def _fa_vjp_fwd(q, k, v, window, causal, scale, bq, bkv, kv_len):
    out, lse = _fa_fwd_inner(q, k, v, window, causal, scale, bq, bkv,
                             kv_len)
    return out, (q, k, v, window, out, lse)


def _fa_vjp_bwd(causal, scale, bq, bkv, kv_len, res, dout):
    """Manual flash backward: recompute P per block; O(S) memory."""
    q, k, v, window, out, lse = res
    B, Hkv, G, Sq, D = q.shape
    Skv_pad = k.shape[2]
    nq, nkv = Sq // bq, Skv_pad // bkv
    delta = jnp.sum(dout * out, axis=-1)                # (B,Hkv,G,Sq)
    qs = q.reshape(B, Hkv, G, nq, bq, D).transpose(3, 0, 1, 2, 4, 5)
    dos = dout.reshape(B, Hkv, G, nq, bq, D).transpose(3, 0, 1, 2, 4, 5)
    ls = lse.reshape(B, Hkv, G, nq, bq).transpose(3, 0, 1, 2, 4)
    ds = delta.reshape(B, Hkv, G, nq, bq).transpose(3, 0, 1, 2, 4)
    ks = k.reshape(B, Hkv, nkv, bkv, D).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, Hkv, nkv, bkv, D).transpose(2, 0, 1, 3, 4)

    def one_q(carry, xs):
        dk_all, dv_all = carry                          # (nkv,B,Hkv,bkv,D)
        qb, dob, lb, db, iq = xs

        def kv_step(carry2, jk):
            dqi, dk_a, dv_a = carry2
            kb, vb = ks[jk], vs[jk]
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb) * scale
            mask = _fa_mask(iq, jk, bq, bkv, kv_len, causal, window)
            p = jnp.where(mask[None, None, None],
                          jnp.exp(s - lb[..., None]), 0.0)
            dv_j = jnp.einsum("bhgqk,bhgqd->bhkd", p, dob)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", dob, vb)
            dsij = p * (dp - db[..., None]) * scale
            dqi = dqi + jnp.einsum("bhgqk,bhkd->bhgqd", dsij, kb)
            dk_j = jnp.einsum("bhgqk,bhgqd->bhkd", dsij, qb)
            dk_a = dk_a.at[jk].add(dk_j)
            dv_a = dv_a.at[jk].add(dv_j)
            return (dqi, dk_a, dv_a), None

        dq0 = jnp.zeros_like(qb)
        (dqi, dk_all, dv_all), _ = jax.lax.scan(
            kv_step, (dq0, dk_all, dv_all), jnp.arange(nkv))
        return (dk_all, dv_all), dqi

    dk0 = jnp.zeros((nkv, B, Hkv, bkv, D), jnp.float32)
    dv0 = jnp.zeros((nkv, B, Hkv, bkv, D), jnp.float32)
    (dk_all, dv_all), dqs = jax.lax.scan(
        one_q, (dk0, dv0), (qs, dos, ls, ds, jnp.arange(nq)))
    dq = dqs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hkv, G, Sq, D)
    dk = dk_all.transpose(1, 2, 0, 3, 4).reshape(B, Hkv, Skv_pad, D)
    dv = dv_all.transpose(1, 2, 0, 3, 4).reshape(B, Hkv, Skv_pad, D)
    return dq, dk, dv, None


_fa_core.defvjp(_fa_vjp_fwd, _fa_vjp_bwd)


def flash_attention_xla(q, k, v, *, causal=True, window=None, scale=None,
                        block_q: int = 1024, block_kv: int = 1024,
                        tile_f32: bool = True):
    """Blockwise attention in pure XLA ops with a **manual flash backward**
    (custom_vjp) — bounded memory in fwd AND bwd, honest HLO for the
    dry-run/roofline.  Handles traced ``window`` (scanned per-layer)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    pq, pkv = (-Sq) % bq, (-Skv) % bkv
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pkv), (0, 0))) if pkv else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pkv), (0, 0))) if pkv else v
    if window is None:
        window = jnp.int32(1 << 30)
    tdt = jnp.float32 if tile_f32 else jnp.bfloat16
    qg = qp.reshape(B, Hkv, group, qp.shape[2], D).astype(tdt)
    out = _fa_core(qg, kp.astype(tdt), vp.astype(tdt),
                   jnp.asarray(window, jnp.int32), causal, scale, bq, bkv,
                   Skv)
    out = out.reshape(B, Hq, qp.shape[2], D)[:, :, :Sq, :]
    return out.astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens, *,
                        scale=None):
    """q: (B, Hq, D); pools (B, P, page, Hkv, D); table (B, NP); lens (B,)."""
    B, Hq, D = q.shape
    _, P, page, Hkv, _ = k_pages.shape
    NP = page_table.shape[1]
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    safe = jnp.maximum(page_table, 0)                          # (B, NP)
    idx = safe[:, :, None, None, None]
    k = jnp.take_along_axis(k_pages, idx, axis=1)              # (B, NP, page, Hkv, D)
    v = jnp.take_along_axis(v_pages, idx, axis=1)
    S = NP * page
    k = k.transpose(0, 3, 1, 2, 4).reshape(B, Hkv, S, D)
    v = v.transpose(0, 3, 1, 2, 4).reshape(B, Hkv, S, D)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    pos = jnp.arange(S)[None, :]                               # (1, S)
    hole = jnp.repeat(page_table < 0, page, axis=1)            # (B, S)
    live = (pos < seq_lens[:, None]) & ~hole
    s = jnp.where(live[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(live[:, None], p, 0.0)
    out = jnp.einsum("bhk,bhkd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def gather_blocks_ref(data, slots, off=None):
    """data: (num_lines, line_elems); slots: (n,) -> (n, line_elems).

    With ``off`` (per-request in-line element offsets), gather single
    elements — ``(n,)`` of ``data[slots, off]`` — instead of whole lines:
    the XLA path moves one element per lane, not a full line per lane.
    """
    safe = jnp.maximum(slots, 0)
    if off is not None:
        return jnp.where(slots >= 0, data[safe, off], 0)
    out = data[safe]
    return jnp.where((slots >= 0)[:, None], out, 0)


def cache_probe_ref(tags, keys, owner=None, tenant=0):
    """Mirror of repro.core.cache.probe on a raw tag directory.

    With ``owner`` (the per-line tenant stamp), a line only hits when its
    owner matches ``tenant`` — the multi-tenant tag namespacing.  Negative
    keys never hit.
    """
    from repro.utils import mix_hash
    num_sets, ways = tags.shape
    valid = keys >= 0
    sets = mix_hash(jnp.where(valid, keys, 0)) % num_sets
    rows = tags[sets]                                          # (m, ways)
    eq = (rows == keys[:, None]) & valid[:, None]
    if owner is not None:
        eq = eq & (owner[sets] == jnp.int32(tenant))
    hit = eq.any(axis=1)
    way = jnp.argmax(eq, axis=1).astype(jnp.int32)
    slot = jnp.where(hit, sets * ways + way, -1).astype(jnp.int32)
    return hit, slot


def probe_allocate_ref(tags, owner, refcount, dirty, speculative, clock_hand,
                       keys, valid, alloc_mask=None, protect_slots=None, *,
                       tenant=0, way_lo=0, way_hi=None, spec_insert=False,
                       protect_hits=True):
    """Fused probe + clock-sweep victim select, pure jnp — the oracle for
    ``probe_allocate_pallas`` and the CPU/XLA hot path.

    One set-local pass per request: hash → tag+owner probe → (for misses)
    pick the request's victim way in *class-then-clock* order.  The victim
    class is 0 = invalid, 1 = speculative (prefetched, unpromoted),
    2 = demand-resident; within a class, ways are taken in clock order
    starting at the set's hand.  Unlike ``repro.core.cache.allocate`` no
    ``(m, ways)`` stable argsort is materialized: the selected way is the
    one whose *eligible-order index* — the count of eligible ways with a
    strictly smaller ``class*ways + clock_pos`` sort key (keys are distinct
    per row) — equals the request's same-set rank.  That count is a handful
    of ``(m, ways)`` comparisons, and it selects exactly the way the stable
    argsort would.

    Eligibility honours everything the inline path honours: pinned lines
    (``refcount > 0``), foreign dirty lines (another tenant's write-back),
    the ``[way_lo, way_hi)`` tenant way window, pending speculative lines
    when ``spec_insert`` (a prefetch never cannibalizes an unconsumed
    prediction), this wavefront's own probe hits (``protect_hits``) and the
    caller's extra ``protect_slots``.

    Returns ``(hit, hit_slot, way, ok, evicted_key, evicted_dirty)``; all
    allocation outputs are masked (-1 / False) on rows with ``ok=False``.
    """
    from repro.utils import mix_hash, segment_rank
    num_sets, ways = tags.shape
    way_hi = ways if way_hi is None else way_hi
    m = keys.shape[0]
    sets = mix_hash(jnp.where(valid, keys, 0)) % num_sets

    # ---- probe -----------------------------------------------------------
    rows_tag = tags[sets]                                      # (m, ways)
    rows_owner = owner[sets]
    eq = (rows_tag == keys[:, None]) & valid[:, None] \
        & (rows_owner == jnp.int32(tenant))
    hit = eq.any(axis=1)
    hway = jnp.argmax(eq, axis=1).astype(jnp.int32)
    hslot = jnp.where(hit, sets * ways + hway, -1).astype(jnp.int32)

    # ---- allocation candidates ------------------------------------------
    miss = valid & ~hit
    if alloc_mask is not None:
        miss = miss & alloc_mask

    # ---- per-(row, way) eviction eligibility ----------------------------
    rows_ref = refcount[sets]
    rows_dirty = dirty[sets]
    rows_spec = speculative[sets]
    elig = rows_ref == 0
    foreign_dirty = (rows_owner != jnp.int32(tenant)) \
        & (rows_tag >= 0) & rows_dirty
    elig = elig & ~foreign_dirty
    warange = jnp.arange(ways, dtype=jnp.int32)
    if way_lo != 0 or way_hi != ways:
        elig = elig & ((warange >= way_lo) & (warange < way_hi))[None, :]
    if spec_insert:
        elig = elig & ~(rows_spec & (rows_tag >= 0))
    overlay = jnp.zeros((num_sets * ways,), bool)
    if protect_hits:
        hs = jnp.where(hit, hslot, num_sets * ways)
        overlay = overlay.at[hs].set(True, mode="drop")
    if protect_slots is not None:
        ps = jnp.where(protect_slots >= 0, protect_slots, num_sets * ways)
        overlay = overlay.at[ps].set(True, mode="drop")
    elig = elig & ~overlay.reshape(num_sets, ways)[sets]

    # ---- class-then-clock victim select, argsort-free -------------------
    rank = segment_rank(sets, miss)                            # (m,)
    hand = clock_hand[sets]                                    # (m,)
    clock_pos = (warange[None, :] - hand[:, None]) % ways      # (m, ways)
    vclass = jnp.where(rows_tag < 0, 0,
                       jnp.where(rows_spec, 1, 2)).astype(jnp.int32)
    key_w = vclass * ways + clock_pos                          # distinct/row
    smaller = key_w[:, None, :] < key_w[:, :, None]            # [i, w, w']
    eidx = jnp.sum(smaller & elig[:, None, :], axis=2)         # (m, ways)
    n_elig = jnp.sum(elig, axis=1)
    sel = elig & (eidx == rank[:, None]) & miss[:, None]
    ok = miss & (n_elig >= rank + 1)
    way = jnp.argmax(sel, axis=1).astype(jnp.int32)
    safe_way = jnp.where(ok, way, 0)
    rows_i = jnp.arange(m)
    evicted_key = jnp.where(ok, rows_tag[rows_i, safe_way], -1)
    evicted_dirty = jnp.where(ok, rows_dirty[rows_i, safe_way], False)
    return (hit, hslot, jnp.where(ok, way, -1), ok,
            evicted_key.astype(jnp.int32), evicted_dirty)
