"""Pure-jnp oracles for every Pallas kernel (the ``assert_allclose`` refs)."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D). Plain masked softmax."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows -> zeros, matching the kernel's l=0 guard
    any_live = mask.any(axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    out = jnp.where(any_live[None, None, :, None], out, 0.0)
    return out.astype(q.dtype)


def _fa_mask(iq, jk, bq, bkv, skv, causal, window):
    q_pos = iq * bq + jnp.arange(bq)[:, None]
    kv_pos = jk * bkv + jnp.arange(bkv)[None, :]
    mask = kv_pos < skv
    if causal:
        mask &= kv_pos <= q_pos
    mask &= kv_pos > q_pos - window
    return mask


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _fa_core(q, k, v, window, causal, scale, bq, bkv, kv_len):
    out, _ = _fa_fwd_inner(q, k, v, window, causal, scale, bq, bkv, kv_len)
    return out


def _fa_fwd_inner(q, k, v, window, causal, scale, bq, bkv, kv_len=None):
    """Blockwise online-softmax forward. q: (B,Hkv,G,Sq,D) (padded);
    k, v: (B,Hkv,Skv,D). Tiles keep the input dtype (bf16 tiles when the
    config sets attn_f32=False); accumulation is f32 via
    preferred_element_type. Returns (out, logsumexp L)."""
    B, Hkv, G, Sq, D = q.shape
    Skv_pad = k.shape[2]
    Skv = Skv_pad if kv_len is None else kv_len     # mask bound (true len)
    nq, nkv = Sq // bq, Skv_pad // bkv
    qs = q.reshape(B, Hkv, G, nq, bq, D).transpose(3, 0, 1, 2, 4, 5)
    ks = k.reshape(B, Hkv, nkv, bkv, D).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, Hkv, nkv, bkv, D).transpose(2, 0, 1, 3, 4)

    def one_q(_, qi):
        qb, iq = qi                                     # (B,Hkv,G,bq,D)

        def kv_step(carry, kvj):
            m, l, acc = carry
            kb, vb, jk = kvj
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = _fa_mask(iq, jk, bq, bkv, Skv, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m2 = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m2)
            p = jnp.where(mask[None, None, None], jnp.exp(s - m2[..., None]),
                          0.0)
            l2 = l * alpha + p.sum(-1)
            acc2 = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(qb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m2, l2, acc2), None

        m0 = jnp.full((B, Hkv, G, bq), NEG_INF, jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, jnp.zeros_like(m0),
                      jnp.zeros((B, Hkv, G, bq, D), jnp.float32)),
            (ks, vs, jnp.arange(nkv)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(one_q, None, (qs, jnp.arange(nq)))
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hkv, G, Sq, D)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, Hkv, G, Sq)
    return out, lse


def _fa_vjp_fwd(q, k, v, window, causal, scale, bq, bkv, kv_len):
    out, lse = _fa_fwd_inner(q, k, v, window, causal, scale, bq, bkv,
                             kv_len)
    return out, (q, k, v, window, out, lse)


def _fa_vjp_bwd(causal, scale, bq, bkv, kv_len, res, dout):
    """Manual flash backward: recompute P per block; O(S) memory."""
    q, k, v, window, out, lse = res
    B, Hkv, G, Sq, D = q.shape
    Skv_pad = k.shape[2]
    nq, nkv = Sq // bq, Skv_pad // bkv
    delta = jnp.sum(dout * out, axis=-1)                # (B,Hkv,G,Sq)
    qs = q.reshape(B, Hkv, G, nq, bq, D).transpose(3, 0, 1, 2, 4, 5)
    dos = dout.reshape(B, Hkv, G, nq, bq, D).transpose(3, 0, 1, 2, 4, 5)
    ls = lse.reshape(B, Hkv, G, nq, bq).transpose(3, 0, 1, 2, 4)
    ds = delta.reshape(B, Hkv, G, nq, bq).transpose(3, 0, 1, 2, 4)
    ks = k.reshape(B, Hkv, nkv, bkv, D).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, Hkv, nkv, bkv, D).transpose(2, 0, 1, 3, 4)

    def one_q(carry, xs):
        dk_all, dv_all = carry                          # (nkv,B,Hkv,bkv,D)
        qb, dob, lb, db, iq = xs

        def kv_step(carry2, jk):
            dqi, dk_a, dv_a = carry2
            kb, vb = ks[jk], vs[jk]
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb) * scale
            mask = _fa_mask(iq, jk, bq, bkv, kv_len, causal, window)
            p = jnp.where(mask[None, None, None],
                          jnp.exp(s - lb[..., None]), 0.0)
            dv_j = jnp.einsum("bhgqk,bhgqd->bhkd", p, dob)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", dob, vb)
            dsij = p * (dp - db[..., None]) * scale
            dqi = dqi + jnp.einsum("bhgqk,bhkd->bhgqd", dsij, kb)
            dk_j = jnp.einsum("bhgqk,bhgqd->bhkd", dsij, qb)
            dk_a = dk_a.at[jk].add(dk_j)
            dv_a = dv_a.at[jk].add(dv_j)
            return (dqi, dk_a, dv_a), None

        dq0 = jnp.zeros_like(qb)
        (dqi, dk_all, dv_all), _ = jax.lax.scan(
            kv_step, (dq0, dk_all, dv_all), jnp.arange(nkv))
        return (dk_all, dv_all), dqi

    dk0 = jnp.zeros((nkv, B, Hkv, bkv, D), jnp.float32)
    dv0 = jnp.zeros((nkv, B, Hkv, bkv, D), jnp.float32)
    (dk_all, dv_all), dqs = jax.lax.scan(
        one_q, (dk0, dv0), (qs, dos, ls, ds, jnp.arange(nq)))
    dq = dqs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hkv, G, Sq, D)
    dk = dk_all.transpose(1, 2, 0, 3, 4).reshape(B, Hkv, Skv_pad, D)
    dv = dv_all.transpose(1, 2, 0, 3, 4).reshape(B, Hkv, Skv_pad, D)
    return dq, dk, dv, None


_fa_core.defvjp(_fa_vjp_fwd, _fa_vjp_bwd)


def flash_attention_xla(q, k, v, *, causal=True, window=None, scale=None,
                        block_q: int = 1024, block_kv: int = 1024,
                        tile_f32: bool = True):
    """Blockwise attention in pure XLA ops with a **manual flash backward**
    (custom_vjp) — bounded memory in fwd AND bwd, honest HLO for the
    dry-run/roofline.  Handles traced ``window`` (scanned per-layer)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    pq, pkv = (-Sq) % bq, (-Skv) % bkv
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pkv), (0, 0))) if pkv else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pkv), (0, 0))) if pkv else v
    if window is None:
        window = jnp.int32(1 << 30)
    tdt = jnp.float32 if tile_f32 else jnp.bfloat16
    qg = qp.reshape(B, Hkv, group, qp.shape[2], D).astype(tdt)
    out = _fa_core(qg, kp.astype(tdt), vp.astype(tdt),
                   jnp.asarray(window, jnp.int32), causal, scale, bq, bkv,
                   Skv)
    out = out.reshape(B, Hq, qp.shape[2], D)[:, :, :Sq, :]
    return out.astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens, *,
                        scale=None):
    """q: (B, Hq, D); pools (B, P, page, Hkv, D); table (B, NP); lens (B,)."""
    B, Hq, D = q.shape
    _, P, page, Hkv, _ = k_pages.shape
    NP = page_table.shape[1]
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    safe = jnp.maximum(page_table, 0)                          # (B, NP)
    idx = safe[:, :, None, None, None]
    k = jnp.take_along_axis(k_pages, idx, axis=1)              # (B, NP, page, Hkv, D)
    v = jnp.take_along_axis(v_pages, idx, axis=1)
    S = NP * page
    k = k.transpose(0, 3, 1, 2, 4).reshape(B, Hkv, S, D)
    v = v.transpose(0, 3, 1, 2, 4).reshape(B, Hkv, S, D)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    pos = jnp.arange(S)[None, :]                               # (1, S)
    hole = jnp.repeat(page_table < 0, page, axis=1)            # (B, S)
    live = (pos < seq_lens[:, None]) & ~hole
    s = jnp.where(live[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(live[:, None], p, 0.0)
    out = jnp.einsum("bhk,bhkd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def gather_blocks_ref(data, slots, off=None):
    """data: (num_lines, line_elems); slots: (n,) -> (n, line_elems).

    With ``off`` (per-request in-line element offsets), gather single
    elements — ``(n,)`` of ``data[slots, off]`` — instead of whole lines:
    the XLA path moves one element per lane, not a full line per lane.
    """
    safe = jnp.maximum(slots, 0)
    if off is not None:
        return jnp.where(slots >= 0, data[safe, off], 0)
    out = data[safe]
    return jnp.where((slots >= 0)[:, None], out, 0)


def cache_probe_ref(tags, keys, owner=None, tenant=0):
    """Mirror of repro.core.cache.probe on a raw tag directory.

    With ``owner`` (the per-line tenant stamp), a line only hits when its
    owner matches ``tenant`` — the multi-tenant tag namespacing.  Negative
    keys never hit.
    """
    from repro.utils import mix_hash
    num_sets, ways = tags.shape
    valid = keys >= 0
    sets = mix_hash(jnp.where(valid, keys, 0)) % num_sets
    rows = tags[sets]                                          # (m, ways)
    eq = (rows == keys[:, None]) & valid[:, None]
    if owner is not None:
        eq = eq & (owner[sets] == jnp.int32(tenant))
    hit = eq.any(axis=1)
    way = jnp.argmax(eq, axis=1).astype(jnp.int32)
    slot = jnp.where(hit, sets * ways + way, -1).astype(jnp.int32)
    return hit, slot


def probe_allocate_ref(tags, owner, refcount, dirty, speculative, clock_hand,
                       keys, valid, alloc_mask=None, protect_slots=None, *,
                       tenant=0, way_lo=0, way_hi=None, spec_insert=False,
                       protect_hits=True):
    """Fused probe + clock-sweep victim select, pure jnp — the oracle for
    ``probe_allocate_pallas`` and the CPU/XLA hot path.

    One set-local pass per request: hash → tag+owner probe → (for misses)
    pick the request's victim way in *class-then-clock* order.  The victim
    class is 0 = invalid, 1 = speculative (prefetched, unpromoted),
    2 = demand-resident; within a class, ways are taken in clock order
    starting at the set's hand.  Unlike ``repro.core.cache.allocate`` no
    ``(m, ways)`` stable argsort is materialized: the selected way is the
    one whose *eligible-order index* — the count of eligible ways with a
    strictly smaller ``class*ways + clock_pos`` sort key (keys are distinct
    per row) — equals the request's same-set rank.  That count is a handful
    of ``(m, ways)`` comparisons, and it selects exactly the way the stable
    argsort would.

    Eligibility honours everything the inline path honours: pinned lines
    (``refcount > 0``), foreign dirty lines (another tenant's write-back),
    the ``[way_lo, way_hi)`` tenant way window, pending speculative lines
    when ``spec_insert`` (a prefetch never cannibalizes an unconsumed
    prediction), this wavefront's own probe hits (``protect_hits``) and the
    caller's extra ``protect_slots``.

    Returns ``(hit, hit_slot, way, ok, evicted_key, evicted_dirty)``; all
    allocation outputs are masked (-1 / False) on rows with ``ok=False``.
    """
    from repro.utils import mix_hash, segment_rank
    num_sets, ways = tags.shape
    way_hi = ways if way_hi is None else way_hi
    m = keys.shape[0]
    sets = mix_hash(jnp.where(valid, keys, 0)) % num_sets

    # ---- probe -----------------------------------------------------------
    rows_tag = tags[sets]                                      # (m, ways)
    rows_owner = owner[sets]
    eq = (rows_tag == keys[:, None]) & valid[:, None] \
        & (rows_owner == jnp.int32(tenant))
    hit = eq.any(axis=1)
    hway = jnp.argmax(eq, axis=1).astype(jnp.int32)
    hslot = jnp.where(hit, sets * ways + hway, -1).astype(jnp.int32)

    # ---- allocation candidates ------------------------------------------
    miss = valid & ~hit
    if alloc_mask is not None:
        miss = miss & alloc_mask

    def _select_victims():
        # ---- per-(row, way) eviction eligibility ------------------------
        rows_ref = refcount[sets]
        rows_dirty = dirty[sets]
        rows_spec = speculative[sets]
        elig = rows_ref == 0
        foreign_dirty = (rows_owner != jnp.int32(tenant)) \
            & (rows_tag >= 0) & rows_dirty
        elig = elig & ~foreign_dirty
        warange = jnp.arange(ways, dtype=jnp.int32)
        if way_lo != 0 or way_hi != ways:
            elig = elig & ((warange >= way_lo) & (warange < way_hi))[None, :]
        if spec_insert:
            elig = elig & ~(rows_spec & (rows_tag >= 0))
        overlay = jnp.zeros((num_sets * ways,), bool)
        if protect_hits:
            hs = jnp.where(hit, hslot, num_sets * ways)
            overlay = overlay.at[hs].set(True, mode="drop")
        if protect_slots is not None:
            ps = jnp.where(protect_slots >= 0, protect_slots,
                           num_sets * ways)
            overlay = overlay.at[ps].set(True, mode="drop")
        elig = elig & ~overlay.reshape(num_sets, ways)[sets]

        # ---- class-then-clock victim select, argsort-free ---------------
        rank = segment_rank(sets, miss)                        # (m,)
        hand = clock_hand[sets]                                # (m,)
        clock_pos = (warange[None, :] - hand[:, None]) % ways  # (m, ways)
        vclass = jnp.where(rows_tag < 0, 0,
                           jnp.where(rows_spec, 1, 2)).astype(jnp.int32)
        key_w = vclass * ways + clock_pos                      # distinct/row
        smaller = key_w[:, None, :] < key_w[:, :, None]        # [i, w, w']
        eidx = jnp.sum(smaller & elig[:, None, :], axis=2)     # (m, ways)
        n_elig = jnp.sum(elig, axis=1)
        sel = elig & (eidx == rank[:, None]) & miss[:, None]
        ok = miss & (n_elig >= rank + 1)
        way = jnp.argmax(sel, axis=1).astype(jnp.int32)
        safe_way = jnp.where(ok, way, 0)
        rows_i = jnp.arange(m)
        evicted_key = jnp.where(ok, rows_tag[rows_i, safe_way], -1)
        evicted_dirty = jnp.where(ok, rows_dirty[rows_i, safe_way], False)
        return (jnp.where(ok, way, -1), ok,
                evicted_key.astype(jnp.int32), evicted_dirty)

    def _no_miss():
        return (jnp.full((m,), -1, jnp.int32), jnp.zeros((m,), bool),
                jnp.full((m,), -1, jnp.int32), jnp.zeros((m,), bool))

    # Hit fast path (paper: cache hits never touch the I/O machinery):
    # with no miss in the wavefront every victim-select output is masked
    # anyway, so skipping the sweep is bit-identical and an all-hit
    # steady-state round pays only the probe.
    way, ok, evicted_key, evicted_dirty = jax.lax.cond(
        jnp.any(miss), _select_victims, _no_miss)
    return hit, hslot, way, ok, evicted_key, evicted_dirty


def sq_enqueue_ref(sq_key, sq_dst, sq_is_write, sq_prio, sq_tenant,
                   sq_ticket, sq_tail, sq_head, rr_ptr, dev_enqueued,
                   keys, dst, is_write, prio, valid, *,
                   seg_bounds, n_devices, stripe_blocks, tenant,
                   failed_devices=()):
    """Fused multi-segment SQ enqueue — one scatter round for a whole
    submission (demand reads + write-backs + bypass writes + readahead).

    ``keys``/``dst``/``is_write``/``prio``/``valid`` are the *concatenated*
    command segments of one tenant's submission; ``seg_bounds`` is the
    static tuple of ``(start, end)`` offsets delimiting them in issue
    order.  Routing (device striping, per-device ticket, round-robin queue
    pick, virtual-slot assignment, ring-full back-pressure) is computed
    per segment with running tails and round-robin pointers — exactly the
    sequence of :func:`repro.core.queues.enqueue` calls it replaces — but
    the five SQ ring fields are each written by a *single* combined
    scatter over all segments.  That is bit-identical to the sequential
    scatters: every accepted command's virtual slot lies in
    ``[head, head + depth)`` of its queue, so accepted ``(queue, slot)``
    pairs are distinct across segments and scatter order cannot matter;
    rejected commands scatter out of bounds and drop.

    Returns ``(sq_key, sq_dst, sq_is_write, sq_prio, sq_tenant, sq_ticket,
    sq_tail, rr_ptr, queue, vslot, accepted, ticket_id, per_seg)`` where
    ``queue``/``vslot``/``accepted``/``ticket_id`` are concatenated
    per-command routing results (unmasked — the caller builds receipts)
    and ``per_seg`` is a dict of stacked per-segment statistics:
    ``n_accepted``, ``n_dropped``, ``n_doorbells``, ``n_tickets`` (each
    ``(S,)``) and ``dev_dropped``, ``dev_accepted`` (each
    ``(S, n_devices)``).

    ``ticket_id`` is the command's per-device *accepted* ordinal
    (``dev_enqueued`` base plus a running in-submission rank) — the
    counter the :class:`~repro.core.ssd.FaultModel` hashes; it is stamped
    into the ``sq_ticket`` ring alongside the other five fields (the
    packed scatter grows to six lanes).  ``failed_devices`` routes around
    hard-failed channels exactly as the sequential enqueue does.
    """
    from repro.core.ssd import device_of_block
    nq, depth = sq_key.shape
    gsize = nq // n_devices
    nd = n_devices
    tail = sq_tail
    rr = rr_ptr
    dev_base = dev_enqueued
    q_parts, v_parts, a_parts, t_parts = [], [], [], []
    n_acc, n_drop, n_db, n_tick = [], [], [], []
    dev_drop, dev_acc = [], []
    for (s, e) in seg_bounds:
        k_s, v_s = keys[s:e], valid[s:e]
        dev = device_of_block(k_s, nd, stripe_blocks, failed_devices)
        onehot = ((dev[:, None] == jnp.arange(nd, dtype=jnp.int32)[None, :])
                  & v_s[:, None]).astype(jnp.int32)
        ticket = jnp.take_along_axis(
            jnp.cumsum(onehot, axis=0) - onehot, dev[:, None], axis=1)[:, 0]
        k_dev = jnp.sum(onehot, axis=0)
        queue = dev * gsize + (rr[dev] + ticket) % gsize
        pos_in_q = ticket // gsize
        vslot = tail[queue] + pos_in_q
        fits = (vslot - sq_head[queue]) < depth
        accepted = v_s & fits
        acc_i = accepted.astype(jnp.int32)
        # one-hot reductions, not scatter-adds: integer sums are order-free
        # (bit-identical to ``.at[].add``) and vectorize where XLA:CPU
        # would serialize the scattered updates
        per_q = jnp.sum(
            (queue[:, None] == jnp.arange(nq, dtype=jnp.int32)[None, :])
            & accepted[:, None], axis=0, dtype=jnp.int32)
        tail = tail + per_q
        rr = (rr + k_dev) % gsize
        drops = v_s & ~fits
        # per-device accepted ordinal with a running cross-segment base —
        # bit-identical to the per-call `dev_enqueued[dev] + rank` of the
        # sequential enqueue path
        acc_oh = onehot * acc_i[:, None]
        arank = jnp.take_along_axis(
            jnp.cumsum(acc_oh, axis=0) - acc_oh, dev[:, None], axis=1)[:, 0]
        dev_acc_seg = jnp.sum(acc_oh, axis=0)
        t_parts.append((dev_base[dev] + arank).astype(jnp.int32))
        dev_base = dev_base + dev_acc_seg
        q_parts.append(queue)
        v_parts.append(vslot)
        a_parts.append(accepted)
        n_acc.append(jnp.sum(acc_i))
        n_drop.append(jnp.sum(drops.astype(jnp.int32)))
        n_db.append(jnp.sum((per_q > 0).astype(jnp.int32)))
        n_tick.append(jnp.sum(k_dev))
        dev_drop.append(jnp.sum(onehot * drops.astype(jnp.int32)[:, None],
                                axis=0))
        dev_acc.append(dev_acc_seg)

    queue = jnp.concatenate(q_parts)
    vslot = jnp.concatenate(v_parts)
    accepted = jnp.concatenate(a_parts)
    ticket_id = jnp.concatenate(t_parts)
    qidx = jnp.where(accepted, queue, nq)
    sidx = jnp.where(accepted, (vslot % depth).astype(jnp.int32), 0)

    def _commit(rings):
        rk, rd, rw, rp, rt, rtk = rings
        # ONE packed scatter, not six: the ring fields share the same
        # (queue, slot) indices, so stacking them into a (nq, depth, 6)
        # view turns six n-update scatters into one whose updates are
        # contiguous 6-lane windows — XLA:CPU processes scattered updates
        # serially, so update *count* is the cost.  The int32 round-trip
        # of the bool field and the unpack slices are bit-exact.
        packed = jnp.stack(
            [rk, rd, rw.astype(jnp.int32), rp, rt, rtk], axis=-1)
        upd = jnp.stack(
            [keys, dst, is_write.astype(jnp.int32), prio,
             jnp.broadcast_to(jnp.int32(tenant), keys.shape),
             ticket_id], axis=-1)
        packed = packed.at[qidx, sidx].set(upd, mode="drop")
        return (packed[..., 0], packed[..., 1], packed[..., 2] != 0,
                packed[..., 3], packed[..., 4], packed[..., 5])

    # Hit fast path: a wavefront that enqueues nothing (every demand lane
    # was a cache hit) drops every update, so the rings pass through
    # bit-identical — skip the six full-ring scatters entirely.
    sq_key, sq_dst, sq_is_write, sq_prio, sq_tenant, sq_ticket = \
        jax.lax.cond(
            jnp.any(accepted), _commit, lambda rings: rings,
            (sq_key, sq_dst, sq_is_write, sq_prio, sq_tenant, sq_ticket))
    per_seg = dict(
        n_accepted=jnp.stack(n_acc), n_dropped=jnp.stack(n_drop),
        n_doorbells=jnp.stack(n_db), n_tickets=jnp.stack(n_tick),
        dev_dropped=jnp.stack(dev_drop), dev_accepted=jnp.stack(dev_acc))
    return (sq_key, sq_dst, sq_is_write, sq_prio, sq_tenant, sq_ticket,
            tail, rr, queue, vslot, accepted, ticket_id, per_seg)


def wfq_drain_ref(sq_key, sq_is_write, sq_tenant, sq_ticket=None, *,
                  n_devices, n_tenants, fault=None):
    """Closed-form drain accounting — the reduction half of
    :func:`repro.core.queues.service_all` without materialising (or
    sorting) the completion stream.

    Because enqueue routes every command to its block key's device group,
    per-device completion counts are plain group-reshaped sums over the
    pending SQ entries, and the read/write split per device falls out of
    the ``sq_is_write`` ring field — no 32k-lane histogram over a sorted
    ``Completions`` vector.  Returns ``(count, count_dev, count_tenant,
    reads_dev, writes_dev, fstats)``, bit-identical to counting
    ``service_all``'s completions (the WFQ/priority *ordering* permutes
    the stream but every reduction here is order-free).

    ``fstats`` carries the fault accounting keyed like the extra
    :class:`~repro.core.queues.DrainReceipt` fields.  With ``fault``
    enabled it resolves each pending command's closed-form retry loop from
    its ``(device, sq_ticket)`` stamp (same pure
    :meth:`~repro.core.ssd.FaultModel.command_status` function the waiter
    applies to its token tickets — the two agree by construction);
    otherwise it is all zeros and no fault computation is traced.
    """
    nq, depth = sq_key.shape
    gsize = nq // n_devices
    pending = sq_key >= 0
    pend_i = pending.astype(jnp.int32)
    count = jnp.sum(pend_i)
    count_dev = jnp.sum(pend_i.reshape(n_devices, gsize * depth), axis=1)
    writes_dev = jnp.sum((pending & sq_is_write).astype(jnp.int32)
                         .reshape(n_devices, gsize * depth), axis=1)
    reads_dev = count_dev - writes_dev
    flat_t = sq_tenant.reshape(-1)
    flat_p = pending.reshape(-1)
    count_tenant = jnp.sum(
        (flat_t[:, None] == jnp.arange(n_tenants, dtype=jnp.int32)[None, :])
        & flat_p[:, None], axis=0).astype(jnp.int32)

    def _group_sum(x_i32):
        return jnp.sum(x_i32.reshape(n_devices, gsize * depth),
                       axis=1).astype(jnp.int32)

    if fault is not None and fault.enabled:
        dev_of_entry = (jnp.arange(nq, dtype=jnp.int32) // gsize)[:, None]
        ok_e, retries_e, transient_e = fault.command_status(
            dev_of_entry, sq_ticket)
        err = pending & ~ok_e
        errors_dev = _group_sum(err.astype(jnp.int32))
        errors_tenant = jnp.sum(
            (flat_t[:, None]
             == jnp.arange(n_tenants, dtype=jnp.int32)[None, :])
            & err.reshape(-1)[:, None], axis=0).astype(jnp.int32)
        err_writes_dev = _group_sum((err & sq_is_write).astype(jnp.int32))
        fstats = dict(
            errors_dev=errors_dev,
            errors_tenant=errors_tenant,
            err_reads_dev=errors_dev - err_writes_dev,
            err_writes_dev=err_writes_dev,
            retry_reads_dev=_group_sum(
                jnp.where(pending & ~sq_is_write, retries_e, 0)),
            retry_writes_dev=_group_sum(
                jnp.where(pending & sq_is_write, retries_e, 0)),
            transient_errors=jnp.sum(
                jnp.where(pending, transient_e, 0)).astype(jnp.int32),
        )
    else:
        zd = jnp.zeros((n_devices,), jnp.int32)
        fstats = dict(
            errors_dev=zd, errors_tenant=jnp.zeros((n_tenants,), jnp.int32),
            err_reads_dev=zd, err_writes_dev=zd,
            retry_reads_dev=zd, retry_writes_dev=zd,
            transient_errors=jnp.zeros((), jnp.int32),
        )
    return count, count_dev, count_tenant, reads_dev, writes_dev, fstats
