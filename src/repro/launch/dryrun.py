import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first lines, before any other import: jax locks the device
#   count at first init.  512 placeholder host devices back the production
#   meshes (16x16 single-pod slice, 2x16x16 multi-pod).

"""Multi-pod dry-run: ``lower().compile()`` every (arch x shape x mesh) cell.

For each cell this builds the full distributed step function — train_step
(fwd+bwd+AdamW) for train cells, last-token prefill forward for prefill
cells, one-token ``serve_step`` with the BaM-paged KV cache for decode
cells — entirely against ShapeDtypeStructs (no allocation), lowers it for
the production mesh, compiles it, and records:

  * ``memory_analysis()``  — proves the cell fits per-device HBM,
  * ``cost_analysis()``    — XLA's own FLOPs/bytes (loop bodies counted 1x),
  * the trip-count-aware HLO walk — FLOPs/bytes/collective-bytes per device
    (feeds EXPERIMENTS.md §Roofline),
  * the collective schedule summary.

Results append incrementally to a JSON file so the sweep is resumable.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.json
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (SHAPES, get_config, input_specs, list_archs)
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis, roofline
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.training import optimizer as opt
from repro.training.train_loop import (batch_shardings, make_train_step,
                                       state_shardings)

KEY = jax.random.PRNGKey(0)


def _eval_shape_with_axes(fn):
    """eval_shape a (value, axes) returning fn; capture axes via closure."""
    box = {}

    def wrapped(*args):
        v, a = fn(*args)
        box["axes"] = a
        return v

    sds = jax.eval_shape(wrapped)
    return sds, box["axes"]


def build_cell(cfg, cell, mesh, rules=None, pod_compression=False,
               microbatches: int = 1):
    """Returns (jittable, example_args, in_shardings, out_shardings,
    donate_argnums) for one cell — everything as ShapeDtypeStructs.

    ``pod_compression`` defaults off here: the int8-EF cross-pod reduction
    (tested on 8 fake devices in tests/test_distributed.py) trips an XLA
    SPMD-partitioner CHECK at 512 devices on some graphs (see DESIGN.md
    §known-issues); the baseline multi-pod pass uses the plain reduction.
    """
    api = build_model(cfg, impl="ref")
    with shd.activate(mesh, rules):
        if cell.kind == "train":
            params_sds, axes = _eval_shape_with_axes(
                lambda: api.init(KEY, cell.seq_len))
            acfg = opt.AdamWConfig(
                pod_compression=(pod_compression
                                 and "pod" in mesh.axis_names))
            opt_sds = jax.eval_shape(lambda: opt.adamw_init(params_sds,
                                                            acfg))
            state_sds = {"params": params_sds, "opt": opt_sds}
            st_sh = state_shardings(cfg, axes, mesh, params_sds, acfg)
            batch_sds = input_specs(cfg, cell)
            b_sh = batch_shardings(batch_sds, mesh)
            step = make_train_step(cfg, api, adamw=acfg, mesh=mesh,
                                   microbatches=microbatches)
            return (step, (state_sds, batch_sds), (st_sh, b_sh),
                    (st_sh, None), (0,))

        if cell.kind == "prefill":
            params_sds, axes = _eval_shape_with_axes(
                lambda: api.init(KEY, cell.seq_len))
            p_sh = shd.param_shardings(axes, mesh, shapes_tree=params_sds)
            batch_sds = input_specs(cfg, cell)
            b_sh = batch_shardings(batch_sds, mesh)

            def prefill_step(params, batch):
                from repro.models import hymba, transformer, xlstm
                mod = {"ssm": xlstm, "hybrid": hymba}.get(
                    cfg.family, transformer)
                logits, _ = mod.forward(cfg, params, batch, "ref",
                                        last_only=True)
                return logits

            return (prefill_step, (params_sds, batch_sds), (p_sh, b_sh),
                    None, ())

        # decode
        B, S = cell.global_batch, cell.seq_len
        params_sds, axes = _eval_shape_with_axes(lambda: api.init(KEY, S))
        p_sh = shd.param_shardings(axes, mesh, shapes_tree=params_sds)
        cache_sds, cache_axes = _eval_shape_with_axes(
            lambda: api.init_decode_cache(B, S))
        c_sh = shd.param_shardings(cache_axes, mesh, shapes_tree=cache_sds)
        tok_sds = input_specs(cfg, cell)["tokens"]
        t_sh = NamedSharding(
            mesh, shd._spec_for_shape(["batch"], tok_sds.shape, mesh,
                                      shd.current_rules()))

        def serve_step(params, cache, tokens):
            return api.decode_step(params, cache, tokens)

        return (serve_step, (params_sds, cache_sds, tok_sds),
                (p_sh, c_sh, t_sh), (None, c_sh), (1,))


def run_cell(arch: str, shape: str, multi_pod: bool, rules=None,
             cfg_override=None, pod_compression=False,
             microbatches: int = 1):
    cell = SHAPES[shape]
    cfg = cfg_override or get_config(arch)
    cfg = cfg.replace(use_pallas="ref")
    out = {"arch": arch, "shape": shape,
           "mesh": "multi" if multi_pod else "single"}
    if not cfg.supports_cell(cell):
        out["skipped"] = ("long_500k needs sub-quadratic attention; "
                          f"{arch} is pure full-attention (see DESIGN.md)")
        return out
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with shd.activate(mesh, rules):
        fn, args, in_sh, out_sh, donate = build_cell(
            cfg, cell, mesh, rules, pod_compression=pod_compression,
            microbatches=microbatches)
        # one-shot lowering probe: jitted once per dryrun invocation
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,  # bamlint: ignore[BAM105]
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    out["memory"] = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "per_device_total": (mem.argument_size_in_bytes
                             + mem.output_size_in_bytes
                             + mem.temp_size_in_bytes
                             - mem.alias_size_in_bytes),
    }
    try:
        ca = compiled.cost_analysis()
        out["cost_analysis"] = {"flops": ca.get("flops", 0.0),
                                "bytes_accessed": ca.get("bytes accessed",
                                                         0.0)}
    except Exception as e:           # pragma: no cover
        out["cost_analysis"] = {"error": str(e)}
    hc = hlo_analysis.analyze_compiled(compiled)
    out["hlo"] = {"flops": hc.flops, "mem_bytes": hc.mem_bytes,
                  "coll_bytes": hc.coll_bytes,
                  "coll_bytes_effective": hc.total_coll_bytes}
    rf = roofline.Roofline(
        flops_per_device=hc.flops,
        mem_bytes_per_device=hc.mem_bytes,
        coll_bytes_per_device=hc.total_coll_bytes,
        model_flops=roofline.model_flops_for_cell(cfg, cell),
        chips=int(mesh.size))
    out["roofline"] = rf.to_dict()
    out["timings"] = {"lower_s": t_lower, "compile_s": t_compile}
    return out


def _run_cell_subprocess(arch, shape, mp, timeout=1500):
    """Isolate one cell in a child process: a native XLA CHECK abort then
    costs one cell, not the sweep."""
    import subprocess
    import sys
    import tempfile
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        tmp = f.name
    Path(tmp).unlink(missing_ok=True)      # child must not read it as JSON
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape,
           "--mesh", "multi" if mp else "single", "--out", tmp, "--force"]
    try:
        p = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=timeout)
        data = json.loads(Path(tmp).read_text()) if Path(tmp).exists() \
            else {}
        key = f"{arch}|{shape}|{'multi' if mp else 'single'}"
        if key in data:
            return data[key]
        return {"arch": arch, "shape": shape,
                "mesh": "multi" if mp else "single",
                "error": f"subprocess died rc={p.returncode}",
                "traceback": (p.stderr or "")[-2000:]}
    except subprocess.TimeoutExpired:
        return {"arch": arch, "shape": shape,
                "mesh": "multi" if mp else "single",
                "error": "subprocess timeout"}
    finally:
        Path(tmp).unlink(missing_ok=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--subproc", action="store_true",
                    help="isolate each cell in a child process")
    ap.add_argument("--compressed", action="store_true",
                    help="enable int8-EF pod compression in train cells")
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = {}
    out_path = Path(args.out) if args.out else None
    if out_path and out_path.exists():
        try:
            results = json.loads(out_path.read_text())
        except json.JSONDecodeError:
            results = {}

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}|{shape}|{'multi' if mp else 'single'}"
                if key in results and not args.force \
                        and "error" not in results[key]:
                    print(f"[skip cached] {key}")
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                if args.subproc:
                    r = _run_cell_subprocess(arch, shape, mp)
                else:
                    try:
                        r = run_cell(arch, shape, mp,
                                     pod_compression=args.compressed)
                    except Exception as e:
                        r = {"arch": arch, "shape": shape,
                             "mesh": "multi" if mp else "single",
                             "error": f"{type(e).__name__}: {e}",
                             "traceback": traceback.format_exc()[-2000:]}
                results[key] = r
                if out_path:
                    out_path.parent.mkdir(parents=True, exist_ok=True)
                    out_path.write_text(json.dumps(results, indent=1))
                if "error" in r:
                    print(f"  ERROR: {r['error']}")
                elif "skipped" in r:
                    print(f"  SKIPPED: {r['skipped']}")
                else:
                    m = r["memory"]["per_device_total"] / 2**30
                    rf = r["roofline"]
                    print(f"  ok mem/dev={m:.2f}GiB bound={rf['bound']} "
                          f"compute={rf['compute_s']:.4f}s "
                          f"mem={rf['memory_s']:.4f}s "
                          f"coll={rf['collective_s']:.4f}s "
                          f"(compile {r['timings']['compile_s']:.0f}s)")
    n_err = sum(1 for r in results.values() if "error" in r)
    print(f"done: {len(results)} cells, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
