"""Trip-count-aware analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` visits each ``while`` body **once**, so a
scan-over-48-layers model under-reports FLOPs/bytes by ~48x and collective
bytes are absent entirely.  This module parses ``compiled.as_text()`` and
walks the computation graph recursively, multiplying loop bodies by their
trip counts (recovered from the loop-condition constants), to produce the
three roofline inputs per device:

  * dot FLOPs              (MXU term)
  * memory traffic bytes   (operand+result bytes of top-level instructions
                            of the post-fusion HLO — the XLA fusion
                            boundary approximates HBM round-trips)
  * collective bytes       (by op kind, ring-factor-adjusted)

Validated against cost_analysis() on loop-free graphs in tests.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# effective wire bytes per device ≈ factor × result bytes (ring algorithms)
_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_ZERO_COST_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id",
    "opt-barrier",
}

# ``custom-call`` is zero-cost for the roofline (an opaque target whose
# bytes/FLOPs XLA cannot see either) but must NOT be invisible: host
# callbacks (``jax.pure_callback`` / ``io_callback``) lower to custom-calls,
# and tools/bamverify's BAM503 rule audits where they sit in the compiled
# graph.  ``parse_computations`` therefore surfaces every custom-call's
# target on the parsed :class:`Instr`.
_CUSTOM_CALL_TARGET_RE = re.compile(r'custom_call_target="([^"]*)"')

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*"
    r"([\w\-]+)\((.*)$")
# computation headers end with "{" and contain "->"; parameter lists may
# nest tuples arbitrarily, so only the leading name token is parsed.
_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)")


def _shape_bytes(type_str: str) -> float:
    """Total bytes of all array shapes appearing in an HLO type string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    args: str
    line: str
    custom_call_target: str = ""   # set when op == "custom-call"


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.mem_bytes += other.mem_bytes
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v
        return self

    def scaled(self, t: float) -> "Cost":
        return Cost(self.flops * t, self.mem_bytes * t,
                    {k: v * t for k, v in self.coll_bytes.items()})

    @property
    def total_coll_bytes(self) -> float:
        return sum(_COLL_FACTOR.get(k, 1.0) * v
                   for k, v in self.coll_bytes.items())


def parse_computations(text: str) -> Tuple[Dict[str, List[Instr]], str]:
    """Split HLO text into computations. Returns (comps, entry_name)."""
    comps: Dict[str, List[Instr]] = {}
    entry = None
    cur: Optional[str] = None
    for line in text.splitlines():
        if "/*" in line:
            line = re.sub(r"/\*.*?\*/", "", line)  # strip /*index=N*/ etc.
        if cur is None:
            stripped = line.strip()
            if stripped.endswith("{") and "->" in stripped \
                    and not stripped.startswith("HloModule"):
                m = _COMP_NAME_RE.match(stripped)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
                    if stripped.startswith("ENTRY"):
                        entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            op = m.group(3)
            target = ""
            if op == "custom-call":
                tm = _CUSTOM_CALL_TARGET_RE.search(line)
                target = tm.group(1) if tm else ""
            comps[cur].append(Instr(
                name=m.group(1), type_str=m.group(2).strip(),
                op=op, args=m.group(4), line=line,
                custom_call_target=target))
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def branch_computations(instr: Instr) -> List[str]:
    """All branch computations of a ``conditional`` instruction.

    ``branch_computations={%a, %b, ...}`` is a brace-delimited *list*; a
    prefix-only regex would see just ``%a`` and silently drop every other
    branch (true-branch-only cost analysis, invisible false branches).
    """
    m = re.search(r"branch_computations=\{([^}]*)\}", instr.line)
    if not m:
        return []
    return re.findall(r"%?([\w\.\-]+)", m.group(1))


def called_computations(instr: Instr,
                        include_branches: bool = True) -> List[str]:
    """Computations an instruction calls into: fusion/call bodies
    (``calls=``/``to_apply=``), while ``body=``/``condition=``, and — when
    ``include_branches`` — a conditional's branch computations.  The
    ``include_branches=False`` form is the edge set for gating analyses
    (tools/bamverify BAM503): following only these edges from the entry
    yields the computations that execute *unconditionally*."""
    out = []
    for key in ("calls=", "body=", "condition=", "to_apply="):
        for m in re.finditer(re.escape(key) + r"%?([\w\.\-]+)", instr.line):
            out.append(m.group(1))
    if include_branches:
        out.extend(branch_computations(instr))
    return out


# Back-compat alias (pre-PR-9 internal name).
_called_comps = called_computations


def iter_custom_calls(comps: Dict[str, List[Instr]]
                      ) -> List[Tuple[str, Instr]]:
    """Every ``custom-call`` instruction as ``(computation_name, Instr)``
    — the shared surfacing hook for callback audits (``Instr
    .custom_call_target`` carries the target string)."""
    out = []
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.op == "custom-call":
                out.append((cname, ins))
    return out


def ungated_computations(comps: Dict[str, List[Instr]],
                         entry: Optional[str]) -> set:
    """Computations reachable from ``entry`` without crossing a
    ``conditional`` branch edge — i.e. the code that runs on *every*
    execution of the module.  A host-callback custom-call inside this set
    executes unconditionally; one outside it only runs when some
    ``lax.cond`` takes its branch (the BaM all-hit fast path contract)."""
    seen: set = set()
    stack = [entry] if entry else []
    while stack:
        c = stack.pop()
        if c is None or c in seen or c not in comps:
            continue
        seen.add(c)
        for ins in comps[c]:
            for cc in called_computations(ins, include_branches=False):
                stack.append(cc)
    return seen


def _trip_count(comps, cond_name: str) -> int:
    """Largest integer constant in the loop condition (scan bound)."""
    best = 1
    seen = set()
    stack = [cond_name]
    while stack:
        c = stack.pop()
        if c in seen or c not in comps:
            continue
        seen.add(c)
        for ins in comps[c]:
            if ins.op == "constant":
                m = re.search(r"constant\((-?\d+)\)", ins.line)
                if m:
                    best = max(best, int(m.group(1)))
            for cc in _called_comps(ins):
                stack.append(cc)
    return best


def _dot_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    out_dims = _shape_dims(instr.type_str)
    n_out = 1
    for d in out_dims:
        n_out *= d
    m = re.search(r"lhs_contracting_dims={([0-9,]*)}", instr.line)
    # First operand name.  Some HLO printers type every operand
    # ("dot(f32[64,64]{1,0} %lhs, ...)"), so prefer the first %-prefixed
    # token and only fall back to the leading bare token.
    lhs_name = None
    am = re.search(r"%([\w\.\-]+)", instr.args) \
        or re.match(r"\s*([\w\.\-]+)", instr.args)
    if am:
        lhs_name = am.group(1)
    contract = 1
    if m and lhs_name and lhs_name in shapes:
        lhs_dims = _shape_dims(shapes[lhs_name])
        idxs = [int(i) for i in m.group(1).split(",") if i != ""]
        for i in idxs:
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * n_out * contract


def _operand_names(instr: Instr) -> List[str]:
    # operands appear before any ", key=value" attribute in the args string
    head = instr.args.split("),")[0]
    return [m.group(1) for m in re.finditer(r"%([\w\.\-]+)", head)]


def _instr_mem_bytes(instr: Instr, shapes: Dict[str, str]) -> float:
    """HBM round-trip bytes for one top-level instruction.

    Sliced/scattered accesses touch only the moved window, not the whole
    operand; loop carries are in-place and cost nothing per se.
    """
    out_b = _shape_bytes(instr.type_str)
    ops = _operand_names(instr)
    if instr.op in ("dynamic-slice", "slice", "gather"):
        return 2.0 * out_b                       # read window + write out
    if instr.op in ("dynamic-update-slice", "scatter"):
        # read+write the update window (operand 1), plus index traffic
        upd = shapes.get(ops[1], "") if len(ops) > 1 else ""
        return 3.0 * _shape_bytes(upd) if upd else out_b
    total = out_b
    for nm in ops:
        if nm in shapes:
            total += _shape_bytes(shapes[nm])
    return total


def analyze_text(text: str) -> Cost:
    comps, entry = parse_computations(text)
    shape_tables = {
        cname: {i.name: i.type_str for i in instrs}
        for cname, instrs in comps.items()
    }
    memo: Dict[str, Cost] = {}

    def walk(cname: str, count_mem: bool = True) -> Cost:
        key = (cname, count_mem)
        if key in memo:
            return memo[key]
        memo[key] = Cost()            # cycle guard
        cost = Cost()
        shapes = shape_tables.get(cname, {})
        for ins in comps.get(cname, []):
            if ins.op == "while":
                body = cond = None
                for m in re.finditer(r"(body|condition)=%?([\w\.\-]+)",
                                     ins.line):
                    if m.group(1) == "body":
                        body = m.group(2)
                    else:
                        cond = m.group(2)
                trip = _trip_count(comps, cond) if cond else 1
                if body:
                    cost += walk(body, count_mem).scaled(trip)
            elif ins.op in ("fusion", "call"):
                for cc in _called_comps(ins):
                    # fusions execute as one kernel: internals contribute
                    # FLOPs/collectives but no extra HBM round trips
                    inner = walk(cc, count_mem=False)
                    cost += inner
                if count_mem:
                    cost += Cost(mem_bytes=_instr_mem_bytes(ins, shapes))
            elif ins.op == "conditional":
                branches = _called_comps(ins)
                if branches:
                    worst = max((walk(b, count_mem) for b in branches),
                                key=lambda c: c.flops + c.mem_bytes)
                    cost += worst
            elif ins.op in ("dot", "dot-general", "convolution"):
                cost += Cost(flops=_dot_flops(ins, shapes),
                             mem_bytes=_instr_mem_bytes(ins, shapes)
                             if count_mem else 0.0)
            elif any(ins.op.startswith(c) for c in COLLECTIVES):
                kind = next(c for c in COLLECTIVES if ins.op.startswith(c))
                if ins.op.endswith("-done"):
                    continue
                b = _shape_bytes(ins.type_str)
                cost += Cost(mem_bytes=b if count_mem else 0.0,
                             coll_bytes={kind: b})
            elif ins.op == "custom-call":
                # opaque target: zero roofline cost (XLA cannot cost it
                # either), but surfaced via iter_custom_calls for the
                # callback-placement audits — never silently dropped.
                pass
            elif ins.op in _ZERO_COST_OPS:
                pass
            else:
                if count_mem:
                    cost += Cost(mem_bytes=_instr_mem_bytes(ins, shapes))
        memo[key] = cost
        return cost

    return walk(entry)


def analyze_compiled(compiled) -> Cost:
    return analyze_text(compiled.as_text())
