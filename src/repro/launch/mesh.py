"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.  Production target: TPU v5e-class pods,
16x16 = 256 chips per pod; the multi-pod mesh adds a leading "pod" axis
(2 pods = 512 chips).  Axis roles:

  pod    — data parallelism across pods (slow links; int8-EF-compressed
           gradient reduction lives on this axis)
  data   — data parallelism + ZeRO-3 weight sharding within a pod
  model  — tensor/expert parallelism + BaM KV-page striping
"""
from __future__ import annotations

import math

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_mesh"]


def make_mesh(shape, axes):
    """Mesh over the first prod(shape) available devices (the dry-run
    exposes 512 host devices; the single-pod mesh uses 256 of them)."""
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(f"need {n} devices, have {len(devs)} — the dry-run "
                         "must set XLA_FLAGS device count first")
    arr = np.asarray(devs[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
