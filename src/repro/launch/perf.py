import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing: hypothesis -> change -> re-lower -> re-analyse.

Three cells (worst roofline fraction at scale / most collective-bound /
most representative of the paper's technique), each with named variants.
Results append to results/perf.json; EXPERIMENTS.md §Perf narrates them.

    PYTHONPATH=src python -m repro.launch.perf [cell ...]
"""
import json
import sys
import traceback
from pathlib import Path

from repro.configs.base import get_config
from repro.distributed.sharding import DEFAULT_RULES
from repro.launch.dryrun import run_cell

SERVE_RULES = dict(DEFAULT_RULES)
SERVE_RULES["w_embed"] = None          # no ZeRO weight sharding at serve

EXPERIMENTS = {
    # ------------------------------------------------------------------
    # Cell A: the paper's technique cell — BaM-paged KV long-context decode
    "gemma3_12b|long_500k": [
        ("baseline", {}, None),
        ("serve_sharding", dict(param_dtype="bfloat16"), SERVE_RULES),
        ("serve_sharding+flash_decode",
         dict(param_dtype="bfloat16", flash_decode_shards=True),
         SERVE_RULES),
    ],
    # ------------------------------------------------------------------
    # Cell B: most collective-bound — MoE train step
    "moonshot_v1_16b_a3b|train_4k": [
        ("baseline", {}, None),
        ("local_combine", dict(moe_combine="allgather"), None),
        ("local_combine+cap1.0",
         dict(moe_combine="allgather", capacity_factor=1.0), None),
        ("scatter_combine", dict(moe_combine="scatter"), None),
        ("scatter_combine+cap1.0",
         dict(moe_combine="scatter", capacity_factor=1.0), None),
    ],
    # ------------------------------------------------------------------
    # Cell C: worst roofline fraction among the large models — 32k prefill
    "qwen2_5_14b|prefill_32k": [
        ("baseline", {}, None),
        ("bf16_tiles", dict(attn_f32=False), None),
        ("bf16_tiles+serve_sharding", dict(attn_f32=False), SERVE_RULES),
    ],
    # ------------------------------------------------------------------
    # Bonus: serve-sharding on a batch decode cell (the same fix matters
    # for every decode cell in the table)
    "olmoe_1b_7b|decode_32k": [
        ("baseline", {}, None),
        ("serve_sharding", dict(param_dtype="bfloat16"), SERVE_RULES),
        ("serve_sharding+flash_decode",
         dict(param_dtype="bfloat16", flash_decode_shards=True),
         SERVE_RULES),
    ],
}


def main():
    only = sys.argv[1:]
    out_path = Path("results/perf.json")
    results = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())
    for cell, variants in EXPERIMENTS.items():
        if only and cell not in only:
            continue
        arch, shape = cell.split("|")
        for name, cfg_kw, rules in variants:
            key = f"{cell}|{name}"
            if key in results and "error" not in results[key]:
                print(f"[cached] {key}")
                continue
            print(f"[perf] {key} ...", flush=True)
            try:
                cfg = get_config(arch).replace(use_pallas="ref", **cfg_kw)
                r = run_cell(arch, shape, multi_pod=False, rules=rules,
                             cfg_override=cfg)
                r["variant"] = name
            except Exception as e:
                r = {"variant": name, "error": f"{type(e).__name__}: {e}",
                     "traceback": traceback.format_exc()[-1500:]}
            results[key] = r
            out_path.write_text(json.dumps(results, indent=1))
            if "error" in r:
                print(f"  ERROR {r['error'][:120]}")
            else:
                rf = r["roofline"]
                print(f"  comp={rf['compute_s']:.4f}s mem={rf['memory_s']:.4f}s "
                      f"coll={rf['collective_s']:.4f}s bound={rf['bound']} "
                      f"mem/dev={r['memory']['per_device_total']/2**30:.1f}GiB")


if __name__ == "__main__":
    main()
