"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun.json.

    PYTHONPATH=src python -m repro.launch.report results/dryrun.json
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from repro.configs.base import SHAPES, list_archs


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(results: dict, mesh: str) -> str:
    lines = [
        "| arch | shape | mem/dev GiB | HLO GFLOPs/dev | coll MB/dev | "
        "compile s |",
        "|---|---|---:|---:|---:|---:|",
    ]
    for arch in list_archs():
        for shape in SHAPES:
            key = f"{arch}|{shape}|{mesh}"
            r = results.get(key)
            if r is None:
                lines.append(f"| {arch} | {shape} | — | — | — | (missing) |")
            elif "skipped" in r:
                lines.append(f"| {arch} | {shape} | skip | skip | skip | "
                             "long_500k needs sub-quadratic |")
            elif "error" in r:
                lines.append(f"| {arch} | {shape} | ERR | | | "
                             f"{r['error'][:40]} |")
            else:
                m = r["memory"]["per_device_total"]
                h = r["hlo"]
                lines.append(
                    f"| {arch} | {shape} | {fmt_bytes(m)} | "
                    f"{h['flops']/1e9:.1f} | "
                    f"{h['coll_bytes_effective']/1e6:.1f} | "
                    f"{r['timings']['compile_s']:.0f} |")
    return "\n".join(lines)


def roofline_table(results: dict) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | bound | "
        "MODEL_FLOPS | useful | roofline frac |",
        "|---|---|---:|---:|---:|---|---:|---:|---:|",
    ]
    for arch in list_archs():
        for shape in SHAPES:
            r = results.get(f"{arch}|{shape}|single")
            if not r or "roofline" not in r:
                continue
            rf = r["roofline"]
            lines.append(
                f"| {arch} | {shape} | {rf['compute_s']:.4f} | "
                f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
                f"**{rf['bound']}** | {rf['model_flops']:.3e} | "
                f"{rf['useful_flops_fraction']:.2f} | "
                f"{rf['roofline_fraction']:.4f} |")
    return "\n".join(lines)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"
    results = json.loads(Path(path).read_text())
    print("## Dry-run — single-pod mesh (16x16 = 256 chips)\n")
    print(dryrun_table(results, "single"))
    print("\n## Dry-run — multi-pod mesh (2x16x16 = 512 chips)\n")
    print(dryrun_table(results, "multi"))
    print("\n## Roofline (single-pod, per device)\n")
    print(roofline_table(results))


if __name__ == "__main__":
    main()
