"""Roofline terms from compiled dry-run artifacts (TPU v5e-class target).

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

FLOPs/bytes come from the trip-count-aware HLO walk
(:mod:`repro.launch.hlo_analysis`) — ``cost_analysis()`` alone visits scan
bodies once.  All quantities are **per device** already (post-SPMD HLO), so
the terms divide by per-chip peaks, not by chip count.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (per-chip effective)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    mem_bytes_per_device: float
    coll_bytes_per_device: float
    model_flops: float = 0.0       # 6*N*D (or 6*N_active*D) global
    chips: int = 1

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.mem_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Perfect-overlap model: the dominant term is the step time."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global): remat/redundancy waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total > 0 else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the modelled step
        time: (MODEL_FLOPS / step_s) / (chips x peak)."""
        if self.step_s <= 0:
            return 0.0
        ach = self.model_flops / self.step_s
        return ach / (self.chips * PEAK_FLOPS)

    def to_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops_per_device,
            "mem_bytes_per_device": self.mem_bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "model_flops": self.model_flops,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bound": self.bound,
            "step_s": self.step_s,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for_cell(cfg, cell) -> float:
    """MODEL_FLOPS per step: 6*N_active*tokens (train), 2*N_active*tokens
    (prefill), 2*N_active*batch (decode) + attention read terms."""
    from repro.models.model import active_params
    n = active_params(cfg)
    tokens = cell.global_batch * (cell.seq_len
                                  if cell.kind in ("train", "prefill") else 1)
    mult = 6.0 if cell.kind == "train" else 2.0
    base = mult * n * tokens
    # attention score+value FLOPs
    attn = 0.0
    if cell.kind in ("train", "prefill"):
        for w in cfg.layer_windows(cell.seq_len):
            s_eff = min(w, cell.seq_len)
            # average causal context ~ s_eff/2 (window: ~w)
            ctx = s_eff / 2 if w >= cell.seq_len else s_eff
            attn += 2 * 2 * ctx * cfg.n_heads * cfg.hd * tokens
        if cell.kind == "train":
            attn *= 3  # fwd + 2x bwd
    else:
        for w in cfg.layer_windows(cell.seq_len):
            ctx = min(w, cell.seq_len)
            attn += 2 * 2 * ctx * cfg.n_heads * cfg.hd * cell.global_batch
    if cfg.family == "ssm":
        attn = 0.0  # recurrent state term is part of N_active math
    return base + attn
