"""Cluster serving launcher: ``--arch <id>`` with the BaM-paged KV engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, smoke_config
from repro.models.model import build_model, count_params
from repro.serving import PagedKVManager, ServeEngine
from repro.serving.engine import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--hot-window", type=int, default=48)
    args = ap.parse_args()

    cfg = (smoke_config(args.arch) if args.smoke
           else get_config(args.arch)).replace(dtype="float32")
    api = build_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0), args.max_seq)
    print(f"[serve] {cfg.name}: {count_params(params)/1e6:.1f}M params")

    kv = PagedKVManager(keep_last=args.hot_window)
    eng = ServeEngine(cfg, params, batch_slots=args.slots,
                      max_seq=args.max_seq, kv_manager=kv)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(2, cfg.vocab, 12).tolist(),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in reqs)
    m = kv.metrics.summary()
    print(f"[serve] {toks} tokens in {dt:.1f}s; paged-KV spilled "
          f"{m['write_ops']:.0f} / fetched {m['misses']:.0f} pages")


if __name__ == "__main__":
    main()
