"""Cluster training launcher: ``--arch <id>`` on the production mesh.

On real TPU pods this runs under ``jax.distributed.initialize()`` (one
process per host; the mesh spans all chips).  On this container it runs
the same code on however many devices exist — the dry-run proves the
production mesh compiles.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
        --seq 128 --batch 8 --steps 50 --workdir /tmp/run1
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, smoke_config
from repro.data import DataConfig, Loader, TokenStore, synth_corpus
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models.model import build_model, count_params
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training.fault_tolerance import run_training
from repro.training.train_loop import (batch_shardings, make_train_step,
                                       state_shardings)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--workdir", default="/tmp/bam_launch")
    ap.add_argument("--mesh", default=None,
                    help="e.g. '2x4' -> (data=2, model=4)")
    ap.add_argument("--pod-compression", action="store_true")
    args = ap.parse_args()

    cfg = (smoke_config(args.arch) if args.smoke
           else get_config(args.arch)).replace(dtype="float32")
    api = build_model(cfg)

    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))

    wd = Path(args.workdir)
    corpus = wd / "corpus.bin"
    if not corpus.exists():
        synth_corpus(corpus, n_tokens=1_000_000, vocab=cfg.vocab)
    loader = Loader(TokenStore.open(corpus),
                    DataConfig(seq_len=args.seq, global_batch=args.batch))
    acfg = opt.AdamWConfig(lr=args.lr, warmup=10, total_steps=args.steps,
                           pod_compression=args.pod_compression)

    def batch_for_step(s):
        return {"tokens": jnp.asarray(loader.batch_for_step(s)["tokens"])}

    with shd.activate(mesh, None):
        params, axes = api.init(jax.random.PRNGKey(0), args.seq)
        print(f"[train] {cfg.name}: {count_params(params)/1e6:.1f}M params "
              f"on {jax.device_count()} device(s)")
        state0 = {"params": params, "opt": opt.adamw_init(params, acfg)}
        step = make_train_step(cfg, api, adamw=acfg,
                               microbatches=args.microbatches, mesh=mesh)
        shardings = None
        if mesh is not None:
            st_sh = state_shardings(cfg, axes, mesh, params, acfg)
            state0 = jax.device_put(state0, st_sh)
            # launch-time setup: one wrapper per training run
            step = jax.jit(step, in_shardings=(st_sh, None),  # bamlint: ignore[BAM105]
                           out_shardings=(st_sh, None),
                           donate_argnums=(0,))
            shardings = st_sh
        else:
            step = jax.jit(step, donate_argnums=(0,))  # bamlint: ignore[BAM105]

        t0 = time.time()

        def on_metrics(s, m):
            if s % 10 == 0:
                print(f"step {s:5d} loss {m['loss']:.4f} "
                      f"({s*args.batch*args.seq/(time.time()-t0):,.0f} "
                      "tok/s)")

        res = run_training(step, lambda: state0, batch_for_step, args.steps,
                           ckpt_dir=wd / "ckpt", ckpt_every=25,
                           shardings=shardings, on_metrics=on_metrics)
        print(f"[train] finished at step {res.step}, "
              f"final loss {res.metrics_history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
