from repro.models.model import (build_model, count_params, init_model,
                                model_flops_per_token)

__all__ = ["build_model", "count_params", "init_model",
           "model_flops_per_token"]
