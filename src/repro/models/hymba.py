"""Hymba — hybrid-head architecture: parallel attention + Mamba (SSM) heads.

Every layer runs a sliding-window GQA attention path and a selective-SSM
(Mamba) path *in parallel* on the same input; their normalised outputs are
averaged (the paper's fusion), followed by a standard MLP.  A small set of
layers ({first, middle, last}) uses global attention.  128 learnable *meta
tokens* are prepended to the sequence (and never scored in the loss).

The SSM path uses a chunked associative scan: chunk boundaries carry the
(d_inner, ssm_state) state, intra-chunk runs vectorised — same
latency-hiding shape as the BaM pipeline (state = the in-flight window).

Uniform layers -> scan-over-layers with a scanned per-layer window array.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.utils import Tagged
from repro.models.transformer import BIG_WINDOW

CONV_K = 4  # causal conv width in the mamba path


# ------------------------------------------------------------- SSM (S6) ----
def init_mamba(cfg: ArchConfig, key, dtype=jnp.float32):
    D = cfg.d_model
    d_inner = 2 * D
    N = cfg.ssm_state
    ks = jax.random.split(key, 6)
    p = {
        "w_in": L._normal(ks[0], (D, 2 * d_inner), 1 / math.sqrt(D), dtype),
        "conv": L._normal(ks[1], (CONV_K, d_inner), 0.5, dtype),
        "w_bc": L._normal(ks[2], (d_inner, 2 * N), 1 / math.sqrt(d_inner),
                          dtype),
        "w_dt": L._normal(ks[3], (d_inner, d_inner), 0.01, dtype),
        "dt_bias": jnp.zeros((d_inner,), dtype) - 4.0,  # softplus ~ 0.018
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (d_inner, N))
        ).astype(dtype),
        "d_skip": jnp.ones((d_inner,), dtype),
        "w_out": L._normal(ks[4], (d_inner, D), 1 / math.sqrt(d_inner),
                           dtype),
    }
    a = {
        "w_in": ("w_embed", "w_inner"), "conv": ("conv", "w_inner"),
        "w_bc": ("w_inner", None), "w_dt": ("w_inner", None),
        "dt_bias": ("w_inner",), "a_log": ("w_inner", None),
        "d_skip": ("w_inner",), "w_out": ("w_inner", "w_embed"),
    }
    return p, a


def _ssm_scan_chunked(a, b, state, chunk):
    """h_t = a_t * h_{t-1} + b_t, scanned over axis 1 (time).

    a, b: (B, S, d_inner, N) f32; state: (B, d_inner, N).
    Returns (h (B,S,d_inner,N), final state).
    """
    B, S, DI, N = a.shape
    Lc = min(chunk, S)
    pad = (-S) % Lc
    if pad:                         # identity steps: a=1, b=0 keep state
        a = jnp.concatenate(
            [a, jnp.ones((B, pad, DI, N), a.dtype)], axis=1)
        b = jnp.concatenate(
            [b, jnp.zeros((B, pad, DI, N), b.dtype)], axis=1)
    NC = a.shape[1] // Lc
    ac = a.reshape(B, NC, Lc, DI, N).transpose(1, 0, 2, 3, 4)
    bc = b.reshape(B, NC, Lc, DI, N).transpose(1, 0, 2, 3, 4)

    def chunk_fn(h0, xs):
        ach, bch = xs                                 # (B, Lc, DI, N)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, br + ar * bl

        hs = jax.lax.associative_scan(combine, (ach, bch), axis=1)
        a_all, b_all = hs
        h = b_all + a_all * h0[:, None]               # fold in carry
        return h[:, -1], h

    chunk_fn = jax.checkpoint(chunk_fn, prevent_cse=False)
    state, hs = jax.lax.scan(chunk_fn, state, (ac, bc))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, NC * Lc, DI, N)[:, :S]
    return h, state


def mamba_apply(cfg: ArchConfig, p, x, *, conv_state=None, ssm_state=None,
                chunk=256):
    """x: (B, S, D) -> (B, S, D). If states given, S must be 1 (decode)."""
    dtype = cfg.compute_dtype
    B, S, D = x.shape
    N = cfg.ssm_state
    up = x.astype(dtype) @ p["w_in"].astype(dtype)
    d_inner = up.shape[-1] // 2
    xm, z = up[..., :d_inner], up[..., d_inner:]

    # causal conv1d
    w = p["conv"].astype(dtype)                       # (K, d_inner)
    if conv_state is None:
        pad = jnp.zeros((B, CONV_K - 1, d_inner), dtype)
        xp = jnp.concatenate([pad, xm], axis=1)
    else:
        xp = jnp.concatenate([conv_state.astype(dtype), xm], axis=1)
    new_conv_state = xp[:, -(CONV_K - 1):]
    xc = sum(xp[:, i:i + S] * w[i] for i in range(CONV_K))
    xc = jax.nn.silu(xc)

    # selective params
    bc = (xc @ p["w_bc"].astype(dtype)).astype(jnp.float32)
    Bp, Cp = bc[..., :N], bc[..., N:]                 # (B,S,N)
    dt = jax.nn.softplus(
        (xc @ p["w_dt"].astype(dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))           # (B,S,d_inner)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))      # (d_inner,N)

    da = jnp.exp(dt[..., None] * A)                   # (B,S,d_inner,N)
    db = (dt * xc.astype(jnp.float32))[..., None] * Bp[..., None, :]

    if ssm_state is None:
        state0 = jnp.zeros((B, d_inner, N), jnp.float32)
    else:
        state0 = ssm_state
    if S == 1:
        h = da[:, 0] * state0 + db[:, 0]              # (B,d_inner,N)
        new_state = h
        y = jnp.einsum("bdn,bn->bd", h, Cp[:, 0].astype(jnp.float32))
        y = y[:, None]
    else:
        hs, new_state = _ssm_scan_chunked(da, db, state0, chunk)
        hs = hs[:, :S]
        y = jnp.einsum("bsdn,bsn->bsd", hs, Cp.astype(jnp.float32))
    y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y.astype(dtype) * jax.nn.silu(z)
    out = y @ p["w_out"].astype(dtype)
    return out, (new_conv_state, new_state)


# ------------------------------------------------------------------ block --
def init_block(cfg: ArchConfig, key, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["ln1"], a["ln1"] = L.init_norm(cfg, cfg.d_model, dtype)
    p["attn"], a["attn"] = L.init_attention(cfg, ks[0], dtype)
    p["mamba"], a["mamba"] = init_mamba(cfg, ks[1], dtype)
    p["n_attn"] = jnp.ones((cfg.d_model,), dtype)
    p["n_ssm"] = jnp.ones((cfg.d_model,), dtype)
    a["n_attn"] = ("act_embed",)
    a["n_ssm"] = ("act_embed",)
    p["ln2"], a["ln2"] = L.init_norm(cfg, cfg.d_model, dtype)
    p["mlp"], a["mlp"] = L.init_mlp(cfg, ks[2], dtype)
    return p, a


def block_apply(cfg: ArchConfig, p, x, *, window, positions, impl="auto"):
    xn = L.norm_apply(cfg, p["ln1"], x)
    h_attn = L.attention(cfg, p["attn"], xn, window=window,
                         positions=positions, impl=impl)
    h_ssm, _ = mamba_apply(cfg, p["mamba"], xn)
    fused = 0.5 * (L.rms_norm_simple(h_attn, p["n_attn"])
                   + L.rms_norm_simple(h_ssm, p["n_ssm"]))
    x = x + fused
    x = x + L.mlp(cfg, p["mlp"], L.norm_apply(cfg, p["ln2"], x))
    return x


# --------------------------------------------------------------------- LM --
def _global_layers(cfg: ArchConfig):
    return {0, cfg.n_layers // 2, cfg.n_layers - 1}


def layer_window_array(cfg: ArchConfig, seq_len: int) -> jax.Array:
    g = _global_layers(cfg)
    w = cfg.window or 1024
    return jnp.asarray(
        [BIG_WINDOW if i in g else w for i in range(cfg.n_layers)],
        jnp.int32)


def init_lm(cfg: ArchConfig, key, max_seq: int = 0):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["embed"], a["embed"] = L.init_embedding(cfg, ks[0], dtype)
    p["meta"] = L._normal(ks[1], (cfg.n_meta_tokens, cfg.d_model), 0.02,
                          dtype)
    a["meta"] = (None, "w_embed")
    _, ba = init_block(cfg, ks[2], dtype)
    p["blocks"] = jax.vmap(lambda k: init_block(cfg, k, dtype)[0])(
        jax.random.split(ks[3], cfg.n_layers))
    a["blocks"] = jax.tree_util.tree_map(
        lambda ax: (None,) + ax, ba, is_leaf=lambda x: isinstance(x, tuple))
    p["ln_f"], a["ln_f"] = L.init_norm(cfg, cfg.d_model, dtype)
    p["head"], a["head"] = L.init_dense(ks[4], cfg.d_model, cfg.vocab,
                                        ("w_embed", "vocab"), dtype=dtype)
    return p, a


def forward(cfg: ArchConfig, params, batch, impl: str = "auto",
            last_only: bool = False, return_hidden: bool = False):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(cfg, params["embed"], tokens)
    M = cfg.n_meta_tokens
    meta = jnp.broadcast_to(params["meta"][None].astype(cfg.compute_dtype),
                            (B, M, cfg.d_model))
    x = jnp.concatenate([meta, x], axis=1)
    x = constrain(x, ("batch", "seq", "act_embed"))
    positions = jnp.arange(S + M)
    windows = layer_window_array(cfg, S + M)

    def body(xc, layer):
        bp, w = layer
        xc = block_apply(cfg, bp, xc, window=w, positions=positions,
                         impl=impl)
        return constrain(xc, ("batch", "seq", "act_embed")), None

    if cfg.remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, (params["blocks"], windows))
    x = L.norm_apply(cfg, params["ln_f"], x)
    x = x[:, M:]
    if last_only:
        x = x[:, -1:, :]
    if return_hidden:
        return x, {}
    logits = L.logits_head(cfg, params.get("head"), params["embed"], x)
    return logits, {}


def loss_fn(cfg: ArchConfig, params, batch, impl: str = "auto"):
    hidden, _ = forward(cfg, params, batch, impl, return_hidden=True)
    tokens = batch["tokens"]
    B, S = tokens.shape
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1)
    mask = jnp.concatenate([jnp.ones((B, S - 1)), jnp.zeros((B, 1))], axis=1)
    loss = L.lm_loss_from_hidden(cfg, params.get("head"), params["embed"],
                                 hidden, labels, mask)
    return loss, {"nll": loss}


# ---------------------------------------------------------------- decode ---
def init_decode_cache(cfg: ArchConfig, B: int, max_seq: int):
    from repro.models.transformer import (_PAGED_AXES, _RING_AXES,
                                          _paged_spec, _ring_spec)
    d_inner = 2 * cfg.d_model
    g = _global_layers(cfg)
    w = cfg.window or 1024
    layers, axes = [], []
    total = max_seq + cfg.n_meta_tokens
    for i in range(cfg.n_layers):
        if i in g:
            attn = Tagged("paged", _paged_spec(cfg, B, total))
            attn_axes = Tagged("paged", _PAGED_AXES)
        else:
            attn = Tagged("ring", _ring_spec(cfg, B, w))
            attn_axes = Tagged("ring", _RING_AXES)
        ssm = {
            "conv": jnp.zeros((B, CONV_K - 1, d_inner), cfg.compute_dtype),
            "state": jnp.zeros((B, d_inner, cfg.ssm_state), jnp.float32),
        }
        ssm_axes = {"conv": ("batch", None, "w_inner"),
                    "state": ("batch", "w_inner", None)}
        layers.append((attn, ssm))
        axes.append((attn_axes, ssm_axes))
    cache = {"seq_lens": jnp.zeros((B,), jnp.int32), "layers": tuple(layers)}
    cache_axes = {"seq_lens": ("batch",), "layers": tuple(axes)}
    return cache, cache_axes


def decode_embed_step(cfg: ArchConfig, params, cache, x,
                      impl: str = "auto"):
    """One decode step on an already-embedded input x: (B, 1, D)."""
    from repro.models.transformer import (_decode_attn_paged,
                                          _decode_attn_ring)
    B = x.shape[0]
    pos = cache["seq_lens"]
    new_layers = []
    for i, (tagged, ssm) in enumerate(cache["layers"]):
        kind, entry = tagged.kind, tagged.value
        bp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
        xn = L.norm_apply(cfg, bp["ln1"], x)
        if kind == "ring":
            h_attn, entry2 = _decode_attn_ring(cfg, bp["attn"], xn, entry,
                                               pos, impl)
        else:
            h_attn, entry2 = _decode_attn_paged(cfg, bp["attn"], xn, entry,
                                                pos, impl)
        h_ssm, (conv2, state2) = mamba_apply(
            cfg, bp["mamba"], xn, conv_state=ssm["conv"],
            ssm_state=ssm["state"])
        fused = 0.5 * (L.rms_norm_simple(h_attn, bp["n_attn"])
                       + L.rms_norm_simple(h_ssm, bp["n_ssm"]))
        x = x + fused
        x = x + L.mlp(cfg, bp["mlp"], L.norm_apply(cfg, bp["ln2"], x))
        new_layers.append((Tagged(kind, entry2),
                           {"conv": conv2, "state": state2}))
    cache2 = dict(cache)
    cache2["layers"] = tuple(new_layers)
    cache2["seq_lens"] = pos + 1
    return x, cache2


def prime_cache(cfg: ArchConfig, params, cache, impl: str = "auto"):
    """Run the learnable meta tokens through the cache before any prompt
    token (hymba's signature feature: the meta tokens are position 0..M-1
    of every sequence)."""
    B = cache["seq_lens"].shape[0]
    for m in range(cfg.n_meta_tokens):
        x = jnp.broadcast_to(
            params["meta"][m][None, None].astype(cfg.compute_dtype),
            (B, 1, cfg.d_model))
        _, cache = decode_embed_step(cfg, params, cache, x, impl)
    return cache


def decode_step(cfg: ArchConfig, params, cache, tokens, impl: str = "auto"):
    x = L.embed(cfg, params["embed"], tokens[:, None])
    x, cache2 = decode_embed_step(cfg, params, cache, x, impl)
    x = L.norm_apply(cfg, params["ln_f"], x)
    logits = L.logits_head(cfg, params.get("head"), params["embed"], x)
    return logits[:, 0, :], cache2
