"""Composable layer library: norms, RoPE, GQA attention, MLP, MoE.

Conventions:
  * params are nested dicts of arrays; every ``init_*`` returns
    ``(params, axes)`` where ``axes`` mirrors the structure with tuples of
    *logical* axis names per dimension (resolved to mesh axes by
    :mod:`repro.distributed.sharding`).
  * ``apply`` functions are pure; compute dtype comes from the config, and
    parameters are cast at use (fp32 master weights, bf16 compute).
  * attention goes through :mod:`repro.kernels.ops` so the same model code
    hits the Pallas kernels on TPU and honest XLA reference HLO on CPU.
"""
from __future__ import annotations

import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.kernels import ops
from repro.utils import segment_rank

Params = Any
Axes = Any


# ------------------------------------------------------------------ init ---
def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_dense(key, in_dim: int, out_dim: int, axes: Tuple, *,
               bias: bool = False, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    p = {"w": _normal(key, (in_dim, out_dim), scale, dtype)}
    a = {"w": axes}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
        a["b"] = (axes[-1],)
    return p, a


def dense(p, x, dtype):
    y = x.astype(dtype) @ p["w"].astype(dtype)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


# ----------------------------------------------------------------- norms ---
def init_norm(cfg: ArchConfig, dim: int, dtype=jnp.float32):
    if cfg.norm == "layer":
        return ({"scale": jnp.ones((dim,), dtype),
                 "bias": jnp.zeros((dim,), dtype)},
                {"scale": ("act_embed",), "bias": ("act_embed",)})
    return ({"scale": jnp.ones((dim,), dtype)}, {"scale": ("act_embed",)})


def norm_apply(cfg: ArchConfig, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layer":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_simple(x, scale):
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + 1e-6) * scale.astype(jnp.float32)
            ).astype(x.dtype)


# ------------------------------------------------------------------ rope ---
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, D) with D even; positions: (S,) or broadcastable."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return y.astype(x.dtype)


# ------------------------------------------------------------- attention ---
def init_attention(cfg: ArchConfig, key, dtype=jnp.float32):
    hd = cfg.hd
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["wq"], a["wq"] = init_dense(ks[0], cfg.d_model, cfg.n_heads * hd,
                                  ("w_embed", "heads"), bias=cfg.qkv_bias,
                                  dtype=dtype)
    p["wk"], a["wk"] = init_dense(ks[1], cfg.d_model, cfg.n_kv_heads * hd,
                                  ("w_embed", "kv_heads"), bias=cfg.qkv_bias,
                                  dtype=dtype)
    p["wv"], a["wv"] = init_dense(ks[2], cfg.d_model, cfg.n_kv_heads * hd,
                                  ("w_embed", "kv_heads"), bias=cfg.qkv_bias,
                                  dtype=dtype)
    p["wo"], a["wo"] = init_dense(ks[3], cfg.n_heads * hd, cfg.d_model,
                                  ("heads", "w_embed"), dtype=dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
        a["q_norm"] = ("head_dim",)
        a["k_norm"] = ("head_dim",)
    return p, a


def attention_qkv(cfg: ArchConfig, p, x, positions, dtype):
    """Project to (B, H, S, hd) q and (B, Hkv, S, hd) k, v with RoPE."""
    B, S, _ = x.shape
    hd = cfg.hd
    q = dense(p["wq"], x, dtype).reshape(B, S, cfg.n_heads, hd)
    k = dense(p["wk"], x, dtype).reshape(B, S, cfg.n_kv_heads, hd)
    v = dense(p["wv"], x, dtype).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm_simple(q, p["q_norm"])
        k = rms_norm_simple(k, p["k_norm"])
    if cfg.pos_emb == "rope":
        q = rope(q.transpose(0, 2, 1, 3), positions, cfg.rope_theta)
        k = rope(k.transpose(0, 2, 1, 3), positions, cfg.rope_theta)
    else:
        q = q.transpose(0, 2, 1, 3)
        k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    q = constrain(q, ("batch", "act_heads", "seq", "head_dim"))
    k = constrain(k, ("batch", "act_kv_heads", "seq", "head_dim"))
    v = constrain(v, ("batch", "act_kv_heads", "seq", "head_dim"))
    return q, k, v


def attention(cfg: ArchConfig, p, x, *, window=None, positions=None,
              causal: bool = True, impl: str = "auto",
              kv_override=None) -> jax.Array:
    """Full-sequence attention (train/prefill). x: (B, S, D)."""
    B, S, _ = x.shape
    dtype = cfg.compute_dtype
    if positions is None:
        positions = jnp.arange(S)
    q, k, v = attention_qkv(cfg, p, x, positions, dtype)
    if kv_override is not None:          # cross-attention (enc-dec)
        k, v = kv_override
    o = ops.flash_attention(q, k, v, causal=causal, window=window,
                            tile_f32=cfg.attn_f32,
                            impl=impl if impl else cfg.use_pallas)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.hd)
    o = constrain(o, ("batch", "seq", None))
    return dense(p["wo"], o, dtype)


# ------------------------------------------------------------------- mlp ---
def init_mlp(cfg: ArchConfig, key, dtype=jnp.float32, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["w1"], a["w1"] = init_dense(ks[0], cfg.d_model, d_ff,
                                  ("w_embed", "ffn"), dtype=dtype)
    p["w2"], a["w2"] = init_dense(ks[1], d_ff, cfg.d_model,
                                  ("ffn", "w_embed"), dtype=dtype)
    if cfg.gated_mlp:
        p["w3"], a["w3"] = init_dense(ks[2], cfg.d_model, d_ff,
                                      ("w_embed", "ffn"), dtype=dtype)
    return p, a


def _act(cfg: ArchConfig, x):
    return jax.nn.gelu(x) if cfg.act == "gelu" else jax.nn.silu(x)


def mlp(cfg: ArchConfig, p, x):
    dtype = cfg.compute_dtype
    h = dense(p["w1"], x, dtype)
    if cfg.gated_mlp:
        h = _act(cfg, h) * dense(p["w3"], x, dtype)
    else:
        h = _act(cfg, h)
    h = constrain(h, ("batch", "seq", "act_ffn"))
    return dense(p["w2"], h, dtype)


# ------------------------------------------------------------------- moe ---
def init_moe(cfg: ArchConfig, key, dtype=jnp.float32):
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    s1, s2 = 1.0 / math.sqrt(D), 1.0 / math.sqrt(F)
    p = {
        "router": _normal(ks[0], (D, E), s1, dtype),
        "w1": _normal(ks[1], (E, D, F), s1, dtype),
        "w2": _normal(ks[2], (E, F, D), s2, dtype),
        "w3": _normal(ks[3], (E, D, F), s1, dtype),
    }
    a = {
        "router": ("w_embed", None),
        "w1": ("experts", "w_embed", None),
        "w2": ("experts", None, "w_embed"),
        "w3": ("experts", "w_embed", None),
    }
    return p, a


def moe_ffn(cfg: ArchConfig, p, x, *, capacity_factor=None):
    """Group-local scatter-based top-k MoE (EP-shardable).

    Dispatch is computed **per sequence** (the dispatch group): capacity,
    expert-queue ranking (the same prefix-sum ranking the BaM cache uses
    for its clock sets) and the scatter all stay inside the batch shard, so
    SPMD keeps everything batch-sharded over ``data`` — no global sort, no
    replicated (T*K, D) gathers.  The expert einsums shard over ``model``
    (EP); XLA inserts the dispatch all-to-all/all-gather between the two.
    x: (B, S, D) -> (B, S, D), plus aux losses dict.
    """
    B, S, D = x.shape
    dtype = cfg.compute_dtype
    E, K = cfg.n_experts, cfg.top_k
    cf = capacity_factor or cfg.capacity_factor
    C = max(8, int(math.ceil(S * K * cf / E / 8.0)) * 8)  # per-seq capacity

    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)              # (B, S, E)
    topv, topi = jax.lax.top_k(probs, K)                 # (B, S, K)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    fe = topi.reshape(B, S * K).astype(jnp.int32)        # (B, S*K)
    rank = jax.vmap(lambda f: segment_rank(f, jnp.ones_like(f, bool)))(fe)
    keep = rank < C
    dest = jnp.where(keep, fe * C + rank, E * C)         # (B, S*K)
    tok = jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)  # (S*K,)

    xg = jnp.take(x.astype(dtype), tok, axis=1)          # (B, S*K, D)

    def scatter_one(dest_b, xg_b):
        return jnp.zeros((E * C + 1, D), dtype).at[dest_b].set(
            xg_b, mode="drop")

    buf = jax.vmap(scatter_one)(dest, xg)                # (B, E*C+1, D)
    h = buf[:, :E * C, :].reshape(B, E, C, D)
    h = constrain(h, ("batch", "experts", None, None))
    up = jnp.einsum("becd,edf->becf", h, p["w1"].astype(dtype))
    gate = jnp.einsum("becd,edf->becf", h, p["w3"].astype(dtype))
    hh = _act(cfg, up) * gate
    y = jnp.einsum("becf,efd->becd", hh, p["w2"].astype(dtype))
    y = constrain(y, ("batch", "experts", None, None))
    y = jnp.concatenate([y.reshape(B, E * C, D),
                         jnp.zeros((B, 1, D), dtype)], axis=1)
    w = (topv.reshape(B, S * K, 1).astype(dtype) *
         keep.astype(dtype)[..., None])
    if cfg.moe_combine == "scatter":
        # Scatter-add combine: per-slot weight and destination token are
        # scattered with *batch-local* indices; the expert-sharded y then
        # scatter-adds into a partial (B, S, D) that XLA reduces over
        # `model` — an O(B*S*D) psum per layer instead of replicating or
        # all-gathering the O(B*E*C*D) expert buffer.
        tok = jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)     # (S*K,)
        w_slot = jax.vmap(lambda d, ww: jnp.zeros(
            (E * C + 1,), dtype).at[d].set(ww, mode="drop"))(
                dest, w[..., 0])                                # (B,E*C+1)
        tok_slot = jax.vmap(lambda d: jnp.full(
            (E * C + 1,), S, jnp.int32).at[d].set(tok, mode="drop"))(
                dest)                                           # (B,E*C+1)
        contrib = y * w_slot[..., None]
        out = jax.vmap(lambda t, c: jnp.zeros((S + 1, D), dtype)
                       .at[t].add(c, mode="drop"))(tok_slot, contrib)
        out = out[:, :S, :]
    else:
        if cfg.moe_combine == "allgather":
            # one explicit all-gather of expert outputs over `model`, so
            # the combine gather stays batch-local
            y = constrain(y, ("batch", None, None))
        out_slots = jnp.take_along_axis(y, dest[..., None], axis=1)
        out = (out_slots * w).reshape(B, S, K, D).sum(axis=2)

    # aux: load-balance (Switch) + router z-loss
    me = probs.mean(axis=(0, 1))                         # (E,)
    ce = jax.vmap(lambda f, kp: jnp.zeros((E,)).at[f].add(
        kp.astype(jnp.float32)))(fe, keep).mean(0) / max(S * K, 1)
    lb = E * jnp.sum(me * ce)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return out, {"load_balance": lb, "router_z": z}


# ----------------------------------------------------------- lm utilities --
def init_embedding(cfg: ArchConfig, key, dtype=jnp.float32):
    p = {"table": _normal(key, (cfg.vocab, cfg.d_model), 1.0, dtype)}
    a = {"table": ("vocab", "w_embed")}
    return p, a


def embed(cfg: ArchConfig, p, tokens):
    e = jnp.take(p["table"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.name.startswith("gemma"):
        e = e * math.sqrt(cfg.d_model)
    return e


def logits_head(cfg: ArchConfig, head_p, embed_p, x):
    dtype = cfg.compute_dtype
    if cfg.tie_embeddings:
        w = embed_p["table"].astype(dtype)
        out = x @ w.T
    else:
        out = x @ head_p["w"].astype(dtype)
    out = constrain(out, ("batch", "seq", "vocab"))
    if cfg.logit_softcap:
        out = jnp.tanh(out / cfg.logit_softcap) * cfg.logit_softcap
    return out


def cross_entropy(logits, labels, mask=None):
    """Mean next-token NLL; logits (B,S,V), labels (B,S) int32, mask (B,S)."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def lm_loss_from_hidden(cfg, head_p, embed_p, x, labels, mask, *,
                        chunk: int = 512):
    """Chunked LM cross-entropy: never materialises (B, S, V).

    The (B, chunk, V) logits slab is transient per scan step (and sharded
    over batch x vocab), which keeps the 262k-vocab archs inside HBM —
    standard production-LM memory optimisation.
    """
    B, S, D = x.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    NC = x.shape[1] // c
    xc = x.reshape(B, NC, c, D).swapaxes(0, 1)
    lc = labels.reshape(B, NC, c).swapaxes(0, 1)
    mc = mask.astype(jnp.float32).reshape(B, NC, c).swapaxes(0, 1)

    def body(acc, xs):
        xch, lch, mch = xs
        logits = logits_head(cfg, head_p, embed_p, xch).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lch[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * mch
        return (acc[0] + nll.sum(), acc[1] + mch.sum()), None

    # remat: the (B, chunk, V) logits slab is recomputed in backward, never
    # stored per scan step.
    body = jax.checkpoint(body, prevent_cse=False)
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(())), (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)
