"""Model dispatcher: one API over all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import hymba, transformer, xlstm


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ArchConfig
    init: Callable          # (key, max_seq) -> (params, axes)
    forward: Callable       # (params, batch) -> (logits, aux)
    loss: Callable          # (params, batch) -> (loss, metrics)
    init_decode_cache: Callable  # (B, max_seq) -> (cache, axes)
    decode_step: Callable   # (params, cache, tokens) -> (logits, cache)
    prime: Optional[Callable] = None  # (params, cache) -> cache (hymba
    #                                   meta tokens before any prompt)


def build_model(cfg: ArchConfig, impl: str = "auto") -> ModelApi:
    if cfg.family == "ssm":
        mod = xlstm
    elif cfg.family == "hybrid":
        mod = hymba
    else:
        mod = transformer
    prime = None
    if cfg.family == "hybrid" and cfg.n_meta_tokens:
        prime = lambda p, c: mod.prime_cache(cfg, p, c, impl)
    return ModelApi(
        cfg=cfg,
        init=lambda key, max_seq=0: mod.init_lm(cfg, key, max_seq),
        forward=lambda p, b: mod.forward(cfg, p, b, impl),
        loss=lambda p, b: mod.loss_fn(cfg, p, b, impl),
        init_decode_cache=lambda B, max_seq: mod.init_decode_cache(
            cfg, B, max_seq),
        decode_step=lambda p, c, t: mod.decode_step(cfg, p, c, t, impl),
        prime=prime,
    )


def init_model(cfg: ArchConfig, key=None, max_seq: int = 0):
    key = key if key is not None else jax.random.PRNGKey(0)
    return build_model(cfg).init(key, max_seq)


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params)
               if hasattr(x, "size"))


def model_flops_per_token(cfg: ArchConfig, seq_len: int) -> float:
    """MODEL_FLOPS per token: 6*N_active for training, 2*N_active for a
    forward pass is handled by the caller (this returns N_active — the
    parameter count that touches each token — plus attention terms)."""
    n = active_params(cfg)
    # attention FLOPs per token: 2 * 2 * S_eff * H * hd (qk^T and pv)
    windows = cfg.layer_windows(seq_len)
    attn = 0.0
    if cfg.family not in ("ssm",):
        for w in windows:
            s_eff = min(w, seq_len) if w < (1 << 29) else seq_len
            # causal average: half the context
            attn += 2 * 2 * (s_eff / 2) * cfg.n_heads * cfg.hd
    return n, attn


def active_params(cfg: ArchConfig) -> float:
    """Parameters touched per token (MoE counts top_k experts only)."""
    D, F, V, L = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    hd = cfg.hd
    if cfg.family == "ssm":
        inner = int(cfg.proj_factor * D)
        per_m = 2 * D * inner + 3 * inner * 4 + inner * 2 * cfg.n_heads \
            + inner * D
        per_s = 4 * D * inner + 4 * (inner // cfg.n_heads) * inner \
            + inner * D
        n_s = sum(1 for i in range(L)
                  if cfg.slstm_every and i % cfg.slstm_every
                  == cfg.slstm_every - 1)
        n = (L - n_s) * per_m + n_s * per_s
        n += V * D * 2
        return float(n)
    attn = D * cfg.n_heads * hd + 2 * D * cfg.n_kv_heads * hd \
        + cfg.n_heads * hd * D
    if cfg.moe:
        ffn = cfg.top_k * 3 * D * F + D * cfg.n_experts
    elif cfg.gated_mlp:
        ffn = 3 * D * F
    else:
        ffn = 2 * D * F
    per_layer = attn + ffn
    if cfg.family == "hybrid":
        d_inner = 2 * D
        per_layer += 2 * D * d_inner + d_inner * (
            2 * cfg.ssm_state + d_inner) + d_inner * D
    if cfg.enc_dec:
        per_layer += attn  # cross attention
    n = L * per_layer
    if cfg.enc_dec:
        enc_ffn = 2 * D * F if not cfg.gated_mlp else 3 * D * F
        n += cfg.n_enc_layers * (attn + enc_ffn)
    n += V * D * (1 if cfg.tie_embeddings else 2)
    return float(n)


def total_params(cfg: ArchConfig) -> float:
    if not cfg.moe:
        return active_params(cfg)
    D, F, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    extra = (cfg.n_experts - cfg.top_k) * 3 * D * F * L
    return active_params(cfg) + extra
