"""Decoder-only and encoder-decoder transformer LMs.

Covers the dense / MoE / VLM / audio families of the assigned pool:
  * scan-over-layers with stacked params (compact HLO at 48 layers);
  * per-layer sliding windows as *scanned traced values* so gemma3's 5:1
    local:global interleave lives inside one uniform scan body;
  * MoE blocks (olmoe / moonshot) via the scatter-based dispatch in
    :mod:`repro.models.layers`;
  * whisper-style enc-dec (audio frames from the stub frontend);
  * decode with a hybrid KV cache: sliding-window layers use ring buffers,
    global layers use the **BaM-paged pool** (page-table indirection,
    pages striped over the ``model`` mesh axis).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.kernels import ops
from repro.models import layers as L
from repro.utils import Tagged

from repro import compat

BIG_WINDOW = 1 << 30


# ------------------------------------------------------------------ block ---
def init_block(cfg: ArchConfig, key, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["ln1"], a["ln1"] = L.init_norm(cfg, cfg.d_model, dtype)
    p["attn"], a["attn"] = L.init_attention(cfg, ks[0], dtype)
    p["ln2"], a["ln2"] = L.init_norm(cfg, cfg.d_model, dtype)
    if cfg.moe:
        p["moe"], a["moe"] = L.init_moe(cfg, ks[1], dtype)
    else:
        p["mlp"], a["mlp"] = L.init_mlp(cfg, ks[1], dtype)
    if cfg.enc_dec:
        p["ln_x"], a["ln_x"] = L.init_norm(cfg, cfg.d_model, dtype)
        p["xattn"], a["xattn"] = L.init_attention(
            cfg.replace(qkv_bias=False), ks[2], dtype)
    return p, a


def block_apply(cfg: ArchConfig, p, x, *, window, positions, impl="auto",
                enc_out=None, causal=True):
    """One decoder block. window may be a traced scalar (scanned)."""
    h = L.attention(cfg, p["attn"], L.norm_apply(cfg, p["ln1"], x),
                    window=window, positions=positions, causal=causal,
                    impl=impl)
    x = x + h
    if enc_out is not None:
        # cross attention: kv from encoder output
        xq = L.norm_apply(cfg, p["ln_x"], x)
        B, S, _ = xq.shape
        dtype = cfg.compute_dtype
        hd = cfg.hd
        q = L.dense(p["xattn"]["wq"], xq, dtype).reshape(
            B, S, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        k = L.dense(p["xattn"]["wk"], enc_out, dtype).reshape(
            B, -1, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
        v = L.dense(p["xattn"]["wv"], enc_out, dtype).reshape(
            B, -1, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
        o = ops.flash_attention(q, k, v, causal=False, impl=impl)
        o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * hd)
        x = x + L.dense(p["xattn"]["wo"], o, dtype)
    xi = L.norm_apply(cfg, p["ln2"], x)
    if cfg.moe:
        y, aux = L.moe_ffn(cfg, p["moe"], xi)
    else:
        y, aux = L.mlp(cfg, p["mlp"], xi), {}
    return x + y, aux


# ------------------------------------------------------------------- init ---
def init_lm(cfg: ArchConfig, key, max_seq: int = 0) -> Tuple[Any, Any]:
    """Returns (params, axes). Layer params stacked along a leading axis."""
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p, a = {}, {}
    p["embed"], a["embed"] = L.init_embedding(cfg, ks[0], dtype)

    def one_block(k):
        return init_block(cfg, k, dtype)

    bp, ba = one_block(ks[1])
    blocks = jax.vmap(lambda k: one_block(k)[0])(
        jax.random.split(ks[2], cfg.n_layers))
    p["blocks"] = blocks
    a["blocks"] = jax.tree_util.tree_map(
        lambda ax: (None,) + ax, ba,
        is_leaf=lambda x: isinstance(x, tuple))

    p["ln_f"], a["ln_f"] = L.init_norm(cfg, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["head"], a["head"] = L.init_dense(
            ks[3], cfg.d_model, cfg.vocab, ("w_embed", "vocab"), dtype=dtype)

    if cfg.pos_emb == "learned":
        n_pos = max(max_seq, 1024)
        p["pos"] = L._normal(ks[4], (n_pos, cfg.d_model), 0.02, dtype)
        a["pos"] = (None, "w_embed")

    if cfg.enc_dec:
        enc_cfg = cfg.replace(moe=False, enc_dec=False)
        ebp, eba = init_block(enc_cfg, ks[5], dtype)
        p["enc_blocks"] = jax.vmap(
            lambda k: init_block(enc_cfg, k, dtype)[0])(
                jax.random.split(ks[6], cfg.n_enc_layers))
        a["enc_blocks"] = jax.tree_util.tree_map(
            lambda ax: (None,) + ax, eba,
            is_leaf=lambda x: isinstance(x, tuple))
        p["enc_pos"] = L._normal(ks[7], (cfg.enc_seq, cfg.d_model), 0.02,
                                 dtype)
        a["enc_pos"] = ("enc_seq", "w_embed")
        p["enc_ln_f"], a["enc_ln_f"] = L.init_norm(cfg, cfg.d_model, dtype)
    return p, a


def layer_window_array(cfg: ArchConfig, seq_len: int) -> jax.Array:
    nl, ng = cfg.local_ratio
    period = max(nl + ng, 1)
    out = []
    for i in range(cfg.n_layers):
        if cfg.window is not None and nl > 0 and (i % period) < nl:
            out.append(cfg.window)
        else:
            out.append(BIG_WINDOW)
    return jnp.asarray(out, jnp.int32)


# ---------------------------------------------------------------- forward ---
def _remat_policy(cfg: ArchConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots_saveable":
        return jax.checkpoint_policies.dots_saveable
    return jax.checkpoint_policies.nothing_saveable


def _scan_blocks(cfg: ArchConfig, blocks, x, windows, positions, impl,
                 enc_out=None, causal=True):
    aux0 = {"load_balance": jnp.zeros(()), "router_z": jnp.zeros(())} \
        if cfg.moe else {}

    def body(carry, layer):
        xc, aux = carry
        bp, w = layer
        xc2, aux_l = block_apply(cfg, bp, xc, window=w, positions=positions,
                                 impl=impl, enc_out=enc_out, causal=causal)
        for k in aux:
            aux[k] = aux[k] + aux_l[k]
        xc2 = constrain(xc2, ("batch", "seq", "act_embed"))
        return (xc2, aux), None

    if cfg.remat != "none":
        body = jax.checkpoint(body, policy=_remat_policy(cfg),
                              prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, aux0), (blocks, windows))
    return x, aux


def encode(cfg: ArchConfig, params, frames, impl="auto"):
    """Whisper-style encoder over precomputed frame embeddings (stub
    frontend): frames (B, Senc, D)."""
    x = frames.astype(cfg.compute_dtype)
    S = x.shape[1]
    x = x + params["enc_pos"][:S].astype(cfg.compute_dtype)
    windows = jnp.full((cfg.n_enc_layers,), BIG_WINDOW, jnp.int32)
    positions = jnp.arange(S)
    x, _ = _scan_blocks(cfg, params["enc_blocks"], x, windows, positions,
                        impl, causal=False)
    return L.norm_apply(cfg, params["enc_ln_f"], x)


def forward(cfg: ArchConfig, params, batch: Dict[str, jax.Array],
            impl: str = "auto", last_only: bool = False,
            return_hidden: bool = False) -> Tuple[jax.Array, Dict]:
    """Full-sequence forward -> (logits (B, S, V), aux).

    batch: tokens (B, S[text]) int32; optional patch_embeds (B, P, D)
    (vlm — prepended), enc_frames (B, Senc, D) (audio).
    """
    tokens = batch["tokens"]
    x = L.embed(cfg, params["embed"], tokens)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(cfg.compute_dtype)
        x = jnp.concatenate([pe, x], axis=1)
    B, S, _ = x.shape
    if cfg.pos_emb == "learned":
        x = x + params["pos"][:S].astype(cfg.compute_dtype)
    x = constrain(x, ("batch", "seq", "act_embed"))

    enc_out = None
    if cfg.enc_dec:
        enc_out = encode(cfg, params, batch["enc_frames"], impl)

    positions = jnp.arange(S)
    windows = layer_window_array(cfg, S)
    x, aux = _scan_blocks(cfg, params["blocks"], x, windows, positions,
                          impl, enc_out=enc_out, causal=True)
    x = L.norm_apply(cfg, params["ln_f"], x)
    if last_only:
        x = x[:, -1:, :]
    if return_hidden:
        return x, aux
    logits = L.logits_head(cfg, params.get("head"), params["embed"], x)
    return logits, aux


def loss_fn(cfg: ArchConfig, params, batch, impl: str = "auto"):
    """Next-token cross entropy (+ MoE aux losses), chunked over seq so the
    (B, S, V) logits tensor is never materialised."""
    hidden, aux = forward(cfg, params, batch, impl, return_hidden=True)
    tokens = batch["tokens"]
    B, S = tokens.shape
    n_prefix = hidden.shape[1] - S          # vlm patches prepended
    hidden_text = hidden[:, n_prefix:, :]
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1)
        mask = jnp.concatenate(
            [jnp.ones((B, S - 1)), jnp.zeros((B, 1))], axis=1)
    else:
        mask = batch.get("loss_mask", jnp.ones_like(labels,
                                                    dtype=jnp.float32))
    loss = L.lm_loss_from_hidden(cfg, params.get("head"), params["embed"],
                                 hidden_text, labels, mask)
    metrics = {"nll": loss}
    if cfg.moe:
        lb = aux["load_balance"] / cfg.n_layers
        z = aux["router_z"] / cfg.n_layers
        metrics.update(load_balance=lb, router_z=z)
        loss = loss + 0.01 * lb + 1e-3 * z
    return loss, metrics


# =========================================================== decode caches ==
def _ring_spec(cfg, B, W):
    hd = cfg.hd
    return {
        "k": jnp.zeros((B, cfg.n_kv_heads, W, hd), cfg.compute_dtype),
        "v": jnp.zeros((B, cfg.n_kv_heads, W, hd), cfg.compute_dtype),
        "pos": jnp.full((B, W), -1, jnp.int32),
    }


_RING_AXES = {
    "k": ("batch", "act_kv_heads", None, None),
    "v": ("batch", "act_kv_heads", None, None),
    "pos": ("batch", None),
}


def _paged_spec(cfg, B, max_seq):
    hd = cfg.hd
    page = cfg.kv_page_size
    n_pages = -(-max_seq // page)
    return {
        "k_pages": jnp.zeros((B, n_pages, page, cfg.n_kv_heads, hd),
                             cfg.compute_dtype),
        "v_pages": jnp.zeros((B, n_pages, page, cfg.n_kv_heads, hd),
                             cfg.compute_dtype),
        # identity mapping at init; the indirection is the BaM page table
        "page_table": jnp.broadcast_to(
            jnp.arange(n_pages, dtype=jnp.int32)[None], (B, n_pages)),
    }


_PAGED_AXES = {
    "k_pages": ("batch", "kv_pages", None, None, None),
    "v_pages": ("batch", "kv_pages", None, None, None),
    "page_table": ("batch", None),
}


def init_decode_cache(cfg: ArchConfig, B: int, max_seq: int,
                      enc_out: Optional[jax.Array] = None):
    """Hybrid cache: ring buffers for window layers, BaM-paged pools for
    global layers.  Returns (cache, axes)."""
    windows = cfg.layer_windows(max_seq)
    layers, axes = [], []
    for w in windows:
        if w < max_seq:                       # sliding-window layer
            layers.append(Tagged("ring", _ring_spec(cfg, B, w)))
            axes.append(Tagged("ring", _RING_AXES))
        else:
            layers.append(Tagged("paged", _paged_spec(cfg, B, max_seq)))
            axes.append(Tagged("paged", _PAGED_AXES))
    cache = {
        "seq_lens": jnp.zeros((B,), jnp.int32),
        "layers": tuple(layers),
    }
    cache_axes = {
        "seq_lens": ("batch",),
        "layers": tuple(axes),
    }
    if cfg.enc_dec:
        # cross-attention KV per decoder layer, computed once at prefill
        Senc = cfg.enc_seq
        hd = cfg.hd
        cache["xkv"] = jnp.zeros(
            (cfg.n_layers, 2, B, cfg.n_kv_heads, Senc, hd),
            cfg.compute_dtype)
        cache_axes["xkv"] = (None, None, "batch", "act_kv_heads",
                             "enc_seq", None)
    return cache, cache_axes


def _decode_attn_ring(cfg, p, xq, entry, pos, impl):
    """xq: (B, 1, D) normed input; returns attn output (B, 1, D)."""
    B = xq.shape[0]
    dtype = cfg.compute_dtype
    hd = cfg.hd
    q = L.dense(p["wq"], xq, dtype).reshape(B, 1, cfg.n_heads, hd)
    k = L.dense(p["wk"], xq, dtype).reshape(B, 1, cfg.n_kv_heads, hd)
    v = L.dense(p["wv"], xq, dtype).reshape(B, 1, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = L.rms_norm_simple(q, p["q_norm"])
        k = L.rms_norm_simple(k, p["k_norm"])
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3)
    if cfg.pos_emb == "rope":
        pb = pos[:, None]                     # (B, 1)
        q = L.rope(q, pb[:, None, :], cfg.rope_theta)
        k = L.rope(k, pb[:, None, :], cfg.rope_theta)
    v = v.transpose(0, 2, 1, 3)

    W = entry["k"].shape[2]
    slot = pos % W                            # (B,)
    bidx = jnp.arange(B)
    k_ring = entry["k"].at[bidx, :, slot].set(k[:, :, 0])
    v_ring = entry["v"].at[bidx, :, slot].set(v[:, :, 0])
    ring_pos = entry["pos"].at[bidx, slot].set(pos)

    # masked attention over the ring (GQA: fold group)
    kr = k_ring.astype(jnp.float32)
    G = cfg.group
    qg = q.reshape(B, cfg.n_kv_heads, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bkwd->bkgw", qg, kr) / math.sqrt(hd)
    valid = (ring_pos >= 0) & (ring_pos > (pos[:, None] - W)) \
        & (ring_pos <= pos[:, None])
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bkwd->bkgd", pr, v_ring.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.n_heads * hd).astype(dtype)
    out = L.dense(p["wo"], o, dtype)
    return out, {"k": k_ring, "v": v_ring, "pos": ring_pos}


def _paged_attention_flash_decode(cfg, q, k_pages, v_pages, page_table,
                                  seq_lens, mesh):
    """Shard-local flash-decoding over the model-striped page pool.

    The naive SPMD lowering of the page-table gather replicates the whole
    pool per step (XLA 'involuntary full rematerialization').  Here each
    model shard attends over only the physical pages it owns and the
    partial (m, l, acc) softmax states are psum-combined — the TPU
    flash-decoding schedule, and exactly what the Pallas paged kernel does
    across cores.  Collective payload per step: O(B x Hq x hd), not O(pool).
    """
    import math as _math
    from jax.sharding import PartitionSpec as PS
    B, Hq, D = q.shape
    P_total, page = k_pages.shape[1], k_pages.shape[2]
    Hkv = k_pages.shape[3]
    NP = page_table.shape[1]
    G = Hq // Hkv
    scale = 1.0 / _math.sqrt(D)

    def shard_fn(qb, kp, vp, pt, sl):
        # kp/vp: (B, P_total/n_shards, page, Hkv, D) local slice
        s = jax.lax.axis_index("model")
        p_loc = kp.shape[1]
        base = s * p_loc
        mine = (pt >= base) & (pt < base + p_loc)          # (B, NP)
        safe = jnp.where(mine, pt - base, 0)
        idx = safe[:, :, None, None, None]
        k = jnp.take_along_axis(kp, idx, axis=1)           # local gather
        v = jnp.take_along_axis(vp, idx, axis=1)
        S = NP * page
        k = k.transpose(0, 3, 1, 2, 4).reshape(B, Hkv, S, D)
        v = v.transpose(0, 3, 1, 2, 4).reshape(B, Hkv, S, D)
        qg = qb.reshape(B, Hkv, G, D).astype(jnp.float32)
        sc = jnp.einsum("bhgd,bhkd->bhgk", qg,
                        k.astype(jnp.float32)) * scale
        pos = jnp.arange(S)[None, :]
        live = (pos < sl[:, None]) & jnp.repeat(mine, page, axis=1)
        sc = jnp.where(live[:, None, None], sc, -1e30)
        m = sc.max(-1)                                      # (B,Hkv,G)
        m_g = jax.lax.pmax(m, "model")
        pr = jnp.where(live[:, None, None],
                       jnp.exp(sc - m_g[..., None]), 0.0)
        l = jax.lax.psum(pr.sum(-1), "model")
        acc = jax.lax.psum(
            jnp.einsum("bhgk,bhkd->bhgd", pr, v.astype(jnp.float32)),
            "model")
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(B, Hq, D).astype(q.dtype)

    return compat.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(PS(), PS(None, "model"), PS(None, "model"), PS(), PS()),
        out_specs=PS(), axis_names={"model"}, check_vma=False,
    )(q, k_pages, v_pages, page_table, seq_lens)


def _decode_attn_paged(cfg, p, xq, entry, pos, impl):
    B = xq.shape[0]
    dtype = cfg.compute_dtype
    hd = cfg.hd
    q = L.dense(p["wq"], xq, dtype).reshape(B, 1, cfg.n_heads, hd)
    k = L.dense(p["wk"], xq, dtype).reshape(B, 1, cfg.n_kv_heads, hd)
    v = L.dense(p["wv"], xq, dtype).reshape(B, 1, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = L.rms_norm_simple(q, p["q_norm"])
        k = L.rms_norm_simple(k, p["k_norm"])
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3)
    if cfg.pos_emb == "rope":
        pb = pos[:, None]
        q = L.rope(q, pb[:, None, :], cfg.rope_theta)
        k = L.rope(k, pb[:, None, :], cfg.rope_theta)
    v = v.transpose(0, 2, 1, 3)

    page = entry["k_pages"].shape[2]
    lpage = pos // page                         # (B,) logical page
    slot_in = pos % page
    bidx = jnp.arange(B)
    ppage = entry["page_table"][bidx, lpage]    # physical page
    ppage = jnp.maximum(ppage, 0)
    k_pages = entry["k_pages"].at[bidx, ppage, slot_in].set(k[:, :, 0])
    v_pages = entry["v_pages"].at[bidx, ppage, slot_in].set(v[:, :, 0])

    from repro.distributed.sharding import current_mesh
    mesh = current_mesh()
    if cfg.flash_decode_shards and mesh is not None \
            and "model" in mesh.axis_names:
        o = _paged_attention_flash_decode(
            cfg, q[:, :, 0], k_pages, v_pages, entry["page_table"],
            pos + 1, mesh)
    else:
        o = ops.paged_attention(
            q[:, :, 0], k_pages, v_pages, entry["page_table"], pos + 1,
            impl=impl)                          # (B, Hq, hd)
    o = o.reshape(B, 1, cfg.n_heads * hd)
    out = L.dense(p["wo"], o.astype(dtype), dtype)
    return out, {"k_pages": k_pages, "v_pages": v_pages,
                 "page_table": entry["page_table"]}


def _decode_xattn(cfg, p, xq, xkv_l):
    """Cross-attention for decode; xkv_l: (2, B, Hkv, Senc, hd)."""
    B = xq.shape[0]
    dtype = cfg.compute_dtype
    hd = cfg.hd
    q = L.dense(p["wq"], xq, dtype).reshape(
        B, 1, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k, v = xkv_l[0], xkv_l[1]
    G = cfg.group
    qg = q.reshape(B, cfg.n_kv_heads, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qg,
                   k.astype(jnp.float32)) / math.sqrt(hd)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", pr, v.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.n_heads * hd).astype(dtype)
    return L.dense(p["wo"], o, dtype)


def decode_step(cfg: ArchConfig, params, cache, tokens: jax.Array,
                impl: str = "auto"):
    """One decode step for all transformer families.

    tokens: (B,) int32 — the tokens generated at the previous step.
    Returns (logits (B, V), cache').
    """
    B = tokens.shape[0]
    pos = cache["seq_lens"]                         # (B,)
    x = L.embed(cfg, params["embed"], tokens[:, None])   # (B, 1, D)
    if cfg.pos_emb == "learned":
        x = x + params["pos"][pos][:, None].astype(cfg.compute_dtype)
    x = constrain(x, ("batch", None, "act_embed"))

    new_layers = []
    for i, tagged in enumerate(cache["layers"]):
        kind, entry = tagged.kind, tagged.value
        bp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
        xq = L.norm_apply(cfg, bp["ln1"], x)
        if kind == "ring":
            h, entry2 = _decode_attn_ring(cfg, bp["attn"], xq, entry, pos,
                                          impl)
        else:
            h, entry2 = _decode_attn_paged(cfg, bp["attn"], xq, entry, pos,
                                           impl)
        x = x + h
        if cfg.enc_dec:
            xq2 = L.norm_apply(cfg, bp["ln_x"], x)
            x = x + _decode_xattn(cfg, bp["xattn"], xq2, cache["xkv"][i])
        xi = L.norm_apply(cfg, bp["ln2"], x)
        if cfg.moe:
            y, _ = L.moe_ffn(cfg, bp["moe"], xi)
        else:
            y = L.mlp(cfg, bp["mlp"], xi)
        x = x + y
        new_layers.append(Tagged(kind, entry2))

    x = L.norm_apply(cfg, params["ln_f"], x)
    logits = L.logits_head(cfg, params.get("head"), params["embed"], x)
    cache2 = dict(cache)
    cache2["layers"] = tuple(new_layers)
    cache2["seq_lens"] = pos + 1
    return logits[:, 0, :], cache2


def prefill(cfg: ArchConfig, params, batch, max_seq: int,
            impl: str = "auto"):
    """Run the full prompt, return (last-token logits, filled cache).

    Correct (matches decode_step semantics) and used by the examples and
    integration tests; the 32k dry-run cells lower `forward` (prefill
    compute) and `decode_step` separately.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc_out = None
    if cfg.enc_dec:
        enc_out = encode(cfg, params, batch["enc_frames"], impl)
    cache, _ = init_decode_cache(cfg, B, max_seq)
    if cfg.enc_dec:
        # fill cross-KV once
        xkv = []
        dtype = cfg.compute_dtype
        hd = cfg.hd
        for i in range(cfg.n_layers):
            bp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            k = L.dense(bp["xattn"]["wk"], enc_out, dtype).reshape(
                B, -1, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
            v = L.dense(bp["xattn"]["wv"], enc_out, dtype).reshape(
                B, -1, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
            xkv.append(jnp.stack([k, v]))
        cache["xkv"] = jnp.stack(xkv)
    # sequential prefill through decode_step (exact; fine at test scale)
    logits = None

    def body(carry, t):
        cache = carry
        logits_t, cache = decode_step(cfg, params, cache, tokens[:, t], impl)
        return cache, logits_t

    cache, all_logits = jax.lax.scan(body, cache, jnp.arange(S))
    return all_logits[-1], cache
