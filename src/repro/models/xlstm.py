"""xLSTM (sLSTM + mLSTM blocks) — the [ssm] architecture of the pool.

mLSTM: matrix-memory cell with exponential gating.  Training/prefill uses
the **chunkwise-parallel** form (intra-chunk attention-like matmuls +
inter-chunk recurrent state), the TPU-friendly formulation — the seq scan
carries only (C, n, m) per chunk boundary.  Decode uses the exact recurrent
step.  The two are validated against each other in tests.

sLSTM: scalar-memory cell with hidden-state recurrence (R per head) — no
parallel form exists (that is *why* the 7:1 interleave exists), so it runs
as a chunked ``lax.scan`` over time with rematerialised chunks.

Layer interleave: one sLSTM per ``cfg.slstm_every`` blocks.  Blocks are
heterogeneous, so the layer loop is unrolled (params are per-layer tuples).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.utils import Tagged

NEG_INF = -1e30
QKV_BLOCK = 4


def _block_linear(w, x, dtype):
    """Block-diagonal linear: w (n_blocks, bs, bs); x (..., n_blocks*bs)."""
    nb, bs, _ = w.shape
    xb = x.reshape(x.shape[:-1] + (nb, bs))
    y = jnp.einsum("...nb,nbc->...nc", xb.astype(dtype), w.astype(dtype))
    return y.reshape(x.shape)


# ------------------------------------------------------------ mLSTM cell ---
def mlstm_chunkwise(q, k, v, ilog, glog, *, chunk: int = 256,
                    state=None):
    """Chunkwise mLSTM. q,k,v: (B,H,S,d) (q pre-scaled by 1/sqrt(d));
    ilog, glog: (B,H,S) input-gate preact and logsigmoid(forget).
    Returns (h (B,H,S,d), final_state (C (B,H,d,d), n (B,H,d), m (B,H)))."""
    B, H, S, d = q.shape
    Lc = min(chunk, S)
    assert S % Lc == 0, (S, Lc)
    NC = S // Lc

    qc = q.reshape(B, H, NC, Lc, d)
    kc = k.reshape(B, H, NC, Lc, d)
    vc = v.reshape(B, H, NC, Lc, d)
    ic = ilog.reshape(B, H, NC, Lc)
    gc = glog.reshape(B, H, NC, Lc)

    if state is None:
        C0 = jnp.zeros((B, H, d, d), jnp.float32)
        n0 = jnp.zeros((B, H, d), jnp.float32)
        m0 = jnp.full((B, H), NEG_INF, jnp.float32)
    else:
        C0, n0, m0 = state

    tril = jnp.tril(jnp.ones((Lc, Lc), bool))

    def chunk_step(carry, xs):
        C, n, m = carry                       # (B,H,d,d),(B,H,d),(B,H)
        Q, K, V, il, gl = xs                  # (B,H,Lc,d) / (B,H,Lc)
        Q = Q.astype(jnp.float32)
        K = K.astype(jnp.float32)
        V = V.astype(jnp.float32)
        b = jnp.cumsum(gl, axis=-1)           # (B,H,Lc) inclusive
        btot = b[..., -1]                     # (B,H)

        D = b[..., :, None] - b[..., None, :] + il[..., None, :]
        D = jnp.where(tril, D, NEG_INF)       # (B,H,Lc,Lc)
        m_intra = jnp.max(D, axis=-1)         # (B,H,Lc)
        m_inter = b + m[..., None]            # (B,H,Lc)
        mt = jnp.maximum(m_intra, m_inter)
        mt = jnp.maximum(mt, -60.0)           # keep exp(-mt) finite at start

        Sij = jnp.einsum("bhtd,bhsd->bhts", Q, K) * jnp.exp(
            D - mt[..., None])
        w_inter = jnp.exp(m_inter - mt)       # (B,H,Lc)
        num = jnp.einsum("bhts,bhsd->bhtd", Sij, V) \
            + w_inter[..., None] * jnp.einsum("bhtd,bhde->bhte", Q, C)
        den = jnp.einsum("bhts->bht", Sij) \
            + w_inter * jnp.einsum("bhtd,bhd->bht", Q, n)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-mt))
        Hc = num / den[..., None]             # (B,H,Lc,d)

        # state update to the end of the chunk
        dec = btot[..., None] - b + il        # (B,H,Lc) decay s -> end
        m_next = jnp.maximum(btot + m, jnp.max(dec, axis=-1))
        sc_old = jnp.exp(btot + m - m_next)   # (B,H)
        wv = jnp.exp(dec - m_next[..., None])  # (B,H,Lc)
        C2 = sc_old[..., None, None] * C + jnp.einsum(
            "bhsd,bhse->bhde", K * wv[..., None], V)
        n2 = sc_old[..., None] * n + jnp.einsum("bhsd->bhd",
                                                K * wv[..., None])
        return (C2, n2, m_next), Hc

    xs = (qc.transpose(2, 0, 1, 3, 4), kc.transpose(2, 0, 1, 3, 4),
          vc.transpose(2, 0, 1, 3, 4), ic.transpose(2, 0, 1, 3),
          gc.transpose(2, 0, 1, 3))
    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, d)
    return h, (C, n, m)


def mlstm_step(state, q, k, v, ilog, glog):
    """Exact recurrent step. q,k,v: (B,H,d) (q pre-scaled); gates (B,H)."""
    C, n, m = state
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    m_new = jnp.maximum(glog + m, ilog)
    m_new = jnp.maximum(m_new, -60.0)
    fp = jnp.exp(glog + m - m_new)            # (B,H)
    ip = jnp.exp(ilog - m_new)
    C2 = fp[..., None, None] * C + ip[..., None, None] * (
        k[..., :, None] * v[..., None, :])    # (B,H,d,d) [k-index first]
    n2 = fp[..., None] * n + ip[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C2)
    den = jnp.einsum("bhd,bhd->bh", q, n2)
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
    h = num / den[..., None]
    return (C2, n2, m_new), h


# ----------------------------------------------------------- mLSTM block ---
def init_mlstm_block(cfg: ArchConfig, key, dtype=jnp.float32):
    d = cfg.d_model
    inner = int(cfg.proj_factor * d)
    ks = jax.random.split(key, 8)
    p, a = {}, {}
    p["ln"], a["ln"] = L.init_norm(cfg, d, dtype)
    p["w_up"], a["w_up"] = L.init_dense(ks[0], d, 2 * inner,
                                        ("w_embed", "w_inner"), dtype=dtype)
    # q,k,v are block-diagonal with blocksize 4 (the official xLSTM
    # "qkv_proj_blocksize=4" BlockLinear) — near-free in params, keeps the
    # 1.3B budget honest.
    bs = QKV_BLOCK
    import math as _math
    for nm, kk in (("wq", 1), ("wk", 2), ("wv", 3)):
        p[nm] = L._normal(ks[kk], (inner // bs, bs, bs),
                          1.0 / _math.sqrt(bs), dtype)
        a[nm] = ("w_inner", None, None)
    p["w_if"], a["w_if"] = L.init_dense(ks[4], inner, 2 * cfg.n_heads,
                                        ("w_inner", None), dtype=dtype)
    p["hn"] = jnp.ones((inner,), dtype)       # per-head output norm
    a["hn"] = (None,)
    p["w_down"], a["w_down"] = L.init_dense(ks[5], inner, d,
                                            ("w_inner", "w_embed"),
                                            dtype=dtype)
    return p, a


def _mlstm_qkv_gates(cfg, p, x):
    """x: (B,S,D) -> q,k,v (B,H,S,hd), ilog/glog (B,H,S), z (B,S,inner)."""
    dtype = cfg.compute_dtype
    B, S, _ = x.shape
    H = cfg.n_heads
    xn = L.norm_apply(cfg, p["ln"], x)
    up = L.dense(p["w_up"], xn, dtype)
    inner = up.shape[-1] // 2
    xm, z = up[..., :inner], up[..., inner:]
    hd = inner // H
    q = _block_linear(p["wq"], xm, dtype).reshape(
        B, S, H, hd).transpose(0, 2, 1, 3)
    k = _block_linear(p["wk"], xm, dtype).reshape(
        B, S, H, hd).transpose(0, 2, 1, 3)
    v = _block_linear(p["wv"], xm, dtype).reshape(
        B, S, H, hd).transpose(0, 2, 1, 3)
    gates = L.dense(p["w_if"], xm, dtype).astype(jnp.float32)
    ilog = gates[..., :H].transpose(0, 2, 1)
    glog = jax.nn.log_sigmoid(gates[..., H:]).transpose(0, 2, 1)
    q = q / math.sqrt(hd)
    return q, k, v, ilog, glog, z, inner, hd


def mlstm_block(cfg: ArchConfig, p, x, *, chunk=256):
    B, S, _ = x.shape
    dtype = cfg.compute_dtype
    q, k, v, ilog, glog, z, inner, hd = _mlstm_qkv_gates(cfg, p, x)
    h, _ = mlstm_chunkwise(q, k, v, ilog, glog, chunk=min(chunk, S))
    h = h.transpose(0, 2, 1, 3).reshape(B, S, inner)
    # per-head norm then gate
    hn = L.rms_norm_simple(h.reshape(B, S, cfg.n_heads, hd),
                           p["hn"].reshape(cfg.n_heads, hd)[None, None])
    h = hn.reshape(B, S, inner).astype(dtype) * jax.nn.silu(z)
    return x + L.dense(p["w_down"], h, dtype)


def mlstm_block_step(cfg: ArchConfig, p, x, state):
    """Decode step. x: (B,1,D); state (C,n,m)."""
    B = x.shape[0]
    dtype = cfg.compute_dtype
    q, k, v, ilog, glog, z, inner, hd = _mlstm_qkv_gates(cfg, p, x)
    state2, h = mlstm_step(state, q[:, :, 0], k[:, :, 0], v[:, :, 0],
                           ilog[:, :, 0], glog[:, :, 0])   # (B,H,hd)
    hn = L.rms_norm_simple(h.reshape(B, 1, cfg.n_heads, hd),
                           p["hn"].reshape(cfg.n_heads, hd)[None, None])
    h = hn.reshape(B, 1, inner).astype(dtype) * jax.nn.silu(z)
    return x + L.dense(p["w_down"], h, dtype), state2


def mlstm_state_spec(cfg: ArchConfig, B: int):
    inner = int(cfg.proj_factor * cfg.d_model)
    hd = inner // cfg.n_heads
    return (jnp.zeros((B, cfg.n_heads, hd, hd), jnp.float32),
            jnp.zeros((B, cfg.n_heads, hd), jnp.float32),
            jnp.full((B, cfg.n_heads), NEG_INF, jnp.float32))


_MLSTM_STATE_AXES = (("batch", "state_head", None, None),
                     ("batch", "state_head", None),
                     ("batch", "state_head"))


# ----------------------------------------------------------- sLSTM block ---
def init_slstm_block(cfg: ArchConfig, key, dtype=jnp.float32):
    d = cfg.d_model
    inner = int(cfg.proj_factor * d)
    H = cfg.n_heads
    hd = inner // H
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["ln"], a["ln"] = L.init_norm(cfg, d, dtype)
    p["w_in"], a["w_in"] = L.init_dense(ks[0], d, 4 * inner,
                                        ("w_embed", "w_inner"), dtype=dtype)
    p["r"] = L._normal(ks[1], (4, H, hd, hd), 1.0 / math.sqrt(hd), dtype)
    a["r"] = (None, "state_head", None, None)
    p["w_down"], a["w_down"] = L.init_dense(ks[2], inner, d,
                                            ("w_inner", "w_embed"),
                                            dtype=dtype)
    return p, a


def _slstm_gate_step(p, xs_t, h, c, n, m, H, hd):
    """One sLSTM time step in f32. xs_t: (B, 4*inner) preacts."""
    B = xs_t.shape[0]
    inner = H * hd
    hh = h.reshape(B, H, hd)
    rec = jnp.einsum("bhd,ghde->gbhe", hh, p["r"].astype(jnp.float32))
    rec = rec.reshape(4, B, inner)
    pre = xs_t.reshape(B, 4, inner).transpose(1, 0, 2) + rec
    i_t, f_t, z_t, o_t = pre[0], pre[1], pre[2], pre[3]
    flog = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(flog + m, i_t)
    ip = jnp.exp(i_t - m_new)
    fp = jnp.exp(flog + m - m_new)
    c2 = fp * c + ip * jnp.tanh(z_t)
    n2 = fp * n + ip
    h2 = jax.nn.sigmoid(o_t) * c2 / jnp.maximum(n2, 1e-6)
    return h2, c2, n2, m_new


def slstm_block(cfg: ArchConfig, p, x, *, chunk=128):
    """Sequential sLSTM over time (chunked scan, remat per chunk)."""
    B, S, D = x.shape
    dtype = cfg.compute_dtype
    H = cfg.n_heads
    inner = int(cfg.proj_factor * D)
    hd = inner // H
    xn = L.norm_apply(cfg, p["ln"], x)
    xs = L.dense(p["w_in"], xn, dtype).astype(jnp.float32)  # (B,S,4*inner)

    Lc = min(chunk, S)
    NC = S // Lc
    xsc = xs.reshape(B, NC, Lc, 4 * inner).transpose(1, 2, 0, 3)

    def chunk_fn(carry, xs_chunk):
        def step(carry, xt):
            h, c, n, m = carry
            h2, c2, n2, m2 = _slstm_gate_step(p, xt, h, c, n, m, H, hd)
            return (h2, c2, n2, m2), h2
        return jax.lax.scan(step, carry, xs_chunk)

    chunk_fn = jax.checkpoint(chunk_fn, prevent_cse=False)
    z = jnp.zeros((B, inner), jnp.float32)
    m0 = jnp.full((B, inner), NEG_INF, jnp.float32)
    carry, hs = jax.lax.scan(chunk_fn, (z, z, z, m0), xsc)
    h = hs.reshape(NC * Lc, B, inner).transpose(1, 0, 2)     # (B,S,inner)
    return x + L.dense(p["w_down"], h.astype(dtype), dtype)


def slstm_block_step(cfg: ArchConfig, p, x, state):
    B = x.shape[0]
    dtype = cfg.compute_dtype
    H = cfg.n_heads
    inner = int(cfg.proj_factor * cfg.d_model)
    hd = inner // H
    xn = L.norm_apply(cfg, p["ln"], x)
    xs = L.dense(p["w_in"], xn, dtype).astype(jnp.float32)[:, 0]
    h, c, n, m = state
    h2, c2, n2, m2 = _slstm_gate_step(p, xs, h, c, n, m, H, hd)
    out = x + L.dense(p["w_down"], h2[:, None].astype(dtype), dtype)
    return out, (h2, c2, n2, m2)


def slstm_state_spec(cfg: ArchConfig, B: int):
    inner = int(cfg.proj_factor * cfg.d_model)
    z = jnp.zeros((B, inner), jnp.float32)
    return (z, z, z, jnp.full((B, inner), NEG_INF, jnp.float32))


_SLSTM_STATE_AXES = tuple(("batch", "w_inner") for _ in range(4))


# ------------------------------------------------------------------- LM ----
def _is_slstm(cfg: ArchConfig, i: int) -> bool:
    return cfg.slstm_every > 0 and (i % cfg.slstm_every
                                    == cfg.slstm_every - 1)


def init_lm(cfg: ArchConfig, key, max_seq: int = 0):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, cfg.n_layers + 3)
    p, a = {}, {}
    p["embed"], a["embed"] = L.init_embedding(cfg, ks[0], dtype)
    blocks, baxes = [], []
    for i in range(cfg.n_layers):
        if _is_slstm(cfg, i):
            bp, ba = init_slstm_block(cfg, ks[i + 1], dtype)
        else:
            bp, ba = init_mlstm_block(cfg, ks[i + 1], dtype)
        blocks.append(bp)
        baxes.append(ba)
    p["blocks"] = tuple(blocks)
    a["blocks"] = tuple(baxes)
    p["ln_f"], a["ln_f"] = L.init_norm(cfg, cfg.d_model, dtype)
    p["head"], a["head"] = L.init_dense(ks[-1], cfg.d_model, cfg.vocab,
                                        ("w_embed", "vocab"), dtype=dtype)
    return p, a


def forward(cfg: ArchConfig, params, batch, impl: str = "auto",
            last_only: bool = False, return_hidden: bool = False):
    tokens = batch["tokens"]
    x = L.embed(cfg, params["embed"], tokens)
    x = constrain(x, ("batch", "seq", "act_embed"))

    for i, bp in enumerate(params["blocks"]):
        fn = slstm_block if _is_slstm(cfg, i) else mlstm_block
        if cfg.remat != "none":
            fn = jax.checkpoint(fn, static_argnums=(0,), prevent_cse=False)
        x = fn(cfg, bp, x)
        x = constrain(x, ("batch", "seq", "act_embed"))
    x = L.norm_apply(cfg, params["ln_f"], x)
    if last_only:
        x = x[:, -1:, :]
    if return_hidden:
        return x, {}
    logits = L.logits_head(cfg, params.get("head"), params["embed"], x)
    return logits, {}


def loss_fn(cfg: ArchConfig, params, batch, impl: str = "auto"):
    hidden, _ = forward(cfg, params, batch, impl, return_hidden=True)
    tokens = batch["tokens"]
    B, S = tokens.shape
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1)
    mask = jnp.concatenate([jnp.ones((B, S - 1)), jnp.zeros((B, 1))], axis=1)
    loss = L.lm_loss_from_hidden(cfg, params.get("head"), params["embed"],
                                 hidden, labels, mask)
    return loss, {"nll": loss}


def init_decode_cache(cfg: ArchConfig, B: int, max_seq: int):
    layers, axes = [], []
    for i in range(cfg.n_layers):
        if _is_slstm(cfg, i):
            layers.append(Tagged("slstm", slstm_state_spec(cfg, B)))
            axes.append(Tagged("slstm", _SLSTM_STATE_AXES))
        else:
            layers.append(Tagged("mlstm", mlstm_state_spec(cfg, B)))
            axes.append(Tagged("mlstm", _MLSTM_STATE_AXES))
    cache = {"seq_lens": jnp.zeros((B,), jnp.int32), "layers": tuple(layers)}
    cache_axes = {"seq_lens": ("batch",), "layers": tuple(axes)}
    return cache, cache_axes


def decode_step(cfg: ArchConfig, params, cache, tokens, impl: str = "auto"):
    B = tokens.shape[0]
    x = L.embed(cfg, params["embed"], tokens[:, None])
    new_layers = []
    for i, tagged in enumerate(cache["layers"]):
        kind, state = tagged.kind, tagged.value
        bp = params["blocks"][i]
        if kind == "slstm":
            x, st2 = slstm_block_step(cfg, bp, x, state)
        else:
            x, st2 = mlstm_block_step(cfg, bp, x, state)
        new_layers.append(Tagged(kind, st2))
    x = L.norm_apply(cfg, params["ln_f"], x)
    logits = L.logits_head(cfg, params.get("head"), params["embed"], x)
    cache2 = dict(cache)
    cache2["layers"] = tuple(new_layers)
    cache2["seq_lens"] = cache["seq_lens"] + 1
    return logits[:, 0, :], cache2
