from repro.serving.engine import ServeEngine
from repro.serving.kv_cache import (PagedKVManager, spill_cold_pages,
                                    fetch_holes)

__all__ = ["ServeEngine", "PagedKVManager", "spill_cold_pages",
           "fetch_holes"]
