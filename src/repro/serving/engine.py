"""Serving engine: fixed-slot continuous batching over the model decode
step, with BaM paged-KV spill/fetch between steps.

The engine owns ``B`` sequence slots.  Each step:

  1. admit queued requests into free slots (prefill via the decode path,
     token-at-a-time — exact, simple; chunked prefill is a TODO flag),
  2. ``ensure_resident`` — BaM-fetch any spilled pages decode will touch,
  3. one jitted ``decode_step`` for the whole batch,
  4. greedy/temperature sampling, retire finished sequences,
  5. ``maybe_spill`` cold pages to the storage tier.

This is the paper's compute model inverted onto serving: the *accelerator*
decides which pages move, the host is just the storage service.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.model import ModelApi, build_model
from repro.serving.kv_cache import PagedKVManager

__all__ = ["ServeEngine", "Request"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0
    pending_prompt: List[int] = dataclasses.field(default_factory=list)


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, batch_slots: int = 4,
                 max_seq: int = 512, kv_manager: PagedKVManager | None = None,
                 greedy: bool = True, impl: str = "auto"):
        self.cfg = cfg
        self.api: ModelApi = build_model(cfg, impl)
        self.params = params
        self.B = batch_slots
        self.max_seq = max_seq
        self.kv = kv_manager
        self.greedy = greedy
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.queue: List[Request] = []
        self.cache, _ = self.api.init_decode_cache(batch_slots, max_seq)
        if self.api.prime is not None:
            self.cache = self.api.prime(params, self.cache)
        # snapshot (post-prime) for per-slot resets; leaves are batch-first
        self._cache0 = jax.tree_util.tree_map(lambda x: x, self.cache)
        self._step = jax.jit(self.api.decode_step)
        self.n_steps = 0

    # ------------------------------------------------------------- admin --
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in self.slots:
            if slot.req is None and self.queue:
                req = self.queue.pop(0)
                slot.req = req
                slot.pos = 0
                slot.pending_prompt = list(req.prompt)

    def _reset_slot_cache(self, b: int):
        """Restore slot b to the (post-prime) initial cache state."""
        def one(cur, init):
            if hasattr(cur, "at") and cur.ndim >= 1 \
                    and cur.shape[0] == self.B:
                return cur.at[b].set(init[b])
            return cur
        self.cache = jax.tree_util.tree_map(one, self.cache, self._cache0)

    # -------------------------------------------------------------- step --
    def step(self) -> int:
        """One engine step; returns number of active slots."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active:
            return 0
        if self.kv is not None:
            self.cache, _ = self.kv.ensure_resident(self.cache)

        tokens = np.zeros((self.B,), np.int32)
        for i, slot in enumerate(self.slots):
            if slot.req is None:
                continue
            if slot.pending_prompt:
                tokens[i] = slot.pending_prompt.pop(0)   # prefill token
            elif slot.req.out:
                tokens[i] = slot.req.out[-1]
            else:
                tokens[i] = slot.req.prompt[-1]
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(tokens))
        self.n_steps += 1
        lg = np.asarray(logits, np.float32)
        for i, slot in enumerate(self.slots):
            if slot.req is None:
                continue
            slot.pos += 1
            if slot.pending_prompt:
                continue                                # still prefilling
            tok = int(lg[i].argmax())
            slot.req.out.append(tok)
            if len(slot.req.out) >= slot.req.max_new_tokens \
                    or slot.pos >= self.max_seq - 1:
                slot.req.done = True
                slot.req = None
                self._reset_slot_cache(i)
        if self.kv is not None and self.n_steps % 16 == 0:
            self.cache, _ = self.kv.maybe_spill(self.cache)
        return len(active)

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and all(s.req is None for s in self.slots):
                break
            self.step()
