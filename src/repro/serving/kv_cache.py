"""BaM-paged KV cache management: the serving-side face of the BaM cache.

The decode caches built by the model modules hold, per global-attention
layer, a paged pool ``(B, P_phys, page, Hkv, hd)`` plus a page table
``(B, NP_logical)``.  This module adds the BaM mechanics on top:

* **spill** — evict cold pages (oldest-first = the clock policy under
  monotonic access recency) to the storage tier, leaving a hole (-1) in
  the page table; the physical page is recycled.
* **fetch** — bring spilled pages back on demand before a decode step that
  needs them (holes inside the live window), through the same
  coalesce -> allocate -> DMA path as ``BamArray``.

The pool *is* the BaM cache data array; the page table *is* the tag store;
the storage tier is an :class:`~repro.core.storage.HBMStorage` /
``SimStorage`` block store whose block key is ``(seq, layer, logical_page,
k_or_v)`` flattened.  I/O accounting reuses ``IOMetrics``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.metrics import IOMetrics
from repro.core.ssd import ArrayOfSSDs, INTEL_OPTANE_P5800X
from repro.utils import Tagged

__all__ = ["PagedKVManager", "spill_cold_pages", "fetch_holes"]


def _paged_entries(cache):
    """Yield (layer_idx, entry) for every paged layer entry in a cache."""
    for i, item in enumerate(cache["layers"]):
        tagged = item[0] if isinstance(item, tuple) else item
        if isinstance(tagged, Tagged) and tagged.kind == "paged":
            yield i, tagged


def spill_cold_pages(cache, keep_last: int, store_fn) -> Tuple[Any, int]:
    """Evict logical pages older than the last ``keep_last`` tokens.

    ``store_fn(layer, b, lpage, k_page, v_page)`` persists a page (host
    side).  Returns (cache', n_spilled).  Holes are marked -1 in the page
    table; the decode path masks holes out (local-window semantics) or
    re-fetches them via :func:`fetch_holes`.
    """
    import numpy as np
    seq_lens = np.asarray(cache["seq_lens"])
    n_spilled = 0
    new_layers = list(cache["layers"])
    for li, tagged in _paged_entries(cache):
        entry = dict(tagged.value)
        pt = np.asarray(entry["page_table"]).copy()
        page = entry["k_pages"].shape[2]
        kp = np.asarray(entry["k_pages"])
        vp = np.asarray(entry["v_pages"])
        B, NP = pt.shape
        for b in range(B):
            last_live = max(int(seq_lens[b]) - keep_last, 0) // page
            for lp in range(last_live):
                phys = pt[b, lp]
                if phys >= 0:
                    store_fn(li, b, lp, kp[b, phys], vp[b, phys])
                    pt[b, lp] = -1
                    n_spilled += 1
        entry["page_table"] = jnp.asarray(pt)
        item = cache["layers"][li]
        if isinstance(item, tuple):
            new_layers[li] = (Tagged("paged", entry),) + item[1:]
        else:
            new_layers[li] = Tagged("paged", entry)
    cache2 = dict(cache)
    cache2["layers"] = tuple(new_layers)
    return cache2, n_spilled


def fetch_holes(cache, load_fn) -> Tuple[Any, int]:
    """Re-materialise spilled pages (``load_fn(layer, b, lpage) ->
    (k_page, v_page) or None``). Returns (cache', n_fetched)."""
    import numpy as np
    n = 0
    new_layers = list(cache["layers"])
    for li, tagged in _paged_entries(cache):
        entry = dict(tagged.value)
        pt = np.asarray(entry["page_table"]).copy()
        kp = np.asarray(entry["k_pages"]).copy()
        vp = np.asarray(entry["v_pages"]).copy()
        B, NP = pt.shape
        free = [set(range(kp.shape[1])) - set(int(x) for x in pt[b]
                                              if x >= 0)
                for b in range(B)]
        for b in range(B):
            for lp in range(NP):
                if pt[b, lp] < 0:
                    got = load_fn(li, b, lp)
                    if got is None:
                        continue
                    if not free[b]:
                        continue                 # pool full: stays a hole
                    phys = free[b].pop()
                    kp[b, phys], vp[b, phys] = got
                    pt[b, lp] = phys
                    n += 1
        entry.update(page_table=jnp.asarray(pt), k_pages=jnp.asarray(kp),
                     v_pages=jnp.asarray(vp))
        item = cache["layers"][li]
        if isinstance(item, tuple):
            new_layers[li] = (Tagged("paged", entry),) + item[1:]
        else:
            new_layers[li] = Tagged("paged", entry)
    cache2 = dict(cache)
    cache2["layers"] = tuple(new_layers)
    return cache2, n


@dataclasses.dataclass
class PagedKVManager:
    """Host-side page store + spill/fetch policy around a decode cache.

    The PAPER mapping: pool pages = BaM cache lines in GPU memory; this
    host store = the NVMe tier; spill/fetch = BaM write/read I/O; the
    Little's-law cost model charges simulated device time per page moved.

    Submit/wait split (mirrors ``BamArray.submit``/``wait``): with
    ``deferred=True`` the page *moves* still happen inside
    :meth:`maybe_spill`/:meth:`ensure_resident` (correctness is
    synchronous) but the device-time charge is deferred — pending page
    counts accumulate until :meth:`drain`, which charges the whole batch
    at its batched Little's-law concurrency.  A decode round that spills
    and fetches across several layers then pays one deep-queue drain
    instead of many shallow ones, exactly the async win of the core's
    token API.  ``deferred=False`` (default) keeps per-call charging.
    """

    ssd: ArrayOfSSDs = dataclasses.field(
        default_factory=lambda: ArrayOfSSDs(INTEL_OPTANE_P5800X, 1))
    keep_last: int = 4096            # hot window kept resident
    store: dict = dataclasses.field(default_factory=dict)
    metrics: IOMetrics = dataclasses.field(
        default_factory=IOMetrics.zeros)
    page_bytes: int = 0
    deferred: bool = False           # defer device-time charge to drain()
    pending_spills: int = 0          # pages moved but not yet time-charged
    pending_fetches: int = 0

    def _store_fn(self, layer, b, lp, k_page, v_page):
        self.store[(layer, b, lp)] = (k_page.copy(), v_page.copy())
        self.page_bytes = k_page.nbytes + v_page.nbytes

    def _load_fn(self, layer, b, lp):
        return self.store.get((layer, b, lp))

    def maybe_spill(self, cache):
        cache, n = spill_cold_pages(cache, self.keep_last, self._store_fn)
        if n:
            import dataclasses as dc
            m = self.metrics
            self.metrics = dc.replace(
                m, write_ops=m.write_ops + n,
                bytes_to_storage=m.bytes_to_storage + n * self.page_bytes)
            if self.deferred:
                self.pending_spills += n
            else:
                self._charge(n_writes=n)
        return cache, n

    def ensure_resident(self, cache):
        cache, n = fetch_holes(cache, self._load_fn)
        if n:
            import dataclasses as dc
            m = self.metrics
            self.metrics = dc.replace(
                m, misses=m.misses + n,
                bytes_from_storage=m.bytes_from_storage
                + n * self.page_bytes)
            if self.deferred:
                self.pending_fetches += n
            else:
                self._charge(n_reads=n)
        return cache, n

    def drain(self) -> Tuple[int, int]:
        """Charge every deferred page move as one batched drain.

        Returns ``(n_reads, n_writes)`` retired.  A no-op when nothing is
        pending, so callers may drain unconditionally (per-round barrier).
        """
        n_r, n_w = self.pending_fetches, self.pending_spills
        self.pending_fetches = self.pending_spills = 0
        self._charge(n_reads=n_r, n_writes=n_w)
        return n_r, n_w

    def _charge(self, n_reads: int = 0, n_writes: int = 0) -> None:
        import dataclasses as dc
        m = self.metrics
        t_r = self.ssd.service_time(n_reads, max(self.page_bytes, 1)) \
            if n_reads else 0.0
        t_w = self.ssd.service_time(n_writes, max(self.page_bytes, 1),
                                    write=True) if n_writes else 0.0
        if t_r or t_w:
            self.metrics = dc.replace(
                m, sim_time_s=m.sim_time_s + t_r + t_w,
                read_time_s=m.read_time_s + t_r,
                write_time_s=m.write_time_s + t_w)
