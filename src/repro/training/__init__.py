from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                      cosine_schedule, global_norm)
from repro.training.train_loop import make_train_step, TrainState

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "make_train_step", "TrainState"]
