"""Checkpointing: atomic, keep-N, resumable, **elastic** (re-mesh restore).

Layout (one directory per step)::

    ckpt_dir/
      step_000100/
        manifest.json        # tree structure, shapes/dtypes, data position
        arr_00000.npy ...    # one file per leaf
      step_000200/ ...
      LATEST                 # atomic pointer file

Writes go to ``step_XXXX.tmp`` then ``os.replace`` (atomic on POSIX), so a
crash mid-save never corrupts the latest checkpoint — the fault-tolerance
layer restarts from ``LATEST``.  Restore takes a *target sharding tree* and
``device_put``s each leaf, so the same checkpoint restores onto any mesh
(elastic scaling: 256 -> 128 chips re-shards transparently; tested on fake
devices).  On a real multi-host deployment each host writes only the shards
it owns (addressable-shard filtering hook below); on this single-process
container every array is fully addressable so files hold full arrays.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "list_steps"]


def _leaves_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save_checkpoint(ckpt_dir, step: int, state: Any, *,
                    extra: Optional[dict] = None, keep: int = 3) -> Path:
    """Atomically write ``state`` (any pytree of arrays) for ``step``."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat, treedef = _leaves_with_paths(state)
    meta = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(state).serialize_using_proto().hex()
        if hasattr(treedef, "serialize_using_proto") else None,
        "extra": extra or {},
        "leaves": [],
    }
    for i, leaf in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"arr_{i:05d}.npy", arr)
        meta["leaves"].append({"file": f"arr_{i:05d}.npy",
                               "shape": list(arr.shape),
                               "dtype": str(arr.dtype)})
    # structure is reconstructed against a template tree at restore; the
    # manifest records leaf count for validation.
    (tmp / "manifest.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                       # atomic publish
    _write_latest(ckpt_dir, step)
    _gc(ckpt_dir, keep)
    return final


def _write_latest(ckpt_dir: Path, step: int):
    tmp = ckpt_dir / "LATEST.tmp"
    tmp.write_text(str(step))
    os.replace(tmp, ckpt_dir / "LATEST")


def _gc(ckpt_dir: Path, keep: int):
    steps = list_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)


def list_steps(ckpt_dir) -> list:
    ckpt_dir = Path(ckpt_dir)
    out = []
    if not ckpt_dir.exists():
        return out
    for p in ckpt_dir.iterdir():
        if p.is_dir() and p.name.startswith("step_") \
                and not p.name.endswith(".tmp"):
            out.append(int(p.name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    marker = ckpt_dir / "LATEST"
    if marker.exists():
        s = int(marker.read_text().strip())
        if (ckpt_dir / f"step_{s:08d}" / "manifest.json").exists():
            return s
    steps = [s for s in list_steps(ckpt_dir)
             if (ckpt_dir / f"step_{s:08d}" / "manifest.json").exists()]
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir, template: Any, *,
                       step: Optional[int] = None,
                       shardings: Any = None):
    """Restore into the structure of ``template``.

    ``shardings``: optional matching tree of NamedShardings — leaves are
    device_put with them (elastic re-mesh: any device count works).
    Returns (state, step, extra).
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    meta = json.loads((d / "manifest.json").read_text())
    flat_t, treedef = jax.tree_util.tree_flatten(template)
    if len(flat_t) != len(meta["leaves"]):
        raise ValueError(
            f"checkpoint has {len(meta['leaves'])} leaves, template has "
            f"{len(flat_t)} — structure mismatch")
    flat_sh = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(flat_t))
    out = []
    for i, (tleaf, sh) in enumerate(zip(flat_t, flat_sh)):
        arr = np.load(d / meta["leaves"][i]["file"])
        if list(arr.shape) != list(tleaf.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != template "
                             f"{tleaf.shape}")
        if sh is not None:
            out.append(jax.device_put(arr.astype(tleaf.dtype), sh))
        else:
            out.append(jax.numpy.asarray(arr.astype(tleaf.dtype)))
    state = jax.tree_util.tree_unflatten(treedef, out)
    return state, step, meta.get("extra", {})
