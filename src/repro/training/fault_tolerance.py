"""Fault tolerance: checkpoint/restart, failure injection, elastic re-mesh,
straggler mitigation.

At 1000+ nodes the design assumptions are:

* **Fail-stop restart** — any worker failure surfaces as an exception in
  the step loop (on real TPU pods, a NCCL/ICI timeout or coordinator
  heartbeat loss).  The driver restores ``LATEST`` and replays from there;
  the data pipeline is a pure function of the step index so replay is
  deterministic (skip-resume for free).
* **Elastic re-mesh** — restore accepts a different device count: the
  checkpoint stores full logical arrays; shardings are recomputed for the
  new mesh and `device_put` re-shards (tested 8 -> 4 fake devices).
* **Straggler mitigation** — (a) the data loader is bounded-latency (memmap
  reads, no network tail); (b) per-step work is shape-static so no device
  does data-dependent extra compute; (c) optional `skip_slow_shard` drops a
  slow host's microbatch by feeding the shard from the previous step
  (bounded staleness) rather than blocking the collective.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from repro.training import checkpoint as ckpt

__all__ = ["FailureInjector", "run_training", "TrainRunResult"]


class FailureInjector:
    """Deterministically raise at given step numbers (tests/drills)."""

    def __init__(self, fail_at=(), exc=RuntimeError):
        self.fail_at = set(fail_at)
        self.exc = exc
        self.tripped = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.tripped:
            self.tripped.add(step)
            raise self.exc(f"injected failure at step {step}")


@dataclasses.dataclass
class TrainRunResult:
    state: Any
    step: int
    metrics_history: list
    restarts: int


def run_training(
    train_step: Callable,            # (state, batch) -> (state, metrics)
    init_state: Callable,            # () -> state (fresh start)
    batch_for_step: Callable,        # (step) -> batch  (pure => resumable)
    n_steps: int,
    *,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    keep: int = 3,
    max_restarts: int = 3,
    failure_injector: Optional[FailureInjector] = None,
    shardings: Any = None,
    on_metrics: Optional[Callable] = None,
) -> TrainRunResult:
    """The fault-tolerant step loop: run, checkpoint, crash, restore, resume.

    Any exception from the step (device failure, injected fault) triggers a
    restore from the latest checkpoint; up to ``max_restarts`` times.
    """
    restarts = 0
    history = []

    def fresh():
        return init_state(), 0

    state, step = fresh()
    if ckpt_dir is not None and ckpt.latest_step(ckpt_dir) is not None:
        state, step, _ = ckpt.restore_checkpoint(
            ckpt_dir, state, shardings=shardings)

    while step < n_steps:
        try:
            if failure_injector is not None:
                failure_injector.maybe_fail(step)
            batch = batch_for_step(step)
            state, metrics = train_step(state, batch)
            step += 1
            history.append(jax.tree_util.tree_map(float, metrics))
            if on_metrics:
                on_metrics(step, history[-1])
            if ckpt_dir is not None and step % ckpt_every == 0:
                ckpt.save_checkpoint(ckpt_dir, step, state, keep=keep)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            if ckpt_dir is None:
                state, step = fresh()
                continue
            last = ckpt.latest_step(ckpt_dir)
            if last is None:
                state, step = fresh()
            else:
                state, step, _ = ckpt.restore_checkpoint(
                    ckpt_dir, state, shardings=shardings)
    if ckpt_dir is not None:
        ckpt.save_checkpoint(ckpt_dir, step, state, keep=keep)
    return TrainRunResult(state=state, step=step, metrics_history=history,
                          restarts=restarts)


def elastic_restore(ckpt_dir, template, make_shardings: Callable,
                    mesh) -> Any:
    """Restore a checkpoint onto a *different* mesh: shardings are computed
    for the new mesh and every leaf is re-distributed."""
    shardings = make_shardings(mesh)
    state, step, extra = ckpt.restore_checkpoint(
        ckpt_dir, template, shardings=shardings)
    return state, step, extra
