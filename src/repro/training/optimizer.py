"""AdamW (+grad clip, wd masks, schedules) and int8 error-feedback gradient
compression for the cross-pod reduction — pure JAX, no optax.

The compression is the hierarchical trick production systems use: the
intra-pod gradient reduce-scatter stays full precision (fast ICI), while
the *inter-pod* all-reduce — the slow link — carries int8 with per-tensor
scales and an error-feedback residual (so the quantisation error is
re-injected next step instead of lost; unbiased over time).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass


# --------------------------------------------------------------- schedule ---
def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


# ------------------------------------------------------------------ adamw ---
@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    total_steps: int = 10_000
    # int8 error-feedback compression of the cross-pod reduction
    pod_compression: bool = False


def _decay_mask(params):
    """No weight decay on 1-D params (norm scales, biases)."""
    return jax.tree_util.tree_map(lambda p: p.ndim >= 2, params)


def adamw_init(params, cfg: AdamWConfig):
    zeros = lambda: jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    state = {"step": jnp.zeros((), jnp.int32), "mu": zeros(), "nu": zeros()}
    if cfg.pod_compression:
        state["ef"] = zeros()      # error-feedback residual
    return state


def quantize_int8(x: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def pod_compressed_mean(grads, ef, axis: str = "pod"):
    """int8 EF all-reduce-mean over the pod axis (inside shard_map/jit with
    a mesh whose ``pod`` axis is in scope).  Returns (grads', ef')."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        # shared scale: one tiny max-reduce, then exact int32 accumulation
        local_scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        scale = jax.lax.pmax(local_scale, axis)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        # wire bytes: int8 payload (+ one f32 scale per tensor)
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
        mean = q_sum.astype(jnp.float32) * scale / n
        e2 = gf - q.astype(jnp.float32) * scale
        return mean.astype(g.dtype), e2
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    g2 = tdef.unflatten([o[0] for o in out])
    e2 = tdef.unflatten([o[1] for o in out])
    return g2, e2


def adamw_update(grads, state, params, cfg: AdamWConfig,
                 lr_fn: Optional[Callable] = None):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    lr_fn = lr_fn or cosine_schedule(cfg.lr, cfg.warmup, cfg.total_steps)
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree_util.tree_map(
        lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
    nu = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * g * g, state["nu"], grads)
    t = step.astype(jnp.float32)
    mu_hat_scale = 1.0 / (1 - b1 ** t)
    nu_hat_scale = 1.0 / (1 - b2 ** t)
    lr = lr_fn(step)
    mask = _decay_mask(params)

    def upd(p, m, v, use_wd):
        u = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + cfg.eps)
        if cfg.weight_decay:
            u = u + jnp.where(use_wd, cfg.weight_decay, 0.0) * \
                p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu, mask)
    new_state = dict(state)
    new_state.update(step=step, mu=mu, nu=nu)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
