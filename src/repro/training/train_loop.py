"""Distributed train step: value_and_grad -> (optional pod-compressed
reduction) -> AdamW, with microbatch gradient accumulation and buffer
donation.  The same builder feeds the real training driver and the dry-run
(lower/compile against ShapeDtypeStructs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed import sharding as shd
from repro.models.model import ModelApi, build_model
from repro.training import optimizer as opt

from repro import compat

TrainState = dict  # {"params": ..., "opt": ...}


def make_train_step(cfg: ArchConfig, api: Optional[ModelApi] = None, *,
                    adamw: Optional[opt.AdamWConfig] = None,
                    microbatches: int = 1,
                    mesh=None):
    """Build ``train_step(state, batch) -> (state, metrics)``.

    ``microbatches > 1`` scans over batch slices accumulating grads (the
    standard memory/throughput trade).  When the mesh has a ``pod`` axis and
    ``adamw.pod_compression`` is set, the cross-pod gradient mean goes
    through int8 error-feedback compression (see optimizer.py).
    """
    api = api or build_model(cfg)
    adamw = adamw or opt.AdamWConfig()
    lr_fn = opt.cosine_schedule(adamw.lr, adamw.warmup, adamw.total_steps)

    def loss_for_grad(params, batch):
        loss, metrics = api.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for_grad, has_aux=True)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        # split batch leaves along dim 0 into (microbatches, mb, ...)
        def resh(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape((microbatches, b // microbatches) + x.shape[1:])
        mbatch = jax.tree_util.tree_map(resh, batch)

        def mb_step(acc, mb):
            (loss, metrics), grads = grad_fn(params, mb)
            acc_g, acc_l = acc
            acc_g = jax.tree_util.tree_map(jnp.add, acc_g, grads)
            return (acc_g, acc_l + loss), metrics

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), metrics = jax.lax.scan(
            mb_step, (zeros, jnp.zeros(())), mbatch)
        grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return loss_sum / microbatches, metrics, grads

    def _plain_step(state: TrainState, batch):
        params, ostate = state["params"], state["opt"]
        loss, metrics, grads = compute_grads(params, batch)
        params, ostate, om = opt.adamw_update(grads, ostate, params, adamw,
                                              lr_fn)
        metrics = dict(metrics, loss=loss, **om)
        return {"params": params, "opt": ostate}, metrics

    mesh_ = mesh or shd.current_mesh()
    use_pod = (adamw.pod_compression and mesh_ is not None
               and "pod" in mesh_.axis_names)
    if not use_pod:
        return _plain_step

    # ---- hierarchical compressed reduction (partial-manual shard_map) ----
    # The pod axis goes manual: each pod computes grads for its own batch
    # slice (data/model stay auto -> normal ZeRO/TP sharding inside), then
    # the cross-pod mean runs through int8 error-feedback psum — the slow
    # inter-pod links carry 4x fewer bytes.
    from jax.sharding import PartitionSpec as PS

    inner_rules = dict(shd.current_rules().rules)
    inner_rules["batch"] = ("data",)
    inner_rules["host_batch"] = ("data",)

    def per_pod(state, batch):
        params, ostate = state["params"], state["opt"]
        with shd.activate(mesh_, inner_rules):
            loss, metrics, grads = compute_grads(params, batch)
        grads, ef = opt.pod_compressed_mean(grads, ostate["ef"],
                                            axis="pod")
        ostate = dict(ostate, ef=ef)
        loss = jax.lax.pmean(loss, "pod")
        metrics = jax.tree_util.tree_map(
            lambda m: jax.lax.pmean(m, "pod"), metrics)
        params, ostate, om = opt.adamw_update(grads, ostate, params, adamw,
                                              lr_fn)
        metrics = dict(metrics, loss=loss, **om)
        return {"params": params, "opt": ostate}, metrics

    def train_step(state: TrainState, batch):
        batch_specs = jax.tree_util.tree_map(lambda _: PS("pod"), batch)
        state_specs = jax.tree_util.tree_map(lambda _: PS(), state)
        return compat.shard_map(
            per_pod, mesh=mesh_,
            in_specs=(state_specs, batch_specs),
            out_specs=(state_specs, PS()),
            axis_names={"pod"}, check_vma=False,
        )(state, batch)

    return train_step


# ------------------------------------------------------- sharding helpers --
def state_shardings(cfg: ArchConfig, axes, mesh, params_shapes,
                    adamw: Optional[opt.AdamWConfig] = None):
    """NamedShardings for {"params", "opt"} given the axes tree."""
    adamw = adamw or opt.AdamWConfig()
    p_sh = shd.param_shardings(axes, mesh, shapes_tree=params_shapes)
    rep = NamedSharding(mesh, P())
    o_sh = {"step": rep, "mu": p_sh, "nu": p_sh}
    if adamw.pod_compression:
        o_sh["ef"] = p_sh
    return {"params": p_sh, "opt": o_sh}


def batch_shardings(batch_specs, mesh):
    """Shard every batch leaf's dim 0 over (pod, data)."""
    def one(spec):
        axes = ["batch"] + [None] * (len(spec.shape) - 1)
        return NamedSharding(
            mesh, shd._spec_for_shape(axes, spec.shape, mesh,
                                      shd.current_rules()))
    return jax.tree_util.tree_map(one, batch_specs)
