"""Small shared utilities: pytree dataclasses, hashing, padding helpers."""
from __future__ import annotations

import dataclasses
from typing import Any, TypeVar

import jax
import jax.numpy as jnp

T = TypeVar("T")

# Sentinel for "no key / invalid slot" throughout the BaM core.
INVALID = jnp.int32(-1)
INVALID_I32 = -1


def pytree_dataclass(cls: type | None = None, *, meta_fields: tuple[str, ...] = ()):
    """Register a dataclass as a JAX pytree.

    ``meta_fields`` are static (hashable, not traced); everything else is a
    leaf/data field.
    """

    def wrap(c):
        c = dataclasses.dataclass(frozen=True)(c)
        data_fields = tuple(
            f.name for f in dataclasses.fields(c) if f.name not in meta_fields
        )
        jax.tree_util.register_dataclass(
            c, data_fields=data_fields, meta_fields=tuple(meta_fields)
        )
        return c

    if cls is None:
        return wrap
    return wrap(cls)


def replace(obj: T, **kwargs: Any) -> T:
    return dataclasses.replace(obj, **kwargs)


def mix_hash(key: jnp.ndarray) -> jnp.ndarray:
    """Cheap integer mixing (Knuth multiplicative) for cache set hashing.

    Works on int32; deliberately avoids 64-bit so it runs with x64 disabled.
    """
    k = key.astype(jnp.uint32)
    k = (k * jnp.uint32(2654435761)) & jnp.uint32(0xFFFFFFFF)
    k = k ^ (k >> 16)
    return k.astype(jnp.int32) & jnp.int32(0x7FFFFFFF)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def pad_to(x: jnp.ndarray, n: int, fill) -> jnp.ndarray:
    """Pad axis 0 of ``x`` to length ``n`` with ``fill``."""
    if x.shape[0] == n:
        return x
    pad_width = [(0, n - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad_width, constant_values=fill)


def tree_bytes(tree: Any) -> int:
    """Total bytes of all array leaves (ShapeDtypeStructs count too)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            n = 1
            for d in leaf.shape:
                n *= int(d)
            total += n * jnp.dtype(leaf.dtype).itemsize
    return total


def fold_rng(key: jax.Array, *ints: int) -> jax.Array:
    for i in ints:
        key = jax.random.fold_in(key, i)
    return key


@pytree_dataclass(meta_fields=("kind",))
class Tagged:
    """A pytree value tagged with a *static* kind string (e.g. cache
    entries: 'ring' vs 'paged' vs 'mlstm')."""

    kind: str
    value: Any


def segment_rank(ids: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Rank of each element among same-id elements (0-based); invalid -> 0.

    The deterministic prefix-sum replacement for 'threads racing on a shared
    counter' — used by the cache's per-set clock and the MoE expert queues.
    """
    m = ids.shape[0]
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    keyed = jnp.where(valid, ids, big)
    order = jnp.argsort(keyed, stable=True)
    ss = keyed[order]
    prev = jnp.concatenate([jnp.full((1,), -2, ss.dtype), ss[:-1]])
    start = ss != prev
    pos = jnp.arange(m, dtype=jnp.int32)
    start_pos = jax.lax.cummax(jnp.where(start, pos, 0))
    rank_sorted = pos - start_pos
    return jnp.zeros((m,), jnp.int32).at[order].set(rank_sorted)
