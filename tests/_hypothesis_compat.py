"""Optional-`hypothesis` shim for the property tests.

The tier-1 suite must collect and pass from a clean checkout with nothing
but `jax`/`numpy`/`pytest` installed (install the ``[test]`` extra for the
full property-based coverage).  Import ``given``/``settings``/``st`` from
here instead of from ``hypothesis``: when hypothesis is present these are
the real thing; when it is absent, ``@given(...)`` replaces the test with
a skipped stub and the module's example-based tests still run.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    _REASON = "hypothesis not installed (pip install '.[test]')"

    class _AnyStrategy:
        """Stands in for `hypothesis.strategies`: every attribute is a
        callable returning None, so strategy expressions in decorators
        evaluate without import-time errors."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            def stub():
                pass
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return pytest.mark.skip(reason=_REASON)(stub)
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn
