# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py (its own process) forces 512.
import os

import numpy as np
import pytest

# On single-core machines XLA's async CPU dispatch can deadlock (the client
# thread pool is sized by core count, and a dependent dispatch waits on a
# worker that never frees up) — observed as a hard futex hang on the second
# execution of a compiled op.  Synchronous dispatch sidesteps it and costs
# nothing when there is no parallelism to lose.
if os.cpu_count() == 1:
    import jax

    jax.config.update("jax_cpu_enable_async_dispatch", False)

# Reproducible property tests: when hypothesis is installed, register and
# load a derandomized profile (examples derived from each test's source, no
# RNG seed dependence) so a CI failure replays identically on a dev box.
# Set HYPOTHESIS_PROFILE=dev for interactive randomized exploration.
try:
    import os

    from hypothesis import settings

    settings.register_profile("ci", derandomize=True, deadline=None,
                              print_blob=True)
    settings.register_profile("dev", deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ModuleNotFoundError:         # tier-1 runs without hypothesis
    pass


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# XLA:CPU's in-process JIT can segfault inside backend_compile once enough
# compiled executables accumulate in a single process — observed
# deterministically several hundred tests into the suite, in unrelated
# long-standing tests (and equally at the previous commit), while every
# module passes in isolation.  Dropping the global executable caches at each
# module boundary keeps the live pool bounded.  Module-scoped on purpose:
# no test ever observes a mid-module flush, so per-instance jit caches,
# trace-count probes, and steady-state retrace assertions stay valid.
@pytest.fixture(autouse=True, scope="module")
def _bounded_xla_executable_pool():
    import jax

    jax.clear_caches()
    yield
