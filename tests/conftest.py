# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py (its own process) forces 512.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
