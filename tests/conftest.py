# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py (its own process) forces 512.
import numpy as np
import pytest

# Reproducible property tests: when hypothesis is installed, register and
# load a derandomized profile (examples derived from each test's source, no
# RNG seed dependence) so a CI failure replays identically on a dev box.
# Set HYPOTHESIS_PROFILE=dev for interactive randomized exploration.
try:
    import os

    from hypothesis import settings

    settings.register_profile("ci", derandomize=True, deadline=None,
                              print_blob=True)
    settings.register_profile("dev", deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ModuleNotFoundError:         # tier-1 runs without hypothesis
    pass


@pytest.fixture
def rng():
    return np.random.default_rng(0)
