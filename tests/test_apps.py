"""Paper applications vs oracles: graph BFS/CC + taxi analytics."""
import numpy as np
import pytest

from repro.analytics import (QUERIES, make_taxi_table, run_query,
                             run_query_baseline)
from repro.graph import (BamGraph, bfs, bfs_oracle, cc, cc_oracle,
                         random_graph)


@pytest.mark.parametrize("seed,n,deg", [(0, 200, 4.0), (1, 400, 8.0),
                                        (2, 100, 2.0)])
def test_bfs_matches_oracle(seed, n, deg):
    indptr, dst = random_graph(n, deg, seed=seed)
    g = BamGraph.build(indptr, dst, cacheline_bytes=256,
                       cache_bytes=1 << 14)
    d, st = bfs(g, 0)
    np.testing.assert_array_equal(d, bfs_oracle(indptr, dst, 0))
    s = st.metrics.summary()
    assert s["misses"] > 0 and s["bytes_from_storage"] > 0


def test_bfs_fetches_only_frontier_edges():
    """On-demand semantics: BFS from an isolated vertex does no edge I/O
    beyond its own (empty) neighbor list."""
    indptr = np.asarray([0, 0, 1, 2], np.int64)   # v0 isolated; 1<->2
    dst = np.asarray([2, 1], np.int32)
    g = BamGraph.build(indptr, dst, cacheline_bytes=256,
                       cache_bytes=1 << 12)
    d, st = bfs(g, 0)
    assert d.tolist() == [0, -1, -1]
    assert float(st.metrics.misses) == 0          # zero storage reads


@pytest.mark.parametrize("seed", [0, 3])
def test_cc_matches_oracle(seed):
    indptr, dst = random_graph(300, 3.0, seed=seed)
    g = BamGraph.build(indptr, dst, cacheline_bytes=256,
                       cache_bytes=1 << 14)
    lab, _ = cc(g)
    lab0 = cc_oracle(indptr, dst)
    m = {}
    for a, b in zip(lab.tolist(), lab0.tolist()):
        assert m.setdefault(a, b) == b            # consistent partition
    assert len(set(lab.tolist())) == len(set(lab0.tolist()))


def test_taxi_queries_match_baseline_and_reduce_io():
    tbl = make_taxi_table(1 << 15, seed=1)
    amps_bam, amps_base = [], []
    for q in QUERIES:
        r, io = run_query(tbl, q)
        rb, iob = run_query_baseline(tbl, q)
        assert r["value"] == pytest.approx(rb["value"], abs=1e-3)
        amps_bam.append(io["amplification"])
        amps_base.append(iob["amplification"])
        # BaM moves strictly fewer bytes than the full-column baseline
        assert io["bytes_moved_total"] < iob["bytes_moved_total"]
    # paper Fig. 2: baseline amplification grows with dependent columns,
    # BaM's stays near 1
    assert amps_base[-1] > amps_base[0] * 2
    assert amps_bam[-1] < amps_base[-1] / 3
    assert amps_bam[0] < 1.5


def test_taxi_selectivity_planted():
    tbl = make_taxi_table(1 << 15, selectivity=5e-4, seed=0)
    frac = float((np.asarray(tbl.pickup) == 17).mean())
    assert frac == pytest.approx(5e-4, rel=0.3)
