"""BamArray: read == direct indexing; writes round-trip; I/O accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import BamArray, software_pipeline, pipelined_bam_map


def build(rng, n_blocks=32, line=8, backend="sim", **kw):
    data = rng.standard_normal((n_blocks, line)).astype(np.float32)
    kw.setdefault("num_sets", 4)
    kw.setdefault("ways", 2)
    arr, st = BamArray.build(data, block_elems=line, backend=backend, **kw)
    return data, arr, st


@given(st.lists(st.integers(-5, 255), min_size=1, max_size=64),
       st.sampled_from(["sim", "hbm"]))
@settings(max_examples=60, deadline=None)
def test_read_equals_direct(idxs, backend):
    rng = np.random.default_rng(0)
    data, arr, st2 = build(rng, backend=backend)
    flat = data.reshape(-1)
    idx = np.asarray(idxs, np.int32)
    vals, st2 = jax.jit(arr.read)(st2, jnp.asarray(idx))
    want = np.where((idx >= 0) & (idx < flat.size), flat[np.clip(idx, 0,
                    flat.size - 1)], 0.0)
    np.testing.assert_allclose(np.asarray(vals), want, rtol=1e-6)


def test_repeat_reads_hit_cache(rng):
    data, arr, st = build(rng, n_blocks=4, line=8, num_sets=4, ways=4)
    idx = jnp.arange(32, dtype=jnp.int32)
    _, st = arr.read(st, idx)
    m1 = st.metrics.summary()
    _, st = arr.read(st, idx)
    m2 = st.metrics.summary()
    assert m2["misses"] == m1["misses"]          # all lines resident
    assert m2["hits"] == m1["hits"] + 4


def test_write_read_flush_roundtrip(rng):
    data, arr, st = build(rng)
    idx = jnp.asarray([3, 77, 100], jnp.int32)
    vals = jnp.asarray([1.5, -2.0, 9.0])
    st = jax.jit(arr.write)(st, idx, vals)
    got, st = arr.read(st, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(vals))
    st = arr.flush(st)
    flat = arr.storage.data.reshape(-1)
    np.testing.assert_allclose(flat[np.asarray(idx)], np.asarray(vals))


def test_amplification_accounting(rng):
    """bytes_from_storage == misses x line bytes; amplification matches the
    analytic expectation for strided access."""
    data, arr, st = build(rng, n_blocks=64, line=8)
    # one element from every 2nd block -> amplification = line size x .. / 1
    idx = jnp.asarray(np.arange(0, 64 * 8, 16), jnp.int32)
    _, st = arr.read(st, idx)
    s = st.metrics.summary()
    assert s["misses"] == 32
    assert s["bytes_from_storage"] == 32 * 8 * 4
    assert abs(s["amplification"] - 8.0) < 1e-6   # 8 elems/line, 1 used


def test_dedup_single_fetch_per_line(rng):
    data, arr, st = build(rng)
    idx = jnp.zeros((50,), jnp.int32)            # 50 requests, same element
    _, st = arr.read(st, idx)
    assert float(st.metrics.misses) == 1.0       # warp coalescing


def test_software_pipeline_matches_sequential(rng):
    data, arr, st = build(rng, backend="hbm")
    flat = data.reshape(-1)
    idx_seq = jnp.asarray(
        rng.integers(0, flat.size, size=(4, 16)), jnp.int32)
    ys, st2 = jax.jit(lambda st, i: pipelined_bam_map(
        arr, st, i, lambda v: v.sum()))(st, idx_seq)
    want = np.asarray([flat[np.asarray(r)].sum() for r in idx_seq])
    np.testing.assert_allclose(np.asarray(ys), want, rtol=1e-5)


def test_kv_store(rng):
    from repro.core import BamKVStore
    keys = np.asarray([3, 17, 123, 99], np.int32)
    vals = rng.standard_normal((4, 8)).astype(np.float32)
    kv, table, st = BamKVStore.build(keys, vals, num_sets=4, ways=2)
    out, found, st = kv.lookup(st, table, jnp.asarray([17, 99, 5], jnp.int32))
    assert found.tolist() == [True, True, False]
    np.testing.assert_allclose(np.asarray(out[0]), vals[1], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out[1]), vals[3], rtol=1e-6)
