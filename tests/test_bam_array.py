"""BamArray: read == direct indexing; writes round-trip; I/O accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import BamArray, software_pipeline, pipelined_bam_map


def build(rng, n_blocks=32, line=8, backend="sim", **kw):
    data = rng.standard_normal((n_blocks, line)).astype(np.float32)
    kw.setdefault("num_sets", 4)
    kw.setdefault("ways", 2)
    arr, st = BamArray.build(data, block_elems=line, backend=backend, **kw)
    return data, arr, st


@given(st.lists(st.integers(-5, 255), min_size=1, max_size=64),
       st.sampled_from(["sim", "hbm"]))
@settings(max_examples=60, deadline=None)
def test_read_equals_direct(idxs, backend):
    rng = np.random.default_rng(0)
    data, arr, st2 = build(rng, backend=backend)
    flat = data.reshape(-1)
    idx = np.asarray(idxs, np.int32)
    vals, st2 = jax.jit(arr.read)(st2, jnp.asarray(idx))
    want = np.where((idx >= 0) & (idx < flat.size), flat[np.clip(idx, 0,
                    flat.size - 1)], 0.0)
    np.testing.assert_allclose(np.asarray(vals), want, rtol=1e-6)


def test_repeat_reads_hit_cache(rng):
    data, arr, st = build(rng, n_blocks=4, line=8, num_sets=4, ways=4)
    idx = jnp.arange(32, dtype=jnp.int32)
    _, st = arr.read(st, idx)
    m1 = st.metrics.summary()
    _, st = arr.read(st, idx)
    m2 = st.metrics.summary()
    assert m2["misses"] == m1["misses"]          # all lines resident
    assert m2["hits"] == m1["hits"] + 4


def test_write_read_flush_roundtrip(rng):
    data, arr, st = build(rng)
    idx = jnp.asarray([3, 77, 100], jnp.int32)
    vals = jnp.asarray([1.5, -2.0, 9.0])
    st = jax.jit(arr.write)(st, idx, vals)
    got, st = arr.read(st, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(vals))
    st = arr.flush(st)
    flat = arr.storage.data.reshape(-1)
    np.testing.assert_allclose(flat[np.asarray(idx)], np.asarray(vals))


def test_amplification_accounting(rng):
    """bytes_from_storage == misses x line bytes; amplification matches the
    analytic expectation for strided access."""
    data, arr, st = build(rng, n_blocks=64, line=8)
    # one element from every 2nd block -> amplification = line size x .. / 1
    idx = jnp.asarray(np.arange(0, 64 * 8, 16), jnp.int32)
    _, st = arr.read(st, idx)
    s = st.metrics.summary()
    assert s["misses"] == 32
    assert s["bytes_from_storage"] == 32 * 8 * 4
    assert abs(s["amplification"] - 8.0) < 1e-6   # 8 elems/line, 1 used


def test_dedup_single_fetch_per_line(rng):
    data, arr, st = build(rng)
    idx = jnp.zeros((50,), jnp.int32)            # 50 requests, same element
    _, st = arr.read(st, idx)
    assert float(st.metrics.misses) == 1.0       # warp coalescing


def test_software_pipeline_matches_sequential(rng):
    data, arr, st = build(rng, backend="hbm")
    flat = data.reshape(-1)
    idx_seq = jnp.asarray(
        rng.integers(0, flat.size, size=(4, 16)), jnp.int32)
    ys, st2 = jax.jit(lambda st, i: pipelined_bam_map(
        arr, st, i, lambda v: v.sum()))(st, idx_seq)
    want = np.asarray([flat[np.asarray(r)].sum() for r in idx_seq])
    np.testing.assert_allclose(np.asarray(ys), want, rtol=1e-5)


def test_kv_store(rng):
    from repro.core import BamKVStore
    keys = np.asarray([3, 17, 123, 99], np.int32)
    vals = rng.standard_normal((4, 8)).astype(np.float32)
    kv, table, st = BamKVStore.build(keys, vals, num_sets=4, ways=2)
    out, found, st = kv.lookup(st, table, jnp.asarray([17, 99, 5], jnp.int32))
    assert found.tolist() == [True, True, False]
    np.testing.assert_allclose(np.asarray(out[0]), vals[1], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out[1]), vals[3], rtol=1e-6)


def test_kv_store_hash_consistency_regression(rng):
    """Keys whose uint32-wrapped hash lands >= 2^31 (e.g. key 1: wrapped
    product 2654435761) used to probe different slots at build vs lookup
    (full-precision product vs wrap+int32+abs) and come back not-found."""
    from repro.core import BamKVStore
    keys = np.asarray([1, 3, 17, 123, 99], np.int32)
    vals = rng.standard_normal((5, 8)).astype(np.float32)
    kv, table, st = BamKVStore.build(keys, vals, capacity=64,
                                     num_sets=4, ways=2)
    out, found, st = kv.lookup(st, table, jnp.asarray(keys))
    assert found.tolist() == [True] * 5
    np.testing.assert_allclose(np.asarray(out), vals, rtol=1e-6)


def test_kv_store_adversarial_keys(rng):
    """High hash bits, INT32_MIN-adjacent (abs(INT32_MIN) is negative!),
    INT32_MAX: every inserted key must be found with its value."""
    from repro.core import BamKVStore
    keys = np.asarray(
        [1, 2, -2147483648, -2147483647, 2147483647, 2147483646,
         0x40000000, 715827882, -809510276, 12345], np.int32)
    vals = rng.standard_normal((len(keys), 4)).astype(np.float32)
    cap = 64
    kv, table, st = BamKVStore.build(keys, vals, capacity=cap, probes=cap,
                                     num_sets=8, ways=4)
    out, found, st = kv.lookup(st, table, jnp.asarray(keys))
    assert found.tolist() == [True] * len(keys)
    np.testing.assert_allclose(np.asarray(out), vals, rtol=1e-6)


def test_kv_store_build_rejects_keys_beyond_probe_window(rng):
    """A probe cluster longer than `probes` must fail at build time, not
    silently insert keys that lookup can never find."""
    from repro.core import BamKVStore
    cap, probes = 16, 4
    home = BamKVStore._hash_host(3, cap)
    # keys >= 0, != -1, all hashing to the same home slot
    colliders = [k for k in range(1, 20000)
                 if BamKVStore._hash_host(k, cap) == home][:probes + 1]
    assert len(colliders) == probes + 1
    vals = rng.standard_normal((len(colliders), 4)).astype(np.float32)
    with pytest.raises(ValueError, match="probes"):
        BamKVStore.build(np.asarray(colliders, np.int32), vals,
                         capacity=cap, probes=probes, num_sets=4, ways=2)
    # one fewer collider fits, and every key is findable at default reach
    kv, table, st = BamKVStore.build(
        np.asarray(colliders[:probes], np.int32), vals[:probes],
        capacity=cap, probes=probes, num_sets=4, ways=2)
    _, found, _ = kv.lookup(st, table,
                            jnp.asarray(colliders[:probes], jnp.int32))
    assert found.tolist() == [True] * probes


def test_kv_store_sentinel_key_rejected_and_never_found(rng):
    """-1 is the empty-slot sentinel: build rejects it, and looking it up
    must not 'match' an empty slot."""
    from repro.core import BamKVStore
    vals = rng.standard_normal((2, 4)).astype(np.float32)
    with pytest.raises(ValueError, match="sentinel"):
        BamKVStore.build(np.asarray([-1, 5], np.int32), vals,
                         num_sets=4, ways=2)
    kv, table, st = BamKVStore.build(np.asarray([5, 9], np.int32), vals,
                                     num_sets=4, ways=2)
    _, found, _ = kv.lookup(st, table, jnp.asarray([-1, 5], jnp.int32))
    assert found.tolist() == [False, True]


def test_kv_store_duplicate_keys_last_writer_wins(rng):
    from repro.core import BamKVStore
    keys = np.asarray([5, 9, 5], np.int32)
    vals = rng.standard_normal((3, 4)).astype(np.float32)
    kv, table, st = BamKVStore.build(keys, vals, num_sets=4, ways=2)
    out, found, st = kv.lookup(st, table, jnp.asarray([5, 9], jnp.int32))
    assert found.tolist() == [True, True]
    np.testing.assert_allclose(np.asarray(out[0]), vals[2], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out[1]), vals[1], rtol=1e-6)


@given(st.lists(st.integers(-2**31, 2**31 - 1),
                min_size=1, max_size=24, unique=True))
@settings(max_examples=60, deadline=None)
def test_kv_store_roundtrip_property(keys):
    """100% of inserted keys are found, for arbitrary int32 keys
    (probes=capacity so linear-probe clusters can't hide a key)."""
    from repro.core import BamKVStore
    rng = np.random.default_rng(0)
    keys = [k for k in keys if k != -1] or [7]   # -1 is the empty sentinel
    keys = np.asarray(keys, np.int64).astype(np.int32)
    vals = rng.standard_normal((len(keys), 4)).astype(np.float32)
    cap = max(2 * len(keys), 16)
    kv, table, st = BamKVStore.build(keys, vals, capacity=cap, probes=cap,
                                     num_sets=8, ways=4)
    out, found, st = kv.lookup(st, table, jnp.asarray(keys))
    assert bool(np.asarray(found).all())
    np.testing.assert_allclose(np.asarray(out), vals, rtol=1e-6)
