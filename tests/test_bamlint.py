"""Self-tests for the bamlint static-analysis suite.

The fixture corpus under ``tools/bamlint/fixtures`` is the contract:
every ``bad/`` file declares the single rule it must trigger in a
``# bamlint-fixture: expect BAMxxx`` header, every ``good/`` file must
come back clean, and the ``suppressed/`` file must flip between clean
and dirty with ``respect_suppressions``.  Deleting any single check
from a pass makes the matching bad-fixture test fail — that is the
point: the linter's own coverage is enforced by the repo's tier-1
suite, the same gate bamlint itself runs under in CI.
"""
import json
import pathlib
import sys

import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from tools.bamlint import ALL_RULES  # noqa: E402
from tools.bamlint.core import (  # noqa: E402
    check_file,
    load_baseline,
    run,
    write_baseline,
)

FIXTURES = REPO_ROOT / "tools" / "bamlint" / "fixtures"
BAD = sorted((FIXTURES / "bad").rglob("bam*.py"))
GOOD = sorted((FIXTURES / "good").rglob("*.py"))
SUPPRESSED = FIXTURES / "suppressed" / "suppressed_ok.py"


def _expected_rule(path: pathlib.Path) -> str:
    header = path.read_text().splitlines()[0]
    assert "bamlint-fixture: expect" in header, f"{path} missing expect header"
    return header.split("expect")[-1].strip()


@pytest.mark.parametrize("path", BAD, ids=lambda p: p.stem)
def test_bad_fixture_triggers_exactly_its_rule(path):
    expected = _expected_rule(path)
    findings = check_file(path, REPO_ROOT)
    assert findings, f"{path.name} produced no findings (expected {expected})"
    assert {f.rule for f in findings} == {expected}, [
        (f.rule, f.line) for f in findings
    ]


@pytest.mark.parametrize("path", GOOD, ids=lambda p: p.stem)
def test_good_fixture_is_clean(path):
    findings = check_file(path, REPO_ROOT)
    assert findings == [], [(f.rule, f.line, f.message) for f in findings]


def test_fixture_corpus_covers_every_rule():
    covered = {_expected_rule(p) for p in BAD}
    assert covered == set(ALL_RULES), (
        f"rules without a bad fixture: {sorted(set(ALL_RULES) - covered)}; "
        f"fixtures for unknown rules: {sorted(covered - set(ALL_RULES))}"
    )


def test_suppression_honored_and_bypassable():
    assert check_file(SUPPRESSED, REPO_ROOT) == []
    raw = check_file(SUPPRESSED, REPO_ROOT, respect_suppressions=False)
    assert {f.rule for f in raw} == {"BAM105"}


def test_bam107_not_suppressible(tmp_path):
    """An ``ignore[BAM107]`` comment is itself an unused suppression: the
    rule polices dead armor and must not be armor-able."""
    f = tmp_path / "self_shield.py"
    f.write_text("x = 1  # bamlint: ignore[BAM107]\n")
    findings = check_file(f, REPO_ROOT)
    assert [fi.rule for fi in findings] == ["BAM107"]


def test_bam107_only_fires_when_suppressions_respected():
    """Under --no-suppress nothing is consumed, so nothing is 'unused' —
    the fixture's dead comments must yield zero findings there."""
    bad = FIXTURES / "bad" / "bam107.py"
    raw = check_file(bad, REPO_ROOT, respect_suppressions=False)
    assert raw == [], [(f.rule, f.line) for f in raw]


def test_used_suppression_does_not_trip_bam107():
    """The suppressed/ corpus file consumes its ignore comments, so the
    BAM107 pass must stay silent on it (same call as the clean check)."""
    findings = check_file(SUPPRESSED, REPO_ROOT)
    assert findings == [], [(f.rule, f.line) for f in findings]


def test_baseline_round_trip(tmp_path):
    bad = FIXTURES / "bad" / "bam105.py"
    findings = check_file(bad, REPO_ROOT)
    assert findings
    baseline_path = tmp_path / "baseline.json"
    write_baseline(baseline_path, findings)

    loaded = load_baseline(baseline_path)
    assert len(loaded) == len(findings)

    # With the baseline applied the known finding is grandfathered…
    new, old, errors = run([str(bad)], REPO_ROOT, baseline_path)
    assert errors == []
    assert new == []
    assert len(old) == len(findings)
    # …and without it the same finding is new again.
    new2, old2, _ = run([str(bad)], REPO_ROOT, baseline_path=None)
    assert len(new2) == len(findings)
    assert old2 == []


def test_committed_baseline_is_well_formed():
    path = REPO_ROOT / "tools" / "bamlint" / "baseline.json"
    data = json.loads(path.read_text())
    assert data["version"] == 1
    assert isinstance(data["findings"], list)


def test_repo_is_clean_under_committed_baseline():
    baseline = REPO_ROOT / "tools" / "bamlint" / "baseline.json"
    new, _old, errors = run(
        ["src", "benchmarks", "examples"], REPO_ROOT, baseline)
    assert errors == []
    assert new == [], [f.render() for f in new]


def test_fixtures_are_excluded_from_normal_collection():
    # ``run`` over the tools tree must not lint the fixture corpus itself.
    new, _old, errors = run(["tools"], REPO_ROOT, baseline_path=None)
    assert errors == []
    fixture_hits = [f for f in new if "fixtures" in f.path]
    assert fixture_hits == []
