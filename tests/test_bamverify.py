"""Self-tests for the bamverify lowered-artifact analysis suite.

Two halves, mirroring the package: the JAX-free rule engine is pinned by
the committed golden fixtures under ``tools/bamverify/fixtures`` (each
``bad/`` artifact triggers exactly its rule, each ``good/`` one is
clean), and the live half lowers the real op family ONCE (module-scoped
fixture — it is the expensive part) and asserts the shipped executables
pass every rule, match the committed manifest, and that deliberately
broken variants (dropped donation, ragged un-bucketed submits) are
flagged.  The CLI exit-code convention (0 clean / 1 findings / 2 usage)
is regression-tested for both ``tools.bamlint`` and ``tools.bamverify``.
"""
import copy
import json
import pathlib
import subprocess
import sys
import warnings

import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from tools.bamverify import ALL_RULES  # noqa: E402
from tools.bamverify.manifest import (  # noqa: E402
    MANIFEST_PATH, diff_manifest, entry_from_stats, load_manifest,
)
from tools.bamverify.rules import (  # noqa: E402
    ArtifactSpec, check_artifact, check_executable_count, check_fixture,
)

FIXTURES = REPO_ROOT / "tools" / "bamverify" / "fixtures"
BAD = sorted(p for p in (FIXTURES / "bad").iterdir() if p.is_file())
GOOD = sorted(p for p in (FIXTURES / "good").iterdir() if p.is_file())


# ------------------------------------------------------ fixtures (JAX-free)
@pytest.mark.parametrize("path", BAD, ids=lambda p: p.stem)
def test_bad_fixture_triggers_exactly_its_rule(path):
    expected, findings = check_fixture(path)
    assert expected in ALL_RULES, f"{path.name}: bad fixture expects clean?"
    assert [f.rule for f in findings] == [expected], [
        (f.rule, f.key) for f in findings
    ]


@pytest.mark.parametrize("path", GOOD, ids=lambda p: p.stem)
def test_good_fixture_is_clean(path):
    expected, findings = check_fixture(path)
    assert expected == "clean"
    assert findings == [], [f.render() for f in findings]


def test_fixture_corpus_covers_every_rule():
    covered = {check_fixture(p)[0] for p in BAD}
    assert covered == set(ALL_RULES), (
        f"rules without a bad fixture: {sorted(set(ALL_RULES) - covered)}; "
        f"fixtures for unknown rules: {sorted(covered - set(ALL_RULES))}"
    )


def test_bam505_threshold_is_exact():
    assert check_executable_count("op", 4, 4) == []
    found = check_executable_count("op", 4, 5)
    assert [f.rule for f in found] == ["BAM505"]


# ------------------------------------------------- manifest diff (JAX-free)
def test_committed_manifest_is_well_formed():
    data = json.loads(MANIFEST_PATH.read_text())
    assert data["version"] == 1
    ops = data["ops"]
    assert ops, "manifest is empty — run python -m tools.bamverify " \
                "--update-manifest"
    for key, entry in ops.items():
        assert "@" in key, key                  # op@bucket
        for field in ("scatters", "while_loops", "donation_aliases",
                      "dtypes", "instructions"):
            assert field in entry, (key, field)


def test_manifest_mutation_detected_per_op_and_bucket():
    """The CI gate: a single mutated scatter count must surface as a
    readable per-op x bucket line, not a blob."""
    recorded = load_manifest()
    key = sorted(recorded)[0]
    current = copy.deepcopy(recorded)
    current[key]["scatters"] += 3
    drift = diff_manifest(recorded, current)
    assert len(drift) == 1
    assert drift[0].startswith(f"{key}: scatters ")

    # removed and added artifacts are reported by key, too
    gone = copy.deepcopy(recorded)
    gone.pop(key)
    assert any(key in line and "no longer lowered" in line
               for line in diff_manifest(recorded, gone))
    assert any(key in line and "missing from the manifest" in line
               for line in diff_manifest(gone, recorded))


# --------------------------------------------------- live lowering (JAX)
@pytest.fixture(scope="module")
def family():
    """Lower the whole op family once (the expensive part) and share the
    artifacts across every live test."""
    from tools.bamverify.lowering import (
        canonical_array, canonical_runtime, collect_stats, lower_op_family,
    )
    arr, st = canonical_array()
    rt, rst = canonical_runtime()
    artifacts = lower_op_family(arr, st) + lower_op_family(rt, rst)
    return {"artifacts": artifacts, "stats": collect_stats(artifacts)}


def test_shipped_artifacts_pass_every_rule(family):
    recorded = load_manifest()
    findings = []
    for spec, _txt in family["artifacts"]:
        findings.extend(check_artifact(
            spec, family["stats"][spec.key], recorded.get(spec.key)))
    assert findings == [], [f.render() for f in findings]


def test_repo_matches_committed_manifest(family):
    current = {key: entry_from_stats(s)
               for key, s in family["stats"].items()}
    drift = diff_manifest(load_manifest(), current)
    assert drift == [], drift


def test_shipped_donated_variants_are_aliased(family):
    donated = [(spec, family["stats"][spec.key])
               for spec, _ in family["artifacts"] if spec.donated]
    assert donated, "no donated variants lowered — registry regressed"
    for spec, stats in donated:
        assert stats.donation_aliases > 0, spec.key


def test_wait_claims_pure_all_hit_and_is_gated(family):
    waits = [(spec, family["stats"][spec.key])
             for spec, _ in family["artifacts"]
             if spec.pure_all_hit]
    assert any(spec.op.startswith("wait") for spec, _ in waits)
    for spec, stats in waits:
        # the callback exists in the executable but only behind the gate
        assert stats.custom_call_targets, spec.key
        assert stats.ungated_callbacks == [], spec.key


def test_bam501_flags_dropped_donation():
    """Donating an argument whose buffer XLA cannot reuse (output shape
    differs) silently drops the donation — exactly what BAM501 exists to
    catch at lowering time."""
    import jax
    import jax.numpy as jnp

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")   # "donated buffers were not usable"
        txt = (jax.jit(lambda x: jnp.concatenate([x, x]),
                       donate_argnums=(0,))
               .lower(jnp.arange(8, dtype=jnp.float32)).compile().as_text())
    spec = ArtifactSpec(op="concat", bucket=8, donated=True,
                        declared_donated=1)
    assert [f.rule for f in check_artifact(spec, txt)] == ["BAM501"]

    # and a donation that sticks is NOT flagged
    txt_ok = (jax.jit(lambda x: x + 1.0, donate_argnums=(0,))
              .lower(jnp.arange(8, dtype=jnp.float32)).compile().as_text())
    assert check_artifact(ArtifactSpec(op="inc", bucket=8, donated=True,
                                       declared_donated=1), txt_ok) == []


def test_bam502_traced_f64_caught_even_when_optimized_out():
    """An f64 intermediate that XLA's optimizer folds away (here a
    lossless f32 -> f64 -> f32 round trip) leaves no trace in the final
    executable — the pre-optimization (jaxpr/StableHLO) side of the
    artifact must still flag the creep, because the widening is live in
    source and one refactor away from being paid for real."""
    import jax
    import jax.numpy as jnp

    def leaky(x):
        return x + x.astype(jnp.float64).astype(jnp.float32)

    with jax.experimental.enable_x64():
        lowered = jax.jit(leaky).lower(
            jax.ShapeDtypeStruct((8,), jnp.float32))
        traced_f64 = "f64" in lowered.as_text()
        compiled = lowered.compile().as_text()
    assert traced_f64
    from tools.bamverify.rules import analyze_artifact
    assert "f64" not in analyze_artifact(compiled).dtypes, \
        "XLA stopped folding the round trip — pick a new dead-f64 idiom"
    spec = ArtifactSpec(op="leaky", bucket=8, traced_f64=traced_f64)
    findings = check_artifact(spec, compiled)
    assert [f.rule for f in findings] == ["BAM502"]
    # without the traced-side bit the executable alone looks clean
    assert check_artifact(ArtifactSpec(op="leaky", bucket=8),
                          compiled) == []


def test_bam505_bucketed_sweep_is_clean():
    from tools.bamverify.lowering import sweep_bucketed
    assert sweep_bucketed() == []


def test_bam505_flags_unbucketed_ragged_submits():
    """Driving submit_jit directly (no bucket padding) at more ragged
    sizes than there are buckets compiles one executable per size — the
    leak BAM505 exists to catch."""
    from repro.core.bam_array import IORequest
    from tools.bamverify.lowering import canonical_array
    import jax.numpy as jnp

    arr, st = canonical_array()
    sizes = (3, 5, 7, 11, 13)
    assert len(sizes) > len(arr.buckets)
    for n in sizes:
        idx = jnp.arange(n, dtype=jnp.int32)
        st, _tok = arr.submit_jit()(st, IORequest.read(idx, idx >= 0))
    found = check_executable_count(
        "submit", len(arr.buckets), arr.trace_counts["submit"])
    assert [f.rule for f in found] == ["BAM505"]


def test_iter_op_family_covers_the_jit_surface():
    """The registry is the verifier's ground truth: it must enumerate the
    ops, mark donatable ones, and claim purity only for wait."""
    from tools.bamverify.lowering import canonical_array, canonical_runtime

    arr, _st = canonical_array()
    entries = {e.name: e for e in arr.iter_op_family()}
    assert set(entries) == {"read", "write", "prefetch", "submit", "wait",
                            "submit_wait", "bucketed_round"}
    assert entries["submit"].donatable and entries["wait"].donatable
    assert entries["wait"].pure_all_hit
    assert not entries["submit"].pure_all_hit
    assert entries["bucketed_round"].kind == "bucketed"
    assert set(entries["bucketed_round"].trace_keys) == {"submit", "wait"}

    rt, _rst = canonical_runtime()
    rentries = {e.name: e for e in rt.iter_op_family()}
    for tenant in ("a", "b"):
        assert f"read:{tenant}" in rentries
        assert rentries[f"submit:{tenant}"].donatable
        assert rentries[f"wait:{tenant}"].pure_all_hit


# -------------------------------------------------------- CLI exit codes
def _cli(module, *argv):
    return subprocess.run(
        [sys.executable, "-m", module, *argv],
        cwd=REPO_ROOT, capture_output=True, text=True)


def test_bamlint_cli_exit_codes():
    assert _cli("tools.bamlint", "--list-rules").returncode == 0
    good = "tools/bamlint/fixtures/good"
    bad = "tools/bamlint/fixtures/bad/bam107.py"
    clean = _cli("tools.bamlint", f"{good}/hostsync_ok.py", "--no-baseline")
    assert clean.returncode == 0, clean.stdout + clean.stderr
    assert _cli("tools.bamlint", bad, "--no-baseline").returncode == 1
    r = _cli("tools.bamlint", "no/such/path")
    assert r.returncode == 2
    assert "no such path" in r.stderr


def test_bamverify_cli_usage_paths():
    assert _cli("tools.bamverify", "--list-rules").returncode == 0
    r = _cli("tools.bamverify", "no/such/path")
    assert r.returncode == 2
    assert "no such path" in r.stderr


def _patched_main(monkeypatch, family, argv):
    """Run the bamverify CLI in-process against the module-scoped
    artifacts (so exit-code tests don't pay a second full lowering)."""
    from tools.bamverify import __main__ as M
    from tools.bamverify import lowering as L
    calls = iter([family["artifacts"], []])
    monkeypatch.setattr(L, "canonical_array", lambda: (None, None))
    monkeypatch.setattr(L, "canonical_runtime", lambda: (None, None))
    monkeypatch.setattr(L, "lower_op_family",
                        lambda owner, st: next(calls))
    monkeypatch.setattr(L, "sweep_bucketed", lambda: [])
    return M.main(argv)


def test_bamverify_cli_clean_exit0(monkeypatch, family, capsys):
    assert _patched_main(monkeypatch, family, []) == 0
    assert "clean" in capsys.readouterr().out


def test_bamverify_cli_manifest_drift_exit1(monkeypatch, family, tmp_path,
                                            capsys):
    recorded = json.loads(MANIFEST_PATH.read_text())
    key = sorted(recorded["ops"])[0]
    recorded["ops"][key]["instructions"] += 1
    mutated = tmp_path / "manifest.json"
    mutated.write_text(json.dumps(recorded))
    rc = _patched_main(monkeypatch, family,
                       ["--manifest", str(mutated)])
    out = capsys.readouterr().out
    assert rc == 1
    assert f"manifest drift: {key}: instructions" in out


def test_bamverify_cli_update_manifest_round_trip(monkeypatch, family,
                                                  tmp_path, capsys):
    target = tmp_path / "manifest.json"
    rc = _patched_main(monkeypatch, family,
                       ["--update-manifest", "--manifest", str(target)])
    assert rc == 0
    written = json.loads(target.read_text())["ops"]
    assert written == {key: entry_from_stats(s)
                       for key, s in family["stats"].items()}
    assert "wrote" in capsys.readouterr().out
