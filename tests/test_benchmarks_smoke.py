"""Tier-1 smoke coverage for every benchmark module.

Each ``benchmarks/*.py`` is imported and run under ``BAM_BENCH_SMOKE=1``
(tiny problem sizes, see ``benchmarks/common.py``), so a benchmark that
crashes fails the tier-1 suite directly instead of only the separate CI
bench-smoke job.  The numbers are meaningless at smoke sizes — the
acceptance gates (device_channels, mixed_tenants) assert only at full
size in their ``__main__`` blocks.
"""
import importlib
import pathlib
import sys

import pytest

# repo root (location-independent), so `benchmarks` resolves as a package
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks.run import MODULES           # noqa: E402


def _reload_in_smoke_mode(mod_name):
    """(Re-)import benchmarks.common + the module with smoke mode on, so
    module-level ``scaled(...)`` constants pick the tiny sizes."""
    import benchmarks.common as common
    common = importlib.reload(common)
    assert common.SMOKE, "BAM_BENCH_SMOKE=1 not seen by benchmarks.common"
    full = f"benchmarks.{mod_name}"
    if full in sys.modules:
        return importlib.reload(sys.modules[full])
    return importlib.import_module(full)


@pytest.fixture()
def smoke_env(monkeypatch):
    monkeypatch.setenv("BAM_BENCH_SMOKE", "1")
    yield
    # monkeypatch restored the env; drop the smoke-size module objects so
    # nothing later in the session sees shrunken constants.
    monkeypatch.undo()
    for name in list(sys.modules):
        if name == "benchmarks.common" or name.startswith("benchmarks."):
            del sys.modules[name]


@pytest.mark.parametrize("mod_name", MODULES)
def test_benchmark_module_smokes(mod_name, smoke_env):
    mod = _reload_in_smoke_mode(mod_name)
    rows = mod.run()
    assert rows, f"{mod_name}.run() returned no rows"
    for row in rows:
        name, us, derived = row               # the run.py CSV contract
        assert isinstance(name, str) and name
        assert float(us) == float(us)         # not NaN
        assert isinstance(derived, str)


def test_module_list_covers_every_benchmark_file():
    """A new benchmarks/*.py must be registered in run.MODULES (and hence
    in this smoke matrix and docs lint)."""
    import pathlib
    root = pathlib.Path(__file__).resolve().parent.parent / "benchmarks"
    files = {p.stem for p in root.glob("*.py")} - {"run", "common"}
    assert files == set(MODULES), (
        f"benchmarks/ files {sorted(files)} != run.MODULES "
        f"{sorted(MODULES)}")
