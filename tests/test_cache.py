"""Property tests: the BaM software cache against a model-checker oracle."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import cache as C


def make(num_sets=4, ways=2, line=4):
    return C.make_cache(num_sets, ways, line)


@given(st.lists(st.lists(st.integers(0, 30), min_size=1, max_size=12),
                min_size=1, max_size=8))
@settings(max_examples=100, deadline=None)
def test_cache_invariants_over_rounds(rounds):
    """After any sequence of probe/allocate/fill rounds:
    - no duplicate tags across the whole cache,
    - probe(k) hits iff k is resident,
    - resident lines hold the lines written for them."""
    cache = make()
    resident = {}                             # key -> expected line value
    for rnd in rounds:
        keys = np.unique(np.asarray(rnd, np.int32))      # coalesced
        kj = jnp.asarray(keys)
        pr = C.probe(cache, kj)
        hit = np.asarray(pr.hit)
        # hits must be exactly the resident keys
        for i, k in enumerate(keys):
            assert hit[i] == (int(k) in resident), (k, resident)
        miss = ~hit
        cache, alloc = C.allocate(cache, kj, jnp.asarray(miss),
                                  protect_slots=pr.slot)
        ok = np.asarray(alloc.ok)
        ev = np.asarray(alloc.evicted_key)
        lines = jnp.asarray(
            np.repeat(keys.astype(np.float32)[:, None], 4, axis=1))
        cache = C.fill(cache, alloc.slot, alloc.ok, lines)
        for i, k in enumerate(keys):
            if miss[i] and ok[i]:
                if ev[i] >= 0:
                    resident.pop(int(ev[i]), None)
                resident[int(k)] = float(k)
        # invariant: tags unique
        tags = np.asarray(cache.tags).reshape(-1)
        live = tags[tags >= 0]
        assert len(set(live.tolist())) == len(live)
        # invariant: resident data correct
        for k, v in resident.items():
            pr2 = C.probe(cache, jnp.asarray([k], jnp.int32))
            assert bool(pr2.hit[0])
            row = np.asarray(cache.data)[int(pr2.slot[0])]
            assert np.all(row == v)


def test_protected_lines_never_evicted():
    cache = make(num_sets=1, ways=2, line=2)
    k = jnp.asarray([5, 9], jnp.int32)
    pr = C.probe(cache, k)
    cache, alloc = C.allocate(cache, k, ~pr.hit)
    cache = C.fill(cache, alloc.slot, alloc.ok,
                   jnp.ones((2, 2), jnp.float32))
    # both ways now full with {5, 9}; probe 5 and protect it, insert 2 keys
    pr5 = C.probe(cache, jnp.asarray([5], jnp.int32))
    assert bool(pr5.hit[0])
    newk = jnp.asarray([11, 12], jnp.int32)
    prn = C.probe(cache, newk)
    cache, alloc = C.allocate(cache, newk, ~prn.hit,
                              protect_slots=pr5.slot)
    # only one eligible way (the one holding 9) -> one insert, one bypass
    assert int(jnp.sum(alloc.ok.astype(jnp.int32))) == 1
    assert bool(C.probe(cache, jnp.asarray([5], jnp.int32)).hit[0])


def test_refcount_pins_line():
    cache = make(num_sets=1, ways=1, line=2)
    k = jnp.asarray([3], jnp.int32)
    cache, alloc = C.allocate(cache, k, jnp.asarray([True]))
    cache = C.fill(cache, alloc.slot, alloc.ok, jnp.ones((1, 2)))
    cache = C.acquire(cache, alloc.slot)
    # try to evict with a new key: the only way is pinned -> bypass
    cache, alloc2 = C.allocate(cache, jnp.asarray([7], jnp.int32),
                               jnp.asarray([True]))
    assert not bool(alloc2.ok[0])
    cache = C.release(cache, alloc.slot)
    cache, alloc3 = C.allocate(cache, jnp.asarray([7], jnp.int32),
                               jnp.asarray([True]))
    assert bool(alloc3.ok[0])


@given(st.lists(st.integers(0, 15), min_size=1, max_size=32))
@settings(max_examples=100, deadline=None)
def test_same_set_misses_get_distinct_ways(keys):
    cache = make(num_sets=2, ways=4)
    keys = np.unique(np.asarray(keys, np.int32))
    kj = jnp.asarray(keys)
    cache, alloc = C.allocate(cache, kj, jnp.ones(len(keys), bool))
    slots = np.asarray(alloc.slot)[np.asarray(alloc.ok)]
    assert len(set(slots.tolist())) == len(slots)   # no slot granted twice
