"""Property tests: the BaM software cache against a model-checker oracle."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import cache as C


def make(num_sets=4, ways=2, line=4):
    return C.make_cache(num_sets, ways, line)


@given(st.lists(st.lists(st.integers(0, 30), min_size=1, max_size=12),
                min_size=1, max_size=8))
@settings(max_examples=100, deadline=None)
def test_cache_invariants_over_rounds(rounds):
    """After any sequence of probe/allocate/fill rounds:
    - no duplicate tags across the whole cache,
    - probe(k) hits iff k is resident,
    - resident lines hold the lines written for them."""
    cache = make()
    resident = {}                             # key -> expected line value
    for rnd in rounds:
        keys = np.unique(np.asarray(rnd, np.int32))      # coalesced
        kj = jnp.asarray(keys)
        pr = C.probe(cache, kj)
        hit = np.asarray(pr.hit)
        # hits must be exactly the resident keys
        for i, k in enumerate(keys):
            assert hit[i] == (int(k) in resident), (k, resident)
        miss = ~hit
        cache, alloc = C.allocate(cache, kj, jnp.asarray(miss),
                                  protect_slots=pr.slot)
        ok = np.asarray(alloc.ok)
        ev = np.asarray(alloc.evicted_key)
        lines = jnp.asarray(
            np.repeat(keys.astype(np.float32)[:, None], 4, axis=1))
        cache = C.fill(cache, alloc.slot, alloc.ok, lines)
        for i, k in enumerate(keys):
            if miss[i] and ok[i]:
                if ev[i] >= 0:
                    resident.pop(int(ev[i]), None)
                resident[int(k)] = float(k)
        # invariant: tags unique
        tags = np.asarray(cache.tags).reshape(-1)
        live = tags[tags >= 0]
        assert len(set(live.tolist())) == len(live)
        # invariant: resident data correct
        for k, v in resident.items():
            pr2 = C.probe(cache, jnp.asarray([k], jnp.int32))
            assert bool(pr2.hit[0])
            row = np.asarray(cache.data)[int(pr2.slot[0])]
            assert np.all(row == v)


def test_protected_lines_never_evicted():
    cache = make(num_sets=1, ways=2, line=2)
    k = jnp.asarray([5, 9], jnp.int32)
    pr = C.probe(cache, k)
    cache, alloc = C.allocate(cache, k, ~pr.hit)
    cache = C.fill(cache, alloc.slot, alloc.ok,
                   jnp.ones((2, 2), jnp.float32))
    # both ways now full with {5, 9}; probe 5 and protect it, insert 2 keys
    pr5 = C.probe(cache, jnp.asarray([5], jnp.int32))
    assert bool(pr5.hit[0])
    newk = jnp.asarray([11, 12], jnp.int32)
    prn = C.probe(cache, newk)
    cache, alloc = C.allocate(cache, newk, ~prn.hit,
                              protect_slots=pr5.slot)
    # only one eligible way (the one holding 9) -> one insert, one bypass
    assert int(jnp.sum(alloc.ok.astype(jnp.int32))) == 1
    assert bool(C.probe(cache, jnp.asarray([5], jnp.int32)).hit[0])


def test_refcount_pins_line():
    cache = make(num_sets=1, ways=1, line=2)
    k = jnp.asarray([3], jnp.int32)
    cache, alloc = C.allocate(cache, k, jnp.asarray([True]))
    cache = C.fill(cache, alloc.slot, alloc.ok, jnp.ones((1, 2)))
    cache = C.acquire(cache, alloc.slot)
    # try to evict with a new key: the only way is pinned -> bypass
    cache, alloc2 = C.allocate(cache, jnp.asarray([7], jnp.int32),
                               jnp.asarray([True]))
    assert not bool(alloc2.ok[0])
    cache = C.release(cache, alloc.slot)
    cache, alloc3 = C.allocate(cache, jnp.asarray([7], jnp.int32),
                               jnp.asarray([True]))
    assert bool(alloc3.ok[0])


@given(st.lists(st.integers(0, 15), min_size=1, max_size=32))
@settings(max_examples=100, deadline=None)
def test_same_set_misses_get_distinct_ways(keys):
    cache = make(num_sets=2, ways=4)
    keys = np.unique(np.asarray(keys, np.int32))
    kj = jnp.asarray(keys)
    cache, alloc = C.allocate(cache, kj, jnp.ones(len(keys), bool))
    slots = np.asarray(alloc.slot)[np.asarray(alloc.ok)]
    assert len(set(slots.tolist())) == len(slots)   # no slot granted twice


@given(st.lists(st.tuples(st.sampled_from(["acquire", "release"]),
                          st.lists(st.integers(-1, 7), min_size=1,
                                   max_size=6)),
                min_size=1, max_size=12))
@settings(max_examples=100, deadline=None)
def test_refcount_never_negative(ops):
    """Arbitrary acquire/release interleavings (including releases of slots
    never acquired and invalid slots) keep every refcount >= 0."""
    cache = make(num_sets=2, ways=4)
    for kind, slots in ops:
        sj = jnp.asarray(slots, jnp.int32)
        cache = C.acquire(cache, sj) if kind == "acquire" \
            else C.release(cache, sj)
        rc = np.asarray(cache.refcount)
        assert (rc >= 0).all(), rc


def test_refcount_example_release_then_acquire():
    cache = make(num_sets=1, ways=2)
    cache = C.release(cache, jnp.asarray([0, 0, 1], jnp.int32))
    assert (np.asarray(cache.refcount) >= 0).all()
    cache = C.acquire(cache, jnp.asarray([0], jnp.int32))
    assert np.asarray(cache.refcount).reshape(-1)[0] == 1
    cache = C.release(cache, jnp.asarray([0, -1], jnp.int32))
    assert (np.asarray(cache.refcount) == 0).all()


def test_promote_only_clears_granted_slots():
    """promote() clears the speculative bit on exactly the slots passed
    (negative slots ignored), leaving other speculative lines pending."""
    cache = make(num_sets=1, ways=4)
    keys = jnp.asarray([1, 2, 3], jnp.int32)
    cache, alloc = C.allocate(cache, keys, jnp.ones(3, bool),
                              speculative=True)
    assert bool(alloc.ok.all())
    spec_before = np.asarray(cache.speculative).reshape(-1)
    assert spec_before[np.asarray(alloc.slot)].all()
    # promote only the middle line (and an ignored -1)
    tags_before = np.asarray(cache.tags).copy()
    cache = C.promote(cache, jnp.asarray([int(alloc.slot[1]), -1], jnp.int32))
    spec = np.asarray(cache.speculative).reshape(-1)
    slots = np.asarray(alloc.slot)
    assert not spec[slots[1]]
    assert spec[slots[0]] and spec[slots[2]]
    # tags untouched by promote
    assert np.array_equal(np.asarray(cache.tags), tags_before)


# ------------------------------------------------------------ multi-tenant --
def _fill_tenant(cache, keys, tenant, way_lo, way_hi, dirty=False):
    kj = jnp.asarray(keys, jnp.int32)
    cache, alloc = C.allocate(cache, kj, jnp.ones(len(keys), bool),
                              tenant=tenant, way_lo=way_lo, way_hi=way_hi)
    cache = C.fill(cache, alloc.slot, alloc.ok,
                   jnp.full((len(keys), cache.line_elems), float(tenant)))
    if dirty:
        cache = C.mark_dirty(cache, jnp.where(alloc.ok, alloc.slot, -1))
    return cache, alloc


def test_tenant_namespacing_same_keys_distinct_lines():
    """Block key k of tenant 0 and tenant 1 are different cache lines."""
    cache = make(num_sets=2, ways=4)
    keys = [3, 5]
    cache, a0 = _fill_tenant(cache, keys, 0, 0, 2)
    cache, a1 = _fill_tenant(cache, keys, 1, 2, 4)
    assert bool(a0.ok.all()) and bool(a1.ok.all())
    assert set(np.asarray(a0.slot).tolist()).isdisjoint(
        np.asarray(a1.slot).tolist())
    pr0 = C.probe(cache, jnp.asarray(keys, jnp.int32), tenant=0)
    pr1 = C.probe(cache, jnp.asarray(keys, jnp.int32), tenant=1)
    assert bool(pr0.hit.all()) and bool(pr1.hit.all())
    assert not np.array_equal(np.asarray(pr0.slot), np.asarray(pr1.slot))
    # and the data is each tenant's own
    assert np.all(np.asarray(cache.data)[np.asarray(pr0.slot)] == 0.0)
    assert np.all(np.asarray(cache.data)[np.asarray(pr1.slot)] == 1.0)


@given(st.lists(st.lists(st.integers(0, 40), min_size=1, max_size=12),
                min_size=1, max_size=8))
@settings(max_examples=100, deadline=None)
def test_partitioned_allocate_never_evicts_foreign_line(rounds):
    """However hard tenant 1 hammers its way window, tenant 0's resident
    lines (ways [0, 2)) survive untouched."""
    cache = make(num_sets=4, ways=4)
    keys0 = [100, 101, 102, 103]
    cache, a0 = _fill_tenant(cache, keys0, 0, 0, 2)
    resident0 = [k for k, ok in zip(keys0, np.asarray(a0.ok)) if ok]
    for rnd in rounds:
        keys = np.unique(np.asarray(rnd, np.int32))
        kj = jnp.asarray(keys)
        pr = C.probe(cache, kj, tenant=1)
        cache, alloc = C.allocate(cache, kj, ~pr.hit, protect_slots=pr.slot,
                                  tenant=1, way_lo=2, way_hi=4)
        # every granted way lies inside tenant 1's window
        ways = np.asarray(alloc.slot)[np.asarray(alloc.ok)] % cache.ways
        assert ((ways >= 2) & (ways < 4)).all(), ways
        # tenant 0's lines are all still resident
        pr0 = C.probe(cache, jnp.asarray(resident0, jnp.int32), tenant=0)
        assert bool(pr0.hit.all())
        owner = np.asarray(cache.owner)
        tags = np.asarray(cache.tags)
        assert not np.any((owner[:, :2] == 1) & (tags[:, :2] >= 0))


def test_shared_mode_never_evicts_foreign_dirty_line():
    """Free-for-all sharing may evict foreign *clean* lines but must leave
    foreign *dirty* lines alone (their write-back belongs to the owner's
    storage tier)."""
    cache = make(num_sets=1, ways=2)
    cache, a0 = _fill_tenant(cache, [7], 0, 0, 2, dirty=True)   # dirty
    cache, a1 = _fill_tenant(cache, [9], 0, 0, 2)               # clean
    assert bool(a0.ok[0]) and bool(a1.ok[0])
    # tenant 1 wants both ways; only the clean line is a legal victim
    cache, alloc = C.allocate(cache, jnp.asarray([20, 21], jnp.int32),
                              jnp.ones(2, bool), tenant=1)
    assert int(alloc.ok.astype(np.int32).sum()) == 1
    pr_dirty = C.probe(cache, jnp.asarray([7], jnp.int32), tenant=0)
    assert bool(pr_dirty.hit[0]), "foreign dirty line was evicted"
    pr_clean = C.probe(cache, jnp.asarray([9], jnp.int32), tenant=0)
    assert not bool(pr_clean.hit[0]), "clean line should have been the victim"


def test_way_window_validation():
    cache = make(num_sets=2, ways=4)
    keys = jnp.asarray([1], jnp.int32)
    ok = jnp.ones(1, bool)
    import pytest
    with pytest.raises(ValueError):
        C.allocate(cache, keys, ok, way_lo=3, way_hi=3)
    with pytest.raises(ValueError):
        C.allocate(cache, keys, ok, way_lo=0, way_hi=5)
