"""Property tests: the wavefront coalescer is an exact vectorized `unique`."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import coalesce

keys_strategy = st.lists(
    st.integers(min_value=-3, max_value=40), min_size=1, max_size=64)


@given(keys_strategy)
@settings(max_examples=200, deadline=None)
def test_coalesce_matches_unique(keys):
    keys = np.asarray(keys, np.int32)
    co = coalesce(jnp.asarray(keys))
    valid = keys >= 0
    expected = sorted(set(keys[valid].tolist()))
    n_u = int(co.num_unique)
    got = np.asarray(co.unique_keys)[:n_u].tolist()
    assert got == expected                      # exact unique set, sorted
    assert np.all(np.asarray(co.unique_keys)[n_u:] == -1)


@given(keys_strategy)
@settings(max_examples=200, deadline=None)
def test_coalesce_inverse_maps_to_own_key(keys):
    keys = np.asarray(keys, np.int32)
    co = coalesce(jnp.asarray(keys))
    uk = np.asarray(co.unique_keys)
    inv = np.asarray(co.inverse_idx)
    for i, k in enumerate(keys):
        if k >= 0:
            assert uk[inv[i]] == k              # broadcast goes to leader


@given(keys_strategy)
@settings(max_examples=200, deadline=None)
def test_coalesce_one_leader_per_line(keys):
    keys = np.asarray(keys, np.int32)
    co = coalesce(jnp.asarray(keys))
    lead = np.asarray(co.leader_mask)
    valid = keys >= 0
    # exactly one leader per distinct valid key; no invalid leaders
    assert lead[~valid].sum() == 0
    led_keys = keys[lead]
    assert len(set(led_keys.tolist())) == len(led_keys)
    assert set(led_keys.tolist()) == set(keys[valid].tolist())
