"""Per-device I/O channels: routing, back-pressure, straggler math, metrics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import BamArray, queues as Q
from repro.core.ssd import (ArrayOfSSDs, INTEL_OPTANE_P5800X,
                            device_histogram, device_of_block)


# ------------------------------------------------------------ routing ------
def test_routing_determinism():
    """Every accepted command lands in its key's device's queue group."""
    nd, gsize, depth = 4, 2, 16
    qs = Q.make_queues(nd * gsize, depth, n_devices=nd)
    keys = jnp.arange(64, dtype=jnp.int32)
    qs, rec = Q.enqueue(qs, keys)
    dev = np.asarray(device_of_block(keys, nd))
    queue = np.asarray(rec.queue)
    acc = np.asarray(rec.accepted)
    assert acc.all()
    np.testing.assert_array_equal(queue[acc] // gsize, dev[acc])
    # rings actually contain the routed keys: group g holds keys ≡ g (mod nd)
    ring = np.asarray(qs.sq_key).reshape(nd, gsize * depth)
    for d in range(nd):
        got = ring[d][ring[d] >= 0]
        assert (got % nd == d).all()


def test_routing_respects_stripe_unit():
    nd, stripe = 2, 8
    qs = Q.make_queues(4, 16, n_devices=nd, stripe_blocks=stripe)
    keys = jnp.asarray([0, 7, 8, 15, 16, 23], jnp.int32)
    qs, rec = Q.enqueue(qs, keys)
    want_dev = np.asarray([0, 0, 1, 1, 0, 0])
    np.testing.assert_array_equal(np.asarray(rec.queue) // 2, want_dev)


def test_single_device_matches_classic_round_robin():
    """n_devices=1 reduces field-for-field to the whole-pool round-robin
    (tails, ring contents, receipt) of a 1-group pool — the back-compat
    contract for every pre-channel caller."""
    keys = jnp.asarray([7, 3, 3, -1, 12, 9, 0, 5], jnp.int32)
    qs_a, rec_a = Q.enqueue(Q.make_queues(4, 8), keys)
    qs_b, rec_b = Q.enqueue(Q.make_queues(4, 8, n_devices=1,
                                          stripe_blocks=16), keys)
    np.testing.assert_array_equal(np.asarray(qs_a.sq_key),
                                  np.asarray(qs_b.sq_key))
    np.testing.assert_array_equal(np.asarray(qs_a.sq_tail),
                                  np.asarray(qs_b.sq_tail))
    np.testing.assert_array_equal(np.asarray(rec_a.queue),
                                  np.asarray(rec_b.queue))


@given(st.integers(1, 4), st.integers(2, 8),
       st.lists(st.lists(st.integers(-2, 100), min_size=1, max_size=24),
                min_size=1, max_size=6))
@settings(max_examples=60, deadline=None)
def test_no_loss_no_duplication_multi_device(nd, depth, waves):
    """The queue-conservation property holds for every device count."""
    qs = Q.make_queues(nd * 2, depth, n_devices=nd)
    submitted, accepted, serviced = 0, 0, []
    for wave in waves:
        keys = jnp.asarray(wave, jnp.int32)
        valid = keys >= 0
        submitted += int(valid.sum())
        qs, rec = Q.enqueue(qs, keys)
        accepted += int(rec.n_accepted)
        qs, comps = Q.service_all(qs)
        got = np.asarray(comps.keys)[np.asarray(comps.valid)]
        serviced.extend(got.tolist())
        assert int(Q.in_flight(qs)) == 0
        assert int(comps.count) == int(np.asarray(comps.count_dev).sum())
    assert int(qs.ticket_total) == submitted
    assert accepted == len(serviced)
    assert accepted + int(qs.dropped) == submitted
    assert int(qs.dropped) == int(np.asarray(qs.dev_dropped).sum())


# ------------------------------------------------------- back-pressure -----
def test_per_device_back_pressure_is_isolated():
    """Flooding device 0 drops only device-0 commands; device 1 flows."""
    qs = Q.make_queues(2, 2, n_devices=2)   # 1 ring x depth 2 per device
    keys = jnp.asarray([0, 2, 4, 6, 1, 3], jnp.int32)  # 4 even, 2 odd
    qs, rec = Q.enqueue(qs, keys)
    acc = np.asarray(rec.accepted)
    assert acc.tolist() == [True, True, False, False, True, True]
    np.testing.assert_array_equal(np.asarray(qs.dev_dropped), [2, 0])
    np.testing.assert_array_equal(np.asarray(Q.in_flight_per_device(qs)),
                                  [2, 2])


# ------------------------------------------------- straggler drain math ----
def test_drain_time_is_max_over_devices():
    ssd = ArrayOfSSDs(INTEL_OPTANE_P5800X, 4)
    skew = [4000, 10, 10, 10]
    t_skew, t_dev = ssd.service_time_per_device(skew, 512)
    assert t_skew == pytest.approx(max(t_dev))
    assert t_dev[0] > 10 * max(t_dev[1:])
    # a balanced split of the same total drains much faster
    t_flat, _ = ssd.service_time_per_device([1008, 1008, 1007, 1007], 512)
    assert t_flat < t_skew / 2
    # traced version agrees with the host version
    t_tr, t_tr_dev = ssd.service_time_per_device_traced(
        jnp.asarray(skew, jnp.int32), 512)
    assert float(t_tr) == pytest.approx(t_skew, rel=1e-5)
    np.testing.assert_allclose(np.asarray(t_tr_dev), t_dev, rtol=1e-5)


def test_queue_group_depth_caps_per_device_concurrency():
    ssd = ArrayOfSSDs(INTEL_OPTANE_P5800X, 2)
    uncapped, _ = ssd.service_time_per_device([10000, 10000], 512)
    capped, _ = ssd.service_time_per_device([10000, 10000], 512,
                                            queue_depth_limit=8)
    assert capped > uncapped * 5       # starved of in-flight parallelism


def test_accel_link_is_aggregate_floor():
    """Many devices can't beat the x16 ingest link."""
    ssd = ArrayOfSSDs(INTEL_OPTANE_P5800X, 16)
    n = [100_000] * 16
    t, _ = ssd.service_time_per_device(n, 4096)
    link_t = 16 * 100_000 * 4096 / ssd.accel_link_bw
    assert t >= link_t


# --------------------------------------------------- end-to-end metrics ----
def _build(nd, rng, n_blocks=64, line=8, **kw):
    data = rng.standard_normal((n_blocks, line)).astype(np.float32)
    kw.setdefault("num_sets", 4)
    kw.setdefault("ways", 2)
    arr, st = BamArray.build(
        data, block_elems=line,
        ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, nd), **kw)
    return data, arr, st


@pytest.mark.parametrize("nd", [1, 2, 4])
def test_read_correct_any_device_count(nd, rng):
    data, arr, st = _build(nd, rng)
    flat = data.reshape(-1)
    idx = rng.integers(-5, flat.size, 128).astype(np.int32)
    vals, st = jax.jit(arr.read)(st, jnp.asarray(idx))
    want = np.where(idx >= 0, flat[np.clip(idx, 0, flat.size - 1)], 0.0)
    np.testing.assert_allclose(np.asarray(vals), want, rtol=1e-6)


def test_per_device_counters_add_up(rng):
    nd = 4
    data, arr, st = _build(nd, rng)
    idx = jnp.arange(0, 64 * 8, 8, dtype=jnp.int32)   # one elem per block
    _, st = arr.read(st, idx)
    m = st.metrics
    s = m.summary()
    assert s["n_devices"] == nd
    assert sum(s["dev_reads"]) == s["misses"]
    np.testing.assert_array_equal(s["dev_reads"], [16.0] * nd)
    assert sum(s["dev_bytes"]) == s["bytes_from_storage"]
    # per-device busy time: balanced load -> no straggler
    assert s["straggler_gap"] == pytest.approx(1.0, abs=1e-3)
    assert s["read_time_s"] > 0 and s["write_time_s"] == 0
    assert s["sim_time_s"] == pytest.approx(
        s["read_time_s"] + s["write_time_s"], rel=1e-6)


def test_skewed_stream_shows_straggler(rng):
    """All traffic on one device: drain time ~= its solo drain time, and
    the other channels stay idle."""
    nd = 4
    data, arr, st = _build(nd, rng, n_blocks=64)
    # blocks 0, 4, 8, ... all stripe to device 0
    idx = jnp.asarray([b * 8 for b in range(0, 64, nd)], jnp.int32)
    _, st = arr.read(st, idx)
    s = st.metrics.summary()
    assert s["dev_reads"][0] == s["misses"] > 0
    assert s["dev_reads"][1:] == [0.0] * (nd - 1)
    assert s["dev_time_s"][0] == pytest.approx(s["read_time_s"], rel=1e-6)
    assert s["dev_time_s"][1:] == [0.0] * (nd - 1)
    assert s["straggler_gap"] == pytest.approx(nd, rel=1e-3)


def test_flush_goes_through_queue_layer(rng):
    """Shutdown write-backs ring doorbells and show up in queue depth."""
    data, arr, st = _build(1, rng)
    idx = jnp.asarray([3, 77, 100], jnp.int32)
    st = arr.write(st, idx, jnp.asarray([1.5, -2.0, 9.0]))
    m0 = st.metrics.summary()
    st = arr.flush(st)
    m1 = st.metrics.summary()
    assert m1["doorbells"] > m0["doorbells"]
    assert m1["write_ops"] == m0["write_ops"] + 3
    assert m1["write_time_s"] > m0["write_time_s"]
    assert int(st.queues.completions) > 0
    assert int(Q.in_flight(st.queues)) == 0
    # the data really hit storage
    flat = arr.storage.data.reshape(-1)
    np.testing.assert_allclose(flat[np.asarray(idx)], [1.5, -2.0, 9.0])


def test_read_iops_not_diluted_by_writebacks(rng):
    """read_iops counts fetched lines over *read* time only."""
    data, arr, st = _build(1, rng, n_blocks=64, num_sets=2, ways=2)
    # dirty many lines, then force evictions -> write-back traffic
    idx = jnp.arange(0, 64 * 8, 8, dtype=jnp.int32)
    st = arr.write(st, idx, jnp.ones((64,)))
    _, st = arr.read(st, idx)
    s = st.metrics.summary()
    assert s["write_time_s"] > 0
    fetched = s["misses"] + s["prefetch_issued"]
    assert s["read_iops"] == pytest.approx(fetched / s["read_time_s"],
                                           rel=1e-6)
    # the old (buggy) denominator would understate it
    assert s["read_iops"] > fetched / s["sim_time_s"]
