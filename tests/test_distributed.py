"""Distribution tests — run in subprocesses with 8 fake devices so the main
pytest process keeps the single real CPU device (see conftest note)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, n_dev: int = 8) -> str:
    code = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = '
        f'"--xla_force_host_platform_device_count={n_dev}"\n'
        "import sys\n"
        f'sys.path.insert(0, {os.path.join(ROOT, "src")!r})\n'
        + textwrap.dedent(body))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=ROOT, timeout=600)
    assert r.returncode == 0 and "OK" in r.stdout, \
        f"stdout={r.stdout[-800:]}\nstderr={r.stderr[-3000:]}"
    return r.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    run_sub("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.configs.base import smoke_config
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_mesh
        from repro.models.model import build_model
        from repro.training import optimizer as opt
        from repro.training.train_loop import (make_train_step,
                                               state_shardings,
                                               batch_shardings)

        cfg = smoke_config("qwen2_5_14b")
        api = build_model(cfg)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)),
                                       jnp.int32)}
        acfg = opt.AdamWConfig(lr=1e-3, warmup=1, total_steps=10)

        # single-device reference
        params, axes = api.init(jax.random.PRNGKey(0), 16)
        state0 = {"params": params, "opt": opt.adamw_init(params, acfg)}
        step = make_train_step(cfg, api, adamw=acfg)
        s1, m1 = jax.jit(step)(state0, batch)

        # sharded
        mesh = make_mesh((2, 4), ("data", "model"))
        with shd.activate(mesh, None):
            st_sh = state_shardings(cfg, axes, mesh, state0["params"], acfg)
            b_sh = batch_shardings(batch, mesh)
            step_d = jax.jit(make_train_step(cfg, api, adamw=acfg,
                                             mesh=mesh),
                             in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None))
            s2, m2 = step_d(jax.device_put(state0, st_sh),
                            jax.device_put(batch, b_sh))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, \
            (float(m1["loss"]), float(m2["loss"]))
        for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                        jax.tree_util.tree_leaves(s2["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-3, rtol=2e-3)
        print("OK")
    """)


def test_pod_compressed_reduction():
    """int8-EF cross-pod mean inside partial-manual shard_map: wire bytes
    are int8 + one scale; the mean matches the exact mean within the
    quantisation bound.

    NOTE: combining this with models containing gathers (embedding lookups)
    currently trips an XLA SPMD-partitioner CHECK (gather partitioning
    under manual subgroups) — tracked in DESIGN.md §known-issues; the
    multi-pod dry-run baseline therefore uses the standard reduction.
    """
    run_sub("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro import compat
        from repro.launch.mesh import make_mesh
        from repro.training import optimizer as opt

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        rng = np.random.default_rng(0)
        g_global = jnp.asarray(rng.standard_normal((2, 64, 32)), jnp.float32)

        def per_pod(g_slice, ef):
            grads = {"w": g_slice[0]}        # this pod's gradient
            mean, ef2 = opt.pod_compressed_mean(grads, {"w": ef},
                                                axis="pod")
            return mean["w"], ef2["w"]

        f = jax.jit(compat.shard_map(
            per_pod, mesh=mesh,
            in_specs=(P("pod"), P()), out_specs=(P(), P("pod")),
            axis_names={"pod"}, check_vma=False))
        ef0 = jnp.zeros((64, 32))
        mean, ef = f(g_global, jnp.stack([ef0, ef0]))
        mean = np.asarray(mean)
        if mean.ndim == 3:            # replicated-per-pod leading dim
            np.testing.assert_allclose(mean[0], mean[1])
            mean = mean[0]
        want = np.asarray(g_global).mean(0)
        scale = np.abs(np.asarray(g_global)).max() / 127.0
        np.testing.assert_allclose(mean, want, atol=2 * scale)
        # int8 payload on the wire: psum accumulates in s32
        txt = f.lower(g_global, jnp.stack([ef0, ef0])).compile().as_text()
        assert "s8[" in txt or "s32[" in txt, "quantized collective missing"
        # error feedback: residual carries the quantisation error
        assert float(jnp.abs(ef).max()) <= scale / 2 + 1e-6
        print("OK")
    """)


def test_elastic_restore_different_mesh(tmp_path):
    run_sub(f"""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.configs.base import smoke_config
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_mesh
        from repro.models.model import build_model
        from repro.training import checkpoint as ckpt

        cfg = smoke_config("gemma3_1b")
        api = build_model(cfg)
        params, axes = api.init(jax.random.PRNGKey(0), 16)

        mesh8 = make_mesh((4, 2), ("data", "model"))
        with shd.activate(mesh8, None):
            sh8 = shd.param_shardings(axes, mesh8, shapes_tree=params)
            p8 = jax.device_put(params, sh8)
        ckpt.save_checkpoint({str(tmp_path)!r}, 3, p8)

        # restore onto a 4-device mesh (elastic shrink)
        mesh4 = make_mesh((2, 2), ("data", "model"))
        with shd.activate(mesh4, None):
            sh4 = shd.param_shardings(axes, mesh4, shapes_tree=params)
            p4, step, _ = ckpt.restore_checkpoint({str(tmp_path)!r}, params,
                                                  shardings=sh4)
        assert step == 3
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p4)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("OK")
    """)


def test_constrain_drops_non_divisible_axes():
    run_sub("""
        import jax, jax.numpy as jnp
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2, 4), ("data", "model"))
        with shd.activate(mesh, None):
            @jax.jit
            def f(x):
                # 25 heads over a 4-way model axis: must silently skip
                return shd.constrain(x, ("batch", "act_heads", None))
            y = f(jnp.ones((4, 25, 8)))
            assert y.shape == (4, 25, 8)
        print("OK")
    """)


def test_mesh_shapes():
    run_sub("""
        from repro.launch.mesh import make_production_mesh
        m = make_production_mesh()
        assert m.shape == {"data": 16, "model": 16}, m.shape
        m2 = make_production_mesh(multi_pod=True)
        assert m2.shape == {"pod": 2, "data": 16, "model": 16}
        print("OK")
    """, n_dev=512)


def test_sharded_flash_decode_matches_ref():
    """`cfg.flash_decode_shards` (shard-local flash-decoding over the
    striped KV pool) is value-identical to the reference paged attention."""
    run_sub("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.kernels import ref
        from repro.models.transformer import _paged_attention_flash_decode
        from repro.configs.base import smoke_config

        mesh = make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        B, Hq, Hkv, D, Pp, page, NP = 2, 4, 2, 16, 8, 8, 6
        q = jnp.asarray(rng.standard_normal((B, Hq, D)), jnp.float32)
        kp = jnp.asarray(rng.standard_normal((B, Pp, page, Hkv, D)),
                         jnp.float32)
        vp = jnp.asarray(rng.standard_normal((B, Pp, page, Hkv, D)),
                         jnp.float32)
        pt = jnp.stack([jnp.asarray(rng.permutation(Pp)[:NP], jnp.int32)
                        for _ in range(B)])
        sl = jnp.asarray([37, 44], jnp.int32)
        cfg = smoke_config("gemma3_12b")
        with mesh:
            o1 = jax.jit(lambda *a: _paged_attention_flash_decode(
                cfg, *a, mesh))(q, kp, vp, pt, sl)
        o2 = ref.paged_attention_ref(q, kp, vp, pt, sl)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=3e-5, rtol=3e-5)
        print("OK")
    """)


def test_gpipe_pipeline_parallel_matches_sequential():
    """GPipe over the pod axis == running the stages sequentially."""
    run_sub("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.distributed.pipeline_parallel import gpipe

        P_STAGES, M, B, D = 4, 8, 16, 32
        mesh = make_mesh((P_STAGES, 2), ("pod", "data"))
        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.standard_normal((P_STAGES, D, D)) / np.sqrt(D),
                         jnp.float32)
        x = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)

        def stage(w, xb):
            return jax.nn.tanh(xb @ w)

        pipe = gpipe(lambda p, xb: stage(p, xb), P_STAGES, M, mesh=mesh)
        y = jax.jit(lambda w, x: pipe(w, x))(ws, x)

        ref = x
        for s in range(P_STAGES):
            ref = stage(ws[s], ref)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)
        print("OK")
    """)
