"""Tier-1 smoke coverage for every example script.

Each ``examples/*.py`` runs in-process (``runpy``) with tiny CLI sizes, so
a broken example fails the suite directly — before this file, examples had
zero coverage (benchmarks are covered by ``test_benchmarks_smoke.py``).
A new ``examples/*.py`` must be registered in ``SMOKE_ARGS`` (the
completeness test at the bottom enforces it) with arguments small enough
to finish in seconds.
"""
import pathlib
import runpy
import sys

import pytest

EXAMPLES_DIR = pathlib.Path(__file__).resolve().parent.parent / "examples"

# script stem -> smoke-size argv (keep each under ~a minute on CPU)
SMOKE_ARGS = {
    "quickstart": ["--elems", "65536", "--wavefront", "512",
                   "--window", "2"],
    "graph_analytics": ["--nodes", "300", "--avg-deg", "4", "--ssds", "2"],
    "taxi_analytics": ["--rows", "4096", "--scan-window", "2"],
    "serve_lm": ["--requests", "2", "--slots", "2", "--max-seq", "64",
                 "--new-tokens", "4", "--hot-window", "16"],
    "train_lm": ["--steps", "3", "--seq", "32", "--batch", "2"],
    "fault_tolerant_io": ["--elems", "65536", "--steps", "12",
                          "--wavefront", "256", "--error-rate", "0.004"],
}


@pytest.mark.parametrize("name", sorted(SMOKE_ARGS))
def test_example_smokes(name, monkeypatch, capsys, tmp_path):
    path = EXAMPLES_DIR / f"{name}.py"
    argv = [str(path)] + SMOKE_ARGS[name]
    if name in ("train_lm", "fault_tolerant_io"):
        argv += ["--workdir", str(tmp_path / f"{name}_demo")]
    monkeypatch.setattr(sys, "argv", argv)
    runpy.run_path(str(path), run_name="__main__")
    out = capsys.readouterr().out
    assert out.strip(), f"{name} produced no output"


def test_smoke_args_cover_every_example():
    files = {p.stem for p in EXAMPLES_DIR.glob("*.py")}
    assert files == set(SMOKE_ARGS), (
        f"examples/ files {sorted(files)} != SMOKE_ARGS "
        f"{sorted(SMOKE_ARGS)}")
