"""Fault-injection coverage: the degradation contract under injected errors.

A :class:`~repro.core.ssd.FaultModel` makes commands fail deterministically
(counter-based hash of ``(device, ticket, attempt)``).  The contract this
file pins down:

* values **never** degrade silently — an errored read lane returns 0 *and*
  its lane is flagged in the ``error_mask`` from ``wait_ex``; an errored
  write lane withholds its payload (storage keeps the old bytes);
* a cache line is never filled from a failed fetch — the line is
  invalidated, and cache bookkeeping (pins, inflight bits, refcounts)
  returns to zero after every token is waited even when *every* command
  fails (``rate=1.0``);
* per-lane ``dropped_mask`` on the token flags ring back-pressure drops at
  submit time (satellite of the same robustness PR);
* per-tenant error counters sum exactly to the global ones through the
  shared :class:`~repro.core.BamRuntime`;
* the fused and legacy drain paths agree bit-for-bit under faults, the
  schedule is a pure function of the seed, and a *disabled* model (rate 0,
  no failed devices) is bit-identical to the default fault-free build.

Same engine as ``test_oracle.py``: hypothesis drives the search when
installed; fixed-seed examples keep the coverage in the tier-1 suite.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import BamArray, BamRuntime, IORequest, TenantSpec
from repro.core.ssd import ArrayOfSSDs, FaultModel, INTEL_OPTANE_P5800X

AOPS = ("read", "write", "flush")


def _tree_equal(a, b, where=""):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), f"{where}: leaf count {len(la)} != {len(lb)}"
    for i, (x, y) in enumerate(zip(la, lb)):
        xa, ya = np.asarray(jax.device_get(x)), np.asarray(jax.device_get(y))
        assert xa.shape == ya.shape and xa.dtype == ya.dtype, \
            f"{where}: leaf {i} {xa.shape}/{xa.dtype} vs {ya.shape}/{ya.dtype}"
        assert np.array_equal(xa, ya), f"{where}: leaf {i} differs"


def _cache_quiescent(cache):
    assert not bool(np.asarray(cache.refcount).any()), "pins leaked"
    assert not bool(np.asarray(cache.inflight).any()), "inflight bit leaked"


def run_fault_ops(num_sets, ways, block_elems, n_devices, queue_depth,
                  seed, op_kinds, *, rate=0.3, retry_budget=1,
                  fault_seed=0):
    """Random op sequence vs the numpy oracle, with injected faults.

    The oracle is maintained through the per-lane ``error_mask`` that
    ``wait_ex`` reports: an errored read lane must be exactly 0, an
    errored write lane must *not* land in the oracle (nor, transitively,
    in storage after flush).  Every surviving lane must be exact.
    """
    rng = np.random.default_rng(seed)
    size = int(rng.integers(block_elems, 6 * block_elems * max(num_sets, 1)))
    data = rng.standard_normal(size).astype(np.float32)
    oracle = data.copy()
    fault = FaultModel(transient_error_rate=rate, tail_latency_mult=2.0,
                       retry_budget=retry_budget, seed=fault_seed)
    arr, st_ = BamArray.build(
        data, block_elems=block_elems, num_sets=num_sets, ways=ways,
        num_queues=2 * n_devices, queue_depth=queue_depth,
        ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, n_devices, fault=fault))

    def check_storage():
        flat = np.asarray(arr.storage.data).reshape(-1)[:size]
        np.testing.assert_array_equal(flat, oracle)

    for kind in op_kinds:
        m = int(rng.integers(1, 25))
        idx = rng.integers(-2, size + 3, m).astype(np.int32)
        valid = (idx >= 0) & (idx < size)
        if kind == "read":
            st_, tok = arr.submit(st_, IORequest.read(jnp.asarray(idx)))
            st_, vals, err = arr.wait_ex(st_, tok)
            err = np.asarray(err)
            vals = np.asarray(vals)
            assert not err[~valid].any(), "error_mask set on invalid lanes"
            expect = np.where(valid, oracle[np.clip(idx, 0, size - 1)], 0.0)
            ok = valid & ~err
            np.testing.assert_array_equal(vals[ok], expect[ok])
            np.testing.assert_array_equal(vals[err], 0.0)
        elif kind == "write":
            uidx = np.unique(idx)
            wvals = rng.standard_normal(len(uidx)).astype(np.float32)
            st_, tok = arr.submit(
                st_, IORequest.write(jnp.asarray(uidx), jnp.asarray(wvals)))
            st_, _, err = arr.wait_ex(st_, tok)
            err = np.asarray(err)
            landed = (uidx >= 0) & (uidx < size) & ~err
            oracle[uidx[landed]] = wvals[landed]
        elif kind == "flush":
            st_ = arr.flush(st_)
            assert not bool(st_.cache.dirty.any()), "flush left dirty lines"
            check_storage()

    # closing barrier: everything persists (flush's host write-back keeps
    # every surviving dirty line), pins and inflight bits are all released.
    st_ = arr.flush(st_)
    assert not bool(st_.cache.dirty.any())
    check_storage()
    _cache_quiescent(st_.cache)
    # queue conservation survives faults: every accepted command drains.
    np.testing.assert_array_equal(np.asarray(st_.queues.dev_enqueued),
                                  np.asarray(st_.queues.dev_completed))
    mt = st_.metrics
    assert int(mt.failed_commands) == int(np.asarray(mt.dev_errors).sum())


@given(st.integers(1, 8),                   # num_sets
       st.integers(1, 4),                   # ways
       st.sampled_from([2, 4, 8]),          # block_elems
       st.integers(1, 2),                   # n_devices
       st.sampled_from([2, 8, 64]),         # queue_depth (2 forces drops)
       st.integers(0, 2 ** 31 - 1),         # data / wavefront seed
       st.lists(st.sampled_from(AOPS), min_size=1, max_size=8),
       st.sampled_from([0.05, 0.3, 1.0]),   # transient error rate
       st.integers(0, 3))                   # retry budget
@settings(max_examples=25, deadline=None)
def test_faulty_bam_array_matches_numpy_oracle(num_sets, ways, block_elems,
                                               n_devices, queue_depth, seed,
                                               op_kinds, rate, budget):
    run_fault_ops(num_sets, ways, block_elems, n_devices, queue_depth,
                  seed, op_kinds, rate=rate, retry_budget=budget,
                  fault_seed=seed & 0xFFFF)


_EXAMPLES = [
    # (num_sets, ways, block_elems, n_devices, depth, seed, ops,
    #  rate, retry_budget)
    (4, 2, 4, 1, 64, 0, ["read", "write", "read", "flush", "read"],
     0.3, 1),
    (1, 1, 2, 1, 2, 1, ["write", "read", "write", "flush", "read"],
     0.5, 0),
    (8, 4, 8, 2, 8, 2, ["read", "write", "read", "write", "flush"],
     0.05, 3),
    (2, 3, 4, 2, 4, 3, ["write", "flush", "write", "read", "flush",
                        "read", "read"], 1.0, 1),
    (5, 2, 2, 1, 8, 4, ["read"] * 3 + ["write"] * 2 + ["flush", "read"],
     0.3, 2),
]


@pytest.mark.parametrize("case", _EXAMPLES,
                         ids=[f"seed{c[5]}_rate{c[7]}" for c in _EXAMPLES])
def test_fault_oracle_examples(case):
    (num_sets, ways, block_elems, n_devices, depth, seed, ops,
     rate, budget) = case
    run_fault_ops(num_sets, ways, block_elems, n_devices, depth, seed, ops,
                  rate=rate, retry_budget=budget, fault_seed=seed)


# =========================================== total failure: rate = 1.0
def test_every_command_fails_leaves_state_clean():
    """rate=1.0, budget=0: every fetch errors.  No line may be filled, no
    pin or inflight bit may leak, every read lane is (0, error), and
    storage is untouched by the withheld writes."""
    rng = np.random.default_rng(11)
    data = rng.standard_normal(512).astype(np.float32)
    fault = FaultModel(transient_error_rate=1.0, retry_budget=0)
    arr, st_ = BamArray.build(
        data, block_elems=8, num_sets=8, ways=2, num_queues=4,
        queue_depth=64, ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, 2, fault=fault))

    idx = jnp.asarray(rng.integers(0, 512, 48), jnp.int32)
    st_, t1 = arr.submit(st_, IORequest.read(idx))
    widx = np.unique(rng.integers(0, 512, 20)).astype(np.int32)
    st_, t2 = arr.submit(st_, IORequest.write(
        jnp.asarray(widx), jnp.zeros((len(widx),), jnp.float32)))
    st_, vals, err1 = arr.wait_ex(st_, t1)
    st_, _, err2 = arr.wait_ex(st_, t2)

    np.testing.assert_array_equal(np.asarray(vals), 0.0)
    assert bool(np.asarray(err1).all()), \
        "rate=1.0 must error every valid read lane"
    # no fill ever happened: every tag is still invalid, nothing dirty
    assert bool((np.asarray(st_.cache.tags) == -1).all())
    assert not bool(np.asarray(st_.cache.dirty).any())
    _cache_quiescent(st_.cache)
    st_ = arr.flush(st_)
    np.testing.assert_array_equal(
        np.asarray(arr.storage.data).reshape(-1)[:512], data)
    mt = st_.metrics
    assert int(mt.failed_commands) > 0
    assert int(mt.degraded_reads) == int(np.asarray(err1).sum()) \
        + int(np.asarray(err2).sum())
    np.testing.assert_array_equal(np.asarray(st_.queues.dev_enqueued),
                                  np.asarray(st_.queues.dev_completed))


def test_retry_budget_recovers_transients():
    """A generous retry budget turns a moderate transient rate into zero
    failed commands — and charges the backoff as extra device time."""
    rng = np.random.default_rng(5)
    data = rng.standard_normal(1024).astype(np.float32)
    idx = jnp.asarray(rng.integers(0, 1024, 64), jnp.int32)

    def run(fault):
        arr, st_ = BamArray.build(
            data, block_elems=8, num_sets=16, ways=2, num_queues=4,
            queue_depth=128,
            ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, 2, fault=fault))
        vals, st_ = arr.read(st_, idx)
        return np.asarray(vals), st_.metrics

    clean_vals, clean_mt = run(FaultModel())
    vals, mt = run(FaultModel(transient_error_rate=0.25, retry_budget=8,
                              tail_latency_mult=4.0, seed=3))
    np.testing.assert_array_equal(vals, clean_vals)
    assert int(mt.failed_commands) == 0
    assert int(mt.degraded_reads) == 0
    assert int(mt.retries) > 0
    assert int(mt.transient_errors) >= int(mt.retries)
    assert float(mt.sim_time_s) > float(clean_mt.sim_time_s), \
        "retry backoff must charge extra device service time"


# ====================================================== dropped_mask
def test_dropped_mask_flags_ring_drops():
    """Satellite: per-lane drop visibility at submit.  A depth-2 ring pool
    cannot hold a 40-miss wavefront; the overflow lanes are flagged on the
    token and still served read-through at wait (exact values)."""
    rng = np.random.default_rng(9)
    data = rng.standard_normal(4096).astype(np.float32)
    arr, st_ = BamArray.build(
        data, block_elems=4, num_sets=64, ways=4, num_queues=2,
        queue_depth=2, ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, 1))
    # 40 distinct lines >> 2*2 ring slots
    idx = jnp.asarray(np.arange(40) * 4, jnp.int32)
    st_, tok = arr.submit(st_, IORequest.read(idx))
    dropped = np.asarray(tok.dropped_mask)
    assert dropped.any(), "depth-2 rings must drop most of a 40-line burst"
    assert not dropped.all(), "the first ring slots must accept"
    st_, vals, err = arr.wait_ex(st_, tok)
    np.testing.assert_array_equal(np.asarray(vals), data[np.asarray(idx)])
    assert not np.asarray(err).any(), \
        "fault model disabled: dropped lanes are read-through, not errors"

    # deep rings: nothing dropped
    arr2, st2 = BamArray.build(
        data, block_elems=4, num_sets=64, ways=4, num_queues=2,
        queue_depth=1024, ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, 1))
    st2, tok2 = arr2.submit(st2, IORequest.read(idx))
    assert not np.asarray(tok2.dropped_mask).any()
    # ticket stamps: accepted rows get distinct per-device ordinals
    tick = np.asarray(tok2.ticket)
    acc = tick[tick >= 0]
    assert len(np.unique(acc)) == len(acc)


# ==================================== dead device: remap + zero errors
def test_hard_failed_device_remaps_cleanly():
    rng = np.random.default_rng(21)
    data = rng.standard_normal(2048).astype(np.float32)
    fault = FaultModel(failed_devices=(1,))
    arr, st_ = BamArray.build(
        data, block_elems=8, num_sets=32, ways=2, num_queues=4,
        queue_depth=256,
        ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, 2, fault=fault))
    idx = jnp.asarray(rng.integers(0, 2048, 96), jnp.int32)
    st_, tok = arr.submit(st_, IORequest.read(idx))
    st_, vals, err = arr.wait_ex(st_, tok)
    np.testing.assert_array_equal(np.asarray(vals),
                                  data[np.asarray(idx)])
    assert not np.asarray(err).any(), \
        "remapped traffic must not error"
    mt = st_.metrics
    assert int(mt.failed_commands) == 0
    assert int(np.asarray(mt.dev_reads)[1]) == 0, \
        "no command may reach the hard-failed device"
    assert int(np.asarray(mt.dev_reads)[0]) > 0


# ===================================== fused == legacy under faults
def test_fused_legacy_parity_under_fault():
    rng = np.random.default_rng(17)
    data = rng.standard_normal(4096).astype(np.float32)
    fault = FaultModel(transient_error_rate=0.3, retry_budget=1,
                       tail_latency_mult=2.0, seed=7)
    kw = dict(block_elems=16, num_sets=16, ways=4, num_queues=4,
              queue_depth=64,
              ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, 2, fault=fault))
    arr, st_f = BamArray.build(data, **kw)
    _, st_l = BamArray.build(data, **kw)
    leg = dataclasses.replace(arr, fused_rounds=False,
                              _jit_ops={}, _trace_counts={})

    for rnd in range(4):
        idx = jnp.asarray(rng.integers(0, 4096, 40), jnp.int32)
        st_f, tf = arr.submit(st_f, IORequest.read(idx))
        st_l, tl = leg.submit(st_l, IORequest.read(idx))
        _tree_equal(tf.ticket, tl.ticket, f"round {rnd} ticket")
        _tree_equal(tf.dropped_mask, tl.dropped_mask,
                    f"round {rnd} dropped_mask")
        st_f, vf, ef = arr.wait_ex(st_f, tf)
        st_l, vl, el = leg.wait_ex(st_l, tl)
        _tree_equal(vf, vl, f"round {rnd} values")
        _tree_equal(ef, el, f"round {rnd} error_mask")
        _tree_equal(st_f.metrics, st_l.metrics, f"round {rnd} metrics")
    _tree_equal(st_f.cache, st_l.cache, "final CacheState")


# ================================ determinism + disabled bit-identity
def _one_faulty_round(fault, seed=23):
    rng = np.random.default_rng(seed)
    data = rng.standard_normal(2048).astype(np.float32)
    arr, st_ = BamArray.build(
        data, block_elems=8, num_sets=16, ways=2, num_queues=4,
        queue_depth=32,
        ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, 2, fault=fault))
    idx = jnp.asarray(rng.integers(0, 2048, 64), jnp.int32)
    st_, tok = arr.submit(st_, IORequest.read(idx))
    st_, vals, err = arr.wait_ex(st_, tok)
    uw = np.unique(rng.integers(0, 2048, 32)).astype(np.int32)
    st_, tw = arr.submit(st_, IORequest.write(
        jnp.asarray(uw), jnp.asarray(rng.standard_normal(len(uw)),
                                     dtype=jnp.float32)))
    st_, _, errw = arr.wait_ex(st_, tw)
    st_ = arr.flush(st_)
    return vals, err, errw, st_


def test_fault_schedule_is_deterministic():
    f = FaultModel(transient_error_rate=0.4, retry_budget=1, seed=42)
    a = _one_faulty_round(f)
    b = _one_faulty_round(f)
    _tree_equal(a[0], b[0], "values")
    _tree_equal(a[1], b[1], "read error_mask")
    _tree_equal(a[2], b[2], "write error_mask")
    _tree_equal(a[3].metrics, b[3].metrics, "metrics")
    # a different seed gives an independent schedule
    c = _one_faulty_round(dataclasses.replace(f, seed=43))
    assert not np.array_equal(np.asarray(a[1]), np.asarray(c[1])) or \
        not np.array_equal(np.asarray(a[2]), np.asarray(c[2])), \
        "different fault seeds produced identical error masks"


def test_disabled_fault_model_is_bit_identical():
    """threshold=0 and no failed devices => `enabled` is False and the
    whole round — values, metrics, cache, queues — is bit-identical to the
    default build, even with non-default latency/seed knobs set."""
    a = _one_faulty_round(FaultModel())
    b = _one_faulty_round(FaultModel(transient_error_rate=0.0,
                                     tail_latency_mult=8.0, seed=99,
                                     retry_budget=7))
    _tree_equal(a[0], b[0], "values")
    assert not np.asarray(a[1]).any() and not np.asarray(b[1]).any()
    _tree_equal(a[3].metrics, b[3].metrics, "metrics")
    _tree_equal(a[3].cache, b[3].cache, "cache")
    _tree_equal(a[3].queues, b[3].queues, "queues")


# =========================== runtime: per-tenant error conservation
@pytest.mark.parametrize("drain", ["per_op", "deferred"])
def test_runtime_tenant_error_counters_sum_exactly(drain):
    rng = np.random.default_rng(31)
    da = rng.standard_normal(1024).astype(np.float32)
    db = rng.standard_normal(1024).astype(np.float32)
    fault = FaultModel(transient_error_rate=0.35, retry_budget=1, seed=13)
    rt, rst = BamRuntime.build(
        [TenantSpec("a", da, block_elems=8, weight=1.0),
         TenantSpec("b", db, block_elems=8, weight=2.0)],
        num_sets=16, ways=4, num_queues=4, queue_depth=64,
        ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, 2, fault=fault),
        drain=drain)

    seen = {"a": 0, "b": 0}
    toks = []
    for rnd in range(3):
        for name, base in (("a", da), ("b", db)):
            idx = jnp.asarray(rng.integers(0, 1024, 32), jnp.int32)
            rst, tok = rt.submit(rst, name, IORequest.read(idx))
            toks.append((name, base, idx, tok))
        while toks:
            name, base, idx, tok = toks.pop()
            rst, vals, err = rt.wait_ex(rst, name, tok)
            err = np.asarray(err)
            seen[name] += int(err.sum())
            ok = ~err
            np.testing.assert_array_equal(np.asarray(vals)[ok],
                                          base[np.asarray(idx)][ok])
            np.testing.assert_array_equal(np.asarray(vals)[err], 0.0)
        if drain == "deferred":
            rst, _ = rt.drain(rst)

    # the tentpole invariant, now including the fault counters
    rt.assert_metrics_consistent(rst)
    for name in ("a", "b"):
        mt = rt.tenant_view(rst, name).metrics
        assert int(mt.degraded_reads) == seen[name], \
            f"tenant {name}: degraded_reads != observed errored lanes"
    glob = rst.metrics
    assert int(glob.degraded_reads) == seen["a"] + seen["b"]
    _cache_quiescent(rst.cache)
