"""Differential tests for the fused traced I/O rounds (PR 8).

The fused submit/wait path (``BamArray.fused_rounds=True``, the default)
routes the whole round through the kernel dispatch layer: multi-segment
SQ enqueue as one fused pass, ring drain as closed-form accounting,
cache bookkeeping as single-pass rebuilds, and a ``lax.cond``-gated
fetch DMA.  Everything here pins it **bit-identical** to the legacy
step-by-step path — values, ``IOMetrics``, and the full ``CacheState``/
``QueueState`` — plus the satellite bugfixes that ride along: the
double-wait guard, the token-watermark re-check, zero-length
short-circuits, and the bucketed-wavefront retrace bounds.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bam_array as B
from repro.core import queues as Q
from repro.core.bam_array import (
    BamArray, BamRuntime, IORequest, PrefetchConfig, TenantSpec,
)
from repro.core.ssd import ArrayOfSSDs, INTEL_OPTANE_P5800X


# ------------------------------------------------------------------ helpers
def _tree_equal(a, b, where=""):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), f"{where}: leaf count {len(la)} != {len(lb)}"
    for i, (x, y) in enumerate(zip(la, lb)):
        xa, ya = np.asarray(jax.device_get(x)), np.asarray(jax.device_get(y))
        assert xa.dtype == ya.dtype and xa.shape == ya.shape, \
            f"{where}: leaf {i} shape/dtype {xa.shape}/{xa.dtype} vs " \
            f"{ya.shape}/{ya.dtype}"
        assert np.array_equal(xa, ya), \
            f"{where}: leaf {i} differs (max abs " \
            f"{np.abs(xa.astype(np.float64) - ya.astype(np.float64)).max()})"


def _assert_states_equal(st_f, st_l, where=""):
    _tree_equal(st_f.cache, st_l.cache, f"{where} CacheState")
    _tree_equal(st_f.queues, st_l.queues, f"{where} QueueState")
    _tree_equal(st_f.metrics, st_l.metrics, f"{where} IOMetrics")


def _build_pair(seed=0, n_elems=8192, n_devices=2, queue_depth=64,
                prefetch=None):
    rng = np.random.default_rng(seed)
    data = rng.standard_normal(n_elems).astype(np.float32)
    ssd = ArrayOfSSDs(INTEL_OPTANE_P5800X, n_devices)
    kw = dict(block_elems=16, num_sets=16, ways=4, num_queues=2 * n_devices,
              queue_depth=queue_depth, ssd=ssd, prefetch=prefetch)
    arr, st_f = BamArray.build(data, **kw)
    _, st_l = BamArray.build(data, **kw)
    leg = dataclasses.replace(arr, fused_rounds=False,
                              _jit_ops={}, _trace_counts={})
    assert arr.fused_rounds and not leg.fused_rounds
    return arr, st_f, leg, st_l, rng


# ================================================== queue-layer fusion
class TestEnqueueSegments:
    def _random_segments(self, rng, n, num_blocks):
        def seg(prio):
            keys = jnp.asarray(
                np.where(rng.random(n) < 0.8,
                         rng.integers(0, num_blocks, n), -1), jnp.int32)
            dst = jnp.asarray(rng.integers(0, 64, n), jnp.int32)
            return keys, dst, prio
        k0, d0, _ = seg(Q.PRIO_DEMAND)
        k1, _, _ = seg(Q.PRIO_DEMAND)
        k2, d2, _ = seg(Q.PRIO_READAHEAD)
        return [
            (k0, d0, None, None, Q.PRIO_DEMAND),
            (k1, None, jnp.ones((n,), bool), None, Q.PRIO_DEMAND),
            (k2, d2, None, None, Q.PRIO_READAHEAD),
        ]

    @pytest.mark.parametrize("n_devices,depth", [(1, 64), (2, 64), (2, 4)])
    def test_matches_sequential_enqueue(self, n_devices, depth):
        # depth=4 forces back-pressure drops mid-segment: the fused pass
        # must reproduce the sequential acceptance decisions exactly.
        rng = np.random.default_rng(7 + n_devices + depth)
        qs0 = Q.make_queues(2 * n_devices, depth, n_devices=n_devices,
                            stripe_blocks=4)
        segs = self._random_segments(rng, 40, num_blocks=256)

        qs_seq = qs0
        recs_seq = []
        for keys, dst, w, v, p in segs:
            qs_seq, rec = Q.enqueue(qs_seq, keys, dst=dst, is_write=w,
                                    valid=v, prio=p)
            recs_seq.append(rec)

        qs_fused, recs_fused = Q.enqueue_segments(qs0, segs)
        _tree_equal(qs_fused, qs_seq, "QueueState")
        assert len(recs_fused) == len(recs_seq)
        for i, (rf, rs) in enumerate(zip(recs_fused, recs_seq)):
            _tree_equal(rf, rs, f"SubmitReceipt[{i}]")

    def test_tenant_namespacing(self):
        qs0 = Q.make_queues(2, 16, n_devices=1, stripe_blocks=1,
                            n_tenants=3, tenant_weights=(1.0, 2.0, 1.0))
        keys = jnp.asarray([3, 5, 9, -1], jnp.int32)
        seg = [(keys, None, None, None, Q.PRIO_DEMAND)]
        qs_a, _ = Q.enqueue(qs0, keys, tenant=2)
        qs_b, _ = Q.enqueue_segments(qs0, seg, tenant=2)
        _tree_equal(qs_b, qs_a, "tenant-2 QueueState")


class TestDrainAccounting:
    def test_matches_service_all(self):
        rng = np.random.default_rng(11)
        qs = Q.make_queues(4, 32, n_devices=2, stripe_blocks=4,
                           n_tenants=2, tenant_weights=(1.0, 3.0))
        # mixed stream: two tenants, demand + readahead, reads + writes
        for tenant in (0, 1):
            keys = jnp.asarray(rng.integers(0, 512, 24), jnp.int32)
            qs, _ = Q.enqueue(qs, keys, tenant=tenant)
            wkeys = jnp.asarray(rng.integers(0, 512, 12), jnp.int32)
            qs, _ = Q.enqueue(qs, wkeys, is_write=jnp.ones((12,), bool),
                              tenant=tenant)
            rkeys = jnp.asarray(rng.integers(0, 512, 8), jnp.int32)
            qs, _ = Q.enqueue(qs, rkeys, prio=Q.PRIO_READAHEAD,
                              tenant=tenant)

        qs_seq, comps = Q.service_all(qs)
        qs_fused, dr = Q.drain_accounting(qs)
        _tree_equal(qs_fused, qs_seq, "post-drain QueueState")

        cvalid = np.asarray(comps.valid)
        ckeys = np.asarray(comps.keys)
        cwrite = np.asarray(comps.is_write)
        ctenant = np.asarray(comps.tenant)
        assert int(dr.count) == int(cvalid.sum())
        from repro.core.ssd import device_of_block
        dev = np.asarray(device_of_block(jnp.asarray(ckeys), 2, 4))
        for d in range(2):
            sel = cvalid & (dev == d)
            assert int(dr.count_dev[d]) == int(sel.sum())
            assert int(dr.writes_dev[d]) == int((sel & cwrite).sum())
            assert int(dr.reads_dev[d]) == int((sel & ~cwrite).sum())
        for t in range(2):
            assert int(dr.count_tenant[t]) == \
                int((cvalid & (ctenant == t)).sum())

    def test_empty_rings_noop(self):
        qs = Q.make_queues(2, 16, n_devices=1, stripe_blocks=1)
        qs_seq, _ = Q.service_all(qs)
        qs_fused, dr = Q.drain_accounting(qs)
        _tree_equal(qs_fused, qs_seq, "empty drain")
        assert int(dr.count) == 0


# ============================================== full-round differential
class TestFusedRoundDifferential:
    def test_mixed_op_sequence_bit_identical(self):
        arr, st_f, leg, st_l, rng = _build_pair(seed=0)
        for step in range(4):
            idx = jnp.asarray(rng.integers(-8, 8192, 64), jnp.int32)
            vf, st_f = arr.read(st_f, idx)
            vl, st_l = leg.read(st_l, idx)
            assert np.array_equal(np.asarray(vf), np.asarray(vl))
            _assert_states_equal(st_f, st_l, f"read[{step}]")

            widx = jnp.asarray(rng.integers(0, 8192, 48), jnp.int32)
            wval = jnp.asarray(rng.standard_normal(48), jnp.float32)
            st_f = arr.write(st_f, widx, wval)
            st_l = leg.write(st_l, widx, wval)
            _assert_states_equal(st_f, st_l, f"write[{step}]")

            pidx = jnp.asarray(rng.integers(0, 8192, 32), jnp.int32)
            st_f = arr.prefetch(st_f, pidx)
            st_l = leg.prefetch(st_l, pidx)
            _assert_states_equal(st_f, st_l, f"prefetch[{step}]")
        st_f = arr.flush(st_f)
        st_l = leg.flush(st_l)
        _assert_states_equal(st_f, st_l, "flush")

    def test_outstanding_token_window_bit_identical(self):
        # several tokens in flight at once: cross-op coalescing, deferred
        # fetches and the drain-everything wait must all line up.
        arr, st_f, leg, st_l, rng = _build_pair(seed=1)
        toks_f, toks_l = [], []
        for _ in range(3):
            idx = jnp.asarray(rng.integers(0, 8192, 40), jnp.int32)
            st_f, tf = arr.submit(st_f, IORequest.read(idx))
            st_l, tl = leg.submit(st_l, IORequest.read(idx))
            toks_f.append(tf)
            toks_l.append(tl)
        _assert_states_equal(st_f, st_l, "after submits")
        for i in (1, 0, 2):                     # out-of-order redemption
            st_f, vf = arr.wait(st_f, toks_f[i])
            st_l, vl = leg.wait(st_l, toks_l[i])
            assert np.array_equal(np.asarray(vf), np.asarray(vl))
            _assert_states_equal(st_f, st_l, f"wait[{i}]")

    def test_stride_readahead_bit_identical(self):
        cfg = PrefetchConfig(enabled=True, window=8)
        arr, st_f, leg, st_l, _ = _build_pair(seed=2, n_elems=16384,
                                              prefetch=cfg)
        for start in (0, 1024, 2048):
            idx = jnp.asarray(np.arange(start, start + 1024, 4), jnp.int32)
            vf, st_f = arr.read(st_f, idx)
            vl, st_l = leg.read(st_l, idx)
            assert np.array_equal(np.asarray(vf), np.asarray(vl))
            _assert_states_equal(st_f, st_l, f"ra read @{start}")

    def test_submit_wait_jit_round_bit_identical(self):
        # the one-executable round op == jitted submit then jitted wait,
        # values, metrics and full state alike
        arr, st_r, _, st_p, rng = _build_pair(seed=7)
        rnd = arr.submit_wait_jit()
        submit, wait = arr.submit_jit(), arr.wait_jit(guard=False)
        for step in range(3):
            idx = jnp.asarray(rng.integers(-4, 8192, 64), jnp.int32)
            req = IORequest.read(idx)
            st_r, vr = rnd(st_r, req)
            st_p, tok = submit(st_p, req)
            st_p, vp = wait(st_p, tok)
            assert np.array_equal(np.asarray(vr), np.asarray(vp))
            _assert_states_equal(st_r, st_p, f"round[{step}]")
        assert arr.trace_counts.get("submit_wait") == 1

    def test_ring_backpressure_drops_bit_identical(self):
        # queue_depth=4 rejects most of the wavefront: drop accounting and
        # read-through completion must match exactly.
        arr, st_f, leg, st_l, rng = _build_pair(seed=3, queue_depth=4)
        idx = jnp.asarray(rng.integers(0, 8192, 128), jnp.int32)
        vf, st_f = arr.read(st_f, idx)
        vl, st_l = leg.read(st_l, idx)
        assert np.array_equal(np.asarray(vf), np.asarray(vl))
        _assert_states_equal(st_f, st_l, "dropped round")
        assert float(st_f.metrics.dropped) > 0   # the regime was exercised


# ==================================================== double-wait guard
class TestDoubleWaitGuard:
    def test_second_eager_wait_raises(self):
        arr, st, _, _, rng = _build_pair(seed=4)
        st, tok = arr.submit(st, IORequest.read(
            jnp.asarray(rng.integers(0, 8192, 16), jnp.int32)))
        st, _ = arr.wait(st, tok)
        with pytest.raises(ValueError, match="already been redeemed"):
            arr.wait(st, tok)

    def test_wait_jit_guard(self):
        arr, st, _, _, rng = _build_pair(seed=5)
        idx = jnp.asarray(rng.integers(0, 8192, 16), jnp.int32)
        st, tok = arr.submit_jit()(st, IORequest.read(idx))
        st, _ = arr.wait_jit()(st, tok)
        with pytest.raises(ValueError, match="already been redeemed"):
            arr.wait_jit()(st, tok)

    def test_guard_false_allows_replay(self):
        # benchmark timing loops deliberately re-run one wait against
        # copies of the same pre-wait state
        arr, st, _, _, rng = _build_pair(seed=6)
        idx = jnp.asarray(rng.integers(0, 8192, 16), jnp.int32)
        st, tok = arr.submit(st, IORequest.read(idx))
        fn = arr.wait_jit(guard=False)
        _, v1 = fn(st, tok)
        _, v2 = fn(st, tok)
        assert np.array_equal(np.asarray(v1), np.asarray(v2))

    def test_mixed_eager_then_jit_raises(self):
        arr, st, _, _, rng = _build_pair(seed=7)
        idx = jnp.asarray(rng.integers(0, 8192, 16), jnp.int32)
        st, tok = arr.submit(st, IORequest.read(idx))
        st, _ = arr.wait(st, tok)
        with pytest.raises(ValueError, match="already been redeemed"):
            arr.wait_jit()(st, tok)


# ================================================== watermark re-check
def _mk_runtime(seed=0, n_tenants=2):
    rng = np.random.default_rng(seed)
    specs = [
        TenantSpec(name=f"t{i}",
                   data=rng.standard_normal(2048).astype(np.float32),
                   block_elems=16)
        for i in range(n_tenants)
    ]
    rt, rst = BamRuntime.build(specs, num_sets=16, ways=4,
                               num_queues=4, queue_depth=64)
    return rt, rst, rng


class TestTokenWatermark:
    def test_two_tenant_global_watermark_is_summed_window(self):
        # 2 tenants x 1 outstanding token each: the true global in-flight
        # window is 2.  Before the fix the global watermark maxed the
        # per-tenant watermarks (= 1).
        rt, rst, rng = _mk_runtime(seed=8)
        idx = jnp.asarray(rng.integers(0, 2048, 16), jnp.int32)
        rst, tok_a = rt.submit(rst, "t0", IORequest.read(idx))
        rst, tok_b = rt.submit(rst, "t1", IORequest.read(idx))
        assert float(rst.metrics.tokens_in_flight) == 2.0
        assert int(rst.metrics.max_tokens_in_flight) == 2
        rst, _ = rt.wait(rst, "t0", tok_a)
        rst, _ = rt.wait(rst, "t1", tok_b)
        assert float(rst.metrics.tokens_in_flight) == 0.0
        assert int(rst.metrics.max_tokens_in_flight) == 2
        rt.assert_metrics_consistent(rst)

    def test_flush_mid_window_keeps_watermark(self):
        arr, st, _, _, rng = _build_pair(seed=9)
        idx = jnp.asarray(rng.integers(0, 8192, 16), jnp.int32)
        st, t1 = arr.submit(st, IORequest.read(idx))
        st, t2 = arr.submit(st, IORequest.read(idx + 1))
        st = arr.flush(st)                      # retires commands mid-window
        st, _ = arr.wait(st, t1)
        st, _ = arr.wait(st, t2)
        assert int(st.metrics.max_tokens_in_flight) == 2
        assert float(st.metrics.tokens_in_flight) == 0.0

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_random_interleavings_oracle(self, seed):
        # Random submit/flush/wait interleavings across two tenants; a
        # host-side oracle tracks the true global in-flight window and its
        # high-water mark, which the runtime metrics must reproduce.
        rt, rst, rng = _mk_runtime(seed=20 + seed)
        outstanding = {"t0": [], "t1": []}
        in_flight, peak = 0, 0
        for _ in range(14):
            name = ("t0", "t1")[int(rng.integers(0, 2))]
            choices = ["submit", "flush"]
            if outstanding[name]:
                choices.append("wait")
            op = choices[int(rng.integers(0, len(choices)))]
            if op == "submit" and in_flight < 6:
                idx = jnp.asarray(rng.integers(0, 2048, 8), jnp.int32)
                rst, tok = rt.submit(rst, name, IORequest.read(idx))
                outstanding[name].append(tok)
                in_flight += 1
                peak = max(peak, in_flight)
            elif op == "flush":
                rst = rt.flush(rst, name)
            elif op == "wait":
                tok = outstanding[name].pop(
                    int(rng.integers(0, len(outstanding[name]))))
                rst, _ = rt.wait(rst, name, tok)
                in_flight -= 1
        for name in ("t0", "t1"):
            for tok in outstanding[name]:
                rst, _ = rt.wait(rst, name, tok)
        assert float(rst.metrics.tokens_in_flight) == 0.0
        assert int(rst.metrics.max_tokens_in_flight) == peak
        rt.assert_metrics_consistent(rst)


# ============================================ bucketed wavefront shapes
class TestBucketedWavefronts:
    def test_ragged_sweep_compiles_at_most_len_buckets(self):
        arr, st_b, leg, st_u, rng = _build_pair(seed=10)
        # leg doubles as the *unbucketed fused* reference here
        ref = dataclasses.replace(arr, _jit_ops={}, _trace_counts={})
        sizes = [3, 17, 40, 64, 90, 130, 200, 256, 300, 512]
        for n in sizes:
            idx = jnp.asarray(rng.integers(-4, 8192, n), jnp.int32)
            st_b, tok = arr.submit_bucketed(st_b, IORequest.read(idx))
            st_b, vb = arr.wait_bucketed(st_b, tok)
            st_u, tok_u = ref.submit(st_u, IORequest.read(idx))
            st_u, vu = ref.wait(st_u, tok_u)
            assert vb.shape == (n,)
            assert np.array_equal(np.asarray(vb), np.asarray(vu)), \
                f"bucketed values differ at n={n}"
            _assert_states_equal(st_b, st_u, f"bucketed n={n}")
        n_buckets_used = len({arr.bucket_size(n) for n in sizes})
        assert arr.trace_counts["submit"] <= min(n_buckets_used,
                                                 len(arr.buckets))
        assert arr.trace_counts["wait"] <= min(n_buckets_used,
                                               len(arr.buckets))

    def test_steady_state_zero_retraces(self):
        arr, st, _, _, rng = _build_pair(seed=11)
        sizes = [10, 50, 60, 12, 33]             # all inside bucket 64
        for n in sizes:
            idx = jnp.asarray(rng.integers(0, 8192, n), jnp.int32)
            st, tok = arr.submit_bucketed(st, IORequest.read(idx))
            st, _ = arr.wait_bucketed(st, tok)
        assert arr.trace_counts == {"submit": 1, "wait": 1}

    def test_bucketed_write_roundtrip(self):
        arr, st, _, _, rng = _build_pair(seed=12)
        idx = jnp.asarray(rng.integers(0, 8192, 37), jnp.int32)
        val = jnp.asarray(np.arange(37), jnp.float32)
        st, tok = arr.submit_bucketed(st, IORequest.write(idx, val))
        st, _ = arr.wait_bucketed(st, tok)
        st, tok = arr.submit_bucketed(st, IORequest.read(idx))
        st, got = arr.wait_bucketed(st, tok)
        # duplicate indices are last-writer-wins; compare per unique index
        ref = {}
        for i, v in zip(np.asarray(idx), np.asarray(val)):
            ref[int(i)] = v
        got = np.asarray(got)
        for k, (i, g) in enumerate(zip(np.asarray(idx), got)):
            assert g == ref[int(i)], f"lane {k}"

    def test_overflow_bucket_rounds_up(self):
        arr, _, _, _, _ = _build_pair(seed=13)
        assert arr.bucket_size(1) == arr.buckets[0]
        assert arr.bucket_size(arr.buckets[-1]) == arr.buckets[-1]
        assert arr.bucket_size(arr.buckets[-1] + 1) == 2 * arr.buckets[-1]


# ============================================= zero-length short-circuit
class TestEmptyBatches:
    def test_empty_submit_wait_is_untraced_noop(self):
        arr, st, _, _, _ = _build_pair(seed=14)
        before = jax.tree_util.tree_map(np.asarray, st.metrics)
        st2, tok = arr.submit_bucketed(st, IORequest.read(
            jnp.zeros((0,), jnp.int32)))
        st2, vals = arr.wait_bucketed(st2, tok)
        assert vals.shape == (0,)
        assert tok.ukeys.shape == (0,)
        assert arr.trace_counts == {}            # nothing compiled
        _tree_equal(st2.metrics, before, "metrics after empty round")

    def test_empty_eager_round(self):
        arr, st, _, _, _ = _build_pair(seed=15)
        st, tok = arr.submit(st, IORequest.read(jnp.zeros((0,), jnp.int32)))
        st, vals = arr.wait(st, tok)
        assert vals.shape == (0,)
        with pytest.raises(ValueError, match="already been redeemed"):
            arr.wait(st, tok)

    def test_bfs_edgeless_graph_tail(self):
        # A node with no edges: the frontier wavefront is size 0 from the
        # first iteration — previously this crashed in the coalescer
        # before any guard could run.
        from repro.graph.analytics import BamGraph, bfs, bfs_oracle
        indptr = np.zeros(5, np.int64)           # 4 nodes, 0 edges
        dst = np.zeros((0,), np.int32)
        g = BamGraph.build(indptr, dst, cacheline_bytes=64,
                           cache_bytes=1 << 12)
        depth, _ = bfs(g, source=0)
        assert np.array_equal(depth, bfs_oracle(indptr, dst, 0))


# ======================================================= donation contract
class TestDonation:
    def test_donating_round_trip_and_reuse_raises(self):
        arr, st, _, _, rng = _build_pair(seed=16)
        idx = jnp.asarray(rng.integers(0, 8192, 32), jnp.int32)
        req = IORequest.read(idx)
        submit = arr.submit_jit(donate=True)
        wait = arr.wait_jit(donate=True)
        for _ in range(3):
            st, tok = submit(st, req)
            st, vals = wait(st, tok)
        assert vals.shape == (32,)
        # donated input buffers are dead after the call
        old = st
        st, tok = submit(st, req)
        with pytest.raises(RuntimeError):
            np.asarray(old.cache.tags)
        st, _ = wait(st, tok)

    def test_donating_keys_are_separate_executables(self):
        arr, st, _, _, rng = _build_pair(seed=17)
        idx = jnp.asarray(rng.integers(0, 8192, 16), jnp.int32)
        fn_plain = arr.submit_jit()
        fn_donate = arr.submit_jit(donate=True)
        assert fn_plain is not fn_donate
        assert arr.submit_jit() is fn_plain
        assert arr.submit_jit(donate=True) is fn_donate
        # plain executables never kill the caller's state
        st2, tok = fn_plain(st, IORequest.read(idx))
        np.asarray(st.cache.tags)                # still alive
        st2, _ = arr.wait_jit()(st2, tok)

    def test_donating_values_match_plain(self):
        arr, st_d, leg_unused, st_p, rng = _build_pair(seed=18)
        ref = dataclasses.replace(arr, _jit_ops={}, _trace_counts={})
        for _ in range(3):
            idx = jnp.asarray(rng.integers(0, 8192, 24), jnp.int32)
            req = IORequest.read(idx)
            st_d, tok_d = arr.submit_jit(donate=True)(st_d, req)
            st_d, vd = arr.wait_jit(donate=True)(st_d, tok_d)
            st_p, tok_p = ref.submit_jit()(st_p, req)
            st_p, vp = ref.wait_jit()(st_p, tok_p)
            assert np.array_equal(np.asarray(vd), np.asarray(vp))
        _assert_states_equal(st_d, st_p, "donated vs plain")
