"""The trip-count-aware HLO analyzer vs XLA's own cost_analysis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def _compiled(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def _xla_cost(c):
    ca = c.cost_analysis()
    return ca[0] if isinstance(ca, list) else ca    # list-of-dicts pre-0.5


def test_matches_cost_analysis_loop_free():
    def f(x, w1, w2):
        return ((x @ w1) @ w2).sum()

    c = _compiled(f,
                  jax.ShapeDtypeStruct((128, 256), jnp.float32),
                  jax.ShapeDtypeStruct((256, 512), jnp.float32),
                  jax.ShapeDtypeStruct((512, 64), jnp.float32))
    got = H.analyze_compiled(c)
    want = _xla_cost(c)["flops"]
    assert got.flops == pytest.approx(want, rel=0.02)


def test_scan_body_multiplied_by_trip_count():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    c = _compiled(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                  jax.ShapeDtypeStruct((8, 64, 64), jnp.float32))
    got = H.analyze_compiled(c)
    # 8 iterations x 2*64^3
    assert got.flops == pytest.approx(8 * 2 * 64 ** 3, rel=0.05)
    # cost_analysis counts the body once — the analyzer must not
    assert got.flops > _xla_cost(c)["flops"] * 4


def test_nested_scan_trip_counts():
    def f(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y.sum()

    c = _compiled(f, jax.ShapeDtypeStruct((32, 32), jnp.float32),
                  jax.ShapeDtypeStruct((5, 32, 32), jnp.float32))
    got = H.analyze_compiled(c)
    assert got.flops == pytest.approx(5 * 3 * 2 * 32 ** 3, rel=0.1)


def test_collective_bytes_parsed():
    import os
    import subprocess, sys, textwrap
    # needs >1 device: subprocess with forced host device count
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        sys.path.insert(0, "src")
        from repro.launch import hlo_analysis as H
        mesh = jax.sharding.Mesh(jax.devices(), ("d",))
        def f(x):
            return x.sum()
        sh = NamedSharding(mesh, P("d"))
        c = jax.jit(f, in_shardings=sh, out_shardings=NamedSharding(mesh, P())
                    ).lower(jax.ShapeDtypeStruct((64, 32), jnp.float32)
                    ).compile()
        got = H.analyze_compiled(c)
        assert sum(got.coll_bytes.values()) > 0, got.coll_bytes
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "OK" in r.stdout, r.stderr[-2000:]


def test_memory_bytes_reasonable_for_big_matmul():
    """Traffic estimate within ~3x of (inputs+outputs) for one matmul."""
    def f(a, b):
        return a @ b

    M = 512
    c = _compiled(f, jax.ShapeDtypeStruct((M, M), jnp.float32),
                  jax.ShapeDtypeStruct((M, M), jnp.float32))
    got = H.analyze_compiled(c)
    ideal = 3 * M * M * 4
    assert ideal * 0.5 <= got.mem_bytes <= ideal * 3
