"""The trip-count-aware HLO analyzer vs XLA's own cost_analysis."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
VERIFY_FIXTURES = REPO_ROOT / "tools" / "bamverify" / "fixtures"


def _fixture_hlo(rel: str) -> str:
    """Body of a committed bamverify fixture (header comment stripped)."""
    return (VERIFY_FIXTURES / rel).read_text().partition("\n")[2]


def _compiled(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def _xla_cost(c):
    ca = c.cost_analysis()
    return ca[0] if isinstance(ca, list) else ca    # list-of-dicts pre-0.5


def test_matches_cost_analysis_loop_free():
    def f(x, w1, w2):
        return ((x @ w1) @ w2).sum()

    c = _compiled(f,
                  jax.ShapeDtypeStruct((128, 256), jnp.float32),
                  jax.ShapeDtypeStruct((256, 512), jnp.float32),
                  jax.ShapeDtypeStruct((512, 64), jnp.float32))
    got = H.analyze_compiled(c)
    want = _xla_cost(c)["flops"]
    assert got.flops == pytest.approx(want, rel=0.02)


def test_scan_body_multiplied_by_trip_count():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    c = _compiled(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                  jax.ShapeDtypeStruct((8, 64, 64), jnp.float32))
    got = H.analyze_compiled(c)
    # 8 iterations x 2*64^3
    assert got.flops == pytest.approx(8 * 2 * 64 ** 3, rel=0.05)
    # cost_analysis counts the body once — the analyzer must not
    assert got.flops > _xla_cost(c)["flops"] * 4


def test_nested_scan_trip_counts():
    def f(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y.sum()

    c = _compiled(f, jax.ShapeDtypeStruct((32, 32), jnp.float32),
                  jax.ShapeDtypeStruct((5, 32, 32), jnp.float32))
    got = H.analyze_compiled(c)
    assert got.flops == pytest.approx(5 * 3 * 2 * 32 ** 3, rel=0.1)


def test_collective_bytes_parsed():
    import os
    import subprocess, sys, textwrap
    # needs >1 device: subprocess with forced host device count
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        sys.path.insert(0, "src")
        from repro.launch import hlo_analysis as H
        mesh = jax.sharding.Mesh(jax.devices(), ("d",))
        def f(x):
            return x.sum()
        sh = NamedSharding(mesh, P("d"))
        c = jax.jit(f, in_shardings=sh, out_shardings=NamedSharding(mesh, P())
                    ).lower(jax.ShapeDtypeStruct((64, 32), jnp.float32)
                    ).compile()
        got = H.analyze_compiled(c)
        assert sum(got.coll_bytes.values()) > 0, got.coll_bytes
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "OK" in r.stdout, r.stderr[-2000:]


def test_custom_call_target_surfaced_in_instr_stream():
    """Host callbacks lower to custom-calls; the parser must expose the
    target string (bamverify's BAM503 keys off it) instead of treating
    custom-call as an anonymous zero-cost op."""
    comps, _entry = H.parse_computations(_fixture_hlo("bad/bam503.hlo"))
    calls = H.iter_custom_calls(comps)
    assert calls, "fixture lost its custom-call"
    assert any("callback" in ins.custom_call_target for _, ins in calls), [
        ins.custom_call_target for _, ins in calls
    ]
    # non-custom-call instructions keep the default empty target
    plain = [ins for instrs in comps.values() for ins in instrs
             if ins.op != "custom-call"]
    assert all(ins.custom_call_target == "" for ins in plain)


def test_branch_computations_lists_every_branch():
    line = ("%c = (f32[8]) conditional(%p, %a, %a), "
            "branch_computations={%region_0.8, %region_2.16}")
    ins = H.Instr(name="c", type_str="(f32[8])", op="conditional",
                  args="%p, %a, %a", line=line)
    assert H.branch_computations(ins) == ["region_0.8", "region_2.16"]
    assert H.called_computations(ins) == ["region_0.8", "region_2.16"]
    assert H.called_computations(ins, include_branches=False) == []


def test_ungated_computations_gated_vs_ungated_callback():
    """A cond-gated callback's computation must NOT be reachable in the
    ungated closure; an unconditional one must be."""
    comps_g, entry_g = H.parse_computations(
        _fixture_hlo("good/gated_callback.hlo"))
    gated_cbs = [cname for cname, ins in H.iter_custom_calls(comps_g)
                 if "callback" in ins.custom_call_target]
    assert gated_cbs
    ungated = H.ungated_computations(comps_g, entry_g)
    assert not any(c in ungated for c in gated_cbs)

    comps_u, entry_u = H.parse_computations(_fixture_hlo("bad/bam503.hlo"))
    open_cbs = [cname for cname, ins in H.iter_custom_calls(comps_u)
                if "callback" in ins.custom_call_target]
    assert open_cbs
    assert all(c in H.ungated_computations(comps_u, entry_u)
               for c in open_cbs)


def test_memory_bytes_reasonable_for_big_matmul():
    """Traffic estimate within ~3x of (inputs+outputs) for one matmul."""
    def f(a, b):
        return a @ b

    M = 512
    c = _compiled(f, jax.ShapeDtypeStruct((M, M), jnp.float32),
                  jax.ShapeDtypeStruct((M, M), jnp.float32))
    got = H.analyze_compiled(c)
    ideal = 3 * M * M * 4
    assert ideal * 0.5 <= got.mem_bytes <= ideal * 3
