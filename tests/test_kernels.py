"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: dict(atol=3e-5, rtol=3e-5),
       jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D", [
    (1, 1, 1, 32, 32, 16),
    (2, 4, 2, 96, 96, 64),
    (1, 8, 1, 64, 64, 32),     # MQA
    (2, 3, 3, 33, 65, 16),     # ragged, no GQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 16)])
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Skv, D, dtype, causal,
                               window):
    if causal and Sq != Skv:
        pytest.skip("causal needs square")
    rng = np.random.default_rng(0)
    q = _mk(rng, (B, Hq, Sq, D), dtype)
    k = _mk(rng, (B, Hkv, Skv, D), dtype)
    v = _mk(rng, (B, Hkv, Skv, D), dtype)
    o_pal = ops.flash_attention(q, k, v, causal=causal, window=window,
                                impl="pallas", interpret=True,
                                block_q=32, block_kv=32)
    o_ref = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32), **TOL[dtype])
    # the blockwise XLA path must agree too
    o_xla = ref.flash_attention_xla(q, k, v, causal=causal, window=window,
                                    block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(o_xla, np.float32),
                               np.asarray(o_ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("B,Hq,Hkv,D,P,page,NP", [
    (2, 4, 2, 32, 8, 8, 6),
    (1, 8, 8, 16, 4, 16, 4),
    (3, 5, 5, 64, 6, 8, 5),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, Hq, Hkv, D, P, page, NP, dtype):
    rng = np.random.default_rng(1)
    q = _mk(rng, (B, Hq, D), dtype)
    kp = _mk(rng, (B, P, page, Hkv, D), dtype)
    vp = _mk(rng, (B, P, page, Hkv, D), dtype)
    pt = jnp.stack([jnp.asarray(rng.permutation(P)[:NP], jnp.int32)
                    for _ in range(B)])
    pt = pt.at[0, NP - 1].set(-1)                  # a hole
    sl = jnp.asarray(rng.integers(1, NP * page, B), jnp.int32)
    o_pal = ops.paged_attention(q, kp, vp, pt, sl, impl="pallas",
                                interpret=True)
    o_ref = ref.paged_attention_ref(q, kp, vp, pt, sl)
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("n,lines,elems", [(7, 16, 32), (64, 8, 128),
                                           (1, 4, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_gather_blocks_sweep(n, lines, elems, dtype):
    rng = np.random.default_rng(2)
    data = _mk(rng, (lines, elems), dtype) if dtype != jnp.int32 else \
        jnp.asarray(rng.integers(0, 100, (lines, elems)), jnp.int32)
    slots = jnp.asarray(rng.integers(-1, lines, n), jnp.int32)
    o_pal = ops.gather_blocks(data, slots, impl="pallas", interpret=True)
    o_ref = ref.gather_blocks_ref(data, slots)
    np.testing.assert_array_equal(np.asarray(o_pal), np.asarray(o_ref))


@pytest.mark.parametrize("n,lines,elems", [(7, 16, 32), (64, 8, 128)])
def test_gather_blocks_element_mode(n, lines, elems):
    """`off=` gathers single elements; pallas(line-DMA + select) == ref."""
    rng = np.random.default_rng(6)
    data = _mk(rng, (lines, elems), jnp.float32)
    slots = jnp.asarray(rng.integers(-1, lines, n), jnp.int32)
    off = jnp.asarray(rng.integers(0, elems, n), jnp.int32)
    o_pal = ops.gather_blocks(data, slots, off=off, impl="pallas",
                              interpret=True)
    o_ref = ops.gather_blocks(data, slots, off=off, impl="ref")
    expect = np.where(np.asarray(slots) >= 0,
                      np.asarray(data)[np.maximum(np.asarray(slots), 0),
                                       np.asarray(off)], 0)
    np.testing.assert_array_equal(np.asarray(o_pal), expect)
    np.testing.assert_array_equal(np.asarray(o_ref), expect)


@pytest.mark.parametrize("sets,ways,m", [(16, 4, 33), (64, 8, 256),
                                         (4, 1, 7)])
def test_cache_probe_sweep(sets, ways, m):
    rng = np.random.default_rng(3)
    tags = jnp.asarray(rng.integers(-1, 5000, (sets, ways)), jnp.int32)
    keys = jnp.concatenate([
        tags.reshape(-1)[:m // 2],
        jnp.asarray(rng.integers(0, 10000, m - m // 2), jnp.int32)])
    h_pal, s_pal = ops.cache_probe(tags, keys, impl="pallas",
                                   interpret=True, block_m=32)
    h_ref, s_ref = ref.cache_probe_ref(tags, keys)
    np.testing.assert_array_equal(np.asarray(h_pal), np.asarray(h_ref))
    np.testing.assert_array_equal(np.asarray(s_pal), np.asarray(s_ref))


def test_cache_probe_matches_core_cache():
    """The kernel is bit-identical to the functional cache's probe."""
    from repro.core import cache as C
    rng = np.random.default_rng(4)
    cache = C.make_cache(8, 2, 4)
    keys = jnp.asarray(rng.integers(0, 50, 16), jnp.int32)
    cache, alloc = C.allocate(cache, keys, jnp.ones(16, bool))
    probe_keys = jnp.asarray(rng.integers(0, 60, 40), jnp.int32)
    pr = C.probe(cache, probe_keys)
    h2, s2 = ops.cache_probe(cache.tags, probe_keys, impl="pallas",
                             interpret=True, block_m=16)
    np.testing.assert_array_equal(np.asarray(pr.hit), np.asarray(h2))
    np.testing.assert_array_equal(np.asarray(pr.slot), np.asarray(s2))


def test_flash_dynamic_window_traced():
    rng = np.random.default_rng(5)
    q = _mk(rng, (1, 2, 64, 32), jnp.float32)
    k = _mk(rng, (1, 2, 64, 32), jnp.float32)
    v = _mk(rng, (1, 2, 64, 32), jnp.float32)
    o1 = ops.flash_attention(q, k, v, causal=True, window=jnp.int32(16),
                             impl="pallas", interpret=True,
                             block_q=32, block_kv=32)
    o2 = ref.flash_attention_ref(q, k, v, causal=True, window=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5,
                               rtol=3e-5)


def test_flash_xla_backward_stays_f32_under_x64():
    """Regression (bamlint BAM303): the manual backward's dk/dv scan
    accumulators were built without a dtype — float64 under x64 — which
    promoted (or broke) the whole custom-vjp backward."""
    import jax.experimental
    rng = np.random.default_rng(6)
    q = _mk(rng, (1, 2, 32, 16), jnp.float32)
    k = _mk(rng, (1, 2, 32, 16), jnp.float32)
    v = _mk(rng, (1, 2, 32, 16), jnp.float32)
    with jax.experimental.enable_x64():
        dq, dk, dv = jax.grad(
            lambda q, k, v: ref.flash_attention_xla(
                q, k, v, causal=True, block_q=16, block_kv=16).sum(),
            argnums=(0, 1, 2))(q, k, v)
    assert dq.dtype == jnp.float32
    assert dk.dtype == jnp.float32
    assert dv.dtype == jnp.float32
