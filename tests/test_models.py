"""Per-arch smoke tests (REQUIRED): reduced config of the same family —
one forward + one train step on CPU, asserting shapes and finiteness —
plus decode-vs-forward consistency for every family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import list_archs, smoke_config, get_config, SHAPES
from repro.models.model import build_model, count_params
from repro.training import optimizer as opt


def _batch(cfg, rng, B=2, S=16):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model)),
            jnp.float32)
    if cfg.family == "audio":
        batch["enc_frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_train_step(arch, rng):
    cfg = smoke_config(arch)
    api = build_model(cfg)
    B, S = 2, 16
    params, axes = api.init(jax.random.PRNGKey(0), S)
    assert count_params(params) > 0
    batch = _batch(cfg, rng, B, S)

    logits, _ = jax.jit(api.forward)(params, batch)
    s_out = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, s_out, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    acfg = opt.AdamWConfig(lr=1e-3, warmup=1, total_steps=10)
    ostate = opt.adamw_init(params, acfg)

    @jax.jit
    def step(params, ostate, batch):
        (loss, metrics), grads = jax.value_and_grad(
            api.loss, has_aux=True)(params, batch)
        params, ostate, om = opt.adamw_update(grads, ostate, params, acfg)
        return params, ostate, loss

    params2, ostate, loss = step(params, ostate, batch)
    assert np.isfinite(float(loss))
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), params, params2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_forward(arch, rng):
    """Sequential decode produces the same last-token logits as the
    full-sequence forward — the strongest cache-correctness check."""
    cfg = smoke_config(arch)
    api = build_model(cfg)
    B, S = 2, 12
    params, _ = api.init(jax.random.PRNGKey(1), S + 4)
    batch = _batch(cfg, rng, B, S)
    logits_fwd, _ = jax.jit(api.forward)(params, batch)

    cache, _ = api.init_decode_cache(B, S + 4)
    if cfg.enc_dec:
        from repro.models import transformer as T
        enc_out = T.encode(cfg, params, batch["enc_frames"], "auto")
        xkv = []
        hd = cfg.hd
        for i in range(cfg.n_layers):
            bp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            from repro.models import layers as L
            k = L.dense(bp["xattn"]["wk"], enc_out, cfg.compute_dtype
                        ).reshape(B, -1, cfg.n_kv_heads, hd
                                  ).transpose(0, 2, 1, 3)
            v = L.dense(bp["xattn"]["wv"], enc_out, cfg.compute_dtype
                        ).reshape(B, -1, cfg.n_kv_heads, hd
                                  ).transpose(0, 2, 1, 3)
            xkv.append(jnp.stack([k, v]))
        cache["xkv"] = jnp.stack(xkv)
    if cfg.family == "vlm":
        # decode path is text-only; compare on text-only forward
        batch = {"tokens": batch["tokens"]}
        logits_fwd, _ = jax.jit(api.forward)(params, batch)
    if api.prime is not None:         # hymba: meta tokens first
        cache = api.prime(params, cache)

    dec = jax.jit(api.decode_step)
    lg = None
    for t in range(S):
        lg, cache = dec(params, cache, batch["tokens"][:, t])
    np.testing.assert_allclose(
        np.asarray(lg, np.float32),
        np.asarray(logits_fwd[:, -1, :], np.float32), atol=2e-3, rtol=2e-3)


def test_moe_routing_drops_bounded(rng):
    """With capacity factor >= 1 and uniform-ish routing, most tokens keep
    all their expert slots."""
    from repro.models import layers as L
    cfg = smoke_config("olmoe_1b_7b")
    p, _ = L.init_moe(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    out, aux = L.moe_ffn(cfg, p, x)
    assert out.shape == x.shape
    assert np.isfinite(float(aux["load_balance"]))
    assert float(aux["load_balance"]) > 0.5      # ~1.0 when balanced


def test_mlstm_chunkwise_equals_recurrent(rng):
    from repro.models.xlstm import mlstm_chunkwise, mlstm_step
    B, H, S, d = 2, 3, 32, 8
    q = jnp.asarray(rng.standard_normal((B, H, S, d)), jnp.float32) / np.sqrt(d)
    k = jnp.asarray(rng.standard_normal((B, H, S, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, S, d)), jnp.float32)
    il = jnp.asarray(rng.standard_normal((B, H, S)), jnp.float32)
    gl = jax.nn.log_sigmoid(
        jnp.asarray(rng.standard_normal((B, H, S)), jnp.float32) * 2)
    st = (jnp.zeros((B, H, d, d)), jnp.zeros((B, H, d)),
          jnp.full((B, H), -1e30))
    hs = []
    for t in range(S):
        st, h = mlstm_step(st, q[:, :, t], k[:, :, t], v[:, :, t],
                           il[:, :, t], gl[:, :, t])
        hs.append(h)
    h_ref = jnp.stack(hs, axis=2)
    for chunk in (8, 16, 32):
        h_c, (C2, _, _) = mlstm_chunkwise(q, k, v, il, gl, chunk=chunk)
        np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_ref),
                                   atol=1e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(C2), np.asarray(st[0]),
                                   atol=1e-4, rtol=1e-3)


def test_full_configs_instantiable():
    """The FULL configs build and report sane parameter counts (no arrays
    are allocated — eval_shape only)."""
    from repro.models.model import active_params, total_params
    expected = {
        "llava_next_mistral_7b": (6.5e9, 8.0e9),
        "gemma3_12b": (10e9, 14e9),
        "gemma3_1b": (0.7e9, 1.5e9),
        "qwen2_5_14b": (13e9, 16e9),
        "minitron_4b": (3.5e9, 5e9),
        "olmoe_1b_7b": (6.0e9, 7.5e9),      # total; active ~1.3B
        "moonshot_v1_16b_a3b": (25e9, 30e9),   # assigned dims give 28B
        "whisper_large_v3": (1.2e9, 2.0e9),
        "xlstm_1_3b": (1.0e9, 1.8e9),
        "hymba_1_5b": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        api = build_model(cfg)
        sds = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0), 0)[0])
        n = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(sds))
        assert lo < n < hi, (arch, n / 1e9)
        tp = total_params(cfg)
        assert 0.5 * n < tp < 2.0 * n, (arch, n, tp)


def test_long_500k_skip_policy():
    cell = SHAPES["long_500k"]
    runs = {a: get_config(a).supports_cell(cell) for a in list_archs()}
    assert runs["gemma3_12b"] and runs["gemma3_1b"]
    assert runs["xlstm_1_3b"] and runs["hymba_1_5b"]
    for a in ("llava_next_mistral_7b", "qwen2_5_14b", "minitron_4b",
              "olmoe_1b_7b", "moonshot_v1_16b_a3b", "whisper_large_v3"):
        assert not runs[a], a


def test_moe_combine_variants_equivalent(rng):
    """The perf combine strategies (allgather / scatter-add) are
    bit-consistent with the baseline gather combine, fwd and bwd."""
    from repro.models import layers as L
    cfg = smoke_config("moonshot_v1_16b_a3b")
    p, _ = L.init_moe(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    outs, grads = {}, {}
    for mode in ("gather", "allgather", "scatter"):
        c = cfg.replace(moe_combine=mode)
        outs[mode], _ = L.moe_ffn(c, p, x)
        grads[mode] = jax.grad(
            lambda p, c=c: L.moe_ffn(c, p, x)[0].sum())(p)
    for mode in ("allgather", "scatter"):
        np.testing.assert_allclose(np.asarray(outs[mode]),
                                   np.asarray(outs["gather"]),
                                   atol=2e-5, rtol=2e-5)
        for a, b in zip(jax.tree_util.tree_leaves(grads[mode]),
                        jax.tree_util.tree_leaves(grads["gather"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=3e-5, rtol=3e-5)
