"""Differential oracle: a BamArray behaves like a plain numpy array.

Random sequences of ``read``/``write``/``prefetch``/``flush`` over random
cache geometry / queue depth / device count are checked element-for-element
against a host numpy array, and the storage tier is compared byte-for-byte
after every ``flush``.  Whatever the cache decides (hit, miss, bypass,
speculative fill, write-back, ring drop), the *values* must be those of the
oracle — the "degrades accounting, never correctness" contract.

The hypothesis-driven search runs when hypothesis is installed (the CI
profile is derandomized, see ``conftest.py``); the example-based runs below
exercise the same engine from fixed seeds so the tier-1 suite keeps this
coverage with nothing but jax/numpy/pytest.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import BamArray, IORequest, PrefetchConfig
from repro.core.ssd import ArrayOfSSDs, INTEL_OPTANE_P5800X

OPS = ("read", "write", "prefetch", "flush")
AOPS = ("read", "write", "prefetch")


def run_ops(num_sets, ways, block_elems, n_devices, queue_depth,
            seed, op_kinds, *, prefetch=False):
    """Execute one op sequence against both BamArray and the numpy oracle."""
    rng = np.random.default_rng(seed)
    size = int(rng.integers(block_elems, 6 * block_elems * max(num_sets, 1)))
    data = rng.standard_normal(size).astype(np.float32)
    oracle = data.copy()
    arr, st_ = BamArray.build(
        data, block_elems=block_elems, num_sets=num_sets, ways=ways,
        num_queues=2 * n_devices, queue_depth=queue_depth,
        ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, n_devices),
        prefetch=PrefetchConfig(enabled=True, window=4) if prefetch
        else None)

    def check_storage():
        flat = np.asarray(arr.storage.data).reshape(-1)[:size]
        np.testing.assert_array_equal(flat, oracle)

    for kind in op_kinds:
        m = int(rng.integers(1, 25))
        # indices deliberately include invalid lanes (<0 and >= size)
        idx = rng.integers(-2, size + 3, m).astype(np.int32)
        valid = (idx >= 0) & (idx < size)
        if kind == "read":
            vals, st_ = arr.read(st_, jnp.asarray(idx))
            expect = np.where(valid, oracle[np.clip(idx, 0, size - 1)], 0.0)
            np.testing.assert_allclose(np.asarray(vals), expect, rtol=0,
                                       atol=0)
        elif kind == "write":
            # duplicate element indices are last-writer-wins with
            # *unspecified* order (as on the GPU) — keep the oracle
            # deterministic by writing unique indices per wavefront.
            uidx = np.unique(idx)
            wvals = rng.standard_normal(len(uidx)).astype(np.float32)
            st_ = arr.write(st_, jnp.asarray(uidx), jnp.asarray(wvals))
            wvalid = (uidx >= 0) & (uidx < size)
            oracle[uidx[wvalid]] = wvals[wvalid]
        elif kind == "prefetch":
            st_ = arr.prefetch(st_, jnp.asarray(idx))      # no visible effect
        elif kind == "flush":
            st_ = arr.flush(st_)
            assert not bool(st_.cache.dirty.any()), \
                "flush left dirty lines behind"
            check_storage()

    # closing barrier: flush everything, then storage and a full read-back
    # must both equal the oracle.
    st_ = arr.flush(st_)
    assert not bool(st_.cache.dirty.any())
    check_storage()
    vals, st_ = arr.read(st_, jnp.arange(size, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(vals), oracle)


@given(st.integers(1, 8),                   # num_sets
       st.integers(1, 4),                   # ways
       st.sampled_from([2, 4, 8]),          # block_elems
       st.integers(1, 2),                   # n_devices
       st.sampled_from([2, 8, 64]),         # queue_depth (2 forces drops)
       st.integers(0, 2 ** 31 - 1),         # data / wavefront seed
       st.lists(st.sampled_from(OPS), min_size=1, max_size=8),
       st.booleans())                       # stride readahead on/off
@settings(max_examples=25, deadline=None)
def test_bam_array_matches_numpy_oracle(num_sets, ways, block_elems,
                                        n_devices, queue_depth, seed,
                                        op_kinds, prefetch):
    run_ops(num_sets, ways, block_elems, n_devices, queue_depth, seed,
            op_kinds, prefetch=prefetch)


# Fixed-seed slices of the same property: run even without hypothesis.
_EXAMPLES = [
    # (num_sets, ways, block_elems, n_devices, depth, seed, ops, prefetch)
    (4, 2, 4, 1, 64, 0,
     ["read", "write", "read", "flush", "read"], False),
    (1, 1, 2, 1, 2, 1,
     ["write", "read", "write", "flush", "prefetch", "read"], False),
    (8, 4, 8, 2, 8, 2,
     ["prefetch", "read", "write", "read", "write", "flush"], True),
    (2, 3, 4, 2, 4, 3,
     ["write", "flush", "write", "read", "flush", "read", "read"], True),
    (5, 2, 2, 1, 8, 4,
     ["read"] * 3 + ["write"] * 2 + ["flush", "read"], False),
]


@pytest.mark.parametrize("case", _EXAMPLES,
                         ids=[f"seed{c[5]}" for c in _EXAMPLES])
def test_oracle_examples(case):
    num_sets, ways, block_elems, n_devices, depth, seed, ops, pf = case
    run_ops(num_sets, ways, block_elems, n_devices, depth, seed, ops,
            prefetch=pf)


def test_oracle_tiny_queue_forces_drops_not_corruption():
    """With depth 2 the rings drop most commands; values must still match
    (read-through / write-through), only accounting degrades."""
    run_ops(4, 2, 4, 1, 2, 7,
            ["read", "write", "read", "write", "flush", "read"],
            prefetch=False)


# ======================================================== async token oracle
def _build_async(num_sets, ways, block_elems, n_devices, queue_depth, rng,
                 *, prefetch=False):
    size = int(rng.integers(block_elems, 6 * block_elems * max(num_sets, 1)))
    data = rng.standard_normal(size).astype(np.float32)
    arr, st_ = BamArray.build(
        data, block_elems=block_elems, num_sets=num_sets, ways=ways,
        num_queues=2 * n_devices, queue_depth=queue_depth,
        ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, n_devices),
        prefetch=PrefetchConfig(enabled=True, window=4) if prefetch
        else None)
    return size, data.copy(), arr, st_


def run_async_ops(num_sets, ways, block_elems, n_devices, queue_depth,
                  seed, n_steps, *, max_window=4, prefetch=False):
    """Random submit/wait interleavings vs the numpy oracle.

    Semantics under test: an op *completes at wait*.  Reads observe every
    write whose token was waited before theirs; writes apply in wait
    order; prefetch tokens are invisible.  After every token is waited the
    cache must hold no pins and no in-flight lines, and a final
    flush + read-back must equal the oracle exactly.
    """
    rng = np.random.default_rng(seed)
    size, oracle, arr, st_ = _build_async(
        num_sets, ways, block_elems, n_devices, queue_depth, rng,
        prefetch=prefetch)
    pending = []                      # [(kind, idx, write_vals, token)]

    def wait_one(st_):
        i = int(rng.integers(len(pending)))    # random completion order
        kind, idxs, wv, tok = pending.pop(i)
        st_, vals = arr.wait(st_, tok)
        valid = (idxs >= 0) & (idxs < size)
        if kind == "read":
            expect = np.where(valid, oracle[np.clip(idxs, 0, size - 1)],
                              0.0)
            np.testing.assert_array_equal(np.asarray(vals), expect)
        elif kind == "write":
            oracle[idxs[valid]] = wv[valid]
        return st_

    for _ in range(n_steps):
        if pending and (len(pending) >= max_window or rng.random() < 0.4):
            st_ = wait_one(st_)
            continue
        kind = AOPS[int(rng.integers(len(AOPS)))]
        m = int(rng.integers(1, 25))
        idxs = rng.integers(-2, size + 3, m).astype(np.int32)
        if kind == "write":
            # wait-order is the write order; keep each wavefront's element
            # indices unique so the oracle is deterministic.
            idxs = np.unique(idxs)
            wv = rng.standard_normal(len(idxs)).astype(np.float32)
            st_, tok = arr.submit(st_, IORequest.write(
                jnp.asarray(idxs), jnp.asarray(wv)))
            pending.append(("write", idxs, wv, tok))
        elif kind == "read":
            st_, tok = arr.submit(st_, IORequest.read(jnp.asarray(idxs)))
            pending.append(("read", idxs, None, tok))
        else:
            st_, tok = arr.submit(st_, IORequest.prefetch(jnp.asarray(idxs)))
            pending.append(("prefetch", idxs, None, tok))

    while pending:
        st_ = wait_one(st_)

    # every pin released, every in-flight line completed
    assert int(np.asarray(st_.cache.refcount).sum()) == 0, \
        "refcounts did not return to zero after all tokens were waited"
    assert not bool(np.asarray(st_.cache.inflight).any()), \
        "in-flight lines left behind after all tokens were waited"
    assert float(st_.metrics.tokens_in_flight) == 0.0

    st_ = arr.flush(st_)
    assert not bool(st_.cache.dirty.any())
    flat = np.asarray(arr.storage.data).reshape(-1)[:size]
    np.testing.assert_array_equal(flat, oracle)
    vals, st_ = arr.read(st_, jnp.arange(size, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(vals), oracle)


@given(st.integers(1, 8),                   # num_sets
       st.integers(1, 4),                   # ways
       st.sampled_from([2, 4, 8]),          # block_elems
       st.integers(1, 2),                   # n_devices
       st.sampled_from([2, 8, 64]),         # queue_depth (2 forces drops)
       st.integers(0, 2 ** 31 - 1),         # data / interleaving seed
       st.integers(2, 10),                  # steps
       st.integers(1, 6),                   # max outstanding tokens
       st.booleans())                       # stride readahead on/off
@settings(max_examples=12, deadline=None)
def test_async_tokens_match_numpy_oracle(num_sets, ways, block_elems,
                                         n_devices, queue_depth, seed,
                                         n_steps, max_window, prefetch):
    run_async_ops(num_sets, ways, block_elems, n_devices, queue_depth,
                  seed, n_steps, max_window=max_window, prefetch=prefetch)


# Fixed-seed slices of the async property: run even without hypothesis.
_ASYNC_EXAMPLES = [
    # (num_sets, ways, block_elems, n_devices, depth, seed, steps, window, pf)
    (4, 2, 4, 1, 64, 0, 10, 4, False),
    (1, 1, 2, 1, 2, 1, 12, 2, False),      # 1-line cache, drops galore
    (8, 4, 8, 2, 8, 2, 14, 6, True),
    (2, 3, 4, 2, 4, 3, 10, 3, True),
    (5, 2, 2, 1, 8, 4, 12, 5, False),
]


@pytest.mark.parametrize("case", _ASYNC_EXAMPLES,
                         ids=[f"seed{c[5]}" for c in _ASYNC_EXAMPLES])
def test_async_oracle_examples(case):
    run_async_ops(*case[:7], max_window=case[7], prefetch=case[8])


def test_flush_inside_submission_window_keeps_read_accounting():
    """A flush between submit and wait drains the pending read commands;
    their device time and per-device counts must be charged (at the flush
    barrier), not silently dropped — totals match the flush-after-wait
    ordering exactly."""
    rng = np.random.default_rng(0)
    data = rng.standard_normal((64, 8)).astype(np.float32)

    def build():
        return BamArray.build(data.copy(), block_elems=8, num_sets=16,
                              ways=4)

    idx = jnp.arange(0, 32 * 8, 8, dtype=jnp.int32)     # 32 distinct blocks
    arr_a, st_a = build()
    st_a, tok = arr_a.submit(st_a, IORequest.read(idx))
    st_a = arr_a.flush(st_a)                  # drains the pending reads
    st_a, va = arr_a.wait(st_a, tok)

    arr_b, st_b = build()
    st_b, tok = arr_b.submit(st_b, IORequest.read(idx))
    st_b, vb = arr_b.wait(st_b, tok)
    st_b = arr_b.flush(st_b)

    np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
    ma, mb = st_a.metrics.summary(), st_b.metrics.summary()
    assert ma["read_time_s"] > 0
    for f in ("sim_time_s", "read_time_s", "write_time_s", "dev_reads",
              "dev_time_s"):
        assert ma[f] == mb[f], (f, ma[f], mb[f])


def test_prefetch_on_inflight_line_counts_cross_op_coalesce():
    """A prefetch hint landing on a line a pending token is already
    fetching enqueues nothing and bumps ``cross_op_coalesced``."""
    rng = np.random.default_rng(0)
    data = rng.standard_normal((64, 8)).astype(np.float32)
    arr, st_ = BamArray.build(data, block_elems=8, num_sets=16, ways=4)
    idx = jnp.arange(0, 8 * 8, 8, dtype=jnp.int32)      # 8 distinct blocks
    st_, tok_r = arr.submit(st_, IORequest.read(idx))
    before = float(st_.metrics.cross_op_coalesced)
    st_, tok_p = arr.submit(st_, IORequest.prefetch(idx))
    assert float(st_.metrics.cross_op_coalesced) == before + 8
    assert float(st_.metrics.prefetch_issued) == 0      # nothing re-claimed
    st_, _ = arr.wait(st_, tok_r)
    st_, _ = arr.wait(st_, tok_p)
    assert int(np.asarray(st_.cache.refcount).sum()) == 0


def test_legacy_shims_bit_exact_vs_submit_wait():
    """Acceptance criterion: ``read``/``write``/``prefetch`` ≡ an immediate
    ``submit``+``wait`` of the same :class:`IORequest` — values bit-exact
    and every metric identical on the same op stream."""
    # two independent builds from the same seed: identical data, separate
    # host storage tiers (the sim backend mutates its numpy array in place)
    size, oracle, arr_a, st_a = _build_async(
        4, 2, 4, 2, 16, np.random.default_rng(11), prefetch=True)
    size_b, _, arr_b, st_b = _build_async(
        4, 2, 4, 2, 16, np.random.default_rng(11), prefetch=True)
    assert size == size_b
    script = []
    r2 = np.random.default_rng(12)
    for _ in range(8):
        kind = AOPS[int(r2.integers(len(AOPS)))]
        idxs = r2.integers(-2, size + 3, int(r2.integers(1, 20)))
        idxs = np.unique(idxs.astype(np.int32)) if kind == "write" else \
            idxs.astype(np.int32)
        wv = r2.standard_normal(len(idxs)).astype(np.float32)
        script.append((kind, jnp.asarray(idxs), jnp.asarray(wv)))

    for kind, idxs, wv in script:
        if kind == "read":
            va, st_a = arr_a.read(st_a, idxs)
            st_b, tok = arr_b.submit(st_b, IORequest.read(idxs))
            st_b, vb = arr_b.wait(st_b, tok)
            np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
        elif kind == "write":
            st_a = arr_a.write(st_a, idxs, wv)
            st_b, tok = arr_b.submit(st_b, IORequest.write(idxs, wv))
            st_b, _ = arr_b.wait(st_b, tok)
        else:
            st_a = arr_a.prefetch(st_a, idxs)
            st_b, tok = arr_b.submit(st_b, IORequest.prefetch(idxs))
            st_b, _ = arr_b.wait(st_b, tok)
        # Full state equivalence, metrics included, after every op.
        for field, a in st_a.metrics.summary().items():
            b = st_b.metrics.summary()[field]
            assert a == b, f"metric {field}: shim={a} submit+wait={b}"
        np.testing.assert_array_equal(np.asarray(st_a.cache.tags),
                                      np.asarray(st_b.cache.tags))
        np.testing.assert_array_equal(np.asarray(st_a.cache.data),
                                      np.asarray(st_b.cache.data))
