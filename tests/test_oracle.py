"""Differential oracle: a BamArray behaves like a plain numpy array.

Random sequences of ``read``/``write``/``prefetch``/``flush`` over random
cache geometry / queue depth / device count are checked element-for-element
against a host numpy array, and the storage tier is compared byte-for-byte
after every ``flush``.  Whatever the cache decides (hit, miss, bypass,
speculative fill, write-back, ring drop), the *values* must be those of the
oracle — the "degrades accounting, never correctness" contract.

The hypothesis-driven search runs when hypothesis is installed (the CI
profile is derandomized, see ``conftest.py``); the example-based runs below
exercise the same engine from fixed seeds so the tier-1 suite keeps this
coverage with nothing but jax/numpy/pytest.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import BamArray, PrefetchConfig
from repro.core.ssd import ArrayOfSSDs, INTEL_OPTANE_P5800X

OPS = ("read", "write", "prefetch", "flush")


def run_ops(num_sets, ways, block_elems, n_devices, queue_depth,
            seed, op_kinds, *, prefetch=False):
    """Execute one op sequence against both BamArray and the numpy oracle."""
    rng = np.random.default_rng(seed)
    size = int(rng.integers(block_elems, 6 * block_elems * max(num_sets, 1)))
    data = rng.standard_normal(size).astype(np.float32)
    oracle = data.copy()
    arr, st_ = BamArray.build(
        data, block_elems=block_elems, num_sets=num_sets, ways=ways,
        num_queues=2 * n_devices, queue_depth=queue_depth,
        ssd=ArrayOfSSDs(INTEL_OPTANE_P5800X, n_devices),
        prefetch=PrefetchConfig(enabled=True, window=4) if prefetch
        else None)

    def check_storage():
        flat = np.asarray(arr.storage.data).reshape(-1)[:size]
        np.testing.assert_array_equal(flat, oracle)

    for kind in op_kinds:
        m = int(rng.integers(1, 25))
        # indices deliberately include invalid lanes (<0 and >= size)
        idx = rng.integers(-2, size + 3, m).astype(np.int32)
        valid = (idx >= 0) & (idx < size)
        if kind == "read":
            vals, st_ = arr.read(st_, jnp.asarray(idx))
            expect = np.where(valid, oracle[np.clip(idx, 0, size - 1)], 0.0)
            np.testing.assert_allclose(np.asarray(vals), expect, rtol=0,
                                       atol=0)
        elif kind == "write":
            # duplicate element indices are last-writer-wins with
            # *unspecified* order (as on the GPU) — keep the oracle
            # deterministic by writing unique indices per wavefront.
            uidx = np.unique(idx)
            wvals = rng.standard_normal(len(uidx)).astype(np.float32)
            st_ = arr.write(st_, jnp.asarray(uidx), jnp.asarray(wvals))
            wvalid = (uidx >= 0) & (uidx < size)
            oracle[uidx[wvalid]] = wvals[wvalid]
        elif kind == "prefetch":
            st_ = arr.prefetch(st_, jnp.asarray(idx))      # no visible effect
        elif kind == "flush":
            st_ = arr.flush(st_)
            assert not bool(st_.cache.dirty.any()), \
                "flush left dirty lines behind"
            check_storage()

    # closing barrier: flush everything, then storage and a full read-back
    # must both equal the oracle.
    st_ = arr.flush(st_)
    assert not bool(st_.cache.dirty.any())
    check_storage()
    vals, st_ = arr.read(st_, jnp.arange(size, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(vals), oracle)


@given(st.integers(1, 8),                   # num_sets
       st.integers(1, 4),                   # ways
       st.sampled_from([2, 4, 8]),          # block_elems
       st.integers(1, 2),                   # n_devices
       st.sampled_from([2, 8, 64]),         # queue_depth (2 forces drops)
       st.integers(0, 2 ** 31 - 1),         # data / wavefront seed
       st.lists(st.sampled_from(OPS), min_size=1, max_size=8),
       st.booleans())                       # stride readahead on/off
@settings(max_examples=25, deadline=None)
def test_bam_array_matches_numpy_oracle(num_sets, ways, block_elems,
                                        n_devices, queue_depth, seed,
                                        op_kinds, prefetch):
    run_ops(num_sets, ways, block_elems, n_devices, queue_depth, seed,
            op_kinds, prefetch=prefetch)


# Fixed-seed slices of the same property: run even without hypothesis.
_EXAMPLES = [
    # (num_sets, ways, block_elems, n_devices, depth, seed, ops, prefetch)
    (4, 2, 4, 1, 64, 0,
     ["read", "write", "read", "flush", "read"], False),
    (1, 1, 2, 1, 2, 1,
     ["write", "read", "write", "flush", "prefetch", "read"], False),
    (8, 4, 8, 2, 8, 2,
     ["prefetch", "read", "write", "read", "write", "flush"], True),
    (2, 3, 4, 2, 4, 3,
     ["write", "flush", "write", "read", "flush", "read", "read"], True),
    (5, 2, 2, 1, 8, 4,
     ["read"] * 3 + ["write"] * 2 + ["flush", "read"], False),
]


@pytest.mark.parametrize("case", _EXAMPLES,
                         ids=[f"seed{c[5]}" for c in _EXAMPLES])
def test_oracle_examples(case):
    num_sets, ways, block_elems, n_devices, depth, seed, ops, pf = case
    run_ops(num_sets, ways, block_elems, n_devices, depth, seed, ops,
            prefetch=pf)


def test_oracle_tiny_queue_forces_drops_not_corruption():
    """With depth 2 the rings drop most commands; values must still match
    (read-through / write-through), only accounting degrades."""
    run_ops(4, 2, 4, 1, 2, 7,
            ["read", "write", "read", "write", "flush", "read"],
            prefetch=False)
