"""Prefetch subsystem: stride detection, bounds clamping, speculative-line
evictability ordering, queue priority, and prefetch-vs-demand accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BamArray, PrefetchConfig, modal_stride,
                        readahead_keys)
from repro.core import cache as C
from repro.core import queues as Q


def keys_of(xs):
    k = jnp.asarray(xs, jnp.int32)
    return k, k >= 0


# ------------------------------------------------------------ stride detect
def test_modal_stride_sequential():
    k, v = keys_of([0, 1, 2, 3, 4, 5, 6, 7])
    stride, support, n = modal_stride(k, v)
    assert int(stride) == 1 and int(support) == 7 and int(n) == 7


def test_modal_stride_strided():
    k, v = keys_of([0, 4, 8, 12])
    stride, support, n = modal_stride(k, v)
    assert int(stride) == 4 and int(support) == 3 and int(n) == 3


def test_modal_stride_unsorted_input():
    k, v = keys_of([12, 0, 8, 4])                # detector sorts internally
    stride, support, _ = modal_stride(k, v)
    assert int(stride) == 4 and int(support) == 3


def test_modal_stride_ignores_invalid_lanes():
    k, v = keys_of([0, 2, 4, -1, -1])
    stride, support, n = modal_stride(k, v)
    assert int(stride) == 2 and int(support) == 2 and int(n) == 2


def test_modal_stride_too_few_keys():
    k, v = keys_of([5])
    stride, support, n = modal_stride(k, v)
    assert int(stride) == 0 and int(support) == 0 and int(n) == 0


def test_modal_stride_mixed_pattern_low_support():
    k, v = keys_of([0, 1, 3, 6])                 # deltas 1, 2, 3: no mode > 1
    _, support, n = modal_stride(k, v)
    assert int(support) == 1 and int(n) == 3


# ------------------------------------------------------------- extrapolation
def test_readahead_extrapolates_sequential():
    k, v = keys_of([0, 1, 2, 3])
    out = readahead_keys(k, v, window=4, num_blocks=100)
    assert out.tolist() == [4, 5, 6, 7]


def test_readahead_extrapolates_stride():
    k, v = keys_of([10, 14, 18])
    out = readahead_keys(k, v, window=3, num_blocks=100)
    assert out.tolist() == [22, 26, 30]


def test_readahead_clamps_at_array_bound():
    k, v = keys_of([94, 95, 96, 97])
    out = readahead_keys(k, v, window=4, num_blocks=100)
    assert out.tolist() == [98, 99, -1, -1]      # window clamp at the end


def test_readahead_fully_past_bound():
    k, v = keys_of([97, 98, 99])
    out = readahead_keys(k, v, window=4, num_blocks=100)
    assert out.tolist() == [-1, -1, -1, -1]


def test_readahead_no_pattern_no_traffic():
    k, v = keys_of([3, 17, 50, 90])              # all deltas distinct
    out = readahead_keys(k, v, window=4, num_blocks=1000)
    assert out.tolist() == [-1] * 4


def test_readahead_respects_max_stride():
    k, v = keys_of([0, 100, 200])
    out = readahead_keys(k, v, window=4, num_blocks=10_000, max_stride=64)
    assert out.tolist() == [-1] * 4


def test_readahead_min_support_bar():
    # deltas 1,1,1,5: support 3/4 passes 0.75, fails 0.9
    k, v = keys_of([0, 1, 2, 3, 8])
    hi = readahead_keys(k, v, window=2, num_blocks=100, min_support=0.75)
    lo = readahead_keys(k, v, window=2, num_blocks=100, min_support=0.9)
    assert hi.tolist() == [9, 10]                # extrapolates past max key
    assert lo.tolist() == [-1, -1]


def test_readahead_descending_scan_extrapolates_downward():
    # raw wavefront order carries the direction the sorted keys lost
    raw, rv = keys_of([50, 49, 48, 47])
    k, v = keys_of([47, 48, 49, 50])             # coalesced (sorted) keys
    out = readahead_keys(k, v, window=3, num_blocks=100,
                         raw_keys=raw, raw_valid=rv)
    assert out.tolist() == [46, 45, 44]


def test_readahead_descending_clamps_at_zero():
    raw, rv = keys_of([2, 1, 0])
    k, v = keys_of([0, 1, 2])
    out = readahead_keys(k, v, window=4, num_blocks=100,
                         raw_keys=raw, raw_valid=rv)
    assert out.tolist() == [-1, -1, -1, -1]


def test_readahead_zero_window():
    k, v = keys_of([0, 1, 2])
    assert readahead_keys(k, v, window=0, num_blocks=10).shape == (0,)


def test_prefetch_config_validation():
    with pytest.raises(ValueError):
        PrefetchConfig(window=-1)
    with pytest.raises(ValueError):
        PrefetchConfig(min_support=0.0)


# ------------------------------------------------- speculative evictability
def _fill_set(cache, keys, speculative=False):
    kj = jnp.asarray(keys, jnp.int32)
    pr = C.probe(cache, kj)
    cache, alloc = C.allocate(cache, kj, ~pr.hit, protect_slots=pr.slot,
                              speculative=speculative)
    lines = jnp.repeat(jnp.asarray(keys, jnp.float32)[:, None],
                       cache.line_elems, axis=1)
    return C.fill(cache, alloc.slot, alloc.ok, lines), alloc


def test_speculative_lines_evicted_before_demand():
    cache = C.make_cache(1, 4, 2)
    cache, _ = _fill_set(cache, [1, 2])                     # demand
    cache, _ = _fill_set(cache, [3, 4], speculative=True)   # prefetched
    # set is full; a demand miss must reclaim a speculative line first
    cache, alloc = C.allocate(cache, jnp.asarray([5], jnp.int32),
                              jnp.asarray([True]))
    assert bool(alloc.ok[0])
    assert int(alloc.evicted_key[0]) in (3, 4)
    for k in (1, 2):
        assert bool(C.probe(cache, jnp.asarray([k], jnp.int32)).hit[0])


def test_promote_clears_evict_first_status():
    cache = C.make_cache(1, 4, 2)
    cache, _ = _fill_set(cache, [1, 2])
    cache, _ = _fill_set(cache, [3, 4], speculative=True)
    pr4 = C.probe(cache, jnp.asarray([4], jnp.int32))
    assert bool(pr4.hit[0]) and bool(pr4.speculative[0])
    cache = C.promote(cache, pr4.slot)                      # demand hit on 4
    pr4b = C.probe(cache, jnp.asarray([4], jnp.int32))
    assert bool(pr4b.hit[0]) and not bool(pr4b.speculative[0])
    # the remaining unpromoted speculative line (3) is now the victim
    cache, alloc = C.allocate(cache, jnp.asarray([5], jnp.int32),
                              jnp.asarray([True]))
    assert int(alloc.evicted_key[0]) == 3
    assert bool(C.probe(cache, jnp.asarray([4], jnp.int32)).hit[0])


def test_speculative_alloc_never_cannibalizes_pending_prefetch():
    cache = C.make_cache(1, 2, 2)
    cache, _ = _fill_set(cache, [3, 4], speculative=True)   # set full of hints
    kj = jnp.asarray([7], jnp.int32)
    cache, alloc = C.allocate(cache, kj, jnp.asarray([True]),
                              speculative=True)
    assert not bool(alloc.ok[0])                 # hint dropped, nothing evicted
    for k in (3, 4):
        assert bool(C.probe(cache, jnp.asarray([k], jnp.int32)).hit[0])


def test_speculative_alloc_not_counted_as_demand_miss():
    cache = C.make_cache(2, 2, 2)
    cache, _ = _fill_set(cache, [1, 2], speculative=True)
    assert int(cache.misses) == 0 and int(cache.bypasses) == 0


# --------------------------------------------------------- queue priority
def test_demand_drains_before_readahead():
    qs = Q.make_queues(2, 8)
    qs, _ = Q.enqueue(qs, jnp.asarray([10, 11], jnp.int32),
                      prio=Q.PRIO_READAHEAD)
    qs, _ = Q.enqueue(qs, jnp.asarray([1, 2], jnp.int32))
    qs, comps = Q.service_all(qs)
    mask = np.asarray(comps.valid)
    keys = np.asarray(comps.keys)[mask]
    prio = np.asarray(comps.prio)[mask]
    assert sorted(keys.tolist()) == [1, 2, 10, 11]          # nothing lost
    assert prio.tolist() == sorted(prio.tolist())           # demand first
    assert set(keys[prio == Q.PRIO_DEMAND].tolist()) == {1, 2}


# ----------------------------------------------------- BamArray end-to-end
def build(n_blocks=64, line=8, *, prefetch=None, num_sets=16, ways=8):
    rng = np.random.default_rng(0)
    data = rng.standard_normal((n_blocks, line)).astype(np.float32)
    arr, st = BamArray.build(data, block_elems=line, num_sets=num_sets,
                             ways=ways, prefetch=prefetch, backend="sim")
    return data, arr, st


def scan(arr, st, n, wavefront=32):
    read = jax.jit(arr.read)
    outs = []
    for start in range(0, n, wavefront):
        idx = jnp.arange(start, start + wavefront, dtype=jnp.int32)
        v, st = read(st, idx)
        outs.append(np.asarray(v))
    return np.concatenate(outs), st


def test_sequential_scan_readahead_hits_and_correctness():
    cfg = PrefetchConfig(enabled=True, window=8)
    data, arr, st = build(prefetch=cfg)
    got, st = scan(arr, st, data.size)
    np.testing.assert_allclose(got, data.reshape(-1), rtol=1e-6)
    m = st.metrics.summary()
    _, arr0, st0 = build()
    _, st0 = scan(arr0, st0, data.size)
    m0 = st0.metrics.summary()
    assert m["hit_rate"] > m0["hit_rate"]
    assert m["misses"] < m0["misses"]
    assert m["prefetch_hits"] > 0
    assert m["prefetch_accuracy"] == pytest.approx(1.0)
    # readahead fetched exactly the lines demand was about to read: the
    # total bytes moved (and so the amplification) match the demand run.
    assert m["bytes_from_storage"] == m0["bytes_from_storage"]


def test_reverse_scan_readahead_no_wasted_bytes():
    cfg = PrefetchConfig(enabled=True, window=8)
    data, arr, st = build(prefetch=cfg)
    read = jax.jit(arr.read)
    outs = []
    for start in range(data.size - 32, -1, -32):    # descending wavefronts
        idx = jnp.arange(start + 31, start - 1, -1, dtype=jnp.int32)
        v, st = read(st, idx)
        outs.append(np.asarray(v))
    got = np.concatenate(outs)
    want = data.reshape(-1)[::-1].copy()
    np.testing.assert_allclose(got, want, rtol=1e-6)
    m = st.metrics.summary()
    _, arr0, st0 = build()
    read0 = jax.jit(arr0.read)
    for start in range(data.size - 32, -1, -32):
        idx = jnp.arange(start + 31, start - 1, -1, dtype=jnp.int32)
        _, st0 = read0(st0, idx)
    m0 = st0.metrics.summary()
    assert m["hit_rate"] > m0["hit_rate"]
    assert m["prefetch_accuracy"] == pytest.approx(1.0)
    assert m["bytes_from_storage"] == m0["bytes_from_storage"]


def test_random_access_triggers_no_readahead():
    cfg = PrefetchConfig(enabled=True, window=8)
    data, arr, st = build(prefetch=cfg)
    # blocks 0, 1, 3, 6: deltas all distinct, below min_support
    idx = jnp.asarray([0, 8, 24, 48], jnp.int32)
    _, st = arr.read(st, idx)
    assert float(st.metrics.prefetch_issued) == 0.0


def test_explicit_prefetch_warms_cache_without_demand_traffic():
    data, arr, st = build()                      # auto-readahead disabled
    idx = jnp.arange(32, dtype=jnp.int32)        # blocks 0..3
    st = jax.jit(arr.prefetch)(st, idx)
    m = st.metrics.summary()
    assert m["requests"] == 0 and m["misses"] == 0 and m["hits"] == 0
    assert m["prefetch_issued"] == 4
    assert m["bytes_from_storage"] == 4 * 8 * 4
    vals, st = arr.read(st, idx)
    np.testing.assert_allclose(np.asarray(vals), data.reshape(-1)[:32],
                               rtol=1e-6)
    m2 = st.metrics.summary()
    assert m2["misses"] == 0 and m2["hits"] == 4
    assert m2["prefetch_hits"] == 4              # all demand hits were warmed
    assert m2["prefetch_accuracy"] == pytest.approx(1.0)


def test_explicit_prefetch_ignores_invalid_and_resident():
    data, arr, st = build()
    idx = jnp.arange(32, dtype=jnp.int32)
    st = arr.prefetch(st, idx)
    issued1 = float(st.metrics.prefetch_issued)
    # again, plus out-of-range lanes: nothing new to fetch
    st = arr.prefetch(st, jnp.asarray([-3, 0, 31, 10_000], jnp.int32))
    assert float(st.metrics.prefetch_issued) == issued1


def test_bfs_frontier_prefetch_parity():
    from repro.graph import BamGraph, bfs, bfs_oracle, random_graph
    indptr, dst = random_graph(200, 4.0, seed=1)
    want = bfs_oracle(indptr, dst, 0)
    d0, _ = bfs(BamGraph.build(indptr, dst, cacheline_bytes=512), 0)
    d1, st = bfs(BamGraph.build(indptr, dst, cacheline_bytes=512), 0,
                 prefetch=True)
    np.testing.assert_array_equal(d0, want)
    np.testing.assert_array_equal(d1, want)     # hints never change results
    assert float(st.metrics.prefetch_issued) > 0


def test_cc_warmup_prefetch_parity():
    from repro.graph import BamGraph, cc, cc_oracle, random_graph
    indptr, dst = random_graph(120, 3.0, seed=2)
    want = cc_oracle(indptr, dst)
    l0, _ = cc(BamGraph.build(indptr, dst, cacheline_bytes=512))
    l1, st = cc(BamGraph.build(indptr, dst, cacheline_bytes=512),
                prefetch=True)
    np.testing.assert_array_equal(l0, want)
    np.testing.assert_array_equal(l1, want)
    assert float(st.metrics.prefetch_issued) > 0


def test_disabled_prefetch_is_inert():
    data, arr, st = build(prefetch=PrefetchConfig(enabled=False, window=8))
    got, st = scan(arr, st, data.size)
    np.testing.assert_allclose(got, data.reshape(-1), rtol=1e-6)
    m = st.metrics.summary()
    assert m["prefetch_issued"] == 0 and m["prefetch_hits"] == 0
