"""Fused probe+allocate kernel: differential sweeps + core bit-identity.

Three layers of evidence that the hot-path fusion changed *nothing*:

1. the Pallas kernel (``impl='pallas', interpret=True``) is bit-identical
   to the jnp oracle across a (num_sets × ways × batch) grid, including
   tenant way windows, protect slots, speculative insert mode, foreign
   dirty lines and duplicate-key wavefronts;
2. the fused core op ``cache.probe_allocate`` (``impl='ref'``) is
   bit-identical — outputs AND the whole updated ``CacheState`` — to
   today's inline-jnp two-step path (``cache.probe`` + the stable-argsort
   ``cache.allocate``), across the same grid and cache data dtypes;
3. a ``BamArray`` driven end-to-end with ``kernel_impl='pallas'``
   (interpret mode) returns the same values and metrics as
   ``kernel_impl='ref'``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BamArray, IORequest
from repro.core import cache as C
from repro.kernels import ops

jax.config.update("jax_platform_name", "cpu")


def _random_state(rng, S, W, line_elems=8, dtype=jnp.float32):
    """A random-but-consistent CacheState: arbitrary tags/flags, so the
    sweep hits every eligibility rule at once."""
    return C.CacheState(
        num_sets=S, ways=W, line_elems=line_elems,
        tags=jnp.asarray(rng.integers(-1, 6 * S * W, (S, W)), jnp.int32),
        owner=jnp.asarray(rng.integers(0, 3, (S, W)), jnp.int32),
        refcount=jnp.asarray(
            rng.integers(0, 2, (S, W)) * rng.integers(1, 3, (S, W)),
            jnp.int32),
        dirty=jnp.asarray(rng.integers(0, 2, (S, W)).astype(bool)),
        speculative=jnp.asarray(rng.integers(0, 2, (S, W)).astype(bool)),
        inflight=jnp.asarray(rng.integers(0, 2, (S, W)).astype(bool)),
        clock_hand=jnp.asarray(rng.integers(0, W, (S,)), jnp.int32),
        data=jnp.asarray(rng.standard_normal((S * W, line_elems)), dtype),
        hits=jnp.zeros((), jnp.int32), misses=jnp.zeros((), jnp.int32),
        bypasses=jnp.zeros((), jnp.int32))


def _wavefront(rng, S, W, m, duplicates=True):
    """Keys with invalid lanes and (optionally) duplicate keys."""
    hi = 6 * S * W
    keys = rng.integers(-1, hi, m)
    if duplicates and m >= 4:
        keys[m // 2:] = rng.choice(keys[:m // 2], m - m // 2)
    return jnp.asarray(keys, jnp.int32)


GRID = [(4, 1, 7), (8, 4, 33), (16, 8, 64), (64, 4, 129)]
VARIANTS = [
    dict(),
    dict(tenant=1),
    dict(way_lo=1, way_hi=3),
    dict(spec_insert=True),
    dict(protect_hits=False),
    dict(tenant=2, way_lo=0, way_hi=2, spec_insert=True),
]


@pytest.mark.parametrize("S,W,m", GRID)
@pytest.mark.parametrize("vi", range(len(VARIANTS)))
def test_pallas_matches_oracle(S, W, m, vi):
    kw = dict(VARIANTS[vi])
    if W == 1 and "way_hi" in kw:
        pytest.skip("way window needs ways > 1")
    if W < 4 and kw.get("way_hi", 0) > W:
        kw["way_hi"] = W
    rng = np.random.default_rng(1000 * vi + S + W + m)
    st = _random_state(rng, S, W)
    keys = _wavefront(rng, S, W, m)
    prot = jnp.asarray(rng.integers(-1, S * W, max(m // 4, 1)), jnp.int32)
    amask = jnp.asarray(rng.integers(0, 2, m).astype(bool))
    args = (st.tags, st.owner, st.refcount, st.dirty, st.speculative,
            st.clock_hand, keys)
    r = ops.probe_allocate(*args, protect_slots=prot, alloc_mask=amask,
                           impl="ref", **kw)
    p = ops.probe_allocate(*args, protect_slots=prot, alloc_mask=amask,
                           impl="pallas", interpret=True, **kw)
    names = ("hit", "hit_slot", "way", "ok", "evicted_key", "evicted_dirty")
    for name, a, b in zip(names, r, p):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"probe_allocate {name} (S={S},W={W},m={m},kw={kw})")


def _tree_equal(a, b, msg):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


@pytest.mark.parametrize("S,W,m", GRID)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_fused_core_matches_inline_two_step(S, W, m, dtype):
    """`cache.probe_allocate(impl='ref')` == today's inline-jnp path:
    `probe` + the stable-argsort `allocate(protect_slots=probe.slot)`,
    comparing results and the full updated CacheState bit-for-bit."""
    rng = np.random.default_rng(S * W + m)
    for kw in (dict(), dict(tenant=1),
               *( [dict(way_lo=1, way_hi=min(3, W))] if W > 1 else [] ),
               dict(speculative=True)):
        st = _random_state(rng, S, W, dtype=dtype)
        keys = _wavefront(rng, S, W, m)
        valid = keys >= 0
        spec = bool(kw.pop("speculative", False))

        pr = C.probe(st, keys, valid, tenant=kw.get("tenant", 0))
        miss = valid & ~pr.hit
        st_ref, alloc_ref = C.allocate(st, keys, miss,
                                       protect_slots=pr.slot,
                                       speculative=spec, **kw)
        st_fused, pr2, alloc2 = C.probe_allocate(st, keys, valid,
                                                 speculative=spec,
                                                 impl="ref", **kw)
        msg = f"S={S} W={W} m={m} kw={kw} spec={spec}"
        _tree_equal(pr, pr2, f"probe result: {msg}")
        _tree_equal(alloc_ref, alloc2, f"alloc result: {msg}")
        _tree_equal(st_ref, st_fused, f"cache state: {msg}")


def test_fused_respects_extra_protect_and_alloc_mask():
    """protect_slots / alloc_mask / protect_hits=False mirror the exact
    readahead-call contract of the two-step path."""
    rng = np.random.default_rng(5)
    S, W, m = 16, 4, 40
    st = _random_state(rng, S, W)
    keys = _wavefront(rng, S, W, m)
    valid = keys >= 0
    prot = jnp.asarray(rng.integers(-1, S * W, 9), jnp.int32)
    amask = jnp.asarray(rng.integers(0, 2, m).astype(bool))

    pr = C.probe(st, keys, valid)
    want = valid & ~pr.hit & amask
    st_ref, alloc_ref = C.allocate(st, keys, want, protect_slots=prot,
                                   speculative=True)
    st_fused, pr2, alloc2 = C.probe_allocate(
        st, keys, valid, alloc_mask=amask, protect_slots=prot,
        protect_hits=False, speculative=True, impl="ref")
    _tree_equal(pr, pr2, "probe result")
    _tree_equal(alloc_ref, alloc2, "alloc result")
    _tree_equal(st_ref, st_fused, "cache state")


def test_probe_owner_namespacing_matches_inline():
    """Kernel-dispatched probe honours the tenant owner stamp."""
    rng = np.random.default_rng(9)
    st = _random_state(rng, 8, 4)
    keys = _wavefront(rng, 8, 4, 30)
    for tenant in (0, 1, 2):
        pr_ref = C.probe(st, keys, tenant=tenant, impl="ref")
        pr_pal = C.probe(st, keys, tenant=tenant, impl="pallas")
        _tree_equal(pr_ref, pr_pal, f"probe tenant={tenant}")
        # inline recomputation of the tag match
        sets = np.asarray(pr_ref.set_idx)
        hit = np.asarray(pr_ref.hit)
        tags = np.asarray(st.tags)
        owner = np.asarray(st.owner)
        kk = np.asarray(keys)
        for i, k in enumerate(kk):
            expect = bool(k >= 0 and np.any(
                (tags[sets[i]] == k) & (owner[sets[i]] == tenant)))
            assert expect == bool(hit[i])


@pytest.mark.parametrize("write", [False, True])
def test_end_to_end_pallas_interpret_matches_ref(write):
    """BamArray with kernel_impl='pallas' (interpret) == kernel_impl='ref'
    end to end: values, metrics, and final cache state."""
    rng = np.random.default_rng(42)
    data = rng.standard_normal((64, 8)).astype(np.float32)
    outs = {}
    for impl in ("ref", "pallas"):
        # data.copy(): the sim backend writes through to the host buffer,
        # and the two runs must start from identical storage
        arr, st = BamArray.build(data.copy(), block_elems=8, num_sets=8,
                                 ways=2, kernel_impl=impl)
        vals = []
        for step in range(4):
            # deterministic per-step indices shared across impls
            idx = jnp.asarray((np.arange(24) * (7 + step)) % data.size,
                              jnp.int32)
            if write and step == 2:
                st = arr.write(st, idx,
                               jnp.arange(24, dtype=jnp.float32))
            v, st = arr.read(st, idx)
            vals.append(np.asarray(v))
        outs[impl] = (vals, st)
    vals_r, st_r = outs["ref"]
    vals_p, st_p = outs["pallas"]
    for a, b in zip(vals_r, vals_p):
        np.testing.assert_array_equal(a, b)
    _tree_equal(st_r.cache, st_p.cache, "final cache state")
    _tree_equal(st_r.metrics, st_p.metrics, "final metrics")


def test_kernel_impl_validated():
    data = np.zeros((16, 4), np.float32)
    with pytest.raises(ValueError, match="kernel_impl"):
        BamArray.build(data, block_elems=4, num_sets=4,
                       kernel_impl="vulkan")
