"""Property tests: SQ/CQ rings never lose or duplicate commands."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import queues as Q


@given(st.integers(1, 4), st.integers(2, 8),
       st.lists(st.lists(st.integers(-2, 100), min_size=1, max_size=24),
                min_size=1, max_size=6))
@settings(max_examples=100, deadline=None)
def test_no_loss_no_duplication(nq, depth, waves):
    qs = Q.make_queues(nq, depth)
    submitted, accepted, serviced = 0, 0, []
    for wave in waves:
        keys = jnp.asarray(wave, jnp.int32)
        valid = keys >= 0
        submitted += int(valid.sum())
        qs, rec = Q.enqueue(qs, keys)
        accepted += int(rec.n_accepted)
        # conservation within the wave
        assert int(rec.n_accepted) <= int(valid.sum())
        qs, comps = Q.service_all(qs)
        got = np.asarray(comps.keys)[np.asarray(comps.valid)]
        serviced.extend(got.tolist())
        # ring empties after service
        assert int(Q.in_flight(qs)) == 0
    assert int(qs.ticket_total) == submitted
    assert accepted == len(serviced)
    assert accepted + int(qs.dropped) == submitted
    assert int(qs.completions) == accepted


@given(st.integers(1, 4), st.integers(2, 16),
       st.lists(st.integers(0, 100), min_size=1, max_size=64))
@settings(max_examples=100, deadline=None)
def test_ring_capacity_respected(nq, depth, wave):
    qs = Q.make_queues(nq, depth)
    keys = jnp.asarray(wave, jnp.int32)
    qs, rec = Q.enqueue(qs, keys)
    # never more in flight than total ring capacity
    assert int(Q.in_flight(qs)) <= nq * depth
    tails = np.asarray(qs.sq_tail)
    heads = np.asarray(qs.sq_head)
    assert np.all(tails - heads <= depth)
    assert np.all(tails - heads >= 0)
    # accepted commands actually sit in the rings
    ring_keys = np.asarray(qs.sq_key)
    assert (ring_keys >= 0).sum() == int(rec.n_accepted)


def test_doorbell_batching():
    """One doorbell per touched queue per wavefront (paper's batching)."""
    qs = Q.make_queues(4, 16)
    keys = jnp.arange(8, dtype=jnp.int32)       # 8 cmds over 4 queues
    qs, rec = Q.enqueue(qs, keys)
    assert int(rec.n_doorbells) == 4            # not 8
    qs, rec2 = Q.enqueue(qs, jnp.asarray([42], jnp.int32))
    assert int(rec2.n_doorbells) == 1


def test_round_robin_balance():
    qs = Q.make_queues(4, 64)
    qs, _ = Q.enqueue(qs, jnp.arange(32, dtype=jnp.int32))
    per_q = np.asarray(qs.sq_tail)
    assert np.all(per_q == 8)                   # perfectly balanced
