"""Property tests: SQ/CQ rings never lose or duplicate commands."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import queues as Q


@given(st.integers(1, 4), st.integers(2, 8),
       st.lists(st.lists(st.integers(-2, 100), min_size=1, max_size=24),
                min_size=1, max_size=6))
@settings(max_examples=100, deadline=None)
def test_no_loss_no_duplication(nq, depth, waves):
    qs = Q.make_queues(nq, depth)
    submitted, accepted, serviced = 0, 0, []
    for wave in waves:
        keys = jnp.asarray(wave, jnp.int32)
        valid = keys >= 0
        submitted += int(valid.sum())
        qs, rec = Q.enqueue(qs, keys)
        accepted += int(rec.n_accepted)
        # conservation within the wave
        assert int(rec.n_accepted) <= int(valid.sum())
        qs, comps = Q.service_all(qs)
        got = np.asarray(comps.keys)[np.asarray(comps.valid)]
        serviced.extend(got.tolist())
        # ring empties after service
        assert int(Q.in_flight(qs)) == 0
    assert int(qs.ticket_total) == submitted
    assert accepted == len(serviced)
    assert accepted + int(qs.dropped) == submitted
    assert int(qs.completions) == accepted


@given(st.integers(1, 4), st.integers(2, 16),
       st.lists(st.integers(0, 100), min_size=1, max_size=64))
@settings(max_examples=100, deadline=None)
def test_ring_capacity_respected(nq, depth, wave):
    qs = Q.make_queues(nq, depth)
    keys = jnp.asarray(wave, jnp.int32)
    qs, rec = Q.enqueue(qs, keys)
    # never more in flight than total ring capacity
    assert int(Q.in_flight(qs)) <= nq * depth
    tails = np.asarray(qs.sq_tail)
    heads = np.asarray(qs.sq_head)
    assert np.all(tails - heads <= depth)
    assert np.all(tails - heads >= 0)
    # accepted commands actually sit in the rings
    ring_keys = np.asarray(qs.sq_key)
    assert (ring_keys >= 0).sum() == int(rec.n_accepted)


def test_doorbell_batching():
    """One doorbell per touched queue per wavefront (paper's batching)."""
    qs = Q.make_queues(4, 16)
    keys = jnp.arange(8, dtype=jnp.int32)       # 8 cmds over 4 queues
    qs, rec = Q.enqueue(qs, keys)
    assert int(rec.n_doorbells) == 4            # not 8
    qs, rec2 = Q.enqueue(qs, jnp.asarray([42], jnp.int32))
    assert int(rec2.n_doorbells) == 1


def test_round_robin_balance():
    qs = Q.make_queues(4, 64)
    qs, _ = Q.enqueue(qs, jnp.arange(32, dtype=jnp.int32))
    per_q = np.asarray(qs.sq_tail)
    assert np.all(per_q == 8)                   # perfectly balanced


# ------------------------------------------------- conservation properties --
def _run_schedule(nq, depth, n_devices, n_tenants, schedule):
    """Random submit/service schedule; returns the final QueueState and the
    multiset of serviced keys."""
    qs = Q.make_queues(nq, depth, n_devices=n_devices, n_tenants=n_tenants)
    serviced = []
    for tenant, wave, do_service in schedule:
        keys = jnp.asarray(wave, jnp.int32)
        qs, rec = Q.enqueue(qs, keys, tenant=tenant % n_tenants)
        # drops + accepts partition the valid submissions of every wave
        assert int(rec.n_accepted) + int(rec.n_dropped) \
            == int((keys >= 0).sum())
        # per-device in-flight never exceeds the device's ring capacity
        inflight_dev = np.asarray(Q.in_flight_per_device(qs))
        assert (inflight_dev <= (nq // n_devices) * depth).all()
        assert (inflight_dev >= 0).all()
        if do_service:
            qs, comps = Q.service_all(qs)
            got = np.asarray(comps.keys)[np.asarray(comps.valid)]
            serviced.extend(got.tolist())
    return qs, serviced


@given(st.integers(1, 2),            # n_devices multiplier
       st.integers(2, 6),            # depth
       st.integers(1, 3),            # n_tenants
       st.lists(st.tuples(st.integers(0, 2),
                          st.lists(st.integers(-2, 60), min_size=1,
                                   max_size=16),
                          st.booleans()),
                min_size=1, max_size=8))
@settings(max_examples=100, deadline=None)
def test_conservation_per_device_and_tenant(ndev, depth, n_tenants,
                                            schedule):
    nq = 2 * ndev
    qs, _ = _run_schedule(nq, depth, ndev, n_tenants, schedule)
    qs, _ = Q.service_all(qs)        # final drain
    # global: every valid submission (= ticket issued) was either
    # completed or dropped — nothing lost, nothing invented
    assert int(qs.completions) + int(qs.dropped) == int(qs.ticket_total)
    # per tenant: enqueued == completed after a full drain, and enqueued +
    # dropped covers every valid submission of that tenant
    enq = np.asarray(qs.tenant_enqueued)
    comp = np.asarray(qs.tenant_completed)
    drop = np.asarray(qs.tenant_dropped)
    assert np.array_equal(enq, comp), (enq, comp)
    assert (drop >= 0).all()
    assert int(qs.dropped) == int(drop.sum())
    assert int(qs.completions) == int(comp.sum())
    # per device: same conservation on the channel axis
    denq = np.asarray(qs.dev_enqueued)
    dcomp = np.asarray(qs.dev_completed)
    ddrop = np.asarray(qs.dev_dropped)
    assert np.array_equal(denq, dcomp), (denq, dcomp)
    assert int(qs.dropped) == int(ddrop.sum())
    assert np.asarray(Q.in_flight_per_tenant(qs)).sum() == 0


def test_conservation_example_tiny_rings():
    """Deterministic slice of the property: depth-2 rings, 2 tenants, heavy
    overflow; nothing lost, nothing double-counted."""
    qs = Q.make_queues(2, 2, n_tenants=2)
    qs, r0 = Q.enqueue(qs, jnp.arange(10, dtype=jnp.int32), tenant=0)
    qs, r1 = Q.enqueue(qs, 100 + jnp.arange(6, dtype=jnp.int32), tenant=1)
    assert int(r0.n_accepted) + int(r0.n_dropped) == 10
    assert int(r1.n_accepted) + int(r1.n_dropped) == 6
    inflight = np.asarray(Q.in_flight_per_tenant(qs))
    assert inflight.sum() == int(r0.n_accepted) + int(r1.n_accepted)
    qs, comps = Q.service_all(qs)
    assert np.array_equal(np.asarray(qs.tenant_enqueued),
                          np.asarray(qs.tenant_completed))
    assert int(qs.dropped) == int(r0.n_dropped) + int(r1.n_dropped)
    assert np.asarray(Q.in_flight_per_tenant(qs)).sum() == 0


def test_weighted_fair_drain_interleaves_tenants():
    """With weights (1, 2) tenant 1 retires ~2 commands per tenant-0
    command in every drain prefix (WFQ, not FIFO bursts)."""
    qs = Q.make_queues(2, 32, n_tenants=2, tenant_weights=(1.0, 2.0))
    qs, _ = Q.enqueue(qs, jnp.arange(8, dtype=jnp.int32), tenant=0)
    qs, _ = Q.enqueue(qs, 100 + jnp.arange(16, dtype=jnp.int32), tenant=1)
    qs, comps = Q.service_all(qs)
    ten = np.asarray(comps.tenant)[np.asarray(comps.valid)]
    # prefix fairness: after k completions, tenant 1 has at least its
    # weighted share minus one command of slack
    for k in range(1, len(ten) + 1):
        n1 = int((ten[:k] == 1).sum())
        assert n1 >= (2 * k) // 3 - 1, (k, ten[:k].tolist())
    # both tenants fully drained
    assert int((ten == 0).sum()) == 8 and int((ten == 1).sum()) == 16


def test_priority_still_dominates_tenant_arbitration():
    """Demand commands of any tenant drain before readahead of any tenant;
    WFQ only orders *within* a priority class."""
    qs = Q.make_queues(2, 32, n_tenants=2)
    qs, _ = Q.enqueue(qs, jnp.arange(4, dtype=jnp.int32),
                      prio=Q.PRIO_READAHEAD, tenant=0)
    qs, _ = Q.enqueue(qs, 100 + jnp.arange(4, dtype=jnp.int32), tenant=1)
    qs, comps = Q.service_all(qs)
    prio = np.asarray(comps.prio)[np.asarray(comps.valid)]
    assert (np.diff(prio) >= 0).all(), prio
    assert prio[0] == Q.PRIO_DEMAND and prio[-1] == Q.PRIO_READAHEAD


def test_wfq_ranks_are_per_priority_class():
    """A tenant's demand backlog must not delay its readahead relative to
    other tenants' readahead: ranks reset per (tenant, priority) class."""
    qs = Q.make_queues(2, 32, n_tenants=2)
    qs, _ = Q.enqueue(qs, jnp.arange(16, dtype=jnp.int32), tenant=0)
    qs, _ = Q.enqueue(qs, 50 + jnp.arange(4, dtype=jnp.int32),
                      prio=Q.PRIO_READAHEAD, tenant=0)
    qs, _ = Q.enqueue(qs, 100 + jnp.arange(4, dtype=jnp.int32),
                      prio=Q.PRIO_READAHEAD, tenant=1)
    qs, comps = Q.service_all(qs)
    v = np.asarray(comps.valid)
    prio = np.asarray(comps.prio)[v]
    ten = np.asarray(comps.tenant)[v]
    ra = ten[prio == Q.PRIO_READAHEAD]
    # equal weights -> strict 1:1 interleave inside the readahead class,
    # regardless of tenant 0's 16-command demand backlog
    for k in range(1, len(ra) + 1):
        assert abs(int((ra[:k] == 0).sum()) - int((ra[:k] == 1).sum())) <= 1, \
            ra.tolist()


def test_single_active_tenant_keeps_ring_order():
    """Multi-tenant pool, but only one tenant has pending commands and no
    readahead: the fast path must return plain ring order."""
    qs = Q.make_queues(2, 8, n_tenants=3)
    keys = jnp.arange(6, dtype=jnp.int32)
    qs, _ = Q.enqueue(qs, keys, tenant=1)
    qs, comps = Q.service_all(qs)
    got = np.asarray(comps.keys)[np.asarray(comps.valid)]
    # round-robin over 2 queues: queue-major drain order is 0,2,4,1,3,5
    assert got.tolist() == [0, 2, 4, 1, 3, 5]
    assert int(qs.tenant_completed[1]) == 6
