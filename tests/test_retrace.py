"""Retrace-regression tests for the jit-cached op family.

Every jit-cached op carries a trace-count probe: the Python body of the
cached callable runs only while JAX *traces* it (a jit cache miss), so
``trace_counts`` counts compilations, not calls.  Steady-state streams at
fixed shapes — the hot path — must trace each op exactly once; a change
that sneaks a fresh ``jax.jit`` wrapper (or a shape-dependent Python
branch) into the loop fails here, not in a benchmark six PRs later.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import BamArray, BamRuntime, IORequest, TenantSpec


def _build(n_blocks=64, block_elems=8):
    rng = np.random.default_rng(0)
    data = rng.standard_normal((n_blocks, block_elems)).astype(np.float32)
    return BamArray.build(data, block_elems=block_elems, num_sets=8, ways=4)


def test_steady_state_read_write_never_retraces():
    arr, st = _build()
    read, write = arr.read_jit(), arr.write_jit()
    idx = jnp.asarray(np.arange(16) * 3 % arr.size, jnp.int32)
    vals = jnp.arange(16, dtype=jnp.float32)
    for _ in range(5):
        _, st = read(st, idx)
        st = write(st, idx, vals)
    assert arr.trace_counts == {"read": 1, "write": 1}


def test_steady_state_submit_wait_never_retraces():
    arr, st = _build()
    submit, wait = arr.submit_jit(), arr.wait_jit()
    idx = jnp.asarray(np.arange(16) * 5 % arr.size, jnp.int32)
    for _ in range(4):
        # a 2-deep submission window, every round
        st, t1 = submit(st, IORequest.read(idx))
        st, t2 = submit(st, IORequest.read(idx + 1))
        st, _ = wait(st, t1)
        st, _ = wait(st, t2)
    assert arr.trace_counts["submit"] == 1
    assert arr.trace_counts["wait"] == 1


def test_new_shape_traces_exactly_once_more():
    arr, st = _build()
    read = arr.read_jit()
    a = jnp.asarray(np.arange(16), jnp.int32)
    b = jnp.asarray(np.arange(32), jnp.int32)
    _, st = read(st, a)
    _, st = read(st, a)
    assert arr.trace_counts["read"] == 1
    _, st = read(st, b)         # new shape: one more trace, no more
    _, st = read(st, b)
    _, st = read(st, a)         # old shape still cached
    assert arr.trace_counts["read"] == 2


def test_jit_family_is_cached_per_instance():
    arr, _ = _build()
    assert arr.read_jit() is arr.read_jit()
    assert arr.submit_jit() is arr.submit_jit()
    # a config variant gets a fresh cache (the old callables close over
    # the old instance's static config)
    from repro.core import PrefetchConfig
    arr2 = arr.with_prefetch(PrefetchConfig(enabled=True, window=4))
    assert arr2.read_jit() is not arr.read_jit()
    assert arr2.trace_counts == {}


def test_runtime_ops_never_retrace_steady_state():
    rng = np.random.default_rng(1)
    specs = [
        TenantSpec(name="a",
                   data=rng.standard_normal((32, 8)).astype(np.float32),
                   block_elems=8),
        TenantSpec(name="b",
                   data=rng.standard_normal((32, 8)).astype(np.float32),
                   block_elems=8),
    ]
    rt, rst = BamRuntime.build(specs, num_sets=8, ways=4)
    idx = jnp.asarray(np.arange(12), jnp.int32)
    for _ in range(4):
        _, rst = rt.read_jit("a")(rst, idx)
        rst, tok = rt.submit_jit("b")(rst, IORequest.read(idx))
        rst, _ = rt.wait_jit("b")(rst, tok)
    assert rt.trace_counts == {"read:a": 1, "submit:b": 1, "wait:b": 1}


def test_taxi_queries_never_retrace_steady_state():
    """Regression (bamlint BAM105): ``run_query`` built a fresh
    ``jax.jit(arr.read)`` wrapper per dependent column per call, so every
    query recompiled every gather.  It now rides the instance cache."""
    from repro.analytics import QUERIES, make_taxi_table, run_query
    tbl = make_taxi_table(1 << 12)
    for _ in range(3):
        run_query(tbl, "Q2")
    for name in QUERIES["Q2"]:
        assert tbl.cols[name].trace_counts == {"read": 1}


def test_graph_analytics_never_retrace_steady_state():
    """Regression (bamlint BAM105): ``bfs``/``cc`` nested ``@jax.jit``
    step functions inside the driver, re-tracing every traversal.  The
    step bodies now live in the per-graph jit cache."""
    from repro.graph import BamGraph, bfs, cc, random_graph
    indptr, dst = random_graph(200, 4.0, seed=0)
    g = BamGraph.build(indptr, dst, cacheline_bytes=256,
                       cache_bytes=1 << 14)
    for _ in range(2):
        bfs(g, 0, async_tokens=True)
        cc(g, async_tokens=True)
    assert g.trace_counts == {"bfs_submit0": 1, "bfs_step_tok": 1,
                              "cc_submit0": 1, "cc_step_tok": 1}
