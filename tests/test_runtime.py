"""Multi-tenant BamRuntime: sharing, isolation, and metric accounting."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BamKVStore, BamRuntime, TenantSpec, in_flight_per_tenant, metrics_sum,
)

BE = 8      # shared cache line geometry (elements)


def _specs(n=2, size=256, **kw):
    return [TenantSpec(f"t{i}", (1000 * i) + np.arange(size,
                                                       dtype=np.float32),
                       block_elems=BE, **kw)
            for i in range(n)]


def build(n=2, size=256, num_sets=8, ways=4, isolation="partitioned", **kw):
    return BamRuntime.build(_specs(n, size), num_sets=num_sets, ways=ways,
                            isolation=isolation, **kw)


# ------------------------------------------------------------- correctness --
@pytest.mark.parametrize("isolation", ["partitioned", "shared"])
def test_overlapping_keyspaces_read_their_own_data(isolation):
    """Both tenants read blocks 0..N of *their own* array — the shared
    cache must namespace the identical block keys."""
    rt, rst = build(isolation=isolation)
    idx = jnp.arange(64, dtype=jnp.int32)
    # interleave so both tenants' lines are simultaneously resident
    for _ in range(3):
        v0, rst = rt.read(rst, "t0", idx)
        v1, rst = rt.read(rst, "t1", idx)
        np.testing.assert_array_equal(np.asarray(v0), np.arange(64))
        np.testing.assert_array_equal(np.asarray(v1), 1000 + np.arange(64))


@pytest.mark.parametrize("isolation", ["partitioned", "shared"])
def test_writes_land_in_the_right_storage(isolation):
    rt, rst = build(isolation=isolation)
    rst = rt.write(rst, "t0", jnp.asarray([5], jnp.int32),
                   jnp.asarray([-1.0]))
    rst = rt.write(rst, "t1", jnp.asarray([5], jnp.int32),
                   jnp.asarray([-2.0]))
    rst = rt.flush(rst)
    # dirty lines fully flushed for every tenant
    assert not bool(rst.cache.dirty.any())
    v0, rst = rt.read(rst, "t0", jnp.asarray([5], jnp.int32))
    v1, rst = rt.read(rst, "t1", jnp.asarray([5], jnp.int32))
    assert float(v0[0]) == -1.0 and float(v1[0]) == -2.0
    # and the storage tiers themselves diverge correctly
    s0 = np.asarray(rt.array("t0").storage.data).reshape(-1)
    s1 = np.asarray(rt.array("t1").storage.data).reshape(-1)
    assert s0[5] == -1.0 and s1[5] == -2.0


def test_flush_one_tenant_leaves_other_tenants_dirty_lines():
    rt, rst = build(isolation="shared")
    rst = rt.write(rst, "t0", jnp.asarray([0], jnp.int32), jnp.asarray([9.]))
    rst = rt.write(rst, "t1", jnp.asarray([0], jnp.int32), jnp.asarray([8.]))
    assert int(np.asarray(rst.cache.dirty).sum()) == 2
    rst = rt.flush(rst, "t0")
    dirty = np.asarray(rst.cache.dirty)
    owner = np.asarray(rst.cache.owner)
    assert int(dirty.sum()) == 1
    assert (owner[dirty] == 1).all(), "flush('t0') touched t1's dirty line"
    # t1's write still reaches its storage on its own flush
    rst = rt.flush(rst, "t1")
    assert not bool(rst.cache.dirty.any())
    assert np.asarray(rt.array("t1").storage.data).reshape(-1)[0] == 8.0


def test_partitioned_streaming_tenant_cannot_evict_neighbour():
    """Stream 10x the cache through t1; t0's resident lines survive and
    keep hitting (the benchmarks/mixed_tenants.py property in miniature)."""
    rt, rst = build(n=2, size=4096, num_sets=4, ways=4)
    hot = jnp.arange(32, dtype=jnp.int32)         # 4 blocks for t0
    v0, rst = rt.read(rst, "t0", hot)             # warm t0's partition
    h0 = float(rst.tenant_metrics[0].hits)
    for start in range(0, 4096, 64):
        idx = start + jnp.arange(64, dtype=jnp.int32)
        _, rst = rt.read(rst, "t1", idx)          # the adversarial scan
    v0b, rst = rt.read(rst, "t0", hot)
    np.testing.assert_array_equal(np.asarray(v0b), np.arange(32))
    hits_gained = float(rst.tenant_metrics[0].hits) - h0
    assert hits_gained == 4.0, "t0's hot lines were evicted by t1's scan"
    rt.assert_metrics_consistent(rst)


# ---------------------------------------------------------------- metrics --
def test_tenant_metrics_sum_to_global():
    rt, rst = build(n=3, ways=6)
    idx = jnp.arange(48, dtype=jnp.int32)
    for rnd in range(3):
        for name in ("t0", "t1", "t2"):
            _, rst = rt.read(rst, name, idx + 8 * rnd)
    rst = rt.write(rst, "t1", jnp.asarray([3, 9], jnp.int32),
                   jnp.asarray([1.0, 2.0]))
    rst = rt.prefetch(rst, "t2", jnp.arange(64, 96, dtype=jnp.int32))
    rst = rt.flush(rst)
    rt.assert_metrics_consistent(rst)
    # integer counters match exactly
    total = metrics_sum(rst.tenant_metrics)
    for f in ("requests", "hits", "misses", "write_ops", "doorbells",
              "dropped", "prefetch_issued"):
        assert float(getattr(total, f)) == float(getattr(rst.metrics, f)), f
    # and the queue pool agrees with the metrics layer on drops
    assert float(rst.metrics.dropped) \
        == float(np.asarray(rst.queues.tenant_dropped).sum())


def test_queue_accounting_per_tenant_after_ops():
    rt, rst = build(n=2)
    _, rst = rt.read(rst, "t0", jnp.arange(32, dtype=jnp.int32))
    _, rst = rt.read(rst, "t1", jnp.arange(16, dtype=jnp.int32))
    qs = rst.queues
    enq = np.asarray(qs.tenant_enqueued)
    comp = np.asarray(qs.tenant_completed)
    assert np.array_equal(enq, comp)            # every read drains its ring
    assert enq[0] == 4 and enq[1] == 2          # 32/8 and 16/8 lines
    assert np.asarray(in_flight_per_tenant(qs)).sum() == 0


# ------------------------------------------------------------ composition --
def test_kv_store_rides_a_runtime_tenant():
    keys = np.arange(64, dtype=np.int32)
    values = np.arange(64 * BE, dtype=np.float32).reshape(64, BE)
    table, store_vals, cap = BamKVStore.build_table(keys, values,
                                                    capacity=128)
    specs = [TenantSpec("kv", store_vals, block_elems=BE),
             TenantSpec("other", np.zeros(64, np.float32), block_elems=BE)]
    rt, rst = BamRuntime.build(specs, num_sets=8, ways=4)
    kv = BamKVStore(array=rt.array("kv"), capacity=cap, value_elems=BE)
    tj = jnp.asarray(table)
    st = rt.tenant_view(rst, "kv")
    vals, found, st = kv.lookup(st, tj, jnp.asarray([0, 17, 63, 99],
                                                    jnp.int32))
    rst = rt.absorb(rst, "kv", st)
    assert bool(found[0]) and bool(found[1]) and bool(found[2])
    assert not bool(found[3])
    np.testing.assert_array_equal(np.asarray(vals[1]), values[17])
    rt.assert_metrics_consistent(rst)


def test_int_tenant_roundtrips_through_float_cache():
    """An int32 tenant (graph edges) shares the float32 cache exactly."""
    edges = np.arange(512, dtype=np.int32)[::-1].copy()
    specs = [TenantSpec("edges", edges, block_elems=BE),
             TenantSpec("col", np.zeros(64, np.float32), block_elems=BE)]
    rt, rst = BamRuntime.build(specs, num_sets=8, ways=4)
    idx = jnp.arange(512, dtype=jnp.int32)
    v, rst = rt.read(rst, "edges", idx)
    assert v.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(v), edges)
    v2, rst = rt.read(rst, "edges", idx)     # now from cache lines
    np.testing.assert_array_equal(np.asarray(v2), edges)


# ------------------------------------------------------------- validation --
def test_build_rejects_bad_configs():
    with pytest.raises(ValueError):
        BamRuntime.build([], num_sets=4)
    with pytest.raises(ValueError):
        BamRuntime.build(_specs(2), num_sets=4, ways=4,
                         isolation="exclusive")
    with pytest.raises(ValueError):            # quotas exceed ways
        BamRuntime.build([TenantSpec("a", np.zeros(8, np.float32),
                                     block_elems=BE, ways=3),
                          TenantSpec("b", np.zeros(8, np.float32),
                                     block_elems=BE, ways=3)],
                         num_sets=4, ways=4)
    with pytest.raises(ValueError):            # mismatched line geometry
        BamRuntime.build([TenantSpec("a", np.zeros(8, np.float32),
                                     block_elems=4),
                          TenantSpec("b", np.zeros(8, np.float32),
                                     block_elems=8)],
                         num_sets=4, ways=4)
    with pytest.raises(ValueError):            # duplicate names
        BamRuntime.build([TenantSpec("a", np.zeros(8, np.float32),
                                     block_elems=BE),
                          TenantSpec("a", np.zeros(8, np.float32),
                                     block_elems=BE)],
                         num_sets=4, ways=4)


def test_way_quotas_partition_the_cache():
    rt, _ = BamRuntime.build(
        [TenantSpec("a", np.zeros(64, np.float32), block_elems=BE, ways=3),
         TenantSpec("b", np.zeros(64, np.float32), block_elems=BE),
         TenantSpec("c", np.zeros(64, np.float32), block_elems=BE)],
        num_sets=4, ways=8)
    ctxs = {n: rt.array(n).tenant_ctx for n in ("a", "b", "c")}
    spans = sorted((c.way_lo, c.way_hi) for c in ctxs.values())
    assert spans[0][0] == 0 and spans[-1][1] == 8
    for (lo1, hi1), (lo2, hi2) in zip(spans, spans[1:]):
        assert hi1 == lo2                       # contiguous, disjoint
    assert ctxs["a"].way_hi - ctxs["a"].way_lo == 3


def test_bfs_on_runtime_tenant_matches_oracle():
    """A full BFS traversal through BamGraph.from_runtime returns the same
    depths as the host oracle while another tenant shares the cache."""
    from repro.graph.analytics import BamGraph, bfs, bfs_oracle, random_graph

    indptr, dst = random_graph(128, 4.0, seed=3)
    specs = [TenantSpec("bfs", dst.astype(np.int32), block_elems=BE),
             TenantSpec("noise", np.arange(256, dtype=np.float32),
                        block_elems=BE)]
    rt, rst = BamRuntime.build(specs, num_sets=8, ways=4)
    # neighbour traffic first, so the shared cache is not pristine
    _, rst = rt.read(rst, "noise", jnp.arange(64, dtype=jnp.int32))
    g = BamGraph.from_runtime(rt, rst, "bfs", indptr)
    depth, g_state = bfs(g, source=0)
    rst = rt.absorb(rst, "bfs", g_state)
    np.testing.assert_array_equal(depth, bfs_oracle(indptr, dst, 0))
    # neighbour still reads its own data afterwards
    v, rst = rt.read(rst, "noise", jnp.arange(64, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(v), np.arange(64))
    rt.assert_metrics_consistent(rst)


def test_scan_column_runtime_sums_and_isolates():
    from repro.analytics.taxi import scan_column_runtime

    col = np.arange(512, dtype=np.float32)
    specs = [TenantSpec("scan", col, block_elems=BE),
             TenantSpec("hot", 7 * np.ones(64, np.float32), block_elems=BE)]
    rt, rst = BamRuntime.build(specs, num_sets=4, ways=4)
    vh, rst = rt.read(rst, "hot", jnp.arange(32, dtype=jnp.int32))
    total, rst, nxt = scan_column_runtime(rt, rst, "scan", n_rows=512,
                                          wavefront=128)
    assert total == float(col.sum())
    assert nxt == 0                               # full wrap
    h_before = float(rst.tenant_metrics[1].hits)
    vh2, rst = rt.read(rst, "hot", jnp.arange(32, dtype=jnp.int32))
    assert float(rst.tenant_metrics[1].hits) - h_before == 4.0, \
        "scan tenant evicted the partitioned hot tenant"
    rt.assert_metrics_consistent(rst)


def test_deferred_drain_mixes_tenants_in_one_stream():
    """drain='deferred': both tenants' commands coexist in the rings and
    one drain retires them weighted-fair; values and conservation are
    unchanged from per-op mode."""
    rt, rst = BamRuntime.build(_specs(2), num_sets=8, ways=4,
                               drain="deferred")
    idx = jnp.arange(64, dtype=jnp.int32)
    v0, rst = rt.read(rst, "t0", idx)
    v1, rst = rt.read(rst, "t1", idx)
    np.testing.assert_array_equal(np.asarray(v0), np.arange(64))
    np.testing.assert_array_equal(np.asarray(v1), 1000 + np.arange(64))
    # both tenants pending simultaneously — the situation per-op mode
    # can never produce
    pend = np.asarray(in_flight_per_tenant(rst.queues))
    assert pend[0] == 8 and pend[1] == 8, pend
    rst, comps = rt.drain(rst)
    ten = np.asarray(comps.tenant)[np.asarray(comps.valid)]
    assert len(ten) == 16
    # equal weights: 1:1 interleave across the drained stream
    for k in range(1, 17):
        assert abs(int((ten[:k] == 0).sum())
                   - int((ten[:k] == 1).sum())) <= 1, ten.tolist()
    qs = rst.queues
    assert np.array_equal(np.asarray(qs.tenant_enqueued),
                          np.asarray(qs.tenant_completed))
    assert np.asarray(in_flight_per_tenant(qs)).sum() == 0
    rt.assert_metrics_consistent(rst)


def test_deferred_and_per_op_modes_agree_on_values():
    idx = jnp.asarray(np.random.default_rng(5).integers(0, 256, 40),
                      jnp.int32)
    outs = {}
    for mode in ("per_op", "deferred"):
        rt, rst = BamRuntime.build(_specs(2), num_sets=8, ways=4, drain=mode)
        v0, rst = rt.read(rst, "t0", idx)
        rst = rt.write(rst, "t1", idx[:8], jnp.arange(8, dtype=jnp.float32))
        rst = rt.flush(rst)
        rst, _ = rt.drain(rst)
        v1, rst = rt.read(rst, "t1", idx)
        outs[mode] = (np.asarray(v0), np.asarray(v1))
        rt.assert_metrics_consistent(rst)
    np.testing.assert_array_equal(outs["per_op"][0], outs["deferred"][0])
    np.testing.assert_array_equal(outs["per_op"][1], outs["deferred"][1])


def test_build_rejects_integers_beyond_float_cache_range():
    big = np.asarray([0, 1 << 25], np.int32)       # > 2^24: not f32-exact
    with pytest.raises(ValueError, match="exact-integer range"):
        BamRuntime.build([TenantSpec("edges", big, block_elems=BE)],
                         num_sets=4, ways=2)
    # a wider cache dtype (or values in range) is accepted
    rt, rst = BamRuntime.build(
        [TenantSpec("edges", np.asarray([0, (1 << 24)], np.int32),
                    block_elems=BE)], num_sets=4, ways=2)
    v, rst = rt.read(rst, "edges", jnp.asarray([1], jnp.int32))
    assert int(v[0]) == 1 << 24


def test_scan_column_runtime_non_divisible_tail():
    from repro.analytics.taxi import scan_column_runtime

    col = np.arange(500, dtype=np.float32)         # 500 % 128 != 0
    rt, rst = BamRuntime.build([TenantSpec("scan", col, block_elems=BE)],
                               num_sets=4, ways=2)
    total, rst, nxt = scan_column_runtime(rt, rst, "scan", n_rows=500,
                                          wavefront=128)
    assert total == float(col.sum())               # no head double-count
    assert nxt == 0
