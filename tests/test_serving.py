"""Serving engine + BaM paged-KV spill/fetch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.models.model import build_model
from repro.serving import PagedKVManager, ServeEngine
from repro.serving.engine import Request


def test_engine_completes_requests():
    cfg = smoke_config("qwen2_5_14b")
    api = build_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0), 64)
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=64)
    reqs = [Request(rid=i, prompt=[1, 2, 3, 4 + i], max_new_tokens=6)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and len(r.out) == 6 for r in reqs)


def test_engine_greedy_matches_manual_decode():
    """Engine output == manual prefill+decode with the model api."""
    cfg = smoke_config("minitron_4b")
    api = build_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(1), 32)
    prompt = [3, 1, 4, 1, 5]
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32)
    r = Request(rid=0, prompt=list(prompt), max_new_tokens=4)
    eng.submit(r)
    eng.run()

    cache, _ = api.init_decode_cache(1, 32)
    lg = None
    for t in prompt:
        lg, cache = api.decode_step(params, cache,
                                    jnp.asarray([t], jnp.int32))
    out = []
    for _ in range(4):
        tok = int(np.asarray(lg).argmax())
        out.append(tok)
        lg, cache = api.decode_step(params, cache,
                                    jnp.asarray([tok], jnp.int32))
    assert r.out == out


def test_spill_and_fetch_roundtrip_preserves_logits():
    """Spilling cold pages to the storage tier and fetching them back is
    value-preserving: decode logits identical."""
    cfg = smoke_config("gemma3_12b").replace(window=None, local_ratio=(0, 1))
    # ^ all layers global -> all layers paged
    api = build_model(cfg)
    S = 48
    params, _ = api.init(jax.random.PRNGKey(2), S)
    cache, _ = api.init_decode_cache(1, S)
    toks = np.random.default_rng(0).integers(0, cfg.vocab, 24)
    for t in toks[:-1]:
        _, cache = api.decode_step(params, cache,
                                   jnp.asarray([t], jnp.int32))

    # reference: continue without spilling
    lg_ref, _ = api.decode_step(params, cache,
                                jnp.asarray([int(toks[-1])], jnp.int32))

    kv = PagedKVManager(keep_last=8)
    cache2, n_spilled = kv.maybe_spill(cache)
    assert n_spilled > 0
    # holes present now
    pt = np.asarray(cache2["layers"][0][0].value["page_table"]
                    if isinstance(cache2["layers"][0], tuple)
                    else cache2["layers"][0].value["page_table"])
    assert (pt < 0).any()
    cache3, n_fetched = kv.ensure_resident(cache2)
    assert n_fetched == n_spilled
    lg2, _ = api.decode_step(params, cache3,
                             jnp.asarray([int(toks[-1])], jnp.int32))
    np.testing.assert_allclose(np.asarray(lg2, np.float32),
                               np.asarray(lg_ref, np.float32),
                               atol=1e-4, rtol=1e-4)
    s = kv.metrics.summary()
    assert s["write_ops"] == n_spilled and s["misses"] == n_fetched
    assert s["sim_time_s"] > 0
