"""End-to-end behaviour tests: a small model actually trains (loss drops)
through the full stack — data pipeline -> train step -> checkpoint ->
fault-tolerant loop — and the BaM-backed serving path generates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.data import DataConfig, Loader, TokenStore, synth_corpus
from repro.models.model import build_model, count_params
from repro.training import optimizer as opt
from repro.training.fault_tolerance import FailureInjector, run_training
from repro.training.train_loop import make_train_step


def test_end_to_end_training_reduces_loss(tmp_path):
    cfg = smoke_config("minitron_4b").replace(dtype="float32")
    api = build_model(cfg)
    path = synth_corpus(tmp_path / "corpus.bin", n_tokens=200_000,
                        vocab=cfg.vocab, seed=0)
    loader = Loader(TokenStore.open(path),
                    DataConfig(seq_len=32, global_batch=8))
    acfg = opt.AdamWConfig(lr=5e-3, warmup=10, total_steps=200,
                           weight_decay=0.01)
    step_fn = jax.jit(make_train_step(cfg, api, adamw=acfg))

    def init_state():
        params, _ = api.init(jax.random.PRNGKey(0), 32)
        return {"params": params, "opt": opt.adamw_init(params, acfg)}

    def batch_for_step(s):
        b = loader.batch_for_step(s)
        return {"tokens": jnp.asarray(b["tokens"])}

    res = run_training(step_fn, init_state, batch_for_step, 200,
                       ckpt_dir=tmp_path / "ck", ckpt_every=40,
                       failure_injector=FailureInjector(fail_at=(60,)))
    assert res.restarts == 1                      # crash mid-run, recovered
    first = np.mean([m["loss"] for m in res.metrics_history[:10]])
    last = np.mean([m["loss"] for m in res.metrics_history[-10:]])
    assert last < first - 0.5, (first, last)      # actually learned


def test_generation_after_training(tmp_path):
    """Train briefly, then serve with the BaM-paged engine."""
    from repro.serving import PagedKVManager, ServeEngine
    from repro.serving.engine import Request
    cfg = smoke_config("gemma3_12b").replace(dtype="float32")
    api = build_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0), 64)
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=64,
                      kv_manager=PagedKVManager(keep_last=16))
    reqs = [Request(rid=i, prompt=[5, 6, 7], max_new_tokens=8)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and len(r.out) == 8 for r in reqs)


def test_bam_pipeline_feeds_training():
    """BaM as the input tier: batches gathered from a BamArray-backed token
    store inside jit (the paper's on-demand access feeding compute)."""
    from repro.core import BamArray
    cfg = smoke_config("qwen2_5_14b").replace(dtype="float32")
    api = build_model(cfg)
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab, 4096).astype(np.int32)
    arr, st = BamArray.build(tokens.reshape(1, -1), block_elems=64,
                             num_sets=8, ways=4)
    params, _ = api.init(jax.random.PRNGKey(0), 16)

    @jax.jit
    def fetch_and_loss(st, params, offsets):
        idx = offsets[:, None] * 16 + jnp.arange(16)[None, :]
        toks, st = arr.read(st, idx.reshape(-1))
        batch = {"tokens": toks.reshape(4, 16).astype(jnp.int32)}
        loss, _ = api.loss(params, batch)
        return loss, st

    loss, st = fetch_and_loss(st, params, jnp.asarray([0, 5, 9, 200]))
    assert np.isfinite(float(loss))
    assert float(st.metrics.misses) > 0
