"""Training substrate: optimizer, checkpointing, fault tolerance, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, Loader, TokenStore, synth_corpus
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training.fault_tolerance import FailureInjector, run_training


# ----------------------------------------------------------- optimizer ----
def test_adamw_reduces_quadratic():
    w = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    acfg = opt.AdamWConfig(lr=0.1, warmup=0, total_steps=200,
                           weight_decay=0.0, clip_norm=10.0)
    state = opt.adamw_init(w, acfg)
    lr_fn = opt.cosine_schedule(acfg.lr, 0, 200)
    for _ in range(150):
        g = jax.tree_util.tree_map(lambda p: 2 * p, w)
        w, state, m = opt.adamw_update(g, state, w, acfg, lr_fn)
    assert float(jnp.abs(w["w"]).max()) < 0.2


def test_grad_clip_applies():
    w = {"w": jnp.ones((4,))}
    acfg = opt.AdamWConfig(lr=1.0, warmup=0, total_steps=10, clip_norm=1.0)
    state = opt.adamw_init(w, acfg)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = opt.adamw_update(g, state, w, acfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-5)


def test_quantize_int8_error_feedback_converges():
    """Quantization error is bounded by the scale; EF re-injects it."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(256), jnp.float32)
    q, scale = opt.quantize_int8(g)
    err = g - q.astype(jnp.float32) * scale
    assert float(jnp.abs(err).max()) <= float(scale) / 2 + 1e-6
    # EF: accumulated mean over steps approaches the true mean
    acc, e = jnp.zeros_like(g), jnp.zeros_like(g)
    for i in range(64):
        q, s = opt.quantize_int8(g + e)
        deq = q.astype(jnp.float32) * s
        e = (g + e) - deq
        acc = acc + deq
    np.testing.assert_allclose(np.asarray(acc / 64), np.asarray(g),
                               atol=float(scale))


# ---------------------------------------------------------- checkpoint ----
def _state(rng):
    return {"params": {"a": jnp.asarray(rng.standard_normal((4, 3)),
                                        jnp.float32),
                       "b": jnp.arange(5, dtype=jnp.int32)},
            "opt": {"step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path, rng):
    st = _state(rng)
    ckpt.save_checkpoint(tmp_path, 10, st, extra={"data_pos": 123})
    got, step, extra = ckpt.restore_checkpoint(tmp_path, st)
    assert step == 10 and extra["data_pos"] == 123
    for a, b in zip(jax.tree_util.tree_leaves(st),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_n_and_latest(tmp_path, rng):
    st = _state(rng)
    for s in (1, 2, 3, 4, 5):
        ckpt.save_checkpoint(tmp_path, s, st, keep=2)
    assert ckpt.list_steps(tmp_path) == [4, 5]
    assert ckpt.latest_step(tmp_path) == 5


def test_checkpoint_atomicity(tmp_path, rng):
    """A stale .tmp dir never shadows a published checkpoint."""
    st = _state(rng)
    ckpt.save_checkpoint(tmp_path, 1, st)
    (tmp_path / "step_00000002.tmp").mkdir()     # simulated crash mid-save
    assert ckpt.latest_step(tmp_path) == 1
    got, step, _ = ckpt.restore_checkpoint(tmp_path, st)
    assert step == 1


def test_checkpoint_structure_mismatch_raises(tmp_path, rng):
    st = _state(rng)
    ckpt.save_checkpoint(tmp_path, 1, st)
    bad = {"params": {"a": st["params"]["a"]}}
    with pytest.raises(ValueError):
        ckpt.restore_checkpoint(tmp_path, bad)


# ------------------------------------------------------ fault tolerance ---
def test_run_training_recovers_from_failures(tmp_path):
    """Injected crashes at steps 7 and 12: the loop restores and the final
    state equals an uninterrupted run (determinism)."""
    def init_state():
        return {"w": jnp.zeros(()), "n": jnp.int32(0)}

    def batch_for_step(s):
        return jnp.float32(s)

    @jax.jit
    def train_step(state, batch):
        return {"w": state["w"] + batch, "n": state["n"] + 1}, \
            {"w": state["w"]}

    res = run_training(train_step, init_state, batch_for_step, 20,
                       ckpt_dir=tmp_path / "ft", ckpt_every=5,
                       failure_injector=FailureInjector(fail_at=(7, 12)))
    assert res.restarts == 2
    assert float(res.state["w"]) == sum(range(20))
    assert int(res.state["n"]) == 20


def test_run_training_too_many_failures_raises(tmp_path):
    def init_state():
        return {"w": jnp.zeros(())}

    def step(state, batch):
        raise RuntimeError("dead device")

    with pytest.raises(RuntimeError):
        run_training(step, init_state, lambda s: None, 5,
                     ckpt_dir=tmp_path / "ft2", max_restarts=2)


# ----------------------------------------------------------------- data ---
def test_data_pipeline_determinism_and_resume(tmp_path):
    path = synth_corpus(tmp_path / "toks.bin", n_tokens=10_000, vocab=97)
    store = TokenStore.open(path)
    ld = Loader(store, DataConfig(seq_len=16, global_batch=4))
    b5 = ld.batch_for_step(5)
    b5b = ld.batch_for_step(5)                  # pure function of step
    np.testing.assert_array_equal(b5["tokens"], b5b["tokens"])
    # next-token alignment
    np.testing.assert_array_equal(b5["tokens"][:, 1:], b5["labels"][:, :-1])
    # rank sharding partitions the global batch
    l0 = Loader(store, DataConfig(seq_len=16, global_batch=4, n_ranks=2,
                                  rank=0))
    l1 = Loader(store, DataConfig(seq_len=16, global_batch=4, n_ranks=2,
                                  rank=1))
    g = ld.batch_for_step(3)["tokens"]
    np.testing.assert_array_equal(
        np.concatenate([l0.batch_for_step(3)["tokens"],
                        l1.batch_for_step(3)["tokens"]]), g)


def test_data_prefetch_iterator(tmp_path):
    path = synth_corpus(tmp_path / "t2.bin", n_tokens=5_000, vocab=31)
    ld = Loader(TokenStore.open(path),
                DataConfig(seq_len=8, global_batch=2, prefetch_depth=2))
    it = ld.iterate(start_step=0)
    got = [next(it) for _ in range(3)]
    ld.stop()
    for s, b in enumerate(got):
        np.testing.assert_array_equal(b["tokens"],
                                      ld.batch_for_step(s)["tokens"])


def test_synth_corpus_learnable_structure(tmp_path):
    """The Markov corpus has sub-uniform conditional entropy (learnable)."""
    path = synth_corpus(tmp_path / "t3.bin", n_tokens=20_000, vocab=64)
    toks = np.memmap(path, dtype=np.int32, mode="r")
    # bigram mutual information > 0: repeated-context tokens are skewed
    big = {}
    for a, b in zip(toks[:-1], toks[1:]):
        big.setdefault(int(a), []).append(int(b))
    skew = np.mean([
        max(np.bincount(v, minlength=64)) / len(v)
        for v in big.values() if len(v) >= 20])
    assert skew > 2.0 / 64                       # far from uniform
