# Repo tooling namespace package (`python -m tools.bamlint`, lint_docs).
