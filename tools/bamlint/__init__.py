"""bamlint — repo-native static analysis for the BaM reproduction.

Six AST passes, stdlib-only (no JAX import, no execution of the checked
code), runnable as ``python -m tools.bamlint src benchmarks examples``:

1. ``hostsync``       host-sync / retrace hazards in jit-reachable code
2. ``tokens``         IOToken linear-lifecycle + pin pairing
3. ``kernel_safety``  Pallas grid/BlockSpec geometry, ref aliasing, f64
4. ``metrics_pass``   IOMetrics additive-vs-watermark conservation
5. ``donation``       state used after a donating ``*_jit(donate=True)``
6. ``receipts``       discarded SubmitReceipt/DrainReceipt accounting

See docs/static_analysis.md for the rule catalogue, suppression syntax
(``# bamlint: ignore[RULE]``) and the baseline workflow.
"""
from __future__ import annotations

from tools.bamlint import (
    core, donation, hostsync, kernel_safety, metrics_pass, receipts,
    tokens,
)

PASSES = [hostsync, tokens, kernel_safety, metrics_pass, donation,
          receipts]

ALL_RULES = dict(core.RULES)   # framework rules (unused suppressions)
for _p in PASSES:
    ALL_RULES.update(_p.RULES)
