"""CLI: ``python -m tools.bamlint [paths...]``.

Exit codes (shared convention with ``tools.bamverify``): ``0`` when every
finding is suppressed inline or covered by the committed baseline (also
``--list-rules`` / ``--write-baseline``), ``1`` on findings, ``2`` on
usage errors (nonexistent paths) or parse errors.  A typo'd path must
not read as "clean".
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from tools.bamlint import ALL_RULES
from tools.bamlint.core import run, write_baseline

REPO_ROOT = pathlib.Path(__file__).resolve().parents[2]
DEFAULT_BASELINE = pathlib.Path(__file__).resolve().parent / "baseline.json"
DEFAULT_PATHS = ["src", "benchmarks", "examples"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.bamlint",
        description="BaM-repo static analysis (host-sync/retrace, token "
                    "lifecycle, kernel safety, metrics conservation).")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files or directories (default: "
                         f"{' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--baseline", type=pathlib.Path,
                    default=DEFAULT_BASELINE,
                    help="baseline file (default: tools/bamlint/"
                         "baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report baselined findings too")
    ap.add_argument("--no-suppress", action="store_true",
                    help="ignore inline `# bamlint: ignore[...]` comments")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept current findings into the baseline file")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in sorted(ALL_RULES):
            print(f"{rule}  {ALL_RULES[rule]}")
        return 0

    paths = args.paths or DEFAULT_PATHS
    missing = [p for p in paths
               if not (pathlib.Path(p) if pathlib.Path(p).is_absolute()
                       else REPO_ROOT / p).exists()]
    if missing:
        print(f"bamlint: no such path(s): {', '.join(missing)}",
              file=sys.stderr)
        return 2
    baseline_path = None if args.no_baseline else args.baseline
    new, old, errors = run(
        paths, REPO_ROOT, baseline_path=baseline_path,
        respect_suppressions=not args.no_suppress)

    for err in errors:
        print(err, file=sys.stderr)
    if errors:
        return 2

    if args.write_baseline:
        write_baseline(args.baseline, new + old)
        print(f"baseline: wrote {len(new) + len(old)} finding(s) to "
              f"{args.baseline}")
        return 0

    for f in new:
        print(f.render())
    if new:
        print(f"\nbamlint: {len(new)} finding(s)"
              + (f" ({len(old)} baselined)" if old else ""))
        return 1
    tail = f" ({len(old)} baselined)" if old else ""
    print(f"bamlint: clean{tail}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
