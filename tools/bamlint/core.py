"""bamlint core: findings, suppressions, baseline, module loading, runner.

The framework is deliberately stdlib-only (``ast`` + ``json``): the linter
must run in CI jobs that never install JAX, and it must never *import* the
code it checks — everything is static AST analysis.

A finding is identified for baseline purposes by ``(rule, file,
stripped-source-line)``, so a committed baseline survives unrelated line
drift but resurfaces the finding as soon as the offending line changes.

Suppression: a finding on line ``L`` is suppressed when line ``L`` or
``L-1`` carries ``# bamlint: ignore[RULE]`` (comma-separated rules, or
``*`` for all).
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

SUPPRESS_RE = re.compile(r"#\s*bamlint:\s*ignore\[([A-Za-z0-9_*,\s]+)\]")

# Framework-level rules (the passes own BAM1xx-BAM4xx; the suppression
# machinery itself owns this one).  BAM107 is deliberately *not*
# suppressible: an ignore-comment that matches nothing is dead armor —
# it reads as "this hazard is known" while hiding nothing today and a
# real regression tomorrow.
RULES = {
    "BAM107": "unused suppression: `# bamlint: ignore[...]` matches no "
              "finding on its own or the following line — delete it "
              "(stale armor silently swallows the next real finding)",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str                  # e.g. "BAM101"
    path: str                  # repo-relative posix path
    line: int                  # 1-based
    col: int                   # 0-based
    message: str
    code: str = ""             # stripped source line (baseline identity)

    @property
    def fingerprint(self) -> Tuple[str, str, str]:
        return (self.rule, self.path, self.code)

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}: " \
               f"{self.rule} {self.message}"


@dataclasses.dataclass
class ModuleInfo:
    """One parsed source file handed to every pass."""

    path: pathlib.Path         # absolute
    rel: str                   # repo-relative posix path
    source: str
    lines: List[str]
    tree: ast.Module

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Finding(rule=rule, path=self.rel, line=line, col=col,
                       message=message, code=self.line_text(line))


def load_module(path: pathlib.Path, root: pathlib.Path) -> ModuleInfo:
    source = path.read_text()
    try:
        rel = path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        rel = path.as_posix()
    tree = ast.parse(source, filename=str(path))
    return ModuleInfo(path=path, rel=rel, source=source,
                      lines=source.splitlines(), tree=tree)


# ------------------------------------------------------------ suppressions
def suppressed_rules_by_line(lines: Sequence[str]) -> Dict[int, Set[str]]:
    """Map 1-based line number -> set of rule ids suppressed on it."""
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(lines, start=1):
        m = SUPPRESS_RE.search(text)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            out[i] = rules
    return out


def suppressing_line(finding: Finding,
                     supp: Dict[int, Set[str]]) -> Optional[int]:
    """The 1-based line of the ignore-comment covering ``finding``, or
    ``None``.  Same-line comments win over line-above ones."""
    for line in (finding.line, finding.line - 1):
        rules = supp.get(line)
        if rules and (finding.rule in rules or "*" in rules):
            return line
    return None


def is_suppressed(finding: Finding, supp: Dict[int, Set[str]]) -> bool:
    return suppressing_line(finding, supp) is not None


# ----------------------------------------------------------------- baseline
def load_baseline(path: pathlib.Path) -> List[Tuple[str, str, str]]:
    """The baseline is a *multiset* of fingerprints (list with repeats)."""
    if not path.is_file():
        return []
    data = json.loads(path.read_text())
    return [(e["rule"], e["file"], e["code"])
            for e in data.get("findings", [])]


def write_baseline(path: pathlib.Path, findings: Iterable[Finding]) -> None:
    entries = [{"rule": f.rule, "file": f.path, "code": f.code}
               for f in sorted(findings,
                               key=lambda f: (f.path, f.line, f.rule))]
    path.write_text(json.dumps({"version": 1, "findings": entries},
                               indent=2) + "\n")


def apply_baseline(findings: Sequence[Finding],
                   baseline: Sequence[Tuple[str, str, str]]
                   ) -> Tuple[List[Finding], List[Finding]]:
    """Split findings into (new, baselined) — multiset semantics."""
    budget: Dict[Tuple[str, str, str], int] = {}
    for fp in baseline:
        budget[fp] = budget.get(fp, 0) + 1
    new, old = [], []
    for f in findings:
        if budget.get(f.fingerprint, 0) > 0:
            budget[f.fingerprint] -= 1
            old.append(f)
        else:
            new.append(f)
    return new, old


# ------------------------------------------------------------------- runner
FIXTURE_DIR_MARKER = ("bamlint", "fixtures")


def _is_fixture(path: pathlib.Path) -> bool:
    parts = path.parts
    for i in range(len(parts) - 1):
        if parts[i:i + 2] == FIXTURE_DIR_MARKER:
            return True
    return False


def collect_files(paths: Sequence[str],
                  root: pathlib.Path) -> List[pathlib.Path]:
    files: List[pathlib.Path] = []
    for p in paths:
        pp = pathlib.Path(p)
        if not pp.is_absolute():
            pp = root / pp
        if pp.is_file() and pp.suffix == ".py":
            files.append(pp)
        elif pp.is_dir():
            files.extend(sorted(
                f for f in pp.rglob("*.py")
                if "__pycache__" not in f.parts and not _is_fixture(f)))
    return files


def check_module(mod: ModuleInfo,
                 passes: Optional[Sequence] = None) -> List[Finding]:
    """Run every pass over one module; raw findings (no suppressions)."""
    from tools.bamlint import PASSES
    out: List[Finding] = []
    for p in (passes if passes is not None else PASSES):
        out.extend(p.check(mod))
    out.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return out


def check_file(path: pathlib.Path, root: pathlib.Path,
               passes: Optional[Sequence] = None,
               respect_suppressions: bool = True) -> List[Finding]:
    mod = load_module(path, root)
    findings = check_module(mod, passes)
    if respect_suppressions:
        supp = suppressed_rules_by_line(mod.lines)
        used: Set[int] = set()
        kept: List[Finding] = []
        for f in findings:
            line = suppressing_line(f, supp)
            if line is None:
                kept.append(f)
            else:
                used.add(line)
        findings = kept
        # BAM107: every ignore-comment must earn its keep.  Only
        # meaningful when suppressions are respected (under
        # --no-suppress nothing is "used", so nothing is "unused").
        for line in sorted(set(supp) - used):
            text = mod.lines[line - 1]
            m = SUPPRESS_RE.search(text)
            findings.append(Finding(
                rule="BAM107", path=mod.rel, line=line,
                col=m.start() if m else 0,
                message=RULES["BAM107"],
                code=mod.line_text(line)))
        findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def run(paths: Sequence[str], root: pathlib.Path,
        baseline_path: Optional[pathlib.Path] = None,
        respect_suppressions: bool = True,
        ) -> Tuple[List[Finding], List[Finding], List[str]]:
    """Lint ``paths``; returns ``(new_findings, baselined, parse_errors)``."""
    findings: List[Finding] = []
    errors: List[str] = []
    for f in collect_files(paths, root):
        try:
            findings.extend(check_file(
                f, root, respect_suppressions=respect_suppressions))
        except SyntaxError as e:
            errors.append(f"{f}: syntax error: {e}")
    baseline = load_baseline(baseline_path) if baseline_path else []
    new, old = apply_baseline(findings, baseline)
    return new, old, errors
