"""Pass 5 — buffer-donation lifetime: state used after a donating call.

The jit-cached op families (``submit_jit``/``wait_jit``/``read_jit`` on
``BamArray`` and ``BamRuntime``) accept ``donate=True`` to donate the
state argument's buffers to the output (``jax.jit(...,
donate_argnums=(0,))``).  Donation is an ownership transfer: after the
call, the caller's ``BamState``/``CacheState``/``QueueState`` value
aliases *dead* buffers — touching it raises ``RuntimeError: Array has
been deleted`` on CPU/TPU, or silently reads clobbered memory where the
runtime reuses the allocation eagerly.  The only valid pattern is to
rebind the state from the call's own result::

    step = arr.submit_jit(donate=True)
    st, tok = step(st, req)      # OK — `st` rebound by the same statement
    ...
    vals = step(st, req)         # BAM106 if a later line still reads the
                                 # pre-call `st`

Rules
-----
BAM106  a ``CacheState``/``QueueState``-carrying value is read after
        being passed to a donating ``*_jit(donate=True)`` call without
        being rebound from that call's result.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from tools.bamlint.core import Finding, ModuleInfo
from tools.bamlint.reach import FuncNode, dotted, tail

RULES = {
    "BAM106": "state value used after donation to a *_jit(donate=True) "
              "call",
}


def _is_donating_jit_factory(call: ast.Call) -> bool:
    """True for ``<expr>.*_jit(..., donate=True, ...)``."""
    if not tail(dotted(call.func)).endswith("_jit"):
        return False
    for kw in call.keywords:
        if kw.arg == "donate" and isinstance(kw.value, ast.Constant) \
                and kw.value.value is True:
            return True
    return False


def _stores(stmt: ast.stmt) -> Set[str]:
    """Names (re)bound by this statement's own targets."""
    out: Set[str] = set()
    targets: List[ast.expr] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        targets = [i.optional_vars for i in stmt.items if i.optional_vars]
    for t in targets:
        for n in ast.walk(t):
            if isinstance(n, ast.Name):
                out.add(n.id)
    return out


def _own_exprs(stmt: ast.stmt) -> List[ast.AST]:
    """Expressions evaluated by the statement *itself*, excluding nested
    blocks — those are scanned in order by the block recursion."""
    if isinstance(stmt, (ast.If, ast.While)):
        return [stmt.test]
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return [stmt.iter]
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        return [i.context_expr for i in stmt.items]
    if isinstance(stmt, ast.Try):
        return []
    return [stmt]


class _FnChecker:
    """Linear statement-order scan of one function body.

    ``donating``: local names bound to a donating callable
    (``step = arr.submit_jit(donate=True)``).
    ``consumed``: names whose buffers were donated and not yet rebound;
    maps name -> line of the consuming call (for the message).
    """

    def __init__(self, mod: ModuleInfo, fn: ast.AST):
        self.mod = mod
        self.fn = fn
        self.donating: Set[str] = set()
        self.consumed: Dict[str, int] = {}
        self.out: List[Finding] = []

    def run(self) -> List[Finding]:
        body = getattr(self.fn, "body", [])
        if isinstance(body, ast.expr):  # lambda
            body = []
        self._scan_block(body)
        return self.out

    # -- block / statement traversal (source order) ----------------------

    def _scan_block(self, stmts: List[ast.stmt]) -> None:
        for stmt in stmts:
            self._scan_stmt(stmt)

    def _scan_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, FuncNode + (ast.ClassDef,)):
            return  # nested scopes get their own checker
        for expr in _own_exprs(stmt):
            # 1. loads of names consumed by an *earlier* statement
            self._flag_loads(expr)
            # 2. consumptions performed by this statement's calls
            self._record_consumption(expr, stmt)
        # 3. donating-callable bindings and rebinds
        self._record_bindings(stmt)
        # recurse into compound statements; exclusive branches each see a
        # copy of the state and the results are unioned (conservative).
        if isinstance(stmt, (ast.If, ast.Try)):
            blocks = []
            if isinstance(stmt, ast.If):
                blocks = [stmt.body, stmt.orelse]
            else:
                blocks = [stmt.body, stmt.orelse, stmt.finalbody] + [
                    h.body for h in stmt.handlers]
            merged: Dict[str, int] = {}
            base = dict(self.consumed)
            for blk in blocks:
                self.consumed = dict(base)
                self._scan_block(blk)
                merged.update(self.consumed)
            self.consumed = merged
        elif isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            self._scan_block(stmt.body)
            self._scan_block(stmt.orelse)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            self._scan_block(stmt.body)

    # -- per-statement pieces --------------------------------------------

    def _flag_loads(self, expr: ast.AST) -> None:
        if not self.consumed:
            return
        for node in ast.walk(expr):
            if isinstance(node, FuncNode):
                continue
            if isinstance(node, ast.Name) and \
                    isinstance(node.ctx, ast.Load) and \
                    node.id in self.consumed:
                self.out.append(self.mod.finding(
                    "BAM106", node,
                    f"`{node.id}` was donated to a *_jit(donate=True) "
                    f"call on line {self.consumed[node.id]}; its buffers "
                    "are dead — rebind the state from that call's result "
                    "(`st, tok = step(st, ...)`) before using it again"))
                # one report per consumption: further loads of the same
                # dead name add noise, not information.
                del self.consumed[node.id]
                if not self.consumed:
                    return

    def _record_consumption(self, expr: ast.AST, stmt: ast.stmt) -> None:
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call) or not node.args:
                continue
            donating = False
            f = node.func
            if isinstance(f, ast.Name) and f.id in self.donating:
                donating = True                       # step(st, req)
            elif isinstance(f, ast.Call) and _is_donating_jit_factory(f):
                donating = True    # arr.submit_jit(donate=True)(st, req)
            if donating and isinstance(node.args[0], ast.Name):
                name = node.args[0].id
                # a same-statement rebind (`st, tok = step(st, req)`)
                # hands ownership straight back — not a hazard.
                if name not in _stores(stmt):
                    self.consumed[name] = node.lineno

    def _record_bindings(self, stmt: ast.stmt) -> None:
        stored = _stores(stmt)
        # any rebind revives the name (fresh value, live buffers)
        for name in stored:
            self.consumed.pop(name, None)
        if isinstance(stmt, ast.Assign) and \
                isinstance(stmt.value, ast.Call) and \
                _is_donating_jit_factory(stmt.value):
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name):
                    self.donating.add(tgt.id)
        elif stored:
            # a name rebound to something else is no longer a donating
            # callable.
            self.donating -= stored


def check(mod: ModuleInfo) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.extend(_FnChecker(mod, node).run())
    return out
