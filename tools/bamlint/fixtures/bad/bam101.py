# bamlint-fixture: expect BAM101
# A host sync on the jit-traced request path: serializes the submission
# window.  Never imported — parsed by tools.bamlint only.
import jax


@jax.jit
def hot_read(st, idx):
    vals = compute(st, idx)
    vals.block_until_ready()
    return vals
