# bamlint-fixture: expect BAM102
# Host transfer of a traced value inside jit-reachable code.
import jax


@jax.jit
def hot_sum(x):
    return x.sum().item()
