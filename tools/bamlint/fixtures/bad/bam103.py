# bamlint-fixture: expect BAM103
# Debug print left inside a Pallas kernel body.
import jax
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    jax.debug.print("x = {}", x_ref[0])
    o_ref[0] = x_ref[0]


def run(x):
    return pl.pallas_call(_kernel, grid=(1,))(x)
