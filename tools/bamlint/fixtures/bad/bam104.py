# bamlint-fixture: expect BAM104
# Python control flow on a traced value: retraces per value (or raises).
import jax


@jax.jit
def clamp(x):
    if x > 0:
        return x + 1
    return x - 1
