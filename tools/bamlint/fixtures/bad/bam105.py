# bamlint-fixture: expect BAM105
# A fresh jax.jit wrapper per call defeats the compilation cache.
import jax


def driver(arr, st, idx):
    read = jax.jit(arr.read)
    v, st = read(st, idx)
    return v, st
