# bamlint-fixture: expect BAM106
# State read after its buffers were donated to a *_jit(donate=True) call.
# Never imported — parsed by tools.bamlint only.


def donated_state_reused(arr, st, req, req2):
    step = arr.submit_jit(donate=True)
    st2, tok = step(st, req)          # donates st's buffers
    vals = arr.wait(st, tok)          # BAM106: st is dead here
    return st2, vals


def inline_donating_call(arr, st, req):
    tok = arr.submit_jit(donate=True)(st, req)[1]   # donates st
    return st.cache, tok              # BAM106: st is dead here
