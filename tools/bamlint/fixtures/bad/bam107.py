# bamlint-fixture: expect BAM107
"""An ignore-comment that matches no finding on its own or the next line.

The function below is lint-clean, so both suppressions are dead armor:
the rule they name can never fire here, and BAM107 flags each one.
BAM107 itself is not suppressible — an ``ignore[BAM107]`` comment that
matches nothing is just another unused suppression.
"""


def tidy(values):
    # bamlint: ignore[BAM101]
    total = 0
    for v in values:  # bamlint: ignore[*]
        total += v
    return total
