# bamlint-fixture: expect BAM108
# Three discard shapes: the bare statement loses the queue state AND the
# receipt; the '_' binding and the [0] subscript keep the state but make
# the drop/error accounting unreadable.
import repro.core.queues as Q


def bare_statement(qs, keys):
    Q.enqueue(qs, keys)
    return qs


def underscore_binding(qs, keys):
    qs, _ = Q.enqueue(qs, keys)
    return qs


def subscript_peel(qs):
    qs = Q.drain_accounting(qs)[0]
    return qs
