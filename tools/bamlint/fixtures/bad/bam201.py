# bamlint-fixture: expect BAM201
# The submit's token is dropped: its cache pins are never released.
def leak(arr, st, req):
    st, tok = arr.submit(st, req)
    return st
