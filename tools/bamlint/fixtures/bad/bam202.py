# bamlint-fixture: expect BAM202
# The same token is waited twice on one path: pin refcount underflow.
def double_wait(arr, st, req):
    st, tok = arr.submit(st, req)
    st, first = arr.wait(st, tok)
    st, again = arr.wait(st, tok)
    return st, first, again
