# bamlint-fixture: expect BAM203
# acquire() takes cache pins but nothing ever releases them.
from repro.core import cache as C


def pin_forever(cache, slots):
    cache2 = C.acquire(cache, slots)
    return transform(cache2)
