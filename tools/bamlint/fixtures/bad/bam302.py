# bamlint-fixture: expect BAM302
# Kernel stores into an input ref with no input_output_aliases entry.
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _k(x_ref, o_ref):
    x_ref[0] = x_ref[0] * 2
    o_ref[...] = x_ref[...]


def run(x):
    return pl.pallas_call(
        _k,
        grid=(1,),
        in_specs=[pl.BlockSpec((8,), lambda i: (0,))],
        out_specs=pl.BlockSpec((8,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
    )(x)
