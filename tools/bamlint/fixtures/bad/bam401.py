# bamlint-fixture: expect BAM401
# WATERMARK_FIELDS names a field that the dataclass does not declare.
class IOMetrics:
    requests: object
    max_depth: object

    @staticmethod
    def zeros():
        return IOMetrics(requests=0, max_depth=0)

    def summary(self):
        return {"requests": self.requests, "max_depth": self.max_depth}


WATERMARK_FIELDS = ("max_queue_depth",)
