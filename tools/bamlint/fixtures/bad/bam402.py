# bamlint-fixture: expect BAM402
# A declared counter never surfaces in summary(): collected, unobservable.
class IOMetrics:
    requests: object
    dropped: object

    @staticmethod
    def zeros():
        return IOMetrics(requests=0, dropped=0)

    def summary(self):
        return {"requests": float(self.requests)}


WATERMARK_FIELDS = ()
ADDITIVE_FIELDS = ("requests", "dropped")
