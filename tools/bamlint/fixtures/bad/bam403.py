# bamlint-fixture: expect BAM403
# zeros() forgets a field: IOMetrics(...) construction is incomplete.
class IOMetrics:
    requests: object
    dropped: object

    @staticmethod
    def zeros():
        return IOMetrics(requests=0)

    def summary(self):
        return {"requests": self.requests, "dropped": self.dropped}


WATERMARK_FIELDS = ()
