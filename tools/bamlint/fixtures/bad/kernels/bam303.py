# bamlint-fixture: expect BAM303
# dtype-less constructor in a kernels module: float64 under x64.
import jax.numpy as jnp


def accumulator(n):
    return jnp.zeros((n, 4))
