# Donation-correct usage: every donating call rebinds the state from its
# own result before the next use.  Never imported — parsed by bamlint only.


def threaded_round(arr, st, reqs):
    submit = arr.submit_jit(donate=True)
    wait = arr.wait_jit(donate=True)
    out = []
    for req in reqs:
        st, tok = submit(st, req)     # same-statement rebind: OK
        st, vals = wait(st, tok)
        out.append(vals)
    return st, out


def plain_jit_is_not_donating(arr, st, req):
    step = arr.submit_jit()           # no donate=True: st stays live
    st2, tok = step(st, req)
    vals = arr.wait(st, tok)          # fine — old state still valid
    return st2, vals


def rebind_revives(arr, st, req):
    step = arr.submit_jit(donate=True)
    st2, tok = step(st, req)
    st = st2                          # explicit rebind before reuse
    return arr.wait(st, tok)
