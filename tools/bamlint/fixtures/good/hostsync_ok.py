# bamlint-fixture: clean
# Idiomatic hot path: jit-cached wrappers, lax control flow, host sync
# only outside jit-reachable code.
import jax
import jax.numpy as jnp


@jax.jit
def hot_step(st, x):
    y = jnp.where(x > 0, x + 1, x - 1)
    return st, y


def driver(arr, st, idx, n_iters: int):
    read = arr.read_jit()
    for _ in range(n_iters):
        vals, st = read(st, idx)
    vals.block_until_ready()
    return float(vals.sum()), st
